//! §VII walkthrough: "beyond simulation" — diagnose Fused-MoE
//! underperformance with a P80 ceiling model and close the gap by
//! brute-force tuning (BLOCK_SIZE, num_stages, num_warps).
//!
//!   cargo run --release --example tune_fused_moe
//!
//! Requires `make artifacts` (the P80 model is an AOT pinball-loss MLP).

use synperf::autotune;
use synperf::dataset;
use synperf::experiments::{Lab, ModelFlavor, Scale};
use synperf::hw;
use synperf::kernels::KernelKind;
use synperf::util::stats::{geomean, mean};

fn main() -> anyhow::Result<()> {
    let lab = Lab::new(Scale::Fast)?;
    println!("training / loading the P80 ceiling model (pinball loss tau=0.8)...");
    let p80 = lab.model(KernelKind::FusedMoe, ModelFlavor::P80)?;
    let ds = lab.dataset(KernelKind::FusedMoe);
    let configs = lab.dataset_configs(KernelKind::FusedMoe);

    let records = autotune::diagnose(&p80, &ds)?;
    let n_under = records.iter().filter(|r| r.underperforming()).count();
    println!(
        "diagnosed {} / {} samples as Underperforming Points (gap > {})",
        n_under,
        records.len(),
        autotune::GAP_THRESHOLD
    );

    let gpu = hw::gpu_by_name("A40").unwrap();
    let n_gpus = hw::all_gpus().len();
    let targets: Vec<usize> = records
        .iter()
        .enumerate()
        .filter(|(_, r)| r.gpu == gpu.name && r.underperforming())
        .map(|(i, _)| i)
        .take(8)
        .collect();
    println!("\ntuning {} diagnosed configs on {}:", targets.len(), gpu.name);
    let mut speedups = Vec::new();
    let mut gaps_before = Vec::new();
    for &si in &targets {
        let cfg = dataset::finalize_for_gpu(&configs[si / n_gpus], &gpu);
        let r = autotune::tune(&cfg, &gpu, si as u64)?;
        println!(
            "  gap {:.3}: {:.1} us -> {:.1} us ({:.2}x) with {:?}",
            records[si].gap,
            r.default_sec * 1e6,
            r.best_sec * 1e6,
            r.speedup(),
            r.best_cfg
        );
        speedups.push(r.speedup());
        gaps_before.push(records[si].gap);
    }
    println!(
        "\ngeo-mean speedup {:.2}x on points with mean gap {:.3}",
        geomean(&speedups),
        mean(&gaps_before)
    );
    Ok(())
}
