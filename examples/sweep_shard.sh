#!/usr/bin/env bash
# Drive the crash-safe sweep surface from a clean checkout, five ways:
#  1. the same campaign split over `--shard 0/3 1/3 2/3` with per-shard
#     journals, merged back by `sweep-merge` — byte-identical to the
#     one-process run (either journal order);
#  2. a journaled run SIGKILLed mid-sweep (the SYNPERF_SWEEP_STALL_MS
#     test hook wedges one point), then `--resume`d — byte-identical to
#     the uninterrupted run, and re-running without `--resume` refuses
#     to clobber the journal;
#  3. panic containment and the point watchdog: injected failures become
#     typed `internal` / `timeout` rows, never aborts;
#  4. procurement constraints: `max_gpus` turns over-budget points into
#     typed `constraint_violated` rows, and every feasible row carries
#     `usd_per_hour`/`usd_per_mtok` from the registry's cost columns;
#  5. the typed merge failures: a missing shard is `merge_incomplete`, a
#     duplicated shard is `merge_conflict`.
# Without trained artifacts everything answers in degraded roofline mode.
set -euo pipefail
cd "$(dirname "$0")/.."

# invoke the built binary directly (not through `cargo run`): leg 2
# SIGKILLs the sweep process, and killing a cargo wrapper would orphan
# the actual synperf child mid-campaign
cargo build --release --quiet --bin synperf
RUN="target/release/synperf"
WORK=$(mktemp -d "${TMPDIR:-/tmp}/synperf_sweep_shard.XXXXXX")
trap 'rm -rf "$WORK"' EXIT

# 3 GPUs x tp {1,2} = 6 points, all feasible
SPEC='{"gpus":["A100","H800","L20"],"tp":[1,2],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}'
printf '%s\n' "$SPEC" > "$WORK/spec.jsonl"

GOLDEN=$($RUN sweep --spec "$WORK/spec.jsonl" --threads 1 --json)

# 1. shard the campaign across three processes, then merge the journals
for i in 0 1 2; do
  $RUN sweep --spec "$WORK/spec.jsonl" --shard "$i/3" \
    --journal "$WORK/shard$i.jsonl" --json > /dev/null
done
MERGED=$($RUN sweep-merge "$WORK/shard0.jsonl" "$WORK/shard1.jsonl" "$WORK/shard2.jsonl" --json)
[ "$MERGED" = "$GOLDEN" ] \
  || { echo "FAIL: sweep-merge must reproduce the one-process bytes"; exit 1; }
SHUFFLED=$($RUN sweep-merge "$WORK/shard2.jsonl" "$WORK/shard0.jsonl" "$WORK/shard1.jsonl" --json)
[ "$SHUFFLED" = "$GOLDEN" ] \
  || { echo "FAIL: merge must not depend on journal argument order"; exit 1; }

# 2. SIGKILL a journaled run mid-sweep, then resume. The stall hook
# wedges index 2 (serial path: rows 0 and 1 are already fsync'd), so the
# kill provably lands mid-campaign.
SYNPERF_SWEEP_STALL_MS=2:120000 $RUN sweep --spec "$WORK/spec.jsonl" \
  --journal "$WORK/resume.jsonl" --threads 1 --json > /dev/null &
PID=$!
for _ in $(seq 1 1200); do
  lines=$(wc -l < "$WORK/resume.jsonl" 2>/dev/null || echo 0)
  [ "$lines" -ge 3 ] && break
  kill -0 "$PID" 2>/dev/null || { echo "FAIL: journaled sweep died early"; exit 1; }
  sleep 0.1
done
[ "$lines" -ge 3 ] || { echo "FAIL: journal never reached header + 2 rows"; exit 1; }
kill -9 "$PID"
wait "$PID" 2>/dev/null || true

RESUMED=$($RUN sweep --spec "$WORK/spec.jsonl" --journal "$WORK/resume.jsonl" --resume --threads 1 --json)
[ "$RESUMED" = "$GOLDEN" ] \
  || { echo "FAIL: resumed run must be byte-identical to the uninterrupted run"; exit 1; }
[ "$(wc -l < "$WORK/resume.jsonl")" -eq 7 ] \
  || { echo "FAIL: resumed journal must hold header + all 6 rows"; exit 1; }
if $RUN sweep --spec "$WORK/spec.jsonl" --journal "$WORK/resume.jsonl" --json > /dev/null 2>&1; then
  echo "FAIL: an existing journal without --resume must refuse to clobber"; exit 1
fi

# 3. injected failures become typed rows, never aborts
PANIC_OUT=$(SYNPERF_SWEEP_PANIC_INDEX=3 $RUN sweep --spec "$WORK/spec.jsonl" --json)
printf '%s\n' "$PANIC_OUT" | grep '"index":3,' | grep -q '"code":"internal"' \
  || { echo "FAIL: contained panic must surface as a typed internal row"; exit 1; }
[ "$(printf '%s\n' "$PANIC_OUT" | grep -c '"ok":true')" -eq 5 ] \
  || { echo "FAIL: a contained panic must not take out healthy rows"; exit 1; }
TIMEOUT_OUT=$(SYNPERF_SWEEP_STALL_MS=1:120000 $RUN sweep --spec "$WORK/spec.jsonl" \
  --point-timeout-ms 250 --threads 2 --json)
printf '%s\n' "$TIMEOUT_OUT" | grep '"index":1,' | grep -q '"code":"timeout"' \
  || { echo "FAIL: the watchdog must convert a wedged point into a timeout row"; exit 1; }

# 4. hard procurement constraints: tp=2 points (2 GPUs) violate max_gpus=1
COST='{"gpus":["A100","H800"],"tp":[1,2],"constraints":{"max_gpus":1},"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}'
COST_OUT=$(printf '%s\n' "$COST" | $RUN sweep --spec - --json)
[ "$(printf '%s\n' "$COST_OUT" | grep -c '"code":"constraint_violated"')" -eq 2 ] \
  || { echo "FAIL: expected 2 constraint_violated rows under max_gpus=1"; exit 1; }
printf '%s\n' "$COST_OUT" | grep '"ok":true' | grep -q '"usd_per_mtok":' \
  || { echo "FAIL: feasible rows must carry the cost columns"; exit 1; }
printf '%s\n' "$COST_OUT" | tail -1 | grep -q '"usd_per_mtok":' \
  || { echo "FAIL: frontier entries must carry usd_per_mtok"; exit 1; }

# 5. the typed merge failures
INCOMPLETE=$($RUN sweep-merge "$WORK/shard0.jsonl" "$WORK/shard1.jsonl" --json)
printf '%s\n' "$INCOMPLETE" | grep -q '"code":"merge_incomplete"' \
  || { echo "FAIL: a missing shard must be merge_incomplete"; exit 1; }
CONFLICT=$($RUN sweep-merge "$WORK/shard0.jsonl" "$WORK/shard0.jsonl" "$WORK/shard1.jsonl" --json)
printf '%s\n' "$CONFLICT" | grep -q '"code":"merge_conflict"' \
  || { echo "FAIL: a duplicated shard must be merge_conflict"; exit 1; }

echo "sweep_shard: all assertions passed"
