//! TCP load generator for `synperf serve --tcp`: N concurrent
//! connections each pipeline M JSONL predict requests and read every
//! response back, tallying ok/error lines and overall throughput.
//!
//!   # terminal 1
//!   cargo run --release --bin synperf -- serve --tcp 127.0.0.1:7411
//!   # terminal 2
//!   cargo run --release --example load_gen -- 127.0.0.1:7411 8 50
//!
//! Exits non-zero if any connection fails or any request goes
//! unanswered — the serving contract is exactly one response per line,
//! in order, per connection.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::Instant;

struct Tally {
    ok: usize,
    errors: usize,
}

fn drive(addr: &str, client: usize, requests: usize) -> anyhow::Result<Tally> {
    let stream = TcpStream::connect(addr)?;
    let reader = stream.try_clone()?;
    let mut tally = Tally { ok: 0, errors: 0 };
    std::thread::scope(|s| -> anyhow::Result<()> {
        let writer = s.spawn(move || -> std::io::Result<()> {
            // pipeline every request; a mix of shapes so the shared
            // engine cache sees both hits and misses
            let mut w = BufWriter::new(stream);
            for j in 0..requests {
                writeln!(
                    w,
                    "{{\"id\":\"c{client}-r{j}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                     \"seq\":{},\"dim\":{}}}}}",
                    1024 + (j % 16) * 64,
                    2048 + client * 256
                )?;
            }
            w.flush()
            // the write half stays open: the reader below stops after
            // `requests` lines, so no half-close choreography is needed
        });
        let mut lines = BufReader::new(reader);
        let mut line = String::new();
        for j in 0..requests {
            line.clear();
            let n = lines.read_line(&mut line)?;
            anyhow::ensure!(
                n > 0,
                "connection {client}: EOF after {j} of {requests} responses"
            );
            if line.contains("\"ok\":true") {
                tally.ok += 1;
            } else {
                tally.errors += 1;
            }
        }
        writer.join().expect("writer thread")?;
        Ok(())
    })?;
    Ok(tally)
}

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let addr = args.next().unwrap_or_else(|| "127.0.0.1:7411".to_string());
    let clients: usize = match args.next() {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!("bad client count: {s}"))?,
        None => 8,
    };
    let requests: usize = match args.next() {
        Some(s) => s.parse().map_err(|_| anyhow::anyhow!("bad request count: {s}"))?,
        None => 50,
    };

    let t0 = Instant::now();
    let tallies = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let addr = addr.as_str();
                s.spawn(move || drive(addr, c, requests))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("client thread"))
            .collect::<anyhow::Result<Vec<Tally>>>()
    })?;
    let secs = t0.elapsed().as_secs_f64();

    let ok: usize = tallies.iter().map(|t| t.ok).sum();
    let errors: usize = tallies.iter().map(|t| t.errors).sum();
    let total = clients * requests;
    println!(
        "load_gen: {clients} clients x {requests} requests -> {} responses in {secs:.3}s \
         ({:.0} req/s): {ok} ok, {errors} errors",
        ok + errors,
        total as f64 / secs.max(1e-9),
    );
    anyhow::ensure!(
        ok + errors == total,
        "answered {} of {total} requests",
        ok + errors
    );
    Ok(())
}
