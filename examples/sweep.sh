#!/usr/bin/env bash
# Drive the hardware-sweep surface from a clean checkout, four ways:
#  1. `synperf sweep --spec -`: a small 3-GPU x 2-tp grid over a scenario
#     and a cluster workload — streamed JSONL rows (infeasible points as
#     typed error rows, not aborts), a frontier line, and a byte-identity
#     diff of stdout at --threads 1 vs --threads 8;
#  2. the acceptance grid: all 11 registry GPUs x tp {1,2} x replicas
#     {1,2} x 2 workloads = 88 points through one spec line;
#  3. spec-level errors: an unknown GPU aborts before any row, with
#     nearest-name suggestions in the message;
#  4. the same sweep request over `serve --stdio` (rows + frontier embed
#     in one response line), plus `synperf gpus` listing the registry.
# Without trained artifacts everything answers in degraded roofline mode.
set -euo pipefail
cd "$(dirname "$0")/.."

RUN="cargo run --release --quiet --bin synperf --"

# 1. small grid: 2 workloads x 3 GPUs x tp {1,3} = 12 rows; tp=3 does not
# divide llama3.1-8b's 32 attention heads, so half the grid is infeasible
SMALL='{"v":1,"id":"sw1","op":"sweep","sweep":{"gpus":["A100","H800","L20"],"tp":[1,3],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}},{"name":"batch","cluster":{"model":"llama3.1-8b","arrivals":{"trace":[[0.0,64,8],[0.01,96,8]]},"max_batch":4,"kv_capacity_tokens":4096,"seed":7}}]}}'

T1=$(printf '%s\n' "$SMALL" | $RUN sweep --spec - --threads 1 --json)
T8=$(printf '%s\n' "$SMALL" | $RUN sweep --spec - --threads 8 --json)
printf '%s\n' "$T1"

lines=$(printf '%s\n' "$T1" | wc -l | tr -d ' ')
[ "$lines" -eq 13 ] || { echo "FAIL: expected 12 rows + 1 frontier line, got $lines"; exit 1; }
rows_ok=$(printf '%s\n' "$T1" | grep -c '"row":{.*"ok":true' || true)
rows_err=$(printf '%s\n' "$T1" | grep -c '"row":{.*"ok":false' || true)
[ "$rows_ok" -eq 6 ] || { echo "FAIL: expected 6 feasible rows, got $rows_ok"; exit 1; }
[ "$rows_err" -eq 6 ] || { echo "FAIL: expected 6 infeasible rows, got $rows_err"; exit 1; }
printf '%s\n' "$T1" | grep '"ok":false' | grep -q '"code":"invalid_parallelism"' \
  || { echo "FAIL: infeasible points must carry the typed ScenarioError"; exit 1; }
printf '%s\n' "$T1" | tail -1 | grep -q '"frontier":\[{"rank":1,' \
  || { echo "FAIL: frontier line missing or unranked"; exit 1; }
printf '%s\n' "$T1" | tail -1 | grep -q '"dominated":\[' \
  || { echo "FAIL: dominated-by annotations missing"; exit 1; }

# the sweep contract: stdout (rows + frontier) is byte-identical across
# thread counts — work stealing may reorder evaluation, never output
[ "$T1" = "$T8" ] \
  || { echo "FAIL: sweep rows must be byte-identical across --threads 1 vs 8"; exit 1; }

# 2. the acceptance grid: the whole registry x tp {1,2} x replicas {1,2}
# x 2 workloads = 88 points (>= 50), every one feasible, one spec line
BIG='{"gpus":"all","tp":[1,2],"replicas":[1,2],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}},{"name":"long","scenario":{"model":"llama3.1-8b","workload":{"requests":[[96,8]]},"seed":5}}]}'
BIG_OUT=$(printf '%s\n' "$BIG" | $RUN sweep --spec - --threads 8 --json)
big_rows=$(printf '%s\n' "$BIG_OUT" | grep -c '"row":{' || true)
[ "$big_rows" -eq 88 ] || { echo "FAIL: expected 88 grid rows, got $big_rows"; exit 1; }
big_ok=$(printf '%s\n' "$BIG_OUT" | grep -c '"ok":true' || true)
[ "$big_ok" -eq 88 ] || { echo "FAIL: all 88 points should be feasible, got $big_ok"; exit 1; }
# unseen (held-out) GPUs sweep alongside the training split
printf '%s\n' "$BIG_OUT" | grep -q '"gpu":"RTX PRO 6000 S"' \
  || { echo "FAIL: held-out GPUs missing from the all-registry sweep"; exit 1; }
printf '%s\n' "$BIG_OUT" | tail -1 | grep -q '"frontier":\[{"rank":1,' \
  || { echo "FAIL: acceptance-grid frontier missing"; exit 1; }

# 3. spec-level errors abort before any row, with nearest-name hints
ERR_OUT=$(printf '%s\n' '{"id":"bad","gpus":["B300"],"workloads":[{"scenario":{"model":"llama3.1-8b"}}]}' \
  | $RUN sweep --spec - --json)
[ "$(printf '%s\n' "$ERR_OUT" | wc -l | tr -d ' ')" -eq 1 ] \
  || { echo "FAIL: spec-level error must be exactly one line"; exit 1; }
printf '%s\n' "$ERR_OUT" | grep -q '"id":"bad","ok":false,"error":{"code":"unknown_gpu"' \
  || { echo "FAIL: unknown_gpu spec error missing"; exit 1; }
printf '%s\n' "$ERR_OUT" | grep -q 'closest: A100, H800, H100' \
  || { echo "FAIL: nearest-name suggestions missing from unknown_gpu"; exit 1; }

# 4a. the same request over the stdio wire: one response line embedding
# rows + frontier, interleaved with the predict verb
WIRE_OUT=$(printf '%s\n' \
  '{"v":1,"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":512,"n":512,"k":512}}' \
  "$SMALL" \
  | $RUN serve --stdio --queue-cap 64)
printf '%s\n' "$WIRE_OUT" | grep -q '"id":"p1","ok":true' \
  || { echo "FAIL: predict verb broken next to sweep"; exit 1; }
printf '%s\n' "$WIRE_OUT" | grep '"id":"sw1"' | grep -q '"ok":true,"sweep":{"rows":\[' \
  || { echo "FAIL: stdio sweep response missing embedded rows"; exit 1; }
printf '%s\n' "$WIRE_OUT" | grep '"id":"sw1"' | grep -q '"frontier":\[' \
  || { echo "FAIL: stdio sweep response missing frontier"; exit 1; }

# 4b. the registry listing sweep specs are authored against
GPUS_OUT=$($RUN gpus)
printf '%s\n' "$GPUS_OUT" | grep -q '11 GPUs: 6 seen (training split), 5 unseen (held out)' \
  || { echo "FAIL: gpus verb must summarize the 6/5 registry split"; exit 1; }
printf '%s\n' "$GPUS_OUT" | grep -q 'RTX PRO 6000 S' \
  || { echo "FAIL: gpus verb must list the Blackwell part"; exit 1; }

echo "sweep: all assertions passed"
