//! Quickstart: the full SynPerf pipeline on a single kernel.
//!
//!   cargo run --release --example quickstart
//!
//! Decomposes a cuBLAS-style GEMM into tasks (Kernel Decomposer), maps them
//! onto SMs (Scheduling Simulator), derives the Table-IV pipeline features
//! (Feature Analyzer), and — if `make artifacts` has produced the AOT MLP —
//! trains a small Performance Estimator and predicts latency, comparing
//! against the oracle testbed.

use synperf::dataset;
use synperf::features::FeatureSet;
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::mlp::{train_model, Predictor, TrainConfig};
use synperf::runtime::Engine;
use synperf::sched::schedule;
use synperf::util::stats::mape;

fn main() -> anyhow::Result<()> {
    let gpu = hw::gpu_by_name("A100").unwrap();
    let cfg = KernelConfig::Gemm { m: 4096, n: 11008, k: 4096, dtype: DType::Bf16 };

    // 1. Kernel Decomposer: F(X, S) -> tasks
    let decomp = cfg.decompose(&gpu);
    println!("decomposed into {} tasks, tile {:?}", decomp.num_tasks(), decomp.tile);

    // 2. Scheduling Simulator: M(T, S) -> task distribution
    let dist = schedule(&decomp, &gpu);
    println!(
        "scheduled across {} SMs (max {} tasks on one SM)",
        dist.num_sms(),
        dist.assignment.iter().map(|v| v.len()).max().unwrap()
    );

    // 3. Feature Analyzer: pipeline demands + theoretical cycles
    let f = FeatureSet::analyze(&decomp, &dist, &gpu);
    println!(
        "tensor roof {:.0} cycles | DRAM roof {:.0} cycles | theory {:.1} us",
        f.tensor.total_cycles,
        f.mio.cycles_dram,
        f.theory_sec * 1e6
    );

    // 4. Performance Estimator: train a small MLP via the AOT PJRT artifact
    let Ok(engine) = Engine::from_env() else {
        println!("(run `make artifacts` to enable the MLP stage — stopping at features)");
        return Ok(());
    };
    println!("building a small training set (this takes ~10s)...");
    let ds = dataset::build(KernelKind::Gemm, &hw::seen_gpus(), 150, 1, 8);
    let xs: Vec<_> = ds.iter().map(|s| s.x).collect();
    let ys: Vec<f64> = ds.iter().map(|s| s.efficiency()).collect();
    let model = train_model(
        &engine,
        &xs,
        &ys,
        &TrainConfig { max_steps: 500, val_every: 100, ..Default::default() },
    )?;
    let pred = Predictor::new(&engine, model.weights)?;

    let sample = dataset::make_sample(&cfg, &gpu, 7);
    let eff = pred.predict_eff(&[sample.x])?[0];
    println!("predicted efficiency {eff:.3}");
    println!("predicted latency    {:.1} us", sample.theory_sec / eff * 1e6);
    println!("testbed ground truth {:.1} us", sample.latency_sec * 1e6);

    // sanity: the trained model should beat the naive roofline on this set
    let effs = pred.predict_eff(&xs)?;
    let lat_pred: Vec<f64> = ds.iter().zip(&effs).map(|(s, e)| s.theory_sec / e).collect();
    let lat_true: Vec<f64> = ds.iter().map(|s| s.latency_sec).collect();
    let roof: Vec<f64> = ds.iter().map(|s| s.roofline_sec).collect();
    println!(
        "train-set MAPE: SynPerf {:.1}% vs roofline {:.1}%",
        mape(&lat_pred, &lat_true),
        mape(&roof, &lat_true)
    );
    Ok(())
}
