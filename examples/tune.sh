#!/usr/bin/env bash
# Drive the autotune surface (§VII: ceiling-guided fused-MoE kernel
# search) from a clean checkout, four ways:
#  1. `synperf tune --spec -`: 8 sampled fused-MoE launches on the A40,
#     diagnosed against the potential-performance ceiling and brute-force
#     tuned over (BLOCK_SIZE, num_stages, num_warps) — streamed JSONL rows
#     plus a summary line, with a byte-identity diff of stdout at
#     --threads 1 vs --threads 8;
#  2. explicit launch shapes through a bare spec object (defaults apply);
#  3. spec-level errors: an unknown GPU aborts before any row, with
#     nearest-name suggestions in the message;
#  4. the same tune request over `serve --stdio` (rows + summary embed in
#     one response line) between predict traffic.
# Without a trained P80 artifact the ceiling falls back to the analytical
# roofline — recorded on every row as "ceiling":"roofline".
set -euo pipefail
cd "$(dirname "$0")/.."

RUN="cargo run --release --quiet --bin synperf --"

# 1. sampled tune: 8 launches x 1 GPU, Underperforming-Point threshold
# 0.02 (tight enough that the A40's known tuning headroom diagnoses
# at least one point even on a lucky sample)
SPEC='{"v":1,"id":"t1","op":"tune","tune":{"gpus":["A40"],"source":{"sampled":8},"gap_threshold":0.02,"seed":3}}'

T1=$(printf '%s\n' "$SPEC" | $RUN tune --spec - --threads 1 --json)
T8=$(printf '%s\n' "$SPEC" | $RUN tune --spec - --threads 8 --json)
printf '%s\n' "$T1"

lines=$(printf '%s\n' "$T1" | wc -l | tr -d ' ')
[ "$lines" -eq 9 ] || { echo "FAIL: expected 8 rows + 1 summary line, got $lines"; exit 1; }
rows=$(printf '%s\n' "$T1" | grep -c '"row":{' || true)
[ "$rows" -eq 8 ] || { echo "FAIL: expected 8 row lines, got $rows"; exit 1; }
# artifact-less checkout: the roofline fallback must be visible provenance
roofline=$(printf '%s\n' "$T1" | grep -c '"ceiling":"roofline"' || true)
[ "$roofline" -eq 9 ] || { echo "FAIL: every row + summary must carry roofline provenance"; exit 1; }

SUMMARY=$(printf '%s\n' "$T1" | tail -1)
printf '%s\n' "$SUMMARY" | grep -q '"summary":{"points":8,' \
  || { echo "FAIL: summary line missing or wrong point count"; exit 1; }
DIAG=$(printf '%s\n' "$SUMMARY" | sed -n 's/.*"diagnosed":\([0-9][0-9]*\).*/\1/p')
[ -n "$DIAG" ] && [ "$DIAG" -ge 1 ] \
  || { echo "FAIL: expected at least one diagnosed (underperforming) point, got '$DIAG'"; exit 1; }
GMD=$(printf '%s\n' "$SUMMARY" | sed -n 's/.*"geomean_speedup_diagnosed":\([^,]*\),.*/\1/p')
awk -v g="$GMD" 'BEGIN { exit !(g + 0 >= 1.0) }' \
  || { echo "FAIL: diagnosed geomean speedup $GMD must be >= 1.0 (tuning never hurts)"; exit 1; }

# the tune contract: stdout (rows + summary) is byte-identical across
# thread counts — work stealing may reorder evaluation, never output
[ "$T1" = "$T8" ] \
  || { echo "FAIL: tune rows must be byte-identical across --threads 1 vs 8"; exit 1; }

# 2. explicit launch shapes through a bare spec object: 2 GPUs x 1 shape
EXPL_OUT=$(printf '%s\n' \
  '{"gpus":["A40","H800"],"source":{"explicit":[{"m":256,"e":16,"topk":2,"h":1024,"n":512}]},"seed":7}' \
  | $RUN tune --spec - --json)
expl_rows=$(printf '%s\n' "$EXPL_OUT" | grep -c '"row":{' || true)
[ "$expl_rows" -eq 2 ] || { echo "FAIL: expected 2 explicit rows, got $expl_rows"; exit 1; }
printf '%s\n' "$EXPL_OUT" | tail -1 | grep -q '"summary":{"points":2,' \
  || { echo "FAIL: explicit-shape summary missing"; exit 1; }

# 3. spec-level errors abort before any row, with nearest-name hints
ERR_OUT=$(printf '%s\n' '{"id":"bad","gpus":["B300"]}' | $RUN tune --spec - --json)
[ "$(printf '%s\n' "$ERR_OUT" | wc -l | tr -d ' ')" -eq 1 ] \
  || { echo "FAIL: spec-level error must be exactly one line"; exit 1; }
printf '%s\n' "$ERR_OUT" | grep -q '"id":"bad","ok":false,"error":{"code":"unknown_gpu"' \
  || { echo "FAIL: unknown_gpu spec error missing"; exit 1; }
printf '%s\n' "$ERR_OUT" | grep -q 'closest: A100, H800, H100' \
  || { echo "FAIL: nearest-name suggestions missing from unknown_gpu"; exit 1; }

# 4. the same request over the stdio wire: one response line embedding
# rows + summary, interleaved with the predict verb
WIRE_OUT=$(printf '%s\n' \
  '{"v":1,"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":512,"n":512,"k":512}}' \
  "$SPEC" \
  | $RUN serve --stdio --queue-cap 64)
printf '%s\n' "$WIRE_OUT" | grep -q '"id":"p1","ok":true' \
  || { echo "FAIL: predict verb broken next to tune"; exit 1; }
printf '%s\n' "$WIRE_OUT" | grep '"id":"t1"' | grep -q '"ok":true,"tune":{"rows":\[' \
  || { echo "FAIL: stdio tune response missing embedded rows"; exit 1; }
printf '%s\n' "$WIRE_OUT" | grep '"id":"t1"' | grep -q '"summary":{"points":8,' \
  || { echo "FAIL: stdio tune response missing summary"; exit 1; }

echo "tune: all assertions passed"
