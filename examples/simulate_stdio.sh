#!/usr/bin/env bash
# Drive the Scenario-API `simulate` verb from a clean checkout, two ways:
#  1. over the stdio wire (`synperf serve --stdio` speaks both the predict
#     and simulate verbs, dispatched per line);
#  2. through the dedicated `synperf simulate` subcommand (flags -> human
#     summary, --json -> one report line, --spec - -> JSONL in/out).
# Without trained artifacts everything answers in degraded roofline mode,
# which the reports make explicit (totals.degraded_kernels > 0).
#
# THREADS=N runs every invocation with --threads N (CI exercises the
# parallel two-pass evaluator with THREADS=2). Reports are bit-identical
# at any thread count, so all assertions below hold unchanged.
#
#   ./examples/simulate_stdio.sh
#   THREADS=2 ./examples/simulate_stdio.sh
set -euo pipefail
cd "$(dirname "$0")/.."

T_FLAG=${THREADS:+--threads $THREADS}

REQUESTS='{"v":1,"id":"sim1","op":"simulate","scenario":{"model":"qwen2.5-14b","gpu":"A100","tp":2,"workload":{"requests":[[256,16],[128,8]]},"seed":7}}
{"v":1,"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":512,"n":512,"k":512}}
{"v":1,"id":"sim2","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"H800","workload":{"kind":"splitwise","batch":4},"phases":"decode","seed":3}}
{"v":1,"id":"bad-model","op":"simulate","scenario":{"model":"GPT-5","gpu":"A100"}}
{"v":1,"id":"bad-par","op":"simulate","scenario":{"model":"qwen2.5-14b","gpu":"A100","tp":3}}'

OUT=$(printf '%s\n' "$REQUESTS" | cargo run --release --quiet --bin synperf -- serve --stdio --queue-cap 64 $T_FLAG)
printf '%s\n' "$OUT"

lines=$(printf '%s\n' "$OUT" | wc -l | tr -d ' ')
[ "$lines" -eq 5 ] || { echo "FAIL: expected 5 response lines, got $lines"; exit 1; }

# sim1: a full report with both phases, TTFT/TPOT, typed breakdown and
# degraded provenance counts
printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"ok":true,"report":{' \
  || { echo "FAIL: sim1 report missing"; exit 1; }
printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"ttft_sec":{' \
  || { echo "FAIL: sim1 TTFT missing"; exit 1; }
printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"tpot_sec":{' \
  || { echo "FAIL: sim1 TPOT missing"; exit 1; }
printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"gemm_sec":' \
  || { echo "FAIL: sim1 typed breakdown missing"; exit 1; }
printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"all_reduce_sec":' \
  || { echo "FAIL: sim1 comm breakdown missing"; exit 1; }
if printf '%s\n' "$OUT" | grep '"id":"sim1"' | grep -q '"degraded_kernels":0,'; then
  echo "FAIL: degraded provenance should be counted without artifacts"; exit 1
fi

# the predict verb still answers between simulations
printf '%s\n' "$OUT" | grep -q '"id":"p1","ok":true' \
  || { echo "FAIL: predict verb broken"; exit 1; }

# a decode-only (disaggregated) scenario has exactly one phase
printf '%s\n' "$OUT" | grep '"id":"sim2"' | grep -q '"phases":\[{"phase":"decode"' \
  || { echo "FAIL: sim2 decode-only phase schedule missing"; exit 1; }
if printf '%s\n' "$OUT" | grep '"id":"sim2"' | grep -q '"phase":"prefill"'; then
  echo "FAIL: sim2 must not schedule prefill"; exit 1
fi

# the closed ScenarioError taxonomy travels the wire
printf '%s\n' "$OUT" | grep -q '"id":"bad-model","ok":false,"error":{"code":"unknown_model"' \
  || { echo "FAIL: unknown_model error missing"; exit 1; }
printf '%s\n' "$OUT" | grep -q '"id":"bad-par","ok":false,"error":{"code":"invalid_parallelism"' \
  || { echo "FAIL: invalid_parallelism error missing"; exit 1; }

# 2a. the dedicated subcommand, JSON mode: exactly one report line
JSON_OUT=$(cargo run --release --quiet --bin synperf -- simulate \
  --model qwen2.5-14b --gpu A100 --tp 2 --batch 4 --seed 7 --json $T_FLAG)
printf '%s\n' "$JSON_OUT" | grep -q '"ok":true,"report":{' \
  || { echo "FAIL: simulate --json report missing"; exit 1; }
[ "$(printf '%s\n' "$JSON_OUT" | wc -l | tr -d ' ')" -eq 1 ] \
  || { echo "FAIL: --json must emit exactly one line"; exit 1; }

# 2b. JSONL specs over stdin (bare scenario objects work too)
SPEC_OUT=$(printf '%s\n' \
  '{"model":"llama3.1-8b","gpu":"A100","workload":{"requests":[[64,8]]}}' \
  '{"id":"x","op":"simulate","scenario":{"model":"nope","gpu":"A100"}}' \
  | cargo run --release --quiet --bin synperf -- simulate --spec - $T_FLAG)
[ "$(printf '%s\n' "$SPEC_OUT" | wc -l | tr -d ' ')" -eq 2 ] \
  || { echo "FAIL: --spec - must answer every line"; exit 1; }
printf '%s\n' "$SPEC_OUT" | head -1 | grep -q '"ok":true,"report":{' \
  || { echo "FAIL: bare spec object not answered"; exit 1; }
printf '%s\n' "$SPEC_OUT" | grep -q '"id":"x","ok":false,"error":{"code":"unknown_model"' \
  || { echo "FAIL: spec-mode error correlation missing"; exit 1; }

# 3. Scenario v2: a "cluster" object rides the same simulate verb and
# answers with the continuous-batching report (percentiles, SLO, replicas)
CLUSTER_REQS='{"v":1,"id":"c1","op":"simulate","cluster":{"model":"llama3.1-8b","gpu":"A100","replicas":2,"policy":"round_robin","arrivals":{"trace":[[0.0,64,8],[0.01,96,8],[0.02,64,4],[0.03,128,8]]},"max_batch":4,"kv_capacity_tokens":4096,"seed":7}}
{"v":1,"id":"c-bad","op":"simulate","cluster":{"model":"llama3.1-8b","gpu":"A100","replicas":0}}'

CL_OUT=$(printf '%s\n' "$CLUSTER_REQS" | cargo run --release --quiet --bin synperf -- serve --stdio --queue-cap 64 $T_FLAG)
printf '%s\n' "$CL_OUT"
printf '%s\n' "$CL_OUT" | grep '"id":"c1"' | grep -q '"ok":true,"report":{"cluster":true' \
  || { echo "FAIL: c1 cluster report missing"; exit 1; }
printf '%s\n' "$CL_OUT" | grep '"id":"c1"' | grep -q '"completed":4' \
  || { echo "FAIL: c1 must complete all 4 offered requests"; exit 1; }
printf '%s\n' "$CL_OUT" | grep '"id":"c1"' | grep -q '"p99_sec":' \
  || { echo "FAIL: c1 percentile summaries missing"; exit 1; }
printf '%s\n' "$CL_OUT" | grep '"id":"c1"' | grep -q '"slo":{' \
  || { echo "FAIL: c1 SLO attainment missing"; exit 1; }
# the v2 taxonomy extension travels the wire with correlation intact
printf '%s\n' "$CL_OUT" | grep -q '"id":"c-bad","ok":false,"error":{"code":"invalid_cluster"' \
  || { echo "FAIL: invalid_cluster error missing"; exit 1; }

# determinism: the same cluster line answers byte-identically at
# --threads 1 and --threads 8 (the event loop is serial; threads only
# fan out the per-step batch prediction)
CL_T1=$(printf '%s\n' "$CLUSTER_REQS" | cargo run --release --quiet --bin synperf -- serve --stdio --queue-cap 64 --threads 1)
CL_T8=$(printf '%s\n' "$CLUSTER_REQS" | cargo run --release --quiet --bin synperf -- serve --stdio --queue-cap 64 --threads 8)
[ "$CL_T1" = "$CL_T8" ] \
  || { echo "FAIL: cluster reports must be byte-identical across thread counts"; exit 1; }

# 3b. the dedicated subcommand grows a --cluster mode (seeded Poisson
# arrivals; --json emits exactly one v2 report line)
CL_JSON=$(cargo run --release --quiet --bin synperf -- simulate --cluster \
  --model llama3.1-8b --gpu A100 --replicas 2 --policy least_loaded \
  --rate 8 --n 8 --seed 7 --json $T_FLAG)
printf '%s\n' "$CL_JSON" | grep -q '"ok":true,"report":{"cluster":true' \
  || { echo "FAIL: simulate --cluster --json report missing"; exit 1; }
[ "$(printf '%s\n' "$CL_JSON" | wc -l | tr -d ' ')" -eq 1 ] \
  || { echo "FAIL: --cluster --json must emit exactly one line"; exit 1; }

echo "simulate_stdio: all assertions passed"
