//! End-to-end driver (the DESIGN.md §5 "end-to-end validation" example):
//! predicts full LLM-serving latency for Qwen2.5-14B on two GPUs under an
//! Arxiv-style workload and compares every method against the testbed
//! ground truth, exercising all layers: kernel decomposition -> scheduling
//! -> features -> AOT'd Pallas/JAX MLP via PJRT -> trace aggregation + RF
//! communication model.
//!
//!   cargo run --release --example e2e_inference
//!
//! Requires `make artifacts`. Models/datasets are cached under runs/.

use synperf::e2e::{llm, predict, trace, workload};
use synperf::experiments::{Lab, Scale};
use synperf::hw;
use synperf::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new(Scale::Fast)?;
    let models = lab.model_set()?;
    let model = llm::qwen2_5_14b();
    let mut rng = Rng::new(42);

    for gpu_name in ["A100", "H100"] {
        let gpu = hw::gpu_by_name(gpu_name).unwrap();
        let comm = lab.comm(&gpu);
        let reqs = workload::sample_batch(workload::WorkloadKind::Arxiv, 8, &mut rng);
        let tr = trace::build_trace(&model, 1, 1, &reqs);
        println!(
            "\n{} on {} — arxiv_8 ({} prompt tokens, {} trace items)",
            model.name,
            gpu.name,
            reqs.iter().map(|r| r.input_len).sum::<u32>(),
            tr.len()
        );
        let t = predict::eval_trace(&tr, &gpu, 1, &models, &comm, 99)?;
        println!("  ground truth {:.1} ms", t.actual * 1e3);
        for (name, v) in [
            ("SynPerf", t.synperf),
            ("Neusight", t.neusight),
            ("Habitat", t.habitat),
            ("Linear", t.linear),
            ("Roofline", t.roofline),
        ] {
            println!(
                "  {name:<9} {:>8.1} ms   err {:+6.1}%",
                v * 1e3,
                100.0 * (v - t.actual) / t.actual
            );
        }
    }
    Ok(())
}
