//! End-to-end driver (the DESIGN.md §5 "end-to-end validation" example):
//! predicts full LLM-serving latency for Qwen2.5-14B on two GPUs under an
//! Arxiv-style workload through the declarative **Scenario API v1** —
//! a `ScenarioSpec` per (GPU, workload) point, a typed `ScenarioReport`
//! back: per-phase TTFT/TPOT, per-method totals vs testbed ground truth,
//! the typed op-class breakdown, and degraded-kernel provenance. Exercises
//! all layers: scenario compiler -> kernel decomposition -> scheduling ->
//! features -> AOT'd Pallas/JAX MLP via PJRT -> trace aggregation + RF
//! communication model.
//!
//!   cargo run --release --example e2e_inference
//!
//! Requires `make artifacts`. Models/datasets are cached under runs/.

use synperf::experiments::{Lab, Scale};
use synperf::scenario::{Method, Phase, ScenarioSpec, WorkloadSpec};
use synperf::e2e::workload::WorkloadKind;

fn main() -> anyhow::Result<()> {
    let lab = Lab::new(Scale::Fast)?;
    let sim = lab.simulator()?;

    for (i, gpu_name) in ["A100", "H100"].iter().enumerate() {
        let spec = ScenarioSpec::new("Qwen2.5-14B", *gpu_name)
            .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Arxiv, batch: 8 })
            .seed(42 + i as u64);
        let r = sim.simulate(&spec)?;
        let prefill = r.phase(Phase::Prefill).expect("both phases scheduled");
        let decode = r.phase(Phase::Decode).expect("both phases scheduled");
        println!(
            "\n{} on {} — arxiv_8 ({:.0} prompt tokens, {:.0} kernel launches)",
            r.model, r.gpu, prefill.tokens, r.launches
        );
        println!(
            "  TTFT {:.1} ms (predicted {:.1}), TPOT {:.2} ms/tok, decode {:.0} tok/s",
            prefill.ttft_sec(Method::Actual).unwrap_or(0.0) * 1e3,
            prefill.ttft_sec(Method::SynPerf).unwrap_or(0.0) * 1e3,
            decode.tpot_sec(Method::Actual).unwrap_or(0.0) * 1e3,
            decode.tokens_per_sec(Method::Actual)
        );
        println!("  ground truth {:.1} ms", r.totals.actual * 1e3);
        for m in
            [Method::SynPerf, Method::Neusight, Method::Habitat, Method::Linear, Method::Roofline]
        {
            let v = r.totals.get(m);
            println!(
                "  {:<9} {:>8.1} ms   err {:+6.1}%",
                m.name(),
                v * 1e3,
                100.0 * (v - r.totals.actual) / r.totals.actual
            );
        }
        if r.totals.degraded_kernels > 0 {
            println!(
                "  note: {} kernel items fell back to the roofline (untrained category)",
                r.totals.degraded_kernels
            );
        }
    }
    Ok(())
}
