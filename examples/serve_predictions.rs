//! Serving example: the Layer-3 coordinator as a protocol-v1 prediction
//! service with dynamic batching and a bounded request queue. Multiple
//! client threads fire mixed kernel prediction requests through cloned
//! [`Client`] handles; the service batches them (size/deadline), routes per
//! kernel category to the AOT'd MLP executables, and answers with
//! provenance-carrying `PredictResponse`s.
//!
//!   cargo run --release --example serve_predictions
//!
//! Runs in degraded (roofline-answer) mode if `make artifacts` hasn't run —
//! visible per answer as `provenance.source == Source::Roofline`.

use synperf::api::{ModelBundle, PredictRequest, Source};
use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::experiments::{Lab, Scale};
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::util::rng::Rng;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let svc = PredictionService::spawn(
        || match Lab::new(Scale::Fast) {
            Ok(lab) => {
                lab.bundle(&[KernelKind::Gemm, KernelKind::RmsNorm, KernelKind::SiluMul])
            }
            Err(_) => {
                eprintln!("(no artifacts — serving degraded roofline answers)");
                ModelBundle::default()
            }
        },
        ServiceConfig::default(),
    );

    let n_clients = 4;
    let per_client = 256;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let client = svc.client();
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64);
                let gpus = hw::all_gpus();
                let mut sum = 0.0;
                let mut mlp_answers = 0usize;
                for i in 0..per_client {
                    let gpu = gpus[(c + i) % gpus.len()].clone();
                    let cfg = match i % 3 {
                        0 => KernelConfig::Gemm {
                            m: rng.log_range_u32(16, 32768),
                            n: rng.log_range_u32(384, 65536),
                            k: rng.log_range_u32(256, 8192),
                            dtype: DType::Bf16,
                        },
                        1 => KernelConfig::RmsNorm {
                            seq: rng.log_range_u32(2, 65536),
                            dim: rng.log_range_u32(128, 16384),
                        },
                        _ => KernelConfig::SiluMul {
                            seq: rng.log_range_u32(2, 65536),
                            dim: rng.log_range_u32(768, 65536),
                        },
                    };
                    let resp = client
                        .predict(PredictRequest::new(cfg, gpu).tagged(format!("c{c}")))
                        .expect("service alive");
                    sum += resp.latency_sec;
                    if resp.provenance.source == Source::Mlp {
                        mlp_answers += 1;
                    }
                }
                (sum, mlp_answers)
            })
        })
        .collect();
    let mut total_pred = 0.0;
    let mut total_mlp = 0usize;
    for h in handles {
        let (sum, mlp) = h.join().expect("client thread");
        total_pred += sum;
        total_mlp += mlp;
    }
    let wall = t0.elapsed();
    let n = n_clients * per_client;
    let snap = svc.metrics.snapshot();
    println!("served {n} predictions from {n_clients} clients in {wall:.2?}");
    println!("throughput: {:.0} predictions/s", n as f64 / wall.as_secs_f64());
    println!(
        "provenance: {total_mlp} mlp answers / {} roofline answers",
        n - total_mlp
    );
    println!(
        "batches: {} (mean size {:.1}), batch latency p50 {:.0} us / p99 {:.0} us",
        snap.batches, snap.mean_batch, snap.p50_us, snap.p99_us
    );
    println!(
        "backpressure: rejected {}, max queue depth {}",
        snap.rejected_requests, snap.max_queue_depth
    );
    println!("sum of predicted latencies: {total_pred:.3} s");
    svc.shutdown();
    Ok(())
}
