//! Serving example: the Layer-3 coordinator as a prediction service with
//! dynamic batching. Multiple client threads fire mixed kernel prediction
//! requests; the service batches them (size/deadline), routes per kernel
//! category to the AOT'd MLP executables, and reports throughput + batch
//! statistics.
//!
//!   cargo run --release --example serve_predictions
//!
//! Runs in degraded (roofline-answer) mode if `make artifacts` hasn't run.

use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::experiments::{Lab, ModelFlavor, Scale};
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let svc = Arc::new(PredictionService::spawn(
        || {
            let mut models = std::collections::HashMap::new();
            if let Ok(lab) = Lab::new(Scale::Fast) {
                for kind in [KernelKind::Gemm, KernelKind::RmsNorm, KernelKind::SiluMul] {
                    if let Ok(p) = lab.model(kind, ModelFlavor::SynPerf) {
                        models.insert(kind, p);
                    }
                }
            } else {
                eprintln!("(no artifacts — serving degraded roofline answers)");
            }
            models
        },
        ServiceConfig::default(),
    ));

    let n_clients = 4;
    let per_client = 256;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let svc = svc.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::new(c as u64);
                let gpus = hw::all_gpus();
                let mut sum = 0.0;
                for i in 0..per_client {
                    let gpu = gpus[(c + i) % gpus.len()].clone();
                    let cfg = match i % 3 {
                        0 => KernelConfig::Gemm {
                            m: rng.log_range_u32(16, 32768),
                            n: rng.log_range_u32(384, 65536),
                            k: rng.log_range_u32(256, 8192),
                            dtype: DType::Bf16,
                        },
                        1 => KernelConfig::RmsNorm {
                            seq: rng.log_range_u32(2, 65536),
                            dim: rng.log_range_u32(128, 16384),
                        },
                        _ => KernelConfig::SiluMul {
                            seq: rng.log_range_u32(2, 65536),
                            dim: rng.log_range_u32(768, 65536),
                        },
                    };
                    sum += svc.submit(cfg, gpu).recv().expect("service alive");
                }
                sum
            })
        })
        .collect();
    let mut total_pred = 0.0;
    for h in handles {
        total_pred += h.join().expect("client thread");
    }
    let wall = t0.elapsed();
    let n = n_clients * per_client;
    let snap = svc.metrics.snapshot();
    println!("served {n} predictions from {n_clients} clients in {wall:.2?}");
    println!("throughput: {:.0} predictions/s", n as f64 / wall.as_secs_f64());
    println!(
        "batches: {} (mean size {:.1}), batch latency p50 {:.0} us / p99 {:.0} us",
        snap.batches, snap.mean_batch, snap.p50_us, snap.p99_us
    );
    println!("sum of predicted latencies: {total_pred:.3} s");
    Arc::try_unwrap(svc).ok().map(|s| s.shutdown());
    Ok(())
}
