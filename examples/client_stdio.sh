#!/usr/bin/env bash
# Drive the protocol-v1 JSONL wire surface (`synperf serve --stdio`) from a
# clean checkout: pipe a handful of requests across kernels and GPUs into
# the service and assert well-formed, correlated responses come back.
# Without trained artifacts the service answers in degraded roofline mode,
# which the responses make explicit ("source":"roofline").
#
#   ./examples/client_stdio.sh
set -euo pipefail
cd "$(dirname "$0")/.."

REQUESTS='{"v":1,"id":"g1","gpu":"A100","kernel":{"type":"gemm","m":4096,"n":4096,"k":4096,"dtype":"bf16"},"tag":"demo"}
{"v":1,"id":"g2","gpu":"H800","kernel":{"type":"gemm","m":4096,"n":4096,"k":4096}}
{"v":1,"id":"a1","gpu":"H100","kernel":{"type":"attention","batch":[[1024,1024],[64,2048]],"nh":16,"nkv":4,"hd":128}}
{"v":1,"id":"r1","gpu":"L40","kernel":{"type":"rmsnorm","seq":2048,"dim":8192},"breakdown":true}
{"v":1,"id":"s1","gpu":"A40","kernel":{"type":"silu_mul","seq":1024,"dim":13824},"flavor":"p80"}
{"v":1,"id":"m1","gpu":"H20","kernel":{"type":"fused_moe","m":512,"e":8,"topk":2,"h":2048,"n":1024}}
{"v":1,"id":"bad-gpu","gpu":"B300","kernel":{"type":"gemm","m":1,"n":1,"k":1}}
{"v":1,"id":"bad-kernel","gpu":"A100","kernel":{"type":"conv2d"}}'

OUT=$(printf '%s\n' "$REQUESTS" | cargo run --release --quiet --bin synperf -- serve --stdio --queue-cap 64)
printf '%s\n' "$OUT"

lines=$(printf '%s\n' "$OUT" | wc -l | tr -d ' ')
[ "$lines" -eq 8 ] || { echo "FAIL: expected 8 response lines, got $lines"; exit 1; }

ok=$(printf '%s\n' "$OUT" | grep -c '"ok":true')
[ "$ok" -eq 6 ] || { echo "FAIL: expected 6 ok responses, got $ok"; exit 1; }

# every successful answer carries provenance and a positive latency
[ "$(printf '%s\n' "$OUT" | grep '"ok":true' | grep -c '"source":')" -eq 6 ] \
  || { echo "FAIL: responses missing provenance"; exit 1; }
if printf '%s\n' "$OUT" | grep '"ok":true' | grep -q '"latency_sec":0e0'; then
  echo "FAIL: zero latency answer"; exit 1
fi

# request ids are echoed back for correlation
for id in g1 g2 a1 r1 s1 m1; do
  printf '%s\n' "$OUT" | grep -q "\"id\":\"$id\",\"ok\":true" \
    || { echo "FAIL: no ok response for id $id"; exit 1; }
done

# the closed error taxonomy travels the wire
printf '%s\n' "$OUT" | grep -q '"id":"bad-gpu","ok":false,"error":{"code":"unknown_gpu"' \
  || { echo "FAIL: unknown_gpu error missing"; exit 1; }
printf '%s\n' "$OUT" | grep -q '"id":"bad-kernel","ok":false,"error":{"code":"unsupported_kernel"' \
  || { echo "FAIL: unsupported_kernel error missing"; exit 1; }

# the breakdown request got its per-pipeline feature block
printf '%s\n' "$OUT" | grep '"id":"r1"' | grep -q '"breakdown":{"tensor"' \
  || { echo "FAIL: breakdown missing"; exit 1; }

# a p80 request is answered with its flavor echoed
printf '%s\n' "$OUT" | grep '"id":"s1"' | grep -q '"flavor":"p80"' \
  || { echo "FAIL: p80 flavor not echoed"; exit 1; }

echo "client_stdio: all assertions passed"
