#!/usr/bin/env bash
# Exercise the TCP serving front end end to end from a clean checkout:
# start `synperf serve --tcp` on an ephemeral port, hammer it with the
# load_gen example (8 connections x 50 pipelined requests, every line
# answered in order), then SIGTERM the server and assert a graceful
# drain — clean exit code and the final accounting line on stderr.
#
#   ./examples/serve_tcp.sh
set -euo pipefail
cd "$(dirname "$0")/.."

cargo build --release --quiet --bin synperf --example load_gen

LOG=$(mktemp)
SRV=""
cleanup() {
  [ -n "$SRV" ] && kill "$SRV" 2>/dev/null || true
  rm -f "$LOG"
}
trap cleanup EXIT

./target/release/synperf serve --tcp 127.0.0.1:0 2>"$LOG" &
SRV=$!

# the server prints the bound ephemeral address on stderr; wait for it
ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's/^tcp: listening on \([0-9.]*:[0-9]*\).*/\1/p' "$LOG" | head -n 1)
  [ -n "$ADDR" ] && break
  sleep 0.1
done
[ -n "$ADDR" ] || { echo "FAIL: server never reported a listening address"; cat "$LOG"; exit 1; }

CLIENTS=8
REQUESTS=50
OUT=$(./target/release/examples/load_gen "$ADDR" "$CLIENTS" "$REQUESTS")
printf '%s\n' "$OUT"
printf '%s\n' "$OUT" | grep -q "400 ok, 0 errors" \
  || { echo "FAIL: expected 400 ok / 0 error responses"; exit 1; }

# graceful drain: SIGTERM must finish in-flight work and exit 0
kill -TERM "$SRV"
for _ in $(seq 1 100); do
  kill -0 "$SRV" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SRV" 2>/dev/null; then
  echo "FAIL: server did not drain within 10s of SIGTERM"; kill -9 "$SRV"; exit 1
fi
status=0
wait "$SRV" || status=$?
SRV=""
[ "$status" -eq 0 ] || { echo "FAIL: server exited $status"; cat "$LOG"; exit 1; }

# the drain summary accounts for every response and connection
grep -q '^tcp: 400 responses (0 errors' "$LOG" \
  || { echo "FAIL: missing or wrong drain summary"; cat "$LOG"; exit 1; }
grep -q 'over 8 connections (0 quarantined, 0 reaped, 0 dropped)' "$LOG" \
  || { echo "FAIL: connection accounting wrong"; cat "$LOG"; exit 1; }

echo "PASS: TCP serve + load_gen + graceful drain"
