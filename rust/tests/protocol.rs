//! Protocol-v1 acceptance tests: exact JSONL golden lines for
//! `PredictRequest` / `PredictResponse` / every `PredictError` variant
//! (wire-format drift fails loudly), plus the backpressure contract of the
//! bounded service queue — saturation yields `QueueFull`, never unbounded
//! growth or a hang — and graceful drain on shutdown.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};
use synperf::api::{
    wire, Flavor, ModelBundle, PredictError, PredictRequest, PredictResponse, Provenance, Source,
};
use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::hw::gpu_by_name;
use synperf::kernels::{DType, KernelConfig, KernelKind};

fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
    KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
}

#[test]
fn request_golden_line() {
    let gpu = gpu_by_name("A100").unwrap();
    let req = PredictRequest::new(gemm(4096, 4096, 4096), gpu).p80().strict().tagged("warmup");
    let line = wire::encode_request(Some("r1"), &req);
    assert_eq!(
        line,
        r#"{"v":1,"id":"r1","gpu":"A100","kernel":{"type":"gemm","m":4096,"n":4096,"k":4096,"dtype":"bf16"},"flavor":"p80","allow_degraded":false,"breakdown":false,"tag":"warmup"}"#
    );
    // and the golden line parses back to the same typed request
    let (id, parsed) = wire::parse_request(&line);
    assert_eq!(id.as_deref(), Some("r1"));
    let back = parsed.unwrap();
    assert_eq!(back.cfg, req.cfg);
    assert_eq!(back.opts, req.opts);
}

#[test]
fn response_golden_line_roundtrips() {
    let resp = PredictResponse {
        latency_sec: 1.5e-4,
        provenance: Provenance { source: Source::Roofline, cache_hit: true },
        flavor: Flavor::Mean,
        kind: KernelKind::Gemm,
        gpu: "A100".to_string(),
        breakdown: None,
        tag: Some("warmup".to_string()),
    };
    let line = wire::encode_response(Some("r1"), &Ok(resp.clone()));
    assert_eq!(
        line,
        r#"{"v":1,"id":"r1","ok":true,"latency_sec":1.5e-4,"latency_us":150.000,"source":"roofline","cache_hit":true,"flavor":"mean","kernel":"gemm","gpu":"A100","tag":"warmup"}"#
    );
    let (id, back) = wire::parse_response(&line).unwrap();
    assert_eq!(id.as_deref(), Some("r1"));
    assert_eq!(back.unwrap(), resp);
}

#[test]
fn error_golden_lines_cover_the_whole_taxonomy() {
    let cases: Vec<(PredictError, &str)> = vec![
        (
            PredictError::UnknownGpu("B300".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_gpu","message":"unknown GPU \"B300\" (see Table VI)","gpu":"B300"}}"#,
        ),
        (
            PredictError::UnsupportedKernel("attention batch must be non-empty".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unsupported_kernel","message":"unsupported kernel: attention batch must be non-empty","reason":"attention batch must be non-empty"}}"#,
        ),
        (
            PredictError::PredictorUnavailable(KernelKind::Gemm),
            r#"{"v":1,"ok":false,"error":{"code":"predictor_unavailable","message":"no trained predictor for category Gemm (degraded answers disabled)","kind":"gemm"}}"#,
        ),
        (
            PredictError::QueueFull,
            r#"{"v":1,"ok":false,"error":{"code":"queue_full","message":"prediction queue at capacity"}}"#,
        ),
        (
            PredictError::Shutdown,
            r#"{"v":1,"ok":false,"error":{"code":"shutdown","message":"prediction service is shut down"}}"#,
        ),
    ];
    for (err, golden) in cases {
        let line = wire::encode_response(None, &Err(err.clone()));
        assert_eq!(line, golden, "wire drift for {:?}", err.code());
        let (_, back) = wire::parse_response(&line).unwrap();
        assert_eq!(back.unwrap_err(), err, "round trip for {:?}", err.code());
    }
}

#[test]
fn breakdown_and_degraded_responses_roundtrip() {
    // a real degraded (roofline-provenance) response with a breakdown
    // survives the wire bit-exactly
    let gpu = gpu_by_name("H800").unwrap();
    let req = PredictRequest::new(gemm(1789, 923, 411), gpu).with_breakdown().tagged("bd");
    let resp = synperf::api::predict_one(&ModelBundle::default(), &req).unwrap();
    assert_eq!(resp.provenance.source, Source::Roofline, "no artifacts in tests");
    assert!(resp.breakdown.is_some());
    let line = wire::encode_response(Some("77"), &Ok(resp.clone()));
    assert!(line.contains(r#""source":"roofline""#), "degraded mode must be visible: {line}");
    assert!(line.contains(r#""breakdown":{"tensor""#));
    let (id, back) = wire::parse_response(&line).unwrap();
    assert_eq!(id.as_deref(), Some("77"));
    let back = back.unwrap();
    assert_eq!(back, resp);
    assert_eq!(
        back.breakdown.unwrap().theory_sec.to_bits(),
        resp.breakdown.unwrap().theory_sec.to_bits()
    );
}

#[test]
fn saturated_queue_returns_queue_full_not_a_hang() {
    // gate the factory so the service loop cannot start draining: the
    // bounded queue saturates deterministically
    let (gate_tx, gate_rx) = channel::<()>();
    let svc = PredictionService::spawn(
        move || {
            gate_rx.recv().ok();
            ModelBundle::default()
        },
        ServiceConfig { max_batch: 8, deadline: Duration::from_millis(1), queue_cap: 2 },
    );
    let client = svc.client();
    let gpu = gpu_by_name("A100").unwrap();
    let req = |i: u32| PredictRequest::new(KernelConfig::RmsNorm { seq: 64 + i, dim: 2048 }, gpu.clone());

    let p1 = client.try_predict(req(1)).unwrap();
    let p2 = client.try_predict(req(2)).unwrap();
    // queue_cap = 2: the third request must bounce immediately
    let err = client.try_predict(req(3)).unwrap_err();
    assert_eq!(err, PredictError::QueueFull);
    // the blocking path with a deadline also reports QueueFull, not a hang
    let t0 = Instant::now();
    let err = client.predict_deadline(req(4), Duration::from_millis(40)).unwrap_err();
    assert_eq!(err, PredictError::QueueFull);
    assert!(t0.elapsed() < Duration::from_secs(5), "deadline must bound the wait");
    assert_eq!(client.queue_depth(), 2, "backlog never exceeds queue_cap");

    // open the gate: everything accepted is answered
    gate_tx.send(()).unwrap();
    assert!(p1.wait().unwrap().latency_sec > 0.0);
    assert!(p2.wait().unwrap().latency_sec > 0.0);
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.rejected_requests, 2);
    assert_eq!(snap.requests, 2);
    svc.shutdown();
}

#[test]
fn shutdown_drains_accepted_requests() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let client = svc.client();
    let gpu = gpu_by_name("L20").unwrap();
    let pendings: Vec<_> = (0..16)
        .map(|i| {
            client
                .try_predict(PredictRequest::new(
                    KernelConfig::SiluMul { seq: 32 + i, dim: 1024 },
                    gpu.clone(),
                ))
                .unwrap()
        })
        .collect();
    // graceful: close the queue, answer everything already accepted
    svc.shutdown();
    for p in pendings {
        assert!(p.wait().unwrap().latency_sec > 0.0, "accepted requests are drained");
    }
    // the surviving client sees the typed terminal state
    let err = client.predict(PredictRequest::new(gemm(64, 64, 64), gpu)).unwrap_err();
    assert_eq!(err, PredictError::Shutdown);
}

#[test]
fn service_answers_are_typed_end_to_end() {
    // the service client consumes PredictResponse — degraded provenance,
    // flavor and tag all travel with the latency
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let gpu = gpu_by_name("A40").unwrap();
    let resp = svc
        .predict(PredictRequest::new(gemm(911, 433, 277), gpu.clone()).tagged("e2e"))
        .unwrap();
    assert_eq!(resp.provenance.source, Source::Roofline);
    assert_eq!(resp.flavor, Flavor::Mean);
    assert_eq!(resp.gpu, "A40");
    assert_eq!(resp.tag.as_deref(), Some("e2e"));
    // strict mode propagates the typed predictor-unavailable error
    let err = svc
        .predict(PredictRequest::new(gemm(911, 433, 277), gpu).strict())
        .unwrap_err();
    assert_eq!(err, PredictError::PredictorUnavailable(KernelKind::Gemm));
    svc.shutdown();
}
