//! Protocol-v1 acceptance tests: exact JSONL golden lines for
//! `PredictRequest` / `PredictResponse` / every `PredictError` variant
//! (wire-format drift fails loudly), plus the backpressure contract of the
//! bounded service queue — saturation yields `QueueFull`, never unbounded
//! growth or a hang — and graceful drain on shutdown.
//!
//! The Scenario-API `simulate` verb gets the same treatment: exact golden
//! lines for the simulate request, the `ScenarioReport` response and every
//! `ScenarioError` variant, plus a full round trip over the stdio wire.
//!
//! The `sweep` verb is pinned the same way: exact goldens for the sweep
//! request, the streamed row shapes (ok and per-row error), the frontier
//! block and the spec-level `SweepError` envelope.
//!
//! The `tune` verb (autotune subsystem) closes the set: exact goldens for
//! the tune request, the streamed row and summary lines, every `TuneError`
//! variant, and a full round trip over the stdio wire between predict,
//! simulate and sweep traffic.

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};
use synperf::api::stdio::serve_lines;
use synperf::autotune::{
    wire as tune_wire, ConfigSource, MoeShape, TuneError, TuneRow, TuneSpec, TuneSummary,
};
use synperf::api::{
    wire, Flavor, ModelBundle, PredictError, PredictRequest, PredictResponse, Provenance, Source,
};
use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::e2e::workload::{Request, WorkloadKind};
use synperf::hw::gpu_by_name;
use synperf::kernels::{DType, KernelConfig, KernelKind, MoeConfig};
use synperf::scenario::wire as scenario_wire;
use synperf::scenario::{
    ClassBreakdown, MethodTotals, OpClass, Phase, PhaseReport, RoutePolicy, ScenarioError,
    ScenarioReport, ScenarioSpec, Simulator, WorkloadSpec,
};
use synperf::sweep::{
    pareto, wire as sweep_wire, GpuFilter, SweepError, SweepMetrics, SweepRow, SweepSpec,
};

fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
    KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
}

#[test]
fn request_golden_line() {
    let gpu = gpu_by_name("A100").unwrap();
    let req = PredictRequest::new(gemm(4096, 4096, 4096), gpu).p80().strict().tagged("warmup");
    let line = wire::encode_request(Some("r1"), &req);
    assert_eq!(
        line,
        r#"{"v":1,"id":"r1","gpu":"A100","kernel":{"type":"gemm","m":4096,"n":4096,"k":4096,"dtype":"bf16"},"flavor":"p80","allow_degraded":false,"breakdown":false,"tag":"warmup"}"#
    );
    // and the golden line parses back to the same typed request
    let (id, parsed) = wire::parse_request(&line);
    assert_eq!(id.as_deref(), Some("r1"));
    let back = parsed.unwrap();
    assert_eq!(back.cfg, req.cfg);
    assert_eq!(back.opts, req.opts);
}

#[test]
fn response_golden_line_roundtrips() {
    let resp = PredictResponse {
        latency_sec: 1.5e-4,
        provenance: Provenance { source: Source::Roofline, cache_hit: true },
        flavor: Flavor::Mean,
        kind: KernelKind::Gemm,
        gpu: "A100".to_string(),
        breakdown: None,
        tag: Some("warmup".to_string()),
    };
    let line = wire::encode_response(Some("r1"), &Ok(resp.clone()));
    assert_eq!(
        line,
        r#"{"v":1,"id":"r1","ok":true,"latency_sec":1.5e-4,"latency_us":150.000,"source":"roofline","cache_hit":true,"flavor":"mean","kernel":"gemm","gpu":"A100","tag":"warmup"}"#
    );
    let (id, back) = wire::parse_response(&line).unwrap();
    assert_eq!(id.as_deref(), Some("r1"));
    assert_eq!(back.unwrap(), resp);
}

#[test]
fn error_golden_lines_cover_the_whole_taxonomy() {
    let cases: Vec<(PredictError, &str)> = vec![
        (
            PredictError::UnknownGpu("B300".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_gpu","message":"unknown GPU \"B300\" (see Table VI; closest: A100, H800, H100)","gpu":"B300"}}"#,
        ),
        (
            PredictError::UnsupportedKernel("attention batch must be non-empty".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unsupported_kernel","message":"unsupported kernel: attention batch must be non-empty","reason":"attention batch must be non-empty"}}"#,
        ),
        (
            PredictError::PredictorUnavailable(KernelKind::Gemm),
            r#"{"v":1,"ok":false,"error":{"code":"predictor_unavailable","message":"no trained predictor for category Gemm (degraded answers disabled)","kind":"gemm"}}"#,
        ),
        (
            PredictError::QueueFull,
            r#"{"v":1,"ok":false,"error":{"code":"queue_full","message":"prediction queue at capacity"}}"#,
        ),
        (
            PredictError::DeadlineExceeded,
            r#"{"v":1,"ok":false,"error":{"code":"deadline_exceeded","message":"request deadline exceeded"}}"#,
        ),
        (
            PredictError::Shutdown,
            r#"{"v":1,"ok":false,"error":{"code":"shutdown","message":"prediction service is shut down"}}"#,
        ),
    ];
    for (err, golden) in cases {
        let line = wire::encode_response(None, &Err(err.clone()));
        assert_eq!(line, golden, "wire drift for {:?}", err.code());
        let (_, back) = wire::parse_response(&line).unwrap();
        assert_eq!(back.unwrap_err(), err, "round trip for {:?}", err.code());
    }
}

#[test]
fn breakdown_and_degraded_responses_roundtrip() {
    // a real degraded (roofline-provenance) response with a breakdown
    // survives the wire bit-exactly
    let gpu = gpu_by_name("H800").unwrap();
    let req = PredictRequest::new(gemm(1789, 923, 411), gpu).with_breakdown().tagged("bd");
    let resp = synperf::api::predict_one(&ModelBundle::default(), &req).unwrap();
    assert_eq!(resp.provenance.source, Source::Roofline, "no artifacts in tests");
    assert!(resp.breakdown.is_some());
    let line = wire::encode_response(Some("77"), &Ok(resp.clone()));
    assert!(line.contains(r#""source":"roofline""#), "degraded mode must be visible: {line}");
    assert!(line.contains(r#""breakdown":{"tensor""#));
    let (id, back) = wire::parse_response(&line).unwrap();
    assert_eq!(id.as_deref(), Some("77"));
    let back = back.unwrap();
    assert_eq!(back, resp);
    assert_eq!(
        back.breakdown.unwrap().theory_sec.to_bits(),
        resp.breakdown.unwrap().theory_sec.to_bits()
    );
}

#[test]
fn saturated_queue_returns_queue_full_not_a_hang() {
    // gate the factory so the service loop cannot start draining: the
    // bounded queue saturates deterministically
    let (gate_tx, gate_rx) = channel::<()>();
    let svc = PredictionService::spawn(
        move || {
            gate_rx.recv().ok();
            ModelBundle::default()
        },
        ServiceConfig {
            max_batch: 8,
            deadline: Duration::from_millis(1),
            queue_cap: 2,
            ..ServiceConfig::default()
        },
    );
    let client = svc.client();
    let gpu = gpu_by_name("A100").unwrap();
    let req = |i: u32| PredictRequest::new(KernelConfig::RmsNorm { seq: 64 + i, dim: 2048 }, gpu.clone());

    let p1 = client.try_predict(req(1)).unwrap();
    let p2 = client.try_predict(req(2)).unwrap();
    // queue_cap = 2: the third request must bounce immediately
    let err = client.try_predict(req(3)).unwrap_err();
    assert_eq!(err, PredictError::QueueFull);
    // the blocking path with a deadline also reports QueueFull, not a hang
    let t0 = Instant::now();
    let err = client.predict_deadline(req(4), Duration::from_millis(40)).unwrap_err();
    assert_eq!(err, PredictError::QueueFull);
    assert!(t0.elapsed() < Duration::from_secs(5), "deadline must bound the wait");
    assert_eq!(client.queue_depth(), 2, "backlog never exceeds queue_cap");

    // open the gate: everything accepted is answered
    gate_tx.send(()).unwrap();
    assert!(p1.wait().unwrap().latency_sec > 0.0);
    assert!(p2.wait().unwrap().latency_sec > 0.0);
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.rejected_requests, 2);
    assert_eq!(snap.requests, 2);
    svc.shutdown();
}

#[test]
fn shutdown_drains_accepted_requests() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let client = svc.client();
    let gpu = gpu_by_name("L20").unwrap();
    let pendings: Vec<_> = (0..16)
        .map(|i| {
            client
                .try_predict(PredictRequest::new(
                    KernelConfig::SiluMul { seq: 32 + i, dim: 1024 },
                    gpu.clone(),
                ))
                .unwrap()
        })
        .collect();
    // graceful: close the queue, answer everything already accepted
    svc.shutdown();
    for p in pendings {
        assert!(p.wait().unwrap().latency_sec > 0.0, "accepted requests are drained");
    }
    // the surviving client sees the typed terminal state
    let err = client.predict(PredictRequest::new(gemm(64, 64, 64), gpu)).unwrap_err();
    assert_eq!(err, PredictError::Shutdown);
}

#[test]
fn service_answers_are_typed_end_to_end() {
    // the service client consumes PredictResponse — degraded provenance,
    // flavor and tag all travel with the latency
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let gpu = gpu_by_name("A40").unwrap();
    let resp = svc
        .predict(PredictRequest::new(gemm(911, 433, 277), gpu.clone()).tagged("e2e"))
        .unwrap();
    assert_eq!(resp.provenance.source, Source::Roofline);
    assert_eq!(resp.flavor, Flavor::Mean);
    assert_eq!(resp.gpu, "A40");
    assert_eq!(resp.tag.as_deref(), Some("e2e"));
    // strict mode propagates the typed predictor-unavailable error
    let err = svc
        .predict(PredictRequest::new(gemm(911, 433, 277), gpu).strict())
        .unwrap_err();
    assert_eq!(err, PredictError::PredictorUnavailable(KernelKind::Gemm));
    svc.shutdown();
}

// ---- The stats verb -------------------------------------------------------

#[test]
fn stats_golden_line_roundtrips() {
    // hand-built report with exactly-representable values: the golden is
    // stable across both wire surfaces (stdio and TCP answer this one
    // shape through the same encoder)
    let report = wire::StatsReport {
        requests: 12,
        batches: 8,
        mean_batch: 1.5,
        rejected_requests: 2,
        deadline_exceeded: 1,
        queue_depth: 3,
        max_queue_depth: 7,
        cache_hits: 9,
        cache_misses: 3,
        served: 14,
        errors: 2,
        simulated: 1,
        swept: 1,
        tuned: 1,
        clients: wire::ClientStats {
            connected: 2,
            total: 5,
            quarantined: 1,
            idle_reaped: 1,
            oversized_lines: 1,
            disconnects: 2,
        },
    };
    let line = wire::encode_stats(Some("st1"), &report);
    assert_eq!(
        line,
        r#"{"v":1,"id":"st1","ok":true,"stats":{"requests":12,"batches":8,"mean_batch":1.5e0,"rejected_requests":2,"deadline_exceeded":1,"queue_depth":3,"max_queue_depth":7,"cache_hits":9,"cache_misses":3,"served":14,"errors":2,"simulated":1,"swept":1,"tuned":1,"clients":{"connected":2,"total":5,"quarantined":1,"idle_reaped":1,"oversized_lines":1,"disconnects":2}}}"#
    );
    let (id, back) = wire::parse_stats(&line).unwrap();
    assert_eq!(id.as_deref(), Some("st1"));
    assert_eq!(back, report);
}

#[test]
fn stats_verb_answers_over_the_stdio_wire() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let input = concat!(
        r#"{"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":320,"n":192,"k":256}}"#,
        "\n",
        "not json\n",
        r#"{"id":"st","op":"stats"}"#,
        "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2).unwrap();
    assert_eq!(stats.served, 3);
    assert_eq!(stats.stats_lines, 1);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 3);
    let (id, report) = wire::parse_stats(lines[2]).unwrap();
    assert_eq!(id.as_deref(), Some("st"));
    assert_eq!(report.served, 3, "the stats line counts itself");
    assert_eq!(report.errors, 1, "the malformed line counted as an error");
    assert_eq!(report.requests, 1, "the predict answered before the stats turn");
    assert_eq!(report.clients.connected, 1);
    assert_eq!(report.clients.total, 1);
    assert_eq!(report.clients.oversized_lines, 0);
    svc.shutdown();
}

// ---- Scenario API v1: the simulate verb ----------------------------------

#[test]
fn simulate_request_golden_lines() {
    let sampled = ScenarioSpec::new("Qwen2.5-14B", "A100")
        .tp(2)
        .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Arxiv, batch: 8 })
        .seed(7);
    let line = scenario_wire::encode_simulate_request(Some("s1"), &sampled);
    assert_eq!(
        line,
        r#"{"v":1,"id":"s1","op":"simulate","scenario":{"model":"Qwen2.5-14B","gpu":"A100","tp":2,"pp":1,"workload":{"kind":"arxiv","batch":8},"phases":"both","seed":7,"host_gap_sec":8e-7}}"#
    );
    let (id, parsed) = scenario_wire::parse_simulate_request(&line);
    assert_eq!(id.as_deref(), Some("s1"));
    assert_eq!(parsed.unwrap(), sampled);

    let explicit = ScenarioSpec::new("Llama3.1-8B", "H800")
        .pp(2)
        .workload(WorkloadSpec::Explicit(vec![
            Request { input_len: 1000, output_len: 200 },
            Request { input_len: 2000, output_len: 100 },
        ]))
        .host_gap_sec(1e-6);
    let line = scenario_wire::encode_simulate_request(None, &explicit);
    assert_eq!(
        line,
        r#"{"v":1,"op":"simulate","scenario":{"model":"Llama3.1-8B","gpu":"H800","tp":1,"pp":2,"workload":{"requests":[[1000,200],[2000,100]]},"phases":"both","seed":0,"host_gap_sec":1e-6}}"#
    );
    let (id, parsed) = scenario_wire::parse_simulate_request(&line);
    assert_eq!(id, None);
    assert_eq!(parsed.unwrap(), explicit);
}

/// A hand-built report with exactly-representable values, so the golden
/// line is stable and the parse-back is bit-exact.
fn golden_report() -> ScenarioReport {
    let mut prefill_bd = ClassBreakdown::default();
    prefill_bd.set(OpClass::Gemm, 0.125);
    prefill_bd.set(OpClass::Attention, 0.0625);
    prefill_bd.set(OpClass::RmsNorm, 0.03125);
    prefill_bd.set(OpClass::SiluMul, 0.015625);
    prefill_bd.set(OpClass::AllReduce, 0.0078125);
    prefill_bd.set(OpClass::HostGap, 0.0078125);
    let mut decode_bd = ClassBreakdown::default();
    decode_bd.set(OpClass::Gemm, 0.25);
    decode_bd.set(OpClass::Attention, 0.125);
    decode_bd.set(OpClass::RmsNorm, 0.0625);
    decode_bd.set(OpClass::SiluMul, 0.03125);
    decode_bd.set(OpClass::AllReduce, 0.015625);
    decode_bd.set(OpClass::SendRecv, 0.0078125);
    decode_bd.set(OpClass::HostGap, 0.0078125);
    let mut grand_bd = ClassBreakdown::default();
    for c in OpClass::ALL {
        grand_bd.set(c, prefill_bd.get(c) + decode_bd.get(c));
    }
    ScenarioReport {
        model: "Qwen2.5-32B".to_string(),
        gpu: "H800".to_string(),
        tp: 4,
        pp: 2,
        phases: vec![
            PhaseReport {
                phase: Phase::Prefill,
                totals: MethodTotals {
                    actual: 0.25,
                    synperf: 0.125,
                    roofline: 0.0625,
                    linear: 0.25,
                    habitat: 0.25,
                    neusight: 0.5,
                    degraded_kernels: 3,
                },
                breakdown: prefill_bd,
                launches: 128.0,
                tokens: 4096.0,
                steps: 1.0,
            },
            PhaseReport {
                phase: Phase::Decode,
                totals: MethodTotals {
                    actual: 0.5,
                    synperf: 0.25,
                    roofline: 0.125,
                    linear: 0.5,
                    habitat: 0.5,
                    neusight: 1.0,
                    degraded_kernels: 5,
                },
                breakdown: decode_bd,
                launches: 256.0,
                tokens: 512.0,
                steps: 64.0,
            },
        ],
        totals: MethodTotals {
            actual: 0.75,
            synperf: 0.375,
            roofline: 0.25,
            linear: 0.75,
            habitat: 0.75,
            neusight: 1.5,
            degraded_kernels: 8,
        },
        breakdown: grand_bd,
        launches: 384.0,
        cache_hits: 42,
        host_gap_sec: 8e-7,
        seed: 7,
    }
}

#[test]
fn simulate_report_golden_line_roundtrips() {
    let report = golden_report();
    let line = scenario_wire::encode_report(Some("s1"), &Ok(report.clone()));
    let golden = concat!(
        r#"{"v":1,"id":"s1","ok":true,"report":{"model":"Qwen2.5-32B","gpu":"H800","tp":4,"pp":2,"seed":7,"host_gap_sec":8e-7,"launches":3.84e2,"cache_hits":42,"#,
        r#""totals":{"actual_sec":7.5e-1,"synperf_sec":3.75e-1,"roofline_sec":2.5e-1,"linear_sec":7.5e-1,"habitat_sec":7.5e-1,"neusight_sec":1.5e0,"degraded_kernels":8},"#,
        r#""breakdown":{"gemm_sec":3.75e-1,"attention_sec":1.875e-1,"rmsnorm_sec":9.375e-2,"silu_mul_sec":4.6875e-2,"fused_moe_sec":0e0,"all_reduce_sec":2.34375e-2,"send_recv_sec":7.8125e-3,"host_gap_total_sec":1.5625e-2},"#,
        r#""phases":[{"phase":"prefill","tokens":4.096e3,"steps":1e0,"launches":1.28e2,"ttft_sec":{"actual":2.5e-1,"synperf":1.25e-1},"tokens_per_sec":{"actual":1.6384e4,"synperf":3.2768e4},"#,
        r#""totals":{"actual_sec":2.5e-1,"synperf_sec":1.25e-1,"roofline_sec":6.25e-2,"linear_sec":2.5e-1,"habitat_sec":2.5e-1,"neusight_sec":5e-1,"degraded_kernels":3},"#,
        r#""breakdown":{"gemm_sec":1.25e-1,"attention_sec":6.25e-2,"rmsnorm_sec":3.125e-2,"silu_mul_sec":1.5625e-2,"fused_moe_sec":0e0,"all_reduce_sec":7.8125e-3,"send_recv_sec":0e0,"host_gap_total_sec":7.8125e-3}},"#,
        r#"{"phase":"decode","tokens":5.12e2,"steps":6.4e1,"launches":2.56e2,"tpot_sec":{"actual":7.8125e-3,"synperf":3.90625e-3},"tokens_per_sec":{"actual":1.024e3,"synperf":2.048e3},"#,
        r#""totals":{"actual_sec":5e-1,"synperf_sec":2.5e-1,"roofline_sec":1.25e-1,"linear_sec":5e-1,"habitat_sec":5e-1,"neusight_sec":1e0,"degraded_kernels":5},"#,
        r#""breakdown":{"gemm_sec":2.5e-1,"attention_sec":1.25e-1,"rmsnorm_sec":6.25e-2,"silu_mul_sec":3.125e-2,"fused_moe_sec":0e0,"all_reduce_sec":1.5625e-2,"send_recv_sec":7.8125e-3,"host_gap_total_sec":7.8125e-3}}]}}"#,
    );
    assert_eq!(line, golden);
    let (id, back) = scenario_wire::parse_report(&line).unwrap();
    assert_eq!(id.as_deref(), Some("s1"));
    let back = back.unwrap();
    assert_eq!(back, report);
    assert_eq!(back.totals.actual.to_bits(), report.totals.actual.to_bits());
    assert_eq!(back.totals.degraded_kernels, 8);
}

#[test]
fn scenario_error_golden_lines_cover_the_whole_taxonomy() {
    let cases: Vec<(ScenarioError, &str)> = vec![
        (
            ScenarioError::UnknownModel("GPT-5".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_model","message":"unknown model \"GPT-5\" (see llm::registry())","model":"GPT-5"}}"#,
        ),
        (
            ScenarioError::UnknownGpu("B300".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_gpu","message":"unknown GPU \"B300\" (see Table VI; closest: A100, H800, H100)","gpu":"B300"}}"#,
        ),
        (
            ScenarioError::InvalidParallelism("tp=3 does not divide 40 attention heads of Qwen2.5-14B".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_parallelism","message":"invalid parallelism: tp=3 does not divide 40 attention heads of Qwen2.5-14B","reason":"tp=3 does not divide 40 attention heads of Qwen2.5-14B"}}"#,
        ),
        (
            ScenarioError::InvalidWorkload("batch must be >= 1".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_workload","message":"invalid workload: batch must be >= 1","reason":"batch must be >= 1"}}"#,
        ),
        (
            ScenarioError::MalformedSpec("simulate request needs a \"scenario\" object".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"malformed_spec","message":"malformed scenario spec: simulate request needs a \"scenario\" object","reason":"simulate request needs a \"scenario\" object"}}"#,
        ),
        (
            ScenarioError::InvalidCluster("replicas must be in 1..=64, got 0".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_cluster","message":"invalid cluster: replicas must be in 1..=64, got 0","reason":"replicas must be in 1..=64, got 0"}}"#,
        ),
    ];
    for (err, golden) in cases {
        let line = scenario_wire::encode_report(None, &Err(err.clone()));
        assert_eq!(line, golden, "wire drift for {:?}", err.code());
        let (_, back) = scenario_wire::parse_report(&line).unwrap();
        assert_eq!(back.unwrap_err(), err, "round trip for {:?}", err.code());
    }
}

#[test]
fn simulate_round_trips_over_the_stdio_wire() {
    // the acceptance path: a ScenarioSpec JSON line in, a typed
    // ScenarioReport line out, interleaved with predict-verb lines, over
    // the same serve loop `synperf serve --stdio` runs
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let input = concat!(
        r#"{"v":1,"id":"sim1","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"A100","tp":2,"workload":{"requests":[[96,8],[64,4]]},"seed":11,"host_gap_sec":1e-6}}"#,
        "\n",
        r#"{"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":256,"n":256,"k":256}}"#,
        "\n",
        r#"{"id":"sim2","op":"simulate","scenario":{"model":"GPT-5","gpu":"A100"}}"#,
        "\n",
        r#"{"id":"sim3","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"B300"}}"#,
        "\n",
        r#"{"id":"sim4","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"A100","tp":5}}"#,
        "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2).unwrap();
    assert_eq!(stats.served, 5);
    assert_eq!(stats.simulated, 4);
    assert_eq!(stats.errors, 3);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 5);

    // line 0: the full typed report round-trips client-side
    let (id, rep) = scenario_wire::parse_report(lines[0]).unwrap();
    assert_eq!(id.as_deref(), Some("sim1"));
    let rep = rep.unwrap();
    assert_eq!(rep.model, "Llama3.1-8B");
    assert_eq!(rep.gpu, "A100");
    assert_eq!((rep.tp, rep.pp), (2, 1));
    assert_eq!(rep.host_gap_sec, 1e-6);
    assert_eq!(rep.phases.len(), 2);
    assert_eq!(rep.phases[0].phase, Phase::Prefill);
    assert_eq!(rep.phases[1].phase, Phase::Decode);
    assert!(rep.ttft_sec(synperf::scenario::Method::SynPerf).unwrap() > 0.0);
    assert!(rep.tpot_sec(synperf::scenario::Method::Actual).unwrap() > 0.0);
    assert!(rep.totals.degraded_kernels > 0, "degraded provenance over the wire");
    assert!(rep.breakdown.get(OpClass::Gemm) > 0.0);
    assert!(rep.breakdown.get(OpClass::AllReduce) > 0.0, "tp=2 schedules collectives");
    assert!(rep.launches > 0.0);

    // line 1: the predict verb still answers between simulations
    assert!(lines[1].contains(r#""id":"p1""#) && lines[1].contains(r#""ok":true"#));
    // lines 2-4: the closed ScenarioError taxonomy travels the wire
    assert!(lines[2].contains(r#""id":"sim2""#) && lines[2].contains(r#""code":"unknown_model""#));
    assert!(lines[3].contains(r#""id":"sim3""#) && lines[3].contains(r#""code":"unknown_gpu""#));
    assert!(
        lines[4].contains(r#""id":"sim4""#) && lines[4].contains(r#""code":"invalid_parallelism""#)
    );
    svc.shutdown();
}

// ---- Sweep subsystem: the sweep verb --------------------------------------

#[test]
fn sweep_request_golden_line() {
    let spec = SweepSpec::new()
        .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
        .tp(vec![1, 2])
        .slo(2.0, 0.25)
        .scenario("chat", ScenarioSpec::new("Qwen2.5-14B", ""));
    let line = sweep_wire::encode_sweep_request(Some("sw1"), &spec);
    assert_eq!(
        line,
        r#"{"v":1,"id":"sw1","op":"sweep","sweep":{"gpus":["A100","H800"],"tp":[1,2],"pp":[1],"replicas":[1],"policies":["round_robin"],"slo":{"ttft_sec":2e0,"tpot_sec":2.5e-1},"workloads":[{"name":"chat","scenario":{"model":"Qwen2.5-14B","gpu":"","tp":1,"pp":1,"workload":{"kind":"arxiv","batch":8},"phases":"both","seed":0,"host_gap_sec":8e-7}}]}}"#
    );
    let (id, parsed) = sweep_wire::parse_sweep_line(&line);
    assert_eq!(id.as_deref(), Some("sw1"));
    let req = parsed.unwrap();
    assert_eq!(req.spec, spec);
    // default shard and no journal never appear on the wire
    assert_eq!((req.shard.index, req.shard.count), (0, 1));
    assert_eq!(req.journal, None);
}

/// Hand-built row with power-of-two metrics, so the `{:e}` golden is
/// hand-computable and the line is stable.
fn sweep_row(index: usize, tp: u32, tps: f64, slo: f64) -> SweepRow {
    SweepRow {
        index,
        workload: "chat".to_string(),
        gpu: "H800".to_string(),
        tp,
        pp: 1,
        replicas: 1,
        policy: RoutePolicy::RoundRobin,
        gpu_count: tp,
        outcome: Ok(SweepMetrics {
            tokens_per_sec: tps,
            slo_attainment: slo,
            ttft_sec: 0.25,
            tpot_sec: 0.03125,
            cluster: false,
            usd_per_hour: 5.0,
            usd_per_mtok: 0.25,
        }),
    }
}

#[test]
fn sweep_row_golden_lines() {
    let ok = sweep_row(3, 2, 4096.0, 0.5);
    let ok_line = sweep_wire::encode_row(&ok);
    assert_eq!(
        ok_line,
        r#"{"v":1,"row":{"index":3,"workload":"chat","gpu":"H800","tp":2,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":2,"ok":true,"cluster":false,"tokens_per_sec":4.096e3,"slo_attainment":5e-1,"ttft_sec":2.5e-1,"tpot_sec":3.125e-2,"usd_per_hour":5e0,"usd_per_mtok":2.5e-1}}"#
    );
    // the journal replay codec is the exact inverse of the row codec
    let replayed = sweep_wire::parse_row(&ok_line).unwrap();
    assert_eq!(sweep_wire::encode_row(&replayed), ok_line);
    // infeasible configs are rows, not failures — the scenario error
    // object rides inside the row byte-for-byte
    let mut err = sweep_row(1, 3, 0.0, 0.0);
    err.outcome = Err(ScenarioError::InvalidParallelism(
        "tp=3 does not divide 32 attention heads of Llama3.1-8B".to_string(),
    )
    .into());
    let err_line = sweep_wire::encode_row(&err);
    assert_eq!(
        err_line,
        r#"{"v":1,"row":{"index":1,"workload":"chat","gpu":"H800","tp":3,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":3,"ok":false,"error":{"code":"invalid_parallelism","message":"invalid parallelism: tp=3 does not divide 32 attention heads of Llama3.1-8B","reason":"tp=3 does not divide 32 attention heads of Llama3.1-8B"}}}"#
    );
    let replayed = sweep_wire::parse_row(&err_line).unwrap();
    assert_eq!(sweep_wire::encode_row(&replayed), err_line);
    // the two crash-safety row shapes: contained panics and watchdog kills
    let mut timeout = sweep_row(2, 1, 0.0, 0.0);
    timeout.outcome = Err(synperf::sweep::RowError::Timeout(
        "point evaluation exceeded 250ms".to_string(),
    ));
    assert_eq!(
        sweep_wire::encode_row(&timeout),
        r#"{"v":1,"row":{"index":2,"workload":"chat","gpu":"H800","tp":1,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":1,"ok":false,"error":{"code":"timeout","message":"sweep point timed out: point evaluation exceeded 250ms","reason":"point evaluation exceeded 250ms"}}}"#
    );
    let mut violated = sweep_row(4, 2, 0.0, 0.0);
    violated.outcome = Err(synperf::sweep::RowError::ConstraintViolated(
        "gpu_count 2 > max_gpus 1".to_string(),
    ));
    assert_eq!(
        sweep_wire::encode_row(&violated),
        r#"{"v":1,"row":{"index":4,"workload":"chat","gpu":"H800","tp":2,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":2,"ok":false,"error":{"code":"constraint_violated","message":"constraint violated: gpu_count 2 > max_gpus 1","reason":"gpu_count 2 > max_gpus 1"}}}"#
    );
}

#[test]
fn sweep_frontier_golden_line() {
    // r1 (2x throughput at 2x cost) and r0 (efficient) both survive; the
    // efficiency tie (1024 tok/s/GPU) ranks r1 first on raw throughput;
    // r2 is dominated by both, in rank order
    let rows = vec![
        sweep_row(0, 1, 1024.0, 1.0),
        sweep_row(1, 2, 2048.0, 0.5),
        sweep_row(2, 2, 512.0, 0.5),
    ];
    let p = pareto(&rows);
    assert_eq!(
        sweep_wire::encode_frontier(&rows, &p),
        r#"{"v":1,"frontier":[{"rank":1,"index":1,"workload":"chat","gpu":"H800","tp":2,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":2,"tokens_per_sec":2.048e3,"slo_attainment":5e-1,"usd_per_mtok":2.5e-1},{"rank":2,"index":0,"workload":"chat","gpu":"H800","tp":1,"pp":1,"replicas":1,"policy":"round_robin","gpu_count":1,"tokens_per_sec":1.024e3,"slo_attainment":1e0,"usd_per_mtok":2.5e-1}],"dominated":[{"index":2,"by":[1,0]}]}"#
    );
}

#[test]
fn sweep_error_golden_lines_cover_the_whole_taxonomy() {
    let cases: Vec<(SweepError, &str)> = vec![
        (
            SweepError::UnknownGpu("B300".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_gpu","message":"unknown GPU \"B300\" (see Table VI; closest: A100, H800, H100)","gpu":"B300"}}"#,
        ),
        (
            SweepError::InvalidAxis("\"tp\" values must be >= 1".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_axis","message":"invalid sweep axis: \"tp\" values must be >= 1","reason":"\"tp\" values must be >= 1"}}"#,
        ),
        (
            SweepError::GridTooLarge("5632 points exceed the cap of 4096".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"grid_too_large","message":"sweep grid too large: 5632 points exceed the cap of 4096","reason":"5632 points exceed the cap of 4096"}}"#,
        ),
        (
            SweepError::MalformedSpec("sweep needs \"workloads\": [..]".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"malformed_spec","message":"malformed sweep spec: sweep needs \"workloads\": [..]","reason":"sweep needs \"workloads\": [..]"}}"#,
        ),
        (
            SweepError::InvalidWorkload("invalid workload: unknown workload kind \"mmlu\" (arxiv|splitwise)".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_workload","message":"invalid sweep workload: invalid workload: unknown workload kind \"mmlu\" (arxiv|splitwise)","reason":"invalid workload: unknown workload kind \"mmlu\" (arxiv|splitwise)"}}"#,
        ),
        (
            SweepError::JournalCorrupt("line 7 is not a sweep row".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"journal_corrupt","message":"sweep journal corrupt: line 7 is not a sweep row","reason":"line 7 is not a sweep row"}}"#,
        ),
        (
            SweepError::FingerprintMismatch("journal holds aaaa; spec is bbbb".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"fingerprint_mismatch","message":"sweep journal fingerprint mismatch: journal holds aaaa; spec is bbbb","reason":"journal holds aaaa; spec is bbbb"}}"#,
        ),
        (
            SweepError::MergeConflict("shard 1/3 appears in both a.jsonl and b.jsonl".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"merge_conflict","message":"sweep merge conflict: shard 1/3 appears in both a.jsonl and b.jsonl","reason":"shard 1/3 appears in both a.jsonl and b.jsonl"}}"#,
        ),
        (
            SweepError::MergeIncomplete("missing shard(s) 2 of 3".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"merge_incomplete","message":"sweep merge incomplete: missing shard(s) 2 of 3","reason":"missing shard(s) 2 of 3"}}"#,
        ),
    ];
    for (err, golden) in cases {
        let line = sweep_wire::encode_sweep_response(None, &Err(err.clone()));
        assert_eq!(line, golden, "wire drift for {:?}", err.code());
    }
}

#[test]
fn sharded_sweep_request_and_journal_header_golden_lines() {
    use synperf::sweep::journal::{encode_header, parse_header_line, JournalHeader};
    use synperf::sweep::{Shard, SweepRequest};

    // the crash-safety envelope: shard + journal ride the request line
    // (only when non-default, so legacy lines stay byte-identical)
    let spec = SweepSpec::new()
        .gpus(GpuFilter::Named(vec!["A100".into()]))
        .tp(vec![1])
        .max_gpus(4);
    let req = SweepRequest {
        spec,
        shard: Shard::new(1, 3),
        journal: Some("shard1.jsonl".to_string()),
    };
    let line = sweep_wire::encode_sweep_request_with(Some("sw2"), &req);
    assert!(line.contains(r#""constraints":{"max_gpus":4}"#), "{line}");
    assert!(line.ends_with(r#","shard":{"index":1,"count":3},"journal":"shard1.jsonl"}"#), "{line}");
    let (id, parsed) = sweep_wire::parse_sweep_line(&line);
    assert_eq!(id.as_deref(), Some("sw2"));
    assert_eq!(parsed.unwrap(), req);

    // the journal's first line identifies the campaign and the shard
    let h = JournalHeader {
        fingerprint: "00ff00ff00ff00ff".to_string(),
        points: 44,
        shard_index: 1,
        shard_count: 3,
    };
    let line = encode_header(&h);
    assert_eq!(
        line,
        r#"{"v":1,"sweep_journal":{"fingerprint":"00ff00ff00ff00ff","points":44,"shard_index":1,"shard_count":3}}"#
    );
    assert_eq!(parse_header_line(&line).unwrap(), h);
}

#[test]
fn sweep_round_trips_over_the_stdio_wire() {
    // a sweep line between predict lines: one request in, one line out,
    // rows + frontier embedded, order preserved
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let input = concat!(
        r#"{"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":256,"n":256,"k":256}}"#,
        "\n",
        r#"{"v":1,"id":"sw1","op":"sweep","sweep":{"gpus":["A100","H800"],"tp":[1,2],"workloads":[{"name":"tiny","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}}"#,
        "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2).unwrap();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.swept, 1);
    assert_eq!(stats.errors, 0);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2);
    assert!(lines[0].contains(r#""id":"p1""#) && lines[0].contains(r#""ok":true"#));
    // 2 GPUs x tp {1,2} = 4 rows (all feasible: 32 heads divide by 2),
    // every index present and a ranked frontier behind them
    assert!(lines[1].starts_with(r#"{"v":1,"id":"sw1","ok":true,"sweep":{"rows":["#));
    for i in 0..4 {
        assert!(lines[1].contains(&format!(r#""index":{i},"#)), "row {i} missing: {}", lines[1]);
    }
    assert!(lines[1].contains(r#""frontier":[{"rank":1,"#));
    svc.shutdown();
}

// ---- Autotune subsystem: the tune verb -------------------------------------

#[test]
fn tune_request_golden_lines() {
    let spec = TuneSpec::new()
        .gpus(GpuFilter::Named(vec!["A40".into()]))
        .source(ConfigSource::Sampled { n: 4 })
        .gap_threshold(0.05)
        .seed(42);
    let line = tune_wire::encode_tune_request(Some("t1"), &spec);
    assert_eq!(
        line,
        r#"{"v":1,"id":"t1","op":"tune","tune":{"gpus":["A40"],"source":{"sampled":4},"gap_threshold":5e-2,"seed":42,"max_block":128,"max_stages":5,"max_warps":8}}"#
    );
    let (id, parsed) = tune_wire::parse_tune_line(&line);
    assert_eq!(id.as_deref(), Some("t1"));
    assert_eq!(parsed.unwrap(), spec);

    // explicit shapes, tightened bounds, paper-default threshold and seed
    let explicit = TuneSpec::new()
        .source(ConfigSource::Explicit(vec![MoeShape { m: 64, e: 8, topk: 2, h: 1024, n: 512 }]))
        .bounds(64, 4, 4);
    let line = tune_wire::encode_tune_request(None, &explicit);
    assert_eq!(
        line,
        r#"{"v":1,"op":"tune","tune":{"gpus":"all","source":{"explicit":[{"m":64,"e":8,"topk":2,"h":1024,"n":512}]},"gap_threshold":1e-1,"seed":31358,"max_block":64,"max_stages":4,"max_warps":4}}"#
    );
    let (id, parsed) = tune_wire::parse_tune_line(&line);
    assert_eq!(id, None);
    assert_eq!(parsed.unwrap(), explicit);
}

/// Hand-built row with power-of-two efficiencies, so the `{:e}` golden is
/// hand-computable and the line is stable.
fn tune_row_golden() -> TuneRow {
    TuneRow {
        index: 0,
        gpu: "A40".to_string(),
        ceiling: "roofline",
        shape: MoeShape { m: 64, e: 8, topk: 2, h: 1024, n: 512 },
        default_cfg: MoeConfig {
            block_m: 64,
            block_n: 64,
            block_k: 32,
            num_stages: 4,
            num_warps: 4,
        },
        best_cfg: MoeConfig {
            block_m: 128,
            block_n: 64,
            block_k: 32,
            num_stages: 3,
            num_warps: 8,
        },
        diagnosed: true,
        actual_eff: 0.5,
        ceiling_eff: 0.75,
        eff_after: 0.625,
        gap_before: 0.25,
        gap_after: 0.125,
        speedup: 1.25,
    }
}

#[test]
fn tune_row_and_summary_golden_lines() {
    assert_eq!(
        tune_wire::encode_row(&tune_row_golden()),
        r#"{"v":1,"row":{"index":0,"gpu":"A40","ceiling":"roofline","shape":{"m":64,"e":8,"topk":2,"h":1024,"n":512},"diagnosed":true,"default":{"block_m":64,"block_n":64,"block_k":32,"num_stages":4,"num_warps":4},"best":{"block_m":128,"block_n":64,"block_k":32,"num_stages":3,"num_warps":8},"actual_eff":5e-1,"ceiling_eff":7.5e-1,"eff_after":6.25e-1,"gap_before":2.5e-1,"gap_after":1.25e-1,"speedup":1.25e0}}"#
    );
    let summary = TuneSummary {
        points: 4,
        diagnosed: 2,
        ceiling: "roofline",
        geomean_speedup: 1.5,
        geomean_speedup_diagnosed: 2.25,
        gap_closure: 0.5,
        max_speedup: 2.5,
        ranked: vec![2, 0],
    };
    assert_eq!(
        tune_wire::encode_summary(&summary),
        r#"{"v":1,"summary":{"points":4,"diagnosed":2,"ceiling":"roofline","geomean_speedup":1.5e0,"geomean_speedup_diagnosed":2.25e0,"gap_closure":5e-1,"max_speedup":2.5e0,"ranked":[2,0]}}"#
    );
}

#[test]
fn tune_error_golden_lines_cover_the_whole_taxonomy() {
    let cases: Vec<(TuneError, &str)> = vec![
        (
            TuneError::UnknownGpu("B300".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unknown_gpu","message":"unknown GPU \"B300\" (see Table VI; closest: A100, H800, H100)","gpu":"B300"}}"#,
        ),
        (
            TuneError::UnsupportedKernel("gemm is not a fused-MoE launch".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"unsupported_kernel","message":"unsupported kernel: gemm is not a fused-MoE launch","reason":"gemm is not a fused-MoE launch"}}"#,
        ),
        (
            TuneError::InvalidSpec("sampled count must be >= 1".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"invalid_spec","message":"invalid tune spec: sampled count must be >= 1","reason":"sampled count must be >= 1"}}"#,
        ),
        (
            TuneError::GridTooLarge("1408 points exceed the cap of 512".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"grid_too_large","message":"tune grid too large: 1408 points exceed the cap of 512","reason":"1408 points exceed the cap of 512"}}"#,
        ),
        (
            TuneError::MalformedSpec("tune request needs a \"tune\" object".to_string()),
            r#"{"v":1,"ok":false,"error":{"code":"malformed_spec","message":"malformed tune spec: tune request needs a \"tune\" object","reason":"tune request needs a \"tune\" object"}}"#,
        ),
    ];
    for (err, golden) in cases {
        let line = tune_wire::encode_tune_response(None, &Err(err.clone()));
        assert_eq!(line, golden, "wire drift for {:?}", err.code());
    }
}

#[test]
fn tune_round_trips_over_the_stdio_wire() {
    // a tune line between predict, simulate and spec-error traffic: one
    // request in, one line out, rows + summary embedded, order preserved
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let input = concat!(
        r#"{"id":"p1","gpu":"A100","kernel":{"type":"gemm","m":256,"n":256,"k":256}}"#,
        "\n",
        r#"{"v":1,"id":"t1","op":"tune","tune":{"gpus":["A40"],"source":{"sampled":2},"seed":31}}"#,
        "\n",
        r#"{"id":"sim1","op":"simulate","scenario":{"model":"llama3.1-8b","gpu":"A100","workload":{"requests":[[64,4]]}}}"#,
        "\n",
        r#"{"id":"t2","op":"tune","tune":{"gpus":["B300"]}}"#,
        "\n",
    );
    let mut out = Vec::new();
    let stats =
        serve_lines(&svc.client(), Simulator::degraded, input.as_bytes(), &mut out, 8, 2).unwrap();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.tuned, 2);
    assert_eq!(stats.simulated, 1);
    assert_eq!(stats.errors, 1, "the unknown-GPU tune is the only error");
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 4);
    assert!(lines[0].contains(r#""id":"p1""#) && lines[0].contains(r#""ok":true"#));
    // the tune answer is one line: every row plus the summary, with
    // ceiling provenance visible (no trained P80 artifact in tests)
    assert!(lines[1].starts_with(r#"{"v":1,"id":"t1","ok":true,"tune":{"rows":["#), "{}", lines[1]);
    for i in 0..2 {
        assert!(lines[1].contains(&format!(r#""index":{i},"#)), "row {i} missing: {}", lines[1]);
    }
    assert!(lines[1].contains(r#""summary":{"points":2"#), "{}", lines[1]);
    assert!(lines[1].contains(r#""ceiling":"roofline""#), "{}", lines[1]);
    // the simulate verb still answers between tunes
    assert!(lines[2].contains(r#""id":"sim1""#) && lines[2].contains(r#""ok":true"#));
    // spec-level tune failures travel the closed taxonomy, in order
    assert!(lines[3].contains(r#""id":"t2""#) && lines[3].contains(r#""code":"unknown_gpu""#));
    assert!(lines[3].contains("closest: A100, H800, H100"), "{}", lines[3]);
    svc.shutdown();
}
