//! Crash-safety acceptance tests for the sweep surface, exercised the
//! only honest way: against spawned `synperf` processes. The contract
//! under test is byte-identity — a run that is SIGKILLed mid-sweep and
//! resumed from its journal, and a run split across three shards and
//! merged back, must both reproduce the uninterrupted single-process
//! stdout exactly. Panic containment and the point watchdog get their
//! own processes because the injection hooks
//! (`SYNPERF_SWEEP_PANIC_INDEX`, `SYNPERF_SWEEP_STALL_MS`,
//! `SYNPERF_TUNE_PANIC_INDEX`) read from the process-global environment.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Output, Stdio};
use std::time::{Duration, Instant};

const BIN: &str = env!("CARGO_BIN_EXE_synperf");

/// The campaign every test sweeps: 3 GPUs x tp {1,2} x 1 workload =
/// 6 points, all feasible, cheap enough to finish in test time.
const SPEC: &str = r#"{"gpus":["A100","H800","L20"],"tp":[1,2],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}"#;

/// A per-test temp path, unique across concurrently running test
/// binaries (same-process tests use distinct `name`s).
fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("synperf_crash_{}_{name}", std::process::id()))
}

fn write_spec(name: &str, spec: &str) -> PathBuf {
    let p = tmp(name);
    std::fs::write(&p, format!("{spec}\n")).unwrap();
    p
}

/// A `synperf` invocation with the failure-injection hooks scrubbed
/// (tests inject them explicitly per spawn).
fn synperf(args: &[&str]) -> Command {
    let mut c = Command::new(BIN);
    c.args(args)
        .env_remove("SYNPERF_SWEEP_PANIC_INDEX")
        .env_remove("SYNPERF_SWEEP_STALL_MS")
        .env_remove("SYNPERF_TUNE_PANIC_INDEX")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    c
}

fn run(args: &[&str]) -> Output {
    synperf(args).output().unwrap()
}

fn stdout_of(out: &Output) -> String {
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    String::from_utf8(out.stdout.clone()).unwrap()
}

/// The ground truth every crash-safety path must reproduce byte-for-byte.
fn baseline(spec_path: &Path) -> String {
    stdout_of(&run(&["sweep", "--spec", spec_path.to_str().unwrap(), "--threads", "1", "--json"]))
}

/// Poll until `journal` holds at least `lines` durable lines (header
/// included), so a kill lands mid-campaign rather than before it starts.
fn wait_for_journal_lines(journal: &Path, lines: usize, child: &mut Child) {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let have =
            std::fs::read_to_string(journal).map(|t| t.lines().count()).unwrap_or(0);
        if have >= lines {
            return;
        }
        if let Some(status) = child.try_wait().unwrap() {
            panic!("sweep exited ({status}) before writing {lines} journal lines (have {have})");
        }
        assert!(Instant::now() < deadline, "journal never reached {lines} lines (have {have})");
        std::thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn sigkilled_sweep_resumes_byte_identically() {
    let spec = write_spec("resume_spec.jsonl", SPEC);
    let journal = tmp("resume.jsonl");
    let _ = std::fs::remove_file(&journal);
    let golden = baseline(&spec);

    // wedge index 2 long enough to guarantee the SIGKILL lands there,
    // with rows 0 and 1 already fsync'd (serial path evaluates in order)
    let mut child = synperf(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--threads",
        "1",
        "--json",
    ])
    .env("SYNPERF_SWEEP_STALL_MS", "2:120000")
    .spawn()
    .unwrap();
    wait_for_journal_lines(&journal, 3, &mut child);
    child.kill().unwrap(); // SIGKILL on unix: no destructors, no flushes
    child.wait().unwrap();

    // resume replays the durable rows and runs only the missing ones;
    // stdout is byte-identical to the uninterrupted run
    let resumed = stdout_of(&run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--threads",
        "1",
        "--json",
    ]));
    assert_eq!(resumed, golden, "resumed stdout must match the uninterrupted run");

    // the journal is now complete: header + all 6 rows
    let text = std::fs::read_to_string(&journal).unwrap();
    assert_eq!(text.lines().count(), 7, "journal: {text}");

    // a second resume replays everything without re-running anything —
    // still byte-identical — while omitting --resume refuses to clobber
    let replayed = stdout_of(&run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--json",
    ]));
    assert_eq!(replayed, golden);
    let clobber = run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--json",
    ]);
    assert!(!clobber.status.success(), "existing journal without --resume must refuse");
    assert!(
        String::from_utf8_lossy(&clobber.stderr).contains("already exists"),
        "stderr: {}",
        String::from_utf8_lossy(&clobber.stderr)
    );

    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&spec);
}

#[test]
fn truncated_tails_recover_and_interior_corruption_is_typed() {
    let spec = write_spec("corrupt_spec.jsonl", SPEC);
    let journal = tmp("corrupt.jsonl");
    let _ = std::fs::remove_file(&journal);
    let golden = baseline(&spec);
    stdout_of(&run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--json",
    ]));
    let complete = std::fs::read_to_string(&journal).unwrap();

    // a half-written final line is a crash artifact: silently discarded
    std::fs::write(&journal, format!("{complete}{{\"v\":1,\"row\":{{\"ind")).unwrap();
    let resumed = stdout_of(&run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--json",
    ]));
    assert_eq!(resumed, golden, "truncated tail must not poison the resume");

    // corruption anywhere else is a typed, loud failure
    let mut lines: Vec<&str> = complete.lines().collect();
    lines[2] = "garbage, not a row";
    std::fs::write(&journal, format!("{}\n", lines.join("\n"))).unwrap();
    let out = stdout_of(&run(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--json",
    ]));
    assert_eq!(out.lines().count(), 1, "corrupt journal must abort before any row: {out}");
    assert!(out.contains(r#""code":"journal_corrupt""#), "{out}");

    // a journal from a different campaign is refused by fingerprint
    std::fs::write(&journal, &complete).unwrap();
    let other = write_spec(
        "corrupt_other_spec.jsonl",
        r#"{"gpus":["A100"],"tp":[1],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}"#,
    );
    let out = stdout_of(&run(&[
        "sweep",
        "--spec",
        other.to_str().unwrap(),
        "--journal",
        journal.to_str().unwrap(),
        "--resume",
        "--json",
    ]));
    assert!(out.contains(r#""code":"fingerprint_mismatch""#), "{out}");

    let _ = std::fs::remove_file(&journal);
    let _ = std::fs::remove_file(&spec);
    let _ = std::fs::remove_file(&other);
}

#[test]
fn contained_panics_and_watchdog_timeouts_become_typed_rows() {
    let spec = write_spec("contain_spec.jsonl", SPEC);

    // an injected panic at index 3 yields a typed internal row; the other
    // five points and the frontier are unharmed
    let out = synperf(&["sweep", "--spec", spec.to_str().unwrap(), "--json"])
        .env("SYNPERF_SWEEP_PANIC_INDEX", "3")
        .output()
        .unwrap();
    let text = stdout_of(&out);
    assert_eq!(text.lines().count(), 7, "{text}");
    let bad: Vec<&str> = text.lines().filter(|l| l.contains(r#""ok":false"#)).collect();
    assert_eq!(bad.len(), 1, "{text}");
    assert!(bad[0].contains(r#""index":3"#), "{}", bad[0]);
    assert!(bad[0].contains(r#""code":"internal""#), "{}", bad[0]);
    assert!(bad[0].contains("panicked"), "{}", bad[0]);
    assert!(text.lines().last().unwrap().contains(r#""frontier":["#), "{text}");

    // a wedged point is abandoned by the watchdog as a typed timeout row
    let out = synperf(&[
        "sweep",
        "--spec",
        spec.to_str().unwrap(),
        "--point-timeout-ms",
        "250",
        "--threads",
        "2",
        "--json",
    ])
    .env("SYNPERF_SWEEP_STALL_MS", "1:120000")
    .output()
    .unwrap();
    let text = stdout_of(&out);
    assert_eq!(text.lines().count(), 7, "{text}");
    let bad: Vec<&str> = text.lines().filter(|l| l.contains(r#""ok":false"#)).collect();
    assert_eq!(bad.len(), 1, "{text}");
    assert!(bad[0].contains(r#""index":1"#), "{}", bad[0]);
    assert!(bad[0].contains(r#""code":"timeout""#), "{}", bad[0]);

    let _ = std::fs::remove_file(&spec);
}

#[test]
fn three_shards_merge_back_to_the_unsharded_bytes() {
    let spec = write_spec("shard_spec.jsonl", SPEC);
    let golden = baseline(&spec);

    let journals: Vec<PathBuf> = (0..3).map(|i| tmp(&format!("shard{i}.jsonl"))).collect();
    for (i, journal) in journals.iter().enumerate() {
        let _ = std::fs::remove_file(journal);
        stdout_of(&run(&[
            "sweep",
            "--spec",
            spec.to_str().unwrap(),
            "--shard",
            &format!("{i}/3"),
            "--journal",
            journal.to_str().unwrap(),
            "--threads",
            "2",
            "--json",
        ]));
    }
    let paths: Vec<&str> = journals.iter().map(|p| p.to_str().unwrap()).collect();

    // union of the three shard journals == the unsharded stream, bytes
    // included — rows by global index, then the recomputed frontier
    let mut merge_args = vec!["sweep-merge"];
    merge_args.extend(paths.iter().copied());
    merge_args.push("--json");
    let merged = stdout_of(&run(&merge_args));
    assert_eq!(merged, golden, "sweep-merge must reproduce the single-process bytes");

    // shard-journal order must not matter
    let shuffled = stdout_of(&run(&["sweep-merge", paths[2], paths[0], paths[1], "--json"]));
    assert_eq!(shuffled, golden);

    // the typed merge failures: a missing shard, a duplicated shard, and
    // a journal from a different campaign
    let out = stdout_of(&run(&["sweep-merge", paths[0], paths[1], "--json"]));
    assert!(out.contains(r#""code":"merge_incomplete""#), "{out}");
    let out = stdout_of(&run(&["sweep-merge", paths[0], paths[0], paths[1], "--json"]));
    assert!(out.contains(r#""code":"merge_conflict""#), "{out}");
    let other_spec = write_spec(
        "shard_other_spec.jsonl",
        r#"{"gpus":["A100"],"tp":[1,2],"workloads":[{"name":"chat","scenario":{"model":"llama3.1-8b","workload":{"requests":[[64,4]]},"seed":3}}]}"#,
    );
    let other_journal = tmp("shard_other.jsonl");
    let _ = std::fs::remove_file(&other_journal);
    stdout_of(&run(&[
        "sweep",
        "--spec",
        other_spec.to_str().unwrap(),
        "--shard",
        "0/3",
        "--journal",
        other_journal.to_str().unwrap(),
        "--json",
    ]));
    let out = stdout_of(&run(&[
        "sweep-merge",
        other_journal.to_str().unwrap(),
        paths[1],
        paths[2],
        "--json",
    ]));
    assert!(out.contains(r#""code":"fingerprint_mismatch""#), "{out}");

    for j in journals.iter().chain([&other_journal]) {
        let _ = std::fs::remove_file(j);
    }
    let _ = std::fs::remove_file(&spec);
    let _ = std::fs::remove_file(&other_spec);
}

#[test]
fn tune_panics_are_contained_as_error_rows() {
    let spec = write_spec(
        "tune_spec.jsonl",
        r#"{"v":1,"op":"tune","tune":{"gpus":["A40"],"source":{"sampled":4},"seed":42}}"#,
    );
    let out = synperf(&["tune", "--spec", spec.to_str().unwrap(), "--threads", "1", "--json"])
        .env("SYNPERF_TUNE_PANIC_INDEX", "1")
        .output()
        .unwrap();
    let text = stdout_of(&out);
    assert_eq!(text.lines().count(), 5, "4 rows + summary: {text}");
    let bad: Vec<&str> = text.lines().filter(|l| l.contains(r#""error":{"#)).collect();
    assert_eq!(bad.len(), 1, "{text}");
    assert!(bad[0].contains(r#""index":1"#), "{}", bad[0]);
    assert!(bad[0].contains(r#""code":"internal""#), "{}", bad[0]);
    assert!(bad[0].contains("panicked"), "{}", bad[0]);
    // the contained row is neutral: undiagnosed, speedup 1.0 — the
    // summary counts no phantom gains from it
    assert!(bad[0].contains(r#""diagnosed":false"#), "{}", bad[0]);
    assert!(bad[0].contains(r#""speedup":1e0"#), "{}", bad[0]);
    assert!(text.lines().last().unwrap().contains(r#""summary":{"#), "{text}");
    let _ = std::fs::remove_file(&spec);
}
