//! Integration tests for the PJRT runtime against the real AOT artifacts.
//! Requires `make artifacts` to have run (skipped gracefully otherwise).

use synperf::runtime::{lit_f32, lit_key, lit_scalar, to_f32, Engine};

fn engine() -> Option<Engine> {
    match Engine::new("artifacts") {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT tests (no artifacts): {err:#}");
            None
        }
    }
}

#[test]
fn manifest_matches_feature_dim() {
    let Some(e) = engine() else { return };
    assert_eq!(e.manifest.feature_dim, synperf::features::FEATURE_DIM);
    assert!(e.manifest.fwd_batches.contains(&1));
    assert!(e.manifest.fwd_batches.contains(&256));
}

#[test]
fn forward_runs_and_outputs_sigmoid_range() {
    let Some(e) = engine() else { return };
    let theta = e.read_f32_blob("init_theta.bin").unwrap();
    let bn = e.read_f32_blob("init_bn.bin").unwrap();
    assert_eq!(theta.len(), e.manifest.theta_size);
    assert_eq!(bn.len(), e.manifest.bn_size);
    let fwd = e.load("mlp_fwd_b64.hlo.txt").unwrap();
    let f = e.manifest.feature_dim;
    let x: Vec<f32> = (0..64 * f).map(|i| ((i % 13) as f32 - 6.0) / 6.0).collect();
    let out = fwd
        .run(&[
            lit_f32(&theta, &[theta.len() as i64]).unwrap(),
            lit_f32(&bn, &[bn.len() as i64]).unwrap(),
            lit_f32(&x, &[64, f as i64]).unwrap(),
        ])
        .unwrap();
    let eff = to_f32(&out[0]).unwrap();
    assert_eq!(eff.len(), 64);
    assert!(eff.iter().all(|v| *v > 0.0 && *v < 1.0), "{eff:?}");
}

#[test]
fn forward_batches_agree() {
    // b1 and b256 variants must produce identical outputs for the same row
    let Some(e) = engine() else { return };
    let theta = e.read_f32_blob("init_theta.bin").unwrap();
    let bn = e.read_f32_blob("init_bn.bin").unwrap();
    let f = e.manifest.feature_dim;
    let row: Vec<f32> = (0..f).map(|i| (i as f32) / 31.0 - 0.5).collect();
    let fwd1 = e.load("mlp_fwd_b1.hlo.txt").unwrap();
    let fwd256 = e.load("mlp_fwd_b256.hlo.txt").unwrap();
    let t = lit_f32(&theta, &[theta.len() as i64]).unwrap();
    let b = lit_f32(&bn, &[bn.len() as i64]).unwrap();
    let o1 = fwd1.run(&[t, b, lit_f32(&row, &[1, f as i64]).unwrap()]).unwrap();
    let mut big = Vec::new();
    for _ in 0..256 {
        big.extend_from_slice(&row);
    }
    let t = lit_f32(&theta, &[theta.len() as i64]).unwrap();
    let b = lit_f32(&bn, &[bn.len() as i64]).unwrap();
    let o256 = fwd256.run(&[t, b, lit_f32(&big, &[256, f as i64]).unwrap()]).unwrap();
    let v1 = to_f32(&o1[0]).unwrap()[0];
    let v256 = to_f32(&o256[0]).unwrap();
    assert!((v1 - v256[0]).abs() < 1e-5);
    assert!((v1 - v256[255]).abs() < 1e-5);
}

#[test]
fn train_step_decreases_loss_and_times_ok() {
    let Some(e) = engine() else { return };
    let m = &e.manifest;
    let train = e.load(&format!("mlp_train_mape_b{}.hlo.txt", m.train_batch)).unwrap();
    let mut theta = e.read_f32_blob("init_theta.bin").unwrap();
    let mut bn = e.read_f32_blob("init_bn.bin").unwrap();
    let mut mom = vec![0f32; m.theta_size];
    let mut vel = vec![0f32; m.theta_size];
    let b = m.train_batch;
    let f = m.feature_dim;
    // toy target: efficiency = sigmoid(x0)
    let x: Vec<f32> = (0..b * f)
        .map(|i| (((i * 2654435761usize) % 1000) as f32 / 500.0) - 1.0)
        .collect();
    let y: Vec<f32> = (0..b).map(|r| 1.0 / (1.0 + (-x[r * f]).exp())).collect();

    let mut first = f32::NAN;
    let mut last = f32::NAN;
    let t0 = std::time::Instant::now();
    let steps = 30;
    for step in 1..=steps {
        let out = train
            .run(&[
                lit_f32(&theta, &[theta.len() as i64]).unwrap(),
                lit_f32(&mom, &[mom.len() as i64]).unwrap(),
                lit_f32(&vel, &[vel.len() as i64]).unwrap(),
                lit_f32(&bn, &[bn.len() as i64]).unwrap(),
                lit_f32(&x, &[b as i64, f as i64]).unwrap(),
                lit_f32(&y, &[b as i64]).unwrap(),
                lit_scalar(step as f32),
                lit_key(step as u64 * 7919).unwrap(),
            ])
            .unwrap();
        theta = to_f32(&out[0]).unwrap();
        mom = to_f32(&out[1]).unwrap();
        vel = to_f32(&out[2]).unwrap();
        bn = to_f32(&out[3]).unwrap();
        let loss = to_f32(&out[4]).unwrap()[0];
        if step == 1 {
            first = loss;
        }
        last = loss;
    }
    let per_step = t0.elapsed().as_secs_f64() / steps as f64;
    eprintln!("train step: {:.2} ms, loss {first:.4} -> {last:.4}", per_step * 1e3);
    assert!(last < first, "loss should decrease: {first} -> {last}");
    assert!(per_step < 0.25, "train step too slow: {per_step}s");
}
