//! Concurrency acceptance tests for the throughput-scale back half: the
//! sharded engine cache and the two-pass deterministic-parallel evaluators.
//! N threads hammer the global engine and the stdio serve loop with mixed
//! predict/simulate traffic; the assertions are the contract — no
//! deadlock, responses strictly in input order, and every report
//! byte-identical to a single-threaded run.

use synperf::api::stdio::serve_lines;
use synperf::api::ModelBundle;
use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::e2e::workload::{Request, WorkloadKind};
use synperf::engine::PredictionEngine;
use synperf::hw::gpu_by_name;
use synperf::kernels::KernelConfig;
use synperf::scenario::{wire as scenario_wire, ScenarioSpec, Simulator, WorkloadSpec};

#[test]
fn concurrent_analyze_and_make_sample_stay_bit_identical() {
    // 8 threads × mixed analyze/make_sample over overlapping shapes on two
    // GPUs: every lookup lands on some shard, concurrent misses may race,
    // and none of it may change a single bit of any analysis
    let engine = PredictionEngine::global();
    let gpus = [gpu_by_name("A100").unwrap(), gpu_by_name("H800").unwrap()];
    // unique shapes (seq >= 9000) keep this test independent of other
    // tests sharing the global engine
    let shape =
        |i: u32| KernelConfig::RmsNorm { seq: 9000 + (i % 12), dim: 4096 + 64 * (i % 3) };
    std::thread::scope(|s| {
        for t in 0..8u32 {
            let gpus = &gpus;
            s.spawn(move || {
                for i in 0..48u32 {
                    let gpu = &gpus[((t + i) % 2) as usize];
                    let cfg = shape(i);
                    let a = PredictionEngine::global().analyze(&cfg, gpu);
                    assert!(a.theory_sec() > 0.0);
                    if i % 6 == 0 {
                        let sm =
                            PredictionEngine::global().make_sample(&cfg, gpu, u64::from(i));
                        assert!(sm.latency_sec > 0.0);
                    }
                }
            });
        }
    });
    // the hammered cache must answer exactly what a fresh single-shard
    // serial engine computes
    let serial = PredictionEngine::with_shards(256, 1);
    for i in 0..12u32 {
        let cfg = shape(i);
        for gpu in &gpus {
            let a = engine.analyze(&cfg, gpu);
            let b = serial.analyze(&cfg, gpu);
            assert_eq!(a.x, b.x, "shape {i} on {}: hammered analysis drifted", gpu.name);
            assert_eq!(a.theory_sec().to_bits(), b.theory_sec().to_bits());
        }
    }
    let stats = engine.stats();
    assert!(stats.hits + stats.misses > 0, "counters must account the hammering");
}

#[test]
fn service_answers_all_clients_under_contention() {
    // 8 client threads × blocking predicts through the bounded queue: no
    // deadlock, every request answered, every latency physical
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    std::thread::scope(|s| {
        for t in 0..8u32 {
            let client = svc.client();
            s.spawn(move || {
                let gpu = gpu_by_name("L40").unwrap();
                for i in 0..32u32 {
                    let cfg = KernelConfig::SiluMul { seq: 8000 + (i % 8), dim: 1024 + t };
                    let resp = client
                        .predict(synperf::api::PredictRequest::new(cfg, gpu.clone()))
                        .expect("service answers under contention");
                    assert!(resp.latency_sec > 0.0 && resp.latency_sec.is_finite());
                }
            });
        }
    });
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.requests, 8 * 32, "every request must be accounted");
    svc.shutdown();
}

#[test]
fn drain_under_load_answers_every_request_exactly_once() {
    // shutdown with K clients mid-flight: every submitted request gets
    // exactly one terminal outcome — an answer, queue_full, or shutdown —
    // and nothing hangs or vanishes
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::mpsc::channel;
    use std::time::Duration;
    let (gate_tx, gate_rx) = channel::<()>();
    let svc = PredictionService::spawn(
        move || {
            gate_rx.recv().ok(); // hold the service loop so the queue fills
            ModelBundle::default()
        },
        ServiceConfig {
            max_batch: 16,
            deadline: Duration::from_millis(1),
            queue_cap: 32,
            ..ServiceConfig::default()
        },
    );
    let (ok, full, shut) = (AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0));
    const CLIENTS: u64 = 6;
    const PER_CLIENT: u64 = 30;
    std::thread::scope(|s| {
        for t in 0..CLIENTS {
            let client = svc.client();
            let (ok, full, shut) = (&ok, &full, &shut);
            s.spawn(move || {
                let gpu = gpu_by_name("A100").unwrap();
                for i in 0..PER_CLIENT {
                    let cfg = KernelConfig::RmsNorm {
                        seq: 12000 + (i % 10) as u32,
                        dim: 1024 + t as u32,
                    };
                    let req = synperf::api::PredictRequest::new(cfg, gpu.clone());
                    match client.predict_deadline(req, Duration::from_millis(20)) {
                        Ok(resp) => {
                            assert!(resp.latency_sec > 0.0 && resp.latency_sec.is_finite());
                            ok.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(synperf::api::PredictError::QueueFull) => {
                            full.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(synperf::api::PredictError::Shutdown) => {
                            shut.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => panic!("unexpected terminal outcome: {e}"),
                    }
                }
            });
        }
        // let the clients pile up against the held queue, then open the
        // gate briefly, then drain while requests are still in flight
        std::thread::sleep(Duration::from_millis(30));
        gate_tx.send(()).unwrap();
        std::thread::sleep(Duration::from_millis(60));
        svc.shutdown(); // Client handles stay valid after the service drops
    });
    let (ok, full, shut) =
        (ok.load(Ordering::Relaxed), full.load(Ordering::Relaxed), shut.load(Ordering::Relaxed));
    assert_eq!(
        ok + full + shut,
        CLIENTS * PER_CLIENT,
        "every request needs exactly one outcome: {ok} ok + {full} full + {shut} shutdown"
    );
    assert!(ok > 0, "the opened gate must have answered some requests");
}

#[test]
fn stdio_mixed_verbs_stay_in_order_under_parallel_load() {
    // the serve loop runs a multi-threaded simulator while extra threads
    // hammer the same global engine: responses must arrive strictly in
    // input order, and every simulate report must be byte-identical to a
    // single-threaded evaluation of the same spec
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let sim_seed = |i: usize| 11 + (i % 2) as u64;
    let mut input = String::new();
    for i in 0..24usize {
        if i % 3 == 0 {
            input.push_str(&format!(
                "{{\"id\":\"l{i}\",\"op\":\"simulate\",\"scenario\":{{\"model\":\"llama3.1-8b\",\
                 \"gpu\":\"A100\",\"tp\":2,\"workload\":{{\"requests\":[[96,8],[64,4]]}},\
                 \"seed\":{}}}}}\n",
                sim_seed(i)
            ));
        } else {
            input.push_str(&format!(
                "{{\"id\":\"l{i}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                 \"seq\":{},\"dim\":2048}}}}\n",
                64 + i
            ));
        }
    }
    let mut out = Vec::new();
    let stats = std::thread::scope(|s| {
        let hammer: Vec<_> = (0..4u32)
            .map(|t| {
                s.spawn(move || {
                    let gpu = gpu_by_name("H20").unwrap();
                    for i in 0..64u32 {
                        let cfg =
                            KernelConfig::SiluMul { seq: 7000 + (i % 16), dim: 2048 + t };
                        assert!(
                            PredictionEngine::global().analyze(&cfg, &gpu).theory_sec() > 0.0
                        );
                    }
                })
            })
            .collect();
        let stats = serve_lines(
            &svc.client(),
            || Simulator::degraded().threads(7),
            input.as_bytes(),
            &mut out,
            8,
            7,
        )
        .unwrap();
        for h in hammer {
            h.join().unwrap();
        }
        stats
    });
    assert_eq!(stats.served, 24);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.simulated, 8);
    let text = String::from_utf8(out).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 24);
    for (i, line) in lines.iter().enumerate() {
        assert!(
            line.contains(&format!("\"id\":\"l{i}\"")),
            "response {i} out of order: {line}"
        );
    }
    // every simulate line == the 1-thread evaluation, byte for byte
    let sim1 = Simulator::degraded().threads(1);
    for (i, line) in lines.iter().enumerate() {
        if i % 3 != 0 {
            continue;
        }
        let spec = ScenarioSpec::new("llama3.1-8b", "A100")
            .tp(2)
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 96, output_len: 8 },
                Request { input_len: 64, output_len: 4 },
            ]))
            .seed(sim_seed(i));
        let id = format!("l{i}");
        let expect = scenario_wire::encode_report(Some(&id), &sim1.simulate(&spec));
        assert_eq!(*line, expect, "simulate line {i} must match the 1-thread run");
    }
    svc.shutdown();
}

#[test]
fn parallel_evaluator_is_byte_identical_across_thread_counts() {
    // sampled workload + collectives: the whole JSONL report (totals,
    // per-phase breakdowns, cache-hit provenance) must not move by a byte
    // between 1, 2 and 7 evaluation threads
    let spec = ScenarioSpec::new("qwen2.5-14b", "H800")
        .tp(2)
        .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Splitwise, batch: 6 })
        .seed(29);
    let sim = Simulator::degraded();
    let lines: Vec<String> = [1usize, 2, 7]
        .iter()
        .map(|&t| scenario_wire::encode_report(None, &sim.simulate_with_threads(&spec, t)))
        .collect();
    assert!(lines[0].contains("\"ok\":true"), "simulation must succeed: {}", lines[0]);
    assert_eq!(lines[0], lines[1], "2-thread report drifted from 1-thread");
    assert_eq!(lines[0], lines[2], "7-thread report drifted from 1-thread");
}

#[test]
fn cluster_simulation_is_byte_identical_across_thread_counts() {
    // Scenario v2's contract: the event loop is serial and threads only
    // fan out the per-step batched predictions, so the whole cluster
    // report — histograms, percentiles, SLO attainment, per-replica
    // accounting — must not move by a byte between 1, 2 and 7 threads,
    // even while other threads hammer the shared global engine cache
    use synperf::scenario::{ArrivalSpec, ClusterSpec, RoutePolicy};
    let spec = ClusterSpec::new("Llama3.1-8B", "A100")
        .replicas(2)
        .policy(RoutePolicy::LeastLoaded)
        .arrivals(ArrivalSpec::Poisson { rate_rps: 16.0, n: 12, kind: WorkloadKind::Arxiv })
        .max_batch(8)
        .kv_capacity_tokens(1 << 17)
        .seed(5);
    let sim = Simulator::degraded();
    let lines: Vec<String> = std::thread::scope(|s| {
        let hammer: Vec<_> = (0..4u32)
            .map(|t| {
                s.spawn(move || {
                    let gpu = gpu_by_name("A100").unwrap();
                    for i in 0..64u32 {
                        let cfg =
                            KernelConfig::RmsNorm { seq: 6000 + (i % 16), dim: 1024 + t };
                        assert!(
                            PredictionEngine::global().analyze(&cfg, &gpu).theory_sec() > 0.0
                        );
                    }
                })
            })
            .collect();
        let lines = [1usize, 2, 7]
            .iter()
            .map(|&t| {
                scenario_wire::encode_cluster_report(
                    None,
                    &sim.simulate_cluster_with_threads(&spec, t),
                )
            })
            .collect();
        for h in hammer {
            h.join().unwrap();
        }
        lines
    });
    assert!(lines[0].contains("\"ok\":true"), "simulation must succeed: {}", lines[0]);
    assert!(lines[0].contains("\"cluster\":true"));
    assert_eq!(lines[0], lines[1], "2-thread cluster report drifted from 1-thread");
    assert_eq!(lines[0], lines[2], "7-thread cluster report drifted from 1-thread");
}
