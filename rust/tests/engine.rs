//! Engine-equivalence tests: the shared `PredictionEngine`'s cached and
//! parallel paths must be *bit-identical* to the direct
//! decompose → schedule → featurize pipeline, for a mixed batch covering
//! all six kernel categories — and its cache behavior must be observable
//! through the coordinator metrics and engine stats (the acceptance
//! criterion for repeated launches in traces).

use std::collections::HashMap;
use std::sync::Arc;
use synperf::dataset::finalize_for_gpu;
use synperf::e2e::comm::CommModel;
use synperf::e2e::predict::{eval_trace, ModelSet, HOST_GAP_SEC};
use synperf::e2e::trace::{Op, TraceItem};
use synperf::engine::{par, PredictionEngine};
use synperf::features::FeatureSet;
use synperf::hw::{all_gpus, gpu_by_name, GpuSpec};
use synperf::kernels::{DType, KernelConfig, MoeConfig};
use synperf::sched::schedule;

/// One fixed config per kernel category (all six), GPU-independent.
fn mixed_configs() -> Vec<KernelConfig> {
    vec![
        KernelConfig::Gemm { m: 4096, n: 11008, k: 4096, dtype: DType::Bf16 },
        KernelConfig::ScaledMm { m: 1024, n: 4096, k: 2048 },
        KernelConfig::Attention {
            batch: vec![(1024, 1024), (64, 2048)],
            nh: 16,
            nkv: 4,
            hd: 128,
            causal: true,
            fa3: false,
        },
        KernelConfig::RmsNorm { seq: 2048, dim: 8192 },
        KernelConfig::SiluMul { seq: 1024, dim: 13824 },
        KernelConfig::FusedMoe {
            m: 512,
            e: 8,
            topk: 2,
            h: 2048,
            n: 1024,
            expert_tokens: vec![128, 0, 64, 257, 300, 1, 100, 174],
            cfg: MoeConfig { block_m: 64, block_n: 64, block_k: 64, num_stages: 3, num_warps: 4 },
        },
    ]
}

fn direct_input(cfg: &KernelConfig, gpu: &GpuSpec) -> ([f32; 32], f64) {
    let cfg = finalize_for_gpu(cfg, gpu);
    let d = cfg.decompose(gpu);
    let dist = schedule(&d, gpu);
    let f = FeatureSet::analyze(&d, &dist, gpu);
    (f.to_model_input(gpu), f.theory_sec)
}

#[test]
fn cached_path_bit_identical_to_direct_path_all_kinds() {
    let engine = PredictionEngine::new(256);
    for gpu_name in ["A100", "H800"] {
        let gpu = gpu_by_name(gpu_name).unwrap();
        for cfg in mixed_configs() {
            let (x_direct, theory_direct) = direct_input(&cfg, &gpu);
            let cold = engine.analyze(&cfg, &gpu);
            let warm = engine.analyze(&cfg, &gpu);
            for a in [&cold, &warm] {
                assert_eq!(a.x, x_direct, "{gpu_name} {:?}: feature vector drifted", cfg.kind());
                assert_eq!(
                    a.theory_sec().to_bits(),
                    theory_direct.to_bits(),
                    "{gpu_name} {:?}: theory_sec drifted",
                    cfg.kind()
                );
            }
            assert!(Arc::ptr_eq(&cold, &warm), "second lookup must be the cached Arc");
        }
    }
    let stats = engine.stats();
    assert_eq!(stats.misses, 12, "6 kinds x 2 GPUs should each miss once");
    assert_eq!(stats.hits, 12, "every repeat must hit");
}

#[test]
fn parallel_batch_matches_serial_batch() {
    let engine = PredictionEngine::new(256);
    let mut reqs: Vec<(KernelConfig, GpuSpec)> = Vec::new();
    for gpu_name in ["A100", "H20", "L20"] {
        let gpu = gpu_by_name(gpu_name).unwrap();
        for cfg in mixed_configs() {
            reqs.push((cfg, gpu.clone()));
        }
    }
    // duplicate the whole batch: half the parallel lookups must hit
    let doubled: Vec<_> = reqs.iter().chain(reqs.iter()).cloned().collect();
    let parallel = engine.analyze_batch(&doubled, 8);
    let serial_engine = PredictionEngine::new(256);
    for (i, (cfg, gpu)) in doubled.iter().enumerate() {
        let s = serial_engine.analyze(cfg, gpu);
        assert_eq!(parallel[i].x, s.x, "row {i}: parallel != serial");
        assert_eq!(parallel[i].x_alt, s.x_alt, "row {i}: alt features diverged");
        assert_eq!(parallel[i].theory_sec().to_bits(), s.theory_sec().to_bits());
    }
    // Concurrent workers may race on a duplicated key (both miss, both
    // compute — correctness is unaffected since the value is pure), so only
    // the totals are exact: every unique key misses at least once and no
    // lookup is lost.
    let stats = engine.stats();
    assert_eq!(stats.hits + stats.misses, doubled.len() as u64);
    assert!(stats.misses >= reqs.len() as u64);
}

#[test]
fn predict_batch_matches_direct_roofline_in_degraded_mode() {
    // with no trained models, batched protocol-v1 predictions are exactly
    // the theoretical roofs computed by the direct path — and say so in
    // their provenance
    let gpu = gpu_by_name("H800").unwrap();
    let raw = synperf::api::predict_batch_view(
        &HashMap::new(),
        synperf::api::FeatureView::SynPerf,
        &mixed_configs().into_iter().map(|c| (c, gpu.clone())).collect::<Vec<_>>(),
    );
    assert_eq!(raw.len(), 6);
    for (p, cfg) in raw.iter().zip(mixed_configs()) {
        let (_, theory) = direct_input(&cfg, &gpu);
        assert_eq!(
            p.latency_sec.to_bits(),
            theory.to_bits(),
            "{:?}: degraded prediction must equal the direct roof",
            cfg.kind()
        );
        assert_eq!(p.provenance.source, synperf::api::Source::Roofline);
    }

    // the typed batch front door agrees with the raw routing path
    let reqs: Vec<synperf::api::PredictRequest> = mixed_configs()
        .into_iter()
        .map(|c| synperf::api::PredictRequest::new(c, gpu.clone()))
        .collect();
    let report = synperf::api::predict_batch(&synperf::api::ModelBundle::default(), &reqs);
    assert_eq!(report.kind_groups, 6);
    for (res, p) in report.results.iter().zip(&raw) {
        let resp = res.as_ref().expect("valid requests succeed");
        assert_eq!(resp.latency_sec.to_bits(), p.latency_sec.to_bits());
    }
}

#[test]
fn occupancy_never_zero_for_any_kind_on_any_gpu() {
    for gpu in all_gpus() {
        for cfg in mixed_configs() {
            let cfg = finalize_for_gpu(&cfg, &gpu);
            let d = cfg.decompose(&gpu);
            assert!(
                d.cta.occupancy(&gpu) >= 1,
                "{} {:?}: occupancy returned 0",
                gpu.name,
                cfg.kind()
            );
        }
    }
}

#[test]
fn par_map_is_deterministic_across_thread_counts() {
    let items: Vec<u32> = (0..500).collect();
    let gpu = gpu_by_name("A40").unwrap();
    let engine = PredictionEngine::new(1024);
    let f = |_: usize, seq: &u32| {
        engine.analyze(&KernelConfig::RmsNorm { seq: seq + 1, dim: 1024 }, &gpu).theory_sec()
    };
    let one = par::par_map(&items, 1, f);
    let many = par::par_map(&items, 8, f);
    assert_eq!(one.len(), many.len());
    for (a, b) in one.iter().zip(&many) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn repeated_trace_launches_hit_the_decomposition_cache() {
    // The acceptance check: an inference trace repeats identical kernel
    // launches (layers x steps); eval_trace routes through the shared
    // engine, so the repeats must show up as cache hits in the engine
    // stats. Unique shapes keep this test independent of other tests
    // sharing the global engine.
    let gpu = gpu_by_name("L20").unwrap();
    let kernel = KernelConfig::RmsNorm { seq: 3511, dim: 5279 };
    let trace: Vec<TraceItem> = (0..12)
        .map(|_| TraceItem { op: Op::Kernel(kernel.clone()), count: 2.0 })
        .collect();
    let models = ModelSet {
        synperf: HashMap::new(),
        neusight: HashMap::new(),
        linear: HashMap::new(),
    };
    let comm = CommModel::train(&gpu, 3);

    let engine = PredictionEngine::global();
    let before = engine.stats();
    let totals = eval_trace(&trace, &gpu, 1, &models, &comm, 99, HOST_GAP_SEC, 1).unwrap();
    let after = engine.stats();

    assert!(totals.actual > 0.0 && totals.synperf > 0.0);
    // 12 identical launches: at most one miss belongs to this config, so at
    // least 11 of the lookups must have hit the cache
    assert!(
        after.hits - before.hits >= 11,
        "repeated launches must hit: {} -> {} hits",
        before.hits,
        after.hits
    );
}

#[test]
fn service_and_dataset_share_the_global_engine() {
    use synperf::api::{ModelBundle, PredictRequest};
    use synperf::coordinator::{PredictionService, ServiceConfig};
    // a unique shape first analyzed via dataset::make_sample must already
    // be cached when the service sees it
    let gpu = gpu_by_name("RTX A6000").unwrap();
    let cfg = KernelConfig::SiluMul { seq: 2731, dim: 6007 };
    let _ = synperf::dataset::make_sample(&cfg, &gpu, 5);

    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let resp = svc.predict(PredictRequest::new(cfg, gpu)).unwrap();
    assert!(resp.latency_sec > 0.0);
    assert!(resp.provenance.cache_hit, "service must reuse the dataset-built analysis");
    let snap = svc.metrics.snapshot();
    assert_eq!(snap.cache_hits, 1, "service must reuse the dataset-built analysis");
    svc.shutdown();
}
