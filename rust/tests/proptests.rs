//! Property-based tests (hand-rolled harness; `proptest` is not in the
//! offline vendor set) over the coordinator invariants the paper's pipeline
//! rests on: scheduling produces exact partitions, feature aggregation is
//! conservative, the oracle is deterministic and physical, routing/batching
//! lose no requests.

use synperf::dataset::{finalize_for_gpu, sample_config};
use synperf::features::FeatureSet;
use synperf::hw::{all_gpus, GpuSpec};
use synperf::kernels::{KernelConfig, KernelKind};
use synperf::oracle;
use synperf::sched::{schedule, TaskDistribution};
use synperf::util::prop_check;
use synperf::util::rng::Rng;

fn random_kind(r: &mut Rng) -> KernelKind {
    *r.choose(&KernelKind::ALL)
}

fn random_gpu(r: &mut Rng) -> GpuSpec {
    let gpus = all_gpus();
    gpus[r.range_usize(0, gpus.len() - 1)].clone()
}

fn random_case(r: &mut Rng) -> (KernelConfig, GpuSpec) {
    let gpu = random_gpu(r);
    let cfg = finalize_for_gpu(&sample_config(random_kind(r), r), &gpu);
    (cfg, gpu)
}

#[test]
fn schedule_is_exact_partition() {
    prop_check("schedule_is_exact_partition", 60, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        assert_partition(&dist, &d, gpu.num_sms as usize);
    });
}

/// Every group's tasks are fully distributed (no loss, no duplication) and
/// per-SM totals reconstruct the task count — the grouped equivalent of the
/// old index-vector partition check.
fn assert_partition(dist: &TaskDistribution, d: &synperf::kernels::Decomposition, n_sms: usize) {
    assert_eq!(dist.num_sms(), n_sms);
    assert_eq!(dist.num_tasks(), d.num_tasks());
    assert_eq!(dist.num_groups(), d.num_groups());
    for (g, grp) in d.task_groups.iter().enumerate() {
        let spread: u64 = (0..n_sms).map(|j| dist.group_count_on_sm(g, j)).sum();
        assert_eq!(spread, grp.count, "group {g} tasks lost or duplicated");
    }
    let per_sm: u64 = (0..n_sms).map(|j| dist.tasks_on_sm(j)).sum();
    assert_eq!(per_sm, d.num_tasks() as u64);
}

#[test]
fn feature_totals_conserve_task_demands() {
    prop_check("feature_totals_conserve", 40, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let tensor: f64 = d.iter_tasks().map(|t| t.tensor_ops).sum();
        let fma: f64 = d.iter_tasks().map(|t| t.fma_ops).sum();
        let loads: f64 = d.iter_tasks().map(|t| t.bytes_load).sum();
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-6 * a.abs().max(b.abs()).max(1.0);
        assert!(close(f.tensor.total_ops, tensor));
        assert!(close(f.fma.total_ops, fma));
        assert!(close(f.mio.total_bytes, loads));
        // max-SM values bounded by totals, and >= total / SMs
        assert!(f.tensor.max_sm_ops <= f.tensor.total_ops + 1e-9);
        if tensor > 0.0 {
            assert!(f.tensor.max_sm_ops * gpu.num_sms as f64 >= tensor * 0.999);
        }
    });
}

#[test]
fn theory_is_a_lower_bound_and_naive_is_above_it() {
    prop_check("theory_lower_bound", 40, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        assert!(f.theory_sec > 0.0 && f.theory_sec.is_finite());
        assert!(f.naive_roofline_sec >= f.theory_sec * 0.999);
        // oracle latency must never beat the theoretical roof
        let o = oracle::measure(&cfg, &gpu, 1234);
        assert!(
            o.clean_sec > f.theory_sec,
            "{}: oracle {} beat theory {}",
            gpu.name,
            o.clean_sec,
            f.theory_sec
        );
    });
}

#[test]
fn oracle_deterministic_and_noise_bounded() {
    prop_check("oracle_determinism", 30, |r| {
        let (cfg, gpu) = random_case(r);
        let seed = r.next_u64();
        let a = oracle::measure(&cfg, &gpu, seed);
        let b = oracle::measure(&cfg, &gpu, seed);
        assert_eq!(a.latency_sec.to_bits(), b.latency_sec.to_bits());
        // measurement noise within +-12% of the clean value
        let ratio = a.latency_sec / a.clean_sec;
        assert!((0.88..1.12).contains(&ratio), "noise ratio {ratio}");
        // counters conserve totals
        let d = cfg.decompose(&gpu);
        let tensor: f64 = d.iter_tasks().map(|t| t.tensor_ops).sum();
        assert!((a.total_tensor_ops - tensor).abs() <= 1e-6 * tensor.max(1.0));
    });
}

#[test]
fn model_inputs_always_finite() {
    prop_check("model_inputs_finite", 60, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let x = f.to_model_input(&gpu);
        assert!(x.iter().all(|v| v.is_finite()), "{x:?}");
        let (xa, alt_th) = synperf::baselines::neusight::features(&d, &gpu);
        assert!(xa.iter().all(|v| v.is_finite()));
        assert!(alt_th > 0.0 && alt_th.is_finite());
    });
}

#[test]
fn service_loses_no_requests_under_load() {
    use synperf::api::{ModelBundle, PredictRequest};
    use synperf::coordinator::{PredictionService, ServiceConfig};
    prop_check("service_conservation", 5, |r| {
        let svc = PredictionService::spawn(
            ModelBundle::default,
            ServiceConfig { max_batch: r.range_usize(1, 64), ..Default::default() },
        );
        let client = svc.client();
        let n = r.range_usize(10, 120);
        let gpu = random_gpu(r);
        let pendings: Vec<_> = (0..n)
            .map(|i| {
                client
                    .submit(PredictRequest::new(
                        KernelConfig::RmsNorm { seq: 16 + i as u32, dim: 1024 },
                        gpu.clone(),
                    ))
                    .expect("queue accepts under its capacity")
            })
            .collect();
        let mut got = 0;
        for p in pendings {
            let resp = p.wait().expect("every request answered");
            assert!(resp.latency_sec > 0.0 && resp.latency_sec.is_finite());
            got += 1;
        }
        assert_eq!(got, n);
        // metrics are recorded after responses are sent; wait for the
        // service thread to settle before asserting conservation
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
        loop {
            let snap = svc.metrics.snapshot();
            if snap.requests == n as u64 {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "metrics must account every request: {} != {n}",
                snap.requests
            );
            std::thread::yield_now();
        }
        svc.shutdown();
    });
}

#[test]
fn occupancy_never_zero() {
    // CtaResources::occupancy must stay >= 1 for every sampled launch on
    // every GPU — a zero would poison waves/occupancy features and the
    // persistent/minheap worker counts.
    prop_check("occupancy_never_zero", 60, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        assert!(d.cta.occupancy(&gpu) >= 1, "{}: occupancy 0", gpu.name);
        // and stays >= 1 even under absurd resource demands
        let monster = synperf::kernels::CtaResources {
            warps: 1024,
            smem_bytes: u32::MAX,
            regs_per_thread: 255,
        };
        assert!(monster.occupancy(&gpu) >= 1);
    });
}

#[test]
fn minheap_sm_cost_bounded_by_round_robin() {
    // The FA3 MinHeap scheduler against cyclic round-robin on the *same*
    // causal-attention task set: arrival-order greedy can exceed RR by a
    // sliver on adversarial task orders (observed worst case +2.9% over
    // these deterministic seeds), so the bound carries 5% headroom, plus
    // the classical list-scheduling guarantee mean + max.
    use synperf::sched::{hardware_rr, minheap};
    prop_check("minheap_vs_rr_sched", 40, |r| {
        let gpu = synperf::hw::gpu_by_name(r.choose(&["H100", "H800", "H20"])).unwrap();
        let bs = r.range_u32(1, 8);
        let nkv = *r.choose(&[1u32, 2, 4]);
        let nh = nkv * *r.choose(&[1u32, 2, 4, 8]);
        let hd = *r.choose(&[64u32, 128]);
        let batch: Vec<(u32, u32)> = (0..bs)
            .map(|_| {
                let q = r.log_range_u32(1, 8192);
                let hist = r.log_range_u32(1, 8192) - 1;
                (q, q + hist)
            })
            .collect();
        let cfg = KernelConfig::Attention { batch, nh, nkv, hd, causal: true, fa3: true };
        let d = cfg.decompose(&gpu);
        let mh = minheap::schedule(&d, &gpu);
        let rr = hardware_rr::schedule(&d, &gpu);
        assert_partition(&mh, &d, gpu.num_sms as usize);
        assert_partition(&rr, &d, gpu.num_sms as usize);
        let mh_max = mh.max_sm_sum(|g| d.task_groups[g].template.cost_hint);
        let rr_max = rr.max_sm_sum(|g| d.task_groups[g].template.cost_hint);
        assert!(
            mh_max <= rr_max * 1.05 + 1e-9,
            "minheap max-SM cost {mh_max} far above RR {rr_max}"
        );
        let total: f64 = d.iter_tasks().map(|t| t.cost_hint).sum();
        let max_cost = d.iter_tasks().map(|t| t.cost_hint).fold(0.0, f64::max);
        let workers = (gpu.num_sms * d.cta.occupancy(&gpu)) as f64;
        assert!(
            mh_max <= total / workers + max_cost + 1e-6,
            "greedy bound violated: {mh_max}"
        );
        // and no schedule can beat the mean load
        assert!(mh_max * workers >= total * 0.999);
    });
}

#[test]
fn minheap_strictly_beats_round_robin_on_skewed_causal_batch() {
    // Deterministic skewed case (verified offline): four causal 2048-token
    // requests on the H20's 78 SMs — the MinHeap balancer must strictly
    // win on max-SM cost.
    use synperf::sched::{hardware_rr, minheap};
    let gpu = synperf::hw::gpu_by_name("H20").unwrap();
    let cfg = KernelConfig::Attention {
        batch: vec![(2048, 2048); 4],
        nh: 8,
        nkv: 2,
        hd: 128,
        causal: true,
        fa3: true,
    };
    let d = cfg.decompose(&gpu);
    let mh_max = minheap::schedule(&d, &gpu).max_sm_sum(|g| d.task_groups[g].template.cost_hint);
    let rr_max =
        hardware_rr::schedule(&d, &gpu).max_sm_sum(|g| d.task_groups[g].template.cost_hint);
    assert!(
        mh_max < rr_max,
        "minheap {mh_max} should strictly beat RR {rr_max} on skewed causal work"
    );
}

#[test]
fn minheap_never_worse_than_round_robin() {
    prop_check("minheap_vs_rr", 40, |r| {
        let n = r.range_usize(8, 400);
        let workers = r.range_usize(2, 64);
        let costs: Vec<f64> = (0..n).map(|_| r.range_f64(0.1, 100.0)).collect();
        let bins = synperf::sched::minheap::balance(&costs, workers);
        let mh_max: f64 = bins
            .iter()
            .map(|b| b.iter().map(|&i| costs[i]).sum::<f64>())
            .fold(0.0, f64::max);
        let rr_max: f64 = (0..workers)
            .map(|w| costs.iter().skip(w).step_by(workers).sum())
            .fold(0.0, f64::max);
        // greedy (arrival-order) list scheduling: classical bound of
        // mean + max; and it should rarely be much worse than RR
        let total: f64 = costs.iter().sum();
        let max_cost = costs.iter().cloned().fold(0.0, f64::max);
        assert!(
            mh_max <= total / workers as f64 + max_cost + 1e-9,
            "greedy bound violated: {mh_max}"
        );
        assert!(mh_max <= rr_max * 1.5 + max_cost, "minheap {mh_max} vs RR {rr_max}");
        // and never below the theoretical optimum (mean load)
        assert!(mh_max * workers as f64 >= total * 0.999);
    });
}

/// Reference implementation of the pre-grouping pipeline: expanded task
/// vectors, per-SM index lists, element-wise feature aggregation. The
/// grouped closed forms must reproduce it bit-for-bit (every per-task
/// demand is an exactly representable integer-valued f64, so replacing
/// repeated addition with count·value is exact).
mod reference {
    use synperf::features::{FeatureSet, MioAgg, PipeAgg};
    use synperf::hw::GpuSpec;
    use synperf::kernels::{Decomposition, Paradigm, Task};
    use synperf::sched::minheap;

    pub struct IndexDist {
        pub assignment: Vec<Vec<usize>>,
    }

    pub fn schedule(d: &Decomposition, tasks: &[Task], gpu: &GpuSpec) -> IndexDist {
        let nsm = gpu.num_sms as usize;
        let mut assignment = vec![Vec::new(); nsm];
        match d.paradigm {
            Paradigm::HardwareRR => {
                for i in 0..tasks.len() {
                    assignment[i % nsm].push(i);
                }
            }
            Paradigm::PersistentTile => {
                let workers = nsm * d.cta.occupancy(gpu) as usize;
                for i in 0..tasks.len() {
                    assignment[(i % workers) % nsm].push(i);
                }
            }
            Paradigm::MinHeap => {
                let workers = nsm * d.cta.occupancy(gpu).max(1) as usize;
                let costs: Vec<f64> = tasks.iter().map(|t| t.cost_hint).collect();
                for (w, bin) in minheap::balance(&costs, workers).into_iter().enumerate() {
                    assignment[w % nsm].extend(bin);
                }
            }
        }
        IndexDist { assignment }
    }

    pub fn analyze(
        decomp: &Decomposition,
        t: &[Task],
        dist: &IndexDist,
        gpu: &GpuSpec,
    ) -> FeatureSet {
        let nsm = gpu.num_sms as f64;
        let sm_sums = |metric: &dyn Fn(&Task) -> f64| -> Vec<f64> {
            dist.assignment
                .iter()
                .map(|tasks| tasks.iter().map(|&i| metric(&t[i])).sum::<f64>())
                .collect()
        };
        let pipe_agg = |metric: &dyn Fn(&Task) -> f64, throughput_per_sm: f64| -> PipeAgg {
            let sums = sm_sums(metric);
            let total_ops: f64 = sums.iter().sum();
            let max_sm_ops = sums.iter().cloned().fold(0.0, f64::max);
            PipeAgg {
                total_ops,
                total_cycles: total_ops / (nsm * throughput_per_sm),
                max_sm_ops,
                max_sm_cycles: max_sm_ops / throughput_per_sm,
            }
        };
        let tensor = pipe_agg(&|t| t.tensor_ops, gpu.tensor_ops_clk_sm);
        let fma = pipe_agg(&|t| t.fma_ops, gpu.fma_ops_clk_sm);
        let xu = pipe_agg(&|t| t.xu_ops, gpu.xu_ops_clk_sm);

        let byte_sums = sm_sums(&|t| t.bytes_load);
        let total_bytes: f64 = byte_sums.iter().sum();
        let max_sm_bytes = byte_sums.iter().cloned().fold(0.0, f64::max);
        let smem_sums = sm_sums(&|t| t.bytes_smem);
        let max_sm_smem = smem_sums.iter().cloned().fold(0.0, f64::max);

        let dram_bpc = gpu.dram_bytes_per_cycle();
        let l2_bpc = gpu.l2_bytes_per_cycle();
        let mio = MioAgg {
            total_bytes,
            cycles_dram: total_bytes / dram_bpc,
            cycles_l2: total_bytes / l2_bpc,
            max_sm_bytes,
            max_sm_cycles_dram: max_sm_bytes / (dram_bpc / nsm),
            max_sm_cycles_l2: max_sm_bytes / (l2_bpc / nsm),
            max_sm_cycles_smem: max_sm_smem / gpu.smem_bw_byte_clk_sm,
        };

        let crit: Vec<f64> = dist
            .assignment
            .iter()
            .map(|tasks| {
                let ops_t: f64 = tasks.iter().map(|&i| t[i].tensor_ops).sum();
                let ops_f: f64 = tasks.iter().map(|&i| t[i].fma_ops).sum();
                let ops_x: f64 = tasks.iter().map(|&i| t[i].xu_ops).sum();
                let by: f64 = tasks.iter().map(|&i| t[i].bytes_load).sum();
                (ops_t / gpu.tensor_ops_clk_sm)
                    .max(ops_f / gpu.fma_ops_clk_sm)
                    .max(ops_x / gpu.xu_ops_clk_sm)
                    .max(by / (dram_bpc / nsm))
            })
            .collect();
        let max_crit = crit.iter().cloned().fold(0.0, f64::max);
        let busy: Vec<&f64> = crit.iter().filter(|c| **c > 0.0).collect();
        let mean_crit = if busy.is_empty() {
            0.0
        } else {
            busy.iter().cloned().sum::<f64>() / busy.len() as f64
        };

        let occupancy = decomp.cta.occupancy(gpu) as f64;
        let num_tasks = t.len() as f64;
        let max_tasks = dist.assignment.iter().map(|v| v.len()).max().unwrap_or(0) as f64;

        let total_stores: f64 = t.iter().map(|t| t.bytes_store).sum();
        let compute_roof = tensor.total_cycles.max(fma.total_cycles).max(xu.total_cycles);
        let theory_cycles = compute_roof.max(decomp.min_dram_bytes / dram_bpc);
        let naive_cycles = compute_roof.max((total_bytes + total_stores) / dram_bpc);

        FeatureSet {
            tensor,
            fma,
            xu,
            mio,
            num_tasks,
            max_tasks_per_sm: max_tasks,
            imbalance: if mean_crit > 0.0 { max_crit / mean_crit } else { 1.0 },
            occupancy,
            waves: num_tasks / (nsm * occupancy),
            theory_sec: theory_cycles * gpu.cycle_sec(),
            naive_roofline_sec: naive_cycles * gpu.cycle_sec(),
        }
    }
}

fn assert_pipe_bits(a: &synperf::features::PipeAgg, b: &synperf::features::PipeAgg, what: &str) {
    for (x, y, f) in [
        (a.total_ops, b.total_ops, "total_ops"),
        (a.total_cycles, b.total_cycles, "total_cycles"),
        (a.max_sm_ops, b.max_sm_ops, "max_sm_ops"),
        (a.max_sm_cycles, b.max_sm_cycles, "max_sm_cycles"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}.{f}: grouped {x:?} vs reference {y:?}");
    }
}

#[test]
fn grouped_expansion_matches_group_sums() {
    // iter_tasks() expansion must reconstruct the closed-form group
    // aggregates exactly, for every kernel kind on every GPU
    prop_check("grouped_expansion_matches_group_sums", 60, |r| {
        let (cfg, gpu) = random_case(r);
        let d = cfg.decompose(&gpu);
        assert_eq!(d.iter_tasks().count(), d.num_tasks());
        let total: usize = d.task_groups.iter().map(|g| g.count as usize).sum();
        assert_eq!(total, d.num_tasks());
        let tensor: f64 = d.iter_tasks().map(|t| t.tensor_ops).sum();
        let bytes: f64 = d.iter_tasks().map(|t| t.total_bytes()).sum();
        assert_eq!(tensor.to_bits(), d.total_tensor_ops().to_bits());
        assert_eq!(bytes.to_bits(), d.total_bytes().to_bits());
        // runs are maximal: adjacent groups always differ
        for w in d.task_groups.windows(2) {
            assert_ne!(w[0].template, w[1].template, "adjacent equal runs not merged");
        }
    });
}

#[test]
fn grouped_pipeline_bit_identical_to_expanded_reference() {
    // the tentpole invariant: grouped schedule + analyze == the pre-grouping
    // index-vector pipeline over materialized tasks, bit for bit, for all
    // six kernel kinds across A100 (FA2/HardwareRR) and H800 (FA3/minheap +
    // persistent tile paths)
    prop_check("grouped_pipeline_bit_identical", 48, |r| {
        let gpu = synperf::hw::gpu_by_name(*r.choose(&["A100", "H800"])).unwrap();
        let kind = *r.choose(&KernelKind::ALL);
        let cfg = finalize_for_gpu(&sample_config(kind, r), &gpu);
        let d = cfg.decompose(&gpu);
        let tasks: Vec<synperf::kernels::Task> = d.iter_tasks().cloned().collect();

        let ref_dist = reference::schedule(&d, &tasks, &gpu);
        let dist = schedule(&d, &gpu);
        // per-(SM, group) counts agree with the index walk
        let mut task_group = Vec::with_capacity(tasks.len());
        for (g, grp) in d.task_groups.iter().enumerate() {
            task_group.extend(std::iter::repeat_n(g, grp.count as usize));
        }
        for (j, sm) in ref_dist.assignment.iter().enumerate() {
            let mut want = vec![0u64; d.num_groups()];
            for &i in sm {
                want[task_group[i]] += 1;
            }
            assert_eq!(dist.tasks_on_sm(j), sm.len() as u64, "{kind:?} sm {j} count");
            for (g, &w) in want.iter().enumerate() {
                assert_eq!(dist.group_count_on_sm(g, j), w, "{kind:?} sm {j} group {g}");
            }
        }

        let f = FeatureSet::analyze(&d, &dist, &gpu);
        let fr = reference::analyze(&d, &tasks, &ref_dist, &gpu);
        assert_pipe_bits(&f.tensor, &fr.tensor, "tensor");
        assert_pipe_bits(&f.fma, &fr.fma, "fma");
        assert_pipe_bits(&f.xu, &fr.xu, "xu");
        for (x, y, what) in [
            (f.mio.total_bytes, fr.mio.total_bytes, "mio.total_bytes"),
            (f.mio.cycles_dram, fr.mio.cycles_dram, "mio.cycles_dram"),
            (f.mio.cycles_l2, fr.mio.cycles_l2, "mio.cycles_l2"),
            (f.mio.max_sm_bytes, fr.mio.max_sm_bytes, "mio.max_sm_bytes"),
            (f.mio.max_sm_cycles_dram, fr.mio.max_sm_cycles_dram, "mio.max_sm_cycles_dram"),
            (f.mio.max_sm_cycles_l2, fr.mio.max_sm_cycles_l2, "mio.max_sm_cycles_l2"),
            (f.mio.max_sm_cycles_smem, fr.mio.max_sm_cycles_smem, "mio.max_sm_cycles_smem"),
            (f.num_tasks, fr.num_tasks, "num_tasks"),
            (f.max_tasks_per_sm, fr.max_tasks_per_sm, "max_tasks_per_sm"),
            (f.imbalance, fr.imbalance, "imbalance"),
            (f.occupancy, fr.occupancy, "occupancy"),
            (f.waves, fr.waves, "waves"),
            (f.theory_sec, fr.theory_sec, "theory_sec"),
            (f.naive_roofline_sec, fr.naive_roofline_sec, "naive_roofline_sec"),
        ] {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{kind:?} on {}: {what}: grouped {x:?} vs reference {y:?}",
                gpu.name
            );
        }
    });
}

#[test]
fn routing_conserves_tokens_and_grid_covers() {
    use synperf::kernels::fused_moe;
    prop_check("moe_routing", 50, |r| {
        let m = r.range_u32(2, 8192);
        let e = r.range_u32(8, 128);
        let topk = r.range_u32(2, 8);
        let counts = fused_moe::route_tokens(m, e, topk, r);
        assert_eq!(counts.iter().sum::<u32>(), m * topk);
        let gpu = random_gpu(r);
        let cfg = fused_moe::default_config(m, &gpu);
        let d = fused_moe::decompose(2048, 1024, &counts, cfg, &gpu);
        // every routed token is covered by a tile row
        let covered: u64 = counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| (c.div_ceil(cfg.block_m) * cfg.block_m) as u64)
            .sum();
        assert!(covered >= (m * topk) as u64);
        assert!(d.num_tasks() > 0);
    });
}

// ---- Scenario API v1 -----------------------------------------------------

use synperf::e2e::comm::CommModel;
use synperf::e2e::llm;
use synperf::e2e::predict::{eval_trace, Method, ModelSet};
use synperf::e2e::trace;
use synperf::e2e::workload::Request;
use synperf::hw::gpu_by_name;
use synperf::scenario::{compile, ScenarioSpec, Simulator, WorkloadSpec};

/// Kernel launches are a property of the workload, not of how the model is
/// sharded: compiled traces must conserve `launch_count` across tp/pp
/// splits (collectives are comm ops, not kernel launches).
#[test]
fn compiled_scenarios_conserve_launch_count_across_parallelism() {
    prop_check("scenario_launch_conservation", 20, |r| {
        let registry = llm::registry();
        let cfg = &registry[r.range_usize(0, registry.len() - 1)];
        let n = r.range_usize(1, 4);
        let reqs: Vec<Request> = (0..n)
            .map(|_| Request {
                input_len: r.range_usize(16, 512) as u32,
                output_len: r.range_usize(1, 64) as u32,
            })
            .collect();
        let spec_for = |tp: u32, pp: u32| {
            ScenarioSpec::new(cfg.name, "A100")
                .tp(tp)
                .pp(pp)
                .workload(WorkloadSpec::Explicit(reqs.clone()))
                .seed(9)
        };
        let base = compile(&spec_for(1, 1)).unwrap();
        let base_lc = base.launch_count();
        assert!(base_lc > 0.0);
        for (tp, pp) in [(2u32, 1u32), (4, 1), (8, 1), (2, 2), (1, 2)] {
            if cfg.heads % tp != 0 || pp > cfg.layers {
                continue;
            }
            let c = compile(&spec_for(tp, pp)).unwrap();
            assert_eq!(
                c.launch_count().to_bits(),
                base_lc.to_bits(),
                "{} tp={tp} pp={pp}: kernel launches must be conserved",
                cfg.name
            );
            assert_eq!(c.requests, base.requests, "explicit mixes are sharding-invariant");
        }
    });
}

/// The declarative path must not change a single bit of the answer — at
/// any thread count. For every registered LLM config on A100 and H800
/// (the two testbed GPUs of the paper's Table VI splits), the sharded
/// cache + parallel two-pass evaluator at `threads ∈ {1, 2, 7}` is pinned
/// against the serial hand-built `build_trace` + `eval_trace` reference,
/// and the encoded JSONL report lines must be byte-identical across
/// thread counts.
#[test]
fn scenario_reports_match_the_handbuilt_trace_reference() {
    let reqs = vec![
        Request { input_len: 160, output_len: 24 },
        Request { input_len: 96, output_len: 12 },
    ];
    let sim = Simulator::degraded();
    let (tp, pp) = (2u32, 2u32);
    for gpu_name in ["A100", "H800"] {
        let gpu = gpu_by_name(gpu_name).unwrap();
        // same comm seed as the simulator's cache: identical RF models
        let comm = CommModel::train(&gpu, Simulator::DEFAULT_COMM_SEED);
        for cfg in llm::registry() {
            let spec = ScenarioSpec::new(cfg.name, gpu_name)
                .tp(tp)
                .pp(pp)
                .workload(WorkloadSpec::Explicit(reqs.clone()))
                .seed(1234)
                .host_gap_sec(1.1e-6);
            let tr = trace::build_trace(cfg, tp, pp, &reqs);
            let reference =
                eval_trace(&tr, &gpu, tp, &ModelSet::default(), &comm, 1234, 1.1e-6, 1)
                    .unwrap();
            let mut lines: Vec<String> = Vec::new();
            for threads in [1usize, 2, 7] {
                let report = sim.simulate_with_threads(&spec, threads).unwrap();
                for m in Method::ALL {
                    assert_eq!(
                        report.totals.get(m).to_bits(),
                        reference.get(m).to_bits(),
                        "{} on {gpu_name} ({threads} threads): {} must be bit-identical \
                         to the serial reference",
                        cfg.name,
                        m.name()
                    );
                }
                assert_eq!(report.totals.degraded_kernels, reference.degraded_kernels);
                assert_eq!(
                    report.launches.to_bits(),
                    trace::launch_count(&tr).to_bits(),
                    "{}: launch accounting must match",
                    cfg.name
                );
                lines.push(synperf::scenario::wire::encode_report(None, &Ok(report)));
            }
            assert!(
                lines.windows(2).all(|w| w[0] == w[1]),
                "{} on {gpu_name}: JSONL reports must be byte-identical across thread counts",
                cfg.name
            );
        }
    }
}

// ---- Scenario v2: cluster simulation -------------------------------------

use synperf::scenario::{ArrivalSpec, ClusterRequest, ClusterSpec, RoutePolicy};

/// The tentpole determinism contract: a cluster simulation's encoded JSONL
/// report is **byte-identical** across thread counts, across repeated runs
/// in one process (warm per-GPU comm-model and engine caches), and across
/// routing policies' own reruns. Seeded arrival generation is covered by
/// sweeping seeds.
#[test]
fn cluster_reports_are_byte_identical_across_threads_and_runs() {
    let sim = Simulator::degraded();
    for seed in [0u64, 0xDEAD_BEEF] {
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::SessionAffinity]
        {
            let spec = ClusterSpec::new("Llama3.1-8B", "A100")
                .replicas(3)
                .policy(policy)
                .arrivals(ArrivalSpec::Poisson {
                    rate_rps: 32.0,
                    n: 24,
                    kind: synperf::e2e::workload::WorkloadKind::Splitwise,
                })
                .max_batch(8)
                .kv_capacity_tokens(1 << 17)
                .seed(seed);
            let mut lines: Vec<String> = Vec::new();
            for threads in [1usize, 2, 7] {
                for _run in 0..2 {
                    let report = sim.simulate_cluster_with_threads(&spec, threads).unwrap();
                    assert_eq!(report.completed, 24);
                    lines.push(synperf::scenario::wire::encode_cluster_report(
                        None,
                        &Ok(report),
                    ));
                }
            }
            assert!(
                lines.windows(2).all(|w| w[0] == w[1]),
                "policy {} seed {seed}: cluster JSONL must be byte-identical across \
                 thread counts and runs",
                policy.name()
            );
        }
    }
}

/// Golden two-replica scenario over a deterministic trace: every field
/// that is exactly computable without the predictor's numbers is pinned
/// (conservation, routing distribution, histogram counts, SLO extremes),
/// and the predictor-dependent fields are sanity-bounded.
#[test]
fn two_replica_trace_scenario_pins_its_exact_fields() {
    let sim = Simulator::degraded();
    let trace: Vec<ClusterRequest> = (0..6u32)
        .map(|i| ClusterRequest {
            arrival_sec: i as f64 * 0.01,
            input_len: 64 + 32 * i,
            output_len: 4 + i,
            session: i as u64,
        })
        .collect();
    let spec = ClusterSpec::new("Llama3.1-8B", "A100")
        .replicas(2)
        .arrivals(ArrivalSpec::Trace(trace))
        .max_batch(4)
        .kv_capacity_tokens(4096)
        .seed(11);
    let r = sim.simulate_cluster(&spec).unwrap();
    assert_eq!(r.offered, 6);
    assert_eq!(r.completed, 6);
    // round-robin: arrivals 0,2,4 on replica 0; 1,3,5 on replica 1
    assert_eq!(r.replicas.len(), 2);
    assert_eq!(r.replicas[0].completed, 3);
    assert_eq!(r.replicas[1].completed, 3);
    // outputs 4..=9 sum to 39 generated tokens
    assert_eq!(r.generated_tokens, 39.0);
    // one TTFT and one queue-delay sample per request; every request
    // generates > 1 token so TPOT is recorded for all six
    assert_eq!(r.ttft.count, 6);
    assert_eq!(r.ttft_hist.count(), 6);
    assert_eq!(r.tpot.count, 6);
    assert_eq!(r.queue_delay.count, 6);
    assert!(r.makespan_sec.is_finite() && r.makespan_sec > 0.05);
    assert!(r.ttft.p50_sec > 0.0 && r.ttft.p99_sec >= r.ttft.p50_sec);
    assert!(r.events >= 12, "at least arrival + one step per request");
    for rep in &r.replicas {
        assert!(rep.peak_kv_tokens <= 4096);
        assert!(rep.max_batch_seen <= 4);
        assert!(rep.utilization >= 0.0 && rep.utilization <= 1.0 + 1e-9);
    }
    // SLO extremes bracket the attainment fields exactly
    let lax = sim.simulate_cluster(&spec.clone().slo(1e6, 1e6)).unwrap();
    assert_eq!(lax.slo_attainment, 1.0);
    let strict = sim.simulate_cluster(&spec.clone().slo(1e-12, 1e-12)).unwrap();
    assert_eq!(strict.slo_attainment, 0.0);
    // the latency summaries derive from the shipped histograms
    assert_eq!(r.ttft.p95_sec, r.ttft_hist.percentile(95.0));
    assert_eq!(r.queue_delay.p50_sec, r.queue_hist.percentile(50.0));
}

// ---- Autotune subsystem ---------------------------------------------------

/// The tune verb's determinism contract: the streamed JSONL — every row
/// line in index order plus the summary line — is byte-identical between
/// the serial path (`--threads 1`) and the work-stealing pool
/// (`--threads 8`), for random GPUs, point counts and seeds.
#[test]
fn tune_stream_is_byte_identical_across_thread_counts() {
    use synperf::autotune::{run_tune, wire as tune_wire, Ceiling, ConfigSource, TuneSpec};
    use synperf::sweep::GpuFilter;
    prop_check("tune_threads_byte_diff", 4, |r| {
        let gpu = (*r.choose(&["A40", "H20", "H800"])).to_string();
        let spec = TuneSpec::new()
            .gpus(GpuFilter::Named(vec![gpu]))
            .source(ConfigSource::Sampled { n: r.range_usize(1, 3) })
            .seed(r.next_u64())
            .bounds(64, 4, 8);
        let mut streams: Vec<String> = Vec::new();
        for threads in [1usize, 8] {
            let mut text = String::new();
            let out = run_tune(&spec, Ceiling::auto, threads, |row| {
                text.push_str(&tune_wire::encode_row(row));
                text.push('\n');
            })
            .unwrap();
            text.push_str(&tune_wire::encode_summary(&out.summary));
            text.push('\n');
            streams.push(text);
        }
        assert_eq!(streams[0], streams[1], "tune JSONL must not depend on --threads");
    });
}

// ---- Sweep sharding -------------------------------------------------------

/// The sharding contract behind `sweep-merge`: for random specs, thread
/// counts and shard counts N in {2, 3}, the union of the N round-robin
/// shard runs is row-for-row identical (encoded bytes included) to the
/// unsharded run of the same spec.
#[test]
fn shard_union_reproduces_the_unsharded_sweep() {
    use synperf::e2e::workload::Request;
    use synperf::scenario::{ScenarioSpec, Simulator, WorkloadSpec};
    use synperf::sweep::{run_sweep_with, wire as sweep_wire, GpuFilter, RunOptions, Shard, SweepRow, SweepSpec};

    prop_check("shard_union_byte_diff", 6, |r| {
        let pool = ["A100", "H800", "L20", "A40"];
        let gpus: Vec<String> =
            pool[..r.range_usize(2, 4)].iter().map(|g| (*g).to_string()).collect();
        // tp=3 never divides llama3.1-8b's 32 heads, so some grids carry
        // typed error rows — sharding must reproduce those bytes too
        let tp: Vec<u32> = if r.range_usize(0, 1) == 0 { vec![1, 2] } else { vec![1, 3] };
        let spec = SweepSpec::new()
            .gpus(GpuFilter::Named(gpus))
            .tp(tp)
            .scenario(
                "tiny",
                ScenarioSpec::new("llama3.1-8b", "")
                    .workload(WorkloadSpec::Explicit(vec![Request { input_len: 64, output_len: 4 }]))
                    .seed(r.range_usize(1, 9) as u64),
            );
        let threads = r.range_usize(1, 4);
        let run = |shard: Shard| -> Vec<SweepRow> {
            let mut rows = Vec::new();
            let opts = RunOptions { shard, ..RunOptions::threads(threads) };
            run_sweep_with(&spec, &Simulator::degraded, &opts, |row| rows.push(row.clone()))
                .unwrap();
            rows
        };
        let whole = run(Shard::default());
        for n in [2u32, 3] {
            let mut union: Vec<SweepRow> =
                (0..n).flat_map(|i| run(Shard::new(i, n))).collect();
            union.sort_by_key(|row| row.index);
            assert_eq!(union.len(), whole.len(), "shard union must cover the grid at N={n}");
            for (a, b) in union.iter().zip(&whole) {
                assert_eq!(a, b, "shard union row drift at N={n}");
                assert_eq!(
                    sweep_wire::encode_row(a),
                    sweep_wire::encode_row(b),
                    "shard union byte drift at N={n}"
                );
            }
        }
    });
}
