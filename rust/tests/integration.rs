//! End-to-end integration: dataset -> PJRT training -> prediction accuracy.
//! Requires artifacts; skips gracefully if absent.

use synperf::dataset;
use synperf::hw;
use synperf::kernels::KernelKind;
use synperf::mlp::{train_model, Predictor, TrainConfig};
use synperf::runtime::Engine;
use synperf::util::stats;

fn engine() -> Option<Engine> {
    Engine::new("artifacts").ok()
}

#[test]
fn trained_gemm_model_beats_roofline() {
    let Some(e) = engine() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let t0 = std::time::Instant::now();
    let ds = dataset::build(KernelKind::Gemm, &hw::all_gpus(), 260, 42, 8);
    eprintln!("dataset: {} samples in {:?}", ds.len(), t0.elapsed());
    let (seen, unseen) = dataset::split_seen(&ds);
    // train on seen GPUs
    let xs: Vec<_> = seen.iter().map(|s| s.x).collect();
    let ys: Vec<f64> = seen.iter().map(|s| s.efficiency()).collect();
    let cfg = TrainConfig { max_steps: 700, val_every: 70, patience: 4, ..Default::default() };
    let t0 = std::time::Instant::now();
    let model = train_model(&e, &xs, &ys, &cfg).unwrap();
    eprintln!(
        "trained {} steps in {:?}, val loss {:.4}",
        model.steps_run,
        t0.elapsed(),
        model.final_val_loss
    );
    let pred = Predictor::new(&e, model.weights).unwrap();

    for (name, split) in [("seen", &seen), ("unseen", &unseen)] {
        let xs: Vec<_> = split.iter().map(|s| s.x).collect();
        let eff = pred.predict_eff(&xs).unwrap();
        let lat_pred: Vec<f64> =
            split.iter().zip(&eff).map(|(s, e)| s.theory_sec / e).collect();
        let lat_true: Vec<f64> = split.iter().map(|s| s.latency_sec).collect();
        let mape = stats::mape(&lat_pred, &lat_true);
        let roof: Vec<f64> = split.iter().map(|s| s.roofline_sec).collect();
        let roof_mape = stats::mape(&roof, &lat_true);
        eprintln!("{name}: synperf {mape:.1}% vs roofline {roof_mape:.1}%");
        assert!(mape < roof_mape * 0.6, "{name}: MLP {mape}% should beat roofline {roof_mape}%");
        if name == "seen" {
            assert!(mape < 15.0, "seen MAPE too high: {mape}%");
        } else {
            assert!(mape < 30.0, "unseen MAPE too high: {mape}%");
        }
    }
}

#[test]
fn native_forward_matches_pjrt() {
    let Some(e) = engine() else { return };
    let theta = e.read_f32_blob("init_theta.bin").unwrap();
    let bn = e.read_f32_blob("init_bn.bin").unwrap();
    let w = synperf::mlp::weights::ModelWeights {
        theta,
        bn,
        scaler: synperf::mlp::Scaler::identity(),
    };
    let pred = Predictor::new(&e, w).unwrap();
    let xs: Vec<[f32; 32]> = (0..7)
        .map(|i| {
            let mut x = [0f32; 32];
            for (j, v) in x.iter_mut().enumerate() {
                *v = ((i * 37 + j * 13) % 29) as f32 / 29.0 - 0.5;
            }
            x
        })
        .collect();
    let a = pred.predict_eff(&xs).unwrap();
    let b = pred.predict_eff_native(&xs);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4, "PJRT {x} vs native {y}");
    }
}
