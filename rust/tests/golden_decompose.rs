//! Golden regression tests for the Kernel Decomposer (paper §IV-A).
//!
//! For every `KernelKind` on fixed A100 + H800 configurations, the four
//! analytical invariants — `num_tasks`, `total_tensor_ops`, `total_bytes`
//! and `min_dram_bytes` — are pinned to exact snapshot values. The paper's
//! headline accuracy (6.1% kernel-level, §VI) rests on these closed-form
//! decompositions being exactly right, so any drift in the Eq. 3
//! coefficients, tile-selection heuristics, loop spaces or byte counts
//! fails loudly here.
//!
//! All golden numbers are exactly representable in f64 (they are products
//! and sums of modest integers), so comparisons use a 1e-9 relative
//! tolerance purely to absorb summation-order differences. On an intended
//! formula change, rerun with `--nocapture` — each failure prints the
//! observed value to re-pin.

use synperf::dataset::finalize_for_gpu;
use synperf::hw::{gpu_by_name, GpuSpec};
use synperf::kernels::{DType, KernelConfig, KernelKind, MoeConfig};

struct Golden {
    label: &'static str,
    gpu: &'static str,
    cfg: KernelConfig,
    num_tasks: usize,
    total_tensor_ops: f64,
    total_bytes: f64,
    min_dram_bytes: f64,
}

fn check(g: &Golden) {
    let gpu: GpuSpec = gpu_by_name(g.gpu).unwrap();
    let cfg = finalize_for_gpu(&g.cfg, &gpu);
    let d = cfg.decompose(&gpu);
    let close = |got: f64, want: f64, what: &str| {
        let tol = 1e-9 * want.abs().max(1.0);
        assert!(
            (got - want).abs() <= tol,
            "{} on {}: {what} drifted — got {got:?}, golden {want:?}",
            g.label,
            g.gpu
        );
    };
    assert_eq!(
        d.num_tasks(),
        g.num_tasks,
        "{} on {}: num_tasks drifted — got {}, golden {}",
        g.label,
        g.gpu,
        d.num_tasks(),
        g.num_tasks
    );
    close(d.total_tensor_ops(), g.total_tensor_ops, "total_tensor_ops");
    close(d.total_bytes(), g.total_bytes, "total_bytes");
    close(d.min_dram_bytes, g.min_dram_bytes, "min_dram_bytes");
}

fn gemm_large() -> KernelConfig {
    KernelConfig::Gemm { m: 4096, n: 4096, k: 4096, dtype: DType::Bf16 }
}

fn gemm_small() -> KernelConfig {
    // exercises the small-problem fallback tile
    KernelConfig::Gemm { m: 96, n: 512, k: 256, dtype: DType::Bf16 }
}

fn scaled_mm() -> KernelConfig {
    KernelConfig::ScaledMm { m: 2048, n: 4096, k: 2048 }
}

fn attention() -> KernelConfig {
    // ragged causal batch: a decode row (1, 4096), an even prefill
    // (512, 512) and a ragged chunk (300, 1000). finalize_for_gpu resolves
    // FA2 on the A100 and FA3 on the H800; the task-set invariants pinned
    // here are identical across the two variants by construction.
    KernelConfig::Attention {
        batch: vec![(1, 4096), (512, 512), (300, 1000)],
        nh: 8,
        nkv: 2,
        hd: 128,
        causal: true,
        fa3: false,
    }
}

fn rmsnorm() -> KernelConfig {
    KernelConfig::RmsNorm { seq: 4096, dim: 8192 }
}

fn silu_mul() -> KernelConfig {
    KernelConfig::SiluMul { seq: 2048, dim: 13824 }
}

fn fused_moe() -> KernelConfig {
    // fixed routing vector (no RNG): covers zero-token experts, sub-block
    // experts and multi-block experts
    KernelConfig::FusedMoe {
        m: 500,
        e: 8,
        topk: 2,
        h: 2048,
        n: 1024,
        expert_tokens: vec![0, 7, 64, 129, 256, 1, 33, 510],
        cfg: MoeConfig { block_m: 64, block_n: 128, block_k: 64, num_stages: 4, num_warps: 8 },
    }
}

#[test]
fn golden_gemm() {
    // A100 (Ampere): tile (128, 256); H800 (Hopper): tile (256, 128) —
    // symmetric problem, identical totals, different paradigm.
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "gemm 4096x4096x4096 bf16",
            gpu,
            cfg: gemm_large(),
            num_tasks: 512,
            total_tensor_ops: 137438953472.0, // exactly 2*M*N*K
            total_bytes: 1644167168.0,
            min_dram_bytes: 100663296.0,
        });
    }
    check(&Golden {
        label: "gemm 96x512x256 bf16",
        gpu: "A100",
        cfg: gemm_small(),
        num_tasks: 32, // fallback tile (64, 32)
        total_tensor_ops: 33554432.0,
        total_bytes: 1703936.0,
        min_dram_bytes: 409600.0,
    });
    check(&Golden {
        label: "gemm 96x512x256 bf16",
        gpu: "H800",
        cfg: gemm_small(),
        num_tasks: 16, // fallback tile (64, 64)
        total_tensor_ops: 33554432.0,
        total_bytes: 1179648.0,
        min_dram_bytes: 409600.0,
    });
}

#[test]
fn golden_scaled_mm() {
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "scaled_mm 2048x4096x2048 fp8",
            gpu,
            cfg: scaled_mm(),
            num_tasks: 256,
            total_tensor_ops: 34359738368.0,
            total_bytes: 218152960.0,
            min_dram_bytes: 29753344.0,
        });
    }
}

#[test]
fn golden_attention() {
    // 8 query tiles (1 decode + 4 + 3) x 8 heads = 64 tasks; FA2/FA3 agree.
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "attention ragged causal",
            gpu,
            cfg: attention(),
            num_tasks: 64,
            total_tensor_ops: 2399141888.0,
            total_bytes: 36779424.0,
            min_dram_bytes: 9072640.0,
        });
    }
}

#[test]
fn golden_rmsnorm() {
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "rmsnorm 4096x8192",
            gpu,
            cfg: rmsnorm(),
            num_tasks: 4096, // one task per token row
            total_tensor_ops: 0.0,
            total_bytes: 201326592.0,
            min_dram_bytes: 134234112.0,
        });
    }
}

#[test]
fn golden_silu_mul() {
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "silu_mul 2048x13824",
            gpu,
            cfg: silu_mul(),
            num_tasks: 2048,
            total_tensor_ops: 0.0,
            total_bytes: 169869312.0,
            min_dram_bytes: 169869312.0, // purely streaming: loads+stores == compulsory
        });
    }
}

#[test]
fn golden_fused_moe() {
    // grid: sum over active experts of ceil(m_e/64) tiles x ceil(1024/128)
    // = 19 * 8 = 152 tasks; decomposition is GPU-independent.
    for gpu in ["A100", "H800"] {
        check(&Golden {
            label: "fused_moe h2048 n1024",
            gpu,
            cfg: fused_moe(),
            num_tasks: 152,
            total_tensor_ops: 5100273664.0,
            total_bytes: 122066944.0,
            min_dram_bytes: 35504128.0,
        });
    }
}

#[test]
fn golden_covers_every_kernel_kind() {
    // the suite above must never silently lose a category
    let covered = [
        gemm_large().kind(),
        scaled_mm().kind(),
        attention().kind(),
        rmsnorm().kind(),
        silu_mul().kind(),
        fused_moe().kind(),
    ];
    for kind in KernelKind::ALL {
        assert!(covered.contains(&kind), "no golden config for {kind:?}");
    }
}

#[test]
fn decomposition_invariants_hold_on_golden_set() {
    // cross-cutting sanity for the same fixed configs: positive task sets,
    // compulsory traffic below total traffic, occupancy never zero
    for gpu_name in ["A100", "H800"] {
        let gpu = gpu_by_name(gpu_name).unwrap();
        for cfg in [gemm_large(), gemm_small(), scaled_mm(), attention(), rmsnorm(), silu_mul(), fused_moe()]
        {
            let cfg = finalize_for_gpu(&cfg, &gpu);
            let d = cfg.decompose(&gpu);
            assert!(d.num_tasks() > 0, "{gpu_name} {:?}", cfg.kind());
            assert!(
                d.min_dram_bytes <= d.total_bytes() + 1e-6,
                "{gpu_name} {:?}: compulsory traffic must lower-bound totals",
                cfg.kind()
            );
            assert!(d.cta.occupancy(&gpu) >= 1, "{gpu_name} {:?}", cfg.kind());
        }
    }
}
