//! Deterministic fault-injection acceptance tests for the TCP serving
//! front end (`synperf serve --tcp`): a FaultPolicy test client drives
//! slow-loris trickles, mid-line disconnects, half-open peers, repeated
//! abuse, and bursty overload against a live `tcp::serve` loop. The
//! contract under every fault: no panics, no dropped well-formed request
//! without a typed error, responses in per-connection input order — and a
//! clean N-client run is **byte-identical** with the stdio wire for the
//! same request streams.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};
use synperf::api::stdio::serve_lines;
use synperf::api::tcp::{self, TcpConfig};
use synperf::api::{wire, ModelBundle};
use synperf::coordinator::{PredictionService, ServiceConfig};
use synperf::scenario::Simulator;

/// A test config with tight ticks so faults trigger in test time.
fn fast_cfg() -> TcpConfig {
    TcpConfig {
        tick: Duration::from_millis(10),
        ..TcpConfig::default()
    }
}

/// Run `tcp::serve` on an ephemeral port, hand the address to `clients`,
/// flip the drain flag when they are done, and return the server's stats.
fn with_server<F>(svc: &PredictionService, cfg: TcpConfig, clients: F) -> tcp::NetStats
where
    F: FnOnce(std::net::SocketAddr) + Send,
{
    // flips the drain flag even if `clients` panics, so a failed
    // assertion surfaces instead of hanging the scope join forever
    struct Drain<'a>(&'a AtomicBool);
    impl Drop for Drain<'_> {
        fn drop(&mut self) {
            self.0.store(true, Ordering::Release);
        }
    }
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = svc.client();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|s| {
        let server = s.spawn(|| {
            tcp::serve(listener, &client, Simulator::degraded, &cfg, &shutdown).unwrap()
        });
        {
            let _drain = Drain(&shutdown);
            clients(addr);
        }
        server.join().expect("tcp server must not panic")
    })
}

/// Write a whole request stream, half-close, read everything to EOF.
fn send_stream(addr: std::net::SocketAddr, input: &[u8]) -> Vec<u8> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(input).unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    let mut out = Vec::new();
    stream.read_to_end(&mut out).unwrap();
    out
}

#[test]
fn clean_multiclient_run_is_byte_identical_with_stdio() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    // per-client streams with disjoint shapes (seq 20000+) so this test
    // owns its slice of the global engine cache
    let stream = |c: usize| -> Vec<u8> {
        let mut s = String::new();
        for j in 0..5usize {
            s.push_str(&format!(
                "{{\"id\":\"c{c}-p{j}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                 \"seq\":{},\"dim\":2048}}}}\n",
                20000 + c * 16 + j
            ));
        }
        s.push_str("##not-json##\n");
        s.push_str(&format!(
            "{{\"id\":\"c{c}-s\",\"op\":\"simulate\",\"scenario\":{{\"model\":\"llama3.1-8b\",\
             \"gpu\":\"A100\",\"workload\":{{\"requests\":[[{},4]]}},\"seed\":{}}}}}\n",
            64 + c,
            3 + c
        ));
        s.into_bytes()
    };
    const N: usize = 4;
    // warm the global engine cache with one stdio pass per stream, then
    // capture the all-cache-hit stdio output as the expected bytes — the
    // TCP run over the warmed cache must match it exactly
    let mut expected: Vec<Vec<u8>> = Vec::new();
    for c in 0..N {
        let mut warm = Vec::new();
        serve_lines(&svc.client(), Simulator::degraded, &stream(c)[..], &mut warm, 8, 2).unwrap();
        let mut out = Vec::new();
        serve_lines(&svc.client(), Simulator::degraded, &stream(c)[..], &mut out, 8, 2).unwrap();
        expected.push(out);
    }
    let stats = with_server(&svc, fast_cfg(), |addr| {
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..N)
                .map(|c| s.spawn(move || send_stream(addr, &stream(c))))
                .collect();
            for (c, h) in handles.into_iter().enumerate() {
                let got = h.join().unwrap();
                assert_eq!(
                    String::from_utf8_lossy(&got),
                    String::from_utf8_lossy(&expected[c]),
                    "client {c}: TCP bytes drifted from the stdio wire"
                );
            }
        });
    });
    assert_eq!(stats.connections, N as u64);
    assert_eq!(stats.served, (N * 7) as u64);
    assert_eq!(stats.errors, N as u64, "one malformed line per client");
    assert_eq!(stats.simulated, N as u64);
    assert_eq!(stats.quarantined, 0);
    assert_eq!(stats.idle_reaped, 0);
    svc.shutdown();
}

#[test]
fn slow_loris_does_not_starve_other_clients() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let cfg = TcpConfig {
        idle_timeout: Duration::from_secs(30), // the loris stays "alive"
        ..fast_cfg()
    };
    let stats = with_server(&svc, cfg, |addr| {
        let (done_tx, done_rx) = channel::<()>();
        std::thread::scope(|s| {
            // the loris: drip one byte of a never-ending line
            s.spawn(move || {
                let mut loris = TcpStream::connect(addr).unwrap();
                loop {
                    if loris.write_all(b"x").is_err() {
                        break;
                    }
                    match done_rx.recv_timeout(Duration::from_millis(5)) {
                        // keep dripping; stop on done OR on a dropped
                        // sender (a panic below), so the scope can join
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                        _ => break,
                    }
                }
            });
            // the honest client: 5 predicts answered while the loris drips
            let mut input = String::new();
            for j in 0..5usize {
                input.push_str(&format!(
                    "{{\"id\":\"h{j}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                     \"seq\":{},\"dim\":1024}}}}\n",
                    20100 + j
                ));
            }
            let got = send_stream(addr, input.as_bytes());
            done_tx.send(()).ok(); // the honest client is done: release the loris
            let text = String::from_utf8(got).unwrap();
            let lines: Vec<&str> = text.lines().collect();
            assert_eq!(lines.len(), 5, "loris must not starve the honest client");
            for (j, line) in lines.iter().enumerate() {
                assert!(
                    line.contains(&format!("\"id\":\"h{j}\"")) && line.contains("\"ok\":true"),
                    "response {j} wrong or out of order: {line}"
                );
            }
        });
    });
    assert_eq!(stats.idle_reaped, 0, "a trickling peer counts as progress");
    svc.shutdown();
}

#[test]
fn mid_line_disconnect_does_not_panic_the_server() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let stats = with_server(&svc, fast_cfg(), |addr| {
        let mut stream = TcpStream::connect(addr).unwrap();
        // one whole request, then half a line, then vanish
        stream
            .write_all(
                b"{\"id\":\"ok1\",\"gpu\":\"A100\",\"kernel\":{\"type\":\"rmsnorm\",\"seq\":20200,\"dim\":1024}}\n{\"id\":\"trunc",
            )
            .unwrap();
        drop(stream); // no half-close: the partial line just stops
        // give the server a moment to observe the hangup and unwind
        std::thread::sleep(Duration::from_millis(150));
    });
    assert_eq!(stats.connections, 1);
    assert!(
        stats.served >= 1,
        "the complete request before the disconnect was answered: {stats:?}"
    );
    svc.shutdown();
}

#[test]
fn half_open_connection_is_reaped() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let cfg = TcpConfig {
        idle_timeout: Duration::from_millis(200),
        ..fast_cfg()
    };
    let stats = with_server(&svc, cfg, |addr| {
        let stream = TcpStream::connect(addr).unwrap();
        // send nothing: the server must notice on its own (the read
        // timeout is a failsafe so a broken reaper fails the test
        // instead of hanging it)
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let t0 = Instant::now();
        let mut buf = Vec::new();
        let mut reader = &stream;
        reader.read_to_end(&mut buf).ok(); // EOF when the server reaps us
        assert!(buf.is_empty());
        assert!(
            t0.elapsed() < Duration::from_secs(15),
            "reap must happen in idle_timeout time, not hang"
        );
    });
    assert_eq!(stats.idle_reaped, 1);
    assert_eq!(stats.connections, 1);
    svc.shutdown();
}

#[test]
fn repeated_abuse_is_quarantined_after_typed_errors() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let cfg = TcpConfig { quarantine_limit: 3, ..fast_cfg() };
    let stats = with_server(&svc, cfg, |addr| {
        // 2 bad lines, then a valid one (resets the strike counter), then
        // 3 bad in a row: quarantine. Exactly 6 responses, then EOF.
        let mut input = Vec::new();
        input.extend_from_slice(b"!!b1\n!!b2\n");
        input.extend_from_slice(
            b"{\"id\":\"good\",\"gpu\":\"A100\",\"kernel\":{\"type\":\"rmsnorm\",\"seq\":20300,\"dim\":1024}}\n",
        );
        input.extend_from_slice(b"!!b3\n!!b4\n!!b5\n");
        let got = send_stream(addr, &input);
        let text = String::from_utf8(got).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 6, "every line up to the quarantine answers: {text}");
        for (i, line) in lines.iter().enumerate() {
            if i == 2 {
                assert!(line.contains("\"id\":\"good\"") && line.contains("\"ok\":true"));
            } else {
                assert!(
                    line.contains("\"code\":\"unsupported_kernel\"")
                        && line.contains("malformed JSON"),
                    "line {i}: {line}"
                );
            }
        }
    });
    assert_eq!(stats.quarantined, 1);
    svc.shutdown();
}

#[test]
fn oversized_line_answers_typed_error_and_connection_survives() {
    let svc = PredictionService::spawn(ModelBundle::default, ServiceConfig::default());
    let stats = with_server(&svc, fast_cfg(), |addr| {
        let mut input = vec![b'z'; 2 << 20]; // 2 MiB, over the 1 MiB cap
        input.push(b'\n');
        input.extend_from_slice(
            b"{\"id\":\"after\",\"gpu\":\"A100\",\"kernel\":{\"type\":\"rmsnorm\",\"seq\":20400,\"dim\":1024}}\n",
        );
        let got = send_stream(addr, &input);
        let text = String::from_utf8(got).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(
            lines[0].contains("\"code\":\"unsupported_kernel\"")
                && lines[0].contains("oversized line"),
            "{}",
            lines[0]
        );
        assert!(lines[1].contains("\"id\":\"after\"") && lines[1].contains("\"ok\":true"));
    });
    assert_eq!(stats.oversized, 1);
    assert_eq!(stats.quarantined, 0, "one oversized line is not abuse");
    svc.shutdown();
}

#[test]
fn burst_overload_answers_typed_backpressure_in_order() {
    // gate the service loop so the bounded queue saturates deterministically
    let (gate_tx, gate_rx) = channel::<()>();
    let svc = PredictionService::spawn(
        move || {
            gate_rx.recv().ok();
            ModelBundle::default()
        },
        ServiceConfig {
            max_batch: 8,
            deadline: Duration::from_millis(1),
            queue_cap: 4,
            ..ServiceConfig::default()
        },
    );
    let cfg = TcpConfig {
        admit_timeout: Duration::from_millis(50),
        tick: Duration::from_millis(5),
        ..TcpConfig::default()
    };
    let stats = with_server(&svc, cfg, |addr| {
        let mut input = String::new();
        let predict = |id: &str, seq: usize, deadline: Option<u64>| {
            let dl = deadline.map(|ms| format!(",\"deadline_ms\":{ms}")).unwrap_or_default();
            format!(
                "{{\"id\":\"{id}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                 \"seq\":{seq},\"dim\":1024}}{dl}}}\n"
            )
        };
        for j in 0..4usize {
            input.push_str(&predict(&format!("f{j}"), 20500 + j, None)); // fill the queue
        }
        for j in 0..8usize {
            input.push_str(&predict(&format!("d{j}"), 20510 + j, Some(1))); // expire fast
        }
        for j in 0..8usize {
            input.push_str(&predict(&format!("n{j}"), 20520 + j, None)); // admit_timeout
        }
        input.push_str("{\"id\":\"st\",\"op\":\"stats\"}\n");
        // open the gate well after every waiting request has expired: the
        // four queued fillers then answer ok, everything else already
        // failed typed — and the response order is still the input order
        let opener = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(300));
            gate_tx.send(()).ok();
        });
        let got = send_stream(addr, input.as_bytes());
        opener.join().unwrap();
        let text = String::from_utf8(got).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 21, "every request answers exactly once: {text}");
        for (j, line) in lines.iter().take(4).enumerate() {
            assert!(
                line.contains(&format!("\"id\":\"f{j}\"")) && line.contains("\"ok\":true"),
                "filler {j}: {line}"
            );
        }
        for (j, line) in lines.iter().skip(4).take(8).enumerate() {
            assert!(
                line.contains("\"code\":\"deadline_exceeded\""),
                "deadline request {j}: {line}"
            );
        }
        for (j, line) in lines.iter().skip(12).take(8).enumerate() {
            assert!(line.contains("\"code\":\"queue_full\""), "waiting request {j}: {line}");
        }
        let (id, report) = wire::parse_stats(lines[20]).unwrap();
        assert_eq!(id.as_deref(), Some("st"));
        assert_eq!(report.requests, 4, "only the fillers reached the service");
        assert_eq!(report.rejected_requests, 16);
        assert_eq!(report.deadline_exceeded, 8);
        assert_eq!(report.served, 21, "the stats line counts itself");
        assert_eq!(report.errors, 16);
        assert_eq!(report.clients.connected, 1);
        assert_eq!(report.clients.total, 1);
    });
    assert_eq!(stats.served, 21);
    assert_eq!(stats.errors, 16);
    svc.shutdown();
}

#[test]
fn graceful_drain_finishes_in_flight_requests() {
    // requests admitted before the drain flag flips must still answer:
    // gate the service, submit, flip the flag, then open the gate
    let (gate_tx, gate_rx) = channel::<()>();
    let svc = PredictionService::spawn(
        move || {
            gate_rx.recv().ok();
            ModelBundle::default()
        },
        ServiceConfig::default(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let client = svc.client();
    let cfg = fast_cfg();
    let shutdown = AtomicBool::new(false);
    let stats = std::thread::scope(|s| {
        let server = s.spawn(|| {
            tcp::serve(listener, &client, Simulator::degraded, &cfg, &shutdown).unwrap()
        });
        let peer = s.spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            for j in 0..3usize {
                stream
                    .write_all(
                        format!(
                            "{{\"id\":\"g{j}\",\"gpu\":\"A100\",\"kernel\":{{\"type\":\"rmsnorm\",\
                             \"seq\":{},\"dim\":1024}}}}\n",
                            20600 + j
                        )
                        .as_bytes(),
                    )
                    .unwrap();
            }
            // connection stays open: EOF must come from the server's drain
            let mut reader = BufReader::new(stream);
            let mut lines = Vec::new();
            loop {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(0) | Err(_) => break,
                    Ok(_) => lines.push(line.trim_end().to_string()),
                }
            }
            lines
        });
        // let the requests get admitted, then drain, then release the gate
        std::thread::sleep(Duration::from_millis(150));
        shutdown.store(true, Ordering::Release);
        std::thread::sleep(Duration::from_millis(50));
        gate_tx.send(()).ok();
        let lines = peer.join().unwrap();
        assert_eq!(lines.len(), 3, "drain must answer every admitted request: {lines:?}");
        for (j, line) in lines.iter().enumerate() {
            assert!(
                line.contains(&format!("\"id\":\"g{j}\"")) && line.contains("\"ok\":true"),
                "drained response {j}: {line}"
            );
        }
        server.join().expect("drain must terminate the server")
    });
    assert_eq!(stats.served, 3);
    assert_eq!(stats.errors, 0);
    svc.shutdown();
}
