//! Offline stub of the `xla` (xla-rs) PJRT surface that SynPerf's runtime
//! layer compiles against.
//!
//! The container image that runs tier-1 verification has no
//! `xla_extension` shared library and no crates.io registry, so this path
//! crate provides the exact API shape the runtime uses with one behavioral
//! difference: [`PjRtClient::cpu`] always returns an "unavailable" error.
//! `runtime::Engine::new` therefore fails cleanly and every PJRT-dependent
//! code path (training, Predictor construction, the runtime integration
//! tests) skips gracefully — the same degraded mode as a machine where
//! `make artifacts` has not been run.
//!
//! [`Literal`] construction and conversion are implemented for real (they
//! are cheap host-side containers), so literal-building helpers keep
//! working and unit-testable without a PJRT backend.
//!
//! To enable the real PJRT runtime, point the `xla` path dependency in
//! `rust/Cargo.toml` at an xla-rs checkout with `xla_extension` installed.

use std::fmt;
use std::path::Path;

/// Stub error: carries the failing operation name.
#[derive(Debug, Clone)]
pub struct Error {
    op: &'static str,
}

impl Error {
    fn unavailable(op: &'static str) -> Error {
        Error { op }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "PJRT unavailable ({}): synperf was built against the offline xla stub",
            self.op
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Typed element storage for [`Literal`].
#[derive(Debug, Clone)]
enum LitData {
    F32(Vec<f32>),
    U32(Vec<u32>),
}

/// Host-side literal: element buffer + dimensions. Fully functional.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LitData,
    dims: Vec<i64>,
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for u32 {}
}

/// Element types a [`Literal`] can hold or yield.
pub trait NativeType: sealed::Sealed + Copy {
    fn wrap(data: Vec<Self>) -> LitDataWrapper;
    fn unwrap_slice(lit: &Literal) -> Option<Vec<Self>>;
}

/// Opaque constructor payload (keeps `LitData` private).
pub struct LitDataWrapper(LitData);

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LitDataWrapper {
        LitDataWrapper(LitData::F32(data))
    }
    fn unwrap_slice(lit: &Literal) -> Option<Vec<f32>> {
        match &lit.data {
            LitData::F32(v) => Some(v.clone()),
            LitData::U32(_) => None,
        }
    }
}

impl NativeType for u32 {
    fn wrap(data: Vec<u32>) -> LitDataWrapper {
        LitDataWrapper(LitData::U32(data))
    }
    fn unwrap_slice(lit: &Literal) -> Option<Vec<u32>> {
        match &lit.data {
            LitData::U32(v) => Some(v.clone()),
            LitData::F32(_) => None,
        }
    }
}

/// Anything accepted by [`Literal::vec1`]: slices and fixed-size arrays of a
/// native element type (matches the call shapes used by the runtime).
pub trait AsNativeSlice {
    type Elem: NativeType;
    fn as_native_slice(&self) -> &[Self::Elem];
}

impl AsNativeSlice for &[f32] {
    type Elem = f32;
    fn as_native_slice(&self) -> &[f32] {
        self
    }
}

impl AsNativeSlice for &[u32] {
    type Elem = u32;
    fn as_native_slice(&self) -> &[u32] {
        self
    }
}

impl<const N: usize> AsNativeSlice for &[f32; N] {
    type Elem = f32;
    fn as_native_slice(&self) -> &[f32] {
        &self[..]
    }
}

impl<const N: usize> AsNativeSlice for &[u32; N] {
    type Elem = u32;
    fn as_native_slice(&self) -> &[u32] {
        &self[..]
    }
}

impl Literal {
    /// Rank-1 literal from a slice (or fixed-size array reference).
    pub fn vec1<D: AsNativeSlice>(data: D) -> Literal {
        let slice = data.as_native_slice();
        let LitDataWrapper(data) = D::Elem::wrap(slice.to_vec());
        Literal { data, dims: vec![slice.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let len = match &self.data {
            LitData::F32(v) => v.len(),
            LitData::U32(v) => v.len(),
        };
        if n as usize != len {
            return Err(Error::unavailable("reshape: element count mismatch"));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Extract the elements as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_slice(self).ok_or(Error::unavailable("to_vec: element type mismatch"))
    }

    /// Flatten a tuple literal into its elements. The stub has no tuple
    /// layout, so a literal is treated as the single-element tuple.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Ok(vec![self])
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl From<f32> for Literal {
    fn from(v: f32) -> Literal {
        Literal { data: LitData::F32(vec![v]), dims: vec![] }
    }
}

/// Parsed HLO module (never constructible through the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT device buffer handle (unreachable through the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable (unreachable through the stub: compilation fails first).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. [`PjRtClient::cpu`] is the single entry point and always
/// fails in the stub, which makes every downstream consumer skip cleanly.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
    }

    #[test]
    fn literals_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0][..]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 3]).is_err());
        assert!(l.to_vec::<u32>().is_err());
        let k = [7u32, 9u32];
        let lk = Literal::vec1(&k).reshape(&[2]).unwrap();
        assert_eq!(lk.to_vec::<u32>().unwrap(), vec![7, 9]);
        let s = Literal::from(1.5f32);
        assert_eq!(s.dims().len(), 0);
    }
}
