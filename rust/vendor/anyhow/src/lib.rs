//! Offline, API-compatible shim of the `anyhow` crate covering the subset
//! SynPerf uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no crates.io registry, so this path crate
//! stands in for the real dependency. Semantics match `anyhow` where it
//! matters for this codebase:
//!  * `Display` prints the outermost message; `{:#}` prints the full
//!    `outer: cause: cause` chain; `Debug` prints the chain multi-line.
//!  * Any `std::error::Error + Send + Sync + 'static` converts via `?`.
//!  * `Error` intentionally does NOT implement `std::error::Error`
//!    (mirroring the real crate, which keeps the blanket `From` coherent).

use std::fmt::{self, Debug, Display};

/// Error type: an outermost message plus the (stringified) cause chain.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

/// `anyhow::Result<T>` — alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: Display + Send + Sync + 'static>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (used by the [`Context`] trait).
    fn wrap(mut self, context: String) -> Error {
        self.chain.insert(0, context);
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost message in the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain on one line, anyhow-style
            for (i, msg) in self.chain.iter().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

/// Every standard error converts into [`Error`], capturing its source chain.
/// (`Error` itself converts via the std reflexive `From<T> for T`; the two
/// impls are coherent because `Error` does not implement `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
///
/// A single blanket impl over `Result<T, E> where Error: From<E>` covers
/// both std-error results and `anyhow::Result` itself (via reflexive
/// `From`), so no overlapping-impl tricks are needed.
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    Error: From<E>,
{
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(context.to_string()))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).wrap(f().to_string()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "missing"))
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_err().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing");
        let e2: Result<(), Error> = Err(e);
        let e2 = e2.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 2: reading config: missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing here").unwrap_err();
        assert_eq!(format!("{e}"), "nothing here");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Err(anyhow!("fell through"))
        }
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(1).unwrap_err()), "fell through");
    }
}
