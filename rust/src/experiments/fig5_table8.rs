//! Fig. 5 + Table VIII: kernel-level prediction accuracy (MAPE %) of the
//! five methods per GPU for the four BF16 LLM-inference kernels, and the
//! seen/unseen averages.

use super::{Lab, ModelFlavor};
use crate::dataset::Sample;
use crate::hw::all_gpus;
use crate::kernels::KernelKind;
use crate::util::stats::{mape, mean};
use crate::util::table::{f, Table};
use anyhow::Result;

pub const KINDS: [KernelKind; 4] =
    [KernelKind::Gemm, KernelKind::Attention, KernelKind::RmsNorm, KernelKind::SiluMul];

pub const METHODS: [&str; 5] = ["Roofline", "Linear", "Habitat", "Neusight", "SynPerf"];

/// MAPE of all five methods over a sample subset (same kernel category).
pub fn method_mapes(lab: &Lab, kind: KernelKind, subset: &[&Sample]) -> Result<[f64; 5]> {
    let actual: Vec<f64> = subset.iter().map(|s| s.latency_sec).collect();
    let roof: Vec<f64> = subset.iter().map(|s| s.roofline_sec).collect();
    let lin_model = lab.linear(kind);
    let lin: Vec<f64> = subset.iter().map(|s| lin_model.predict(s)).collect();
    let hab: Vec<f64> = subset.iter().map(|s| s.habitat_sec).collect();

    let neu_model = lab.model(kind, ModelFlavor::Neusight)?;
    let xs_alt: Vec<[f32; 32]> = subset.iter().map(|s| s.x_alt).collect();
    let neu_eff = neu_model.predict_eff(&xs_alt)?;
    let neu: Vec<f64> =
        subset.iter().zip(neu_eff).map(|(s, e)| s.alt_theory_sec / e).collect();

    let syn_model = lab.model(kind, ModelFlavor::SynPerf)?;
    let xs: Vec<[f32; 32]> = subset.iter().map(|s| s.x).collect();
    let syn_eff = syn_model.predict_eff(&xs)?;
    let syn: Vec<f64> = subset.iter().zip(syn_eff).map(|(s, e)| s.theory_sec / e).collect();

    Ok([
        mape(&roof, &actual),
        mape(&lin, &actual),
        mape(&hab, &actual),
        mape(&neu, &actual),
        mape(&syn, &actual),
    ])
}

pub fn run(lab: &Lab) -> Result<String> {
    let mut out = String::new();
    // accumulate per (method, seen?) for Table VIII
    let mut seen_acc: Vec<Vec<f64>> = vec![Vec::new(); 5];
    let mut unseen_acc: Vec<Vec<f64>> = vec![Vec::new(); 5];

    for kind in KINDS {
        let ds = lab.dataset(kind);
        let mut t = Table::new(
            &format!("Fig. 5 — kernel-level MAPE (%), {}", kind.name()),
            &["GPU", "Roofline", "Linear", "Habitat", "Neusight", "SynPerf"],
        );
        for gpu in all_gpus() {
            let subset: Vec<&Sample> = ds.iter().filter(|s| s.gpu == gpu.name).collect();
            if subset.is_empty() {
                continue;
            }
            let m = method_mapes(lab, kind, &subset)?;
            for i in 0..5 {
                if gpu.seen {
                    seen_acc[i].push(m[i]);
                } else {
                    unseen_acc[i].push(m[i]);
                }
            }
            let tag = if gpu.seen { "" } else { " (unseen)" };
            t.row(vec![
                format!("{}{}", gpu.name, tag),
                f(m[0], 1),
                f(m[1], 1),
                f(m[2], 1),
                f(m[3], 1),
                f(m[4], 1),
            ]);
        }
        let block = t.render();
        print!("{block}");
        out.push_str(&block);
    }

    let mut t8 = Table::new(
        "Table VIII — average MAPE (%) on seen and unseen GPUs",
        &["Hardware", "Roofline", "Linear", "Habitat", "Neusight", "SynPerf"],
    );
    let seen_avg: Vec<f64> = seen_acc.iter().map(|v| mean(v)).collect();
    let unseen_avg: Vec<f64> = unseen_acc.iter().map(|v| mean(v)).collect();
    t8.row(vec![
        "Seen".into(),
        f(seen_avg[0], 2),
        f(seen_avg[1], 2),
        f(seen_avg[2], 2),
        f(seen_avg[3], 2),
        f(seen_avg[4], 2),
    ]);
    t8.row(vec![
        "Unseen".into(),
        f(unseen_avg[0], 2),
        f(unseen_avg[1], 2),
        f(unseen_avg[2], 2),
        f(unseen_avg[3], 2),
        f(unseen_avg[4], 2),
    ]);
    let block = t8.render();
    print!("{block}");
    out.push_str(&block);

    // paper-shape assertions: SynPerf best on both splits
    for i in 0..4 {
        assert!(seen_avg[4] < seen_avg[i], "SynPerf must win on seen GPUs");
        assert!(unseen_avg[4] < unseen_avg[i], "SynPerf must win on unseen GPUs");
    }
    Ok(out)
}
