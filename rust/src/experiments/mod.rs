//! Experiment harness: one module per paper table / figure (DESIGN.md §5).
//! Each experiment prints the paper-style rows and returns a rendered block
//! that the CLI appends to runs/results.txt.
//!
//! [`Lab`] provides shared, disk-cached infrastructure: per-kernel datasets
//! (runs/data/*.csv) and trained models (runs/models/*.bin) at a chosen
//! scale, so individual experiments stay fast and reproducible.

pub mod fig3;
pub mod fig4;
pub mod fig5_table8;
pub mod fig6;
pub mod fig7;
pub mod fig8_table10;
pub mod scaledmm;
pub mod table1;
pub mod table7;
pub mod table9;

use crate::baselines::linear::LinearModel;
use crate::dataset::{self, Sample};
use crate::e2e::predict::ModelSet;
use crate::hw::all_gpus;
use crate::kernels::KernelKind;
use crate::mlp::{train_model, Predictor, TrainConfig};
use crate::runtime::Engine;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::PathBuf;

/// Dataset / training scale knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// quick shake-out (CI-sized)
    Fast,
    /// default: minutes for the full suite
    Normal,
    /// closer to the paper's sample counts
    Full,
}

impl Scale {
    pub fn n_configs(&self) -> usize {
        match self {
            Scale::Fast => 120,
            Scale::Normal => 420,
            Scale::Full => 1200,
        }
    }

    pub fn train_steps(&self) -> usize {
        match self {
            Scale::Fast => 600,
            Scale::Normal => 2200,
            Scale::Full => 6000,
        }
    }

    pub fn tag(&self) -> &'static str {
        match self {
            Scale::Fast => "fast",
            Scale::Normal => "normal",
            Scale::Full => "full",
        }
    }
}

/// Shared experiment state with disk caches.
pub struct Lab {
    pub engine: Engine,
    pub scale: Scale,
    pub root: PathBuf,
    pub seed: u64,
    datasets: std::cell::RefCell<HashMap<KernelKind, std::rc::Rc<Vec<Sample>>>>,
    /// Built once, shared across experiments — the Simulator carries the
    /// per-GPU RF comm-model cache, so repeated `simulator()` callers must
    /// not each retrain it.
    simulator: std::cell::RefCell<Option<std::rc::Rc<crate::scenario::Simulator>>>,
}

/// Which feature view / loss a cached model was trained with.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelFlavor {
    SynPerf,
    /// pinball tau=0.8 ceiling model (§VII)
    P80,
    /// Neusight tile-level features
    Neusight,
    /// SynPerf features with the MIO block zeroed (Fig. 4 ablation)
    NoMio,
    /// SynPerf features with the Math block zeroed (Fig. 4 ablation)
    NoMath,
}

/// The run root shared by every artifact consumer: `SYNPERF_RUNS` or
/// `./runs`. Pure path computation — nothing is created.
pub(crate) fn runs_root() -> PathBuf {
    PathBuf::from(std::env::var("SYNPERF_RUNS").unwrap_or_else(|_| "runs".into()))
}

/// Cached-model file name under `<runs_root>/models/` — exposed so
/// artifact probes (e.g. [`crate::autotune::Ceiling::auto`]) can check
/// `exists()` without constructing a [`Lab`] (which needs a PJRT engine
/// and creates the run directories as a side effect).
pub(crate) fn model_artifact_name(kind: KernelKind, flavor: ModelFlavor, scale: Scale) -> String {
    format!("{}_{}_{}.bin", kind.name(), flavor.tag(), scale.tag())
}

impl ModelFlavor {
    fn tag(&self) -> &'static str {
        match self {
            ModelFlavor::SynPerf => "syn",
            ModelFlavor::P80 => "p80",
            ModelFlavor::Neusight => "neu",
            ModelFlavor::NoMio => "nomio",
            ModelFlavor::NoMath => "nomath",
        }
    }
}

/// Feature masking for the ablations: zero a block of the SynPerf vector.
pub fn mask_features(x: &[f32; 32], flavor: ModelFlavor) -> [f32; 32] {
    let mut out = *x;
    match flavor {
        ModelFlavor::NoMio => {
            for v in &mut out[12..19] {
                *v = 0.0;
            }
        }
        ModelFlavor::NoMath => {
            for v in &mut out[0..12] {
                *v = 0.0;
            }
        }
        _ => {}
    }
    out
}

impl Lab {
    pub fn new(scale: Scale) -> Result<Lab> {
        let engine = Engine::from_env().context(
            "PJRT engine unavailable — run `make artifacts` before experiments",
        )?;
        let root = runs_root();
        std::fs::create_dir_all(root.join("data"))?;
        std::fs::create_dir_all(root.join("models"))?;
        Ok(Lab {
            engine,
            scale,
            root,
            seed: 0x5EED_CAFE,
            datasets: Default::default(),
            simulator: Default::default(),
        })
    }

    /// Per-kernel dataset, cached in memory and on disk.
    pub fn dataset(&self, kind: KernelKind) -> std::rc::Rc<Vec<Sample>> {
        if let Some(ds) = self.datasets.borrow().get(&kind) {
            return ds.clone();
        }
        let path = self
            .root
            .join("data")
            .join(format!("{}_{}.csv", kind.name(), self.scale.tag()));
        let ds = if path.exists() {
            dataset::load(&path).expect("cached dataset readable")
        } else {
            eprintln!("[lab] building {} dataset ({} configs x 11 GPUs)...", kind.name(), self.scale.n_configs());
            let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
            let ds = dataset::build(kind, &all_gpus(), self.scale.n_configs(), self.seed, threads);
            dataset::save(&ds, &path).expect("cache dataset");
            ds
        };
        let rc = std::rc::Rc::new(ds);
        self.datasets.borrow_mut().insert(kind, rc.clone());
        rc
    }

    /// The deterministic config list matching `dataset(kind)` row-major
    /// order (configs x GPUs).
    pub fn dataset_configs(&self, kind: KernelKind) -> Vec<crate::kernels::KernelConfig> {
        dataset::sample_configs(kind, self.scale.n_configs(), self.seed)
    }

    fn model_path(&self, kind: KernelKind, flavor: ModelFlavor) -> PathBuf {
        self.root.join("models").join(model_artifact_name(kind, flavor, self.scale))
    }

    /// Train (or load cached) one per-kernel model of the given flavor;
    /// trained on the *seen*-GPU split only.
    pub fn model(&self, kind: KernelKind, flavor: ModelFlavor) -> Result<Predictor> {
        let path = self.model_path(kind, flavor);
        if path.exists() {
            return Predictor::from_file(&self.engine, path.to_str().unwrap());
        }
        let ds = self.dataset(kind);
        let (seen, _) = dataset::split_seen(&ds);
        let (xs, ys): (Vec<[f32; 32]>, Vec<f64>) = match flavor {
            ModelFlavor::Neusight => (
                seen.iter().map(|s| s.x_alt).collect(),
                seen.iter()
                    .map(|s| (s.alt_theory_sec / s.latency_sec).clamp(0.002, 0.995))
                    .collect(),
            ),
            _ => (
                seen.iter().map(|s| mask_features(&s.x, flavor)).collect(),
                seen.iter().map(|s| s.efficiency()).collect(),
            ),
        };
        let cfg = TrainConfig {
            max_steps: self.scale.train_steps(),
            val_every: (self.scale.train_steps() / 12).max(50),
            patience: 4,
            tau: if flavor == ModelFlavor::P80 { Some(0.8) } else { None },
            seed: self.seed ^ kind.name().len() as u64,
            verbose: false,
        };
        eprintln!("[lab] training {} ({})...", kind.name(), flavor.tag());
        let model = train_model(&self.engine, &xs, &ys, &cfg)?;
        crate::mlp::weights::save(&model.weights, &path)?;
        Predictor::new(&self.engine, model.weights)
    }

    /// Linear baseline fitted on the seen split (closed form, not cached).
    pub fn linear(&self, kind: KernelKind) -> LinearModel {
        let ds = self.dataset(kind);
        let (seen, _) = dataset::split_seen(&ds);
        LinearModel::fit(&seen)
    }

    /// Scenario-API simulator backed by this lab's trained model set and
    /// comm seed — the entry point E2E experiments and the CLI use.
    /// Cached: every caller shares one instance (and its per-GPU comm
    /// models).
    pub fn simulator(&self) -> Result<std::rc::Rc<crate::scenario::Simulator>> {
        if let Some(sim) = self.simulator.borrow().as_ref() {
            return Ok(sim.clone());
        }
        let sim = std::rc::Rc::new(crate::scenario::Simulator::with_comm_seed(
            self.model_set()?,
            self.seed,
        ));
        *self.simulator.borrow_mut() = Some(sim.clone());
        Ok(sim)
    }

    /// Full model set for E2E evaluation over the trace kernel categories.
    pub fn model_set(&self) -> Result<ModelSet> {
        let kinds = [
            KernelKind::Gemm,
            KernelKind::Attention,
            KernelKind::RmsNorm,
            KernelKind::SiluMul,
        ];
        let mut synperf = HashMap::new();
        let mut neusight = HashMap::new();
        let mut linear = HashMap::new();
        for kind in kinds {
            synperf.insert(kind, self.model(kind, ModelFlavor::SynPerf)?);
            neusight.insert(kind, self.model(kind, ModelFlavor::Neusight)?);
            linear.insert(kind, self.linear(kind));
        }
        Ok(ModelSet { synperf, neusight, linear })
    }

    /// Best-effort protocol-v1 model bundle for serving: mean models are
    /// loaded or trained per category; p80 ceiling models are only picked
    /// up when already cached on disk (serve startup never blocks on extra
    /// trainings for a flavor nobody may request). Missing categories
    /// answer in degraded roofline mode, visible in response provenance.
    pub fn bundle(&self, kinds: &[KernelKind]) -> crate::api::ModelBundle {
        let mut b = crate::api::ModelBundle::default();
        for &kind in kinds {
            if let Ok(p) = self.model(kind, ModelFlavor::SynPerf) {
                b.mean.insert(kind, p);
            }
            if self.model_path(kind, ModelFlavor::P80).exists() {
                if let Ok(p) = self.model(kind, ModelFlavor::P80) {
                    b.p80.insert(kind, p);
                }
            }
        }
        b
    }

    /// Append a rendered experiment block to runs/results.txt.
    pub fn record(&self, block: &str) {
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.root.join("results.txt"))
        {
            let _ = writeln!(f, "{block}");
        }
    }
}

/// Run one experiment by id; returns the rendered output.
pub fn run(lab: &Lab, id: &str) -> Result<String> {
    let out = match id {
        "table1" => table1::run(lab)?,
        "table7" => table7::run(lab)?,
        "fig3" => fig3::run(lab)?,
        "fig4" => fig4::run(lab)?,
        "fig5" | "table8" => fig5_table8::run(lab)?,
        "scaledmm" => scaledmm::run(lab)?,
        "fig6" => fig6::run(lab)?,
        "fig7" => fig7::run(lab)?,
        "table9" => table9::run(lab)?,
        "fig8" | "fig9" | "table10" => fig8_table10::run(lab)?,
        "all" => {
            let mut all = String::new();
            for id in [
                "table1", "table7", "fig3", "fig4", "fig5", "scaledmm", "fig7", "fig6",
                "table9", "fig8",
            ] {
                all.push_str(&run(lab, id)?);
                all.push('\n');
            }
            all
        }
        other => anyhow::bail!("unknown experiment {other:?} (see DESIGN.md §5)"),
    };
    lab.record(&out);
    Ok(out)
}
