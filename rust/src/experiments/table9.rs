//! Table IX: E2E prediction MAPE (%) for multi-GPU inference — two serving
//! frameworks, three models, TP=2/4/8 and TP=4&PP=2, arxiv and splitwise
//! workloads, across the paper's 20 configurations — each point one
//! declarative Scenario-API simulation.

use super::Lab;
use crate::e2e::workload::WorkloadKind;
use crate::scenario::{ScenarioSpec, WorkloadSpec};
use crate::util::stats::{mape, mean};
use crate::util::table::{f, Table};
use anyhow::Result;

struct Config {
    framework: &'static str,
    model: &'static str,
    tp: u32,
    pp: u32,
    dataset: WorkloadKind,
    batch: usize,
    hardware: &'static [&'static str],
}

pub fn run(lab: &Lab) -> Result<String> {
    use WorkloadKind::{Arxiv, Splitwise};
    let configs = [
        Config { framework: "SGLang", model: "Qwen3-32B", tp: 2, pp: 1, dataset: Arxiv, batch: 12, hardware: &["A100", "RTX 6000 Ada", "H100", "RTX PRO 6000 S"] },
        Config { framework: "SGLang", model: "Qwen3-32B", tp: 2, pp: 1, dataset: Splitwise, batch: 48, hardware: &["A100", "RTX 6000 Ada", "H100", "RTX PRO 6000 S"] },
        Config { framework: "SGLang", model: "Llama3.1-70B", tp: 4, pp: 1, dataset: Arxiv, batch: 16, hardware: &["A100", "H100"] },
        Config { framework: "SGLang", model: "Llama3.1-70B", tp: 4, pp: 1, dataset: Splitwise, batch: 64, hardware: &["A100", "H100"] },
        Config { framework: "SGLang", model: "Llama3.1-70B", tp: 8, pp: 1, dataset: Arxiv, batch: 16, hardware: &["H20", "H800"] },
        Config { framework: "SGLang", model: "Llama3.1-70B", tp: 8, pp: 1, dataset: Splitwise, batch: 64, hardware: &["H20", "H800"] },
        Config { framework: "vLLM", model: "Llama3.1-70B", tp: 4, pp: 2, dataset: Arxiv, batch: 16, hardware: &["H20", "H800"] },
        Config { framework: "vLLM", model: "Llama3.1-70B", tp: 4, pp: 2, dataset: Splitwise, batch: 64, hardware: &["H20", "H800"] },
    ];

    let sim = lab.simulator()?;
    let n_batches = if lab.scale == super::Scale::Fast { 2 } else { 3 };
    let mut t = Table::new(
        "Table IX — E2E MAPE (%), multi-GPU inference",
        &["Framework", "Model", "Dataset", "HW", "Roofline", "Linear", "Habitat", "Neusight", "SynPerf"],
    );
    let mut syn_all = Vec::new();
    let mut neu_all = Vec::new();
    let mut tested = 0usize;

    for c in &configs {
        for hw in c.hardware {
            let mut actuals = Vec::new();
            let mut acc: [Vec<f64>; 5] = Default::default();
            for b in 0..n_batches {
                let spec = ScenarioSpec::new(c.model, *hw)
                    .tp(c.tp)
                    .pp(c.pp)
                    .workload(WorkloadSpec::Sampled { kind: c.dataset, batch: c.batch })
                    .seed(lab.seed + (tested * 100 + b) as u64);
                let totals = sim.simulate(&spec)?.totals;
                actuals.push(totals.actual);
                acc[0].push(totals.roofline);
                acc[1].push(totals.linear);
                acc[2].push(totals.habitat);
                acc[3].push(totals.neusight);
                acc[4].push(totals.synperf);
            }
            let m: Vec<f64> = acc.iter().map(|p| mape(p, &actuals)).collect();
            syn_all.push(m[4]);
            neu_all.push(m[3]);
            tested += 1;
            t.row(vec![
                c.framework.into(),
                format!("{} (TP={}{})", c.model, c.tp, if c.pp > 1 { format!(",PP={}", c.pp) } else { String::new() }),
                format!("{}_{}", c.dataset.name(), c.batch),
                hw.to_string(),
                f(m[0], 1),
                f(m[1], 1),
                f(m[2], 1),
                f(m[3], 1),
                f(m[4], 1),
            ]);
        }
    }
    let mut block = t.render();
    let summary = format!(
        "{} configs: SynPerf overall avg {:.1}% vs Neusight {:.1}%\n",
        tested,
        mean(&syn_all),
        mean(&neu_all)
    );
    block.push_str(&summary);
    print!("{block}");
    assert_eq!(tested, 20, "the paper evaluates 20 configurations");
    assert!(mean(&syn_all) < mean(&neu_all));
    Ok(block)
}
