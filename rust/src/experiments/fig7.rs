//! Fig. 7: simulation overhead vs prediction error on standalone GEMMs
//! (A100) — SynPerf vs the detailed comparators (AMALI-style instruction
//! trace model, LLMCompass-style systolic tile simulator).

use super::{Lab, ModelFlavor};
use crate::baselines::{amali, llmcompass};
use crate::dataset::{make_sample, sample_configs};
use crate::hw::gpu_by_name;
use crate::kernels::{KernelConfig, KernelKind};
use crate::util::stats::{mean, signed_rel_err};
use crate::util::table::{f, Table};
use anyhow::Result;
use std::time::Instant;

pub fn run(lab: &Lab) -> Result<String> {
    let gpu = gpu_by_name("A100").unwrap();
    let n = match lab.scale {
        super::Scale::Fast => 60,
        super::Scale::Normal => 200,
        super::Scale::Full => 540, // the paper's count
    };
    let configs = sample_configs(KernelKind::Gemm, n, lab.seed ^ 0xF16);
    let model = lab.model(KernelKind::Gemm, ModelFlavor::SynPerf)?;

    let mut syn_err = Vec::new();
    let mut amali_err = Vec::new();
    let mut llmc_err = Vec::new();
    let (mut syn_t, mut amali_t, mut llmc_t) = (0.0f64, 0.0f64, 0.0f64);

    for (i, cfg) in configs.iter().enumerate() {
        let s = make_sample(cfg, &gpu, lab.seed + 7000 + i as u64);
        let actual = s.latency_sec;
        let KernelConfig::Gemm { m, n, k, .. } = *cfg else { unreachable!() };

        // SynPerf: full request path (decompose -> schedule -> features ->
        // MLP b1 via PJRT)
        let t0 = Instant::now();
        let eff = model.predict_eff(&[s.x])?[0];
        let syn_pred = s.theory_sec / eff;
        syn_t += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (a_pred, _) = amali::predict_gemm(m, n, k, &gpu);
        amali_t += t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let (l_pred, _) = llmcompass::predict_gemm(m, n, k, &gpu);
        llmc_t += t0.elapsed().as_secs_f64();

        syn_err.push(signed_rel_err(syn_pred, actual));
        amali_err.push(signed_rel_err(a_pred, actual));
        llmc_err.push(signed_rel_err(l_pred, actual));
    }

    let nf = configs.len() as f64;
    let mapes = |errs: &[f64]| mean(&errs.iter().map(|e| e.abs()).collect::<Vec<_>>());
    let mut t = Table::new(
        &format!("Fig. 7 — overhead vs error, {n} GEMMs on A100"),
        &["Method", "MAPE (%)", "mean signed err (%)", "per-GEMM time"],
    );
    t.row(vec![
        "SynPerf".into(),
        f(mapes(&syn_err), 1),
        f(mean(&syn_err), 1),
        format!("{:.1} us", syn_t / nf * 1e6),
    ]);
    t.row(vec![
        "AMALI".into(),
        f(mapes(&amali_err), 1),
        f(mean(&amali_err), 1),
        format!("{:.1} us", amali_t / nf * 1e6),
    ]);
    t.row(vec![
        "LLMCompass".into(),
        f(mapes(&llmc_err), 1),
        f(mean(&llmc_err), 1),
        format!("{:.1} us", llmc_t / nf * 1e6),
    ]);
    let block = t.render();
    print!("{block}");

    // paper shape: SynPerf more accurate AND cheaper than both comparators
    assert!(mapes(&syn_err) < mapes(&amali_err));
    assert!(mapes(&syn_err) < mapes(&llmc_err));
    assert!(syn_t < llmc_t, "SynPerf should be cheaper than the tile simulator");
    Ok(block)
}
