//! Fig. 4: ablation study — full model vs w/o MIO features, w/o Math
//! features, and w/o MLP (Roofline-style predictor) on the GEMM and
//! Attention kernels. Reported as MAPE and as the paper's accuracy ratios
//! (ablated error / full error).

use super::{mask_features, Lab, ModelFlavor};
use crate::dataset::Sample;
use crate::kernels::KernelKind;
use crate::util::stats::mape;
use crate::util::table::{f, Table};
use anyhow::Result;

fn eval(lab: &Lab, kind: KernelKind, flavor: ModelFlavor, ds: &[Sample]) -> Result<f64> {
    let pred = lab.model(kind, flavor)?;
    let xs: Vec<[f32; 32]> = ds.iter().map(|s| mask_features(&s.x, flavor)).collect();
    let eff = pred.predict_eff(&xs)?;
    let lat: Vec<f64> = ds.iter().zip(eff).map(|(s, e)| s.theory_sec / e).collect();
    let actual: Vec<f64> = ds.iter().map(|s| s.latency_sec).collect();
    Ok(mape(&lat, &actual))
}

pub fn run(lab: &Lab) -> Result<String> {
    let mut t = Table::new(
        "Fig. 4 — ablation (MAPE %, ratio vs full)",
        &["Kernel", "Full", "w/o MIO", "w/o Math", "w/o MLP (roofline)"],
    );
    let mut out_block = String::new();
    for kind in [KernelKind::Gemm, KernelKind::Attention] {
        let ds = lab.dataset(kind);
        let full = eval(lab, kind, ModelFlavor::SynPerf, &ds)?;
        let no_mio = eval(lab, kind, ModelFlavor::NoMio, &ds)?;
        let no_math = eval(lab, kind, ModelFlavor::NoMath, &ds)?;
        let roof: Vec<f64> = ds.iter().map(|s| s.roofline_sec).collect();
        let actual: Vec<f64> = ds.iter().map(|s| s.latency_sec).collect();
        let no_mlp = mape(&roof, &actual);
        t.row(vec![
            kind.name().into(),
            format!("{}", f(full, 1)),
            format!("{} ({}x)", f(no_mio, 1), f(no_mio / full, 1)),
            format!("{} ({}x)", f(no_math, 1), f(no_math / full, 1)),
            format!("{} ({}x)", f(no_mlp, 1), f(no_mlp / full, 1)),
        ]);
        // every component must contribute (ablations strictly worse)
        assert!(no_mlp > full, "{kind:?}: removing the MLP should hurt");
        out_block.push_str(&format!(
            "# {}: full={full:.1} no_mio={no_mio:.1} no_math={no_math:.1} no_mlp={no_mlp:.1}\n",
            kind.name()
        ));
    }
    let block = t.render();
    print!("{block}");
    Ok(format!("{block}{out_block}"))
}
