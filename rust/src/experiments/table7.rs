//! Table VII: MAPE of the analytical operation counts (total and per-SM
//! maximum) against the oracle's NCU-style counters, for the four validated
//! kernel implementations: gemm8 (A100, HW-scheduled), gemm9 (H100,
//! persistent), FA2 (A100), FA3 (H100).
//!
//! The model-side counters come from the protocol-v1 breakdown
//! ([`crate::api::Breakdown`] per-pipe `total_ops` / `max_sm_ops`), so this
//! experiment validates exactly what the serving surface reports.

use super::Lab;
use crate::api::{self, ModelBundle, PredictRequest};
use crate::dataset::{finalize_for_gpu, sample_configs};
use crate::hw::gpu_by_name;
use crate::kernels::KernelKind;
use crate::oracle;
use crate::util::table::{f, Table};
use anyhow::Result;

fn validate(kind: KernelKind, gpu_name: &str, n: usize, seed: u64) -> Result<(f64, f64)> {
    let bundle = ModelBundle::default();
    let gpu = gpu_by_name(gpu_name).unwrap();
    let configs = sample_configs(kind, n, seed);
    let (mut max_err, mut tot_err) = (0.0, 0.0);
    let mut count = 0usize;
    for (i, cfg) in configs.iter().enumerate() {
        let cfg = finalize_for_gpu(cfg, &gpu);
        let resp = api::predict_one(
            &bundle,
            &PredictRequest::new(cfg.clone(), gpu.clone()).with_breakdown(),
        )?;
        let b = resp.breakdown.expect("breakdown requested");
        let o = oracle::measure(&cfg, &gpu, seed + i as u64);
        // attention also exercises non-tensor pipes, but Table VII compares
        // the dominant math pipe counters
        let (model_max, model_tot, oracle_max, oracle_tot) = if o.total_tensor_ops > 0.0 {
            (b.tensor.max_sm_ops, b.tensor.total_ops, o.max_sm_tensor_ops, o.total_tensor_ops)
        } else {
            (b.fma.max_sm_ops, b.fma.total_ops, o.max_sm_fma_ops, o.total_fma_ops)
        };
        if oracle_tot <= 0.0 {
            continue;
        }
        max_err += ((model_max - oracle_max) / oracle_max).abs();
        tot_err += ((model_tot - oracle_tot) / oracle_tot).abs();
        count += 1;
    }
    Ok((100.0 * max_err / count as f64, 100.0 * tot_err / count as f64))
}

pub fn run(lab: &Lab) -> Result<String> {
    let n = match lab.scale {
        super::Scale::Fast => 60,
        super::Scale::Normal => 200,
        super::Scale::Full => 500,
    };
    let (g8_max, g8_tot) = validate(KernelKind::Gemm, "A100", n, lab.seed)?;
    let (g9_max, g9_tot) = validate(KernelKind::Gemm, "H100", n, lab.seed ^ 1)?;
    let (fa2_max, fa2_tot) = validate(KernelKind::Attention, "A100", n, lab.seed ^ 2)?;
    let (fa3_max, fa3_tot) = validate(KernelKind::Attention, "H100", n, lab.seed ^ 3)?;

    let mut t = Table::new(
        "Table VII — MAPE (%) of analytical operation counts",
        &["Metric", "gemm8", "gemm9", "FA2", "FA3"],
    );
    t.row(vec![
        "Max SM Ops (%)".into(),
        f(g8_max, 2),
        f(g9_max, 2),
        f(fa2_max, 2),
        f(fa3_max, 2),
    ]);
    t.row(vec![
        "Total Ops (%)".into(),
        f(g8_tot, 2),
        f(g9_tot, 2),
        f(fa2_tot, 2),
        f(fa3_tot, 2),
    ]);
    let out = t.render();
    print!("{out}");

    // paper-shape sanity: FA2's dynamic HW scheduling makes its max-SM error
    // the largest; persistent/deterministic kernels stay near zero
    assert!(fa2_max > fa3_max, "FA2 max-SM error should exceed FA3");
    Ok(out)
}
