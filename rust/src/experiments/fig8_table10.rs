//! §VII — Beyond simulation (Fig. 8, Fig. 9, Table X): P80 performance-
//! ceiling diagnosis of the Fused-MoE kernel, underperforming-point counts
//! per GPU, brute-force tuning of the diagnosed points, gap closure, and
//! the speedup-vs-counts correlation.

use super::{Lab, ModelFlavor};
use crate::autotune::{self, GAP_THRESHOLD};
use crate::dataset;
use crate::hw::{gpu_by_name, seen_gpus};
use crate::kernels::KernelKind;
use crate::util::stats::{geomean, mean, pearson, percentile};
use crate::util::table::{f, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    let mut out = String::new();
    let ds = lab.dataset(KernelKind::FusedMoe);
    let configs = lab.dataset_configs(KernelKind::FusedMoe);
    let p80 = lab.model(KernelKind::FusedMoe, ModelFlavor::P80)?;
    let records = autotune::diagnose(&p80, &ds)?;

    // ---- Fig. 8: gap CDF + underperforming counts per GPU ---------------
    let gaps: Vec<f64> = records.iter().map(|r| r.gap).collect();
    let frac_below_thr =
        gaps.iter().filter(|g| **g < GAP_THRESHOLD).count() as f64 / gaps.len() as f64;
    let mut t = Table::new(
        "Fig. 8 — performance-gap distribution (Fused MoE, P80 ceiling)",
        &["stat", "value"],
    );
    for (q, label) in [(50.0, "P50 gap"), (80.0, "P80 gap"), (95.0, "P95 gap")] {
        t.row(vec![label.into(), f(percentile(&gaps, q), 3)]);
    }
    t.row(vec!["frac(gap < 0.1)".into(), f(frac_below_thr, 3)]);
    let block = t.render();
    print!("{block}");
    out.push_str(&block);

    let mut t = Table::new(
        "Fig. 8 — Underperforming Points (gap > 0.1) by hardware",
        &["GPU", "count", "share of GPU samples"],
    );
    let mut counts = std::collections::BTreeMap::new();
    for gpu in seen_gpus() {
        let total = records.iter().filter(|r| r.gpu == gpu.name).count();
        let n = records
            .iter()
            .filter(|r| r.gpu == gpu.name && r.underperforming())
            .count();
        counts.insert(gpu.name.to_string(), n);
        t.row(vec![
            gpu.name.to_string(),
            n.to_string(),
            f(100.0 * n as f64 / total.max(1) as f64, 1),
        ]);
    }
    let block = t.render();
    print!("{block}");
    out.push_str(&block);

    // the long-tail shape + hardware specificity of the paper: the default
    // config is Hopper-tuned, so pre-Hopper parts carry the bulk of the
    // underperforming points (the per-GPU ordering within each group is
    // scale/noise sensitive — see EXPERIMENTS.md)
    assert!(frac_below_thr > 0.5, "most points should be near their ceiling");
    let pre_hopper: usize = ["A40", "A100", "RTX 6000 Ada", "L20"]
        .iter()
        .filter_map(|g| counts.get(*g))
        .sum();
    let hopper: usize =
        ["H20", "H800"].iter().filter_map(|g| counts.get(*g)).sum();
    assert!(
        pre_hopper > hopper,
        "pre-Hopper parts should dominate underperforming counts: {pre_hopper} vs {hopper}"
    );

    // ---- Table X + Fig. 9: tune diagnosed points ------------------------
    let per_gpu = match lab.scale {
        super::Scale::Fast => 12,
        super::Scale::Normal => 30,
        super::Scale::Full => 70, // the paper's ~70 per GPU
    };
    let n_gpus = 11usize;
    let mut t10 = Table::new(
        "Table X — speedup vs underperforming points",
        &["GPU", "Underperf. points", "tuned configs", "geo-mean speedup"],
    );
    let mut fig9 = Table::new(
        "Fig. 9 — perf gap before/after model-guided tuning",
        &["GPU", "avg gap before", "avg gap after"],
    );
    let mut xs_counts = Vec::new();
    let mut ys_speedups = Vec::new();
    for gpu_name in ["A40", "L20", "A100", "H800"] {
        let gpu = gpu_by_name(gpu_name).unwrap();
        // indices of this GPU's underperforming samples (dataset layout is
        // config-major x GPUs)
        let under: Vec<usize> = records
            .iter()
            .enumerate()
            .filter(|(i, r)| r.gpu == gpu.name && r.underperforming() && *i / n_gpus < configs.len())
            .map(|(i, _)| i)
            .take(per_gpu)
            .collect();
        let mut speedups = Vec::new();
        let mut gap_before = Vec::new();
        let mut gap_after = Vec::new();
        for &si in &under {
            let cfg_idx = si / n_gpus;
            let cfg = dataset::finalize_for_gpu(&configs[cfg_idx], &gpu);
            let res = autotune::tune(&cfg, &gpu, lab.seed + si as u64)?;
            speedups.push(res.speedup());
            let s = &ds[si];
            let rec = &records[si];
            gap_before.push(rec.gap);
            let eff_after = (s.theory_sec / (s.latency_sec / res.speedup())).clamp(0.002, 0.995);
            gap_after.push((rec.ceiling_eff - eff_after).max(0.0));
        }
        let count = counts.get(gpu_name).copied().unwrap_or(0);
        let gm = if speedups.is_empty() { 1.0 } else { geomean(&speedups) };
        xs_counts.push(count as f64);
        ys_speedups.push(gm);
        t10.row(vec![
            gpu_name.into(),
            count.to_string(),
            speedups.len().to_string(),
            format!("{}x", f(gm, 2)),
        ]);
        fig9.row(vec![gpu_name.into(), f(mean(&gap_before), 3), f(mean(&gap_after), 3)]);
        if !gap_before.is_empty() {
            assert!(
                mean(&gap_after) < mean(&gap_before),
                "{gpu_name}: tuning must close the gap"
            );
        }
    }
    let corr = pearson(&xs_counts, &ys_speedups);
    let mut block = t10.render();
    block.push_str(&format!("Pearson(counts, speedups) = {corr:.2}\n"));
    block.push_str(&fig9.render());
    print!("{block}");
    out.push_str(&block);
    assert!(corr > 0.0, "speedups should correlate with diagnosed counts: {corr}");
    Ok(out)
}
