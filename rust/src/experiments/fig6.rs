//! Fig. 6: end-to-end single-GPU (TP=1) inference prediction accuracy for
//! Qwen2.5-14B across all 11 GPUs, five methods — one Scenario-API
//! simulation per (GPU, batch) point.

use super::Lab;
use crate::e2e::workload::WorkloadKind;
use crate::hw::all_gpus;
use crate::scenario::{ScenarioSpec, WorkloadSpec};
use crate::util::stats::{mape, mean};
use crate::util::table::{f, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    let sim = lab.simulator()?;
    let n_batches = if lab.scale == super::Scale::Fast { 2 } else { 4 };

    let mut t = Table::new(
        "Fig. 6 — E2E MAPE (%), Qwen2.5-14B single-GPU (TP=1)",
        &["GPU", "Roofline", "Linear", "Habitat", "Neusight", "SynPerf"],
    );
    let mut seen_syn = Vec::new();
    let mut unseen_syn = Vec::new();
    let mut seen_neu = Vec::new();
    let mut unseen_neu = Vec::new();
    let mut out = String::new();

    for gpu in all_gpus() {
        let mut acc: [Vec<f64>; 5] = Default::default();
        let mut actuals = Vec::new();
        for b in 0..n_batches {
            let kind = if b % 2 == 0 { WorkloadKind::Arxiv } else { WorkloadKind::Splitwise };
            let bs = [8usize, 16][b % 2];
            let spec = ScenarioSpec::new("Qwen2.5-14B", gpu.name)
                .workload(WorkloadSpec::Sampled { kind, batch: bs })
                .seed(lab.seed ^ (gpu.num_sms as u64) ^ (b as u64 * 977));
            let totals = sim.simulate(&spec)?.totals;
            actuals.push(totals.actual);
            acc[0].push(totals.roofline);
            acc[1].push(totals.linear);
            acc[2].push(totals.habitat);
            acc[3].push(totals.neusight);
            acc[4].push(totals.synperf);
        }
        let m: Vec<f64> = acc.iter().map(|p| mape(p, &actuals)).collect();
        if gpu.seen {
            seen_syn.push(m[4]);
            seen_neu.push(m[3]);
        } else {
            unseen_syn.push(m[4]);
            unseen_neu.push(m[3]);
        }
        let tag = if gpu.seen { "" } else { " (unseen)" };
        t.row(vec![
            format!("{}{}", gpu.name, tag),
            f(m[0], 1),
            f(m[1], 1),
            f(m[2], 1),
            f(m[3], 1),
            f(m[4], 1),
        ]);
    }
    let block = t.render();
    print!("{block}");
    out.push_str(&block);
    let summary = format!(
        "E2E avg: SynPerf seen {:.1}% / unseen {:.1}%; Neusight seen {:.1}% / unseen {:.1}%\n",
        mean(&seen_syn),
        mean(&unseen_syn),
        mean(&seen_neu),
        mean(&unseen_neu)
    );
    print!("{summary}");
    out.push_str(&summary);
    assert!(mean(&seen_syn) < mean(&seen_neu), "SynPerf must beat Neusight E2E (seen)");
    Ok(out)
}
