//! Table I: runtime breakdown of Qwen2.5-32B inference on a 4xA100 cluster
//! with TP=4 (batch 8, sequence length 8192), per phase — a Scenario-API
//! simulation read through the typed per-phase [`OpClass`] breakdown.

use super::Lab;
use crate::e2e::predict::ModelSet;
use crate::e2e::workload::Request;
use crate::scenario::{OpClass, Phase, ScenarioSpec, Simulator, WorkloadSpec};
use crate::util::table::{pct, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    // batch 8, sequence 8192: 7k prompt + 1k generated
    let reqs: Vec<Request> =
        (0..8).map(|_| Request { input_len: 7168, output_len: 1024 }).collect();
    let spec = ScenarioSpec::new("Qwen2.5-32B", "A100")
        .tp(4)
        .workload(WorkloadSpec::Explicit(reqs))
        .seed(lab.seed);
    // the breakdown is computed purely from oracle ground truth, so no
    // trained model set is needed — a degraded simulator is bit-identical
    // here and avoids lab.simulator()'s dataset/MLP-training work. The
    // throwaway comm-RF fit this pays is sub-second; reusing the lab
    // simulator to save it would cost the full model set.
    let report = Simulator::with_comm_seed(ModelSet::default(), lab.seed).simulate(&spec)?;

    let mut t = Table::new(
        "Table I — Qwen2.5-32B on 4xA100 (TP=4): runtime breakdown",
        &["Phase", "GEMM", "Attention", "RMSNorm", "SiLU&Mul", "All-Reduce", "Other"],
    );
    for ph in &report.phases {
        let label = match ph.phase {
            Phase::Prefill => "Prefill",
            Phase::Decode => "Decode",
        };
        let mut cells = vec![label.to_string()];
        for class in [OpClass::Gemm, OpClass::Attention, OpClass::RmsNorm, OpClass::SiluMul, OpClass::AllReduce]
        {
            cells.push(pct(ph.breakdown.share_pct(class)));
        }
        // "Other" = host launch gaps + PP send/recv (+ any MoE share)
        cells.push(pct(ph.breakdown.share_pct(OpClass::HostGap)
            + ph.breakdown.share_pct(OpClass::SendRecv)
            + ph.breakdown.share_pct(OpClass::FusedMoe)));
        t.row(cells);
    }
    let out = t.render();
    print!("{out}");
    Ok(out)
}
