//! Table I: runtime breakdown of Qwen2.5-32B inference on a 4xA100 cluster
//! with TP=4 (batch 8, sequence length 8192), per phase.

use super::Lab;
use crate::e2e::{llm, predict, trace, workload::Request};
use crate::hw::gpu_by_name;
use crate::util::table::{pct, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    let gpu = gpu_by_name("A100").unwrap();
    let model = llm::qwen2_5_32b();
    // batch 8, sequence 8192: 7k prompt + 1k generated
    let reqs: Vec<Request> =
        (0..8).map(|_| Request { input_len: 7168, output_len: 1024 }).collect();
    let (prefill, decode) = trace::build_phase_traces(&model, 4, 1, &reqs);

    let categories = ["GEMM", "Attention", "RMSNorm", "SiLU&Mul", "All-Reduce", "Other"];
    let mut t = Table::new(
        "Table I — Qwen2.5-32B on 4xA100 (TP=4): runtime breakdown",
        &["Phase", "GEMM", "Attention", "RMSNorm", "SiLU&Mul", "All-Reduce", "Other"],
    );
    for (phase, tr) in [("Prefill", &prefill), ("Decode", &decode)] {
        let rows = predict::breakdown(tr, &gpu, 4, lab.seed);
        let get = |name: &str| {
            rows.iter().find(|(k, _)| k == name).map(|(_, v)| *v).unwrap_or(0.0)
        };
        let mut cells = vec![phase.to_string()];
        for c in categories {
            cells.push(pct(get(c)));
        }
        t.row(cells);
    }
    let out = t.render();
    print!("{out}");
    Ok(out)
}
