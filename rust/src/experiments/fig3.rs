//! Fig. 3: multi-dimensional saturation analysis for FlashAttention-2 on
//! the A100 — execution efficiency (theoretical cycles / measured latency)
//! vs absolute pipeline demand, per pipeline, for two configurations. As
//! demand grows, measured performance approaches each pipeline's "roof" and
//! plateaus.
//!
//! Pipeline demands come from the protocol-v1 request path: a breakdown-
//! carrying [`crate::api::PredictResponse`] (`with_breakdown`), not a raw
//! engine peek. Ground-truth efficiency still comes from the oracle.

use super::Lab;
use crate::api::{self, ModelBundle, PredictRequest};
use crate::engine::PredictionEngine;
use crate::hw::gpu_by_name;
use crate::kernels::KernelConfig;
use crate::util::table::{f, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    let engine = PredictionEngine::global();
    let gpu = gpu_by_name("A100").unwrap();
    let mut out = String::new();
    for (label, nh, hd) in [("cfg-A nh=8 hd=128", 8u32, 128u32), ("cfg-B nh=32 hd=64", 32, 64)] {
        let mut t = Table::new(
            &format!("Fig. 3 — FA2/A100 saturation, {label}"),
            &["kv_len", "tensor demand (Gops)", "mem demand (MB)", "efficiency"],
        );
        let mut effs = Vec::new();
        for kv_exp in 7..=14u32 {
            let kv = 1u32 << kv_exp;
            let cfg = KernelConfig::Attention {
                batch: vec![(kv, kv); 4],
                nh,
                nkv: nh / 4,
                hd,
                causal: false,
                fa3: false,
            };
            let resp = api::predict_one(
                &ModelBundle::default(),
                &PredictRequest::new(cfg.clone(), gpu.clone()).with_breakdown(),
            )?;
            let b = resp.breakdown.expect("breakdown requested");
            let s = engine.make_sample(&cfg, &gpu, lab.seed + kv as u64);
            let eff = s.theory_sec / s.latency_sec;
            effs.push(eff);
            t.row(vec![
                kv.to_string(),
                f(b.tensor.total_ops / 1e9, 2),
                f(b.mio_bytes / 1e6, 1),
                f(eff, 3),
            ]);
        }
        // the saturation shape: efficiency rises with demand then plateaus
        assert!(
            effs.last().unwrap() > &(effs[0] * 1.5),
            "efficiency should rise towards the roof: {effs:?}"
        );
        let block = t.render();
        print!("{block}");
        out.push_str(&block);
    }
    Ok(out)
}
