//! FP8 Scaled-MM results (§VI-C text): per-GPU MAPE on the Hopper-class
//! devices — seen (H20, H800) and unseen (H100, H200) — plus accuracy gains
//! over the four baselines.

use super::{fig5_table8::method_mapes, Lab};
use crate::dataset::Sample;
use crate::kernels::KernelKind;
use crate::util::table::{f, Table};
use anyhow::Result;

pub fn run(lab: &Lab) -> Result<String> {
    let ds = lab.dataset(KernelKind::ScaledMm);
    let mut t = Table::new(
        "FP8 Scaled-MM — MAPE (%) per Hopper GPU (§VI-C)",
        &["GPU", "Roofline", "Linear", "Habitat", "Neusight", "SynPerf"],
    );
    let mut gains = [0.0f64; 4];
    let mut rows = 0usize;
    for (gpu, seen) in [("H20", true), ("H800", true), ("H100", false), ("H200", false)] {
        let subset: Vec<&Sample> = ds.iter().filter(|s| s.gpu == gpu).collect();
        if subset.is_empty() {
            continue;
        }
        let m = method_mapes(lab, KernelKind::ScaledMm, &subset)?;
        for i in 0..4 {
            gains[i] += m[i] / m[4];
        }
        rows += 1;
        let tag = if seen { "" } else { " (unseen)" };
        t.row(vec![
            format!("{gpu}{tag}"),
            f(m[0], 1),
            f(m[1], 1),
            f(m[2], 1),
            f(m[3], 1),
            f(m[4], 1),
        ]);
    }
    let mut block = t.render();
    block.push_str(&format!(
        "avg accuracy gains vs Roofline {:.1}x, Linear {:.1}x, Habitat {:.1}x, Neusight {:.1}x\n",
        gains[0] / rows as f64,
        gains[1] / rows as f64,
        gains[2] / rows as f64,
        gains[3] / rows as f64
    ));
    print!("{block}");
    Ok(block)
}
