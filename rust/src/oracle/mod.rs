//! Ground-truth oracle — the synthetic testbed standing in for the paper's
//! 11 physical GPUs (DESIGN.md §2, §6).
//!
//! The oracle produces "measured" kernel latencies (and NCU-style per-SM
//! operation counters for Table VII) from a micro-architecture-inspired
//! execution model that is deliberately *richer* than the analytical
//! Table-IV features:
//!
//!  * per-task execution combines pipeline friction (architecture +
//!    MXU-tile utilization + software-pipelining depth + warp mix),
//!    latency hiding from warp-level parallelism, and a memory path with an
//!    L2 reuse-capture model and chip-level bandwidth floors;
//!  * tasks are dispatched *dynamically* (earliest-finish, modeling the
//!    retire-driven GigaThread engine) with per-task jitter — persistent
//!    kernels instead follow their deterministic software schedulers;
//!  * kernel launch overhead and lognormal measurement noise round it out.
//!
//! The analytical features carry only totals, maxima and theoretical
//! cycles, so the residual between theory and oracle latency is a genuine
//! learning problem — the premise of the paper's hybrid design.

mod friction;

pub use friction::*;

use crate::hw::GpuSpec;
use crate::kernels::{Decomposition, KernelConfig, KernelKind, Paradigm, Task};
use crate::sched::minheap;
use crate::util::rng::Rng;

/// "Measurement" of one kernel launch on the synthetic testbed.
#[derive(Debug, Clone)]
pub struct OracleResult {
    /// Measured wall latency in seconds (including launch overhead + noise).
    pub latency_sec: f64,
    /// Latency before measurement noise — used by deterministic analyses.
    pub clean_sec: f64,
    /// NCU-style counters from the *dynamic* assignment: max per-SM ops on
    /// the dominant math pipe, and kernel-wide totals (Table VII).
    pub max_sm_tensor_ops: f64,
    pub max_sm_fma_ops: f64,
    pub total_tensor_ops: f64,
    pub total_fma_ops: f64,
}

/// Per-kernel-launch execution context shared by the per-task model.
struct ExecCtx<'a> {
    gpu: &'a GpuSpec,
    kind: KernelKind,
    occ: u32,
    /// Fraction of per-task loads that actually reach DRAM (post-L2).
    dram_frac: f64,
    /// Estimated concurrently active SMs (small grids get a bandwidth boost).
    active_sms: f64,
    stages: u32,
    tile: (u32, u32, u32),
    warps: u32,
}

/// Deterministic per-task execution time in cycles (§6 step 3-4).
fn task_cycles(t: &Task, cx: &ExecCtx) -> f64 {
    let g = cx.gpu;

    // --- math pipes ---------------------------------------------------
    let tensor_th = g.tensor_ops_clk_sm
        * tensor_friction(g, cx.kind, cx.tile, cx.stages, cx.warps);
    let tc = if t.tensor_ops > 0.0 { t.tensor_ops / tensor_th } else { 0.0 };
    let fc = if t.fma_ops > 0.0 { t.fma_ops / (g.fma_ops_clk_sm * FMA_FRICTION) } else { 0.0 };
    let xc = if t.xu_ops > 0.0 { t.xu_ops / (g.xu_ops_clk_sm * XU_FRICTION) } else { 0.0 };
    // pipes issue concurrently but share schedulers: max + partial residue
    let cmax = tc.max(fc).max(xc);
    let compute = cmax + PIPE_RESIDUE * (tc + fc + xc - cmax);

    // --- memory path ----------------------------------------------------
    let nsm = g.num_sms as f64;
    let boost = (nsm / cx.active_sms).clamp(1.0, 4.0);
    let dram_share = g.dram_bytes_per_cycle() / nsm * boost;
    let l2_share = g.l2_bytes_per_cycle() / nsm * boost;
    // Hopper/Blackwell tensor kernels multicast operand tiles (TMA +
    // thread-block clusters), halving effective L2 pull.
    let l2_discount = l2_multicast_discount(g, cx.kind);
    let dram_c = t.bytes_load * cx.dram_frac / dram_share;
    let l2_c = t.bytes_load * l2_discount / l2_share;
    let smem_c = t.bytes_smem / g.smem_bw_byte_clk_sm;
    let mem = dram_c.max(l2_c).max(smem_c);

    // --- overlap + latency hiding ---------------------------------------
    let ov = overlap_quality(cx.kind, cx.stages, g);
    // warp-level parallelism hides latency; independent CTAs hide it better
    // than warps within one CTA (no shared barriers), hence the occ exponent
    let wlp = cx.warps as f64 * (cx.occ as f64).powf(1.5);
    let hide = wlp / (wlp + 1.3);
    let busy = compute.max(mem) + (1.0 - ov) * compute.min(mem);
    busy / hide + TASK_PROLOGUE_CYCLES
}

/// L2 reuse capture (§6 step 4): how much of the excess (reuse) traffic the
/// L2 absorbs, as a function of the concurrent working set vs capacity.
/// `loads` is the kernel-wide sum of per-task `bytes_load` (the caller has
/// it already — avoids re-walking the task set).
fn l2_capture(decomp: &Decomposition, loads: f64, kind: KernelKind, gpu: &GpuSpec, occ: u32) -> f64 {
    if loads <= 0.0 {
        return 0.0;
    }
    let n_tasks = decomp.num_tasks();
    let active = (n_tasks as f64).min(gpu.num_sms as f64 * occ as f64);
    let (tm, tn, tk) = decomp.tile;
    let ws = match kind {
        // tile kernels: concurrently resident operand slabs, shared along
        // wave rows/columns (sqrt scaling)
        KernelKind::Gemm | KernelKind::ScaledMm | KernelKind::FusedMoe => {
            active.sqrt() * (tm + tn) as f64 * tk as f64
                * decomp.pipeline_stages as f64 * 2.0 * 2.0
        }
        // attention: resident K/V panels (shared across grouped query heads)
        KernelKind::Attention => {
            let per_task = loads / n_tasks as f64;
            active * per_task * 0.5
        }
        // streaming elementwise: no reuse to capture
        KernelKind::RmsNorm | KernelKind::SiluMul => return 0.3,
    };
    let cap = gpu.l2_mb * 1024.0 * 1024.0;
    (0.9 * cap / ws.max(1.0)).clamp(0.10, 0.92)
}

/// Measure one kernel launch. `seed` individualizes jitter + noise streams;
/// the same (config, gpu, seed) always reproduces the same measurement.
pub fn measure(cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> OracleResult {
    let decomp = cfg.decompose(gpu);
    measure_decomposed(cfg.kind(), &decomp, gpu, seed)
}

/// Measurement given an existing decomposition (lets the autotuner reuse
/// routing results while sweeping launch configs).
pub fn measure_decomposed(
    kind: KernelKind,
    decomp: &Decomposition,
    gpu: &GpuSpec,
    seed: u64,
) -> OracleResult {
    let mut rng = Rng::new(seed ^ 0x07AC1E5EED);
    let occ = decomp.cta.occupancy(gpu);
    let nsm = gpu.num_sms as usize;
    // The dynamic simulation is inherently per-task (jitter streams,
    // finish-time dispatch), so expand the run-length groups once here —
    // the launch-order expansion keeps every seeded stream bit-identical
    // to the pre-grouping task list.
    let tasks: Vec<&Task> = decomp.iter_tasks().collect();
    let n_tasks = tasks.len();

    // memory model ingredients
    let loads: f64 = tasks.iter().map(|t| t.bytes_load).sum();
    let stores: f64 = tasks.iter().map(|t| t.bytes_store).sum();
    let rho = l2_capture(decomp, loads, kind, gpu, occ);
    let excess = (loads - decomp.min_dram_bytes).max(0.0);
    let dram_total = (decomp.min_dram_bytes + (1.0 - rho) * excess).min(loads.max(decomp.min_dram_bytes));
    let dram_frac = if loads > 0.0 { dram_total / loads } else { 0.0 };

    let cx = ExecCtx {
        gpu,
        kind,
        occ,
        dram_frac,
        active_sms: (n_tasks as f64).min(nsm as f64),
        stages: decomp.pipeline_stages,
        tile: decomp.tile,
        warps: decomp.cta.warps,
    };

    // deterministic per-task durations + jitter
    let base: Vec<f64> = tasks.iter().map(|t| task_cycles(t, &cx)).collect();
    let jittered: Vec<f64> =
        base.iter().map(|c| c * rng.range_f64(1.0 - TASK_JITTER, 1.0 + TASK_JITTER)).collect();

    // dynamic / software scheduling (§6 step 2 & 5)
    let mut sm_finish = vec![0.0f64; nsm];
    let mut sm_tensor = vec![0.0f64; nsm];
    let mut sm_fma = vec![0.0f64; nsm];
    match decomp.paradigm {
        Paradigm::HardwareRR => {
            // earliest-finish dispatch (retire-driven GigaThread engine)
            let mut heap: std::collections::BinaryHeap<
                std::cmp::Reverse<(u64, usize)>,
            > = (0..nsm).map(|j| std::cmp::Reverse((0u64, j))).collect();
            for (i, &dur) in jittered.iter().enumerate() {
                let std::cmp::Reverse((t_bits, j)) = heap.pop().unwrap();
                let t = f64::from_bits(t_bits) + dur;
                sm_finish[j] = t;
                sm_tensor[j] += tasks[i].tensor_ops;
                sm_fma[j] += tasks[i].fma_ops;
                heap.push(std::cmp::Reverse((t.to_bits(), j)));
            }
        }
        Paradigm::PersistentTile => {
            // deterministic strided software tile scheduler
            let workers = nsm * occ.max(1) as usize;
            let mut worker_time = vec![0.0f64; workers];
            for (i, &dur) in jittered.iter().enumerate() {
                let w = i % workers;
                worker_time[w] += dur;
                let j = w % nsm;
                sm_tensor[j] += tasks[i].tensor_ops;
                sm_fma[j] += tasks[i].fma_ops;
            }
            for (w, &t) in worker_time.iter().enumerate() {
                let j = w % nsm;
                sm_finish[j] = sm_finish[j].max(t);
            }
        }
        Paradigm::MinHeap => {
            // FA3's software scheduler balances on the *kernel's own* cost
            // estimate, which differs slightly from the simulator's analytic
            // replica (page-granular KV lengths, integer cost quantization)
            // — the source of Table VII's small-but-nonzero FA3 error.
            let costs: Vec<f64> = tasks
                .iter()
                .enumerate()
                .map(|(i, t)| {
                    let mut h = seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15);
                    let u = crate::util::rng::splitmix64(&mut h) as f64 / u64::MAX as f64;
                    t.cost_hint * (1.0 + 0.05 * (u - 0.5))
                })
                .collect();
            let workers = nsm * occ.max(1) as usize;
            let bins = minheap::balance(&costs, workers);
            for (w, bin) in bins.iter().enumerate() {
                let j = w % nsm;
                let t: f64 = bin.iter().map(|&i| jittered[i]).sum();
                sm_finish[j] = sm_finish[j].max(t);
                for &i in bin {
                    sm_tensor[j] += tasks[i].tensor_ops;
                    sm_fma[j] += tasks[i].fma_ops;
                }
            }
        }
    }

    let makespan = sm_finish.iter().cloned().fold(0.0, f64::max);
    // chip-level bandwidth floors (contention: no schedule can beat them)
    let dram_floor = (dram_total + stores) / gpu.dram_bytes_per_cycle();
    let l2_floor = loads * l2_multicast_discount(gpu, kind) / gpu.l2_bytes_per_cycle();
    let cycles = makespan.max(dram_floor).max(l2_floor);

    let clean_sec = cycles * gpu.cycle_sec() + launch_overhead_sec(gpu);
    let latency_sec = clean_sec * rng.lognormal_factor(MEASUREMENT_NOISE_SIGMA);

    OracleResult {
        latency_sec,
        clean_sec,
        max_sm_tensor_ops: sm_tensor.iter().cloned().fold(0.0, f64::max),
        max_sm_fma_ops: sm_fma.iter().cloned().fold(0.0, f64::max),
        total_tensor_ops: sm_tensor.iter().sum(),
        total_fma_ops: sm_fma.iter().sum(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::DType;

    fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
        KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
    }

    #[test]
    fn deterministic_given_seed() {
        let gpu = gpu_by_name("A100").unwrap();
        let a = measure(&gemm(4096, 4096, 1024), &gpu, 7);
        let b = measure(&gemm(4096, 4096, 1024), &gpu, 7);
        assert_eq!(a.latency_sec, b.latency_sec);
        let c = measure(&gemm(4096, 4096, 1024), &gpu, 8);
        assert_ne!(a.latency_sec, c.latency_sec);
    }

    #[test]
    fn latency_always_above_theory() {
        use crate::features::FeatureSet;
        use crate::sched::schedule;
        for name in ["A40", "A100", "H800", "H20", "L40", "RTX PRO 6000 S"] {
            let gpu = gpu_by_name(name).unwrap();
            for (m, n, k) in [(512, 512, 512), (8192, 8192, 8192), (64, 13824, 5120)] {
                let cfg = gemm(m, n, k);
                let d = cfg.decompose(&gpu);
                let dist = schedule(&d, &gpu);
                let f = FeatureSet::analyze(&d, &dist, &gpu);
                let o = measure(&cfg, &gpu, 3);
                let eff = f.theory_sec / o.clean_sec;
                assert!(
                    eff < 1.0,
                    "{name} gemm {m}x{n}x{k}: efficiency {eff} >= 1 (theory must lower-bound)"
                );
                assert!(eff > 0.02, "{name} gemm {m}x{n}x{k}: efficiency {eff} absurdly low");
            }
        }
    }

    #[test]
    fn big_gemm_reaches_decent_efficiency() {
        use crate::features::FeatureSet;
        use crate::sched::schedule;
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = gemm(8192, 8192, 8192);
        let d = cfg.decompose(&gpu);
        let f = FeatureSet::analyze(&d, &schedule(&d, &gpu), &gpu);
        let o = measure(&cfg, &gpu, 1);
        let eff = f.theory_sec / o.clean_sec;
        assert!(eff > 0.45, "large GEMM should be reasonably efficient: {eff}");
    }

    #[test]
    fn small_kernels_dominated_by_overhead() {
        let gpu = gpu_by_name("H100").unwrap();
        let o = measure(&KernelConfig::RmsNorm { seq: 2, dim: 128 }, &gpu, 1);
        // tiny kernel: latency ~ launch overhead (microseconds)
        assert!(o.clean_sec > 1e-6 && o.clean_sec < 2e-5, "{}", o.clean_sec);
    }

    #[test]
    fn h20_gemm_more_efficient_than_h800() {
        // §VI-C: the H20's low compute-to-memory ratio keeps its tensor
        // pipes fed; the H800's huge MXU is hard to saturate.
        use crate::features::FeatureSet;
        use crate::sched::schedule;
        let cfg = gemm(8192, 8192, 8192);
        let eff = |name: &str| {
            let gpu = gpu_by_name(name).unwrap();
            let d = cfg.decompose(&gpu);
            let f = FeatureSet::analyze(&d, &schedule(&d, &gpu), &gpu);
            f.theory_sec / measure(&cfg, &gpu, 5).clean_sec
        };
        assert!(eff("H20") > eff("H800") + 0.05, "H20 {} vs H800 {}", eff("H20"), eff("H800"));
    }

    #[test]
    fn dynamic_vs_static_max_sm_ops_gap_small_for_uniform() {
        use crate::sched::schedule;
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = gemm(4096, 8192, 1024);
        let d = cfg.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let model_max = dist.max_sm_sum(|g| d.task_groups[g].template.tensor_ops);
        let o = measure(&cfg, &gpu, 11);
        let rel = (model_max - o.max_sm_tensor_ops).abs() / o.max_sm_tensor_ops;
        assert!(rel < 0.02, "uniform-task max-SM gap should be tiny: {rel}");
        // totals agree exactly
        assert!((d.total_tensor_ops() - o.total_tensor_ops).abs() / o.total_tensor_ops < 1e-9);
    }

    #[test]
    fn causal_fa2_max_sm_gap_larger_than_fa3() {
        use crate::sched::schedule;
        let gpu = gpu_by_name("H800").unwrap();
        let batch: Vec<(u32, u32)> = vec![(3000, 3000), (1500, 6000), (700, 900), (4500, 4500)];
        let rel_gap = |fa3: bool, seed: u64| {
            let cfg = KernelConfig::Attention {
                batch: batch.clone(),
                nh: 16,
                nkv: 4,
                hd: 128,
                causal: true,
                fa3,
            };
            let d = cfg.decompose(&gpu);
            let dist = schedule(&d, &gpu);
            let model_max = dist.max_sm_sum(|g| d.task_groups[g].template.tensor_ops);
            let o = measure(&cfg, &gpu, seed);
            (model_max - o.max_sm_tensor_ops).abs() / o.max_sm_tensor_ops
        };
        let fa2: f64 = (0..8).map(|s| rel_gap(false, s)).sum::<f64>() / 8.0;
        let fa3: f64 = (0..8).map(|s| rel_gap(true, s)).sum::<f64>() / 8.0;
        assert!(fa2 > fa3, "FA2 avg gap {fa2} should exceed FA3 {fa3}");
        assert!(fa3 < 0.03, "FA3 deterministic scheduler gap should be small: {fa3}");
    }

    #[test]
    fn noise_is_small_and_centered() {
        let gpu = gpu_by_name("L20").unwrap();
        let cfg = gemm(2048, 2048, 2048);
        let ratios: Vec<f64> = (0..200)
            .map(|s| {
                let o = measure(&cfg, &gpu, s);
                o.latency_sec / o.clean_sec
            })
            .collect();
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.01, "noise should be centered: {mean}");
        assert!(ratios.iter().all(|r| (0.9..1.1).contains(r)));
    }

    #[test]
    fn moe_default_config_worse_on_a40_than_tuned() {
        use crate::kernels::fused_moe;
        let a40 = gpu_by_name("A40").unwrap();
        let mut rng = crate::util::rng::Rng::new(9);
        let experts = fused_moe::route_tokens(2048, 16, 2, &mut rng);
        let default = fused_moe::default_config(2048, &a40);
        let d_def = fused_moe::decompose(4096, 2048, &experts, default, &a40);
        let t_def = measure_decomposed(KernelKind::FusedMoe, &d_def, &a40, 1).clean_sec;
        let best = fused_moe::tuning_space()
            .into_iter()
            .filter(|c| fused_moe::config_valid(c, &a40))
            .map(|c| {
                let d = fused_moe::decompose(4096, 2048, &experts, c, &a40);
                measure_decomposed(KernelKind::FusedMoe, &d, &a40, 1).clean_sec
            })
            .fold(f64::MAX, f64::min);
        assert!(
            t_def / best > 1.15,
            "tuning should find >=15% on A40: default {t_def}, best {best}"
        );
    }
}
