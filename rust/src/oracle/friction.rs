//! Architecture-specific efficiency ("friction") curves for the oracle —
//! the micro-architectural realities the analytical model deliberately does
//! NOT encode (§IV-C "we do not construct rigid analytical models for …
//! instruction-level concurrency or architecture-specific mechanisms"), and
//! which the Performance Estimator MLP therefore has to learn.
//!
//! Calibration rationale (not fitted to any profile — chosen to reproduce
//! the qualitative behaviours the paper reports):
//!  * wider MXUs are harder to saturate -> achievable fraction falls with
//!    per-SM tensor throughput (H800's 4096 ops/clk is "exceedingly
//!    difficult to saturate", §VI-C, while the H20 runs near its roof);
//!  * small tiles waste MXU issue slots (wave quantization inside the SM);
//!  * deeper software pipelines overlap better, but pre-Hopper parts pay
//!    register pressure beyond 3 stages (Triton/A40 behaviour in §VII);
//!  * Hopper prefers 8-warp cooperative groups, older parts 4.

use crate::hw::{Arch, GpuSpec};
use crate::kernels::KernelKind;

/// Fraction of residual (non-dominant) pipe time that leaks into the
/// critical path — imperfect dual-issue across heterogeneous pipes.
pub const PIPE_RESIDUE: f64 = 0.20;

/// Achievable fraction of FMA / XU pipe peak.
pub const FMA_FRICTION: f64 = 0.82;
pub const XU_FRICTION: f64 = 0.78;

/// Per-task fixed cost: CTA launch/drain, prologue, epilogue barriers.
pub const TASK_PROLOGUE_CYCLES: f64 = 900.0;

/// Per-task execution-time jitter (uniform ±).
pub const TASK_JITTER: f64 = 0.03;

/// Lognormal sigma of the run-to-run measurement noise.
pub const MEASUREMENT_NOISE_SIGMA: f64 = 0.02;

/// Achievable fraction of tensor-pipe peak for a tiled MMA kernel.
pub fn tensor_friction(
    gpu: &GpuSpec,
    kind: KernelKind,
    tile: (u32, u32, u32),
    stages: u32,
    warps: u32,
) -> f64 {
    // base: wider MXUs are harder to feed/saturate
    let width_penalty = (gpu.tensor_ops_clk_sm / 512.0).log2().max(0.0);
    let mut f = 0.97 - 0.055 * width_penalty;

    // tile (MXU) utilization: edge/issue losses for small tiles
    let (tm, tn, tk) = tile;
    let grain = match gpu.arch {
        Arch::Hopper => 8.0,
        Arch::Blackwell => 10.0,
        Arch::Ampere => 12.0,
        Arch::Ada => 14.0,
    };
    f *= tm as f64 / (tm as f64 + grain);
    f *= tn as f64 / (tn as f64 + grain);
    f *= tk as f64 / (tk as f64 + 4.0);

    // software pipelining depth
    let stage_gain = 1.0 - 0.45 / (stages.max(1) as f64 + 0.5);
    f *= stage_gain / (1.0 - 0.45 / 4.5); // normalized so 4 stages = 1.0
    // register pressure beyond 3 stages on pre-Hopper parts (spills);
    // Ampere's older async-copy path suffers more than Ada's
    match gpu.arch {
        Arch::Ampere if stages > 3 => f *= 0.84_f64.powi((stages - 3) as i32),
        Arch::Ada if stages > 3 => f *= 0.92_f64.powi((stages - 3) as i32),
        _ => {}
    }

    // warp-mix preference (8-warp cooperative groups need Hopper's wider
    // scheduler; on older parts they serialize at the MMA issue stage)
    let (ideal_warps, warp_tax): (f64, f64) = match gpu.arch {
        Arch::Hopper | Arch::Blackwell => (8.0, 0.05),
        Arch::Ampere => (4.0, 0.12),
        Arch::Ada => (4.0, 0.06),
    };
    f *= 1.0 - warp_tax * ((warps as f64 - ideal_warps).abs() / 4.0);

    // FP8 on Hopper+: double-rate MMA with a small conversion tax is applied
    // at the throughput site; here only the residual scheduling tax.
    if kind == KernelKind::ScaledMm && gpu.fp8_tensor_mult > 1.0 {
        f *= 0.93;
    }

    f.clamp(0.05, 0.98)
}

/// Compute/memory overlap quality per kernel family (async-copy pipelining
/// for tile kernels; softmax dependency chains limit attention).
pub fn overlap_quality(kind: KernelKind, stages: u32, gpu: &GpuSpec) -> f64 {
    let base = match kind {
        KernelKind::Gemm | KernelKind::ScaledMm | KernelKind::FusedMoe => 0.90,
        KernelKind::Attention => 0.80,
        KernelKind::RmsNorm | KernelKind::SiluMul => 0.85,
    };
    let stage_bonus = 0.03 * (stages.min(4).saturating_sub(1)) as f64;
    let arch_bonus = match gpu.arch {
        Arch::Hopper => 0.03, // TMA: hardware async copies
        Arch::Blackwell => 0.02,
        _ => 0.0,
    };
    (base + stage_bonus + arch_bonus).min(0.97)
}

/// Effective L2 pull per loaded byte: TMA multicast + thread-block clusters
/// let Hopper/Blackwell tensor kernels share operand fetches.
pub fn l2_multicast_discount(gpu: &GpuSpec, kind: KernelKind) -> f64 {
    match (gpu.arch, kind) {
        (Arch::Hopper | Arch::Blackwell,
         KernelKind::Gemm | KernelKind::ScaledMm | KernelKind::FusedMoe) => 0.55,
        _ => 1.0,
    }
}

/// Kernel launch overhead (driver + GigaThread ramp), seconds.
pub fn launch_overhead_sec(gpu: &GpuSpec) -> f64 {
    match gpu.arch {
        Arch::Ampere => 2.6e-6,
        Arch::Ada => 2.3e-6,
        Arch::Hopper => 2.0e-6,
        Arch::Blackwell => 2.1e-6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn wider_mxu_lower_fraction() {
        let h800 = gpu_by_name("H800").unwrap();
        let h20 = gpu_by_name("H20").unwrap();
        let f800 = tensor_friction(&h800, KernelKind::Gemm, (256, 128, 64), 4, 8);
        let f20 = tensor_friction(&h20, KernelKind::Gemm, (256, 128, 64), 4, 8);
        assert!(f20 > f800 + 0.05, "H20 {f20} vs H800 {f800}");
    }

    #[test]
    fn small_tiles_hurt() {
        let a100 = gpu_by_name("A100").unwrap();
        let big = tensor_friction(&a100, KernelKind::Gemm, (128, 256, 32), 3, 8);
        let small = tensor_friction(&a100, KernelKind::Gemm, (16, 64, 32), 3, 8);
        assert!(big > small * 1.2);
    }

    #[test]
    fn deep_stages_hurt_ampere_help_hopper() {
        let a40 = gpu_by_name("A40").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let t = (64, 128, 64);
        let a3 = tensor_friction(&a40, KernelKind::FusedMoe, t, 3, 4);
        let a5 = tensor_friction(&a40, KernelKind::FusedMoe, t, 5, 4);
        assert!(a3 > a5, "A40 should prefer 3 stages: {a3} vs {a5}");
        let h4 = tensor_friction(&h800, KernelKind::FusedMoe, t, 4, 8);
        let h2 = tensor_friction(&h800, KernelKind::FusedMoe, t, 2, 8);
        assert!(h4 > h2, "Hopper should prefer deep stages: {h4} vs {h2}");
    }

    #[test]
    fn warp_preference_differs_by_arch() {
        let a40 = gpu_by_name("A40").unwrap();
        let h100 = gpu_by_name("H100").unwrap();
        let t = (64, 64, 32);
        assert!(
            tensor_friction(&a40, KernelKind::FusedMoe, t, 3, 4)
                > tensor_friction(&a40, KernelKind::FusedMoe, t, 3, 8)
        );
        assert!(
            tensor_friction(&h100, KernelKind::FusedMoe, t, 4, 8)
                > tensor_friction(&h100, KernelKind::FusedMoe, t, 4, 4)
        );
    }

    #[test]
    fn frictions_in_unit_range() {
        for gpu in crate::hw::all_gpus() {
            for tile in [(16, 64, 32), (128, 128, 32), (256, 128, 64)] {
                for stages in [2, 3, 4, 5] {
                    for warps in [4, 8] {
                        let f = tensor_friction(&gpu, KernelKind::Gemm, tile, stages, warps);
                        assert!((0.05..=0.98).contains(&f), "{} {f}", gpu.name);
                        let ov = overlap_quality(KernelKind::Gemm, stages, &gpu);
                        assert!((0.5..=0.97).contains(&ov));
                    }
                }
            }
        }
    }
}
