//! Trained-model persistence: a tiny self-describing binary format holding
//! the flat theta/bn blobs plus the feature scaler (no serde available in
//! the offline vendor set).

use crate::features::FEATURE_DIM;
use crate::mlp::scaler::Scaler;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SYNPERF1";

#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub theta: Vec<f32>,
    pub bn: Vec<f32>,
    pub scaler: Scaler,
}

fn write_f32s(w: &mut impl Write, data: &[f32]) -> Result<()> {
    w.write_all(&(data.len() as u32).to_le_bytes())?;
    for v in data {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(r: &mut impl Read) -> Result<Vec<f32>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let n = u32::from_le_bytes(len) as usize;
    if n > 100_000_000 {
        bail!("implausible blob length {n}");
    }
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

pub fn save<P: AsRef<Path>>(w: &ModelWeights, path: P) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(&path)
        .with_context(|| format!("create {:?}", path.as_ref()))?;
    f.write_all(MAGIC)?;
    write_f32s(&mut f, &w.theta)?;
    write_f32s(&mut f, &w.bn)?;
    write_f32s(&mut f, &w.scaler.mean)?;
    write_f32s(&mut f, &w.scaler.std)?;
    Ok(())
}

pub fn load<P: AsRef<Path>>(path: P) -> Result<ModelWeights> {
    let mut f = std::fs::File::open(&path)
        .with_context(|| format!("open model weights {:?}", path.as_ref()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic in {:?}", path.as_ref());
    }
    let theta = read_f32s(&mut f)?;
    let bn = read_f32s(&mut f)?;
    let mean = read_f32s(&mut f)?;
    let std = read_f32s(&mut f)?;
    if mean.len() != FEATURE_DIM || std.len() != FEATURE_DIM {
        bail!("scaler width mismatch");
    }
    let mut scaler = Scaler::identity();
    scaler.mean.copy_from_slice(&mean);
    scaler.std.copy_from_slice(&std);
    Ok(ModelWeights { theta, bn, scaler })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let w = ModelWeights {
            theta: (0..100).map(|i| i as f32 * 0.5).collect(),
            bn: vec![1.0; 8],
            scaler: Scaler::identity(),
        };
        let path = std::env::temp_dir().join("synperf_w_test.bin");
        save(&w, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(w.theta, back.theta);
        assert_eq!(w.bn, back.bn);
        assert_eq!(w.scaler.mean, back.scaler.mean);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("synperf_w_bad.bin");
        std::fs::write(&path, b"NOTMAGIC123").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
