//! Performance Estimator (paper §IV-D / §V-C): the per-kernel-category MLP
//! that maps the Table-IV analytical feature vector to predicted *execution
//! efficiency*, trained with MAPE loss (accuracy model) or pinball loss
//! τ=0.8 (the §VII "potential performance ceiling" model).
//!
//! The MLP itself is the AOT-compiled JAX/Pallas artifact executed through
//! [`crate::runtime`]; this module owns standardization, the rust-side
//! training loop (minibatching, shuffling, early stopping), weight
//! persistence, and a pure-rust mirror of the forward pass used to
//! cross-check PJRT numerics.

pub mod native;
pub mod predictor;
pub mod scaler;
pub mod train;
pub mod weights;

pub use predictor::Predictor;
pub use scaler::Scaler;
pub use train::{train_model, TrainConfig, TrainedModel};
