//! Feature standardization fitted on the training split. The MLP sees
//! z-scored features; the scaler rides along with the weights so unseen-GPU
//! evaluation uses the training-set statistics.

use crate::features::FEATURE_DIM;

#[derive(Debug, Clone)]
pub struct Scaler {
    pub mean: [f32; FEATURE_DIM],
    pub std: [f32; FEATURE_DIM],
}

impl Scaler {
    pub fn identity() -> Scaler {
        Scaler { mean: [0.0; FEATURE_DIM], std: [1.0; FEATURE_DIM] }
    }

    pub fn fit(xs: &[[f32; FEATURE_DIM]]) -> Scaler {
        assert!(!xs.is_empty());
        let n = xs.len() as f64;
        let mut mean = [0f64; FEATURE_DIM];
        for x in xs {
            for (m, v) in mean.iter_mut().zip(x) {
                *m += *v as f64;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = [0f64; FEATURE_DIM];
        for x in xs {
            for i in 0..FEATURE_DIM {
                let d = x[i] as f64 - mean[i];
                var[i] += d * d;
            }
        }
        let mut out = Scaler::identity();
        for i in 0..FEATURE_DIM {
            out.mean[i] = mean[i] as f32;
            out.std[i] = (var[i] / n).sqrt().max(1e-6) as f32;
        }
        out
    }

    pub fn transform(&self, x: &[f32; FEATURE_DIM]) -> [f32; FEATURE_DIM] {
        let mut out = [0f32; FEATURE_DIM];
        for i in 0..FEATURE_DIM {
            // clamp to +-4 sigma: unseen architectures land outside the
            // training range on some descriptors; saturating instead of
            // extrapolating keeps the MLP on its learned manifold
            out[i] = ((x[i] - self.mean[i]) / self.std[i]).clamp(-4.0, 4.0);
        }
        out
    }

    pub fn transform_all(&self, xs: &[[f32; FEATURE_DIM]]) -> Vec<[f32; FEATURE_DIM]> {
        xs.iter().map(|x| self.transform(x)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_mean_unit_std_after_transform() {
        let xs: Vec<[f32; FEATURE_DIM]> = (0..100)
            .map(|i| {
                let mut x = [0f32; FEATURE_DIM];
                for (j, v) in x.iter_mut().enumerate() {
                    *v = ((i * (j + 1)) % 97) as f32 + j as f32;
                }
                x
            })
            .collect();
        let s = Scaler::fit(&xs);
        let t = s.transform_all(&xs);
        for j in 0..FEATURE_DIM {
            let mean: f32 = t.iter().map(|x| x[j]).sum::<f32>() / t.len() as f32;
            let var: f32 = t.iter().map(|x| (x[j] - mean).powi(2)).sum::<f32>() / t.len() as f32;
            assert!(mean.abs() < 1e-4, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "col {j} var {var}");
        }
    }

    #[test]
    fn constant_column_safe() {
        let xs = vec![[1.0f32; FEATURE_DIM]; 10];
        let s = Scaler::fit(&xs);
        let t = s.transform(&xs[0]);
        assert!(t.iter().all(|v| v.is_finite()));
    }
}
