//! Inference-side wrapper: standardize features, run the AOT'd forward
//! artifact (largest batch variant that fits, wrap-padded), convert
//! efficiency back to latency via the theoretical roof.

use crate::features::{FeatureSet, FEATURE_DIM};
use crate::mlp::native;
use crate::mlp::weights::ModelWeights;
use crate::runtime::{lit_f32, to_f32, Engine, Executable};
use anyhow::Result;
use std::sync::Mutex;

pub struct Predictor {
    weights: ModelWeights,
    /// (batch, executable), descending batch size.
    fwds: Vec<(usize, Executable)>,
    /// theta/bn encoded once (§Perf: saves ~200KB of literal re-encoding
    /// per forward call — dominant on the batch-1 path).
    theta_lit: xla::Literal,
    bn_lit: xla::Literal,
    /// Reused workspace for the native forward (allocated once, not per
    /// call; Mutex because prediction entry points take `&self`).
    native_scratch: Mutex<native::Scratch>,
}

impl Predictor {
    pub fn new(engine: &Engine, weights: ModelWeights) -> Result<Predictor> {
        let mut batches = engine.manifest.fwd_batches.clone();
        batches.sort_unstable_by(|a, b| b.cmp(a));
        let mut fwds = Vec::new();
        for b in batches {
            fwds.push((b, engine.load(&format!("mlp_fwd_b{b}.hlo.txt"))?));
        }
        let theta_lit = lit_f32(&weights.theta, &[weights.theta.len() as i64])?;
        let bn_lit = lit_f32(&weights.bn, &[weights.bn.len() as i64])?;
        Ok(Predictor {
            weights,
            fwds,
            theta_lit,
            bn_lit,
            native_scratch: Mutex::new(native::Scratch::new()),
        })
    }

    pub fn from_file(engine: &Engine, path: &str) -> Result<Predictor> {
        Predictor::new(engine, crate::mlp::weights::load(path)?)
    }

    /// Predict execution efficiency for a batch of raw feature rows.
    pub fn predict_eff(&self, xs: &[[f32; FEATURE_DIM]]) -> Result<Vec<f64>> {
        let zs = self.weights.scaler.transform_all(xs);
        let mut out = Vec::with_capacity(xs.len());
        let mut i = 0usize;
        while i < zs.len() {
            let remaining = zs.len() - i;
            // smallest variant that covers the remainder, else the largest
            let (b, exe) = self
                .fwds
                .iter()
                .rev()
                .find(|(b, _)| *b >= remaining)
                .unwrap_or(&self.fwds[0]);
            let take = remaining.min(*b);
            let mut flat = Vec::with_capacity(b * FEATURE_DIM);
            for r in 0..*b {
                flat.extend_from_slice(&zs[i + r.min(take - 1)]);
            }
            let x_lit = lit_f32(&flat, &[*b as i64, FEATURE_DIM as i64])?;
            let res = exe.run_ref(&[&self.theta_lit, &self.bn_lit, &x_lit])?;
            let eff = to_f32(&res[0])?;
            for r in 0..take {
                // floor at 0.5%: efficiencies below that are launch-overhead
                // regime noise; prevents saturated-sigmoid blowups on
                // out-of-distribution inputs
                out.push((eff[r] as f64).clamp(5e-3, 0.9999));
            }
            i += take;
        }
        Ok(out)
    }

    /// Latency prediction: theoretical roof divided by predicted efficiency
    /// (§V-C "final latency prediction").
    pub fn predict_latency(&self, feats: &[FeatureSet], gpu: &crate::hw::GpuSpec) -> Result<Vec<f64>> {
        let xs: Vec<[f32; FEATURE_DIM]> = feats.iter().map(|f| f.to_model_input(gpu)).collect();
        let effs = self.predict_eff(&xs)?;
        Ok(feats.iter().zip(effs).map(|(f, e)| f.theory_sec / e).collect())
    }

    /// Rows at or above which the native forward fans out over
    /// [`native::forward_par`] (chunked, one thread-local scratch per
    /// worker); smaller batches stay on the serial reused-scratch path.
    /// Both paths are bit-identical, so this is purely a wall-clock knob.
    const NATIVE_PAR_MIN_ROWS: usize = 256;

    /// Native (pure-rust) forward for cross-checking the PJRT path and as
    /// the artifact-free fallback. Large batches split into
    /// `ROW_BLOCK`-aligned chunks across worker threads (bit-identical to
    /// the serial walk — row blocks are independent); small batches reuse
    /// the predictor's scratch panels when they are free, falling back to
    /// a fresh local workspace rather than serializing concurrent callers
    /// on the lock. Workers default to available parallelism — callers
    /// holding a `--threads`-style cap (or already running inside a
    /// parallel region) use
    /// [`predict_eff_native_threads`](Self::predict_eff_native_threads).
    pub fn predict_eff_native(&self, xs: &[[f32; FEATURE_DIM]]) -> Vec<f64> {
        self.predict_eff_native_threads(xs, crate::engine::par::default_threads())
    }

    /// [`predict_eff_native`](Self::predict_eff_native) with an explicit
    /// worker cap: `threads = 1` (or a batch under
    /// [`NATIVE_PAR_MIN_ROWS`](Self::NATIVE_PAR_MIN_ROWS) rows) stays on
    /// the serial reused-scratch path. Outputs are bit-identical at any
    /// thread count.
    pub fn predict_eff_native_threads(
        &self,
        xs: &[[f32; FEATURE_DIM]],
        threads: usize,
    ) -> Vec<f64> {
        let zs = self.weights.scaler.transform_all(xs);
        if threads > 1 && zs.len() >= Self::NATIVE_PAR_MIN_ROWS {
            let effs =
                native::forward_par(&self.weights.theta, &self.weights.bn, &zs, threads);
            return effs.into_iter().map(|v| (v as f64).clamp(1e-3, 0.9999)).collect();
        }
        let mut effs = Vec::with_capacity(zs.len());
        let mut guard;
        let mut local;
        let scratch: &mut native::Scratch = if let Ok(g) = self.native_scratch.try_lock() {
            guard = g;
            &mut guard
        } else {
            local = native::Scratch::new();
            &mut local
        };
        native::forward_into(&self.weights.theta, &self.weights.bn, &zs, scratch, &mut effs);
        effs.into_iter().map(|v| (v as f64).clamp(1e-3, 0.9999)).collect()
    }

    pub fn weights(&self) -> &ModelWeights {
        &self.weights
    }
}
