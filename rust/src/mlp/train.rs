//! Rust-side training loop driving the AOT-compiled train-step executable
//! (Layer-2 JAX + Layer-1 Pallas, via PJRT). Implements the paper's §V-C
//! recipe: AdamW (inside the artifact), MAPE (or pinball τ=0.8) loss,
//! shuffled minibatches, early stopping on validation loss.

use crate::features::FEATURE_DIM;
use crate::mlp::scaler::Scaler;
use crate::mlp::weights::ModelWeights;
use crate::runtime::{lit_f32, lit_key, lit_scalar, to_f32, Engine};
use crate::util::rng::Rng;
use anyhow::{Context, Result};

#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Max optimizer steps.
    pub max_steps: usize,
    /// Validate every N steps.
    pub val_every: usize,
    /// Early-stop after this many validations without improvement.
    pub patience: usize,
    /// None = MAPE loss; Some(tau) = pinball quantile loss (P80 ceiling).
    pub tau: Option<f64>,
    pub seed: u64,
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            max_steps: 1500,
            val_every: 100,
            patience: 4,
            tau: None,
            seed: 0xBEEF,
            verbose: false,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainedModel {
    pub weights: ModelWeights,
    pub final_val_loss: f64,
    pub steps_run: usize,
}

/// Train one per-kernel-category MLP on (features, efficiency) pairs.
pub fn train_model(
    engine: &Engine,
    xs: &[[f32; FEATURE_DIM]],
    ys: &[f64],
    cfg: &TrainConfig,
) -> Result<TrainedModel> {
    anyhow::ensure!(xs.len() == ys.len() && !xs.is_empty(), "bad training set");
    let m = &engine.manifest;
    let b = m.train_batch;
    let loss_name = if cfg.tau.is_some() { "p80" } else { "mape" };
    let train_exe = engine
        .load(&format!("mlp_train_{loss_name}_b{b}.hlo.txt"))
        .context("load train artifact")?;
    let fwd_exe = engine.load(&format!("mlp_fwd_b{b}.hlo.txt"))?;

    // standardize on the full provided training set
    let scaler = Scaler::fit(xs);
    let zs = scaler.transform_all(xs);

    // 90/10 train/val split (deterministic shuffle)
    let mut rng = Rng::new(cfg.seed);
    let mut idx: Vec<usize> = (0..zs.len()).collect();
    rng.shuffle(&mut idx);
    let n_val = (zs.len() / 10).clamp(1, 4096);
    let (val_idx, train_idx) = idx.split_at(n_val);

    let mut theta = engine.read_f32_blob("init_theta.bin")?;
    let mut bn = engine.read_f32_blob("init_bn.bin")?;
    let mut mom = vec![0f32; m.theta_size];
    let mut vel = vec![0f32; m.theta_size];

    // pre-pack validation batches (wrap-padded)
    let val_batches = pack_batches(&zs, ys, val_idx, b);

    let mut best_val = f64::MAX;
    let mut best = (theta.clone(), bn.clone());
    let mut bad_rounds = 0usize;
    let mut cursor = 0usize;
    let mut order: Vec<usize> = train_idx.to_vec();
    rng.shuffle(&mut order);
    let mut steps_run = 0usize;

    for step in 1..=cfg.max_steps {
        // next minibatch (reshuffle at epoch boundary)
        let mut bx = Vec::with_capacity(b * FEATURE_DIM);
        let mut by = Vec::with_capacity(b);
        for _ in 0..b {
            if cursor >= order.len() {
                cursor = 0;
                rng.shuffle(&mut order);
            }
            let i = order[cursor];
            cursor += 1;
            bx.extend_from_slice(&zs[i]);
            by.push(ys[i] as f32);
        }
        let out = train_exe.run(&[
            lit_f32(&theta, &[theta.len() as i64])?,
            lit_f32(&mom, &[mom.len() as i64])?,
            lit_f32(&vel, &[vel.len() as i64])?,
            lit_f32(&bn, &[bn.len() as i64])?,
            lit_f32(&bx, &[b as i64, FEATURE_DIM as i64])?,
            lit_f32(&by, &[b as i64])?,
            lit_scalar(step as f32),
            lit_key(cfg.seed ^ (step as u64).wrapping_mul(0x9E3779B9))?,
        ])?;
        theta = to_f32(&out[0])?;
        mom = to_f32(&out[1])?;
        vel = to_f32(&out[2])?;
        bn = to_f32(&out[3])?;
        steps_run = step;

        if step % cfg.val_every == 0 || step == cfg.max_steps {
            let val = eval_loss(&fwd_exe, &theta, &bn, &val_batches, b, cfg.tau)?;
            if cfg.verbose {
                let train_loss = to_f32(&out[4])?[0];
                eprintln!("  step {step:>5}  train {train_loss:.4}  val {val:.4}");
            }
            if val < best_val - 1e-5 {
                best_val = val;
                best = (theta.clone(), bn.clone());
                bad_rounds = 0;
            } else {
                bad_rounds += 1;
                if bad_rounds >= cfg.patience {
                    break;
                }
            }
        }
    }

    Ok(TrainedModel {
        weights: ModelWeights { theta: best.0, bn: best.1, scaler },
        final_val_loss: best_val,
        steps_run,
    })
}

type Batch = (Vec<f32>, Vec<f32>, usize); // x, y, valid_rows

fn pack_batches(
    zs: &[[f32; FEATURE_DIM]],
    ys: &[f64],
    idx: &[usize],
    b: usize,
) -> Vec<Batch> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < idx.len() {
        let mut bx = Vec::with_capacity(b * FEATURE_DIM);
        let mut by = Vec::with_capacity(b);
        let valid = (idx.len() - i).min(b);
        for r in 0..b {
            let j = idx[(i + r) % idx.len().max(1)].min(zs.len() - 1);
            let j = if r < valid { idx[i + r] } else { j };
            bx.extend_from_slice(&zs[j]);
            by.push(ys[j] as f32);
        }
        out.push((bx, by, valid));
        i += b;
    }
    out
}

fn eval_loss(
    fwd: &crate::runtime::Executable,
    theta: &[f32],
    bn: &[f32],
    batches: &[Batch],
    b: usize,
    tau: Option<f64>,
) -> Result<f64> {
    let mut total = 0.0;
    let mut count = 0usize;
    for (bx, by, valid) in batches {
        let out = fwd.run(&[
            lit_f32(theta, &[theta.len() as i64])?,
            lit_f32(bn, &[bn.len() as i64])?,
            lit_f32(bx, &[b as i64, FEATURE_DIM as i64])?,
        ])?;
        let pred = to_f32(&out[0])?;
        for r in 0..*valid {
            let (p, y) = (pred[r] as f64, by[r] as f64);
            total += match tau {
                None => (p - y).abs() / y.max(1e-4),
                Some(t) => {
                    let d = y - p;
                    (t * d).max((t - 1.0) * d)
                }
            };
            count += 1;
        }
    }
    Ok(total / count.max(1) as f64)
}
