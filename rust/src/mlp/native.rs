//! Pure-rust mirror of the Layer-2 inference forward pass (dense -> ReLU ->
//! BatchNorm(running stats) x3 -> dense -> sigmoid), operating on the same
//! flat theta/bn blobs the artifacts use.
//!
//! Purpose: (1) cross-check PJRT numerics in integration tests, (2) the
//! documented fallback when artifacts are unavailable. The PJRT path stays
//! the production route (the AOT'd Pallas kernels are the deliverable).
//!
//! The forward is a blocked batch-GEMM: rows are processed [`ROW_BLOCK`] at
//! a time so each weight row is streamed through once per block instead of
//! once per input row, with the ReLU + BatchNorm epilogue fused into a
//! single pass over the activation panel (the per-feature `sqrt(var + eps)`
//! is hoisted out of the row loop). All buffers live in a caller-reusable
//! [`Scratch`], so repeated calls — the artifact-free serving fallback and
//! dataset-scale cross-checks — allocate nothing but the output. Per output
//! element the `fi`-ascending accumulation order of the original per-row
//! loop is preserved, so results are bit-identical to it.
//!
//! The inner `acc += x * w_row` sweep dispatches to an AVX2 f32x8 kernel
//! when the CPU has it (runtime-detected once per call; see
//! [`simd_available`]). The vector path deliberately uses a separate
//! multiply and add — **not FMA** — so each lane computes exactly the
//! scalar `acc[j] + x * w[j]` and the whole forward stays bit-identical
//! to the scalar loop, which remains compiled on every target as the
//! reference ([`forward_into_with`] forces either path for tests and
//! benches).

use crate::features::FEATURE_DIM;

/// Layer shapes — must mirror python/compile/model.py::LAYERS.
pub const LAYERS: [(usize, usize); 4] =
    [(FEATURE_DIM, 256), (256, 128), (128, 64), (64, 1)];
const BN_EPS: f32 = 1e-5;

/// Input rows processed per weight-matrix sweep.
const ROW_BLOCK: usize = 8;

/// theta length implied by LAYERS (w + b per layer, gamma/beta on hidden).
pub fn theta_size() -> usize {
    let mut n = 0;
    for (i, (fi, fo)) in LAYERS.iter().enumerate() {
        n += fi * fo + fo;
        if i < LAYERS.len() - 1 {
            n += 2 * fo;
        }
    }
    n
}

/// bn state length (mu + var per hidden layer).
pub fn bn_size() -> usize {
    LAYERS[..LAYERS.len() - 1].iter().map(|(_, fo)| 2 * fo).sum()
}

/// Reusable workspace for [`forward_into`]: two activation panels
/// (`ROW_BLOCK` × widest layer) plus the hoisted per-feature BatchNorm
/// standard deviations.
pub struct Scratch {
    /// Current activation panel, row-major `rb × fi`.
    act: Vec<f32>,
    /// Next-layer accumulator panel, row-major `rb × fo`.
    acc: Vec<f32>,
    /// Per-hidden-layer `sqrt(var + eps)`, laid out like the bn mu halves.
    std: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        let widest = LAYERS.iter().map(|&(fi, fo)| fi.max(fo)).max().unwrap_or(1);
        Scratch {
            act: vec![0.0; ROW_BLOCK * widest],
            acc: vec![0.0; ROW_BLOCK * widest],
            std: vec![0.0; bn_size() / 2],
        }
    }
}

impl Default for Scratch {
    fn default() -> Self {
        Scratch::new()
    }
}

/// Inference forward for a batch of standardized feature rows.
pub fn forward(theta: &[f32], bn: &[f32], xs: &[[f32; FEATURE_DIM]]) -> Vec<f32> {
    let mut scratch = Scratch::new();
    let mut out = Vec::with_capacity(xs.len());
    forward_into(theta, bn, xs, &mut scratch, &mut out);
    out
}

/// Parallel batched forward: rows split into [`ROW_BLOCK`]-aligned
/// contiguous chunks fanned out over [`crate::engine::par::par_map`], each
/// worker reusing one thread-local [`Scratch`] across its whole chunk.
/// Row blocks are computationally independent (the panel is rebuilt per
/// block), so splitting at block boundaries is **bit-identical** to
/// [`forward`] at every thread count.
pub fn forward_par(
    theta: &[f32],
    bn: &[f32],
    xs: &[[f32; FEATURE_DIM]],
    threads: usize,
) -> Vec<f32> {
    /// Minimum rows per chunk (a `ROW_BLOCK` multiple): a worker gets at
    /// least this much work, so a large `threads` against a modest batch
    /// cannot dissolve into per-handful-of-rows thread spawns.
    const PAR_GRAIN_ROWS: usize = 64;
    let threads = threads.max(1);
    if threads == 1 || xs.len() <= PAR_GRAIN_ROWS {
        return forward(theta, bn, xs);
    }
    let chunk =
        (xs.len().div_ceil(threads).div_ceil(ROW_BLOCK) * ROW_BLOCK).max(PAR_GRAIN_ROWS);
    let chunks: Vec<&[[f32; FEATURE_DIM]]> = xs.chunks(chunk).collect();
    let parts: Vec<Vec<f32>> = crate::engine::par::par_map(&chunks, threads, |_, &rows| {
        let mut scratch = Scratch::new();
        let mut out = Vec::with_capacity(rows.len());
        forward_into(theta, bn, rows, &mut scratch, &mut out);
        out
    });
    parts.into_iter().flatten().collect()
}

/// Is the AVX2 fast path usable on this CPU? Always `false` off x86.
pub fn simd_available() -> bool {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(any(target_arch = "x86", target_arch = "x86_64")))]
    {
        false
    }
}

/// `acc[j] += x * w[j]` over one output row — the forward's only hot loop.
/// The two paths are bit-identical; `simd` must only be `true` when
/// [`simd_available`] says so.
#[inline]
fn axpy(simd: bool, x: f32, w: &[f32], acc: &mut [f32]) {
    #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
    if simd {
        // SAFETY: the caller gated `simd` on the runtime AVX2 probe.
        unsafe { axpy_avx2(x, w, acc) };
        return;
    }
    let _ = simd;
    axpy_scalar(x, w, acc);
}

#[inline]
fn axpy_scalar(x: f32, w: &[f32], acc: &mut [f32]) {
    for (aj, wj) in acc.iter_mut().zip(w) {
        *aj += x * *wj;
    }
}

/// AVX2 f32x8 axpy. Separate `mul` then `add` — not FMA — so every lane
/// rounds exactly like the scalar `acc[j] + x * w[j]`; the tail that
/// doesn't fill a lane runs the scalar loop.
#[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
#[target_feature(enable = "avx2")]
unsafe fn axpy_avx2(x: f32, w: &[f32], acc: &mut [f32]) {
    #[cfg(target_arch = "x86")]
    use std::arch::x86::*;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;
    let n = acc.len().min(w.len());
    let xv = _mm256_set1_ps(x);
    let mut j = 0usize;
    while j + 8 <= n {
        let wv = _mm256_loadu_ps(w.as_ptr().add(j));
        let av = _mm256_loadu_ps(acc.as_ptr().add(j));
        _mm256_storeu_ps(acc.as_mut_ptr().add(j), _mm256_add_ps(av, _mm256_mul_ps(xv, wv)));
        j += 8;
    }
    axpy_scalar(x, &w[j..n], &mut acc[j..n]);
}

/// Batched inference forward appending one efficiency per row to `out`,
/// reusing `scratch` across calls. Uses the AVX2 path when the CPU has it.
pub fn forward_into(
    theta: &[f32],
    bn: &[f32],
    xs: &[[f32; FEATURE_DIM]],
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) {
    forward_into_with(simd_available(), theta, bn, xs, scratch, out)
}

/// [`forward_into`] with the axpy path pinned: `simd == false` forces the
/// scalar reference everywhere, `simd == true` requires AVX2 (see
/// [`simd_available`]). Exposed so tests and benches can compare the two.
pub fn forward_into_with(
    simd: bool,
    theta: &[f32],
    bn: &[f32],
    xs: &[[f32; FEATURE_DIM]],
    scratch: &mut Scratch,
    out: &mut Vec<f32>,
) {
    assert_eq!(theta.len(), theta_size(), "theta blob size mismatch");
    assert_eq!(bn.len(), bn_size(), "bn blob size mismatch");
    out.reserve(xs.len());

    // hoist the BatchNorm denominators: same sqrt per feature as the
    // unfused epilogue, computed once per call instead of once per row
    {
        let mut boff = 0usize;
        let mut soff = 0usize;
        for &(_, fo) in &LAYERS[..LAYERS.len() - 1] {
            let var = &bn[boff + fo..boff + 2 * fo];
            for (s, v) in scratch.std[soff..soff + fo].iter_mut().zip(var) {
                *s = (v + BN_EPS).sqrt();
            }
            boff += 2 * fo;
            soff += fo;
        }
    }

    for block in xs.chunks(ROW_BLOCK) {
        let rb = block.len();
        for (r, x) in block.iter().enumerate() {
            scratch.act[r * FEATURE_DIM..(r + 1) * FEATURE_DIM].copy_from_slice(x);
        }
        let mut toff = 0usize;
        let mut boff = 0usize;
        let mut soff = 0usize;
        for (li, &(fi, fo)) in LAYERS.iter().enumerate() {
            let w = &theta[toff..toff + fi * fo];
            toff += fi * fo;
            let b = &theta[toff..toff + fo];
            toff += fo;
            let acc = &mut scratch.acc[..rb * fo];
            acc.fill(0.0);
            // blocked dense: acc[rb, fo] += act[rb, fi] @ w[fi, fo], one
            // sweep over W per row block; the zero-input skip mirrors the
            // sparse log1p feature vectors
            for i in 0..fi {
                let wrow = &w[i * fo..(i + 1) * fo];
                for r in 0..rb {
                    let xi = scratch.act[r * fi + i];
                    if xi == 0.0 {
                        continue;
                    }
                    axpy(simd, xi, wrow, &mut acc[r * fo..(r + 1) * fo]);
                }
            }
            for r in 0..rb {
                for (aj, bj) in acc[r * fo..(r + 1) * fo].iter_mut().zip(b) {
                    *aj += bj;
                }
            }
            if li < LAYERS.len() - 1 {
                let gamma = &theta[toff..toff + fo];
                toff += fo;
                let beta = &theta[toff..toff + fo];
                toff += fo;
                let mu = &bn[boff..boff + fo];
                let std = &scratch.std[soff..soff + fo];
                boff += 2 * fo;
                soff += fo;
                // fused ReLU + BatchNorm epilogue, written back into the
                // activation panel for the next layer
                for r in 0..rb {
                    for j in 0..fo {
                        let v = acc[r * fo + j].max(0.0);
                        scratch.act[r * fo + j] = ((v - mu[j]) / std[j]) * gamma[j] + beta[j];
                    }
                }
            } else {
                for r in 0..rb {
                    out.push(1.0 / (1.0 + (-acc[r * fo]).exp())); // sigmoid head
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_weights() -> (Vec<f32>, Vec<f32>) {
        let theta: Vec<f32> = (0..theta_size())
            .map(|i| ((i * 31 % 97) as f32 / 97.0 - 0.5) * 0.1)
            .collect();
        let mut bn = vec![0f32; bn_size()];
        // var slots must be positive: layout is mu,var per layer
        let mut off = 0;
        for (_, fo) in &LAYERS[..3] {
            for v in &mut bn[off + fo..off + 2 * fo] {
                *v = 1.0;
            }
            off += 2 * fo;
        }
        (theta, bn)
    }

    /// The pre-blocking per-row forward, kept as the bit-identity oracle.
    fn reference_forward(theta: &[f32], bn: &[f32], xs: &[[f32; FEATURE_DIM]]) -> Vec<f32> {
        let mut out = Vec::with_capacity(xs.len());
        let mut h = vec![0f32; 256];
        let mut h2 = vec![0f32; 256];
        for x in xs {
            let mut cur: Vec<f32> = x.to_vec();
            let mut toff = 0usize;
            let mut boff = 0usize;
            for (li, &(fi, fo)) in LAYERS.iter().enumerate() {
                let w = &theta[toff..toff + fi * fo];
                toff += fi * fo;
                let b = &theta[toff..toff + fo];
                toff += fo;
                h.clear();
                h.resize(fo, 0.0);
                for (i, &xi) in cur.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let row = &w[i * fo..(i + 1) * fo];
                    for (hj, wj) in h.iter_mut().zip(row) {
                        *hj += xi * wj;
                    }
                }
                for (hj, bj) in h.iter_mut().zip(b) {
                    *hj += bj;
                }
                if li < LAYERS.len() - 1 {
                    let gamma = &theta[toff..toff + fo];
                    toff += fo;
                    let beta = &theta[toff..toff + fo];
                    toff += fo;
                    let mu = &bn[boff..boff + fo];
                    let var = &bn[boff + fo..boff + 2 * fo];
                    boff += 2 * fo;
                    h2.clear();
                    h2.resize(fo, 0.0);
                    for j in 0..fo {
                        let r = h[j].max(0.0);
                        let z = (r - mu[j]) / (var[j] + BN_EPS).sqrt();
                        h2[j] = z * gamma[j] + beta[j];
                    }
                    std::mem::swap(&mut cur, &mut h2);
                    cur.truncate(fo);
                } else {
                    out.push(1.0 / (1.0 + (-h[0]).exp()));
                }
            }
        }
        out
    }

    #[test]
    fn sizes_match_manifest_convention() {
        // 32*256+256 + 2*256 | 256*128+128 + 2*128 | 128*64+64 + 2*64 | 64+1
        assert_eq!(theta_size(), 8192 + 256 + 512 + 32768 + 128 + 256 + 8192 + 64 + 128 + 64 + 1);
        assert_eq!(bn_size(), 2 * (256 + 128 + 64));
    }

    #[test]
    fn forward_outputs_in_unit_interval() {
        let (theta, bn) = synthetic_weights();
        let xs = vec![[0.3f32; FEATURE_DIM], [-1.0; FEATURE_DIM]];
        let ys = forward(&theta, &bn, &xs);
        assert_eq!(ys.len(), 2);
        assert!(ys.iter().all(|y| *y > 0.0 && *y < 1.0));
        assert_ne!(ys[0], ys[1]);
    }

    #[test]
    fn blocked_forward_bit_identical_to_reference() {
        let (theta, bn) = synthetic_weights();
        // ragged batch (not a multiple of ROW_BLOCK), with zeros to hit the
        // sparse skip and negatives to hit the ReLU clamp
        let xs: Vec<[f32; FEATURE_DIM]> = (0..11)
            .map(|r| {
                let mut x = [0f32; FEATURE_DIM];
                for (i, v) in x.iter_mut().enumerate() {
                    *v = match (r + i) % 4 {
                        0 => 0.0,
                        1 => 0.7 * (i as f32 + 1.0).ln(),
                        2 => -0.9,
                        _ => (r as f32) - 4.0,
                    };
                }
                x
            })
            .collect();
        let want = reference_forward(&theta, &bn, &xs);
        let got = forward(&theta, &bn, &xs);
        assert_eq!(want.len(), got.len());
        for (w, g) in want.iter().zip(&got) {
            assert_eq!(w.to_bits(), g.to_bits(), "blocked forward drifted");
        }
    }

    #[test]
    fn parallel_forward_bit_identical_at_any_thread_count() {
        let (theta, bn) = synthetic_weights();
        // ragged sizes around the block/chunk boundaries
        for n in [1usize, 7, 8, 9, 61, 256] {
            let xs: Vec<[f32; FEATURE_DIM]> = (0..n)
                .map(|r| {
                    let mut x = [0f32; FEATURE_DIM];
                    for (i, v) in x.iter_mut().enumerate() {
                        *v = ((r * 13 + i * 7) % 5) as f32 - 2.0;
                    }
                    x
                })
                .collect();
            let want = forward(&theta, &bn, &xs);
            for threads in [1usize, 2, 3, 8] {
                let got = forward_par(&theta, &bn, &xs, threads);
                assert_eq!(want.len(), got.len());
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(w.to_bits(), g.to_bits(), "n={n} threads={threads} drifted");
                }
            }
        }
    }

    /// Ragged batch with zeros (sparse skip), negatives (ReLU clamp) and
    /// mixed magnitudes — the shape both bit-identity tests use.
    fn ragged_rows(n: usize) -> Vec<[f32; FEATURE_DIM]> {
        (0..n)
            .map(|r| {
                let mut x = [0f32; FEATURE_DIM];
                for (i, v) in x.iter_mut().enumerate() {
                    *v = match (r + i) % 4 {
                        0 => 0.0,
                        1 => 0.7 * (i as f32 + 1.0).ln(),
                        2 => -0.9,
                        _ => (r as f32) - 4.0,
                    };
                }
                x
            })
            .collect()
    }

    #[test]
    fn simd_forward_bit_identical_to_scalar() {
        if !simd_available() {
            eprintln!("(avx2 unavailable — scalar-only target, nothing to compare)");
            return;
        }
        let (theta, bn) = synthetic_weights();
        // 11 rows: one full ROW_BLOCK panel plus a 3-row remainder panel
        let xs = ragged_rows(11);
        let (mut s_scalar, mut s_simd) = (Scratch::new(), Scratch::new());
        let (mut scalar, mut simd) = (Vec::new(), Vec::new());
        forward_into_with(false, &theta, &bn, &xs, &mut s_scalar, &mut scalar);
        forward_into_with(true, &theta, &bn, &xs, &mut s_simd, &mut simd);
        assert_eq!(scalar.len(), simd.len());
        for (w, g) in scalar.iter().zip(&simd) {
            assert_eq!(w.to_bits(), g.to_bits(), "simd forward drifted off scalar");
        }
    }

    #[test]
    fn simd_axpy_matches_scalar_on_remainder_lengths() {
        if !simd_available() {
            return;
        }
        // lengths straddling the 8-lane width: pure remainder, exact
        // multiples, and blocked-plus-tail
        for n in [1usize, 3, 7, 8, 9, 16, 19] {
            let w: Vec<f32> = (0..n).map(|i| (i as f32) * 0.37 - 1.0).collect();
            let mut scalar: Vec<f32> = (0..n).map(|i| (i as f32) * 0.11 + 0.5).collect();
            let mut simd = scalar.clone();
            axpy(false, 1.7, &w, &mut scalar);
            axpy(true, 1.7, &w, &mut simd);
            for (a, b) in scalar.iter().zip(&simd) {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_stateless() {
        let (theta, bn) = synthetic_weights();
        let xs1 = vec![[0.5f32; FEATURE_DIM]; 3];
        let xs2 = vec![[-0.25f32; FEATURE_DIM]; 9];
        let mut scratch = Scratch::new();
        let mut out = Vec::new();
        forward_into(&theta, &bn, &xs1, &mut scratch, &mut out);
        forward_into(&theta, &bn, &xs2, &mut scratch, &mut out);
        assert_eq!(out.len(), 12);
        let fresh1 = forward(&theta, &bn, &xs1);
        let fresh2 = forward(&theta, &bn, &xs2);
        let want: Vec<f32> = fresh1.into_iter().chain(fresh2).collect();
        for (w, g) in want.iter().zip(&out) {
            assert_eq!(w.to_bits(), g.to_bits(), "scratch reuse leaked state");
        }
    }
}
