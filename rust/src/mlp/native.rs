//! Pure-rust mirror of the Layer-2 inference forward pass (dense -> ReLU ->
//! BatchNorm(running stats) x3 -> dense -> sigmoid), operating on the same
//! flat theta/bn blobs the artifacts use.
//!
//! Purpose: (1) cross-check PJRT numerics in integration tests, (2) a
//! documented fallback when artifacts are unavailable. The PJRT path stays
//! the production route (the AOT'd Pallas kernels are the deliverable).

use crate::features::FEATURE_DIM;

/// Layer shapes — must mirror python/compile/model.py::LAYERS.
pub const LAYERS: [(usize, usize); 4] =
    [(FEATURE_DIM, 256), (256, 128), (128, 64), (64, 1)];
const BN_EPS: f32 = 1e-5;

/// theta length implied by LAYERS (w + b per layer, gamma/beta on hidden).
pub fn theta_size() -> usize {
    let mut n = 0;
    for (i, (fi, fo)) in LAYERS.iter().enumerate() {
        n += fi * fo + fo;
        if i < LAYERS.len() - 1 {
            n += 2 * fo;
        }
    }
    n
}

/// bn state length (mu + var per hidden layer).
pub fn bn_size() -> usize {
    LAYERS[..LAYERS.len() - 1].iter().map(|(_, fo)| 2 * fo).sum()
}

/// Inference forward for a batch of standardized feature rows.
pub fn forward(theta: &[f32], bn: &[f32], xs: &[[f32; FEATURE_DIM]]) -> Vec<f32> {
    assert_eq!(theta.len(), theta_size(), "theta blob size mismatch");
    assert_eq!(bn.len(), bn_size(), "bn blob size mismatch");
    let mut out = Vec::with_capacity(xs.len());
    let mut h = vec![0f32; 256];
    let mut h2 = vec![0f32; 256];
    for x in xs {
        let mut cur: Vec<f32> = x.to_vec();
        let mut toff = 0usize;
        let mut boff = 0usize;
        for (li, &(fi, fo)) in LAYERS.iter().enumerate() {
            let w = &theta[toff..toff + fi * fo];
            toff += fi * fo;
            let b = &theta[toff..toff + fo];
            toff += fo;
            h.clear();
            h.resize(fo, 0.0);
            // dense: cur[fi] @ w[fi,fo] + b
            for (i, &xi) in cur.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row = &w[i * fo..(i + 1) * fo];
                for (hj, wj) in h.iter_mut().zip(row) {
                    *hj += xi * wj;
                }
            }
            for (hj, bj) in h.iter_mut().zip(b) {
                *hj += bj;
            }
            if li < LAYERS.len() - 1 {
                let gamma = &theta[toff..toff + fo];
                toff += fo;
                let beta = &theta[toff..toff + fo];
                toff += fo;
                let mu = &bn[boff..boff + fo];
                let var = &bn[boff + fo..boff + 2 * fo];
                boff += 2 * fo;
                h2.clear();
                h2.resize(fo, 0.0);
                for j in 0..fo {
                    let r = h[j].max(0.0); // ReLU
                    let z = (r - mu[j]) / (var[j] + BN_EPS).sqrt();
                    h2[j] = z * gamma[j] + beta[j];
                }
                std::mem::swap(&mut cur, &mut h2);
                cur.truncate(fo);
            } else {
                out.push(1.0 / (1.0 + (-h[0]).exp())); // sigmoid head
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_manifest_convention() {
        // 32*256+256 + 2*256 | 256*128+128 + 2*128 | 128*64+64 + 2*64 | 64+1
        assert_eq!(theta_size(), 8192 + 256 + 512 + 32768 + 128 + 256 + 8192 + 64 + 128 + 64 + 1);
        assert_eq!(bn_size(), 2 * (256 + 128 + 64));
    }

    #[test]
    fn forward_outputs_in_unit_interval() {
        let theta: Vec<f32> = (0..theta_size())
            .map(|i| ((i * 31 % 97) as f32 / 97.0 - 0.5) * 0.1)
            .collect();
        let mut bn = vec![0f32; bn_size()];
        // var slots must be positive: layout is mu,var per layer
        let mut off = 0;
        for (_, fo) in &LAYERS[..3] {
            for v in &mut bn[off + fo..off + 2 * fo] {
                *v = 1.0;
            }
            off += 2 * fo;
        }
        let xs = vec![[0.3f32; FEATURE_DIM], [-1.0; FEATURE_DIM]];
        let ys = forward(&theta, &bn, &xs);
        assert_eq!(ys.len(), 2);
        assert!(ys.iter().all(|y| *y > 0.0 && *y < 1.0));
        assert_ne!(ys[0], ys[1]);
    }
}
