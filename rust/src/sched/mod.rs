//! Scheduling Simulator (paper §IV-B): converts the task set T into a task
//! distribution `{T_1 .. T_NSM} = M(T, S)` (Eq. 2) — a partition of tasks
//! across SMs.
//!
//! Three policies, matching the paper's taxonomy:
//!  * [`hardware_rr`] — the GigaThread engine's inferred round-robin for
//!    conventional kernels;
//!  * [`persistent`] — the static software tile scheduler of persistent
//!    (ping-pong / Stream-K style) kernels;
//!  * [`minheap`] — FlashInfer FA3's cost-balancing MinHeap scheduler.
//!
//! The distribution is *closed-form over run-length task groups*: instead
//! of materializing one index vector per SM (O(num_tasks) allocation per
//! request), it records per-group spans and derives per-(SM, group) task
//! counts arithmetically. The cyclic policies (round-robin, persistent
//! strided) need no storage beyond the group prefix table; only the
//! data-dependent MinHeap result stores explicit per-SM runs.

pub mod hardware_rr;
pub mod minheap;
pub mod persistent;

use crate::hw::GpuSpec;
use crate::kernels::{Decomposition, Paradigm};

/// How task groups map onto SMs.
#[derive(Debug, Clone)]
enum Plan {
    /// Task with global launch index `i` runs on SM `i % num_sms` — the
    /// closed form shared by hardware round-robin and the strided
    /// persistent scheduler (worker = i % (nsm·occ) and SM = worker % nsm
    /// compose to i % nsm because nsm divides the worker count).
    Cyclic,
    /// Explicit per-SM `(group index, task count)` runs for data-dependent
    /// schedules (MinHeap over non-uniform costs), listed in the order the
    /// reference per-task schedule would enumerate each SM's tasks.
    PerSm(Vec<Vec<(u32, u64)>>),
}

/// A partition of the task set across SMs in grouped, closed form. Per-SM
/// aggregates are derived as Σ_g count(g, j) · metric(g) — O(num_groups)
/// per SM rather than O(tasks per SM).
#[derive(Debug, Clone)]
pub struct TaskDistribution {
    num_sms: usize,
    /// Global start offset of each group in launch order (prefix sums).
    starts: Vec<u64>,
    /// Task count of each group (mirrors the decomposition).
    counts: Vec<u64>,
    plan: Plan,
}

impl TaskDistribution {
    fn spans(decomp: &Decomposition) -> (Vec<u64>, Vec<u64>) {
        let mut starts = Vec::with_capacity(decomp.num_groups());
        let mut counts = Vec::with_capacity(decomp.num_groups());
        let mut off = 0u64;
        for g in &decomp.task_groups {
            starts.push(off);
            counts.push(g.count);
            off += g.count;
        }
        (starts, counts)
    }

    /// Closed-form cyclic distribution (task i → SM i % num_sms).
    pub(crate) fn cyclic(decomp: &Decomposition, num_sms: usize) -> TaskDistribution {
        let (starts, counts) = Self::spans(decomp);
        TaskDistribution { num_sms, starts, counts, plan: Plan::Cyclic }
    }

    /// Distribution with explicit per-SM `(group, count)` runs.
    pub(crate) fn per_sm(
        decomp: &Decomposition,
        num_sms: usize,
        sm_groups: Vec<Vec<(u32, u64)>>,
    ) -> TaskDistribution {
        debug_assert_eq!(sm_groups.len(), num_sms);
        let (starts, counts) = Self::spans(decomp);
        TaskDistribution { num_sms, starts, counts, plan: Plan::PerSm(sm_groups) }
    }

    pub fn num_sms(&self) -> usize {
        self.num_sms
    }

    pub fn num_tasks(&self) -> usize {
        self.counts.iter().map(|&c| c as usize).sum()
    }

    pub fn num_groups(&self) -> usize {
        self.counts.len()
    }

    /// How many tasks of group `g` land on SM `j`.
    pub fn group_count_on_sm(&self, g: usize, j: usize) -> u64 {
        match &self.plan {
            Plan::Cyclic => {
                let c = self.counts[g];
                let nsm = self.num_sms as u64;
                // first index of the run with residue j, relative to start
                let off = (j as u64 + nsm - self.starts[g] % nsm) % nsm;
                if off >= c {
                    0
                } else {
                    1 + (c - 1 - off) / nsm
                }
            }
            Plan::PerSm(sms) => sms[j]
                .iter()
                .filter(|&&(gi, _)| gi as usize == g)
                .map(|&(_, c)| c)
                .sum(),
        }
    }

    /// Visit SM `j`'s `(group index, task count)` runs in schedule order.
    pub fn visit_sm(&self, j: usize, mut f: impl FnMut(usize, u64)) {
        match &self.plan {
            Plan::Cyclic => {
                let nsm = self.num_sms as u64;
                for (g, (&start, &c)) in self.starts.iter().zip(&self.counts).enumerate() {
                    if c == 0 {
                        continue;
                    }
                    let off = (j as u64 + nsm - start % nsm) % nsm;
                    if off < c {
                        f(g, 1 + (c - 1 - off) / nsm);
                    }
                }
            }
            Plan::PerSm(sms) => {
                for &(g, c) in &sms[j] {
                    f(g as usize, c);
                }
            }
        }
    }

    /// Number of tasks assigned to SM `j`.
    pub fn tasks_on_sm(&self, j: usize) -> u64 {
        let mut n = 0u64;
        self.visit_sm(j, |_, c| n += c);
        n
    }

    /// Per-SM sums of an additive per-task metric; `per_task` is keyed by
    /// *group* index (all tasks of a group share the metric value).
    pub fn sm_sums<F: Fn(usize) -> f64>(&self, per_task: F) -> Vec<f64> {
        (0..self.num_sms)
            .map(|j| {
                let mut s = 0.0;
                self.visit_sm(j, |g, c| s += c as f64 * per_task(g));
                s
            })
            .collect()
    }

    /// Max over SMs of an additive per-task metric (keyed by group index).
    pub fn max_sm_sum<F: Fn(usize) -> f64>(&self, per_task: F) -> f64 {
        (0..self.num_sms)
            .map(|j| {
                let mut s = 0.0;
                self.visit_sm(j, |g, c| s += c as f64 * per_task(g));
                s
            })
            .fold(0.0, f64::max)
    }
}

/// Dispatch on the kernel's execution paradigm.
pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    match decomp.paradigm {
        Paradigm::HardwareRR => hardware_rr::schedule(decomp, gpu),
        Paradigm::PersistentTile => persistent::schedule(decomp, gpu),
        Paradigm::MinHeap => minheap::schedule(decomp, gpu),
    }
}

#[cfg(test)]
pub(crate) fn assert_is_partition(dist: &TaskDistribution, decomp: &Decomposition) {
    assert_eq!(dist.num_tasks(), decomp.num_tasks(), "distribution lost tasks");
    assert_eq!(dist.num_groups(), decomp.num_groups());
    for (g, grp) in decomp.task_groups.iter().enumerate() {
        let spread: u64 = (0..dist.num_sms()).map(|j| dist.group_count_on_sm(g, j)).sum();
        assert_eq!(spread, grp.count, "group {g} tasks lost or duplicated");
    }
}
