//! Scheduling Simulator (paper §IV-B): converts the task set T into a task
//! distribution `{T_1 .. T_NSM} = M(T, S)` (Eq. 2) — a partition of task
//! indices across SMs.
//!
//! Three policies, matching the paper's taxonomy:
//!  * [`hardware_rr`] — the GigaThread engine's inferred round-robin for
//!    conventional kernels;
//!  * [`persistent`] — the static software tile scheduler of persistent
//!    (ping-pong / Stream-K style) kernels;
//!  * [`minheap`] — FlashInfer FA3's cost-balancing MinHeap scheduler.

pub mod hardware_rr;
pub mod minheap;
pub mod persistent;

use crate::hw::GpuSpec;
use crate::kernels::{Decomposition, Paradigm};

/// A partition of task indices across SMs: `assignment[j]` holds the indices
/// of the tasks executed by SM j. The sets are disjoint and their union is
/// the full task set (checked by the property tests).
#[derive(Debug, Clone)]
pub struct TaskDistribution {
    pub assignment: Vec<Vec<usize>>,
}

impl TaskDistribution {
    pub fn num_sms(&self) -> usize {
        self.assignment.len()
    }

    pub fn num_tasks(&self) -> usize {
        self.assignment.iter().map(|v| v.len()).sum()
    }

    /// Max over SMs of an additive per-task metric.
    pub fn max_sm_sum<F: Fn(usize) -> f64>(&self, metric: F) -> f64 {
        self.assignment
            .iter()
            .map(|tasks| tasks.iter().map(|&i| metric(i)).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Per-SM sums of an additive metric.
    pub fn sm_sums<F: Fn(usize) -> f64>(&self, metric: F) -> Vec<f64> {
        self.assignment
            .iter()
            .map(|tasks| tasks.iter().map(|&i| metric(i)).sum::<f64>())
            .collect()
    }
}

/// Dispatch on the kernel's execution paradigm.
pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    match decomp.paradigm {
        Paradigm::HardwareRR => hardware_rr::schedule(decomp, gpu),
        Paradigm::PersistentTile => persistent::schedule(decomp, gpu),
        Paradigm::MinHeap => minheap::schedule(decomp, gpu),
    }
}

#[cfg(test)]
pub(crate) fn assert_is_partition(dist: &TaskDistribution, n_tasks: usize) {
    let mut seen = vec![false; n_tasks];
    for sm in &dist.assignment {
        for &t in sm {
            assert!(t < n_tasks, "task index out of range");
            assert!(!seen[t], "task {t} assigned twice");
            seen[t] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "some tasks unassigned");
}
