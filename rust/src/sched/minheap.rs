//! FlashInfer FA3's MinHeap software scheduler (§V-A: replicated "with
//! around 40 code lines"). Persistent workers are kept in a min-heap keyed
//! by accumulated estimated cost; each incoming task goes to the currently
//! least-loaded worker. Deterministic (ties broken by worker id), so the
//! simulator reproduces the real kernel's assignment given the same cost
//! estimates.
//!
//! Two grouped fast paths keep the analytical pipeline off the per-task
//! allocation: a uniform-cost task set reduces to cyclic assignment
//! (proved below), and the general data-dependent walk records only
//! per-worker `(group, count)` runs instead of index vectors.

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64 wrapper with total ordering for heap keys.
#[derive(PartialEq, PartialOrd)]
struct F(f64);
impl Eq for F {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Generic min-heap balanced assignment over `workers` bins given per-task
/// costs; returns per-worker task lists. Shared with the oracle (which uses
/// jittered "actual" costs instead of analytic hints).
pub fn balance(costs: &[f64], workers: usize) -> Vec<Vec<usize>> {
    let mut heap: BinaryHeap<Reverse<(F, usize)>> =
        (0..workers).map(|w| Reverse((F(0.0), w))).collect();
    let mut bins = vec![Vec::new(); workers];
    for (i, &c) in costs.iter().enumerate() {
        let Reverse((F(load), w)) = heap.pop().expect("non-empty heap");
        bins[w].push(i);
        heap.push(Reverse((F(load + c), w)));
    }
    bins
}

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    let nsm = gpu.num_sms as usize;
    let occ = decomp.cta.occupancy(gpu) as usize;
    let workers = nsm * occ.max(1);
    let groups = &decomp.task_groups;

    // Uniform positive costs reduce to cyclic assignment: by induction,
    // when task i arrives the workers with the fewest tasks are exactly
    // {i % W .. W-1}, all at equal load, so the id tie-break pops worker
    // i % W — and SM = worker % nsm = i % nsm since nsm divides W. This is
    // bit-identical to running [`balance`] over the expanded cost vector.
    let first_cost = groups.first().map_or(0.0, |g| g.template.cost_hint);
    let uniform = first_cost > 0.0
        && groups.iter().all(|g| g.template.cost_hint == first_cost);
    if uniform {
        return TaskDistribution::cyclic(decomp, nsm);
    }

    // Data-dependent case: replicate the reference per-task heap walk
    // exactly (same pop order, same repeated-addition load updates), but
    // record per-worker (group, count) runs instead of task indices.
    let mut heap: BinaryHeap<Reverse<(F, usize)>> =
        (0..workers).map(|w| Reverse((F(0.0), w))).collect();
    let mut bins: Vec<Vec<(u32, u64)>> = vec![Vec::new(); workers];
    for (g, grp) in groups.iter().enumerate() {
        let cost = grp.template.cost_hint;
        for _ in 0..grp.count {
            let Reverse((F(load), w)) = heap.pop().expect("non-empty heap");
            match bins[w].last_mut() {
                Some((lg, c)) if *lg as usize == g => *c += 1,
                _ => bins[w].push((g as u32, 1)),
            }
            heap.push(Reverse((F(load + cost), w)));
        }
    }

    // Fold workers onto SMs in worker order (w → SM w % nsm), merging
    // adjacent same-group runs; per-SM run order matches the reference
    // concatenation bin(j) ++ bin(j + nsm) ++ …
    let mut sm_groups: Vec<Vec<(u32, u64)>> = vec![Vec::new(); nsm];
    for (w, runs) in bins.into_iter().enumerate() {
        let sm = &mut sm_groups[w % nsm];
        for (g, c) in runs {
            match sm.last_mut() {
                Some((lg, lc)) if *lg == g => *lc += c,
                _ => sm.push((g, c)),
            }
        }
    }
    TaskDistribution::per_sm(decomp, nsm, sm_groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::KernelConfig;

    #[test]
    fn balance_evens_out_variable_costs() {
        // strongly increasing costs (causal attention shape)
        let costs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let bins = balance(&costs, 4);
        let sums: Vec<f64> = bins
            .iter()
            .map(|b| b.iter().map(|&i| costs[i]).sum::<f64>())
            .collect();
        let max = sums.iter().cloned().fold(0.0, f64::max);
        let min = sums.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 1.1, "minheap should balance: {sums:?}");
    }

    #[test]
    fn beats_round_robin_on_skewed_work() {
        let costs: Vec<f64> = (0..64).map(|i| ((i % 8) * (i % 8)) as f64 + 1.0).collect();
        let mh = balance(&costs, 8);
        let mh_max: f64 = mh
            .iter()
            .map(|b| b.iter().map(|&i| costs[i]).sum::<f64>())
            .fold(0.0, f64::max);
        let rr_max: f64 = (0..8)
            .map(|w| costs.iter().enumerate().filter(|(i, _)| i % 8 == w).map(|(_, c)| c).sum())
            .fold(0.0, f64::max);
        assert!(mh_max <= rr_max);
    }

    #[test]
    fn full_partition_on_fa3() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = KernelConfig::Attention {
            batch: vec![(4096, 4096), (100, 2000)],
            nh: 16,
            nkv: 4,
            hd: 128,
            causal: true,
            fa3: true,
        }
        .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, &d);
    }

    #[test]
    fn grouped_walk_matches_expanded_balance() {
        // per-(SM, group) counts from the grouped heap walk must equal the
        // reference: balance() over the expanded cost vector, workers
        // folded onto SMs in worker order
        let gpu = gpu_by_name("H20").unwrap();
        let d = KernelConfig::Attention {
            batch: vec![(2048, 2048), (511, 700), (64, 4096)],
            nh: 4,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: true,
        }
        .decompose(&gpu);
        let dist = schedule(&d, &gpu);

        let nsm = gpu.num_sms as usize;
        let workers = nsm * d.cta.occupancy(&gpu).max(1) as usize;
        let costs: Vec<f64> = d.iter_tasks().map(|t| t.cost_hint).collect();
        // task index -> group index map
        let mut task_group = Vec::with_capacity(costs.len());
        for (g, grp) in d.task_groups.iter().enumerate() {
            task_group.extend(std::iter::repeat_n(g, grp.count as usize));
        }
        let mut expect = vec![vec![0u64; d.num_groups()]; nsm];
        for (w, bin) in balance(&costs, workers).into_iter().enumerate() {
            for i in bin {
                expect[w % nsm][task_group[i]] += 1;
            }
        }
        for (j, row) in expect.iter().enumerate() {
            for (g, &want) in row.iter().enumerate() {
                assert_eq!(dist.group_count_on_sm(g, j), want, "sm {j} group {g}");
            }
        }
    }

    #[test]
    fn uniform_costs_reduce_to_cyclic() {
        // non-causal equal-length batch: every task has the same cost, so
        // the heap walk must match plain round-robin over workers
        let gpu = gpu_by_name("H800").unwrap();
        let d = KernelConfig::Attention {
            batch: vec![(1024, 1024); 3],
            nh: 8,
            nkv: 8,
            hd: 128,
            causal: false,
            fa3: true,
        }
        .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, &d);
        let nsm = gpu.num_sms as usize;
        for j in 0..nsm {
            let expect = (d.num_tasks() + nsm - 1 - j) / nsm; // ceil((n - j) / nsm)
            assert_eq!(dist.tasks_on_sm(j), expect as u64, "sm {j}");
        }
    }
}
