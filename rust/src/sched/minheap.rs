//! FlashInfer FA3's MinHeap software scheduler (§V-A: replicated "with
//! around 40 code lines"). Persistent workers are kept in a min-heap keyed
//! by accumulated estimated cost; each incoming task goes to the currently
//! least-loaded worker. Deterministic (ties broken by worker id), so the
//! simulator reproduces the real kernel's assignment given the same cost
//! estimates.

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// f64 wrapper with total ordering for heap keys.
#[derive(PartialEq, PartialOrd)]
struct F(f64);
impl Eq for F {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Generic min-heap balanced assignment over `workers` bins given per-task
/// costs; returns per-worker task lists. Shared with the oracle (which uses
/// jittered "actual" costs instead of analytic hints).
pub fn balance(costs: &[f64], workers: usize) -> Vec<Vec<usize>> {
    let mut heap: BinaryHeap<Reverse<(F, usize)>> =
        (0..workers).map(|w| Reverse((F(0.0), w))).collect();
    let mut bins = vec![Vec::new(); workers];
    for (i, &c) in costs.iter().enumerate() {
        let Reverse((F(load), w)) = heap.pop().expect("non-empty heap");
        bins[w].push(i);
        heap.push(Reverse((F(load + c), w)));
    }
    bins
}

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    let nsm = gpu.num_sms as usize;
    let occ = decomp.cta.occupancy(gpu) as usize;
    let workers = nsm * occ.max(1);
    let costs: Vec<f64> = decomp.tasks.iter().map(|t| t.cost_hint).collect();
    let bins = balance(&costs, workers);
    let mut assignment = vec![Vec::new(); nsm];
    for (w, tasks) in bins.into_iter().enumerate() {
        assignment[w % nsm].extend(tasks);
    }
    TaskDistribution { assignment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::KernelConfig;

    #[test]
    fn balance_evens_out_variable_costs() {
        // strongly increasing costs (causal attention shape)
        let costs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let bins = balance(&costs, 4);
        let sums: Vec<f64> = bins
            .iter()
            .map(|b| b.iter().map(|&i| costs[i]).sum::<f64>())
            .collect();
        let max = sums.iter().cloned().fold(0.0, f64::max);
        let min = sums.iter().cloned().fold(f64::MAX, f64::min);
        assert!(max / min < 1.1, "minheap should balance: {sums:?}");
    }

    #[test]
    fn beats_round_robin_on_skewed_work() {
        let costs: Vec<f64> = (0..64).map(|i| ((i % 8) * (i % 8)) as f64 + 1.0).collect();
        let mh = balance(&costs, 8);
        let mh_max: f64 = mh
            .iter()
            .map(|b| b.iter().map(|&i| costs[i]).sum::<f64>())
            .fold(0.0, f64::max);
        let rr_max: f64 = (0..8)
            .map(|w| costs.iter().enumerate().filter(|(i, _)| i % 8 == w).map(|(_, c)| c).sum())
            .fold(0.0, f64::max);
        assert!(mh_max <= rr_max);
    }

    #[test]
    fn full_partition_on_fa3() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = KernelConfig::Attention {
            batch: vec![(4096, 4096), (100, 2000)],
            nh: 16,
            nkv: 4,
            hd: 128,
            causal: true,
            fa3: true,
        }
        .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, d.num_tasks());
    }
}
