//! Software tile scheduler for persistent kernels (cuBLAS ping-pong GEMM /
//! CUTLASS persistent kernels [27],[50],[71]). One long-lived CTA per
//! occupancy slot stays resident on its SM and pulls tile indices from a
//! global counter — i.e. tiles are strided across workers in launch order,
//! which is fully deterministic and therefore exactly reproducible by the
//! simulator (this is why gemm9's max-SM error in Table VII is ~0.04%).
//!
//! At SM granularity the strided worker walk is cyclic: worker =
//! i % (nsm·occ) and SM = worker % nsm compose to SM = i % nsm because nsm
//! divides the worker count, so the distribution shares the round-robin
//! closed form (the per-worker split only matters to the oracle, which
//! replays it over the expanded task list).

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    TaskDistribution::cyclic(decomp, gpu.num_sms as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig, Paradigm};

    #[test]
    fn strided_and_complete() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = KernelConfig::Gemm { m: 8192, n: 8192, k: 1024, dtype: DType::Bf16 }
            .decompose(&gpu);
        assert_eq!(d.paradigm, Paradigm::PersistentTile);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, &d);
    }

    #[test]
    fn workers_scale_with_occupancy() {
        let gpu = gpu_by_name("H800").unwrap();
        let d = KernelConfig::Gemm { m: 131072, n: 8192, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        // every SM busy for a grid this large
        assert!((0..dist.num_sms()).all(|j| dist.tasks_on_sm(j) > 0));
    }

    #[test]
    fn worker_stride_folds_to_sm_cycle() {
        // the invariant the closed form rests on: (i % (nsm*occ)) % nsm
        // == i % nsm for every task index
        let gpu = gpu_by_name("H100").unwrap();
        let d = KernelConfig::Gemm { m: 4096, n: 4096, k: 2048, dtype: DType::Bf16 }
            .decompose(&gpu);
        let nsm = gpu.num_sms as usize;
        let workers = nsm * d.cta.occupancy(&gpu) as usize;
        for i in (0..d.num_tasks()).step_by(37) {
            assert_eq!((i % workers) % nsm, i % nsm);
        }
    }
}
