//! Software tile scheduler for persistent kernels (cuBLAS ping-pong GEMM /
//! CUTLASS persistent kernels [27],[50],[71]). One long-lived CTA per
//! occupancy slot stays resident on its SM and pulls tile indices from a
//! global counter — i.e. tiles are strided across workers in launch order,
//! which is fully deterministic and therefore exactly reproducible by the
//! simulator (this is why gemm9's max-SM error in Table VII is ~0.04%).

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    let nsm = gpu.num_sms as usize;
    let occ = decomp.cta.occupancy(gpu) as usize;
    let workers = nsm * occ;
    let mut assignment = vec![Vec::new(); nsm];
    for i in 0..decomp.tasks.len() {
        let worker = i % workers;
        assignment[worker % nsm].push(i);
    }
    TaskDistribution { assignment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig, Paradigm};

    #[test]
    fn strided_and_complete() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = KernelConfig::Gemm { m: 8192, n: 8192, k: 1024, dtype: DType::Bf16 }
            .decompose(&gpu);
        assert_eq!(d.paradigm, Paradigm::PersistentTile);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, d.num_tasks());
    }

    #[test]
    fn workers_scale_with_occupancy() {
        let gpu = gpu_by_name("H800").unwrap();
        let d = KernelConfig::Gemm { m: 131072, n: 8192, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        // every SM busy for a grid this large
        assert!(dist.assignment.iter().all(|v| !v.is_empty()));
    }
}
