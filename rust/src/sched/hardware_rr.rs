//! Hardware (GigaThread engine) scheduler model. Its exact policy is
//! undocumented; following the empirical literature the paper cites
//! [18],[20],[21],[28],[30],[31],[35],[65],[79], we model it as round-robin:
//! each SM receives one CTA per round until resource limits are reached,
//! and thereafter CTAs backfill as predecessors retire. With the analytical
//! (count-based) view — no execution times available at this stage — the
//! retire-driven steady state reduces to cyclic assignment.
//!
//! This *static* approximation is exactly what the paper contrasts with the
//! dynamic reality for variable-latency workloads (causal attention): the
//! oracle's finish-time-aware dispatch produces slightly different per-SM
//! maxima, reproducing the FA2 gap in Table VII.

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    let nsm = gpu.num_sms as usize;
    let mut assignment = vec![Vec::new(); nsm];
    for (i, _) in decomp.tasks.iter().enumerate() {
        assignment[i % nsm].push(i);
    }
    TaskDistribution { assignment }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig};

    #[test]
    fn balanced_counts() {
        let gpu = gpu_by_name("A100").unwrap();
        let d = KernelConfig::Gemm { m: 4096, n: 4096, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, d.num_tasks());
        let (min, max) = dist
            .assignment
            .iter()
            .map(|v| v.len())
            .fold((usize::MAX, 0), |(lo, hi), l| (lo.min(l), hi.max(l)));
        assert!(max - min <= 1, "RR must balance counts: {min}..{max}");
    }

    #[test]
    fn fewer_tasks_than_sms() {
        let gpu = gpu_by_name("H800").unwrap();
        let d = KernelConfig::RmsNorm { seq: 7, dim: 1024 }.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, 7);
        assert_eq!(dist.assignment.iter().filter(|v| !v.is_empty()).count(), 7);
    }
}
