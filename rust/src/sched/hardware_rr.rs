//! Hardware (GigaThread engine) scheduler model. Its exact policy is
//! undocumented; following the empirical literature the paper cites
//! [18],[20],[21],[28],[30],[31],[35],[65],[79], we model it as round-robin:
//! each SM receives one CTA per round until resource limits are reached,
//! and thereafter CTAs backfill as predecessors retire. With the analytical
//! (count-based) view — no execution times available at this stage — the
//! retire-driven steady state reduces to cyclic assignment, which the
//! distribution expresses in closed form (no index vectors: task i → SM
//! i % num_sms is pure arithmetic over the group spans).
//!
//! This *static* approximation is exactly what the paper contrasts with the
//! dynamic reality for variable-latency workloads (causal attention): the
//! oracle's finish-time-aware dispatch produces slightly different per-SM
//! maxima, reproducing the FA2 gap in Table VII.

use super::TaskDistribution;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;

pub fn schedule(decomp: &Decomposition, gpu: &GpuSpec) -> TaskDistribution {
    TaskDistribution::cyclic(decomp, gpu.num_sms as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig};

    #[test]
    fn balanced_counts() {
        let gpu = gpu_by_name("A100").unwrap();
        let d = KernelConfig::Gemm { m: 4096, n: 4096, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, &d);
        let (min, max) = (0..dist.num_sms())
            .map(|j| dist.tasks_on_sm(j))
            .fold((u64::MAX, 0), |(lo, hi), l| (lo.min(l), hi.max(l)));
        assert!(max - min <= 1, "RR must balance counts: {min}..{max}");
    }

    #[test]
    fn fewer_tasks_than_sms() {
        let gpu = gpu_by_name("H800").unwrap();
        let d = KernelConfig::RmsNorm { seq: 7, dim: 1024 }.decompose(&gpu);
        let dist = schedule(&d, &gpu);
        super::super::assert_is_partition(&dist, &d);
        assert_eq!((0..dist.num_sms()).filter(|&j| dist.tasks_on_sm(j) > 0).count(), 7);
    }

    #[test]
    fn cyclic_counts_match_reference_modulo_walk() {
        // multi-group case: the closed-form per-(SM, group) counts must
        // agree with an explicit i % nsm walk over the expanded task list
        let gpu = gpu_by_name("A100").unwrap();
        let d = KernelConfig::Attention {
            batch: vec![(700, 900), (300, 3000)],
            nh: 3,
            nkv: 1,
            hd: 128,
            causal: true,
            fa3: false,
        }
        .decompose(&gpu);
        let dist = schedule(&d, &gpu);
        let nsm = gpu.num_sms as usize;
        let mut expect = vec![vec![0u64; d.num_groups()]; nsm];
        let mut i = 0usize;
        for (g, grp) in d.task_groups.iter().enumerate() {
            for _ in 0..grp.count {
                expect[i % nsm][g] += 1;
                i += 1;
            }
        }
        for (j, row) in expect.iter().enumerate() {
            for (g, &want) in row.iter().enumerate() {
                assert_eq!(dist.group_count_on_sm(g, j), want, "sm {j} group {g}");
            }
        }
    }
}
