//! SynPerf CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   dataset     build + cache a per-kernel profiling dataset
//!   train       train a per-kernel MLP (MAPE or P80 pinball loss)
//!   predict     one-shot kernel latency prediction (protocol v1)
//!   e2e         end-to-end LLM inference prediction vs ground truth
//!   serve       run the batching prediction service (synthetic load or
//!               the JSONL stdio wire surface: `serve --stdio`)
//!   tune        model-guided Fused-MoE autotuning (§VII)
//!   experiment  regenerate a paper table/figure (see DESIGN.md §5)

use anyhow::{bail, Context, Result};
use synperf::api::{self, ModelBundle, PredictRequest, Source};
use synperf::dataset;
use synperf::e2e::{llm, predict as e2e_predict, trace, workload};
use synperf::experiments::{self, Lab, ModelFlavor, Scale};
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::util::argp::Args;

fn usage() -> &'static str {
    "synperf <subcommand> [flags]\n\
     \n\
     subcommands:\n\
       dataset    --kernel <k> [--n 420] [--out runs/data/<k>.csv] [--scale fast|normal|full]\n\
       train      --kernel <k> [--p80] [--scale ...]\n\
       predict    --kernel gemm --gpu A100 --m 4096 --n 4096 --k 4096 [--p80] [--strict]\n\
       e2e        --model qwen2.5-14b --gpu H100 [--tp 1] [--pp 1] [--workload arxiv] [--batch 8]\n\
       serve      [--stdio] [--requests 512] [--gpu A100]\n\
                  [--max-batch 256] [--deadline-us 2000] [--queue-cap 1024]\n\
       tune       --gpu A40 [--n 20]\n\
       experiment <table1|table7|fig3|fig4|fig5|table8|scaledmm|fig6|fig7|table9|fig8|table10|all>\n\
     \n\
     kernels: gemm scaled_mm attention rmsnorm silu_mul fused_moe"
}

fn scale_of(args: &Args) -> Scale {
    match args.str_or("scale", "normal").as_str() {
        "fast" => Scale::Fast,
        "full" => Scale::Full,
        _ => Scale::Normal,
    }
}

fn kernel_of(args: &Args) -> Result<KernelKind> {
    let name = args.req("kernel")?;
    Ok(KernelKind::from_name(name).ok_or_else(|| {
        api::PredictError::UnsupportedKernel(format!("unknown kernel {name:?}"))
    })?)
}

fn gpu_of(args: &Args, default: &str) -> Result<hw::GpuSpec> {
    let name = args.str_or("gpu", default);
    Ok(api::resolve_gpu(&name)?)
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let Ok((sub, rest)) = args.subcommand() else {
        println!("{}", usage());
        return Ok(());
    };
    match sub {
        "dataset" => cmd_dataset(&rest),
        "train" => cmd_train(&rest),
        "predict" => cmd_predict(&rest),
        "e2e" => cmd_e2e(&rest),
        "serve" => cmd_serve(&rest),
        "tune" => cmd_tune(&rest),
        "experiment" => cmd_experiment(&rest),
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{}", usage()),
    }
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let scale = scale_of(args);
    let n = args.usize_or("n", scale.n_configs())?;
    let out = args.str_or("out", &format!("runs/data/{}_{}.csv", kind.name(), scale.tag()));
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    eprintln!("building {} dataset: {} configs x 11 GPUs...", kind.name(), n);
    let t0 = std::time::Instant::now();
    let ds = dataset::build(kind, &hw::all_gpus(), n, 0x5EED_CAFE, threads);
    dataset::save(&ds, &out)?;
    println!("wrote {} samples to {} in {:?}", ds.len(), out, t0.elapsed());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let lab = Lab::new(scale_of(args))?;
    let flavor = if args.has("p80") { ModelFlavor::P80 } else { ModelFlavor::SynPerf };
    let t0 = std::time::Instant::now();
    let _pred = lab.model(kind, flavor)?;
    println!(
        "model {} ({:?}) ready in {:?} (cached under runs/models)",
        kind.name(),
        flavor,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let gpu = gpu_of(args, "A100")?;
    let cfg = match kind {
        KernelKind::Gemm => KernelConfig::Gemm {
            m: args.usize_or("m", 4096)? as u32,
            n: args.usize_or("n", 4096)? as u32,
            k: args.usize_or("k", 4096)? as u32,
            dtype: DType::Bf16,
        },
        KernelKind::RmsNorm => KernelConfig::RmsNorm {
            seq: args.usize_or("seq", 4096)? as u32,
            dim: args.usize_or("dim", 8192)? as u32,
        },
        KernelKind::SiluMul => KernelConfig::SiluMul {
            seq: args.usize_or("seq", 4096)? as u32,
            dim: args.usize_or("dim", 13824)? as u32,
        },
        other => {
            return Err(api::PredictError::UnsupportedKernel(format!(
                "predict CLI supports gemm/rmsnorm/silu_mul (got {})",
                other.name()
            ))
            .into())
        }
    };
    // best-effort models: without artifacts the answer is the documented
    // degraded roofline mode, visible in the provenance line below
    let bundle = match Lab::new(scale_of(args)) {
        Ok(lab) => lab.bundle(&[kind]),
        Err(_) => {
            eprintln!("(no artifacts — answering in degraded roofline mode)");
            ModelBundle::default()
        }
    };
    let mut req = PredictRequest::new(cfg.clone(), gpu.clone()).with_breakdown();
    if args.has("p80") {
        req = req.p80();
    }
    if args.has("strict") {
        req = req.strict();
    }
    let resp = api::predict_one(&bundle, &req)?;
    let b = resp.breakdown.as_ref().expect("breakdown requested");
    println!("kernel:        {} on {}", kind.name(), gpu.name);
    println!("theory roof:   {:.3} us", b.theory_sec * 1e6);
    println!("pred eff:      {:.3}", b.theory_sec / resp.latency_sec);
    println!("pred latency:  {:.3} us", resp.latency_sec * 1e6);
    println!(
        "provenance:    {} ({} flavor, cache {})",
        resp.provenance.source.name(),
        resp.flavor.name(),
        if resp.provenance.cache_hit { "hit" } else { "miss" }
    );
    let s = dataset::make_sample(&cfg, &gpu, 0);
    println!("oracle actual: {:.3} us (testbed ground truth)", s.latency_sec * 1e6);
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let lab = Lab::new(scale_of(args))?;
    let model_name = args.str_or("model", "qwen2.5-14b");
    let llm_cfg =
        llm::by_name(&model_name).with_context(|| format!("unknown model {model_name:?}"))?;
    let gpu = gpu_of(args, "A100")?;
    let tp = args.usize_or("tp", 1)? as u32;
    let pp = args.usize_or("pp", 1)? as u32;
    let batch = args.usize_or("batch", 8)?;
    let wk = match args.str_or("workload", "arxiv").as_str() {
        "splitwise" => workload::WorkloadKind::Splitwise,
        _ => workload::WorkloadKind::Arxiv,
    };
    let mut rng = synperf::util::rng::Rng::new(args.u64_or("seed", 7)?);
    let reqs = workload::sample_batch(wk, batch, &mut rng);
    let tr = trace::build_trace(&llm_cfg, tp, pp, &reqs);
    let models = lab.model_set()?;
    let comm = lab.comm(&gpu);
    let t = e2e_predict::eval_trace(&tr, &gpu, tp, &models, &comm, 11)?;
    println!("{} on {} (TP={tp}, PP={pp}), {}_{batch}:", llm_cfg.name, gpu.name, wk.name());
    println!("  ground truth: {:.1} ms", t.actual * 1e3);
    for (name, v) in [
        ("SynPerf", t.synperf),
        ("Roofline", t.roofline),
        ("Linear", t.linear),
        ("Habitat", t.habitat),
        ("Neusight", t.neusight),
    ] {
        println!(
            "  {name:<9} {:.1} ms  (err {:+.1}%)",
            v * 1e3,
            100.0 * (v - t.actual) / t.actual
        );
    }
    if t.degraded_kernels > 0 {
        println!(
            "  note: {} kernel items fell back to the roofline (untrained category)",
            t.degraded_kernels
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use synperf::coordinator::{PredictionService, ServiceConfig};
    let defaults = ServiceConfig::default();
    let cfg = ServiceConfig {
        max_batch: args.usize_or("max-batch", defaults.max_batch)?,
        deadline: std::time::Duration::from_micros(
            args.u64_or("deadline-us", defaults.deadline.as_micros() as u64)?,
        ),
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap)?,
    };
    let scale = scale_of(args);
    // effective config at startup (stderr: stdout carries JSONL in --stdio)
    eprintln!(
        "serve: protocol v{}, max_batch={}, deadline={}us, queue_cap={}",
        api::PROTOCOL_VERSION,
        cfg.max_batch,
        cfg.deadline.as_micros(),
        cfg.queue_cap
    );
    let svc = PredictionService::spawn(
        move || match Lab::new(scale) {
            Ok(lab) => {
                lab.bundle(&[KernelKind::Gemm, KernelKind::RmsNorm, KernelKind::SiluMul])
            }
            Err(_) => {
                eprintln!("(no artifacts — serving degraded roofline answers)");
                ModelBundle::default()
            }
        },
        cfg.clone(),
    );

    if args.has("stdio") {
        // JSONL wire surface: one request per line on stdin, one response
        // per line on stdout (see rust/README.md for the schema). Stdin is
        // wrapped (not locked): the reader moves into serve_lines' reader
        // thread, and StdinLock is not Send.
        let stdout = std::io::stdout();
        let stats = synperf::api::stdio::serve_lines(
            &svc.client(),
            std::io::BufReader::new(std::io::stdin()),
            &mut stdout.lock(),
            cfg.max_batch,
        )?;
        let snap = svc.metrics.snapshot();
        eprintln!(
            "stdio: {} responses ({} errors), mean batch {:.1}, rejected {}, max depth {}",
            stats.served, stats.errors, snap.mean_batch, snap.rejected_requests, snap.max_queue_depth
        );
        svc.shutdown();
        return Ok(());
    }

    // synthetic-load mode: fire n GEMM predictions through the client
    let n = args.usize_or("requests", 512)?;
    let gpu = gpu_of(args, "A100")?;
    let client = svc.client();
    let mut rng = synperf::util::rng::Rng::new(3);
    let t0 = std::time::Instant::now();
    let pendings: Vec<_> = (0..n)
        .map(|_| {
            let cfg = KernelConfig::Gemm {
                m: rng.log_range_u32(16, 32768),
                n: rng.log_range_u32(384, 32768),
                k: rng.log_range_u32(256, 8192),
                dtype: DType::Bf16,
            };
            client.submit(PredictRequest::new(cfg, gpu.clone()))
        })
        .collect::<std::result::Result<_, _>>()?;
    let mut total = 0.0;
    let mut mlp = 0usize;
    for p in pendings {
        let resp = p.wait()?;
        total += resp.latency_sec;
        if resp.provenance.source == Source::Mlp {
            mlp += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = svc.metrics.snapshot();
    println!(
        "served {n} predictions in {wall:?} ({:.0} req/s; {mlp} mlp / {} roofline)",
        n as f64 / wall.as_secs_f64(),
        n - mlp
    );
    println!(
        "mean batch {:.1}, batch p50 {:.0} us, p99 {:.0} us, rejected {}, max queue depth {}",
        snap.mean_batch, snap.p50_us, snap.p99_us, snap.rejected_requests, snap.max_queue_depth
    );
    println!(
        "analysis cache: {} hits / {} misses ({:.0}% hit rate), mean kind-batch {:.1}",
        snap.cache_hits,
        snap.cache_misses,
        100.0 * snap.cache_hit_rate(),
        snap.mean_kind_batch
    );
    let es = synperf::engine::PredictionEngine::global().stats();
    println!("engine cache: {} entries / {} capacity", es.entries, es.capacity);
    println!("sum of predicted latencies: {:.3} s", total);
    svc.shutdown();
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let gpu = gpu_of(args, "A40")?;
    let n = args.usize_or("n", 20)?;
    let configs = dataset::sample_configs(KernelKind::FusedMoe, n, 0x7A7E);
    let mut speedups = Vec::new();
    for (i, cfg) in configs.iter().enumerate() {
        let r = synperf::autotune::tune(cfg, &gpu, 42 + i as u64)?;
        println!(
            "cfg {i:>3}: default {:.1} us -> best {:.1} us  ({:.2}x)  best = {:?}",
            r.default_sec * 1e6,
            r.best_sec * 1e6,
            r.speedup(),
            r.best_cfg
        );
        speedups.push(r.speedup());
    }
    println!(
        "geo-mean speedup on {}: {:.2}x",
        gpu.name,
        synperf::util::stats::geomean(&speedups)
    );
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!("experiment id required (see DESIGN.md §5)");
    };
    let lab = Lab::new(scale_of(args))?;
    let t0 = std::time::Instant::now();
    experiments::run(&lab, id)?;
    eprintln!("[{} done in {:?}]", id, t0.elapsed());
    Ok(())
}
