//! SynPerf CLI — the Layer-3 leader entrypoint.
//!
//! Subcommands:
//!   dataset     build + cache a per-kernel profiling dataset
//!   train       train a per-kernel MLP (MAPE or P80 pinball loss)
//!   predict     one-shot kernel latency prediction (protocol v1)
//!   simulate    declarative end-to-end serving simulation (Scenario API
//!               v1): a ScenarioSpec in, a typed ScenarioReport out —
//!               flags, a JSONL spec file, or stdin (`--spec -`); with
//!               `--cluster`, the Scenario v2 deterministic
//!               continuous-batching cluster simulation (replicas,
//!               routing policies, per-request percentile reports)
//!   e2e         end-to-end prediction vs ground truth (a scenario
//!               simulation printed as the paper's method comparison)
//!   sweep       fleet-scale hardware search: a declarative grid over
//!               GPUs x tp x pp x replicas x policies x workloads,
//!               streamed as one JSONL row per config plus a Pareto
//!               frontier over (tokens/sec, SLO attainment, GPU count);
//!               crash-safe and shardable (`--shard I/N`, `--journal`
//!               with `--resume`, `--point-timeout-ms` watchdog)
//!   sweep-merge deterministic merge of one campaign's shard journals
//!               back into the full row stream + recomputed frontier
//!   gpus        list the Table-VI hardware registry (seen/unseen split,
//!               headline compute:memory ratios)
//!   serve       run the batching prediction service (synthetic load or
//!               the JSONL stdio wire surface: `serve --stdio`; speaks
//!               the predict, simulate, sweep and tune verbs)
//!   tune        ceiling-guided Fused-MoE autotuning (§VII): a declarative
//!               TuneSpec over the Table-VI registry, diagnosed against
//!               the P80 ceiling (roofline fallback recorded in
//!               provenance), streamed as one JSONL row per point plus a
//!               summary line (geomean speedups, gap closure)
//!   experiment  regenerate a paper table/figure (see DESIGN.md §5)

use anyhow::{bail, Result};
use synperf::api::{self, ModelBundle, PredictRequest, Source};
use synperf::dataset;
use synperf::experiments::{self, Lab, ModelFlavor, Scale};
use synperf::hw;
use synperf::kernels::{DType, KernelConfig, KernelKind};
use synperf::scenario::wire::SimulateRequest;
use synperf::scenario::{
    self, ArrivalSpec, ClusterReport, ClusterSpec, Method, OpClass, Phase, PhaseSelection,
    RoutePolicy, ScenarioReport, ScenarioSpec, Simulator, WorkloadSpec,
};
use synperf::util::argp::Args;

fn usage() -> &'static str {
    "synperf <subcommand> [flags]\n\
     \n\
     subcommands:\n\
       dataset    --kernel <k> [--n 420] [--out runs/data/<k>.csv] [--scale fast|normal|full]\n\
       train      --kernel <k> [--p80] [--scale ...]\n\
       predict    --kernel gemm --gpu A100 --m 4096 --n 4096 --k 4096 [--p80] [--strict]\n\
       simulate   --model qwen2.5-14b --gpu A100 [--tp 1] [--pp 1]\n\
                  [--workload arxiv|splitwise] [--batch 8] [--requests 1000:200,...]\n\
                  [--phases both|prefill|decode] [--seed 7] [--host-gap-us 0.8]\n\
                  [--threads N] [--json] | [--spec <file|->]\n\
                  --cluster [--replicas 1] [--policy round_robin|least_loaded|session_affinity]\n\
                  [--rate 4.0 | --gap-ms 250] [--n 16] [--max-batch 16]\n\
                  [--kv-tokens 262144] [--kv-quant 16] [--slo-ttft-ms 2000] [--slo-tpot-ms 200]\n\
       e2e        --model qwen2.5-14b --gpu H100 [--tp 1] [--pp 1] [--workload arxiv] [--batch 8]\n\
                  [--threads N]\n\
       sweep      --spec <file|-> [--threads N] [--shard I/N] [--journal PATH [--resume]]\n\
                  [--point-timeout-ms T] [--json]\n\
       sweep-merge <journal> <journal> ... [--json]\n\
       gpus\n\
       serve      [--stdio | --tcp ADDR] [--requests 512] [--gpu A100] [--threads N]\n\
                  [--max-batch 256] [--deadline-us 2000] [--queue-cap 1024]\n\
                  [--max-clients 64] [--inbox-cap 64] [--max-inflight 32]\n\
                  [--admit-timeout-ms 2000] [--idle-timeout-ms 60000] [--quarantine-limit 8]\n\
       tune       --spec <file|-> [--threads N] [--json]\n\
       experiment <table1|table7|fig3|fig4|fig5|table8|scaledmm|fig6|fig7|table9|fig8|table10|all>\n\
     \n\
     kernels: gemm scaled_mm attention rmsnorm silu_mul fused_moe\n\
     models:  see llm::registry() — qwen2.5-14b qwen2.5-32b qwen3-32b llama3.1-70b llama3.1-8b"
}

fn scale_of(args: &Args) -> Scale {
    match args.str_or("scale", "normal").as_str() {
        "fast" => Scale::Fast,
        "full" => Scale::Full,
        _ => Scale::Normal,
    }
}

fn kernel_of(args: &Args) -> Result<KernelKind> {
    let name = args.req("kernel")?;
    Ok(KernelKind::from_name(name).ok_or_else(|| {
        api::PredictError::UnsupportedKernel(format!("unknown kernel {name:?}"))
    })?)
}

fn gpu_of(args: &Args, default: &str) -> Result<hw::GpuSpec> {
    let name = args.str_or("gpu", default);
    Ok(api::resolve_gpu(&name)?)
}

/// `--threads` on `simulate`/`serve`/`e2e`: worker-thread count for the
/// two-pass parallel evaluator and the service routing pass. Outputs are
/// bit-identical at any value — this is purely a wall-clock knob.
fn threads_of(args: &Args) -> Result<usize> {
    Ok(args.usize_or("threads", synperf::engine::par::default_threads())?.max(1))
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let Ok((sub, rest)) = args.subcommand() else {
        println!("{}", usage());
        return Ok(());
    };
    match sub {
        "dataset" => cmd_dataset(&rest),
        "train" => cmd_train(&rest),
        "predict" => cmd_predict(&rest),
        "simulate" => cmd_simulate(&rest),
        "sweep" => cmd_sweep(&rest),
        "sweep-merge" => cmd_sweep_merge(&rest),
        "gpus" => cmd_gpus(),
        "e2e" => cmd_e2e(&rest),
        "serve" => cmd_serve(&rest),
        "tune" => cmd_tune(&rest),
        "experiment" => cmd_experiment(&rest),
        "help" | "--help" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown subcommand {other:?}\n{}", usage()),
    }
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let scale = scale_of(args);
    let n = args.usize_or("n", scale.n_configs())?;
    let out = args.str_or("out", &format!("runs/data/{}_{}.csv", kind.name(), scale.tag()));
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    eprintln!("building {} dataset: {} configs x 11 GPUs...", kind.name(), n);
    let t0 = std::time::Instant::now();
    let ds = dataset::build(kind, &hw::all_gpus(), n, 0x5EED_CAFE, threads);
    dataset::save(&ds, &out)?;
    println!("wrote {} samples to {} in {:?}", ds.len(), out, t0.elapsed());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let lab = Lab::new(scale_of(args))?;
    let flavor = if args.has("p80") { ModelFlavor::P80 } else { ModelFlavor::SynPerf };
    let t0 = std::time::Instant::now();
    let _pred = lab.model(kind, flavor)?;
    println!(
        "model {} ({:?}) ready in {:?} (cached under runs/models)",
        kind.name(),
        flavor,
        t0.elapsed()
    );
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let kind = kernel_of(args)?;
    let gpu = gpu_of(args, "A100")?;
    let cfg = match kind {
        KernelKind::Gemm => KernelConfig::Gemm {
            m: args.usize_or("m", 4096)? as u32,
            n: args.usize_or("n", 4096)? as u32,
            k: args.usize_or("k", 4096)? as u32,
            dtype: DType::Bf16,
        },
        KernelKind::RmsNorm => KernelConfig::RmsNorm {
            seq: args.usize_or("seq", 4096)? as u32,
            dim: args.usize_or("dim", 8192)? as u32,
        },
        KernelKind::SiluMul => KernelConfig::SiluMul {
            seq: args.usize_or("seq", 4096)? as u32,
            dim: args.usize_or("dim", 13824)? as u32,
        },
        other => {
            return Err(api::PredictError::UnsupportedKernel(format!(
                "predict CLI supports gemm/rmsnorm/silu_mul (got {})",
                other.name()
            ))
            .into())
        }
    };
    // best-effort models: without artifacts the answer is the documented
    // degraded roofline mode, visible in the provenance line below
    let bundle = match Lab::new(scale_of(args)) {
        Ok(lab) => lab.bundle(&[kind]),
        Err(_) => {
            eprintln!("(no artifacts — answering in degraded roofline mode)");
            ModelBundle::default()
        }
    };
    let mut req = PredictRequest::new(cfg.clone(), gpu.clone()).with_breakdown();
    if args.has("p80") {
        req = req.p80();
    }
    if args.has("strict") {
        req = req.strict();
    }
    let resp = api::predict_one(&bundle, &req)?;
    let b = resp.breakdown.as_ref().expect("breakdown requested");
    println!("kernel:        {} on {}", kind.name(), gpu.name);
    println!("theory roof:   {:.3} us", b.theory_sec * 1e6);
    println!("pred eff:      {:.3}", b.theory_sec / resp.latency_sec);
    println!("pred latency:  {:.3} us", resp.latency_sec * 1e6);
    println!(
        "provenance:    {} ({} flavor, cache {})",
        resp.provenance.source.name(),
        resp.flavor.name(),
        if resp.provenance.cache_hit { "hit" } else { "miss" }
    );
    let s = dataset::make_sample(&cfg, &gpu, 0);
    println!("oracle actual: {:.3} us (testbed ground truth)", s.latency_sec * 1e6);
    Ok(())
}

/// Parse `--requests 1000:200,2000:100` into an explicit request mix.
fn requests_of(raw: &str) -> Result<Vec<synperf::e2e::workload::Request>> {
    let mut reqs = Vec::new();
    for part in raw.split(',') {
        let Some((i, o)) = part.split_once(':') else {
            bail!("--requests entries are input:output pairs (got {part:?})");
        };
        reqs.push(synperf::e2e::workload::Request {
            input_len: i.trim().parse()?,
            output_len: o.trim().parse()?,
        });
    }
    Ok(reqs)
}

/// Build a [`ScenarioSpec`] from CLI flags (shared by `simulate` and `e2e`).
fn spec_of(args: &Args) -> Result<ScenarioSpec> {
    // only convert when the flag is given, so the default stays the exact
    // HOST_GAP_SEC constant (no us -> sec float round trip)
    let host_gap_sec = match args.str_opt("host-gap-us") {
        Some(_) => args.f64_or("host-gap-us", 0.0)? * 1e-6,
        None => scenario::HOST_GAP_SEC,
    };
    let mut spec = ScenarioSpec::new(
        args.str_or("model", "qwen2.5-14b"),
        args.str_or("gpu", "A100"),
    )
    .tp(args.usize_or("tp", 1)? as u32)
    .pp(args.usize_or("pp", 1)? as u32)
    .seed(args.u64_or("seed", 7)?)
    .host_gap_sec(host_gap_sec);
    spec = match args.str_opt("requests") {
        Some(raw) => spec.workload(WorkloadSpec::Explicit(requests_of(raw)?)),
        None => {
            let kind = scenario::workload_kind(&args.str_or("workload", "arxiv"))?;
            spec.workload(WorkloadSpec::Sampled { kind, batch: args.usize_or("batch", 8)? })
        }
    };
    spec = spec.phases(PhaseSelection::parse(&args.str_or("phases", "both"))?);
    Ok(spec)
}

/// Build a [`ClusterSpec`] from `simulate --cluster` flags. Shares the
/// model/GPU/parallelism/seed/host-gap flags with [`spec_of`]; arrivals
/// default to a seeded Poisson process (`--rate`), or a fixed-gap uniform
/// process when `--gap-ms` is given.
fn cluster_spec_of(args: &Args) -> Result<ClusterSpec> {
    let host_gap_sec = match args.str_opt("host-gap-us") {
        Some(_) => args.f64_or("host-gap-us", 0.0)? * 1e-6,
        None => scenario::HOST_GAP_SEC,
    };
    let kind = scenario::workload_kind(&args.str_or("workload", "arxiv"))?;
    let n = args.usize_or("n", 16)?;
    let arrivals = match args.str_opt("gap-ms") {
        Some(_) => {
            ArrivalSpec::Uniform { gap_sec: args.f64_or("gap-ms", 0.0)? * 1e-3, n, kind }
        }
        None => ArrivalSpec::Poisson { rate_rps: args.f64_or("rate", 4.0)?, n, kind },
    };
    Ok(ClusterSpec::new(args.str_or("model", "qwen2.5-14b"), args.str_or("gpu", "A100"))
        .tp(args.usize_or("tp", 1)? as u32)
        .pp(args.usize_or("pp", 1)? as u32)
        .replicas(args.usize_or("replicas", 1)? as u32)
        .policy(RoutePolicy::parse(&args.str_or("policy", "round_robin"))?)
        .arrivals(arrivals)
        .max_batch(args.usize_or("max-batch", 16)? as u32)
        .kv_capacity_tokens(args.u64_or("kv-tokens", 262_144)?)
        .kv_quant(args.usize_or("kv-quant", 16)? as u32)
        .seed(args.u64_or("seed", 7)?)
        .host_gap_sec(host_gap_sec)
        .slo(
            args.f64_or("slo-ttft-ms", 2000.0)? * 1e-3,
            args.f64_or("slo-tpot-ms", 200.0)? * 1e-3,
        ))
}

/// Best-effort simulator: trained models when artifacts exist, otherwise
/// the documented degraded roofline mode (visible in the report counts).
/// Both fallback paths say so on stderr — degraded numbers are never
/// silent.
fn simulator_of(scale: Scale) -> Simulator {
    match Lab::new(scale) {
        Ok(lab) => match lab.model_set() {
            Ok(models) => Simulator::with_comm_seed(models, lab.seed),
            Err(e) => {
                eprintln!("(simulator init failed: {e} — simulating in degraded roofline mode)");
                Simulator::degraded()
            }
        },
        Err(_) => {
            eprintln!("(no artifacts — simulating in degraded roofline mode)");
            Simulator::degraded()
        }
    }
}

/// Simulator factory for the multi-simulator surfaces (sweep workers, the
/// stdio wire). Each call probes the artifact lab so workers get
/// independent, artifact-backed simulators; the degraded fallback is
/// announced once, not once per worker — and only if a simulator is ever
/// actually built, so predict-only stdio peers stay silent and pay
/// nothing.
fn simulator_factory(scale: Scale) -> impl Fn() -> Simulator + Sync {
    let warned = std::sync::Once::new();
    move || match Lab::new(scale).and_then(|lab| Ok((lab.model_set()?, lab.seed))) {
        Ok((models, seed)) => Simulator::with_comm_seed(models, seed),
        Err(e) => {
            warned.call_once(|| {
                eprintln!("(no artifacts: {e} — simulating in degraded roofline mode)");
            });
            Simulator::degraded()
        }
    }
}

fn print_report(report: &ScenarioReport) {
    println!(
        "scenario: {} on {} (TP={}, PP={}), seed {}, host gap {:.2} us",
        report.model,
        report.gpu,
        report.tp,
        report.pp,
        report.seed,
        report.host_gap_sec * 1e6
    );
    for ph in &report.phases {
        let actual = ph.time_sec(Method::Actual);
        let syn = ph.time_sec(Method::SynPerf);
        print!(
            "  {:<7} actual {:>9.2} ms, synperf {:>9.2} ms, {:>7.0} tok/s",
            ph.phase.name(),
            actual * 1e3,
            syn * 1e3,
            ph.tokens_per_sec(Method::Actual)
        );
        match ph.phase {
            Phase::Prefill => println!(
                "  (TTFT {:.2} ms)",
                ph.ttft_sec(Method::SynPerf).unwrap_or(0.0) * 1e3
            ),
            Phase::Decode => println!(
                "  (TPOT {:.3} ms/tok)",
                ph.tpot_sec(Method::SynPerf).unwrap_or(0.0) * 1e3
            ),
        }
    }
    println!("  totals: ground truth {:.2} ms", report.totals.actual * 1e3);
    for m in [Method::SynPerf, Method::Roofline, Method::Linear, Method::Habitat, Method::Neusight]
    {
        let v = report.totals.get(m);
        println!(
            "    {:<9} {:>9.2} ms  (err {:+.1}%)",
            m.name(),
            v * 1e3,
            100.0 * (v - report.totals.actual) / report.totals.actual
        );
    }
    let shares: Vec<String> = OpClass::ALL
        .iter()
        .filter(|c| report.breakdown.get(**c) > 0.0)
        .map(|c| format!("{} {:.1}%", c.name(), report.breakdown.share_pct(*c)))
        .collect();
    println!("  breakdown (ground truth): {}", shares.join(", "));
    println!(
        "  provenance: {:.0} launches, {} degraded kernel items, {} analysis-cache hits",
        report.launches, report.totals.degraded_kernels, report.cache_hits
    );
}

fn print_cluster_report(r: &ClusterReport) {
    println!(
        "cluster: {} on {} (TP={}, PP={}) x {} replicas, policy {}, seed {}",
        r.model,
        r.gpu,
        r.tp,
        r.pp,
        r.replicas.len(),
        r.policy.name(),
        r.seed
    );
    println!(
        "  {} offered, {} completed in {:.3} s  ({:.2} req/s, {:.0} tok/s)",
        r.offered, r.completed, r.makespan_sec, r.requests_per_sec, r.tokens_per_sec
    );
    let line = |label: &str, s: &synperf::scenario::LatencySummary| {
        println!(
            "  {:<12} p50 {:>8.2} ms, p95 {:>8.2} ms, p99 {:>8.2} ms, mean {:>8.2} ms  (n={})",
            label,
            s.p50_sec * 1e3,
            s.p95_sec * 1e3,
            s.p99_sec * 1e3,
            s.mean_sec * 1e3,
            s.count
        );
    };
    line("TTFT", &r.ttft);
    line("TPOT", &r.tpot);
    line("queue delay", &r.queue_delay);
    println!(
        "  SLO attainment: {:.1}% ttft, {:.1}% tpot, {:.1}% joint",
        100.0 * r.slo_ttft_attainment,
        100.0 * r.slo_tpot_attainment,
        100.0 * r.slo_attainment
    );
    for (i, rep) in r.replicas.iter().enumerate() {
        println!(
            "  replica {i}: {} done, {} steps ({} prefill), util {:.0}%, peak KV {} tok, max batch {}",
            rep.completed,
            rep.steps,
            rep.prefill_steps,
            100.0 * rep.utilization,
            rep.peak_kv_tokens,
            rep.max_batch_seen
        );
    }
    println!(
        "  provenance: {} events, {} distinct step shapes, {} degraded kernel items",
        r.events, r.distinct_steps, r.degraded_kernels
    );
}

fn cmd_simulate(args: &Args) -> Result<()> {
    // --spec <file|->: JSONL in (wire envelopes or bare scenario/cluster
    // objects), one report line out per input line — the offline twin of
    // the `serve --stdio` simulate verb.
    if let Some(path) = args.str_opt("spec") {
        // spec lines carry their own scenario fields; flag-built fields
        // would be contradictory, so say so instead of silently dropping
        for flag in
            ["model", "gpu", "tp", "pp", "workload", "batch", "requests", "phases", "seed", "host-gap-us"]
        {
            if args.str_opt(flag).is_some() {
                eprintln!("(--{flag} ignored: --spec lines carry their own scenario fields)");
            }
        }
        let text = if path == "-" {
            use std::io::Read;
            let mut buf = String::new();
            std::io::stdin().read_to_string(&mut buf)?;
            buf
        } else {
            std::fs::read_to_string(path)?
        };
        let sim = simulator_of(scale_of(args)).threads(threads_of(args)?);
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            let (id, req) = scenario::wire::parse_request_line(line);
            let out = match req {
                Ok(SimulateRequest::Scenario(spec)) => {
                    scenario::wire::encode_report(id.as_deref(), &sim.simulate(&spec))
                }
                Ok(SimulateRequest::Cluster(spec)) => scenario::wire::encode_cluster_report(
                    id.as_deref(),
                    &sim.simulate_cluster(&spec),
                ),
                Err(e) => scenario::wire::encode_report(id.as_deref(), &Err(e)),
            };
            println!("{out}");
        }
        return Ok(());
    }

    let sim = simulator_of(scale_of(args)).threads(threads_of(args)?);
    if args.has("cluster") {
        let spec = cluster_spec_of(args)?;
        let report = sim.simulate_cluster(&spec)?;
        if args.has("json") {
            println!("{}", scenario::wire::encode_cluster_report(None, &Ok(report)));
        } else {
            print_cluster_report(&report);
        }
        return Ok(());
    }
    let spec = spec_of(args)?;
    let report = sim.simulate(&spec)?;
    if args.has("json") {
        // machine consumers get exactly one report line on stdout
        println!("{}", scenario::wire::encode_report(None, &Ok(report)));
    } else {
        print_report(&report);
    }
    Ok(())
}

/// Human summary of a finished sweep, on stderr (stdout carries only the
/// JSONL rows + frontier, so `--threads` runs stay byte-diffable).
fn print_frontier(out: &synperf::sweep::SweepOutcome) {
    use synperf::util::table;
    let ok = out.rows.iter().filter(|r| r.outcome.is_ok()).count();
    eprintln!(
        "sweep: {} configs ({} ok, {} infeasible), frontier of {}",
        out.rows.len(),
        ok,
        out.rows.len() - ok,
        out.pareto.frontier.len()
    );
    let mut t = table::Table::new(
        "Pareto frontier (tok/s up, SLO up, GPUs down)",
        &[
            "rank", "workload", "gpu", "tp", "pp", "rep", "policy", "gpus", "tok/s", "slo",
            "tok/s/gpu", "$/Mtok",
        ],
    );
    for (rank, &ri) in out.pareto.frontier.iter().enumerate() {
        let r = &out.rows[ri];
        let m = r.outcome.as_ref().expect("frontier rows carry metrics");
        t.row(vec![
            (rank + 1).to_string(),
            r.workload.clone(),
            r.gpu.clone(),
            r.tp.to_string(),
            r.pp.to_string(),
            r.replicas.to_string(),
            r.policy.name().to_string(),
            r.gpu_count.to_string(),
            table::f(m.tokens_per_sec, 0),
            table::pct(m.slo_attainment),
            table::f(m.tokens_per_sec / f64::from(r.gpu_count), 0),
            table::f(m.usd_per_mtok, 2),
        ]);
    }
    eprint!("{}", t.render());
}

/// One journaled (or plain) sweep run: replayed rows re-emit without
/// journaling, fresh rows are fsync'd before the next point can finish
/// emitting, and a journal write failure fails the run loudly.
fn run_one_sweep<F>(
    spec: &synperf::sweep::SweepSpec,
    shard: synperf::sweep::Shard,
    journal: Option<&str>,
    resume: bool,
    timeout_ms: Option<u64>,
    threads: usize,
    factory: &std::sync::Arc<F>,
) -> std::result::Result<synperf::sweep::SweepOutcome, synperf::sweep::SweepError>
where
    F: Fn() -> Simulator + Send + Sync + 'static,
{
    use synperf::sweep::{self, wire as sweep_wire, JournalSession, RunOptions};
    let mut session = match journal {
        Some(p) => Some(JournalSession::open(std::path::Path::new(p), spec, shard, resume)?),
        None => None,
    };
    let done = session.as_mut().map(|s| std::mem::take(&mut s.done)).unwrap_or_default();
    let replayed: std::collections::BTreeSet<usize> = done.keys().copied().collect();
    let opts = RunOptions { threads, shard, point_timeout_ms: timeout_ms, done };
    let mut io_err = None;
    let on_row = |row: &sweep::SweepRow| {
        let line = sweep_wire::encode_row(row);
        println!("{line}");
        if io_err.is_none() && !replayed.contains(&row.index) {
            if let Some(s) = session.as_mut() {
                if let Err(e) = s.record(&line) {
                    io_err = Some(e);
                }
            }
        }
    };
    let out = match timeout_ms {
        Some(_) => sweep::run_sweep_deadline(spec, std::sync::Arc::clone(factory), &opts, on_row),
        None => sweep::run_sweep_with(spec, factory.as_ref(), &opts, on_row),
    }?;
    match io_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use synperf::sweep::{wire as sweep_wire, Shard};
    // JSONL in (wire envelopes or bare sweep objects), streaming out: one
    // row line per grid point, then one frontier line — the offline twin
    // of the `serve --stdio` sweep verb, which answers in a single line.
    // `--shard I/N` runs one round-robin slice of the grid (merge the
    // shards back with `sweep-merge`); `--journal PATH` makes the run
    // crash-safe (fsync'd JSONL rows, `--resume` to continue after a
    // crash); `--point-timeout-ms` converts wedged points into typed
    // timeout rows via the watchdog runner.
    let Some(path) = args.str_opt("spec") else {
        bail!("sweep requires --spec <file|-> (JSONL sweep specs; see rust/README.md)\n{}", usage());
    };
    let text = if path == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin().read_to_string(&mut buf)?;
        buf
    } else {
        std::fs::read_to_string(path)?
    };
    let threads = threads_of(args)?;
    let flag_shard = match args.str_opt("shard") {
        None => None,
        Some(raw) => {
            let parsed = raw.split_once('/').and_then(|(i, n)| {
                Some(Shard::new(i.trim().parse().ok()?, n.trim().parse().ok()?))
            });
            let Some(shard) = parsed else {
                bail!("--shard takes I/N (e.g. --shard 0/3), got {raw:?}");
            };
            Some(shard)
        }
    };
    let flag_journal = args.str_opt("journal");
    let resume = args.has("resume");
    let timeout_ms = match args.str_opt("point-timeout-ms") {
        Some(_) => Some(args.u64_or("point-timeout-ms", 0)?),
        None => None,
    };
    let factory = std::sync::Arc::new(simulator_factory(scale_of(args)));
    let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
    for line in &lines {
        let (id, req) = sweep_wire::parse_sweep_line(line);
        // spec-level failures (bad JSON, bad axes, unknown GPUs, oversized
        // grids, bad shards, unusable journals) answer as one typed error
        // line; infeasible or constraint-violating grid points surface as
        // per-row error rows inside a succeeding sweep instead
        let res = match req {
            Err(e) => Err(e),
            Ok(req) => {
                // CLI flags override wire-envelope fields
                let shard = flag_shard.unwrap_or(req.shard);
                let journal = flag_journal.map(str::to_string).or(req.journal);
                if let Some(jp) = &journal {
                    if lines.len() > 1 {
                        bail!(
                            "--journal binds to exactly one sweep spec line (got {})",
                            lines.len()
                        );
                    }
                    if !resume && std::path::Path::new(jp).exists() {
                        bail!(
                            "journal {jp} already exists; pass --resume to continue it \
                             (or remove it to start over)"
                        );
                    }
                }
                run_one_sweep(
                    &req.spec,
                    shard,
                    journal.as_deref(),
                    resume,
                    timeout_ms,
                    threads,
                    &factory,
                )
            }
        };
        match res {
            Ok(out) => {
                println!("{}", sweep_wire::encode_frontier(&out.rows, &out.pareto));
                if !args.has("json") {
                    print_frontier(&out);
                }
            }
            Err(e) => {
                println!("{}", sweep_wire::encode_sweep_response(id.as_deref(), &Err(e)));
            }
        }
    }
    Ok(())
}

fn cmd_sweep_merge(args: &Args) -> Result<()> {
    use synperf::sweep::{self, wire as sweep_wire};
    // Deterministic shard-journal merge: fingerprints must agree, every
    // shard must be present exactly once and complete, and the output —
    // rows by global index, then the recomputed frontier — is
    // byte-identical to what one unsharded process would have streamed.
    if args.positional.is_empty() {
        bail!(
            "sweep-merge takes the shard journal paths of one campaign:\n\
             synperf sweep-merge runs/shard0.jsonl runs/shard1.jsonl ... [--json]\n{}",
            usage()
        );
    }
    let paths: Vec<std::path::PathBuf> =
        args.positional.iter().map(std::path::PathBuf::from).collect();
    match sweep::merge(&paths) {
        Ok(rows) => {
            for row in &rows {
                println!("{}", sweep_wire::encode_row(row));
            }
            let pareto = sweep::pareto(&rows);
            println!("{}", sweep_wire::encode_frontier(&rows, &pareto));
            if !args.has("json") {
                print_frontier(&sweep::SweepOutcome { rows, pareto });
            }
        }
        Err(e) => {
            println!("{}", sweep_wire::encode_sweep_response(None, &Err(e)));
        }
    }
    Ok(())
}

fn cmd_gpus() -> Result<()> {
    use synperf::util::table;
    let mut t = table::Table::new(
        "Hardware registry (Table VI)",
        &[
            "gpu", "arch", "gen", "split", "SMs", "clk MHz", "Ttops/s", "DRAM GB/s", "ops:byte",
            "$/hr", "TDP W",
        ],
    );
    let gpus = hw::all_gpus();
    for g in &gpus {
        t.row(vec![
            g.name.to_string(),
            g.arch.name().to_string(),
            g.arch.generation().to_string(),
            if g.seen { "seen" } else { "unseen" }.to_string(),
            g.num_sms.to_string(),
            table::f(g.sm_clock_mhz, 0),
            table::f(g.tensor_ops_per_sec() / 1e12, 1),
            table::f(g.dram_bw_gbs, 0),
            table::f(g.compute_mem_ratio(), 1),
            table::f(g.usd_per_hour, 2),
            table::f(g.tdp_watts, 0),
        ]);
    }
    t.print();
    let seen = gpus.iter().filter(|g| g.seen).count();
    println!(
        "{} GPUs: {} seen (training split), {} unseen (held out)",
        gpus.len(),
        seen,
        gpus.len() - seen
    );
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    // the paper's method comparison, now a scenario simulation: requires
    // trained artifacts (use `simulate` for the degraded-friendly verb)
    let lab = Lab::new(scale_of(args))?;
    let spec = spec_of(args)?;
    let report = lab.simulator()?.simulate_with_threads(&spec, threads_of(args)?)?;
    print_report(&report);
    Ok(())
}

/// Drain flag for `serve --tcp`: flipped by SIGTERM/SIGINT, watched by the
/// TCP accept loop and readers — stop accepting, finish in-flight work,
/// flush every connection, exit cleanly.
static DRAIN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Install a minimal SIGTERM/SIGINT handler without libc: `signal(2)` via
/// a raw extern declaration (the only async-signal-safe work is one
/// atomic store).
#[cfg(unix)]
fn install_drain_handler() {
    extern "C" fn on_signal(_signum: i32) {
        DRAIN.store(true, std::sync::atomic::Ordering::Release);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as usize);
        signal(SIGINT, on_signal as usize);
    }
}

#[cfg(not(unix))]
fn install_drain_handler() {}

fn cmd_serve(args: &Args) -> Result<()> {
    use synperf::coordinator::{PredictionService, ServiceConfig};
    let defaults = ServiceConfig::default();
    let threads = threads_of(args)?;
    let cfg = ServiceConfig {
        max_batch: args.usize_or("max-batch", defaults.max_batch)?,
        deadline: std::time::Duration::from_micros(
            args.u64_or("deadline-us", defaults.deadline.as_micros() as u64)?,
        ),
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap)?,
        threads,
    };
    let scale = scale_of(args);
    // effective config at startup (stderr: stdout carries JSONL in --stdio)
    eprintln!(
        "serve: protocol v{}, max_batch={}, deadline={}us, queue_cap={}, threads={}",
        api::PROTOCOL_VERSION,
        cfg.max_batch,
        cfg.deadline.as_micros(),
        cfg.queue_cap,
        cfg.threads
    );
    let svc = PredictionService::spawn(
        move || match Lab::new(scale) {
            Ok(lab) => {
                lab.bundle(&[KernelKind::Gemm, KernelKind::RmsNorm, KernelKind::SiluMul])
            }
            Err(_) => {
                eprintln!("(no artifacts — serving degraded roofline answers)");
                ModelBundle::default()
            }
        },
        cfg.clone(),
    );

    if let Some(addr) = args.str_opt("tcp") {
        // JSONL TCP surface: same wire as --stdio (byte-identical
        // responses for the same request stream), many concurrent clients
        // with fair admission, per-request deadlines, and graceful drain
        // on SIGTERM/SIGINT (see rust/README.md "Network serving")
        use synperf::api::tcp::{self, TcpConfig};
        let d = TcpConfig::default();
        let tcp_cfg = TcpConfig {
            max_clients: args.usize_or("max-clients", d.max_clients)?,
            inbox_cap: args.usize_or("inbox-cap", d.inbox_cap)?,
            max_inflight: args.usize_or("max-inflight", d.max_inflight)?,
            quarantine_limit: args.u64_or("quarantine-limit", u64::from(d.quarantine_limit))?
                as u32,
            admit_timeout: std::time::Duration::from_millis(
                args.u64_or("admit-timeout-ms", d.admit_timeout.as_millis() as u64)?,
            ),
            idle_timeout: std::time::Duration::from_millis(
                args.u64_or("idle-timeout-ms", d.idle_timeout.as_millis() as u64)?,
            ),
            write_timeout: d.write_timeout,
            tick: d.tick,
            threads,
        };
        let listener = std::net::TcpListener::bind(addr)?;
        install_drain_handler();
        eprintln!(
            "tcp: listening on {} (max {} clients; SIGTERM/SIGINT drains)",
            listener.local_addr()?,
            tcp_cfg.max_clients
        );
        let factory = simulator_factory(scale);
        let stats = tcp::serve(
            listener,
            &svc.client(),
            move || factory().threads(threads),
            &tcp_cfg,
            &DRAIN,
        )?;
        let snap = svc.metrics.snapshot();
        eprintln!(
            "tcp: {} responses ({} errors, {} simulations, {} sweeps, {} tunes, {} stats) over {} connections ({} quarantined, {} reaped, {} dropped); rejected {}, deadline exceeded {}",
            stats.served,
            stats.errors,
            stats.simulated,
            stats.swept,
            stats.tuned,
            stats.stats_lines,
            stats.connections,
            stats.quarantined,
            stats.idle_reaped,
            stats.disconnects,
            snap.rejected_requests,
            snap.deadline_exceeded
        );
        svc.shutdown();
        return Ok(());
    }

    if args.has("stdio") {
        // JSONL wire surface: one request per line on stdin, one response
        // per line on stdout (see rust/README.md for the schema); predict
        // lines route through the coordinator, simulate lines through the
        // Simulator (built lazily on the first simulate line, so
        // predict-only peers never pay its model-set startup cost). Stdin
        // is wrapped (not locked): the reader moves into serve_lines'
        // reader thread, and StdinLock is not Send.
        let stdout = std::io::stdout();
        let factory = simulator_factory(scale);
        let stats = synperf::api::stdio::serve_lines(
            &svc.client(),
            move || factory().threads(threads),
            std::io::BufReader::new(std::io::stdin()),
            &mut stdout.lock(),
            cfg.max_batch,
            threads,
        )?;
        let snap = svc.metrics.snapshot();
        eprintln!(
            "stdio: {} responses ({} errors, {} simulations, {} sweeps, {} tunes), mean batch {:.1}, rejected {}, max depth {}",
            stats.served, stats.errors, stats.simulated, stats.swept, stats.tuned, snap.mean_batch, snap.rejected_requests, snap.max_queue_depth
        );
        svc.shutdown();
        return Ok(());
    }

    // synthetic-load mode: fire n GEMM predictions through the client
    let n = args.usize_or("requests", 512)?;
    let gpu = gpu_of(args, "A100")?;
    let client = svc.client();
    let mut rng = synperf::util::rng::Rng::new(3);
    let t0 = std::time::Instant::now();
    let pendings: Vec<_> = (0..n)
        .map(|_| {
            let cfg = KernelConfig::Gemm {
                m: rng.log_range_u32(16, 32768),
                n: rng.log_range_u32(384, 32768),
                k: rng.log_range_u32(256, 8192),
                dtype: DType::Bf16,
            };
            client.submit(PredictRequest::new(cfg, gpu.clone()))
        })
        .collect::<std::result::Result<_, _>>()?;
    let mut total = 0.0;
    let mut mlp = 0usize;
    for p in pendings {
        let resp = p.wait()?;
        total += resp.latency_sec;
        if resp.provenance.source == Source::Mlp {
            mlp += 1;
        }
    }
    let wall = t0.elapsed();
    let snap = svc.metrics.snapshot();
    println!(
        "served {n} predictions in {wall:?} ({:.0} req/s; {mlp} mlp / {} roofline)",
        n as f64 / wall.as_secs_f64(),
        n - mlp
    );
    println!(
        "mean batch {:.1}, batch p50 {:.0} us, p99 {:.0} us, rejected {}, max queue depth {}",
        snap.mean_batch, snap.p50_us, snap.p99_us, snap.rejected_requests, snap.max_queue_depth
    );
    println!(
        "analysis cache: {} hits / {} misses ({:.0}% hit rate), mean kind-batch {:.1}",
        snap.cache_hits,
        snap.cache_misses,
        100.0 * snap.cache_hit_rate(),
        snap.mean_kind_batch
    );
    let es = synperf::engine::PredictionEngine::global().stats();
    println!("engine cache: {} entries / {} capacity", es.entries, es.capacity);
    println!("sum of predicted latencies: {:.3} s", total);
    svc.shutdown();
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    use synperf::autotune::{self, wire as tune_wire};
    // JSONL in (wire envelopes or bare tune objects), streaming out: one
    // row line per point, then one summary line — the offline twin of the
    // `serve --stdio` tune verb, which answers in a single line. Stdout
    // carries only the JSONL rows + summary, so `--threads` runs stay
    // byte-diffable; the Table-X-style report goes to stderr.
    let Some(path) = args.str_opt("spec") else {
        bail!(
            "tune requires --spec <file|-> (JSONL tune specs; see rust/README.md)\n\
             (the old `tune --gpu A40 --n 20` flags became a spec line:\n\
              {{\"tune\":{{\"gpus\":[\"A40\"],\"source\":{{\"sampled\":20}}}}}})\n{}",
            usage()
        );
    };
    let text = if path == "-" {
        use std::io::Read;
        let mut buf = String::new();
        std::io::stdin().read_to_string(&mut buf)?;
        buf
    } else {
        std::fs::read_to_string(path)?
    };
    let threads = threads_of(args)?;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let (id, spec) = tune_wire::parse_tune_line(line);
        // spec-level failures (bad JSON, unknown GPUs, bad bounds,
        // oversized grids) answer as one typed error line; healthy specs
        // stream one row per point in index order, then the summary
        let res = spec.and_then(|spec| {
            autotune::run_tune(&spec, autotune::Ceiling::auto, threads, |row| {
                println!("{}", tune_wire::encode_row(row));
            })
        });
        match res {
            Ok(out) => {
                println!("{}", tune_wire::encode_summary(&out.summary));
                if !args.has("json") {
                    autotune::print_report(&out);
                }
            }
            Err(e) => {
                println!("{}", tune_wire::encode_tune_response(id.as_deref(), &Err(e)));
            }
        }
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let Some(id) = args.positional.first() else {
        bail!("experiment id required (see DESIGN.md §5)");
    };
    let lab = Lab::new(scale_of(args))?;
    let t0 = std::time::Instant::now();
    experiments::run(&lab, id)?;
    eprintln!("[{} done in {:?}]", id, t0.elapsed());
    Ok(())
}
