//! The tune executor (§VII end to end): expand a [`TuneSpec`] into
//! indexed fused-MoE launch points, diagnose each against the
//! [`Ceiling`], brute-force the bounded §VII-C candidate space on the
//! diagnosed points, and re-emit finished rows in strict index order
//! regardless of scheduling — the same work-stealing shape as
//! [`crate::sweep::run_sweep`], with one ceiling (and its measurement
//! scratch) owned per worker.

use super::report::{summarize, TuneOutcome, TuneRow};
use super::spec::{ConfigSource, MoeShape, TuneSpec, MAX_TUNE_CONFIGS, MAX_TUNE_POINTS};
use super::TuneError;
use crate::dataset::{self, finalize_for_gpu, Sample};
use crate::hw;
use crate::kernels::{fused_moe, KernelConfig, KernelKind, MoeConfig};
use crate::mlp::Predictor;
use crate::oracle;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;

/// The Potential Performance Ceiling a tune diagnoses against (§VII-A).
pub enum Ceiling {
    /// A trained pinball-loss (τ=0.8) MLP — the paper's P80 ceiling.
    P80(Predictor),
    /// Analytical fallback when no P80 artifact exists: the roofline
    /// bound `theory_sec / roofline_sec`, clamped to the model's
    /// efficiency scale. Recorded in row provenance so consumers can
    /// tell the regimes apart.
    Roofline,
}

impl Ceiling {
    /// Provenance tag carried on every row: `"p80"` or `"roofline"`.
    pub fn provenance(&self) -> &'static str {
        match self {
            Ceiling::P80(_) => "p80",
            Ceiling::Roofline => "roofline",
        }
    }

    /// Resolve the best available ceiling: the fused-MoE P80 artifact at
    /// the largest trained scale when one exists on disk (and the PJRT
    /// engine can load it), the analytical roofline otherwise. Probing
    /// never trains and never touches the filesystem beyond `exists()` —
    /// `Lab::model` would fit a model on a cache miss, so the artifact
    /// path is checked first.
    pub fn auto() -> Ceiling {
        use crate::experiments::{model_artifact_name, runs_root, Lab, ModelFlavor, Scale};
        let models = runs_root().join("models");
        for scale in [Scale::Full, Scale::Normal, Scale::Fast] {
            let name = model_artifact_name(KernelKind::FusedMoe, ModelFlavor::P80, scale);
            if !models.join(name).exists() {
                continue;
            }
            let Ok(lab) = Lab::new(scale) else { break };
            if let Ok(p) = lab.model(KernelKind::FusedMoe, ModelFlavor::P80) {
                return Ceiling::P80(p);
            }
            break;
        }
        Ceiling::Roofline
    }

    /// Ceiling efficiency for one profiled sample, on the same clamped
    /// scale as [`Sample::efficiency`].
    pub fn eff(&self, s: &Sample) -> f64 {
        match self {
            Ceiling::P80(p) => p.predict_eff_native(&[s.x])[0],
            Ceiling::Roofline => (s.theory_sec / s.roofline_sec).clamp(1e-3, 0.9999),
        }
    }
}

/// One cell of the expanded tune: a fused-MoE launch on one GPU.
#[derive(Debug, Clone, PartialEq)]
pub struct TunePoint {
    pub index: usize,
    /// Canonical registry name (post [`hw::gpu_by_name`] resolution).
    pub gpu: String,
    pub shape: MoeShape,
    /// Always a [`KernelConfig::FusedMoe`] — expansion guarantees it.
    pub cfg: KernelConfig,
}

/// The bounded §VII-C candidate space this spec searches (before the
/// per-GPU shared-memory validity filter).
pub fn candidates(spec: &TuneSpec) -> Vec<MoeConfig> {
    fused_moe::tuning_space()
        .into_iter()
        .filter(|c| {
            c.block_m.max(c.block_n) <= spec.max_block
                && c.num_stages <= spec.max_stages
                && c.num_warps <= spec.max_warps
        })
        .collect()
}

fn shape_of(cfg: &KernelConfig) -> Result<MoeShape, TuneError> {
    match cfg {
        KernelConfig::FusedMoe { m, e, topk, h, n, .. } => {
            Ok(MoeShape { m: *m, e: *e, topk: *topk, h: *h, n: *n })
        }
        other => Err(TuneError::UnsupportedKernel(format!(
            "tune() expects a fused_moe config, got {:?}",
            other.kind().name()
        ))),
    }
}

/// Bounds under which an explicit shape is guaranteed to profile cleanly
/// (route_tokens stays in u32 and the launch passes request validation).
fn check_shape(i: usize, s: &MoeShape) -> Result<(), TuneError> {
    let bad = |why: String| Err(TuneError::InvalidSpec(format!("shape {i}: {why}")));
    if s.m == 0 || s.e == 0 || s.topk == 0 || s.h == 0 || s.n == 0 {
        return bad(format!(
            "dims must be positive (m={} e={} topk={} h={} n={})",
            s.m, s.e, s.topk, s.h, s.n
        ));
    }
    if s.m > 16_384 || s.e > 256 || s.topk > 16 || s.h > 8_192 || s.n > 8_192 {
        return bad("dims exceed the tune caps (m<=16384 e<=256 topk<=16 h<=8192 n<=8192)".into());
    }
    if s.topk > s.e {
        return bad(format!("topk={} cannot exceed e={}", s.topk, s.e));
    }
    Ok(())
}

fn source_configs(spec: &TuneSpec) -> Result<Vec<(MoeShape, KernelConfig)>, TuneError> {
    let sampled = |n: usize, seed: u64| -> Result<Vec<(MoeShape, KernelConfig)>, TuneError> {
        if n == 0 || n > MAX_TUNE_CONFIGS {
            return Err(TuneError::InvalidSpec(format!(
                "source count must be in 1..={MAX_TUNE_CONFIGS}, got {n}"
            )));
        }
        dataset::sample_configs(KernelKind::FusedMoe, n, seed)
            .into_iter()
            .map(|cfg| Ok((shape_of(&cfg)?, cfg)))
            .collect()
    };
    match &spec.source {
        ConfigSource::Sampled { n } => sampled(*n, spec.seed),
        // the fixed lab seed, so rows line up with `Lab::dataset_configs`
        ConfigSource::Dataset { n } => sampled(*n, 0x5EED_CAFE),
        ConfigSource::Explicit(shapes) => {
            if shapes.is_empty() || shapes.len() > MAX_TUNE_CONFIGS {
                return Err(TuneError::InvalidSpec(format!(
                    "\"explicit\" must list 1..={MAX_TUNE_CONFIGS} shapes, got {}",
                    shapes.len()
                )));
            }
            shapes
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    check_shape(i, s)?;
                    let mut rng = Rng::new(spec.seed ^ ((i as u64) << 20) ^ 0xD1A6);
                    let expert_tokens = fused_moe::route_tokens(s.m, s.e, s.topk, &mut rng);
                    let m_per_expert = (s.m * s.topk / s.e).max(1);
                    let cfg = KernelConfig::FusedMoe {
                        m: s.m,
                        e: s.e,
                        topk: s.topk,
                        h: s.h,
                        n: s.n,
                        expert_tokens,
                        cfg: fused_moe::default_config(m_per_expert, &hw::all_gpus()[0]),
                    };
                    Ok((*s, cfg))
                })
                .collect()
        }
    }
}

/// Validate the spec and materialize the launch × GPU cross-product as
/// indexed [`TunePoint`]s. Expansion order is GPUs (registry order, or as
/// named) → launches, so row indices are stable and human-predictable.
pub fn expand(spec: &TuneSpec) -> Result<Vec<TunePoint>, TuneError> {
    if !(spec.gap_threshold > 0.0 && spec.gap_threshold < 1.0) {
        return Err(TuneError::InvalidSpec(format!(
            "\"gap_threshold\" must be in (0, 1), got {}",
            spec.gap_threshold
        )));
    }
    if candidates(spec).is_empty() {
        return Err(TuneError::InvalidSpec(format!(
            "candidate bounds (max_block={} max_stages={} max_warps={}) exclude the whole §VII-C space",
            spec.max_block, spec.max_stages, spec.max_warps
        )));
    }
    let gpus = crate::sweep::grid::gpu_names(&spec.gpus).map_err(TuneError::from)?;
    let configs = source_configs(spec)?;
    let total = gpus.len() * configs.len();
    if total > MAX_TUNE_POINTS {
        return Err(TuneError::GridTooLarge(format!(
            "{} GPUs x {} launches = {total} points exceeds the cap of {MAX_TUNE_POINTS}",
            gpus.len(),
            configs.len()
        )));
    }
    let mut points = Vec::with_capacity(total);
    for gpu in &gpus {
        for (shape, cfg) in &configs {
            points.push(TunePoint {
                index: points.len(),
                gpu: gpu.clone(),
                shape: *shape,
                cfg: cfg.clone(),
            });
        }
    }
    Ok(points)
}

/// Evaluate one point: profile the default launch, diagnose against the
/// ceiling, and — when diagnosed — brute-force the bounded candidate
/// space on the same oracle measurement stream (§VII-C).
fn eval_point(ceiling: &Ceiling, spec: &TuneSpec, cands: &[MoeConfig], point: &TunePoint) -> TuneRow {
    let gpu = hw::gpu_by_name(&point.gpu).expect("expand resolved canonical names");
    let seed = spec.seed.wrapping_add(point.index as u64);
    let cfg = finalize_for_gpu(&point.cfg, &gpu);
    let sample = dataset::make_sample(&cfg, &gpu, seed);
    let actual_eff = sample.efficiency();
    let ceiling_eff = ceiling.eff(&sample);
    let gap_before = ceiling_eff - actual_eff;
    let diagnosed = gap_before > spec.gap_threshold;
    let KernelConfig::FusedMoe { h, n, expert_tokens, cfg: default_cfg, .. } = cfg else {
        unreachable!("expand only materializes fused-MoE points")
    };
    let mut best_cfg = default_cfg;
    let mut speedup = 1.0;
    if diagnosed {
        let measure = |c: MoeConfig| {
            let d = fused_moe::decompose(h, n, &expert_tokens, c, &gpu);
            oracle::measure_decomposed(KernelKind::FusedMoe, &d, &gpu, seed).clean_sec
        };
        let default_sec = measure(default_cfg);
        let mut best_sec = default_sec;
        for cand in cands {
            if !fused_moe::config_valid(cand, &gpu) {
                continue;
            }
            let t = measure(*cand);
            if t < best_sec {
                best_sec = t;
                best_cfg = *cand;
            }
        }
        speedup = default_sec / best_sec;
    }
    let eff_after = if diagnosed {
        (sample.theory_sec / (sample.latency_sec / speedup)).clamp(0.002, 0.995)
    } else {
        actual_eff
    };
    TuneRow {
        index: point.index,
        gpu: point.gpu.clone(),
        ceiling: ceiling.provenance(),
        shape: point.shape,
        default_cfg,
        best_cfg,
        diagnosed,
        actual_eff,
        ceiling_eff,
        eff_after,
        gap_before,
        gap_after: (ceiling_eff - eff_after).max(0.0),
        speedup,
        error: None,
    }
}

/// Test-only failure injection, read once per run:
/// `SYNPERF_TUNE_PANIC_INDEX=N` panics while evaluating point N
/// (exercising `catch_unwind` containment). Only spawned-process
/// integration tests and example scripts set this — the environment is
/// process-global.
fn panic_hook_from_env() -> Option<usize> {
    std::env::var("SYNPERF_TUNE_PANIC_INDEX").ok().and_then(|v| v.parse().ok())
}

/// The typed error row a panicking point collapses into: the point's
/// coordinates with neutral metrics (undiagnosed, speedup 1.0), so the
/// summary aggregates never count phantom gains.
fn error_row(point: &TunePoint, ceiling: &'static str, why: String) -> TuneRow {
    let KernelConfig::FusedMoe { cfg, .. } = &point.cfg else {
        unreachable!("expand only materializes fused-MoE points")
    };
    TuneRow {
        index: point.index,
        gpu: point.gpu.clone(),
        ceiling,
        shape: point.shape,
        default_cfg: *cfg,
        best_cfg: *cfg,
        diagnosed: false,
        actual_eff: 0.0,
        ceiling_eff: 0.0,
        eff_after: 0.0,
        gap_before: 0.0,
        gap_after: 0.0,
        speedup: 1.0,
        error: Some(why),
    }
}

/// Contained evaluation: a panic inside one point becomes a typed error
/// row and the worker's ceiling is rebuilt (a P80 predictor's forward
/// scratch may be mid-update when the stack unwinds), so one poisoned
/// point cannot corrupt — or abort — the rest of the tune.
fn eval_contained(
    ceil: &mut Ceiling,
    ceiling: impl Fn() -> Ceiling,
    spec: &TuneSpec,
    cands: &[MoeConfig],
    point: &TunePoint,
    panic_index: Option<usize>,
) -> TuneRow {
    let result = catch_unwind(AssertUnwindSafe(|| {
        if panic_index == Some(point.index) {
            panic!("test hook: injected panic at index {}", point.index);
        }
        eval_point(ceil, spec, cands, point)
    }));
    match result {
        Ok(row) => row,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("panic");
            *ceil = ceiling();
            error_row(
                point,
                ceil.provenance(),
                format!("tune point evaluation panicked: {msg}"),
            )
        }
    }
}

/// Run the whole tune. `ceiling` builds one [`Ceiling`] per worker (a
/// P80 [`Predictor`] is not `Send`, and per-worker construction keeps
/// its forward scratch uncontended — the same per-worker measurement
/// state discipline as the sweep's simulators); `threads` bounds the
/// worker count. Rows stream through `on_row` in strict index order and
/// are byte-identical at any thread count — the repo-wide `--threads`
/// invariant.
pub fn run_tune<C, G>(
    spec: &TuneSpec,
    ceiling: C,
    threads: usize,
    mut on_row: G,
) -> Result<TuneOutcome, TuneError>
where
    C: Fn() -> Ceiling + Sync,
    G: FnMut(&TuneRow),
{
    let points = expand(spec)?;
    let cands = candidates(spec);
    let panic_index = panic_hook_from_env();
    let threads = threads.max(1);
    let workers = threads.min(points.len()).max(1);
    let mut rows: Vec<TuneRow> = Vec::with_capacity(points.len());
    if workers <= 1 {
        let mut ceil = ceiling();
        for point in &points {
            let row = eval_contained(&mut ceil, &ceiling, spec, &cands, point, panic_index);
            on_row(&row);
            rows.push(row);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = sync_channel::<TuneRow>(workers * 4);
        let next_ref = &next;
        let ceiling_ref = &ceiling;
        let points_ref = &points[..];
        let cands_ref = &cands[..];
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                s.spawn(move || {
                    let mut ceil = ceiling_ref();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= points_ref.len() {
                            break;
                        }
                        let row = eval_contained(
                            &mut ceil,
                            ceiling_ref,
                            spec,
                            cands_ref,
                            &points_ref[i],
                            panic_index,
                        );
                        if tx.send(row).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            // reorder out-of-order completions with O(workers + channel)
            // buffered rows: emit strictly by index as gaps fill
            let mut pending: BTreeMap<usize, TuneRow> = BTreeMap::new();
            let mut next_emit = 0usize;
            while let Ok(row) = rx.recv() {
                pending.insert(row.index, row);
                while let Some(row) = pending.remove(&next_emit) {
                    on_row(&row);
                    rows.push(row);
                    next_emit += 1;
                }
            }
        });
    }
    let summary = summarize(&rows);
    Ok(TuneOutcome { rows, summary })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::GpuFilter;

    fn small_spec() -> TuneSpec {
        TuneSpec::new()
            .gpus(GpuFilter::Named(vec!["A40".into()]))
            .source(ConfigSource::Sampled { n: 3 })
            .seed(31)
    }

    #[test]
    fn rows_stream_in_index_order_and_are_identical_across_thread_counts() {
        let spec = small_spec();
        let run = |threads: usize| {
            let mut streamed: Vec<usize> = Vec::new();
            let out =
                run_tune(&spec, Ceiling::auto, threads, |r| streamed.push(r.index)).unwrap();
            assert_eq!(streamed, vec![0, 1, 2], "streaming order at {threads} threads");
            out
        };
        let one = run(1);
        let eight = run(8);
        assert_eq!(one.rows, eight.rows, "rows must not depend on scheduling");
        assert_eq!(one.summary, eight.summary);
        for (i, r) in one.rows.iter().enumerate() {
            assert_eq!(r.index, i);
        }
    }

    #[test]
    fn tuned_points_never_get_slower_and_close_their_gap() {
        let spec = small_spec().gap_threshold(0.02);
        let out = run_tune(&spec, Ceiling::auto, 2, |_| {}).unwrap();
        for r in &out.rows {
            assert!(r.speedup >= 1.0, "row {} speedup {}", r.index, r.speedup);
            assert!(r.gap_after <= r.gap_before.max(0.0) + 1e-12, "row {}", r.index);
            if !r.diagnosed {
                assert_eq!(r.best_cfg, r.default_cfg, "undiagnosed rows stay untouched");
                assert_eq!(r.speedup, 1.0);
            }
        }
        assert!(out.summary.geomean_speedup >= 1.0);
    }

    #[test]
    fn spec_level_failures_abort_before_any_row() {
        let mut streamed = 0usize;
        let spec = small_spec().gpus(GpuFilter::Named(vec!["B300".into()]));
        let err = run_tune(&spec, Ceiling::auto, 2, |_| streamed += 1).unwrap_err();
        assert_eq!(err.code(), "unknown_gpu");
        assert_eq!(streamed, 0);

        let err = expand(&small_spec().gap_threshold(0.0)).unwrap_err();
        assert_eq!(err.code(), "invalid_spec");
        let err = expand(&small_spec().bounds(8, 1, 1)).unwrap_err();
        assert_eq!(err.code(), "invalid_spec");
        let err =
            expand(&small_spec().source(ConfigSource::Sampled { n: 200 })).unwrap_err();
        assert_eq!(err.code(), "invalid_spec");
        let err = expand(
            &small_spec().gpus(GpuFilter::All).source(ConfigSource::Sampled { n: 100 }),
        )
        .unwrap_err();
        assert_eq!(err.code(), "grid_too_large");
        let err = expand(
            &small_spec().source(ConfigSource::Explicit(vec![MoeShape {
                m: 8,
                e: 4,
                topk: 6,
                h: 64,
                n: 64,
            }])),
        )
        .unwrap_err();
        assert_eq!(err.code(), "invalid_spec");
    }

    #[test]
    fn explicit_shapes_expand_deterministically() {
        let shape = MoeShape { m: 256, e: 16, topk: 2, h: 1024, n: 512 };
        let spec = small_spec().source(ConfigSource::Explicit(vec![shape]));
        let a = expand(&spec).unwrap();
        let b = expand(&spec).unwrap();
        assert_eq!(a, b, "routing must be a pure function of the spec");
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].shape, shape);
        let KernelConfig::FusedMoe { ref expert_tokens, .. } = a[0].cfg else { unreachable!() };
        assert_eq!(expert_tokens.iter().map(|&t| u64::from(t)).sum::<u64>(), 256 * 2);
    }

    #[test]
    fn candidate_bounds_restrict_the_search_space() {
        let full = candidates(&TuneSpec::new());
        assert_eq!(full.len(), fused_moe::tuning_space().len());
        let bounded = candidates(&TuneSpec::new().bounds(64, 3, 4));
        assert!(!bounded.is_empty());
        assert!(bounded.len() < full.len());
        for c in &bounded {
            assert!(c.block_m.max(c.block_n) <= 64 && c.num_stages <= 3 && c.num_warps <= 4);
        }
    }

    #[test]
    fn roofline_ceiling_is_recorded_in_provenance() {
        // tests run artifact-less: auto() must fall back to the roofline
        // (and say so on every row)
        let ceil = Ceiling::auto();
        assert_eq!(ceil.provenance(), "roofline");
        let out = run_tune(&small_spec(), Ceiling::auto, 1, |_| {}).unwrap();
        assert!(out.rows.iter().all(|r| r.ceiling == "roofline"));
        assert_eq!(out.summary.ceiling, "roofline");
    }
}
