//! JSONL wire codec for the **`tune` verb**: a request line carries a
//! [`TuneSpec`]; the CLI streams one row line per point plus a summary
//! line, while the stdio/TCP wire answers with a single line embedding
//! every row and the summary (one-line-per-request holds).
//!
//! Request line:
//!
//! ```json
//! {"v":1,"id":"t1","op":"tune","tune":{"gpus":["A40"],
//!  "source":{"sampled":4},"gap_threshold":1e-1,"seed":42,
//!  "max_block":128,"max_stages":5,"max_warps":8}}
//! ```
//!
//! `gpus` is `"all"` (default), `"seen"`, `"unseen"`, or an array of
//! names; `source` is `{"sampled":N}` (default N=4), `{"dataset":N}`, or
//! `{"explicit":[{"m":..,"e":..,"topk":..,"h":..,"n":..},..]}`; every
//! other field defaults to the §VII setup. Streamed row lines carry the
//! ceiling provenance (`"p80"` or `"roofline"`), the default and best
//! `MoeConfig` (the same object shape the predict wire uses), and the
//! gap movement; the summary line carries the Table-X aggregates:
//!
//! ```json
//! {"v":1,"row":{"index":0,"gpu":"A40","ceiling":"roofline","shape":
//!  {"m":64,"e":8,"topk":2,"h":1024,"n":512},"diagnosed":true,
//!  "default":{"block_m":64,...},"best":{"block_m":128,...},
//!  "actual_eff":5e-1,"ceiling_eff":7.5e-1,"eff_after":6.25e-1,
//!  "gap_before":2.5e-1,"gap_after":1.25e-1,"speedup":1.25e0}}
//! {"v":1,"summary":{"points":4,"diagnosed":1,"ceiling":"roofline",
//!  "geomean_speedup":1.1e0,...,"ranked":[0]}}
//! ```
//!
//! Spec-level failures speak the closed [`TuneError`] taxonomy.

use super::report::{TuneOutcome, TuneRow, TuneSummary};
use super::spec::{ConfigSource, MoeShape, TuneSpec};
use super::TuneError;
use crate::api::wire::{esc, id_of};
use crate::api::PROTOCOL_VERSION;
use crate::kernels::MoeConfig;
use crate::sweep::GpuFilter;
use crate::util::json::{parse, Json};

fn malformed(why: impl Into<String>) -> TuneError {
    TuneError::MalformedSpec(why.into())
}

// ---- spec ----------------------------------------------------------------

fn filter_to_json(f: &GpuFilter) -> String {
    match f {
        GpuFilter::All => "\"all\"".to_string(),
        GpuFilter::Seen => "\"seen\"".to_string(),
        GpuFilter::Unseen => "\"unseen\"".to_string(),
        GpuFilter::Named(names) => {
            let items: Vec<String> = names.iter().map(|n| format!("\"{}\"", esc(n))).collect();
            format!("[{}]", items.join(","))
        }
    }
}

fn filter_from_json(v: &Json) -> Result<GpuFilter, TuneError> {
    match v {
        Json::Str(s) => match s.as_str() {
            "all" => Ok(GpuFilter::All),
            "seen" => Ok(GpuFilter::Seen),
            "unseen" => Ok(GpuFilter::Unseen),
            other => Err(malformed(format!(
                "\"gpus\" filter {other:?} is not all|seen|unseen"
            ))),
        },
        Json::Arr(items) => items
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| malformed("\"gpus\" entries must be strings"))
            })
            .collect::<Result<Vec<String>, TuneError>>()
            .map(GpuFilter::Named),
        _ => Err(malformed("\"gpus\" must be \"all\"|\"seen\"|\"unseen\" or an array of names")),
    }
}

fn u32_field(v: &Json, what: &str) -> Result<u32, TuneError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
        .map(|n| n as u32)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

fn count_field(v: &Json, what: &str) -> Result<usize, TuneError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
        .map(|n| n as usize)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

/// Seeds are full u64s; JSON numbers are only exact to 2^53, so bigger
/// seeds ride the wire as strings (the scenario wire's convention).
fn seed_to_json(v: u64) -> String {
    if v <= (1u64 << 53) {
        v.to_string()
    } else {
        format!("\"{v}\"")
    }
}

fn seed_from_json(v: &Json) -> Result<u64, TuneError> {
    let bad = || malformed("\"seed\" must be an unsigned integer");
    match v {
        Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= 9_007_199_254_740_992.0 => {
            Ok(*n as u64)
        }
        Json::Str(s) => s.parse().map_err(|_| bad()),
        _ => Err(bad()),
    }
}

fn source_to_json(s: &ConfigSource) -> String {
    match s {
        ConfigSource::Sampled { n } => format!("{{\"sampled\":{n}}}"),
        ConfigSource::Dataset { n } => format!("{{\"dataset\":{n}}}"),
        ConfigSource::Explicit(shapes) => {
            let items: Vec<String> = shapes.iter().map(shape_to_json).collect();
            format!("{{\"explicit\":[{}]}}", items.join(","))
        }
    }
}

fn shape_to_json(s: &MoeShape) -> String {
    format!(
        r#"{{"m":{},"e":{},"topk":{},"h":{},"n":{}}}"#,
        s.m, s.e, s.topk, s.h, s.n
    )
}

fn shape_from_json(v: &Json) -> Result<MoeShape, TuneError> {
    let field = |key: &str| -> Result<u32, TuneError> {
        let x = v
            .get(key)
            .ok_or_else(|| malformed(format!("explicit shapes need {key:?}")))?;
        u32_field(x, key)
    };
    Ok(MoeShape {
        m: field("m")?,
        e: field("e")?,
        topk: field("topk")?,
        h: field("h")?,
        n: field("n")?,
    })
}

fn source_from_json(v: &Json) -> Result<ConfigSource, TuneError> {
    if let Some(n) = v.get("sampled") {
        return Ok(ConfigSource::Sampled { n: count_field(n, "sampled")? });
    }
    if let Some(n) = v.get("dataset") {
        return Ok(ConfigSource::Dataset { n: count_field(n, "dataset")? });
    }
    if let Some(e) = v.get("explicit") {
        let arr =
            e.as_arr().ok_or_else(|| malformed("\"explicit\" must be an array of MoE shapes"))?;
        return arr
            .iter()
            .map(shape_from_json)
            .collect::<Result<Vec<MoeShape>, TuneError>>()
            .map(ConfigSource::Explicit);
    }
    Err(malformed(
        "\"source\" must be {\"sampled\":N}, {\"dataset\":N} or {\"explicit\":[..]}",
    ))
}

fn tune_to_json(spec: &TuneSpec) -> String {
    format!(
        r#"{{"gpus":{},"source":{},"gap_threshold":{:e},"seed":{},"max_block":{},"max_stages":{},"max_warps":{}}}"#,
        filter_to_json(&spec.gpus),
        source_to_json(&spec.source),
        spec.gap_threshold,
        seed_to_json(spec.seed),
        spec.max_block,
        spec.max_stages,
        spec.max_warps
    )
}

/// Serialize a tune request into its canonical wire line (no trailing
/// newline). The inverse of [`parse_tune_line`].
pub fn encode_tune_request(id: Option<&str>, spec: &TuneSpec) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(",\"op\":\"tune\",\"tune\":{}", tune_to_json(spec)));
    out.push('}');
    out
}

fn parse_tune_object(j: &Json) -> Result<TuneSpec, TuneError> {
    let mut spec = TuneSpec::new();
    if let Some(v) = j.get("gpus") {
        spec.gpus = filter_from_json(v)?;
    }
    if let Some(v) = j.get("source") {
        spec.source = source_from_json(v)?;
    }
    if let Some(v) = j.get("gap_threshold") {
        spec.gap_threshold =
            v.as_f64().ok_or_else(|| malformed("\"gap_threshold\" must be a number"))?;
    }
    if let Some(v) = j.get("seed") {
        spec.seed = seed_from_json(v)?;
    }
    if let Some(v) = j.get("max_block") {
        spec.max_block = u32_field(v, "max_block")?;
    }
    if let Some(v) = j.get("max_stages") {
        spec.max_stages = u32_field(v, "max_stages")?;
    }
    if let Some(v) = j.get("max_warps") {
        spec.max_warps = u32_field(v, "max_warps")?;
    }
    Ok(spec)
}

fn check_version(j: &Json) -> Result<(), TuneError> {
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        if v as u32 != PROTOCOL_VERSION {
            return Err(malformed(format!(
                "protocol version {v} (this build speaks v{PROTOCOL_VERSION})"
            )));
        }
    }
    Ok(())
}

fn tune_fields(j: &Json) -> Result<TuneSpec, TuneError> {
    check_version(j)?;
    let t = j.get("tune").ok_or_else(|| malformed("tune request needs a \"tune\" object"))?;
    parse_tune_object(t)
}

/// Envelope parse over an already-decoded line (single-parse dispatch —
/// what the stdio loop uses).
pub(crate) fn parse_tune_json(j: &Json) -> (Option<String>, Result<TuneSpec, TuneError>) {
    (id_of(j), tune_fields(j))
}

/// Whether a decoded wire object addresses the tune verb. Checked before
/// the simulate shapes in the stdio dispatcher.
pub(crate) fn is_tune_json(j: &Json) -> bool {
    j.get("op").and_then(|v| v.as_str()) == Some("tune") || j.get("tune").is_some()
}

/// Parse a tune line in either shape: the wire envelope or a bare tune
/// object (`{"gpus":..,"source":..}`) — what `synperf tune --spec`
/// accepts.
pub fn parse_tune_line(line: &str) -> (Option<String>, Result<TuneSpec, TuneError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    let res = if j.get("tune").is_some() || j.get("op").is_some() {
        tune_fields(&j)
    } else {
        parse_tune_object(&j)
    };
    (id_of(&j), res)
}

/// Whether a wire line addresses the tune verb (malformed JSON is not
/// claimed — the predict codec owns that bucket).
pub fn is_tune_request(line: &str) -> bool {
    match parse(line) {
        Ok(j) => is_tune_json(&j),
        Err(_) => false,
    }
}

// ---- rows & summary -------------------------------------------------------

/// The predict wire's `MoeConfig` object shape, reused verbatim.
fn cfg_to_json(c: &MoeConfig) -> String {
    format!(
        r#"{{"block_m":{},"block_n":{},"block_k":{},"num_stages":{},"num_warps":{}}}"#,
        c.block_m, c.block_n, c.block_k, c.num_stages, c.num_warps
    )
}

fn row_to_json(r: &TuneRow) -> String {
    // contained-panic rows carry a typed error object; healthy rows stay
    // byte-identical to the pre-containment wire
    let error = match &r.error {
        Some(why) => format!(r#","error":{{"code":"internal","message":"{}"}}"#, esc(why)),
        None => String::new(),
    };
    format!(
        r#"{{"index":{},"gpu":"{}","ceiling":"{}","shape":{},"diagnosed":{},"default":{},"best":{},"actual_eff":{:e},"ceiling_eff":{:e},"eff_after":{:e},"gap_before":{:e},"gap_after":{:e},"speedup":{:e}{}}}"#,
        r.index,
        esc(&r.gpu),
        r.ceiling,
        shape_to_json(&r.shape),
        r.diagnosed,
        cfg_to_json(&r.default_cfg),
        cfg_to_json(&r.best_cfg),
        r.actual_eff,
        r.ceiling_eff,
        r.eff_after,
        r.gap_before,
        r.gap_after,
        r.speedup,
        error
    )
}

/// One streamed JSONL result row (no trailing newline).
pub fn encode_row(r: &TuneRow) -> String {
    format!("{{\"v\":{PROTOCOL_VERSION},\"row\":{}}}", row_to_json(r))
}

fn summary_to_json(s: &TuneSummary) -> String {
    let ranked: Vec<String> = s.ranked.iter().map(usize::to_string).collect();
    format!(
        r#"{{"points":{},"diagnosed":{},"ceiling":"{}","geomean_speedup":{:e},"geomean_speedup_diagnosed":{:e},"gap_closure":{:e},"max_speedup":{:e},"ranked":[{}]}}"#,
        s.points,
        s.diagnosed,
        s.ceiling,
        s.geomean_speedup,
        s.geomean_speedup_diagnosed,
        s.gap_closure,
        s.max_speedup,
        ranked.join(",")
    )
}

/// The summary line the CLI emits after the last row (no trailing
/// newline).
pub fn encode_summary(s: &TuneSummary) -> String {
    format!("{{\"v\":{PROTOCOL_VERSION},\"summary\":{}}}", summary_to_json(s))
}

fn tune_error_to_json(e: &TuneError) -> String {
    let mut out =
        format!("{{\"code\":\"{}\",\"message\":\"{}\"", e.code(), esc(&e.to_string()));
    match e {
        TuneError::UnknownGpu(name) => out.push_str(&format!(",\"gpu\":\"{}\"", esc(name))),
        TuneError::UnsupportedKernel(why)
        | TuneError::InvalidSpec(why)
        | TuneError::GridTooLarge(why)
        | TuneError::MalformedSpec(why) => {
            out.push_str(&format!(",\"reason\":\"{}\"", esc(why)));
        }
    }
    out.push('}');
    out
}

/// One-line tune response for the stdio/TCP wire: every row plus the
/// summary in a single envelope, or the spec-level error. The point cap
/// ([`super::MAX_TUNE_POINTS`]) bounds the line length.
pub fn encode_tune_response(id: Option<&str>, res: &Result<TuneOutcome, TuneError>) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(o) => {
            let rows: Vec<String> = o.rows.iter().map(row_to_json).collect();
            out.push_str(&format!(
                ",\"ok\":true,\"tune\":{{\"rows\":[{}],\"summary\":{}}}",
                rows.join(","),
                summary_to_json(&o.summary)
            ));
        }
        Err(e) => out.push_str(&format!(",\"ok\":false,\"error\":{}", tune_error_to_json(e))),
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{run_tune, Ceiling};

    fn round_trip_spec() -> TuneSpec {
        TuneSpec::new()
            .gpus(GpuFilter::Named(vec!["A40".into(), "H800".into()]))
            .source(ConfigSource::Explicit(vec![MoeShape {
                m: 256,
                e: 16,
                topk: 2,
                h: 1024,
                n: 512,
            }]))
            .gap_threshold(0.05)
            .seed(u64::MAX - 1)
            .bounds(64, 4, 8)
    }

    #[test]
    fn tune_requests_round_trip() {
        let spec = round_trip_spec();
        let line = encode_tune_request(Some("t"), &spec);
        assert!(is_tune_request(&line), "{line}");
        let (id, parsed) = parse_tune_line(&line);
        assert_eq!(id.as_deref(), Some("t"));
        assert_eq!(parsed.unwrap(), spec, "round trip of {line}");
        // sampled/dataset sources round-trip too
        for src in [ConfigSource::Sampled { n: 7 }, ConfigSource::Dataset { n: 9 }] {
            let spec = TuneSpec::new().source(src);
            let (_, parsed) = parse_tune_line(&encode_tune_request(None, &spec));
            assert_eq!(parsed.unwrap(), spec);
        }
    }

    #[test]
    fn bare_tune_objects_parse_with_defaults() {
        let (id, res) = parse_tune_line(r#"{"gpus":["A40"]}"#);
        assert_eq!(id, None);
        let spec = res.unwrap();
        assert_eq!(spec.gpus, GpuFilter::Named(vec!["A40".into()]));
        assert_eq!(spec.source, ConfigSource::Sampled { n: 4 });
        assert_eq!(spec.gap_threshold, crate::autotune::GAP_THRESHOLD);
        assert_eq!(spec.max_block, 128);
    }

    #[test]
    fn malformed_tunes_map_into_the_taxonomy() {
        let cases = [
            ("not json", "malformed_spec"),
            (r#"{"op":"tune"}"#, "malformed_spec"),
            (r#"{"v":9,"op":"tune","tune":{}}"#, "malformed_spec"),
            (r#"{"tune":{"gpus":"fastest"}}"#, "malformed_spec"),
            (r#"{"tune":{"source":{}}}"#, "malformed_spec"),
            (r#"{"tune":{"source":{"sampled":1.5}}}"#, "malformed_spec"),
            (r#"{"tune":{"source":{"explicit":[{"m":4}]}}}"#, "malformed_spec"),
            (r#"{"tune":{"gap_threshold":"big"}}"#, "malformed_spec"),
            (r#"{"tune":{"seed":-1}}"#, "malformed_spec"),
        ];
        for (line, code) in cases {
            let (_, res) = parse_tune_line(line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn verb_dispatch_does_not_overlap_other_verbs() {
        assert!(is_tune_request(r#"{"op":"tune","tune":{}}"#));
        assert!(is_tune_request(r#"{"tune":{"gpus":"all"}}"#));
        assert!(!is_tune_request(r#"{"op":"sweep","sweep":{"workloads":[]}}"#));
        assert!(!is_tune_request(r#"{"scenario":{"model":"m","gpu":"g"}}"#));
        assert!(!is_tune_request(r#"{"gpu":"A100","kernel":{"type":"rmsnorm","seq":1,"dim":8}}"#));
        assert!(!crate::sweep::wire::is_sweep_request(r#"{"op":"tune","tune":{}}"#));
    }

    #[test]
    fn contained_panic_rows_carry_a_typed_error_object() {
        let cfg =
            MoeConfig { block_m: 64, block_n: 64, block_k: 32, num_stages: 4, num_warps: 8 };
        let row = TuneRow {
            index: 3,
            gpu: "A40".into(),
            ceiling: "roofline",
            shape: MoeShape { m: 64, e: 8, topk: 2, h: 1024, n: 512 },
            default_cfg: cfg,
            best_cfg: cfg,
            diagnosed: false,
            actual_eff: 0.0,
            ceiling_eff: 0.0,
            eff_after: 0.0,
            gap_before: 0.0,
            gap_after: 0.0,
            speedup: 1.0,
            error: Some("tune point evaluation panicked: boom".into()),
        };
        assert_eq!(
            encode_row(&row),
            r#"{"v":1,"row":{"index":3,"gpu":"A40","ceiling":"roofline","shape":{"m":64,"e":8,"topk":2,"h":1024,"n":512},"diagnosed":false,"default":{"block_m":64,"block_n":64,"block_k":32,"num_stages":4,"num_warps":8},"best":{"block_m":64,"block_n":64,"block_k":32,"num_stages":4,"num_warps":8},"actual_eff":0e0,"ceiling_eff":0e0,"eff_after":0e0,"gap_before":0e0,"gap_after":0e0,"speedup":1e0,"error":{"code":"internal","message":"tune point evaluation panicked: boom"}}}"#
        );
        // healthy rows never grow the field
        let healthy = TuneRow { error: None, speedup: 1.25, diagnosed: true, ..row };
        assert!(!encode_row(&healthy).contains("\"error\""));
    }

    #[test]
    fn responses_embed_rows_and_summary_in_one_line() {
        let spec = TuneSpec::new()
            .gpus(GpuFilter::Named(vec!["A40".into()]))
            .source(ConfigSource::Sampled { n: 2 })
            .seed(31);
        let out = run_tune(&spec, Ceiling::auto, 2, |_| {}).unwrap();
        let line = encode_tune_response(Some("t1"), &Ok(out.clone()));
        assert!(line.starts_with(r#"{"v":1,"id":"t1","ok":true,"tune":{"rows":["#), "{line}");
        assert!(line.contains(r#""summary":{"points":2"#), "{line}");
        assert!(!line.contains('\n'));
        // each row's embedded object matches its streamed encoding
        for row in &out.rows {
            let streamed = encode_row(row);
            let inner = streamed
                .strip_prefix(r#"{"v":1,"row":"#)
                .and_then(|s| s.strip_suffix('}'))
                .unwrap();
            assert!(line.contains(inner), "row {} drifted between shapes", row.index);
        }
        // spec-level errors ride the same envelope
        let err = encode_tune_response(None, &Err(TuneError::GridTooLarge("big".into())));
        assert_eq!(
            err,
            r#"{"v":1,"ok":false,"error":{"code":"grid_too_large","message":"tune grid too large: big","reason":"big"}}"#
        );
    }
}
