//! Tune result rows and the Table-X-style summary: per-point
//! default/best configs with efficiency and gap movement, plus the
//! aggregate geomean speedups and gap-closure rate (§VII-C, Fig. 9).

use crate::kernels::MoeConfig;
use crate::util::stats::geomean;

use super::spec::MoeShape;

/// One streamed result row: the point's launch on one GPU, its diagnosis
/// against the ceiling, and — when diagnosed — the best §VII-C candidate
/// found and the gap movement it buys.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneRow {
    pub index: usize,
    /// Canonical registry name.
    pub gpu: String,
    /// Ceiling provenance: `"p80"` (trained pinball model) or
    /// `"roofline"` (analytical fallback).
    pub ceiling: &'static str,
    pub shape: MoeShape,
    /// The shipped default config for this launch (what SGLang would run).
    pub default_cfg: MoeConfig,
    /// The best candidate found; equals `default_cfg` when the point was
    /// not diagnosed (undiagnosed points are never tuned).
    pub best_cfg: MoeConfig,
    /// `gap_before > gap_threshold` — an Underperforming Point (§VII-B).
    pub diagnosed: bool,
    /// Measured efficiency of the default config.
    pub actual_eff: f64,
    /// Ceiling efficiency (P80 prediction or roofline bound).
    pub ceiling_eff: f64,
    /// Efficiency after tuning (= `actual_eff` when not diagnosed).
    pub eff_after: f64,
    /// `ceiling_eff - actual_eff`; may be negative (no headroom).
    pub gap_before: f64,
    /// `max(ceiling_eff - eff_after, 0)`.
    pub gap_after: f64,
    /// `default_sec / best_sec` over clean oracle time; 1.0 when not
    /// diagnosed.
    pub speedup: f64,
    /// Set when evaluating this point panicked: the point is contained
    /// into a typed error row (neutral metrics — undiagnosed, speedup
    /// 1.0 — so the summary never counts phantom gains) instead of
    /// taking the whole tune down.
    pub error: Option<String>,
}

/// The one-line aggregate over a finished tune (Table X / Fig. 9).
#[derive(Debug, Clone, PartialEq)]
pub struct TuneSummary {
    pub points: usize,
    pub diagnosed: usize,
    /// Ceiling provenance shared by every row of the run.
    pub ceiling: &'static str,
    /// Geomean speedup over every point (undiagnosed points contribute
    /// 1.0 — the "don't touch what isn't broken" view).
    pub geomean_speedup: f64,
    /// Geomean speedup over diagnosed points only (the Table-X headline).
    pub geomean_speedup_diagnosed: f64,
    /// Fraction of the summed diagnosed gap closed by tuning, in [0, 1].
    pub gap_closure: f64,
    pub max_speedup: f64,
    /// Diagnosed row indices ranked widest-gap-first (§VII-B ranking).
    pub ranked: Vec<usize>,
}

/// Everything a finished tune yields: the rows (in index order) and the
/// summary over them.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneOutcome {
    pub rows: Vec<TuneRow>,
    pub summary: TuneSummary,
}

/// Collapse rows (in index order) into the summary.
pub(crate) fn summarize(rows: &[TuneRow]) -> TuneSummary {
    let all: Vec<f64> = rows.iter().map(|r| r.speedup).collect();
    let diagnosed: Vec<&TuneRow> = rows.iter().filter(|r| r.diagnosed).collect();
    let mut ranked: Vec<usize> = diagnosed.iter().map(|r| r.index).collect();
    ranked.sort_by(|&a, &b| {
        rows[b]
            .gap_before
            .partial_cmp(&rows[a].gap_before)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let dsp: Vec<f64> = diagnosed.iter().map(|r| r.speedup).collect();
    let gap_sum: f64 = diagnosed.iter().map(|r| r.gap_before.max(0.0)).sum();
    let gap_after_sum: f64 = diagnosed.iter().map(|r| r.gap_after).sum();
    TuneSummary {
        points: rows.len(),
        diagnosed: diagnosed.len(),
        ceiling: rows.first().map_or("roofline", |r| r.ceiling),
        geomean_speedup: geomean(&all),
        geomean_speedup_diagnosed: geomean(&dsp),
        gap_closure: if gap_sum > 0.0 {
            ((gap_sum - gap_after_sum) / gap_sum).clamp(0.0, 1.0)
        } else {
            0.0
        },
        max_speedup: all.iter().copied().fold(1.0, f64::max),
        ranked,
    }
}

fn cfg_label(c: &MoeConfig) -> String {
    format!("{}x{}x{}/s{}/w{}", c.block_m, c.block_n, c.block_k, c.num_stages, c.num_warps)
}

/// Human Table-X-style report on stderr: diagnosed points ranked
/// widest-gap-first, then the aggregate line. Stdout stays pure JSONL.
pub fn print_report(out: &TuneOutcome) {
    use crate::util::table::{f, pct, Table};
    let s = &out.summary;
    if !s.ranked.is_empty() {
        let mut t = Table::new(
            &format!("underperforming points, widest gap first (ceiling: {})", s.ceiling),
            &["#", "gpu", "m/e/topk/h/n", "eff", "ceiling", "gap", "best cfg", "speedup", "gap'"],
        );
        for &i in &s.ranked {
            let r = &out.rows[i];
            t.row(vec![
                r.index.to_string(),
                r.gpu.clone(),
                format!(
                    "{}/{}/{}/{}/{}",
                    r.shape.m, r.shape.e, r.shape.topk, r.shape.h, r.shape.n
                ),
                f(r.actual_eff, 3),
                f(r.ceiling_eff, 3),
                f(r.gap_before, 3),
                cfg_label(&r.best_cfg),
                format!("{}x", f(r.speedup, 2)),
                f(r.gap_after, 3),
            ]);
        }
        eprint!("{}", t.render());
    }
    eprintln!(
        "tune: {} points, {} diagnosed (ceiling: {}); geomean speedup {}x overall, {}x on diagnosed; max {}x; gap closure {}",
        s.points,
        s.diagnosed,
        s.ceiling,
        f(s.geomean_speedup, 3),
        f(s.geomean_speedup_diagnosed, 3),
        f(s.max_speedup, 2),
        pct(s.gap_closure)
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(index: usize, diagnosed: bool, gap_before: f64, speedup: f64) -> TuneRow {
        let cfg = MoeConfig { block_m: 64, block_n: 64, block_k: 32, num_stages: 4, num_warps: 8 };
        let ceiling_eff = 0.8;
        let actual_eff = ceiling_eff - gap_before;
        let eff_after = if diagnosed { (actual_eff * speedup).min(0.995) } else { actual_eff };
        TuneRow {
            index,
            gpu: "A40".into(),
            ceiling: "roofline",
            shape: MoeShape { m: 64, e: 8, topk: 2, h: 1024, n: 512 },
            default_cfg: cfg,
            best_cfg: cfg,
            diagnosed,
            actual_eff,
            ceiling_eff,
            eff_after,
            gap_before,
            gap_after: (ceiling_eff - eff_after).max(0.0),
            speedup,
            error: None,
        }
    }

    #[test]
    fn summary_ranks_diagnosed_points_widest_gap_first() {
        let rows =
            vec![row(0, true, 0.2, 1.2), row(1, false, 0.05, 1.0), row(2, true, 0.4, 1.5)];
        let s = summarize(&rows);
        assert_eq!(s.points, 3);
        assert_eq!(s.diagnosed, 2);
        assert_eq!(s.ranked, vec![2, 0]);
        assert!(s.geomean_speedup_diagnosed > s.geomean_speedup);
        assert_eq!(s.max_speedup, 1.5);
        assert!(s.gap_closure > 0.0 && s.gap_closure <= 1.0, "{}", s.gap_closure);
    }

    #[test]
    fn empty_and_undiagnosed_summaries_stay_defined() {
        let s = summarize(&[]);
        assert_eq!((s.points, s.diagnosed), (0, 0));
        assert_eq!(s.geomean_speedup, 1.0);
        assert_eq!(s.geomean_speedup_diagnosed, 1.0);
        assert_eq!(s.gap_closure, 0.0);
        let s = summarize(&[row(0, false, 0.02, 1.0)]);
        assert_eq!(s.diagnosed, 0);
        assert_eq!(s.geomean_speedup_diagnosed, 1.0);
        assert!(s.ranked.is_empty());
    }
}
