//! **Autotune subsystem** — "beyond simulation" (paper §VII):
//! model-guided optimization of the Fused-MoE Triton kernel, end to end.
//!
//!  1. Train the same MLP with **pinball loss τ=0.8** -> a statistically
//!     robust *Potential Performance Ceiling* `ŷ_p80` (§VII-A). When no
//!     trained P80 artifact exists the analytical roofline bound stands
//!     in, recorded in row provenance ([`Ceiling::auto`]).
//!  2. Diagnose: perf_gap = ŷ_p80 − y_actual; configurations with gap >
//!     the spec threshold (default [`GAP_THRESHOLD`]) are
//!     *Underperforming Points*, ranked widest-gap-first (§VII-B, Fig. 8).
//!  3. Act: brute-force autotune `(BLOCK_SIZE, num_stages, num_warps)`
//!     on the diagnosed points and verify the gap closes (§VII-C,
//!     Table X / Fig. 9).
//!
//! The declarative [`TuneSpec`] (GPU filter over the Table-VI registry,
//! launch source, gap threshold, candidate bounds) drives the whole
//! pipeline through [`run_tune`], which mirrors the sweep subsystem:
//! work-stealing workers each owning one [`Ceiling`], rows streamed in
//! strict index order (byte-identical at any `--threads`), a closed
//! [`TuneError`] taxonomy, and a JSONL wire shape ([`wire`]) riding the
//! `synperf tune` CLI verb plus the `tune` request on `serve`
//! `--stdio`/`--tcp`.
//!
//! The original free functions survive as the low-level library surface:
//! [`diagnose`] applies a caller-supplied P80 model to a dataset split,
//! and [`tune`] brute-forces one launch on one GPU.

pub mod report;
pub mod search;
pub mod spec;
pub mod wire;

pub use report::{print_report, TuneOutcome, TuneRow, TuneSummary};
pub use search::{candidates, expand, run_tune, Ceiling, TunePoint};
pub use spec::{ConfigSource, MoeShape, TuneError, TuneSpec, MAX_TUNE_CONFIGS, MAX_TUNE_POINTS};

use crate::dataset::{finalize_for_gpu, Sample};
use crate::hw::GpuSpec;
use crate::kernels::{fused_moe, KernelConfig, KernelKind};
use crate::mlp::Predictor;
use crate::oracle;
use anyhow::Result;

/// Default gap threshold defining an Underperforming Point (§VII-B) —
/// the [`TuneSpec::gap_threshold`] default.
pub const GAP_THRESHOLD: f64 = 0.1;

/// Per-sample diagnosis record.
#[derive(Debug, Clone)]
pub struct GapRecord {
    pub gpu: String,
    pub actual_eff: f64,
    pub ceiling_eff: f64,
    pub gap: f64,
}

impl GapRecord {
    pub fn underperforming(&self) -> bool {
        self.gap > GAP_THRESHOLD
    }
}

/// Apply the P80 ceiling model to a dataset split (§VII-B).
pub fn diagnose(p80: &Predictor, samples: &[Sample]) -> Result<Vec<GapRecord>> {
    let xs: Vec<_> = samples.iter().map(|s| s.x).collect();
    let ceil = p80.predict_eff(&xs)?;
    Ok(samples
        .iter()
        .zip(ceil)
        .map(|(s, c)| {
            let actual = s.efficiency();
            GapRecord { gpu: s.gpu.clone(), actual_eff: actual, ceiling_eff: c, gap: c - actual }
        })
        .collect())
}

/// Result of brute-force tuning one configuration on one GPU (§VII-C).
#[derive(Debug, Clone)]
pub struct TuneResult {
    pub default_sec: f64,
    pub best_sec: f64,
    pub best_cfg: crate::kernels::MoeConfig,
}

impl TuneResult {
    pub fn speedup(&self) -> f64 {
        self.default_sec / self.best_sec
    }
}

/// Brute-force sweep over the §VII-C space for one Fused-MoE launch.
/// `seed` fixes the oracle measurement stream (routing is reused across
/// candidates). Non-MoE configs are a typed [`TuneError::UnsupportedKernel`].
pub fn tune(cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> Result<TuneResult, TuneError> {
    let KernelConfig::FusedMoe { h, n, expert_tokens, cfg: default_cfg, .. } =
        finalize_for_gpu(cfg, gpu)
    else {
        return Err(TuneError::UnsupportedKernel(format!(
            "tune() expects a fused_moe config, got {:?}",
            cfg.kind().name()
        )));
    };
    let measure = |c: crate::kernels::MoeConfig, s: u64| {
        let d = fused_moe::decompose(h, n, &expert_tokens, c, gpu);
        oracle::measure_decomposed(KernelKind::FusedMoe, &d, gpu, s).clean_sec
    };
    let default_sec = measure(default_cfg, seed);
    let mut best_sec = default_sec;
    let mut best_cfg = default_cfg;
    for cand in fused_moe::tuning_space() {
        if !fused_moe::config_valid(&cand, gpu) {
            continue;
        }
        let t = measure(cand, seed);
        if t < best_sec {
            best_sec = t;
            best_cfg = cand;
        }
    }
    Ok(TuneResult { default_sec, best_sec, best_cfg })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset;
    use crate::hw::gpu_by_name;

    #[test]
    fn tuning_never_hurts() {
        let configs = dataset::sample_configs(KernelKind::FusedMoe, 5, 31);
        let gpu = gpu_by_name("A40").unwrap();
        for (i, cfg) in configs.iter().enumerate() {
            let r = tune(cfg, &gpu, 100 + i as u64).unwrap();
            assert!(r.speedup() >= 1.0, "speedup {}", r.speedup());
        }
    }

    #[test]
    fn a40_gains_exceed_h800() {
        let configs = dataset::sample_configs(KernelKind::FusedMoe, 10, 77);
        let geo = |gpu_name: &str| {
            let gpu = gpu_by_name(gpu_name).unwrap();
            let sp: Vec<f64> = configs
                .iter()
                .enumerate()
                .map(|(i, c)| tune(c, &gpu, 500 + i as u64).unwrap().speedup())
                .collect();
            crate::util::stats::geomean(&sp)
        };
        let a40 = geo("A40");
        let h800 = geo("H800");
        assert!(a40 > h800, "A40 {a40} should out-gain H800 {h800}");
        // on *random* (not diagnosed) configs the headroom is modest; the
        // diagnosed-point geomean in Table X is substantially higher
        assert!(a40 > 1.04, "A40 tuning headroom too small: {a40}");
    }

    #[test]
    fn gap_record_threshold() {
        let g = GapRecord { gpu: "A40".into(), actual_eff: 0.4, ceiling_eff: 0.55, gap: 0.15 };
        assert!(g.underperforming());
        let g2 = GapRecord { gpu: "H20".into(), actual_eff: 0.6, ceiling_eff: 0.65, gap: 0.05 };
        assert!(!g2.underperforming());
    }

    #[test]
    fn non_moe_configs_are_a_typed_error() {
        let gpu = gpu_by_name("A40").unwrap();
        let cfg = KernelConfig::RmsNorm { seq: 64, dim: 1024 };
        let err = tune(&cfg, &gpu, 1).unwrap_err();
        assert_eq!(err.code(), "unsupported_kernel");
        assert!(err.to_string().contains("fused_moe"), "{err}");
    }
}
