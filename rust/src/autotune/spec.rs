//! The declarative tune spec ([`TuneSpec`]): which GPUs of the Table-VI
//! registry to diagnose, where the fused-MoE launches come from
//! ([`ConfigSource`]), the Underperforming-Point threshold, and the
//! candidate-space bounds of the §VII-C brute-force search — plus the
//! closed [`TuneError`] taxonomy mirroring [`SweepError`].

use crate::sweep::{GpuFilter, SweepError};
use std::fmt;

/// Hard cap on launches × GPUs: every diagnosed point costs up to a full
/// §VII-C candidate sweep (~100 oracle measurements), so the cap sits well
/// below [`crate::sweep::MAX_SWEEP_POINTS`] while still covering the full
/// registry at dataset-sized config counts.
pub const MAX_TUNE_POINTS: usize = 512;

/// Cap on the fused-MoE launch count a single source may materialize.
pub const MAX_TUNE_CONFIGS: usize = 128;

/// One explicit fused-MoE launch shape: `m` tokens routed to `e` experts
/// with `topk` choices, hidden `h`, output `n`. Routing (the per-expert
/// token counts) is derived deterministically from the spec seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeShape {
    pub m: u32,
    pub e: u32,
    pub topk: u32,
    pub h: u32,
    pub n: u32,
}

/// Where the tuned launches come from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ConfigSource {
    /// `n` launches drawn from the dataset sampler with the spec seed.
    Sampled { n: usize },
    /// `n` launches from the canonical dataset split (the fixed lab seed),
    /// so tune rows line up with `Lab::dataset_configs` positions.
    Dataset { n: usize },
    /// Explicit launch shapes, routed deterministically per shape.
    Explicit(Vec<MoeShape>),
}

/// The declarative tune: GPU slice × launch source × thresholds × §VII-C
/// candidate bounds. Builder defaults mirror the paper's setup: the whole
/// registry, a handful of sampled launches, gap threshold 0.1 and the
/// full `(BLOCK_SIZE, num_stages, num_warps)` space.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneSpec {
    pub gpus: GpuFilter,
    pub source: ConfigSource,
    /// Underperforming-Point threshold (§VII-B): points with
    /// `ceiling_eff - actual_eff > gap_threshold` are brute-force tuned.
    /// Defaults to [`super::GAP_THRESHOLD`].
    pub gap_threshold: f64,
    /// Seeds sampling, routing and the per-point oracle streams.
    pub seed: u64,
    /// Candidate bound: `max(block_m, block_n) <= max_block`.
    pub max_block: u32,
    /// Candidate bound: `num_stages <= max_stages`.
    pub max_stages: u32,
    /// Candidate bound: `num_warps <= max_warps`.
    pub max_warps: u32,
}

impl TuneSpec {
    pub fn new() -> Self {
        TuneSpec {
            gpus: GpuFilter::All,
            source: ConfigSource::Sampled { n: 4 },
            gap_threshold: super::GAP_THRESHOLD,
            seed: 0x7A7E,
            max_block: 128,
            max_stages: 5,
            max_warps: 8,
        }
    }

    pub fn gpus(mut self, gpus: GpuFilter) -> Self {
        self.gpus = gpus;
        self
    }

    pub fn source(mut self, source: ConfigSource) -> Self {
        self.source = source;
        self
    }

    pub fn gap_threshold(mut self, t: f64) -> Self {
        self.gap_threshold = t;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Restrict the §VII-C candidate space.
    pub fn bounds(mut self, max_block: u32, max_stages: u32, max_warps: u32) -> Self {
        self.max_block = max_block;
        self.max_stages = max_stages;
        self.max_warps = max_warps;
        self
    }
}

impl Default for TuneSpec {
    fn default() -> Self {
        Self::new()
    }
}

/// The closed error taxonomy of the tune surface, mirroring
/// [`SweepError`]. These are spec-level failures that abort before any
/// row is evaluated; the per-point pipeline itself never fails (expansion
/// only materializes launches that are valid by construction).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TuneError {
    /// A named GPU is not in the Table-VI registry.
    UnknownGpu(String),
    /// The launch to tune is not a fused-MoE kernel (§VII only covers the
    /// Triton fused-MoE space).
    UnsupportedKernel(String),
    /// A spec field is empty, zero-valued or out of range.
    InvalidSpec(String),
    /// launches × GPUs exceeds [`MAX_TUNE_POINTS`].
    GridTooLarge(String),
    /// The spec itself is malformed (bad JSON, bad field types).
    MalformedSpec(String),
}

impl TuneError {
    /// Stable machine-readable code (the `error.code` of the wire surface).
    pub fn code(&self) -> &'static str {
        match self {
            TuneError::UnknownGpu(_) => "unknown_gpu",
            TuneError::UnsupportedKernel(_) => "unsupported_kernel",
            TuneError::InvalidSpec(_) => "invalid_spec",
            TuneError::GridTooLarge(_) => "grid_too_large",
            TuneError::MalformedSpec(_) => "malformed_spec",
        }
    }
}

impl fmt::Display for TuneError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TuneError::UnknownGpu(name) => {
                write!(
                    f,
                    "unknown GPU {name:?} (see Table VI; closest: {})",
                    crate::hw::nearest_names(name, 3).join(", ")
                )
            }
            TuneError::UnsupportedKernel(why) => write!(f, "unsupported kernel: {why}"),
            TuneError::InvalidSpec(why) => write!(f, "invalid tune spec: {why}"),
            TuneError::GridTooLarge(why) => write!(f, "tune grid too large: {why}"),
            TuneError::MalformedSpec(why) => write!(f, "malformed tune spec: {why}"),
        }
    }
}

impl std::error::Error for TuneError {}

/// The GPU-filter machinery is shared with the sweep subsystem; map its
/// failures into the tune taxonomy.
impl From<SweepError> for TuneError {
    fn from(e: SweepError) -> TuneError {
        match e {
            SweepError::UnknownGpu(name) => TuneError::UnknownGpu(name),
            SweepError::MalformedSpec(why) => TuneError::MalformedSpec(why),
            SweepError::GridTooLarge(why) => TuneError::GridTooLarge(why),
            SweepError::InvalidAxis(why) | SweepError::InvalidWorkload(why) => {
                TuneError::InvalidSpec(why)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_mirror_the_paper_setup() {
        let s = TuneSpec::new();
        assert_eq!(s.gpus, GpuFilter::All);
        assert_eq!(s.source, ConfigSource::Sampled { n: 4 });
        assert_eq!(s.gap_threshold, crate::autotune::GAP_THRESHOLD);
        assert_eq!((s.max_block, s.max_stages, s.max_warps), (128, 5, 8));
    }

    #[test]
    fn unknown_gpu_carries_nearest_names() {
        let msg = TuneError::UnknownGpu("B300".into()).to_string();
        assert!(msg.contains("closest:"), "{msg}");
        assert_eq!(TuneError::UnknownGpu("B300".into()).code(), "unknown_gpu");
    }

    #[test]
    fn sweep_errors_map_into_the_taxonomy() {
        let e: TuneError = SweepError::UnknownGpu("X".into()).into();
        assert_eq!(e, TuneError::UnknownGpu("X".into()));
        let e: TuneError = SweepError::InvalidAxis("empty".into()).into();
        assert_eq!(e.code(), "invalid_spec");
    }
}
