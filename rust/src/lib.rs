//! # SynPerf (PipeWeave)
//!
//! A hybrid analytical-ML framework for GPU performance prediction,
//! reproducing "PIPEWEAVE: Synergizing Analytical and Learning Models for
//! Unified GPU Performance Prediction" (ISCA 2026) as a three-layer
//! rust + JAX + Pallas system (AOT via PJRT).
//!
//! Pipeline: [`kernels`] (Kernel Decomposer) -> [`sched`] (Scheduling
//! Simulator) -> [`features`] (Feature Analyzer) -> the Performance
//! Estimator MLP executed through [`runtime`] (PJRT) / [`mlp`].
//! The whole request path is owned by the shared [`engine`] subsystem
//! (memoizing analysis cache + parallel fan-out + per-category batched
//! routing); the [`coordinator`], [`e2e`] evaluator, [`dataset`] builder
//! and [`experiments`] all route through it.
//! Ground truth comes from the [`oracle`] testbed (the hardware
//! substitution documented in DESIGN.md §2).
//!
//! Every prediction consumer speaks **protocol v1** ([`api`]): typed
//! `PredictRequest`/`PredictResponse` with provenance (MLP vs degraded
//! roofline, cache hit), a closed `PredictError` taxonomy, and the same
//! schema as a JSONL wire surface (`synperf serve --stdio`).
//!
//! End-to-end serving prediction is declarative (**Scenario API v1**,
//! [`scenario`]): a `ScenarioSpec` (model by registry name, `{tp, pp}`
//! parallelism, workload, phase schedule, GPU, seed, host gap) compiles to
//! phase-tagged op streams and evaluates into a typed `ScenarioReport` —
//! per-phase TTFT/TPOT/tokens-per-second, per-method totals, a typed
//! `OpClass` breakdown, and degraded-kernel provenance — also exposed as
//! the `synperf simulate` JSONL wire verb.
//!
//! On top of the scenario stack, the [`sweep`] subsystem runs
//! fleet-scale hardware search: a declarative grid over GPUs ×
//! parallelism × replicas × routing policies × workloads, fanned through
//! work-stealing evaluators into deterministic JSONL rows and ranked by
//! Pareto frontier over (tokens/sec, SLO attainment, GPU count) — the
//! `synperf sweep` verb.
//!
//! Beyond prediction, the [`autotune`] subsystem closes the paper's §VII
//! loop: a declarative `TuneSpec` diagnoses Fused-MoE launches against the
//! P80 potential-performance ceiling (analytical roofline fallback,
//! recorded in provenance), ranks the underperforming points widest-gap
//! first, and brute-force-tunes `(BLOCK_SIZE, num_stages, num_warps)` on
//! work-stealing workers into deterministic JSONL rows plus a summary
//! (geomean speedups, gap-closure rate) — the `synperf tune` verb.

pub mod api;
pub mod coordinator;
pub mod dataset;
pub mod autotune;
pub mod baselines;
pub mod e2e;
pub mod engine;
pub mod experiments;
pub mod features;
pub mod forest;
pub mod hw;
pub mod kernels;
pub mod mlp;
pub mod oracle;
pub mod runtime;
pub mod sched;
pub mod scenario;
pub mod sweep;
pub mod util;
