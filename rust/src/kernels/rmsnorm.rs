//! FlashInfer fused RMSNorm decomposition: one CTA per token row (source-
//! derived F). FP32 math on the FMA pipe plus one rsqrt on the XU pipe per
//! row (Table V: Math Pipe = FMA, XU).

use super::{CtaResources, Decomposition, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::GpuSpec;

pub fn decompose(seq: u32, dim: u32, _gpu: &GpuSpec) -> Decomposition {
    let d = dim as f64;
    // x*x accumulate (1 FMA/elem), normalize multiply, weight multiply.
    let fma_ops = 3.0 * d;
    // rsqrt of the row mean (one MUFU per warp reduction lane).
    let xu_ops = 32.0;
    // loads: activation row (bf16) + weight row (bf16, highly L2-resident);
    // stores: normalized row.
    let bytes_load = 2.0 * d + 2.0 * d;
    let bytes_store = 2.0 * d;
    let task = Task {
        tensor_ops: 0.0,
        fma_ops,
        xu_ops,
        bytes_load,
        bytes_store,
        bytes_smem: 4.0 * 32.0, // warp-reduction scratch
        cost_hint: fma_ops + 4.0 * bytes_load,
    };
    Decomposition {
        // one task per token row, all identical: a single run
        task_groups: vec![TaskGroup { template: task, count: seq as u64 }],
        paradigm: Paradigm::HardwareRR,
        cta: CtaResources {
            warps: (dim.div_ceil(1024)).clamp(1, 8),
            smem_bytes: 1024,
            regs_per_thread: 40,
        },
        tile: (1, dim, 1),
        pipes: vec![Pipe::Fma, Pipe::Xu],
        // rows in and out once + the (tiny, cached) weight vector
        min_dram_bytes: 2.0 * 2.0 * seq as f64 * d + 2.0 * d,
        pipeline_stages: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn one_task_per_row() {
        let gpu = gpu_by_name("A100").unwrap();
        let d = decompose(4096, 8192, &gpu);
        assert_eq!(d.num_tasks(), 4096);
        assert_eq!(d.paradigm, Paradigm::HardwareRR);
    }

    #[test]
    fn no_tensor_demand() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = decompose(16, 1024, &gpu);
        assert_eq!(d.total_tensor_ops(), 0.0);
        assert!(d.task_groups[0].template.fma_ops > 0.0);
        assert!(d.task_groups[0].template.xu_ops > 0.0);
    }

    #[test]
    fn memory_dominated_profile() {
        // RMSNorm is bandwidth-bound: bytes ~ 3*dim*2, flops ~ 3*dim
        let gpu = gpu_by_name("A100").unwrap();
        let d = decompose(1, 16384, &gpu);
        let t = &d.task_groups[0].template;
        let ai = t.fma_ops / t.total_bytes();
        assert!(ai < 1.0, "arithmetic intensity should be low: {ai}");
    }

    #[test]
    fn high_occupancy_small_ctas() {
        let gpu = gpu_by_name("A100").unwrap();
        let d = decompose(64, 4096, &gpu);
        assert!(d.cta.occupancy(&gpu) >= 8);
    }
}
