//! SGLang Triton Fused-MoE grouped-GEMM decomposition (§II-A, §VII).
//!
//! After token routing, tokens are grouped per expert and the kernel runs a
//! batched GEMM across expert sub-networks: grid = Σ_e ceil(m_e/BM) ·
//! ceil(N/BN) CTAs, hardware-scheduled (Triton kernels launch conventional
//! grids — Table V). The launch configuration (BLOCK sizes, num_stages,
//! num_warps) is the §VII tuning space: it shifts occupancy, pipelining
//! depth, and MXU utilization, which is exactly where the paper finds
//! hardware-specific inefficiency on A40/L20.

use super::{CtaResources, Decomposition, MoeConfig, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::GpuSpec;

/// SGLang-style default launch config. The heuristic keys on the expected
/// per-expert token count only (as the shipped config dictionaries do for unlisted shapes) —
/// tuned on Hopper-class machines, which is why it mis-fits smaller-smem
/// parts like the A40 (§VII-B finds 30.4% of A40 samples underperforming).
pub fn default_config(m_tokens: u32, _gpu: &GpuSpec) -> MoeConfig {
    // deep 4-stage pipelines + 8-warp cooperative groups: ideal on Hopper's
    // 228KB smem and wide schedulers, register/occupancy poison on
    // 100KB-smem Ampere/Ada parts
    if m_tokens <= 32 {
        MoeConfig { block_m: 16, block_n: 64, block_k: 64, num_stages: 4, num_warps: 8 }
    } else if m_tokens <= 256 {
        MoeConfig { block_m: 64, block_n: 128, block_k: 64, num_stages: 4, num_warps: 8 }
    } else {
        MoeConfig { block_m: 128, block_n: 128, block_k: 32, num_stages: 4, num_warps: 8 }
    }
}

/// The §VII-C brute-force autotuning space: BLOCK_SIZE x num_stages x
/// num_warps.
pub fn tuning_space() -> Vec<MoeConfig> {
    let mut out = Vec::new();
    for &(bm, bn) in &[(16u32, 64u32), (32, 64), (64, 64), (64, 128), (128, 64), (128, 128)] {
        for &bk in &[32u32, 64] {
            for &num_stages in &[2u32, 3, 4, 5] {
                for &num_warps in &[4u32, 8] {
                    out.push(MoeConfig { block_m: bm, block_n: bn, block_k: bk, num_stages, num_warps });
                }
            }
        }
    }
    out
}

/// Shared-memory footprint of a config (A and B staging buffers per stage).
pub fn smem_bytes(cfg: &MoeConfig) -> u32 {
    cfg.num_stages * (cfg.block_m + cfg.block_n) * cfg.block_k * 2
}

/// A config is launchable on `gpu` if its staging buffers fit shared memory.
pub fn config_valid(cfg: &MoeConfig, gpu: &GpuSpec) -> bool {
    smem_bytes(cfg) <= gpu.smem_kb_sm * 1024
}

pub fn decompose(
    h: u32,
    n: u32,
    expert_tokens: &[u32],
    cfg: MoeConfig,
    _gpu: &GpuSpec,
) -> Decomposition {
    // Per-expert sub-grids share one tile shape (demands depend only on the
    // launch config and hidden size), so adjacent expert runs merge into a
    // single group covering the whole grouped-GEMM grid.
    let mut task_groups = Vec::new();
    let grid_n = n.div_ceil(cfg.block_n);
    for &m_e in expert_tokens {
        if m_e == 0 {
            continue;
        }
        let grid_m = m_e.div_ceil(cfg.block_m);
        let tensor_ops = 2.0 * cfg.block_m as f64 * cfg.block_n as f64 * h as f64;
        // routing gather indices + accumulate/convert epilogue
        let fma_ops = cfg.block_m as f64 * cfg.block_n as f64 + cfg.block_m as f64 * 2.0;
        let bytes_load = (cfg.block_m as f64 + cfg.block_n as f64) * h as f64 * 2.0
            + cfg.block_m as f64 * 4.0; // sorted token ids
        let bytes_store = cfg.block_m as f64 * cfg.block_n as f64 * 2.0;
        let task = Task {
            tensor_ops,
            fma_ops,
            xu_ops: 0.0,
            bytes_load,
            bytes_store,
            bytes_smem: 2.0 * bytes_load,
            cost_hint: tensor_ops,
        };
        TaskGroup::push_run(&mut task_groups, task, grid_m as u64 * grid_n as u64);
    }

    let cta = CtaResources {
        warps: cfg.num_warps,
        smem_bytes: smem_bytes(&cfg),
        regs_per_thread: if cfg.num_warps >= 8 { 128 } else { 192 },
    };

    // Compulsory traffic: routed activations + active expert weights + out.
    let routed: f64 = expert_tokens.iter().map(|&m| m as f64).sum();
    let active: f64 = expert_tokens.iter().filter(|&&m| m > 0).count() as f64;
    let min_dram_bytes =
        routed * h as f64 * 2.0 + active * n as f64 * h as f64 * 2.0 + routed * n as f64 * 2.0;

    Decomposition {
        task_groups,
        paradigm: Paradigm::HardwareRR,
        cta,
        tile: (cfg.block_m, cfg.block_n, cfg.block_k),
        pipes: vec![Pipe::Tensor],
        min_dram_bytes,
        pipeline_stages: cfg.num_stages,
    }
}

/// Route `m` tokens to `e` experts with `topk` choices each, with realistic
/// imbalance (softmax-router hot experts). Returns per-expert token counts
/// summing to m*topk.
pub fn route_tokens(m: u32, e: u32, topk: u32, rng: &mut crate::util::rng::Rng) -> Vec<u32> {
    // mild popularity skew: production routers are aux-loss balanced, so
    // hot/cold expert ratios stay small
    let mut weights: Vec<f64> = (0..e)
        .map(|i| 1.0 / (1.0 + i as f64).powf(0.08) * rng.range_f64(0.85, 1.18))
        .collect();
    let sum: f64 = weights.iter().sum();
    for w in &mut weights {
        *w /= sum;
    }
    let total = (m * topk) as f64;
    let mut counts: Vec<u32> = weights.iter().map(|w| (w * total) as u32).collect();
    let assigned: u32 = counts.iter().sum();
    let mut rem = (m * topk).saturating_sub(assigned);
    let mut i = 0usize;
    while rem > 0 {
        counts[i % e as usize] += 1;
        rem -= 1;
        i += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::util::rng::Rng;

    #[test]
    fn routing_conserves_tokens() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let (m, e, topk) = (rng.range_u32(2, 4096), rng.range_u32(8, 128), rng.range_u32(2, 8));
            let counts = route_tokens(m, e, topk, &mut rng);
            assert_eq!(counts.len(), e as usize);
            assert_eq!(counts.iter().sum::<u32>(), m * topk);
        }
    }

    #[test]
    fn grid_matches_routing() {
        let gpu = gpu_by_name("H800").unwrap();
        let cfg = MoeConfig { block_m: 64, block_n: 64, block_k: 64, num_stages: 3, num_warps: 4 };
        let experts = vec![100, 0, 65, 1];
        let d = decompose(1024, 2048, &experts, cfg, &gpu);
        let gn = 2048u32.div_ceil(64);
        let expect: u32 =
            experts.iter().filter(|&&m| m > 0).map(|&m| m.div_ceil(64) * gn).sum();
        assert_eq!(d.num_tasks() as u32, expect);
    }

    #[test]
    fn default_config_fits_hopper_but_squeezes_a40() {
        let a40 = gpu_by_name("A40").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let cfg = default_config(2048, &a40);
        assert!(config_valid(&cfg, &a40));
        // occupancy on A40 is strictly worse than on Hopper for the default
        let d_a40 = decompose(4096, 2048, &[2048], cfg, &a40);
        let d_h800 = decompose(4096, 2048, &[2048], cfg, &h800);
        let occ_a40 = d_a40.cta.occupancy(&a40);
        let occ_h800 = d_h800.cta.occupancy(&h800);
        assert!(occ_a40 < occ_h800, "A40 occ {occ_a40} vs H800 {occ_h800}");
    }

    #[test]
    fn tuning_space_has_alternatives() {
        let space = tuning_space();
        assert!(space.len() >= 50);
        let a40 = gpu_by_name("A40").unwrap();
        assert!(space.iter().any(|c| config_valid(c, &a40) && c.num_stages == 2));
    }

    #[test]
    fn zero_token_experts_skipped() {
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = default_config(16, &gpu);
        let d = decompose(1024, 512, &[0, 0, 16, 0], cfg, &gpu);
        assert!(d.num_tasks() > 0);
        assert_eq!(d.num_tasks() as u32, 16u32.div_ceil(cfg.block_m) * 512u32.div_ceil(cfg.block_n));
    }
}
