//! FlashInfer attention decomposition (FA2 / FA3 variants, §IV-A).
//!
//! Open-source kernels: F is extracted from the parallelization strategy in
//! the source — one task per (request, query-head, query-tile). With causal
//! masking the effective KV extent differs per query tile, so tasks are NOT
//! uniform: this is the paper's key example of workload variance (Fig. 3 /
//! §VI-B discussion of FA2's higher max-SM error).
//!
//! FA2 launches a CTA per task (hardware scheduler); FA3 is a persistent
//! kernel whose MinHeap software scheduler balances tasks by estimated cost
//! (§V-A: "we accurately replicated its MinHeap-based scheduler logic").

use super::{CtaResources, Decomposition, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::GpuSpec;

/// Query-tile rows (Br) for prefill. FlashInfer uses 128-row tiles for
/// hd<=128 prefill; decode (single-query) kernels use 16-row MMA fragments.
pub const BR: u32 = 128;
pub const BR_DECODE: u32 = 16;

/// Tile rows for a request: decode-length queries take the decode kernel.
pub fn br_for(qlen: u32) -> u32 {
    if qlen < 64 {
        BR_DECODE
    } else {
        BR
    }
}

/// Coefficient alpha = 4 for FlashAttention (two chained matmuls, Eq. 3).
pub const ALPHA: f64 = 4.0;

/// Build the per-tile task for `rows` query rows attending to `kv_eff` keys.
fn attn_task(rows: u32, kv_eff: u32, hd: u32, br: u32) -> Task {
    let (rows, kv, hd) = (rows as f64, kv_eff as f64, hd as f64);
    // Q@K^T (2*rows*kv*hd) + P@V (2*rows*kv*hd) — MMA executes full Br tiles,
    // matching hardware counters; we count the nominal tile rows.
    let tensor_ops = ALPHA * br as f64 * kv * hd;
    // Online-softmax elementwise chain: scale, running-max update, rescale,
    // accumulate — ~5 FP32 ops per score + final O normalization.
    let fma_ops = 5.0 * rows * kv + rows * hd;
    // exp2 per score on the XU pipe.
    let xu_ops = rows * kv;
    // Loads: Q tile + K,V panels (bf16); stores: O tile (+ lse).
    let bytes_load = rows * hd * 2.0 + 2.0 * kv * hd * 2.0;
    let bytes_store = rows * hd * 2.0 + rows * 4.0;
    let bytes_smem = 2.0 * (2.0 * kv * hd * 2.0) + rows * hd * 2.0;
    Task {
        tensor_ops,
        fma_ops,
        xu_ops,
        bytes_load,
        bytes_store,
        bytes_smem,
        cost_hint: tensor_ops + 8.0 * bytes_load,
    }
}

/// Decompose a (possibly ragged) attention batch.
///
/// `batch` holds per-request (qlen, kvlen) with kvlen >= qlen (the KV cache
/// holds `kvlen - qlen` history tokens plus the current chunk).
pub fn decompose(
    batch: &[(u32, u32)],
    nh: u32,
    _nkv: u32,
    hd: u32,
    causal: bool,
    fa3: bool,
    _gpu: &GpuSpec,
) -> Decomposition {
    // All `nh` heads of one query tile share a task shape, so each tile is
    // one run of `nh` tasks; with causal masking the effective KV extent
    // differs per tile, so runs stay distinct along the query axis (the
    // paper's workload-variance example), while non-causal batches collapse
    // to one run per distinct (rows, kvlen).
    let mut task_groups = Vec::new();
    for &(qlen, kvlen) in batch {
        debug_assert!(kvlen >= qlen, "kv cache must cover the query chunk");
        let hist = kvlen - qlen;
        let br = br_for(qlen);
        let q_tiles = qlen.div_ceil(br).max(1);
        for qt in 0..q_tiles {
            let q_start = qt * br;
            let q_end = (q_start + br).min(qlen);
            let rows = q_end - q_start;
            // Causal: rows in this tile see history plus everything up to the
            // last query row of the tile.
            let kv_eff = if causal { (hist + q_end).min(kvlen) } else { kvlen };
            TaskGroup::push_run(
                &mut task_groups,
                attn_task(rows, kv_eff.max(1), hd, br),
                nh as u64,
            );
        }
    }

    // FA2: 4 warps, double-buffered K/V tiles in smem. FA3: warp-specialized
    // producer/consumer (8 warps), bigger smem footprint.
    let bc = 64u32; // KV tile columns staged in smem
    let smem = if fa3 {
        (BR * hd + 2 * 2 * bc * hd) * 2
    } else {
        (BR * hd + 2 * bc * hd) * 2
    };
    let cta = CtaResources {
        warps: if fa3 { 8 } else { 4 },
        smem_bytes: smem,
        regs_per_thread: 192,
    };

    // Compulsory traffic: Q and O once per head, K/V once per KV head.
    let min_dram_bytes: f64 = batch
        .iter()
        .map(|&(qlen, kvlen)| {
            2.0 * qlen as f64 * hd as f64 * nh as f64 * 2.0
                + 2.0 * kvlen as f64 * hd as f64 * _nkv as f64 * 2.0
        })
        .sum();

    Decomposition {
        task_groups,
        paradigm: if fa3 { Paradigm::MinHeap } else { Paradigm::HardwareRR },
        cta,
        tile: (BR, bc, hd),
        pipes: vec![Pipe::Tensor, Pipe::Xu],
        min_dram_bytes,
        pipeline_stages: 2, // double-buffered K/V tiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    fn gpu() -> crate::hw::GpuSpec {
        gpu_by_name("A100").unwrap()
    }

    #[test]
    fn task_count_is_batch_heads_qtiles() {
        let d = decompose(&[(512, 512), (300, 1000)], 8, 2, 128, true, false, &gpu());
        let tiles_r1 = 512u32.div_ceil(BR); // 4
        let tiles_r2 = 300u32.div_ceil(BR); // 3
        assert_eq!(d.num_tasks() as u32, (tiles_r1 + tiles_r2) * 8);
    }

    #[test]
    fn causal_tasks_grow_along_query() {
        let d = decompose(&[(512, 512)], 1, 1, 128, true, false, &gpu());
        let ops: Vec<f64> = d.iter_tasks().map(|t| t.tensor_ops).collect();
        // later query tiles attend to more KV -> strictly increasing work,
        // and hence one group per query tile
        assert!(ops.windows(2).all(|w| w[0] < w[1]), "{ops:?}");
        assert_eq!(d.num_groups(), 4);
    }

    #[test]
    fn non_causal_tasks_uniform() {
        let d = decompose(&[(512, 2048)], 2, 2, 128, false, false, &gpu());
        let first = d.task_groups[0].template.tensor_ops;
        assert!(d.iter_tasks().all(|t| (t.tensor_ops - first).abs() < 1e-9));
        // uniform tiles collapse into a single run
        assert_eq!(d.num_groups(), 1);
    }

    #[test]
    fn decode_single_token_attends_full_cache() {
        let d = decompose(&[(1, 4096)], 4, 1, 128, true, false, &gpu());
        assert_eq!(d.num_tasks(), 4);
        // kv_eff = kvlen for the last (only) token; decode uses 16-row tiles
        let expect = ALPHA * BR_DECODE as f64 * 4096.0 * 128.0;
        assert!((d.task_groups[0].template.tensor_ops - expect).abs() < 1e-6);
    }

    #[test]
    fn fa3_uses_minheap_and_more_warps() {
        let d2 = decompose(&[(1024, 1024)], 2, 2, 128, true, false, &gpu());
        let d3 = decompose(&[(1024, 1024)], 2, 2, 128, true, true, &gpu());
        assert_eq!(d2.paradigm, Paradigm::HardwareRR);
        assert_eq!(d3.paradigm, Paradigm::MinHeap);
        assert!(d3.cta.warps > d2.cta.warps);
        // same total math either way
        assert!((d2.total_tensor_ops() - d3.total_tensor_ops()).abs() < 1e-6);
    }

    #[test]
    fn alpha_is_four() {
        // one full-tile non-causal task: ops = 4 * Br * kv * hd
        let d = decompose(&[(128, 777)], 1, 1, 64, false, false, &gpu());
        let expect = 4.0 * 128.0 * 777.0 * 64.0;
        assert!((d.task_groups[0].template.tensor_ops - expect).abs() < 1e-6);
    }

    #[test]
    fn xu_demand_tracks_scores() {
        let d = decompose(&[(128, 1000)], 1, 1, 128, false, false, &gpu());
        assert!((d.task_groups[0].template.xu_ops - 128.0 * 1000.0).abs() < 1e-6);
    }
}
