//! FlashInfer SiLU-and-multiply decomposition (SwiGLU FFN activation):
//! out[s, d] = silu(x[s, d]) * x[s, d + dim]. One CTA per token row,
//! FP32 elementwise math (FMA pipe) + one exp per element (XU pipe).

use super::{CtaResources, Decomposition, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::GpuSpec;

pub fn decompose(seq: u32, dim: u32, _gpu: &GpuSpec) -> Decomposition {
    let d = dim as f64;
    // silu(x) = x * sigmoid(x): negate+exp handled by XU, add/div/mul on FMA,
    // plus the gating multiply — ~4 FP32 ops per output element.
    let fma_ops = 4.0 * d;
    let xu_ops = d; // one EX2 per element
    let bytes_load = 2.0 * d * 2.0; // gate + up halves, bf16
    let bytes_store = d * 2.0;
    let task = Task {
        tensor_ops: 0.0,
        fma_ops,
        xu_ops,
        bytes_load,
        bytes_store,
        bytes_smem: 0.0,
        cost_hint: fma_ops + 4.0 * bytes_load,
    };
    Decomposition {
        // one task per token row, all identical: a single run
        task_groups: vec![TaskGroup { template: task, count: seq as u64 }],
        paradigm: Paradigm::HardwareRR,
        cta: CtaResources { warps: (dim.div_ceil(2048)).clamp(1, 8), smem_bytes: 0, regs_per_thread: 32 },
        tile: (1, dim, 1),
        pipes: vec![Pipe::Fma, Pipe::Xu],
        // purely streaming: 2*dim read + dim written per row
        min_dram_bytes: 3.0 * seq as f64 * d * 2.0,
        pipeline_stages: 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn grid_and_demands() {
        let gpu = gpu_by_name("L20").unwrap();
        let d = decompose(1000, 13824, &gpu);
        assert_eq!(d.num_tasks(), 1000);
        let t = &d.task_groups[0].template;
        assert_eq!(t.tensor_ops, 0.0);
        assert!((t.xu_ops - 13824.0).abs() < 1e-9);
        // reads two halves, writes one
        assert!((t.bytes_load / t.bytes_store - 2.0).abs() < 1e-9);
    }

    #[test]
    fn xu_heavier_than_rmsnorm() {
        // SiLU&Mul exercises XU per element; RMSNorm only per row.
        let gpu = gpu_by_name("A100").unwrap();
        let s = decompose(64, 4096, &gpu);
        let r = super::super::rmsnorm::decompose(64, 4096, &gpu);
        let (st, rt) = (&s.task_groups[0].template, &r.task_groups[0].template);
        assert!(st.xu_ops > 50.0 * rt.xu_ops);
    }
}
