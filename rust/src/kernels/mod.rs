//! Kernel Decomposer (paper §IV-A): maps a kernel launch — input parameters
//! **X** plus hardware spec **S** — to the set of fundamental *tasks*
//! `{τ_i} = F(X, S)` (Eq. 1), the schedulable units of work for an SM.
//!
//! For conventional kernels a task is a CTA; for persistent kernels (cuBLAS
//! ping-pong GEMM on Hopper, FlashAttention-3) a task is the work-queue
//! packet a resident CTA fetches. Each kernel category implements its own
//! decomposition, mirroring the source-derived (or, for cuBLAS,
//! profile-inferred) mapping logic the paper describes; the per-task pipeline
//! demand formulas of §IV-C1/2 live alongside the decomposition because they
//! are kernel-specific (Eq. 3 coefficients, loop spaces, byte counts).

pub mod attention;
pub mod fused_moe;
pub mod gemm;
pub mod rmsnorm;
pub mod scaled_mm;
pub mod silu_mul;

use crate::hw::GpuSpec;

/// SM instruction pipelines modeled by the Feature Analyzer (Table III).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pipe {
    Tensor,
    Fma,
    Xu,
}

/// Element precision of the kernel's operands (Table V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    Fp32,
    Bf16,
    Fp8,
}

impl DType {
    pub fn bytes(&self) -> f64 {
        match self {
            DType::Fp32 => 4.0,
            DType::Bf16 => 2.0,
            DType::Fp8 => 1.0,
        }
    }
}

/// A fundamental task τ_i with its analytically derived pipeline demands.
///
/// `*_ops` are executed operations per math pipe (§IV-C1); byte counts are
/// the MIO demands (§IV-C2): `bytes_load` is data loaded from the memory
/// hierarchy (the critical path — loads feed the math pipes), `bytes_store`
/// the writeback, `bytes_smem` shared-memory traffic (staging both ways).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Task {
    pub tensor_ops: f64,
    pub fma_ops: f64,
    pub xu_ops: f64,
    pub bytes_load: f64,
    pub bytes_store: f64,
    pub bytes_smem: f64,
    /// Scheduler cost estimate (work-proportional), used by the MinHeap
    /// software scheduler and by the oracle as the base duration scale.
    pub cost_hint: f64,
}

impl Task {
    pub fn total_bytes(&self) -> f64 {
        self.bytes_load + self.bytes_store
    }
}

/// A run of `count` identical tasks in launch order — the run-length
/// encoding of the task set. Per-CTA work is overwhelmingly uniform (tile
/// kernels repeat one tile shape; elementwise kernels repeat one row task),
/// so most kernels decompose into 1–3 groups and the analytical pipeline
/// can aggregate in closed form over groups instead of walking tasks.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskGroup {
    pub template: Task,
    /// u64: grid dimensions are u32, so a 2-D grid's CTA count can exceed
    /// u32 — the closed-form pipeline handles such grids without ever
    /// materializing them.
    pub count: u64,
}

impl TaskGroup {
    /// Append a run of `count` copies of `template`, merging into the last
    /// group when the template is identical. Merging only adjacent runs
    /// preserves launch order, so [`Decomposition::iter_tasks`] reproduces
    /// the exact pre-grouping task sequence.
    pub fn push_run(groups: &mut Vec<TaskGroup>, template: Task, count: u64) {
        if count == 0 {
            return;
        }
        if let Some(last) = groups.last_mut() {
            if last.template == template {
                last.count += count;
                return;
            }
        }
        groups.push(TaskGroup { template, count });
    }
}

/// How tasks reach SMs (paper §IV-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Paradigm {
    /// GigaThread engine: round-robin, retire-driven (conventional kernels).
    HardwareRR,
    /// Persistent kernel with a software tile scheduler (cuBLAS ping-pong).
    PersistentTile,
    /// Persistent kernel with FlashInfer FA3's MinHeap load balancer.
    MinHeap,
}

/// Per-CTA resource footprint — the occupancy inputs of the hardware
/// scheduler (registers, shared memory, warp slots).
#[derive(Debug, Clone, Copy)]
pub struct CtaResources {
    pub warps: u32,
    pub smem_bytes: u32,
    pub regs_per_thread: u32,
}

impl CtaResources {
    /// Max concurrent CTAs per SM under the resource limits of `gpu`.
    pub fn occupancy(&self, gpu: &GpuSpec) -> u32 {
        let by_warps = gpu.max_warps_per_sm / self.warps.max(1);
        let by_smem = if self.smem_bytes == 0 {
            gpu.max_ctas_per_sm
        } else {
            (gpu.smem_kb_sm * 1024) / self.smem_bytes
        };
        let regs_per_cta = self.regs_per_thread * self.warps * 32;
        let by_regs = if regs_per_cta == 0 {
            gpu.max_ctas_per_sm
        } else {
            (gpu.regfile_kb_sm * 1024 / 4) / regs_per_cta
        };
        by_warps
            .min(by_smem)
            .min(by_regs)
            .min(gpu.max_ctas_per_sm)
            .max(1)
    }
}

/// Output of the Kernel Decomposer: the task set plus execution metadata the
/// Scheduling Simulator and the oracle need.
#[derive(Debug, Clone)]
pub struct Decomposition {
    /// Run-length-encoded task set {τ_i}, in launch order. The analytical
    /// pipeline (schedule → features) aggregates over these groups in
    /// closed form; the oracle's dynamic simulation expands them on demand
    /// via [`iter_tasks`](Self::iter_tasks).
    pub task_groups: Vec<TaskGroup>,
    pub paradigm: Paradigm,
    pub cta: CtaResources,
    /// Uniform tile geometry (tile_M, tile_N, tile_K) where applicable —
    /// drives MXU-utilization curves in the oracle.
    pub tile: (u32, u32, u32),
    /// Which math pipes this kernel exercises (Table V "Math Pipe" column).
    pub pipes: Vec<Pipe>,
    /// Compulsory off-chip traffic: each distinct operand/result byte moved
    /// once. This is the *valid* DRAM lower bound for the theoretical roof
    /// (summed per-task loads overcount reuse that L2 absorbs — exactly the
    /// overestimate that sinks the naive Roofline baseline on H800, §VI-C).
    pub min_dram_bytes: f64,
    /// Software pipelining depth (smem staging buffers / async-copy stages).
    pub pipeline_stages: u32,
}

impl Decomposition {
    pub fn num_tasks(&self) -> usize {
        self.task_groups.iter().map(|g| g.count as usize).sum()
    }

    pub fn num_groups(&self) -> usize {
        self.task_groups.len()
    }

    /// Expand the run-length groups back to the per-task view, in launch
    /// order. The oracle's per-task simulation and the grouped↔materialized
    /// equivalence tests consume this; the analytical hot path never does.
    pub fn iter_tasks(&self) -> impl Iterator<Item = &Task> + '_ {
        self.task_groups
            .iter()
            .flat_map(|g| std::iter::repeat_n(&g.template, g.count as usize))
    }

    /// Closed-form sum of an additive per-task metric over the whole task
    /// set: Σ_g count_g · metric(template_g). All per-task demands are
    /// exactly representable integer-valued f64s (products of launch
    /// geometry), so this is bit-identical to element-wise summation.
    pub fn group_sum(&self, metric: impl Fn(&Task) -> f64) -> f64 {
        self.task_groups.iter().map(|g| g.count as f64 * metric(&g.template)).sum()
    }

    pub fn total_tensor_ops(&self) -> f64 {
        self.group_sum(|t| t.tensor_ops)
    }

    pub fn total_bytes(&self) -> f64 {
        self.group_sum(|t| t.total_bytes())
    }
}

/// Fused-MoE Triton launch configuration (§VII tuning space).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MoeConfig {
    pub block_m: u32,
    pub block_n: u32,
    pub block_k: u32,
    pub num_stages: u32,
    pub num_warps: u32,
}

/// Kernel launch description — the model input parameters **X** (§IV-A).
/// Hashable/comparable: it is pure launch geometry (no floats), which makes
/// it usable directly in the engine's analysis-cache key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum KernelConfig {
    /// cuBLAS GEMM: C[M,N] = A[M,K] @ B[K,N].
    Gemm { m: u32, n: u32, k: u32, dtype: DType },
    /// vLLM CUTLASS FP8 blockwise-quantized scaled matmul.
    ScaledMm { m: u32, n: u32, k: u32 },
    /// FlashInfer attention (prefill or decode), FA2 or FA3 variant.
    Attention {
        batch: Vec<(u32, u32)>, // per-request (qlen, kvlen), kvlen >= qlen
        nh: u32,
        nkv: u32,
        hd: u32,
        causal: bool,
        fa3: bool,
    },
    /// FlashInfer fused RMSNorm over [seq, dim].
    RmsNorm { seq: u32, dim: u32 },
    /// FlashInfer SiLU-and-multiply over [seq, 2*dim] -> [seq, dim].
    SiluMul { seq: u32, dim: u32 },
    /// SGLang Triton fused-MoE grouped GEMM (w13 projection shape):
    /// `m` tokens routed to `e` experts with `topk`, hidden `h`, out `n`.
    FusedMoe {
        m: u32,
        e: u32,
        topk: u32,
        h: u32,
        n: u32,
        /// per-expert token counts (routing result), len == e
        expert_tokens: Vec<u32>,
        cfg: MoeConfig,
    },
}

/// Kernel category identifiers — one Performance-Estimator MLP is trained
/// per category (§IV-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    Gemm,
    ScaledMm,
    Attention,
    RmsNorm,
    SiluMul,
    FusedMoe,
}

impl KernelKind {
    pub const ALL: [KernelKind; 6] = [
        KernelKind::Gemm,
        KernelKind::ScaledMm,
        KernelKind::Attention,
        KernelKind::RmsNorm,
        KernelKind::SiluMul,
        KernelKind::FusedMoe,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Gemm => "gemm",
            KernelKind::ScaledMm => "scaled_mm",
            KernelKind::Attention => "attention",
            KernelKind::RmsNorm => "rmsnorm",
            KernelKind::SiluMul => "silu_mul",
            KernelKind::FusedMoe => "fused_moe",
        }
    }

    pub fn from_name(s: &str) -> Option<KernelKind> {
        Self::ALL.into_iter().find(|k| k.name() == s)
    }
}

impl KernelConfig {
    pub fn kind(&self) -> KernelKind {
        match self {
            KernelConfig::Gemm { .. } => KernelKind::Gemm,
            KernelConfig::ScaledMm { .. } => KernelKind::ScaledMm,
            KernelConfig::Attention { .. } => KernelKind::Attention,
            KernelConfig::RmsNorm { .. } => KernelKind::RmsNorm,
            KernelConfig::SiluMul { .. } => KernelKind::SiluMul,
            KernelConfig::FusedMoe { .. } => KernelKind::FusedMoe,
        }
    }

    /// The mapping function F(X, S) — dispatch to the per-category
    /// decomposer (Eq. 1).
    pub fn decompose(&self, gpu: &GpuSpec) -> Decomposition {
        match self {
            KernelConfig::Gemm { m, n, k, dtype } => gemm::decompose(*m, *n, *k, *dtype, gpu),
            KernelConfig::ScaledMm { m, n, k } => scaled_mm::decompose(*m, *n, *k, gpu),
            KernelConfig::Attention { batch, nh, nkv, hd, causal, fa3 } => {
                attention::decompose(batch, *nh, *nkv, *hd, *causal, *fa3, gpu)
            }
            KernelConfig::RmsNorm { seq, dim } => rmsnorm::decompose(*seq, *dim, gpu),
            KernelConfig::SiluMul { seq, dim } => silu_mul::decompose(*seq, *dim, gpu),
            KernelConfig::FusedMoe { h, n, expert_tokens, cfg, .. } => {
                fused_moe::decompose(*h, *n, expert_tokens, *cfg, gpu)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn occupancy_respects_all_limits() {
        let a100 = gpu_by_name("A100").unwrap();
        // warp-limited: 16 warps per CTA, 64 slots -> 4
        let cta = CtaResources { warps: 16, smem_bytes: 0, regs_per_thread: 32 };
        assert_eq!(cta.occupancy(&a100), 4);
        // smem-limited: 82KB per CTA on 164KB SM -> 2
        let cta = CtaResources { warps: 4, smem_bytes: 82 * 1024, regs_per_thread: 32 };
        assert_eq!(cta.occupancy(&a100), 2);
        // never zero
        let cta = CtaResources { warps: 64, smem_bytes: 300 * 1024, regs_per_thread: 255 };
        assert_eq!(cta.occupancy(&a100), 1);
    }

    #[test]
    fn kind_name_roundtrip() {
        for k in KernelKind::ALL {
            assert_eq!(KernelKind::from_name(k.name()), Some(k));
        }
        assert_eq!(KernelKind::from_name("bogus"), None);
    }
}
