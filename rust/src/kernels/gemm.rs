//! cuBLAS GEMM decomposition (closed-source — the mapping function F is
//! inferred empirically, §IV-A / §V-A).
//!
//! The paper profiles cuBLAS across (M, N, K) and reverse-engineers the tile
//! selection per architecture; on unseen GPUs it reuses the logic of the
//! most architecturally similar profiled GPU. We encode the inferred
//! heuristic directly as per-architecture candidate tables ("gemm8" on
//! Ampere/Ada, persistent "gemm9" on Hopper/Blackwell — the two kernel
//! implementations validated in Table VII).

use super::{CtaResources, Decomposition, DType, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::{Arch, GpuSpec};

/// Candidate output tiles (tile_M, tile_N), largest first. The inferred
/// cuBLAS policy prefers the biggest tile that still produces enough CTAs to
/// occupy the device.
fn tile_candidates(arch: Arch) -> &'static [(u32, u32)] {
    match arch {
        // gemm9-style persistent kernels favour large ping-pong tiles.
        Arch::Hopper | Arch::Blackwell => {
            &[(256, 128), (128, 256), (128, 128), (128, 64), (64, 128), (64, 64)]
        }
        Arch::Ampere | Arch::Ada => {
            &[(128, 256), (256, 128), (128, 128), (128, 64), (64, 128), (64, 64), (64, 32)]
        }
    }
}

fn tile_k(arch: Arch, dtype: DType) -> u32 {
    let base = match arch {
        Arch::Hopper | Arch::Blackwell => 64,
        _ => 32,
    };
    if dtype == DType::Fp8 {
        base * 2
    } else {
        base
    }
}

/// Inferred tile selection: largest candidate tile whose grid still covers
/// every SM at least once; falls back to the smallest candidate for tiny
/// problems.
pub fn select_tile(m: u32, n: u32, gpu: &GpuSpec) -> (u32, u32) {
    let cands = tile_candidates(gpu.arch);
    for &(tm, tn) in cands {
        let tiles = (m.div_ceil(tm) as u64) * (n.div_ceil(tn) as u64);
        if tiles >= gpu.num_sms as u64 {
            return (tm, tn);
        }
    }
    *cands.last().unwrap()
}

pub fn decompose(m: u32, n: u32, k: u32, dtype: DType, gpu: &GpuSpec) -> Decomposition {
    let (tm, tn) = select_tile(m, n, gpu);
    let tk = tile_k(gpu.arch, dtype);
    let grid_m = m.div_ceil(tm);
    let grid_n = n.div_ceil(tn);
    let eb = dtype.bytes();
    let out_b = 2.0; // bf16/fp16 outputs

    // Per-task demands (uniform — edge tiles still execute full MMA shapes,
    // matching what NCU counts on padded tiles).
    let tensor_ops = 2.0 * tm as f64 * tn as f64 * k as f64; // alpha = 2 (Eq. 3)
    let fma_ops = tm as f64 * tn as f64; // epilogue alpha*acc + beta*C
    let bytes_load = (tm as f64 + tn as f64) * k as f64 * eb;
    let bytes_store = tm as f64 * tn as f64 * out_b;
    // A/B staged through shared memory: write + read.
    let bytes_smem = 2.0 * bytes_load;

    let task = Task {
        tensor_ops,
        fma_ops,
        xu_ops: 0.0,
        bytes_load,
        bytes_store,
        bytes_smem,
        cost_hint: tensor_ops,
    };
    // uniform tile grid: the whole CTA set is one run
    let task_groups =
        vec![TaskGroup { template: task, count: grid_m as u64 * grid_n as u64 }];

    let persistent = matches!(gpu.arch, Arch::Hopper | Arch::Blackwell);
    // Deepest pipeline (up to 4 stages) that still fits shared memory.
    let max_stages: u32 = if persistent { 4 } else { 3 };
    let stage_bytes = (tm + tn) * tk * eb as u32;
    let num_stages = (gpu.smem_kb_sm * 1024 / stage_bytes).clamp(2, max_stages);
    let cta = CtaResources {
        warps: if tm * tn >= 128 * 128 { 8 } else { 4 },
        smem_bytes: num_stages * stage_bytes,
        regs_per_thread: 224,
    };

    // Compulsory traffic: A and B read once, C written once.
    let min_dram_bytes =
        (m as f64 * k as f64 + n as f64 * k as f64) * eb + m as f64 * n as f64 * out_b;

    Decomposition {
        task_groups,
        paradigm: if persistent { Paradigm::PersistentTile } else { Paradigm::HardwareRR },
        cta,
        tile: (tm, tn, tk),
        pipes: vec![Pipe::Tensor],
        min_dram_bytes,
        pipeline_stages: num_stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn grid_covers_problem() {
        let gpu = gpu_by_name("A100").unwrap();
        let d = decompose(4096, 4096, 4096, DType::Bf16, &gpu);
        let (tm, tn, _) = d.tile;
        let tiles = (4096u64.div_ceil(tm as u64)) * (4096u64.div_ceil(tn as u64));
        assert_eq!(d.num_tasks() as u64, tiles);
    }

    #[test]
    fn total_tensor_ops_cover_flops() {
        // Total MMA ops must be >= 2*M*N*K (padding can only add work).
        let gpu = gpu_by_name("H800").unwrap();
        let (m, n, k) = (1000, 2000, 512);
        let d = decompose(m, n, k, DType::Bf16, &gpu);
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        assert!(d.total_tensor_ops() >= flops);
        assert!(d.total_tensor_ops() < flops * 1.6, "padding overhead too big");
    }

    #[test]
    fn small_problems_use_small_tiles() {
        let gpu = gpu_by_name("A100").unwrap();
        let (tm, tn) = select_tile(128, 128, &gpu);
        assert!(tm * tn <= 64 * 64 * 4);
        let (tm2, tn2) = select_tile(131_072, 131_072, &gpu);
        assert!(tm2 * tn2 >= 128 * 256);
    }

    #[test]
    fn hopper_is_persistent_ampere_is_hw() {
        let h = gpu_by_name("H100").unwrap();
        let a = gpu_by_name("A100").unwrap();
        let cfg_h = decompose(8192, 8192, 1024, DType::Bf16, &h);
        let cfg_a = decompose(8192, 8192, 1024, DType::Bf16, &a);
        assert_eq!(cfg_h.paradigm, Paradigm::PersistentTile);
        assert_eq!(cfg_a.paradigm, Paradigm::HardwareRR);
    }

    #[test]
    fn demands_scale_with_k() {
        let gpu = gpu_by_name("A100").unwrap();
        let d1 = decompose(4096, 4096, 1024, DType::Bf16, &gpu);
        let d2 = decompose(4096, 4096, 2048, DType::Bf16, &gpu);
        let (t1, t2) = (&d1.task_groups[0].template, &d2.task_groups[0].template);
        assert!(t2.tensor_ops > 1.9 * t1.tensor_ops);
        assert!(t2.bytes_load > 1.9 * t1.bytes_load);
    }

    #[test]
    fn smem_fits_device() {
        for gpu in crate::hw::all_gpus() {
            let d = decompose(8192, 8192, 4096, DType::Bf16, &gpu);
            assert!(
                d.cta.smem_bytes <= gpu.smem_kb_sm * 1024,
                "{}: smem {} > {}",
                gpu.name,
                d.cta.smem_bytes,
                gpu.smem_kb_sm * 1024
            );
        }
    }
}
