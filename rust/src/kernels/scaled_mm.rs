//! vLLM CUTLASS FP8 blockwise-quantized Scaled-MM decomposition (W8A8,
//! §II-A). Tile structure mirrors the CUTLASS scaled-MM kernels [40]: FP8
//! operands with per-128-block scales applied in an FP32 epilogue.
//! Persistent (SW-scheduled) on Hopper+, hardware-scheduled before.

use super::{CtaResources, Decomposition, DType, Paradigm, Pipe, Task, TaskGroup};
use crate::hw::{Arch, GpuSpec};

const SCALE_BLOCK: u32 = 128;

pub fn decompose(m: u32, n: u32, k: u32, gpu: &GpuSpec) -> Decomposition {
    // FP8 kernels use the same macro-tile family as BF16 GEMM but with a
    // deeper K stage (FP8 bytes are half as wide).
    let (tm, tn) = super::gemm::select_tile(m, n, gpu);
    let tk = if matches!(gpu.arch, Arch::Hopper | Arch::Blackwell) { 128 } else { 64 };
    let grid_m = m.div_ceil(tm);
    let grid_n = n.div_ceil(tn);
    let eb = DType::Fp8.bytes();

    let tensor_ops = 2.0 * tm as f64 * tn as f64 * k as f64;
    // Epilogue: two scale multiplies + accumulate-convert per output element,
    // plus per-k-block rescale of the accumulator tile.
    let k_blocks = (k.div_ceil(SCALE_BLOCK)) as f64;
    let fma_ops = 3.0 * tm as f64 * tn as f64 + k_blocks * tm as f64 * tn as f64 / 16.0;
    let scale_bytes =
        k_blocks * (tm as f64 / SCALE_BLOCK as f64 + tn as f64 / SCALE_BLOCK as f64).max(2.0) * 4.0;
    let bytes_load = (tm as f64 + tn as f64) * k as f64 * eb + scale_bytes;
    let bytes_store = tm as f64 * tn as f64 * 2.0;
    let task = Task {
        tensor_ops,
        fma_ops,
        xu_ops: 0.0,
        bytes_load,
        bytes_store,
        bytes_smem: 2.0 * bytes_load,
        cost_hint: tensor_ops,
    };
    // uniform tile grid: the whole CTA set is one run
    let task_groups =
        vec![TaskGroup { template: task, count: grid_m as u64 * grid_n as u64 }];

    let persistent = matches!(gpu.arch, Arch::Hopper | Arch::Blackwell);
    let max_stages: u32 = if persistent { 4 } else { 3 };
    let stage_bytes = (tm + tn) * tk * eb as u32;
    let stages = (gpu.smem_kb_sm * 1024 / stage_bytes).clamp(2, max_stages);
    let cta = CtaResources {
        warps: 8,
        smem_bytes: stages * stage_bytes,
        regs_per_thread: 224,
    };

    let min_dram_bytes = (m as f64 * k as f64 + n as f64 * k as f64) * eb
        + m as f64 * n as f64 * 2.0
        + (m as f64 + n as f64) * (k as f64 / SCALE_BLOCK as f64) * 4.0;

    Decomposition {
        task_groups,
        paradigm: if persistent { Paradigm::PersistentTile } else { Paradigm::HardwareRR },
        cta,
        tile: (tm, tn, tk),
        pipes: vec![Pipe::Tensor],
        min_dram_bytes,
        pipeline_stages: stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn fp8_loads_half_of_bf16() {
        let gpu = gpu_by_name("H800").unwrap();
        let f8 = decompose(4096, 4096, 4096, &gpu);
        let bf = super::super::gemm::decompose(4096, 4096, 4096, DType::Bf16, &gpu);
        // same tile family -> FP8 A/B panels are ~half the bytes
        let ratio =
            f8.task_groups[0].template.bytes_load / bf.task_groups[0].template.bytes_load;
        assert!(ratio < 0.6, "ratio {ratio}");
    }

    #[test]
    fn persistent_on_hopper() {
        let h = gpu_by_name("H20").unwrap();
        assert_eq!(decompose(2048, 2048, 2048, &h).paradigm, Paradigm::PersistentTile);
        let a = gpu_by_name("A100").unwrap();
        assert_eq!(decompose(2048, 2048, 2048, &a).paradigm, Paradigm::HardwareRR);
    }

    #[test]
    fn epilogue_fma_present() {
        let gpu = gpu_by_name("H100").unwrap();
        let d = decompose(1024, 1024, 2048, &gpu);
        let t = &d.task_groups[0].template;
        assert!(t.fma_ops > 0.0);
        assert!(t.tensor_ops > 100.0 * t.fma_ops);
    }

    #[test]
    fn smem_fits_all_gpus() {
        for gpu in crate::hw::all_gpus() {
            let d = decompose(8192, 8192, 8192, &gpu);
            assert!(d.cta.smem_bytes <= gpu.smem_kb_sm * 1024, "{}", gpu.name);
        }
    }
}
