//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client via the
//! `xla` crate. This is the ONLY bridge between the rust request path and
//! the AOT-compiled JAX/Pallas model — python never runs at prediction time.
//!
//! Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Parsed `artifacts/manifest.json` — the packing/arg-order contract between
/// aot.py and this runtime.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub feature_dim: usize,
    pub theta_size: usize,
    pub bn_size: usize,
    pub fwd_batches: Vec<usize>,
    pub train_batch: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("read {:?}/manifest.json — run `make artifacts`", dir))?;
        let j = json::parse(&text)?;
        let usize_field = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .with_context(|| format!("manifest missing {k}"))
        };
        Ok(Manifest {
            feature_dim: usize_field("feature_dim")?,
            theta_size: usize_field("theta_size")?,
            bn_size: usize_field("bn_size")?,
            fwd_batches: j
                .get("fwd_batches")
                .and_then(Json::as_arr)
                .context("manifest missing fwd_batches")?
                .iter()
                .filter_map(Json::as_usize)
                .collect(),
            train_batch: usize_field("train_batch")?,
        })
    }
}

/// A compiled HLO executable plus convenience I/O.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with the given literals; unwraps the jax `return_tuple=True`
    /// output tuple into its elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Borrowed-argument variant: lets callers cache large constant inputs
    /// (e.g. the 200KB theta blob) across calls instead of re-encoding them
    /// — the main lever on the single-prediction hot path (§Perf).
    pub fn run_ref(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("execute {}", self.name))?;
        let lit = bufs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// PJRT CPU engine owning the client and the artifact directory.
pub struct Engine {
    client: xla::PjRtClient,
    pub dir: PathBuf,
    pub manifest: Manifest,
}

impl Engine {
    /// Create a CPU PJRT client over `artifacts_dir`.
    pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Engine> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Engine { client, dir, manifest })
    }

    /// Default artifacts location: $SYNPERF_ARTIFACTS or ./artifacts.
    pub fn from_env() -> Result<Engine> {
        let dir = std::env::var("SYNPERF_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Engine::new(dir)
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, file: &str) -> Result<Executable> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path:?} — run `make artifacts`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {file}"))?;
        Ok(Executable { exe, name: file.to_string() })
    }

    /// Read a raw little-endian f32 blob (init_theta.bin / init_bn.bin).
    pub fn read_f32_blob(&self, file: &str) -> Result<Vec<f32>> {
        let bytes = std::fs::read(self.dir.join(file))
            .with_context(|| format!("read blob {file}"))?;
        anyhow::ensure!(bytes.len() % 4 == 0, "blob {file} not f32-aligned");
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Build an f32 literal of the given shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::from(v)
}

/// PRNG key literal (uint32[2]) for the dropout stream.
pub fn lit_key(seed: u64) -> Result<xla::Literal> {
    let k = [(seed >> 32) as u32, seed as u32];
    Ok(xla::Literal::vec1(&k).reshape(&[2])?)
}

/// Extract a literal back into f32s.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
