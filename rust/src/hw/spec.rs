//! `GpuSpec` — the compact architectural parameter vector **S** (paper
//! Table II) for every evaluated GPU (paper Table VI). Headline numbers
//! (SMs, memory bandwidth, BF16 tensor throughput, clock) are taken directly
//! from Table VI; the remaining Table II parameters (L2 bandwidth, shared
//! memory size, occupancy limits, interconnect) are filled from the public
//! architecture whitepapers the paper cites [36]-[38],[44].

/// GPU micro-architecture generation (Ampere and later share the SM
/// organization SynPerf relies on — §II-B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    Ampere,
    Ada,
    Hopper,
    Blackwell,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Ampere => "Ampere",
            Arch::Ada => "Ada",
            Arch::Hopper => "Hopper",
            Arch::Blackwell => "Blackwell",
        }
    }

    /// Ordinal used when looking for the "most architecturally similar"
    /// sibling (closed-source decomposition fallback, §V-A).
    pub fn generation(&self) -> u32 {
        match self {
            Arch::Ampere => 0,
            Arch::Ada => 1,
            Arch::Hopper => 2,
            Arch::Blackwell => 3,
        }
    }
}

/// Architectural specification vector (Table II) + interconnect info used by
/// the communication model.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: &'static str,
    pub arch: Arch,
    /// CUDA compute capability (8.0 – 12.0).
    pub compute_capability: f64,
    pub num_sms: u32,
    pub sm_clock_mhz: f64,
    /// Dense BF16 MMA throughput, ops/cycle/SM (Table VI column).
    pub tensor_ops_clk_sm: f64,
    /// FP32 FMA pipe throughput, ops/cycle/SM.
    pub fma_ops_clk_sm: f64,
    /// XU (special function) pipe throughput, ops/cycle/SM.
    pub xu_ops_clk_sm: f64,
    /// Off-chip (HBM/GDDR) bandwidth, GB/s.
    pub dram_bw_gbs: f64,
    /// Aggregate L2 bandwidth, GB/s.
    pub l2_bw_gbs: f64,
    /// Shared-memory bandwidth per SM, bytes/cycle (128 across the board).
    pub smem_bw_byte_clk_sm: f64,
    /// Usable shared memory per SM, KiB.
    pub smem_kb_sm: u32,
    /// Register file per SM, KiB (256 across the board).
    pub regfile_kb_sm: u32,
    /// L2 cache size, MiB.
    pub l2_mb: f64,
    /// Occupancy ceilings.
    pub max_warps_per_sm: u32,
    pub max_ctas_per_sm: u32,
    /// FP8 MMA throughput multiplier over BF16 (2.0 on Hopper+, 1.0 before).
    pub fp8_tensor_mult: f64,
    /// Per-direction interconnect bandwidth for collectives, GB/s
    /// (NVLink where present, PCIe otherwise).
    pub interconnect_gbs: f64,
    /// Representative cloud rental rate, USD per GPU-hour — the cost
    /// column behind the sweep's `$ / 1M tokens` objective and the
    /// `max_usd_per_hour` procurement constraint.
    pub usd_per_hour: f64,
    /// Board power limit (TDP), watts.
    pub tdp_watts: f64,
    /// Whether the GPU is in the training ("seen") split of Table VI.
    pub seen: bool,
}

impl GpuSpec {
    /// Peak tensor-pipe throughput in ops/s.
    pub fn tensor_ops_per_sec(&self) -> f64 {
        self.num_sms as f64 * self.tensor_ops_clk_sm * self.sm_clock_mhz * 1e6
    }

    pub fn fma_ops_per_sec(&self) -> f64 {
        self.num_sms as f64 * self.fma_ops_clk_sm * self.sm_clock_mhz * 1e6
    }

    pub fn xu_ops_per_sec(&self) -> f64 {
        self.num_sms as f64 * self.xu_ops_clk_sm * self.sm_clock_mhz * 1e6
    }

    /// Clock period in seconds.
    pub fn cycle_sec(&self) -> f64 {
        1.0 / (self.sm_clock_mhz * 1e6)
    }

    /// DRAM bytes per GPU-cycle (whole chip).
    pub fn dram_bytes_per_cycle(&self) -> f64 {
        self.dram_bw_gbs * 1e9 / (self.sm_clock_mhz * 1e6)
    }

    pub fn l2_bytes_per_cycle(&self) -> f64 {
        self.l2_bw_gbs * 1e9 / (self.sm_clock_mhz * 1e6)
    }

    /// Compute-to-memory balance (BF16 ops per DRAM byte at peak) — the
    /// quantity behind the H20-vs-H800 roofline discussion in §VI-C.
    pub fn compute_mem_ratio(&self) -> f64 {
        self.tensor_ops_per_sec() / (self.dram_bw_gbs * 1e9)
    }
}

macro_rules! gpu {
    ($name:literal, $arch:expr, $cc:expr, $sms:expr, $clk:expr, $tensor:expr,
     $dram:expr, $l2bw:expr, $smem:expr, $l2mb:expr, $fp8:expr, $ic:expr,
     $usd:expr, $tdp:expr, $seen:expr) => {
        GpuSpec {
            name: $name,
            arch: $arch,
            compute_capability: $cc,
            num_sms: $sms,
            sm_clock_mhz: $clk,
            tensor_ops_clk_sm: $tensor,
            fma_ops_clk_sm: 128.0,
            xu_ops_clk_sm: 16.0,
            dram_bw_gbs: $dram,
            l2_bw_gbs: $l2bw,
            smem_bw_byte_clk_sm: 128.0,
            smem_kb_sm: $smem,
            regfile_kb_sm: 256,
            l2_mb: $l2mb,
            max_warps_per_sm: if matches!($arch, Arch::Ampere) && $cc > 8.05 { 48 } else { 64 },
            max_ctas_per_sm: if matches!($arch, Arch::Hopper) { 32 } else { 24 },
            fp8_tensor_mult: $fp8,
            interconnect_gbs: $ic,
            usd_per_hour: $usd,
            tdp_watts: $tdp,
            seen: $seen,
        }
    };
}

/// The 11 GPUs of Table VI. First six are the training ("seen") group.
pub fn all_gpus() -> Vec<GpuSpec> {
    vec![
        //    name             arch            cc    SMs  clk    tensor dram   l2bw   smem l2mb fp8  ic    $/hr tdpW  seen
        gpu!("A40",            Arch::Ampere,   8.6,  84,  1740.0, 1024.0, 696.0, 2430.0, 100, 6.0, 1.0, 32.0, 0.8, 300.0, true),
        gpu!("A100",           Arch::Ampere,   8.0,  108, 1410.0, 2048.0, 2039.0, 4500.0, 164, 40.0, 1.0, 300.0, 1.9, 400.0, true),
        gpu!("RTX 6000 Ada",   Arch::Ada,      8.9,  142, 2505.0, 1024.0, 960.0, 4800.0, 100, 96.0, 1.0, 32.0, 1.1, 300.0, true),
        gpu!("L20",            Arch::Ada,      8.9,  92,  2520.0, 516.0,  864.0, 3100.0, 100, 96.0, 1.0, 32.0, 0.9, 275.0, true),
        gpu!("H20",            Arch::Hopper,   9.0,  78,  1830.0, 1024.0, 4023.0, 5200.0, 228, 60.0, 2.0, 450.0, 1.5, 400.0, true),
        gpu!("H800",           Arch::Hopper,   9.0,  132, 1830.0, 4096.0, 3352.0, 8000.0, 228, 50.0, 2.0, 200.0, 2.8, 700.0, true),
        gpu!("RTX A6000",      Arch::Ampere,   8.6,  84,  1800.0, 1024.0, 768.0, 2500.0, 100, 6.0, 1.0, 32.0, 0.7, 300.0, false),
        gpu!("L40",            Arch::Ada,      8.9,  142, 2490.0, 512.0,  864.0, 4700.0, 100, 96.0, 1.0, 32.0, 1.0, 300.0, false),
        gpu!("H100",           Arch::Hopper,   9.0,  132, 1830.0, 4096.0, 3352.0, 8000.0, 228, 50.0, 2.0, 450.0, 2.5, 700.0, false),
        gpu!("H200",           Arch::Hopper,   9.0,  132, 1830.0, 4096.0, 4917.0, 9500.0, 228, 50.0, 2.0, 450.0, 3.5, 700.0, false),
        gpu!("RTX PRO 6000 S", Arch::Blackwell, 12.0, 188, 2340.0, 1024.0, 1792.0, 10400.0, 128, 128.0, 2.0, 64.0, 1.8, 600.0, false),
    ]
}

pub fn seen_gpus() -> Vec<GpuSpec> {
    all_gpus().into_iter().filter(|g| g.seen).collect()
}

pub fn unseen_gpus() -> Vec<GpuSpec> {
    all_gpus().into_iter().filter(|g| !g.seen).collect()
}

/// The lookup key behind [`gpu_by_name`]: case- and separator-insensitive,
/// so `"rtx_6000_ada"`, `"RTX 6000 Ada"` and `"rtx-6000-ada"` all hit the
/// same registry entry.
fn normalize(name: &str) -> String {
    name.to_lowercase().replace([' ', '_', '-'], "")
}

pub fn gpu_by_name(name: &str) -> Option<GpuSpec> {
    let want = normalize(name);
    all_gpus().into_iter().find(|g| normalize(g.name) == want)
}

/// Levenshtein distance between two normalized name keys — small enough
/// strings (≤ 16 chars) that the O(|a|·|b|) DP is trivially cheap.
fn edit_distance(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    for (i, ca) in a.iter().enumerate() {
        let mut cur = vec![i + 1];
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur.push(sub.min(prev[j + 1] + 1).min(cur[j] + 1));
        }
        prev = cur;
    }
    prev[b.len()]
}

/// The `k` registry names nearest to `name` under edit distance over
/// normalized keys, ties broken by registry (Table VI) order — the
/// suggestion list behind the `unknown_gpu` error detail on the CLI and
/// wire paths.
pub fn nearest_names(name: &str, k: usize) -> Vec<&'static str> {
    let want = normalize(name);
    let mut scored: Vec<(usize, usize, &'static str)> = all_gpus()
        .iter()
        .enumerate()
        .map(|(i, g)| (edit_distance(&want, &normalize(g.name)), i, g.name))
        .collect();
    scored.sort_by_key(|&(d, i, _)| (d, i));
    scored.into_iter().take(k).map(|(_, _, n)| n).collect()
}

/// The most architecturally similar *seen* GPU — used for closed-source
/// kernel decomposition on unseen hardware (§V-A) and by the Habitat
/// baseline as its local reference device.
pub fn nearest_seen(gpu: &GpuSpec) -> GpuSpec {
    let seen = seen_gpus();
    seen.iter()
        .min_by_key(|s| {
            let gen_gap = (s.arch.generation() as i64 - gpu.arch.generation() as i64).abs();
            let sm_gap = (s.num_sms as i64 - gpu.num_sms as i64).abs();
            gen_gap * 1_000 + sm_gap
        })
        .cloned()
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eleven_gpus_six_seen() {
        let all = all_gpus();
        assert_eq!(all.len(), 11);
        assert_eq!(seen_gpus().len(), 6);
        assert_eq!(unseen_gpus().len(), 5);
    }

    #[test]
    fn table_vi_headline_numbers() {
        let a100 = gpu_by_name("A100").unwrap();
        assert_eq!(a100.num_sms, 108);
        assert_eq!(a100.dram_bw_gbs, 2039.0);
        assert_eq!(a100.tensor_ops_clk_sm, 2048.0);
        assert_eq!(a100.sm_clock_mhz, 1410.0);
        let h20 = gpu_by_name("H20").unwrap();
        assert_eq!(h20.num_sms, 78);
        assert_eq!(h20.dram_bw_gbs, 4023.0);
        let pro = gpu_by_name("RTX PRO 6000 S").unwrap();
        assert_eq!(pro.arch, Arch::Blackwell);
        assert_eq!(pro.num_sms, 188);
    }

    #[test]
    fn table_ii_ranges_hold() {
        for g in all_gpus() {
            assert!((8.0..=12.0).contains(&g.compute_capability), "{}", g.name);
            assert!((78..=188).contains(&g.num_sms), "{}", g.name);
            assert!((1410.0..=2520.0).contains(&g.sm_clock_mhz), "{}", g.name);
            assert!((512.0..=4096.0).contains(&g.tensor_ops_clk_sm), "{}", g.name);
            assert!((696.0..=4917.0).contains(&g.dram_bw_gbs), "{}", g.name);
            assert!((2430.0..=10400.0).contains(&g.l2_bw_gbs), "{}", g.name);
            assert_eq!(g.smem_bw_byte_clk_sm, 128.0, "{}", g.name);
            assert!((100..=228).contains(&g.smem_kb_sm), "{}", g.name);
            assert_eq!(g.regfile_kb_sm, 256, "{}", g.name);
            assert_eq!(g.xu_ops_clk_sm, 16.0, "{}", g.name);
        }
    }

    #[test]
    fn h20_vs_h800_balance() {
        // The §VI-C discussion: H20 keeps ~120% of H800's bandwidth but only
        // ~15-25% of its compute -> much lower compute-to-memory ratio.
        let h20 = gpu_by_name("H20").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        assert!(h20.dram_bw_gbs > h800.dram_bw_gbs);
        assert!(h20.compute_mem_ratio() < 0.3 * h800.compute_mem_ratio());
    }

    #[test]
    fn name_lookup_is_fuzzy() {
        assert!(gpu_by_name("rtx_6000_ada").is_some());
        assert!(gpu_by_name("h100").is_some());
        assert!(gpu_by_name("nope").is_none());
    }

    #[test]
    fn nearest_seen_prefers_same_generation() {
        let h100 = gpu_by_name("H100").unwrap();
        let near = nearest_seen(&h100);
        assert_eq!(near.arch, Arch::Hopper);
        assert_eq!(near.name, "H800"); // same SM count
        let a6000 = gpu_by_name("RTX A6000").unwrap();
        assert_eq!(nearest_seen(&a6000).arch, Arch::Ampere);
    }

    #[test]
    fn derived_quantities_positive() {
        for g in all_gpus() {
            assert!(g.tensor_ops_per_sec() > 0.0);
            assert!(g.dram_bytes_per_cycle() > 0.0);
            assert!(g.cycle_sec() > 0.0 && g.cycle_sec() < 1e-8);
        }
    }

    #[test]
    fn registry_invariants_hold() {
        // every rate and clock strictly positive — a zero here would turn
        // into an Inf/NaN latency deep inside the rooflines
        for g in all_gpus() {
            assert!(g.sm_clock_mhz > 0.0, "{}", g.name);
            assert!(g.tensor_ops_clk_sm > 0.0, "{}", g.name);
            assert!(g.fma_ops_clk_sm > 0.0, "{}", g.name);
            assert!(g.xu_ops_clk_sm > 0.0, "{}", g.name);
            assert!(g.dram_bw_gbs > 0.0, "{}", g.name);
            assert!(g.l2_bw_gbs > 0.0, "{}", g.name);
            assert!(g.smem_bw_byte_clk_sm > 0.0, "{}", g.name);
            assert!(g.interconnect_gbs > 0.0, "{}", g.name);
            assert!(g.fp8_tensor_mult >= 1.0, "{}", g.name);
            assert!(g.num_sms > 0 && g.max_warps_per_sm > 0 && g.max_ctas_per_sm > 0);
        }
    }

    #[test]
    fn cost_and_power_columns_are_sane() {
        // rental rates and TDPs feed the sweep's $/Mtok objective and
        // budget constraints — a zero or wild value would poison every row
        for g in all_gpus() {
            assert!(
                (0.1..=10.0).contains(&g.usd_per_hour),
                "{}: usd_per_hour {}",
                g.name,
                g.usd_per_hour
            );
            assert!(
                (200.0..=1000.0).contains(&g.tdp_watts),
                "{}: tdp_watts {}",
                g.name,
                g.tdp_watts
            );
        }
        // flagship parts rent above the workstation parts
        let h100 = gpu_by_name("H100").unwrap();
        let a40 = gpu_by_name("A40").unwrap();
        assert!(h100.usd_per_hour > a40.usd_per_hour);
        assert!(h100.tdp_watts > a40.tdp_watts);
    }

    #[test]
    fn normalized_names_are_unique() {
        // gpu_by_name keys on the normalized form; a collision would make
        // one registry entry unreachable
        let mut keys: Vec<String> = all_gpus().iter().map(|g| normalize(g.name)).collect();
        keys.sort();
        let before = keys.len();
        keys.dedup();
        assert_eq!(keys.len(), before, "normalized registry names must be unique");
    }

    #[test]
    fn seen_unseen_partition_the_registry() {
        let all: Vec<&str> = all_gpus().iter().map(|g| g.name).collect();
        let mut split: Vec<&str> = seen_gpus().iter().map(|g| g.name).collect();
        split.extend(unseen_gpus().iter().map(|g| g.name));
        // all_gpus lists the seen group first, so the concatenation must
        // reproduce the registry exactly — no overlap, nothing dropped
        assert_eq!(all, split, "seen/unseen must partition all_gpus in order");
    }

    #[test]
    fn nearest_seen_lands_in_the_seen_split_for_every_unseen_gpu() {
        for g in unseen_gpus() {
            let near = nearest_seen(&g);
            assert!(near.seen, "nearest_seen({}) returned unseen {}", g.name, near.name);
        }
    }

    #[test]
    fn nearest_names_ranks_by_edit_distance_then_registry_order() {
        // "B300": distance 2 to A100/H800/H100/H200, 3 to A40/L20/H20/L40 —
        // the top 3 follow Table VI order among the distance-2 ties
        assert_eq!(nearest_names("B300", 3), vec!["A100", "H800", "H100"]);
        // exact (normalized) matches rank themselves first
        assert_eq!(nearest_names("h800", 1), vec!["H800"]);
        assert_eq!(nearest_names("rtx_6000_ada", 1), vec!["RTX 6000 Ada"]);
        // k larger than the registry just returns everything
        assert_eq!(nearest_names("A100", 99).len(), 11);
    }
}
