//! GPU hardware model: the architectural specification vector `S` of
//! Table II, instantiated for the 11 GPUs of Table VI, plus the
//! seen/unseen split used throughout the evaluation.

mod spec;

pub use spec::*;
