//! AMALI-style stand-in (paper [6], Fig. 7): an instruction-trace-based
//! analytical model. For each task it synthesizes the interleaved SASS-level
//! instruction stream (async copies, MMA groups, epilogue FMA) and walks it
//! with interval analysis — issue-width constraints, dependency stalls,
//! memory-latency windows — at per-instruction granularity.
//!
//! Deliberately detailed and therefore *slow* (the Fig. 7 trade-off): cost
//! grows with the instruction count, not the tile count. Accuracy is
//! mid-range: it models the SM interior well but has no dynamic scheduling,
//! no L2 reuse model, and fixed friction constants.

use crate::hw::GpuSpec;
use crate::kernels::{DType, KernelConfig};

/// One synthesized instruction: (pipe, latency, issue cycles).
#[derive(Clone, Copy)]
enum Inst {
    Mma,
    LoadGlobal,
    LoadShared,
    Fma,
    Sync,
}

/// Interval-walk one task's instruction stream; returns cycles.
fn walk(insts: &[Inst], gpu: &GpuSpec) -> f64 {
    // per-pipe next-available cycle
    let mut t_issue = 0.0f64; // warp scheduler front
    let mut t_mma = 0.0f64;
    let mut t_mem = 0.0f64;
    let mut last_dep = 0.0f64;
    // instruction-class costs (SASS-level approximations)
    let mma_cycles = 16.0; // one HMMA group on a 16x8x16 fragment
    let ldg_latency = 450.0;
    let lds_latency = 25.0;
    let fma_cycles = 4.0;
    for inst in insts {
        t_issue += 1.0; // single-issue front end
        match inst {
            Inst::Mma => {
                let start = t_issue.max(t_mma).max(last_dep);
                t_mma = start + mma_cycles;
                last_dep = start; // pipelined MMAs overlap
            }
            Inst::LoadGlobal => {
                let start = t_issue.max(t_mem);
                t_mem = start + 4.0;
                last_dep = last_dep.max(start + ldg_latency / 8.0); // 8 in flight
            }
            Inst::LoadShared => {
                let start = t_issue.max(t_mem);
                t_mem = start + 2.0;
                last_dep = last_dep.max(start + lds_latency / 4.0);
            }
            Inst::Fma => {
                let start = t_issue.max(last_dep);
                last_dep = start + fma_cycles / 2.0;
            }
            Inst::Sync => {
                let barrier = t_issue.max(t_mma).max(t_mem).max(last_dep);
                t_issue = barrier;
                last_dep = barrier;
            }
        }
    }
    // drain
    t_issue.max(t_mma).max(t_mem).max(last_dep) * scale_for(gpu)
}

fn scale_for(gpu: &GpuSpec) -> f64 {
    // calibration constant vs. an idealized SM — fixed across shapes, which
    // is exactly why the model's error is shape-dependent
    256.0 / gpu.tensor_ops_clk_sm.max(256.0) * 0.9 + 0.35
}

/// Predict GEMM latency; returns (seconds, instructions walked).
pub fn predict_gemm(m: u32, n: u32, k: u32, gpu: &GpuSpec) -> (f64, usize) {
    let cfg = KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 };
    let d = cfg.decompose(gpu);
    let (tm, tn, tk) = d.tile;
    // synthesize the per-task instruction stream: k-loop of (copy stage,
    // smem loads, MMA fragment grid, sync), then epilogue
    let k_iters = (k.div_ceil(tk)).max(1) as usize;
    let frags = ((tm / 16) * (tn / 8)).max(1) as usize;
    let mut insts = Vec::with_capacity(k_iters * (frags + 12) + 64);
    for _ in 0..k_iters {
        for _ in 0..4 {
            insts.push(Inst::LoadGlobal);
        }
        for _ in 0..8 {
            insts.push(Inst::LoadShared);
        }
        for _ in 0..frags.min(512) {
            insts.push(Inst::Mma);
        }
        insts.push(Inst::Sync);
    }
    for _ in 0..((tm * tn / 128).min(512)) {
        insts.push(Inst::Fma); // epilogue
    }
    insts.push(Inst::Sync);

    let per_task_cycles = walk(&insts, gpu);
    let occ = d.cta.occupancy(gpu) as f64;
    let waves = (d.num_tasks() as f64 / (gpu.num_sms as f64 * occ)).ceil();
    let cycles = per_task_cycles * waves;
    (
        cycles * gpu.cycle_sec() + 2.0e-6,
        insts.len() * d.num_tasks().min(1) + insts.len(), // walked once/task-shape
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn monotone_in_k() {
        let gpu = gpu_by_name("A100").unwrap();
        let (t1, _) = predict_gemm(4096, 4096, 1024, &gpu);
        let (t2, _) = predict_gemm(4096, 4096, 4096, &gpu);
        assert!(t2 > 2.0 * t1, "{t1} vs {t2}");
    }

    #[test]
    fn within_sane_band_of_oracle() {
        use crate::kernels::{DType, KernelConfig};
        let gpu = gpu_by_name("A100").unwrap();
        let mut errs = Vec::new();
        for (m, n, k) in [(2048, 2048, 2048), (8192, 4096, 1024), (512, 8192, 4096)] {
            let (pred, _) = predict_gemm(m, n, k, &gpu);
            let actual = crate::oracle::measure(
                &KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 },
                &gpu,
                1,
            )
            .latency_sec;
            errs.push(((pred - actual) / actual).abs());
        }
        let avg = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(avg < 0.8, "AMALI stand-in wildly off: {errs:?}");
    }
}
