//! Baseline predictors (paper §VI-A): Roofline [74], Linear [29],
//! Habitat [76], Neusight [26] — all fed with SynPerf's own analytical
//! components for a fair comparison, exactly as the paper does ("we adjusted
//! them to incorporate our analytical components") — plus the two detailed
//! secondary comparators of Fig. 7: an AMALI-style instruction-trace
//! analytical model and an LLMCompass-style systolic-array tile simulator.

pub mod amali;
pub mod habitat;
pub mod linear;
pub mod llmcompass;
pub mod neusight;

use crate::dataset::Sample;

/// The classic Roofline estimate: max(compute roof, naive memory roof).
/// Overestimates latency where L2 reuse matters, underestimates where pipes
/// can't be saturated (§VI-C).
pub fn roofline_predict(s: &Sample) -> f64 {
    s.roofline_sec
}

#[cfg(test)]
mod tests {
    use crate::dataset;
    use crate::hw::gpu_by_name;
    use crate::kernels::KernelKind;

    #[test]
    fn roofline_underestimates_latency() {
        let gpus = vec![gpu_by_name("A100").unwrap()];
        let ds = dataset::build(KernelKind::Gemm, &gpus, 12, 3, 2);
        // roofline (a lower-bound style estimate with naive memory) should
        // sit below measured latency most of the time
        let below = ds.iter().filter(|s| s.roofline_sec < s.latency_sec).count();
        assert!(below * 3 > ds.len() * 2, "{below}/{}", ds.len());
    }
}
