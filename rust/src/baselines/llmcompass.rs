//! LLMCompass-style stand-in (paper [78], Fig. 7): a hybrid framework whose
//! compute model simulates the systolic array *cycle-granularly* tile by
//! tile: the k-loop is stepped through in systolic passes with fill/drain
//! phases and a double-buffered operand feed, for every distinct tile shape
//! and every wave. Heavier than AMALI (more simulated steps), moderately
//! accurate for the same reasons (no dynamic scheduling, fixed constants).

use crate::hw::GpuSpec;
use crate::kernels::{DType, KernelConfig};

/// Simulate one output tile on a WxW systolic array; returns cycles and the
/// number of simulated systolic steps (the Fig. 7 cost metric).
fn simulate_tile(tm: u32, tn: u32, k: u32, array_dim: u32) -> (f64, usize) {
    let w = array_dim as u64;
    let mut cycles = 0u64;
    let mut steps = 0usize;
    // the tile is processed as a grid of WxW output sub-blocks
    let sub_m = tm.div_ceil(array_dim) as u64;
    let sub_n = tn.div_ceil(array_dim) as u64;
    for _ in 0..sub_m {
        for _ in 0..sub_n {
            // fill pipeline
            cycles += 2 * w - 1;
            // stream K in vectors of W with a double-buffered feed,
            // accounting cycle-by-cycle for the skewed operand wavefront
            let k_steps = k.div_ceil(array_dim) as u64;
            for s in 0..k_steps {
                let mut pass = 0u64;
                for r in 0..w {
                    // one cycle per row plus a feed-parity bubble; black_box
                    // pins the per-cycle accounting (this simulator's cost
                    // IS the deliverable being measured in Fig. 7)
                    pass = std::hint::black_box(pass + 1 + ((s + r) & 1) / w.max(1));
                    steps += 1;
                }
                cycles += pass.max(w);
                if s % 16 == 15 {
                    cycles += 4; // buffer swap bubble
                }
            }
            // drain
            cycles += w;
        }
    }
    (cycles as f64, steps)
}

/// Predict GEMM latency; returns (seconds, simulated systolic steps).
pub fn predict_gemm(m: u32, n: u32, k: u32, gpu: &GpuSpec) -> (f64, usize) {
    let cfg = KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 };
    let d = cfg.decompose(gpu);
    let (tm, tn, _) = d.tile;
    // effective systolic width from the SM's MMA throughput:
    // ops/cycle = 2 * W^2  =>  W = sqrt(th / 2)
    let array_dim = ((gpu.tensor_ops_clk_sm / 2.0).sqrt() as u32).max(8);
    let occ = d.cta.occupancy(gpu) as f64;
    let waves = (d.num_tasks() as f64 / (gpu.num_sms as f64 * occ)).ceil() as usize;
    // simulate every wave tile-by-tile (cycle-granular — the cost the
    // Fig. 7 comparison charges this modeling paradigm with)
    let mut cycles = 0.0;
    let mut steps = 0usize;
    for _ in 0..waves.max(1) {
        let (c, s) = simulate_tile(tm, tn, k, array_dim);
        cycles += c;
        steps += s;
    }
    // fixed feed efficiency (the model's blind spot)
    cycles /= 0.78;
    (cycles * gpu.cycle_sec() + 2.0e-6, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;

    #[test]
    fn step_count_scales_with_problem() {
        let gpu = gpu_by_name("A100").unwrap();
        let (_, s1) = predict_gemm(1024, 1024, 1024, &gpu);
        let (_, s2) = predict_gemm(1024, 1024, 8192, &gpu);
        assert!(s2 > 4 * s1);
    }

    #[test]
    fn slower_than_amali_stand_in() {
        // the Fig. 7 ordering: LLMCompass simulates more steps than AMALI
        // walks instructions for the same GEMM
        let gpu = gpu_by_name("A100").unwrap();
        let t0 = std::time::Instant::now();
        let _ = predict_gemm(8192, 8192, 8192, &gpu);
        let t_llmc = t0.elapsed();
        let t0 = std::time::Instant::now();
        let _ = super::super::amali::predict_gemm(8192, 8192, 8192, &gpu);
        let t_amali = t0.elapsed();
        // both should be measurable work, llmcompass heavier
        assert!(t_llmc >= t_amali, "{t_llmc:?} vs {t_amali:?}");
    }

    #[test]
    fn prediction_positive_and_finite() {
        for g in crate::hw::all_gpus() {
            let (t, _) = predict_gemm(2048, 4096, 1024, &g);
            assert!(t.is_finite() && t > 0.0, "{}", g.name);
        }
    }
}
