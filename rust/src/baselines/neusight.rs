//! Neusight-style baseline (paper [26]): tile-level decomposition + ML, with
//! the three §III limitations reproduced faithfully:
//!  * tile-centric features — heterogeneous pipeline activity collapsed
//!    into aggregate FLOPs/bytes per tile (no per-pipe split);
//!  * operator-level modeling — no awareness of fused-kernel coupling
//!    beyond tile counts;
//!  * static wave assumption — latency = waves x uniform tile latency, no
//!    per-SM distribution / imbalance features.
//!
//! It reuses SynPerf's task decomposition (as the paper does for fairness)
//! and the same MLP artifact machinery, just with its restricted feature
//! view (`Sample::x_alt`, built in dataset::make_sample).

use crate::features::FEATURE_DIM;
use crate::hw::GpuSpec;
use crate::kernels::Decomposition;

/// Tile-level feature vector + static-wave theoretical time.
pub fn features(decomp: &Decomposition, gpu: &GpuSpec) -> ([f32; FEATURE_DIM], f64) {
    let n = decomp.num_tasks().max(1) as f64;
    let flops: f64 = decomp.group_sum(|t| t.tensor_ops + t.fma_ops + t.xu_ops);
    let bytes: f64 = decomp.total_bytes();
    let tile_flops = flops / n;
    let tile_bytes = bytes / n;
    let occ = decomp.cta.occupancy(gpu) as f64;
    let waves = (n / (gpu.num_sms as f64 * occ)).ceil().max(1.0);

    // static wave model: each wave runs `wave_size` uniform tiles in
    // parallel — per-SM compute, aggregate memory over full bandwidth
    let peak_flops_sm = (gpu.tensor_ops_clk_sm + gpu.fma_ops_clk_sm) * gpu.sm_clock_mhz * 1e6;
    let tile_compute = tile_flops / peak_flops_sm;
    let wave_size = n.min(gpu.num_sms as f64 * occ);
    // static cache assumption: a fixed 70% of tile loads hit on-chip —
    // Neusight-style fixed coefficients where the workload actually varies
    // (the §III "static" blind spot; real reuse spans 10%..92%)
    let wave_mem = tile_bytes * wave_size * 0.30 / (gpu.dram_bw_gbs * 1e9);
    let tile_roof = tile_compute.max(wave_mem);
    let alt_theory_sec = waves * tile_roof;

    #[inline]
    fn l(v: f64) -> f32 {
        v.max(0.0).ln_1p() as f32
    }
    let mut x = [0f32; FEATURE_DIM];
    x[0] = l(tile_flops);
    x[1] = l(tile_bytes);
    x[2] = l(n);
    x[3] = l(waves);
    x[4] = l(tile_roof * 1e9);
    x[5] = occ as f32;
    x[6] = l(flops);
    x[7] = l(bytes);
    x[8] = (tile_flops / tile_bytes.max(1.0)).min(1e4).ln_1p() as f32; // AI
    // hardware descriptors (same subset SynPerf exposes)
    x[9] = (gpu.num_sms as f64).ln() as f32;
    x[10] = gpu.sm_clock_mhz.ln() as f32;
    x[11] = gpu.dram_bw_gbs.ln() as f32;
    x[12] = gpu.tensor_ops_clk_sm.ln() as f32;
    x[13] = gpu.compute_mem_ratio().ln() as f32;
    x[14] = gpu.l2_mb.ln() as f32;
    (x, alt_theory_sec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::{DType, KernelConfig};

    #[test]
    fn static_wave_blind_to_imbalance() {
        // Two attention batches with identical totals but different skew
        // produce identical Neusight features (mean-tile view) while the
        // real latencies differ — the §III "static wave modeling" failure.
        let gpu = gpu_by_name("A100").unwrap();
        let balanced = KernelConfig::Attention {
            batch: vec![(2048, 2048); 4],
            nh: 8,
            nkv: 8,
            hd: 128,
            causal: false,
            fa3: false,
        };
        let d = balanced.decompose(&gpu);
        let (x, th) = features(&d, &gpu);
        assert!(th > 0.0);
        assert!(x.iter().all(|v| v.is_finite()));
        // no per-SM max / imbalance feature present: x has at most 15 slots
        assert!(x[15..].iter().all(|v| *v == 0.0));
    }

    #[test]
    fn waves_quantize() {
        let gpu = gpu_by_name("H100").unwrap();
        let small = KernelConfig::Gemm { m: 256, n: 256, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let (_, th_small) = features(&small, &gpu);
        let big = KernelConfig::Gemm { m: 8192, n: 8192, k: 512, dtype: DType::Bf16 }
            .decompose(&gpu);
        let (_, th_big) = features(&big, &gpu);
        assert!(th_big > th_small);
    }
}
