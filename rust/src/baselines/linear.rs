//! Linear baseline (paper [29], §VI-A): ordinary least squares from the two
//! aggregate analytical features — theoretical compute cycles and memory
//! cycles — to measured latency. Closed-form fit on the seen-GPU split.

use crate::dataset::Sample;
use crate::util::stats::ols;

#[derive(Debug, Clone)]
pub struct LinearModel {
    /// [intercept, w_compute, w_mem]
    pub beta: Vec<f64>,
}

impl LinearModel {
    pub fn fit(train: &[Sample]) -> LinearModel {
        // weight rows by 1/sqrt(latency): the paper's ranges span five
        // decades; unweighted OLS fits only the largest kernels, while full
        // relative weighting would overfit the small-kernel regime — the
        // original predictor [29] lands in between
        let x: Vec<Vec<f64>> = train
            .iter()
            .map(|s| {
                let w = 1.0 / s.latency_sec.max(1e-9).sqrt();
                vec![w, s.compute_sec * w, s.mem_sec * w]
            })
            .collect();
        let y: Vec<f64> = train.iter().map(|s| s.latency_sec.max(1e-9).sqrt().recip() * s.latency_sec).collect();
        LinearModel { beta: ols(&x, &y) }
    }

    pub fn predict(&self, s: &Sample) -> f64 {
        (self.beta[0] + self.beta[1] * s.compute_sec + self.beta[2] * s.mem_sec).max(1e-7)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset;
    use crate::hw::seen_gpus;
    use crate::kernels::KernelKind;
    use crate::util::stats::mape;

    #[test]
    fn linear_fits_but_poorly() {
        let ds = dataset::build(KernelKind::Gemm, &seen_gpus(), 60, 17, 4);
        let m = LinearModel::fit(&ds);
        let pred: Vec<f64> = ds.iter().map(|s| m.predict(&s)).collect();
        let actual: Vec<f64> = ds.iter().map(|s| s.latency_sec).collect();
        let err = mape(&pred, &actual);
        // sane but far from the hybrid model's accuracy
        assert!(err < 500.0, "linear degenerate: {err}%");
        assert!(err > 10.0, "linear unexpectedly perfect: {err}%");
    }
}
