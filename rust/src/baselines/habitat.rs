//! Habitat-style baseline (paper [76]): wave-scaling a *measured* runtime
//! from a local reference GPU to the target GPU using compute / bandwidth
//! ratios. Black-box w.r.t. microarchitecture — which is why it transfers
//! poorly to unseen architectures (85.96% in Table VIII).

use crate::features::FeatureSet;
use crate::hw::{gpu_by_name, GpuSpec};
use crate::kernels::KernelConfig;
use crate::oracle;
use crate::sched::schedule;

/// Reference device: the A100 (the most common "local" GPU); falls back to
/// the A40 when predicting the A100 itself.
pub fn reference_gpu(target: &GpuSpec) -> GpuSpec {
    if target.name == "A100" {
        gpu_by_name("A40").unwrap()
    } else {
        gpu_by_name("A100").unwrap()
    }
}

/// Aggregate compute / (naive) memory roofs of `cfg` on `gpu`, in seconds.
fn roofs(cfg: &KernelConfig, gpu: &GpuSpec) -> (f64, f64) {
    let c = crate::dataset::finalize_for_gpu(cfg, gpu);
    let d = c.decompose(gpu);
    let f = FeatureSet::analyze(&d, &schedule(&d, gpu), gpu);
    let compute = f.tensor.total_cycles.max(f.fma.total_cycles).max(f.xu.total_cycles)
        * gpu.cycle_sec();
    let mem = f.mio.cycles_dram * gpu.cycle_sec();
    (compute, mem)
}

/// Wave-scaling prediction: measure on the reference, then scale by the
/// roof ratio of whichever regime (compute/memory) dominates on each side.
pub fn predict(cfg: &KernelConfig, target: &GpuSpec, seed: u64) -> f64 {
    let reference = reference_gpu(target);
    predict_with_roofs(cfg, &reference, seed, roofs(cfg, target), roofs(cfg, &reference))
}

/// Same prediction with the reference device plus the target and reference
/// roofs supplied by the caller — the [`crate::engine::PredictionEngine`]
/// holds both roof pairs in its analysis cache, which spares two full
/// decompose+schedule+featurize passes per sample; only the seeded
/// reference measurement remains.
pub fn predict_with_roofs(
    cfg: &KernelConfig,
    reference: &GpuSpec,
    seed: u64,
    (c_tgt, m_tgt): (f64, f64),
    (c_ref, m_ref): (f64, f64),
) -> f64 {
    let ref_cfg = crate::dataset::finalize_for_gpu(cfg, reference);
    let t_ref = oracle::measure(&ref_cfg, reference, seed ^ 0xAB17A7).latency_sec;

    // wave scaling: blend the per-regime ratios by how memory-bound the
    // kernel is on the reference device
    let mem_weight = m_ref / (m_ref + c_ref).max(1e-12);
    let ratio = mem_weight * (m_tgt / m_ref.max(1e-12))
        + (1.0 - mem_weight) * (c_tgt / c_ref.max(1e-12));
    (t_ref * ratio).max(1e-7)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::DType;

    #[test]
    fn scales_toward_faster_hardware() {
        let cfg = KernelConfig::Gemm { m: 4096, n: 4096, k: 4096, dtype: DType::Bf16 };
        let a40 = gpu_by_name("A40").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let p_a40 = predict(&cfg, &a40, 1);
        let p_h800 = predict(&cfg, &h800, 1);
        assert!(p_h800 < p_a40, "H800 {p_h800} should beat A40 {p_a40}");
    }

    #[test]
    fn reference_never_self() {
        for g in crate::hw::all_gpus() {
            assert_ne!(reference_gpu(&g).name, g.name);
        }
    }
}
