//! The shared **PredictionEngine** — the one owner of the analytical
//! request path `decompose F(X,S) → schedule M(T,S) → featurize (Table IV)
//! → predict`.
//!
//! Before this subsystem existed the path was duplicated across the
//! coordinator service loop, the E2E trace evaluator, dataset construction
//! and the experiment drivers; they now all route through here and share:
//!
//!  * a **sharded memoizing analysis cache** keyed by the canonical
//!    `(KernelConfig, GpuSpec)` key ([`key::CacheKey`]): the probe hash
//!    picks one of [`DEFAULT_CACHE_SHARDS`] independent
//!    `Mutex<LruCache>` shards ([`cache::LruCache`]), so concurrent
//!    callers only contend when they touch the same shard — repeated
//!    launches in traces and in the service loop skip re-decomposition
//!    entirely, and parallel evaluators never serialize on one global
//!    lock;
//!  * **parallel fan-out** ([`par::par_map`], scoped threads, order
//!    preserving and thread-count deterministic) for dataset generation and
//!    batch featurization.
//!
//! The engine is the *analysis* half of the stack. Request routing — the
//! per-`KernelKind` batched MLP forwards, provenance, degraded-mode rules —
//! lives one layer up in [`crate::api`] (protocol v1), which every
//! prediction consumer calls through.
//!
//! The cached [`Analysis`] holds everything seed-independent about a launch
//! (feature set, MLP input vectors for SynPerf and the Neusight baseline,
//! roof components, and — post the run-length refactor — the tiny grouped
//! [`Decomposition`] itself). Ground-truth oracle measurement is
//! seed-dependent and is never cached; [`PredictionEngine::make_sample`]
//! feeds the oracle from the cached decomposition, so the hit path neither
//! clones the config nor re-decomposes.

pub mod cache;
pub mod key;
pub mod par;

use crate::dataset::{self, finalize_for_gpu, Sample};
use crate::features::{FeatureSet, FEATURE_DIM};
use crate::hw::GpuSpec;
use crate::kernels::{Decomposition, KernelConfig, KernelKind};
use crate::oracle;
use crate::sched::schedule;
use self::cache::LruCache;
use self::key::CacheKey;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default requested cache capacity across all shards (each shard is
/// provisioned with 1/4 headroom over its even split — see
/// [`PredictionEngine::with_shards`]). An entry is a few hundred bytes
/// (the grouped decomposition it retains is 1–3 groups for
/// tile/elementwise kernels and one group per query tile for causal
/// attention — not the materialized task set), so this is a few MB at most.
pub const DEFAULT_CACHE_CAPACITY: usize = 8192;

/// Default shard count (power of two). The probe hash's low bits select
/// the shard, so concurrent `analyze` callers contend on a given shard's
/// mutex with probability ~1/shards instead of always.
pub const DEFAULT_CACHE_SHARDS: usize = 16;

/// Everything seed-independent the pipeline derives for one kernel launch
/// on one GPU.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub kind: KernelKind,
    /// The full Table-IV feature set (per-pipe demands, MIO, imbalance,
    /// `theory_sec`, `naive_roofline_sec`).
    pub features: FeatureSet,
    /// SynPerf MLP input vector.
    pub x: [f32; FEATURE_DIM],
    /// Neusight-baseline tile-level feature vector + its static-wave roof.
    pub x_alt: [f32; FEATURE_DIM],
    pub alt_theory_sec: f64,
    /// Aggregate compute / (naive) memory roofs in seconds — the Linear
    /// baseline inputs and the Habitat wave-scaling ratios.
    pub compute_sec: f64,
    pub mem_sec: f64,
    /// The run-length decomposition (post-PR-3 `{template, count}` groups,
    /// launch order). Retained so seed-dependent consumers — the oracle in
    /// [`PredictionEngine::make_sample`] — expand tasks from the cache
    /// instead of re-decomposing on every repeated launch.
    pub decomp: Decomposition,
}

impl Analysis {
    pub fn theory_sec(&self) -> f64 {
        self.features.theory_sec
    }
}

/// Cache counters — cumulative over the engine's lifetime, aggregated
/// across shards.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub capacity: usize,
}

impl EngineStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// One cache shard: an independent LRU plus its own counters, so the hot
/// path touches exactly one mutex and `stats()` touches none.
struct Shard {
    cache: Mutex<LruCache<CacheKey, Analysis>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Mirror of `cache.len()`, written under the shard lock on insert and
    /// read lock-free by [`PredictionEngine::stats`] — metrics scraping
    /// under load never stalls `analyze`.
    entries: AtomicUsize,
}

pub struct PredictionEngine {
    shards: Vec<Shard>,
    /// `shards.len() - 1`; shard count is a power of two.
    shard_mask: u64,
    /// Total capacity across shards (per-shard capacity × shard count).
    capacity: usize,
}

static GLOBAL: OnceLock<PredictionEngine> = OnceLock::new();

impl PredictionEngine {
    pub fn new(capacity: usize) -> PredictionEngine {
        PredictionEngine::with_shards(capacity, DEFAULT_CACHE_SHARDS)
    }

    /// Engine with an explicit shard count (rounded up to a power of two;
    /// `with_shards(cap, 1)` is the single-mutex baseline the contention
    /// benches compare against). Each shard gets the even split of
    /// `capacity` plus 1/4 headroom (at least one entry): uniform hashing
    /// skews shard occupancy (std ≈ √(cap/n) keys), and without headroom a
    /// working set that fit the single-mutex cache exactly would start
    /// evicting from the fuller shards. [`stats`](Self::stats) reports the
    /// actually provisioned total.
    pub fn with_shards(capacity: usize, shards: usize) -> PredictionEngine {
        let n = shards.max(1).next_power_of_two();
        let even = capacity.div_ceil(n);
        let per_shard = if n > 1 { (even + even.div_ceil(4)).max(1) } else { even.max(1) };
        PredictionEngine {
            shards: (0..n)
                .map(|_| Shard {
                    cache: Mutex::new(LruCache::new(per_shard)),
                    hits: AtomicU64::new(0),
                    misses: AtomicU64::new(0),
                    entries: AtomicUsize::new(0),
                })
                .collect(),
            shard_mask: (n - 1) as u64,
            capacity: per_shard * n,
        }
    }

    /// The process-wide shared engine. The coordinator service, the E2E
    /// evaluator and dataset construction all share this cache, so a trace
    /// evaluated after serving (or vice versa) reuses prior analyses.
    pub fn global() -> &'static PredictionEngine {
        GLOBAL.get_or_init(|| PredictionEngine::new(DEFAULT_CACHE_CAPACITY))
    }

    fn shard_for(&self, hash: u64) -> &Shard {
        &self.shards[(hash & self.shard_mask) as usize]
    }

    /// Aggregate counters without touching any cache lock: hits/misses and
    /// per-shard entry counts are atomics, so scraping metrics while
    /// `analyze` runs hot never blocks it (and cannot deadlock).
    pub fn stats(&self) -> EngineStats {
        let mut stats =
            EngineStats { hits: 0, misses: 0, entries: 0, capacity: self.capacity };
        for shard in &self.shards {
            stats.hits += shard.hits.load(Ordering::Relaxed);
            stats.misses += shard.misses.load(Ordering::Relaxed);
            stats.entries += shard.entries.load(Ordering::Relaxed);
        }
        stats
    }

    /// Cached decompose → schedule → featurize. Returns the shared analysis.
    pub fn analyze(&self, cfg: &KernelConfig, gpu: &GpuSpec) -> Arc<Analysis> {
        self.analyze_hit(cfg, gpu).0
    }

    /// Like [`analyze`](Self::analyze) but also reports whether the result
    /// came from the cache (the coordinator metrics consume this).
    ///
    /// The config may be unfinalized: the shard is probed with a
    /// borrowed-key hash ([`key::probe_hash`]) over the raw config plus the
    /// GPU-resolved FA variant, so the **hit path performs no
    /// `KernelConfig` clone and no allocation** (attention's `batch` vec
    /// would heap-allocate on every request otherwise). Finalization — the
    /// one clone on the whole path — happens only on a miss.
    pub fn analyze_hit(&self, cfg: &KernelConfig, gpu: &GpuSpec) -> (Arc<Analysis>, bool) {
        let gpu_fp = key::gpu_fingerprint(gpu);
        let fa3 = dataset::fa3_for(gpu);
        let hash = key::probe_hash(cfg, fa3, gpu_fp);
        let shard = self.shard_for(hash);
        if let Some(hit) =
            shard.cache.lock().unwrap().get_matching(hash, |k| k.matches(cfg, fa3, gpu_fp))
        {
            shard.hits.fetch_add(1, Ordering::Relaxed);
            return (hit, true);
        }
        (self.compute_and_insert(finalize_for_gpu(cfg, gpu), gpu, gpu_fp, hash, shard), false)
    }

    /// Miss path: run the analytical pipeline **outside the lock** (parallel
    /// builders must not serialize on the cheap map while doing the
    /// expensive analysis) and insert. Concurrent misses on the same key
    /// may both compute; the value is pure, so whichever insert lands last
    /// wins with an identical analysis.
    fn compute_and_insert(
        &self,
        cfg: KernelConfig,
        gpu: &GpuSpec,
        gpu_fp: u64,
        hash: u64,
        shard: &Shard,
    ) -> Arc<Analysis> {
        let decomp = cfg.decompose(gpu);
        let dist = schedule(&decomp, gpu);
        let features = FeatureSet::analyze(&decomp, &dist, gpu);
        let x = features.to_model_input(gpu);
        let (x_alt, alt_theory_sec) = crate::baselines::neusight::features(&decomp, gpu);
        let compute_roof =
            features.tensor.total_cycles.max(features.fma.total_cycles).max(features.xu.total_cycles);
        let analysis = Arc::new(Analysis {
            kind: cfg.kind(),
            x,
            x_alt,
            alt_theory_sec,
            compute_sec: compute_roof * gpu.cycle_sec(),
            mem_sec: features.mio.cycles_dram * gpu.cycle_sec(),
            features,
            decomp,
        });
        shard.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = shard.cache.lock().unwrap();
        guard.insert_hashed(hash, CacheKey::from_finalized(cfg, gpu_fp), analysis.clone());
        shard.entries.store(guard.len(), Ordering::Relaxed);
        drop(guard);
        analysis
    }

    /// Featurize a batch of launches with parallel fan-out. Results are in
    /// input order and bit-identical to serial [`analyze`](Self::analyze)
    /// calls.
    pub fn analyze_batch(
        &self,
        reqs: &[(KernelConfig, GpuSpec)],
        threads: usize,
    ) -> Vec<Arc<Analysis>> {
        par::par_map(reqs, threads, |_, (cfg, gpu)| self.analyze(cfg, gpu))
    }

    /// Analyze + oracle-profile one `(config, gpu, seed)` into a training
    /// [`Sample`]. The analytical half is cached; the oracle measurement is
    /// seeded and always runs — fed from the decomposition retained in the
    /// cached analysis, so a repeated launch performs **zero** config
    /// clones and zero re-decompositions (the Habitat baseline's
    /// reference-GPU roofs come from the same cache; only the two seeded
    /// oracle measurements remain).
    pub fn make_sample(&self, cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> Sample {
        let (a, _) = self.analyze_hit(cfg, gpu);
        let o = oracle::measure_decomposed(a.kind, &a.decomp, gpu, seed);
        let reference = crate::baselines::habitat::reference_gpu(gpu);
        let ref_a = self.analyze(cfg, &reference);
        // the raw config is equivalent to the target-finalized one here:
        // predict_with_roofs re-finalizes for the reference GPU, which
        // overwrites the only field finalization touches (the FA variant)
        let habitat_sec = crate::baselines::habitat::predict_with_roofs(
            cfg,
            &reference,
            seed,
            (a.compute_sec, a.mem_sec),
            (ref_a.compute_sec, ref_a.mem_sec),
        );
        Sample {
            kind: a.kind,
            gpu: gpu.name.to_string(),
            seen: gpu.seen,
            x: a.x,
            theory_sec: a.features.theory_sec,
            latency_sec: o.latency_sec,
            roofline_sec: a.features.naive_roofline_sec,
            compute_sec: a.compute_sec,
            mem_sec: a.mem_sec,
            habitat_sec,
            x_alt: a.x_alt,
            alt_theory_sec: a.alt_theory_sec,
        }
    }

    /// Build a profiling dataset: `n_configs` sampled configs × every GPU,
    /// fanned out over `threads` workers. Row order and values are
    /// independent of the thread count (per-row seeds derive from the
    /// config index).
    pub fn build_dataset(
        &self,
        kind: KernelKind,
        gpus: &[GpuSpec],
        n_configs: usize,
        seed: u64,
        threads: usize,
    ) -> Vec<Sample> {
        let configs = dataset::sample_configs(kind, n_configs, seed);
        let per_cfg: Vec<Vec<Sample>> = par::par_map(&configs, threads, |idx, cfg| {
            let mut local = Vec::with_capacity(gpus.len());
            for gpu in gpus {
                // name hash: identically-specced GPUs (H100/H800) get
                // independent noise streams
                let h = gpu
                    .name
                    .bytes()
                    .fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
                let s = seed.wrapping_add((idx as u64) << 8).wrapping_add(h);
                local.push(self.make_sample(cfg, gpu, s));
            }
            local
        });
        per_cfg.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::DType;
    use std::time::Duration;

    fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
        KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
    }

    #[test]
    fn second_lookup_hits() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = gemm(1024, 2048, 512);
        let (_, hit0) = engine.analyze_hit(&cfg, &gpu);
        let (_, hit1) = engine.analyze_hit(&cfg, &gpu);
        assert!(!hit0);
        assert!(hit1);
        let s = engine.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cached_analysis_is_bit_identical() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("H800").unwrap();
        let cfg = gemm(4096, 4096, 1024);
        let a = engine.analyze(&cfg, &gpu);
        let b = engine.analyze(&cfg, &gpu);
        assert_eq!(a.x, b.x);
        assert_eq!(a.theory_sec().to_bits(), b.theory_sec().to_bits());
        assert!(Arc::ptr_eq(&a, &b), "hit must return the shared analysis");
    }

    #[test]
    fn fa_variant_resolution_separates_keys() {
        // The same logical attention launch is FA2 on A100, FA3 on H800 —
        // the engine finalizes before keying, so both cache cleanly.
        let engine = PredictionEngine::new(64);
        let cfg = KernelConfig::Attention {
            batch: vec![(256, 256)],
            nh: 4,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: false,
        };
        let a100 = gpu_by_name("A100").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let a = engine.analyze(&cfg, &a100);
        let b = engine.analyze(&cfg, &h800);
        assert_ne!(a.x, b.x);
        assert_eq!(engine.stats().misses, 2);
        // looking the pre-finalized config up again still hits
        engine.analyze(&cfg, &h800);
        assert_eq!(engine.stats().hits, 1);
    }

    #[test]
    fn make_sample_matches_direct_path() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("A40").unwrap();
        let cfg = gemm(2048, 1024, 512);
        let via_engine = engine.make_sample(&cfg, &gpu, 42);
        // second call goes through the cache; the oracle part re-runs
        let cached = engine.make_sample(&cfg, &gpu, 42);
        assert_eq!(via_engine.x, cached.x);
        assert_eq!(via_engine.latency_sec.to_bits(), cached.latency_sec.to_bits());
        assert_eq!(via_engine.habitat_sec.to_bits(), cached.habitat_sec.to_bits());
    }

    #[test]
    fn cached_decomposition_matches_a_fresh_one() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("H800").unwrap();
        let cfg = gemm(1024, 512, 2048);
        let a = engine.analyze(&cfg, &gpu);
        let fresh = finalize_for_gpu(&cfg, &gpu).decompose(&gpu);
        assert_eq!(a.decomp.num_tasks(), fresh.num_tasks());
        assert_eq!(a.decomp.num_groups(), fresh.num_groups());
        assert_eq!(
            a.decomp.total_tensor_ops().to_bits(),
            fresh.total_tensor_ops().to_bits()
        );
    }

    #[test]
    fn shard_count_does_not_change_results_or_totals() {
        let gpu = gpu_by_name("H20").unwrap();
        // capacity well above 40 keys x worst-case shard skew, so neither
        // layout can evict and the entry totals must agree exactly
        let one = PredictionEngine::with_shards(1024, 1);
        let many = PredictionEngine::with_shards(1024, 16);
        for i in 0..40u32 {
            let cfg = gemm(64 + i, 128, 256);
            let a = one.analyze(&cfg, &gpu);
            let b = many.analyze(&cfg, &gpu);
            assert_eq!(a.x, b.x);
            assert_eq!(a.theory_sec().to_bits(), b.theory_sec().to_bits());
        }
        let (s1, s16) = (one.stats(), many.stats());
        assert_eq!((s1.hits, s1.misses), (s16.hits, s16.misses));
        assert_eq!(s1.entries, s16.entries);
    }

    #[test]
    fn stats_never_block_on_held_shard_locks() {
        // the satellite fix: metrics scraping must not take the hot-path
        // lock — stats() reads only atomics, so it completes even while
        // every shard mutex is held by someone else
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("L40").unwrap();
        engine.analyze(&gemm(128, 128, 128), &gpu);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::scope(|s| {
            let guards: Vec<_> =
                engine.shards.iter().map(|sh| sh.cache.lock().unwrap()).collect();
            let eng = &engine;
            s.spawn(move || {
                let _ = tx.send(eng.stats());
            });
            let got = rx.recv_timeout(Duration::from_secs(10));
            drop(guards);
            let stats = got.expect("stats() must not block on cache locks");
            assert_eq!(stats.entries, 1);
            assert_eq!(stats.misses, 1);
        });
    }
}
