//! The shared **PredictionEngine** — the one owner of the analytical
//! request path `decompose F(X,S) → schedule M(T,S) → featurize (Table IV)
//! → predict`.
//!
//! Before this subsystem existed the path was duplicated across the
//! coordinator service loop, the E2E trace evaluator, dataset construction
//! and the experiment drivers; they now all route through here and share:
//!
//!  * a **memoizing analysis cache** keyed by the canonical
//!    `(KernelConfig, GpuSpec)` key ([`key::CacheKey`]) with LRU bounding
//!    ([`cache::LruCache`]) — repeated launches in traces and in the
//!    service loop skip re-decomposition entirely;
//!  * **parallel fan-out** ([`par::par_map`], scoped threads, order
//!    preserving and thread-count deterministic) for dataset generation and
//!    batch featurization.
//!
//! The engine is the *analysis* half of the stack. Request routing — the
//! per-`KernelKind` batched MLP forwards, provenance, degraded-mode rules —
//! lives one layer up in [`crate::api`] (protocol v1), which every
//! prediction consumer calls through.
//!
//! The cached [`Analysis`] holds everything seed-independent about a launch
//! (feature set, MLP input vectors for SynPerf and the Neusight baseline,
//! roof components). Ground-truth oracle measurement is seed-dependent and
//! is never cached; [`PredictionEngine::make_sample`] reuses the
//! decomposition computed on a cache miss so profiling does no duplicate
//! work.

pub mod cache;
pub mod key;
pub mod par;

use crate::dataset::{self, finalize_for_gpu, Sample};
use crate::features::{FeatureSet, FEATURE_DIM};
use crate::hw::GpuSpec;
use crate::kernels::{Decomposition, KernelConfig, KernelKind};
use crate::oracle;
use crate::sched::schedule;
use self::cache::LruCache;
use self::key::CacheKey;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Default number of cached analyses. An entry is a few hundred bytes (the
/// task set itself is *not* retained), so this is a few MB at most.
pub const DEFAULT_CACHE_CAPACITY: usize = 8192;

/// Everything seed-independent the pipeline derives for one kernel launch
/// on one GPU.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub kind: KernelKind,
    /// The full Table-IV feature set (per-pipe demands, MIO, imbalance,
    /// `theory_sec`, `naive_roofline_sec`).
    pub features: FeatureSet,
    /// SynPerf MLP input vector.
    pub x: [f32; FEATURE_DIM],
    /// Neusight-baseline tile-level feature vector + its static-wave roof.
    pub x_alt: [f32; FEATURE_DIM],
    pub alt_theory_sec: f64,
    /// Aggregate compute / (naive) memory roofs in seconds — the Linear
    /// baseline inputs and the Habitat wave-scaling ratios.
    pub compute_sec: f64,
    pub mem_sec: f64,
}

impl Analysis {
    pub fn theory_sec(&self) -> f64 {
        self.features.theory_sec
    }
}

/// Cache counters — cumulative over the engine's lifetime.
#[derive(Debug, Clone, Copy)]
pub struct EngineStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    pub capacity: usize,
}

impl EngineStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

pub struct PredictionEngine {
    cache: Mutex<LruCache<CacheKey, Analysis>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

static GLOBAL: OnceLock<PredictionEngine> = OnceLock::new();

impl PredictionEngine {
    pub fn new(capacity: usize) -> PredictionEngine {
        PredictionEngine {
            cache: Mutex::new(LruCache::new(capacity)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// The process-wide shared engine. The coordinator service, the E2E
    /// evaluator and dataset construction all share this cache, so a trace
    /// evaluated after serving (or vice versa) reuses prior analyses.
    pub fn global() -> &'static PredictionEngine {
        GLOBAL.get_or_init(|| PredictionEngine::new(DEFAULT_CACHE_CAPACITY))
    }

    pub fn stats(&self) -> EngineStats {
        let guard = self.cache.lock().unwrap();
        EngineStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: guard.len(),
            capacity: guard.capacity(),
        }
    }

    /// Cached decompose → schedule → featurize. Returns the shared analysis.
    pub fn analyze(&self, cfg: &KernelConfig, gpu: &GpuSpec) -> Arc<Analysis> {
        self.lookup(cfg, gpu).0
    }

    /// Like [`analyze`](Self::analyze) but also reports whether the result
    /// came from the cache (the coordinator metrics consume this).
    pub fn analyze_hit(&self, cfg: &KernelConfig, gpu: &GpuSpec) -> (Arc<Analysis>, bool) {
        let (a, _, hit) = self.lookup(cfg, gpu);
        (a, hit)
    }

    /// Core lookup. The config may be unfinalized: the cache is probed with
    /// a borrowed-key hash ([`key::probe_hash`]) over the raw config plus
    /// the GPU-resolved FA variant, so the **hit path performs no
    /// `KernelConfig` clone and no allocation** (attention's `batch` vec
    /// would heap-allocate on every request otherwise). Finalization — the
    /// one clone — happens only on a miss, where the fresh
    /// [`Decomposition`] is also returned so callers that need the task set
    /// (the oracle) avoid decomposing twice.
    fn lookup(
        &self,
        cfg: &KernelConfig,
        gpu: &GpuSpec,
    ) -> (Arc<Analysis>, Option<Decomposition>, bool) {
        self.lookup_with(cfg, gpu, false)
    }

    /// `already_finalized` skips the miss path's re-finalization when the
    /// caller holds a finalized config (make_sample) — the key is cloned
    /// directly instead of run through `finalize_for_gpu` a second time.
    fn lookup_with(
        &self,
        cfg: &KernelConfig,
        gpu: &GpuSpec,
        already_finalized: bool,
    ) -> (Arc<Analysis>, Option<Decomposition>, bool) {
        let gpu_fp = key::gpu_fingerprint(gpu);
        let fa3 = dataset::fa3_for(gpu);
        let hash = key::probe_hash(cfg, fa3, gpu_fp);
        if let Some(hit) =
            self.cache.lock().unwrap().get_matching(hash, |k| k.matches(cfg, fa3, gpu_fp))
        {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (hit, None, true);
        }

        // Compute outside the lock: parallel builders must not serialize on
        // the (cheap) map while doing the (expensive) analysis.
        let cfg = if already_finalized { cfg.clone() } else { finalize_for_gpu(cfg, gpu) };
        let decomp = cfg.decompose(gpu);
        let dist = schedule(&decomp, gpu);
        let features = FeatureSet::analyze(&decomp, &dist, gpu);
        let x = features.to_model_input(gpu);
        let (x_alt, alt_theory_sec) = crate::baselines::neusight::features(&decomp, gpu);
        let compute_roof =
            features.tensor.total_cycles.max(features.fma.total_cycles).max(features.xu.total_cycles);
        let analysis = Arc::new(Analysis {
            kind: cfg.kind(),
            x,
            x_alt,
            alt_theory_sec,
            compute_sec: compute_roof * gpu.cycle_sec(),
            mem_sec: features.mio.cycles_dram * gpu.cycle_sec(),
            features,
        });
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.cache
            .lock()
            .unwrap()
            .insert_hashed(hash, CacheKey::from_finalized(cfg, gpu_fp), analysis.clone());
        (analysis, Some(decomp), false)
    }

    /// Featurize a batch of launches with parallel fan-out. Results are in
    /// input order and bit-identical to serial [`analyze`](Self::analyze)
    /// calls.
    pub fn analyze_batch(
        &self,
        reqs: &[(KernelConfig, GpuSpec)],
        threads: usize,
    ) -> Vec<Arc<Analysis>> {
        par::par_map(reqs, threads, |_, (cfg, gpu)| self.analyze(cfg, gpu))
    }

    /// Analyze + oracle-profile one `(config, gpu, seed)` into a training
    /// [`Sample`]. The analytical half is cached; the oracle measurement is
    /// seeded and always runs.
    pub fn make_sample(&self, cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> Sample {
        let cfg = finalize_for_gpu(cfg, gpu);
        let (a, decomp, _) = self.lookup_with(&cfg, gpu, true);
        // Reuse the miss-path decomposition; on a hit only the oracle needs
        // the task set, so decompose for it alone.
        let decomp = decomp.unwrap_or_else(|| cfg.decompose(gpu));
        let o = oracle::measure_decomposed(cfg.kind(), &decomp, gpu, seed);
        // the Habitat baseline's reference-GPU roofs come from the same
        // cache, so a repeated launch costs only the two seeded oracle
        // measurements (target ground truth + reference wave-scaling base)
        let reference = crate::baselines::habitat::reference_gpu(gpu);
        let ref_a = self.analyze(&cfg, &reference);
        let habitat_sec = crate::baselines::habitat::predict_with_roofs(
            &cfg,
            &reference,
            seed,
            (a.compute_sec, a.mem_sec),
            (ref_a.compute_sec, ref_a.mem_sec),
        );
        Sample {
            kind: cfg.kind(),
            gpu: gpu.name.to_string(),
            seen: gpu.seen,
            x: a.x,
            theory_sec: a.features.theory_sec,
            latency_sec: o.latency_sec,
            roofline_sec: a.features.naive_roofline_sec,
            compute_sec: a.compute_sec,
            mem_sec: a.mem_sec,
            habitat_sec,
            x_alt: a.x_alt,
            alt_theory_sec: a.alt_theory_sec,
        }
    }

    /// Build a profiling dataset: `n_configs` sampled configs × every GPU,
    /// fanned out over `threads` workers. Row order and values are
    /// independent of the thread count (per-row seeds derive from the
    /// config index).
    pub fn build_dataset(
        &self,
        kind: KernelKind,
        gpus: &[GpuSpec],
        n_configs: usize,
        seed: u64,
        threads: usize,
    ) -> Vec<Sample> {
        let configs = dataset::sample_configs(kind, n_configs, seed);
        let per_cfg: Vec<Vec<Sample>> = par::par_map(&configs, threads, |idx, cfg| {
            let mut local = Vec::with_capacity(gpus.len());
            for gpu in gpus {
                // name hash: identically-specced GPUs (H100/H800) get
                // independent noise streams
                let h = gpu
                    .name
                    .bytes()
                    .fold(0u64, |a, b| a.wrapping_mul(131).wrapping_add(b as u64));
                let s = seed.wrapping_add((idx as u64) << 8).wrapping_add(h);
                local.push(self.make_sample(cfg, gpu, s));
            }
            local
        });
        per_cfg.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::gpu_by_name;
    use crate::kernels::DType;

    fn gemm(m: u32, n: u32, k: u32) -> KernelConfig {
        KernelConfig::Gemm { m, n, k, dtype: DType::Bf16 }
    }

    #[test]
    fn second_lookup_hits() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("A100").unwrap();
        let cfg = gemm(1024, 2048, 512);
        let (_, hit0) = engine.analyze_hit(&cfg, &gpu);
        let (_, hit1) = engine.analyze_hit(&cfg, &gpu);
        assert!(!hit0);
        assert!(hit1);
        let s = engine.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cached_analysis_is_bit_identical() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("H800").unwrap();
        let cfg = gemm(4096, 4096, 1024);
        let a = engine.analyze(&cfg, &gpu);
        let b = engine.analyze(&cfg, &gpu);
        assert_eq!(a.x, b.x);
        assert_eq!(a.theory_sec().to_bits(), b.theory_sec().to_bits());
        assert!(Arc::ptr_eq(&a, &b), "hit must return the shared analysis");
    }

    #[test]
    fn fa_variant_resolution_separates_keys() {
        // The same logical attention launch is FA2 on A100, FA3 on H800 —
        // the engine finalizes before keying, so both cache cleanly.
        let engine = PredictionEngine::new(64);
        let cfg = KernelConfig::Attention {
            batch: vec![(256, 256)],
            nh: 4,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: false,
        };
        let a100 = gpu_by_name("A100").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let a = engine.analyze(&cfg, &a100);
        let b = engine.analyze(&cfg, &h800);
        assert_ne!(a.x, b.x);
        assert_eq!(engine.stats().misses, 2);
        // looking the pre-finalized config up again still hits
        engine.analyze(&cfg, &h800);
        assert_eq!(engine.stats().hits, 1);
    }

    #[test]
    fn make_sample_matches_direct_path() {
        let engine = PredictionEngine::new(64);
        let gpu = gpu_by_name("A40").unwrap();
        let cfg = gemm(2048, 1024, 512);
        let via_engine = engine.make_sample(&cfg, &gpu, 42);
        // second call goes through the cache; the oracle part re-runs
        let cached = engine.make_sample(&cfg, &gpu, 42);
        assert_eq!(via_engine.x, cached.x);
        assert_eq!(via_engine.latency_sec.to_bits(), cached.latency_sec.to_bits());
        assert_eq!(via_engine.habitat_sec.to_bits(), cached.habitat_sec.to_bits());
    }
}
