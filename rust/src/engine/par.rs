//! Scoped-thread parallel map — the engine's fan-out primitive for dataset
//! generation and batch featurization.
//!
//! Hand-rolled on `std::thread::scope` because the offline vendor set
//! carries no `rayon`: workers pull indices from a shared atomic counter
//! (fine-grained work stealing, so skewed per-item cost — e.g. huge GEMM
//! grids next to tiny RMSNorms — cannot strand a thread), and results are
//! reassembled in input order, keeping callers deterministic regardless of
//! thread count.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Apply `f` to every item (with its index) across `threads` workers and
/// return the results in input order. Falls back to a serial loop for
/// degenerate sizes. Panics in `f` propagate to the caller.
pub fn par_map<T, U, F>(items: &[T], threads: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let next_ref = &next;
    let f_ref = &f;
    let mut parts: Vec<Vec<(usize, U)>> = Vec::with_capacity(threads);
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f_ref(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            parts.push(h.join().expect("par_map worker panicked"));
        }
    });

    let mut out: Vec<Option<U>> = (0..n).map(|_| None).collect();
    for part in parts {
        for (i, v) in part {
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|v| v.expect("par_map: every index computed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all() {
        let items: Vec<u64> = (0..1000).collect();
        let out = par_map(&items, 8, |i, v| {
            assert_eq!(i as u64, *v);
            v * 2
        });
        assert_eq!(out, items.iter().map(|v| v * 2).collect::<Vec<_>>());
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let items: Vec<u64> = (0..257).collect();
        let a = par_map(&items, 1, |i, v| v.wrapping_mul(i as u64 + 3));
        let b = par_map(&items, 7, |i, v| v.wrapping_mul(i as u64 + 3));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, 4, |_, v| *v).is_empty());
        assert_eq!(par_map(&[9u32], 4, |_, v| *v + 1), vec![10]);
    }
}
