//! Canonical cache key for the analysis cache: the (finalized) kernel
//! launch parameters **X** plus a fingerprint of the hardware vector **S**.
//!
//! `KernelConfig` is hashable directly (it is plain launch geometry — no
//! floats), so the key is exact: two launches collide only if they decompose
//! identically. `GpuSpec` carries `f64` throughput numbers, so it is folded
//! into a 64-bit fingerprint over the bit patterns of every field that the
//! decompose → schedule → featurize pipeline reads; two specs with any
//! differing parameter hash apart.

use crate::hw::GpuSpec;
use crate::kernels::KernelConfig;
use std::hash::{Hash, Hasher};

/// Key of one `(KernelConfig, GpuSpec)` analysis.
///
/// The config stored here must already be resolved by
/// `dataset::finalize_for_gpu` (FA2-vs-FA3 selection), which the engine
/// guarantees before lookup — otherwise the same logical launch would key
/// differently on Hopper and pre-Hopper parts.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    cfg: KernelConfig,
    gpu_fp: u64,
}

impl CacheKey {
    pub fn new(finalized_cfg: &KernelConfig, gpu: &GpuSpec) -> CacheKey {
        CacheKey { cfg: finalized_cfg.clone(), gpu_fp: gpu_fingerprint(gpu) }
    }
}

/// Deterministic 64-bit digest of the architectural parameter vector.
pub fn gpu_fingerprint(gpu: &GpuSpec) -> u64 {
    // SipHash with the default (zeroed) keys — stable within and across
    // processes, which keeps cache behavior reproducible.
    let mut h = std::collections::hash_map::DefaultHasher::new();
    gpu.name.hash(&mut h);
    gpu.arch.hash(&mut h);
    gpu.compute_capability.to_bits().hash(&mut h);
    gpu.num_sms.hash(&mut h);
    gpu.sm_clock_mhz.to_bits().hash(&mut h);
    gpu.tensor_ops_clk_sm.to_bits().hash(&mut h);
    gpu.fma_ops_clk_sm.to_bits().hash(&mut h);
    gpu.xu_ops_clk_sm.to_bits().hash(&mut h);
    gpu.dram_bw_gbs.to_bits().hash(&mut h);
    gpu.l2_bw_gbs.to_bits().hash(&mut h);
    gpu.smem_bw_byte_clk_sm.to_bits().hash(&mut h);
    gpu.smem_kb_sm.hash(&mut h);
    gpu.regfile_kb_sm.hash(&mut h);
    gpu.l2_mb.to_bits().hash(&mut h);
    gpu.max_warps_per_sm.hash(&mut h);
    gpu.max_ctas_per_sm.hash(&mut h);
    gpu.fp8_tensor_mult.to_bits().hash(&mut h);
    gpu.interconnect_gbs.to_bits().hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{all_gpus, gpu_by_name};
    use crate::kernels::DType;

    #[test]
    fn fingerprints_distinguish_all_gpus() {
        let fps: Vec<u64> = all_gpus().iter().map(gpu_fingerprint).collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "GPU fingerprints must be unique");
            }
        }
    }

    #[test]
    fn fingerprint_is_stable() {
        let a100 = gpu_by_name("A100").unwrap();
        assert_eq!(gpu_fingerprint(&a100), gpu_fingerprint(&a100.clone()));
    }

    #[test]
    fn fingerprint_tracks_parameter_changes() {
        let mut h20 = gpu_by_name("H20").unwrap();
        let base = gpu_fingerprint(&h20);
        h20.dram_bw_gbs += 1.0;
        assert_ne!(gpu_fingerprint(&h20), base);
    }

    #[test]
    fn keys_separate_configs_and_gpus() {
        let a100 = gpu_by_name("A100").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let c1 = KernelConfig::Gemm { m: 128, n: 128, k: 128, dtype: DType::Bf16 };
        let c2 = KernelConfig::Gemm { m: 128, n: 128, k: 256, dtype: DType::Bf16 };
        assert_eq!(CacheKey::new(&c1, &a100), CacheKey::new(&c1, &a100));
        assert_ne!(CacheKey::new(&c1, &a100), CacheKey::new(&c2, &a100));
        assert_ne!(CacheKey::new(&c1, &a100), CacheKey::new(&c1, &h800));
    }
}
