//! Canonical cache key for the analysis cache: the (finalized) kernel
//! launch parameters **X** plus a fingerprint of the hardware vector **S**.
//!
//! `KernelConfig` is hashable directly (it is plain launch geometry — no
//! floats), so the key is exact: two launches collide only if they decompose
//! identically. `GpuSpec` carries `f64` throughput numbers, so it is folded
//! into a 64-bit fingerprint over the bit patterns of every field that the
//! decompose → schedule → featurize pipeline reads; two specs with any
//! differing parameter hash apart.
//!
//! Lookups are allocation-free: [`probe_hash`] digests a *borrowed* config
//! together with the GPU-resolved FA variant, so the hot path neither
//! clones the config (attention's `batch` vec heap-allocates) nor runs
//! `finalize_for_gpu`; the owned [`CacheKey`] is only built on a miss.
//! The same digest doubles as the engine's shard selector (its low bits
//! pick the cache shard), so a probe touches exactly one shard mutex.

use crate::hw::GpuSpec;
use crate::kernels::KernelConfig;
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

/// Key of one `(KernelConfig, GpuSpec)` analysis.
///
/// The config stored here must already be resolved by
/// `dataset::finalize_for_gpu` (FA2-vs-FA3 selection), which the engine
/// guarantees before insertion — otherwise the same logical launch would
/// key differently on Hopper and pre-Hopper parts.
#[derive(Clone, PartialEq, Eq, Hash)]
pub struct CacheKey {
    cfg: KernelConfig,
    gpu_fp: u64,
}

impl CacheKey {
    pub fn new(finalized_cfg: &KernelConfig, gpu: &GpuSpec) -> CacheKey {
        CacheKey { cfg: finalized_cfg.clone(), gpu_fp: gpu_fingerprint(gpu) }
    }

    /// Build a key from an already-owned finalized config (the engine's
    /// miss path — the config is moved, not cloned again).
    pub fn from_finalized(cfg: KernelConfig, gpu_fp: u64) -> CacheKey {
        CacheKey { cfg, gpu_fp }
    }

    /// The hash this key is stored under — [`probe_hash`] over its own
    /// (finalized) parameters, so borrowed probes and stored keys agree.
    pub fn stored_hash(&self) -> u64 {
        let fa3 = matches!(self.cfg, KernelConfig::Attention { fa3: true, .. });
        probe_hash(&self.cfg, fa3, self.gpu_fp)
    }

    /// Does this stored (finalized) key describe the borrowed launch
    /// `probe` on the GPU with fingerprint `gpu_fp`? `fa3` is the
    /// GPU-resolved FA variant; the probe's own `fa3` field is ignored,
    /// mirroring what `finalize_for_gpu` would overwrite.
    pub fn matches(&self, probe: &KernelConfig, fa3: bool, gpu_fp: u64) -> bool {
        if self.gpu_fp != gpu_fp {
            return false;
        }
        match (&self.cfg, probe) {
            (
                KernelConfig::Attention {
                    batch: b1,
                    nh: nh1,
                    nkv: nkv1,
                    hd: hd1,
                    causal: c1,
                    fa3: f1,
                },
                KernelConfig::Attention {
                    batch: b2,
                    nh: nh2,
                    nkv: nkv2,
                    hd: hd2,
                    causal: c2,
                    fa3: _,
                },
            ) => {
                *f1 == fa3
                    && nh1 == nh2
                    && nkv1 == nkv2
                    && hd1 == hd2
                    && c1 == c2
                    && b1 == b2
            }
            (stored, probe) => stored == probe,
        }
    }
}

/// Stable 64-bit digest of a borrowed `(config, gpu fingerprint)` probe.
/// For Attention configs the GPU-resolved `fa3` replaces the config's own
/// flag (unfinalized and finalized forms of the same launch hash alike);
/// other kinds ignore `fa3`. No allocation, no clone.
pub fn probe_hash(cfg: &KernelConfig, fa3: bool, gpu_fp: u64) -> u64 {
    let mut h = DefaultHasher::new();
    gpu_fp.hash(&mut h);
    match cfg {
        KernelConfig::Gemm { m, n, k, dtype } => {
            0u8.hash(&mut h);
            (m, n, k, dtype).hash(&mut h);
        }
        KernelConfig::ScaledMm { m, n, k } => {
            1u8.hash(&mut h);
            (m, n, k).hash(&mut h);
        }
        KernelConfig::Attention { batch, nh, nkv, hd, causal, fa3: _ } => {
            2u8.hash(&mut h);
            (batch, nh, nkv, hd, causal, fa3).hash(&mut h);
        }
        KernelConfig::RmsNorm { seq, dim } => {
            3u8.hash(&mut h);
            (seq, dim).hash(&mut h);
        }
        KernelConfig::SiluMul { seq, dim } => {
            4u8.hash(&mut h);
            (seq, dim).hash(&mut h);
        }
        KernelConfig::FusedMoe { m, e, topk, h: hid, n, expert_tokens, cfg: moe } => {
            5u8.hash(&mut h);
            (m, e, topk, hid, n, expert_tokens, moe).hash(&mut h);
        }
    }
    h.finish()
}

/// Deterministic 64-bit digest of the architectural parameter vector.
pub fn gpu_fingerprint(gpu: &GpuSpec) -> u64 {
    // SipHash with the default (zeroed) keys — stable within and across
    // processes, which keeps cache behavior reproducible.
    let mut h = DefaultHasher::new();
    gpu.name.hash(&mut h);
    gpu.arch.hash(&mut h);
    gpu.compute_capability.to_bits().hash(&mut h);
    gpu.num_sms.hash(&mut h);
    gpu.sm_clock_mhz.to_bits().hash(&mut h);
    gpu.tensor_ops_clk_sm.to_bits().hash(&mut h);
    gpu.fma_ops_clk_sm.to_bits().hash(&mut h);
    gpu.xu_ops_clk_sm.to_bits().hash(&mut h);
    gpu.dram_bw_gbs.to_bits().hash(&mut h);
    gpu.l2_bw_gbs.to_bits().hash(&mut h);
    gpu.smem_bw_byte_clk_sm.to_bits().hash(&mut h);
    gpu.smem_kb_sm.hash(&mut h);
    gpu.regfile_kb_sm.hash(&mut h);
    gpu.l2_mb.to_bits().hash(&mut h);
    gpu.max_warps_per_sm.hash(&mut h);
    gpu.max_ctas_per_sm.hash(&mut h);
    gpu.fp8_tensor_mult.to_bits().hash(&mut h);
    gpu.interconnect_gbs.to_bits().hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{fa3_for, finalize_for_gpu};
    use crate::hw::{all_gpus, gpu_by_name};
    use crate::kernels::DType;

    #[test]
    fn fingerprints_distinguish_all_gpus() {
        let fps: Vec<u64> = all_gpus().iter().map(gpu_fingerprint).collect();
        for i in 0..fps.len() {
            for j in (i + 1)..fps.len() {
                assert_ne!(fps[i], fps[j], "GPU fingerprints must be unique");
            }
        }
    }

    #[test]
    fn fingerprint_is_stable() {
        let a100 = gpu_by_name("A100").unwrap();
        assert_eq!(gpu_fingerprint(&a100), gpu_fingerprint(&a100.clone()));
    }

    #[test]
    fn fingerprint_tracks_parameter_changes() {
        let mut h20 = gpu_by_name("H20").unwrap();
        let base = gpu_fingerprint(&h20);
        h20.dram_bw_gbs += 1.0;
        assert_ne!(gpu_fingerprint(&h20), base);
    }

    #[test]
    fn keys_separate_configs_and_gpus() {
        let a100 = gpu_by_name("A100").unwrap();
        let h800 = gpu_by_name("H800").unwrap();
        let c1 = KernelConfig::Gemm { m: 128, n: 128, k: 128, dtype: DType::Bf16 };
        let c2 = KernelConfig::Gemm { m: 128, n: 128, k: 256, dtype: DType::Bf16 };
        assert_eq!(CacheKey::new(&c1, &a100), CacheKey::new(&c1, &a100));
        assert_ne!(CacheKey::new(&c1, &a100), CacheKey::new(&c2, &a100));
        assert_ne!(CacheKey::new(&c1, &a100), CacheKey::new(&c1, &h800));
    }

    #[test]
    fn borrowed_probe_agrees_with_stored_key() {
        // an unfinalized attention probe must hash and match exactly like
        // the finalized stored key, on both FA2 and FA3 hardware
        let probe = KernelConfig::Attention {
            batch: vec![(256, 512), (64, 64)],
            nh: 8,
            nkv: 2,
            hd: 128,
            causal: true,
            fa3: false, // pre-finalization value; must be irrelevant
        };
        for gpu_name in ["A100", "H800"] {
            let gpu = gpu_by_name(gpu_name).unwrap();
            let fp = gpu_fingerprint(&gpu);
            let fa3 = fa3_for(&gpu);
            let stored = CacheKey::new(&finalize_for_gpu(&probe, &gpu), &gpu);
            assert_eq!(stored.stored_hash(), probe_hash(&probe, fa3, fp), "{gpu_name}");
            assert!(stored.matches(&probe, fa3, fp), "{gpu_name}");
            // flipping the resolved variant must miss
            assert!(!stored.matches(&probe, !fa3, fp), "{gpu_name}");
        }
    }

    #[test]
    fn probe_hash_separates_kinds_and_params() {
        let gpu = gpu_by_name("A100").unwrap();
        let fp = gpu_fingerprint(&gpu);
        let gemm = KernelConfig::Gemm { m: 64, n: 64, k: 64, dtype: DType::Bf16 };
        let mm = KernelConfig::ScaledMm { m: 64, n: 64, k: 64 };
        let rms = KernelConfig::RmsNorm { seq: 64, dim: 64 };
        let silu = KernelConfig::SiluMul { seq: 64, dim: 64 };
        let hashes: Vec<u64> =
            [&gemm, &mm, &rms, &silu].iter().map(|&c| probe_hash(c, false, fp)).collect();
        for i in 0..hashes.len() {
            for j in (i + 1)..hashes.len() {
                assert_ne!(hashes[i], hashes[j]);
            }
        }
        // and a non-attention kind never matches an attention key
        let stored = CacheKey::new(&gemm, &gpu);
        assert!(stored.matches(&gemm, true, fp), "fa3 is ignored for non-attention");
        assert!(!stored.matches(&mm, false, fp));
    }
}
