//! Bounded LRU for analysis results, probeable by precomputed hash.
//!
//! The map is bucketed by a caller-supplied 64-bit hash with full-key
//! equality inside the bucket, so lookups can probe with a *borrowed* key
//! representation (`get_matching(hash, |k| …)`) — the hit path builds no
//! owned key and allocates nothing. `get`/`insert` are O(1); when the map
//! is full, eviction drops the least-recently-used eighth of the entries in
//! one O(n) sweep, amortizing to O(1) amortized-ish per insert. Values are
//! handed out as `Arc` clones so hits never copy the (large) analysis.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Arc;

struct Entry<V> {
    value: Arc<V>,
    last_used: u64,
}

pub struct LruCache<K, V> {
    /// hash → entries whose key digests to it (collision list; almost
    /// always length 1).
    buckets: HashMap<u64, Vec<(K, Entry<V>)>>,
    len: usize,
    capacity: usize,
    tick: u64,
}

impl<K: Eq, V> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        LruCache { buckets: HashMap::new(), len: 0, capacity: capacity.max(1), tick: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Probe with a precomputed hash and an equality closure over the
    /// stored key — the allocation-free lookup path.
    pub fn get_matching(&mut self, hash: u64, matches: impl Fn(&K) -> bool) -> Option<Arc<V>> {
        self.tick += 1;
        let tick = self.tick;
        let bucket = self.buckets.get_mut(&hash)?;
        bucket.iter_mut().find(|(k, _)| matches(k)).map(|(_, e)| {
            e.last_used = tick;
            e.value.clone()
        })
    }

    /// Insert under a precomputed hash (which must equal the hash future
    /// probes use for this key). Replaces the value if the key exists.
    pub fn insert_hashed(&mut self, hash: u64, key: K, value: Arc<V>) {
        self.tick += 1;
        let replacing =
            self.buckets.get(&hash).is_some_and(|b| b.iter().any(|(k, _)| *k == key));
        if self.len >= self.capacity && !replacing {
            self.evict_lru_batch();
        }
        let tick = self.tick;
        let bucket = self.buckets.entry(hash).or_default();
        if let Some((_, e)) = bucket.iter_mut().find(|(k, _)| *k == key) {
            e.value = value;
            e.last_used = tick;
        } else {
            bucket.push((key, Entry { value, last_used: tick }));
            self.len += 1;
        }
    }

    /// Drop the stalest ~1/8 of entries (at least one). Recency stamps are
    /// unique, so selecting the drop_n-th smallest stamp and retaining
    /// everything newer evicts exactly drop_n entries — O(n), no key clones,
    /// no full sort (this runs under one engine cache-shard lock).
    fn evict_lru_batch(&mut self) {
        let drop_n = (self.capacity / 8).max(1).min(self.len);
        if drop_n == 0 {
            return;
        }
        let mut stamps: Vec<u64> = self
            .buckets
            .values()
            .flat_map(|b| b.iter().map(|(_, e)| e.last_used))
            .collect();
        let (_, &mut threshold, _) = stamps.select_nth_unstable(drop_n - 1);
        let mut removed = 0usize;
        self.buckets.retain(|_, bucket| {
            bucket.retain(|(_, e)| {
                let keep = e.last_used > threshold;
                if !keep {
                    removed += 1;
                }
                keep
            });
            !bucket.is_empty()
        });
        self.len -= removed;
    }
}

impl<K: Eq + Hash, V> LruCache<K, V> {
    fn hash_of(key: &K) -> u64 {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        h.finish()
    }

    pub fn get(&mut self, key: &K) -> Option<Arc<V>> {
        self.get_matching(Self::hash_of(key), |k| k == key)
    }

    pub fn insert(&mut self, key: K, value: Arc<V>) {
        self.insert_hashed(Self::hash_of(&key), key, value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss() {
        let mut c: LruCache<u32, u32> = LruCache::new(8);
        assert!(c.get(&1).is_none());
        c.insert(1, Arc::new(10));
        assert_eq!(*c.get(&1).unwrap(), 10);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_prefers_stale_entries() {
        let mut c: LruCache<u32, u32> = LruCache::new(16);
        for i in 0..16 {
            c.insert(i, Arc::new(i));
        }
        // refresh 0..8, then overflow: the stale half should be the victims
        for i in 0..8 {
            assert!(c.get(&i).is_some());
        }
        for i in 16..20 {
            c.insert(i, Arc::new(i));
        }
        assert!(c.len() <= 18);
        for i in 0..8 {
            assert!(c.get(&i).is_some(), "recently-used entry {i} evicted");
        }
    }

    #[test]
    fn capacity_is_bounded() {
        let mut c: LruCache<u32, u32> = LruCache::new(32);
        for i in 0..10_000 {
            c.insert(i, Arc::new(i));
        }
        assert!(c.len() <= 32, "len {} exceeds capacity", c.len());
    }

    #[test]
    fn reinsert_updates_value() {
        let mut c: LruCache<u32, u32> = LruCache::new(4);
        c.insert(1, Arc::new(10));
        c.insert(1, Arc::new(11));
        assert_eq!(*c.get(&1).unwrap(), 11);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn hashed_probe_matches_and_collisions_separate() {
        // two distinct keys forced into the same bucket: equality must
        // disambiguate, and len must count both
        let mut c: LruCache<u32, u32> = LruCache::new(8);
        c.insert_hashed(42, 1, Arc::new(10));
        c.insert_hashed(42, 2, Arc::new(20));
        assert_eq!(c.len(), 2);
        assert_eq!(*c.get_matching(42, |k| *k == 1).unwrap(), 10);
        assert_eq!(*c.get_matching(42, |k| *k == 2).unwrap(), 20);
        assert!(c.get_matching(42, |k| *k == 3).is_none());
        assert!(c.get_matching(7, |_| true).is_none());
        // replace within the collision bucket
        c.insert_hashed(42, 2, Arc::new(21));
        assert_eq!(c.len(), 2);
        assert_eq!(*c.get_matching(42, |k| *k == 2).unwrap(), 21);
    }
}
