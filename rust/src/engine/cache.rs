//! Bounded LRU for analysis results.
//!
//! A `HashMap` with per-entry recency stamps: `get`/`insert` are O(1); when
//! the map is full, eviction drops the least-recently-used eighth of the
//! entries in one O(n log n) sweep, amortizing to O(log n) per insert. Values
//! are handed out as `Arc` clones so hits never copy the (large) analysis.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::Arc;

struct Entry<V> {
    value: Arc<V>,
    last_used: u64,
}

pub struct LruCache<K, V> {
    map: HashMap<K, Entry<V>>,
    capacity: usize,
    tick: u64,
}

impl<K: Eq + Hash, V> LruCache<K, V> {
    pub fn new(capacity: usize) -> LruCache<K, V> {
        LruCache { map: HashMap::new(), capacity: capacity.max(1), tick: 0 }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn get(&mut self, key: &K) -> Option<Arc<V>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|e| {
            e.last_used = tick;
            e.value.clone()
        })
    }

    pub fn insert(&mut self, key: K, value: Arc<V>) {
        self.tick += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            self.evict_lru_batch();
        }
        let tick = self.tick;
        self.map.insert(key, Entry { value, last_used: tick });
    }

    /// Drop the stalest ~1/8 of entries (at least one). Recency stamps are
    /// unique, so selecting the drop_n-th smallest stamp and retaining
    /// everything newer evicts exactly drop_n entries — O(n), no key clones,
    /// no full sort (this runs under the engine's shared cache lock).
    fn evict_lru_batch(&mut self) {
        let drop_n = (self.capacity / 8).max(1).min(self.map.len());
        if drop_n == 0 {
            return;
        }
        let mut stamps: Vec<u64> = self.map.values().map(|e| e.last_used).collect();
        let (_, &mut threshold, _) = stamps.select_nth_unstable(drop_n - 1);
        self.map.retain(|_, e| e.last_used > threshold);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_and_miss() {
        let mut c: LruCache<u32, u32> = LruCache::new(8);
        assert!(c.get(&1).is_none());
        c.insert(1, Arc::new(10));
        assert_eq!(*c.get(&1).unwrap(), 10);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn eviction_prefers_stale_entries() {
        let mut c: LruCache<u32, u32> = LruCache::new(16);
        for i in 0..16 {
            c.insert(i, Arc::new(i));
        }
        // refresh 0..8, then overflow: the stale half should be the victims
        for i in 0..8 {
            assert!(c.get(&i).is_some());
        }
        for i in 16..20 {
            c.insert(i, Arc::new(i));
        }
        assert!(c.len() <= 18);
        for i in 0..8 {
            assert!(c.get(&i).is_some(), "recently-used entry {i} evicted");
        }
    }

    #[test]
    fn capacity_is_bounded() {
        let mut c: LruCache<u32, u32> = LruCache::new(32);
        for i in 0..10_000 {
            c.insert(i, Arc::new(i));
        }
        assert!(c.len() <= 32, "len {} exceeds capacity", c.len());
    }

    #[test]
    fn reinsert_updates_value() {
        let mut c: LruCache<u32, u32> = LruCache::new(4);
        c.insert(1, Arc::new(10));
        c.insert(1, Arc::new(11));
        assert_eq!(*c.get(&1).unwrap(), 11);
        assert_eq!(c.len(), 1);
    }
}
