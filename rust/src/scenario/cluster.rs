//! **Scenario v2 — deterministic continuous-batching cluster simulation.**
//!
//! Where Scenario v1 walks a static phase schedule once, this module runs
//! a discrete-event simulation of a serving *cluster*: requests arrive
//! over virtual time (an explicit trace, or a seeded Poisson/uniform
//! process), a router spreads them over N identical replicas
//! ([`RoutePolicy`]), and each replica runs prefill-prioritized continuous
//! batching — a step is either a **prefill** over every admissible waiting
//! request or a **decode** appending one token to every running request.
//! Admission is gated by two knobs: `max_batch` running requests and a
//! per-replica KV budget (`kv_capacity_tokens`), with a request's full
//! `input + output` token footprint reserved up front so a running batch
//! can never overflow (no preemption modeling). The waiting queue is
//! strict FIFO — a head-of-line request that does not fit blocks later
//! ones (fairness over packing), and because compilation rejects any
//! request that cannot fit an *empty* replica, every request eventually
//! completes.
//!
//! Step service times come from the predictor path
//! ([`super::eval::predict_stream_cost`] →
//! [`crate::api::predict_batch_view_on`]) — no oracle sampling enters the
//! virtual clock, so a timeline is a pure function of
//! `(spec, models, comm)`. Step shapes repeat heavily under continuous
//! batching; costs are memoized per shape with the KV length quantized to
//! `kv_quant` tokens (lookup-only `HashMap`, never iterated). The event
//! loop itself is serial and tie-breaks simultaneous events by push order
//! ([`super::event::EventQueue`]); `threads` only fans out the batched
//! prediction calls inside a step, which are pinned bit-identical across
//! thread counts. Reports are therefore **byte-identical at any
//! `--threads` count and across runs**.
//!
//! Per-request latencies (TTFT, TPOT, queueing delay) aggregate into
//! fixed-bin mergeable [`LogHistogram`]s → bin-resolution p50/p95/p99,
//! while SLO attainment is computed exactly per request at completion.

use super::compiler::{self, MAX_BATCH};
use super::event::EventQueue;
use super::{eval, ScenarioError};
use crate::e2e::comm::CommModel;
use crate::e2e::llm::LlmConfig;
use crate::e2e::predict::{ModelSet, HOST_GAP_SEC};
use crate::e2e::trace;
use crate::e2e::workload::{sample_batch, Request, WorkloadKind};
use crate::hw::GpuSpec;
use crate::util::rng::{splitmix64, Rng};
use crate::util::stats::LogHistogram;
use std::collections::{HashMap, VecDeque};

/// Most replicas a cluster spec may ask for.
pub const MAX_REPLICAS: u32 = 64;
/// Most requests a cluster spec may offer (same wire-scale reasoning as
/// [`MAX_BATCH`]: one JSONL line must not be able to take the process
/// down).
pub const MAX_CLUSTER_REQUESTS: usize = MAX_BATCH;
/// Cap on the total token footprint (inputs + outputs) of the offered
/// load. The event loop walks every decode step, so unlike the v1
/// checkpoint integrator its work is proportional to generated tokens —
/// this bounds a hostile line's compute, not just its allocation.
pub const MAX_CLUSTER_TOKENS: u64 = 1 << 22;

/// One request offered to the cluster: arrival instant, prompt/generation
/// lengths, and a session key (the input of the affinity router).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterRequest {
    pub arrival_sec: f64,
    pub input_len: u32,
    pub output_len: u32,
    pub session: u64,
}

/// How requests arrive over virtual time.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalSpec {
    /// Deterministic replay of an explicit arrival trace.
    Trace(Vec<ClusterRequest>),
    /// Seeded Poisson process: exponential inter-arrival gaps at
    /// `rate_rps` requests/sec, lengths sampled from `kind`.
    Poisson { rate_rps: f64, n: usize, kind: WorkloadKind },
    /// Seeded uniform process: arrivals a fixed `gap_sec` apart, lengths
    /// sampled from `kind`.
    Uniform { gap_sec: f64, n: usize, kind: WorkloadKind },
}

/// Which replica an arriving request queues on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Arrival order modulo replica count.
    RoundRobin,
    /// Fewest waiting + in-step + running requests; ties break to the
    /// lowest replica index.
    LeastLoaded,
    /// `splitmix64(session) % replicas` — one session always lands on the
    /// same replica (KV locality), at the cost of skew under hot sessions.
    SessionAffinity,
}

impl RoutePolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastLoaded => "least_loaded",
            RoutePolicy::SessionAffinity => "session_affinity",
        }
    }

    pub fn from_name(s: &str) -> Option<RoutePolicy> {
        match s {
            "round_robin" => Some(RoutePolicy::RoundRobin),
            "least_loaded" => Some(RoutePolicy::LeastLoaded),
            "session_affinity" => Some(RoutePolicy::SessionAffinity),
            _ => None,
        }
    }

    /// Parse with the closed-taxonomy error — one owner of the message,
    /// shared by the wire codec and the CLI.
    pub fn parse(s: &str) -> Result<RoutePolicy, ScenarioError> {
        RoutePolicy::from_name(s).ok_or_else(|| {
            ScenarioError::InvalidCluster(format!(
                "unknown policy {s:?} (round_robin|least_loaded|session_affinity)"
            ))
        })
    }
}

/// The declarative description of one cluster scenario (Scenario v2).
/// Built fluently like [`super::ScenarioSpec`]:
///
/// ```ignore
/// let spec = ClusterSpec::new("Llama3.1-8B", "A100")
///     .replicas(2)
///     .policy(RoutePolicy::LeastLoaded)
///     .arrivals(ArrivalSpec::Poisson { rate_rps: 8.0, n: 32, kind: WorkloadKind::Arxiv })
///     .seed(7);
/// let report = Simulator::degraded().simulate_cluster(&spec)?;
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    pub model: String,
    pub gpu: String,
    /// Tensor/pipeline parallelism *within* each replica.
    pub tp: u32,
    pub pp: u32,
    pub replicas: u32,
    pub policy: RoutePolicy,
    pub arrivals: ArrivalSpec,
    /// Continuous-batching admission: most concurrently running requests
    /// per replica.
    pub max_batch: u32,
    /// Per-replica KV budget, tokens. Admission reserves a request's full
    /// `input + output` footprint up front.
    pub kv_capacity_tokens: u64,
    /// KV-length quantum for the step-cost memo: decode service times are
    /// evaluated at KV lengths rounded up to this multiple, so `T` steps
    /// cost roughly `T / kv_quant` distinct predictions. 1 = exact.
    pub kv_quant: u32,
    /// Seeds arrival generation (gap sampling, request lengths, sessions).
    pub seed: u64,
    /// Per-kernel host launch gap inside every step.
    pub host_gap_sec: f64,
    /// SLO threshold on time-to-first-token, seconds.
    pub slo_ttft_sec: f64,
    /// SLO threshold on time-per-output-token, seconds.
    pub slo_tpot_sec: f64,
}

impl ClusterSpec {
    pub fn new(model: impl Into<String>, gpu: impl Into<String>) -> ClusterSpec {
        ClusterSpec {
            model: model.into(),
            gpu: gpu.into(),
            tp: 1,
            pp: 1,
            replicas: 1,
            policy: RoutePolicy::RoundRobin,
            arrivals: ArrivalSpec::Poisson { rate_rps: 4.0, n: 16, kind: WorkloadKind::Arxiv },
            max_batch: 16,
            kv_capacity_tokens: 262_144,
            kv_quant: 16,
            seed: 0,
            host_gap_sec: HOST_GAP_SEC,
            slo_ttft_sec: 2.0,
            slo_tpot_sec: 0.2,
        }
    }

    pub fn tp(mut self, tp: u32) -> Self {
        self.tp = tp;
        self
    }

    pub fn pp(mut self, pp: u32) -> Self {
        self.pp = pp;
        self
    }

    pub fn replicas(mut self, replicas: u32) -> Self {
        self.replicas = replicas;
        self
    }

    pub fn policy(mut self, policy: RoutePolicy) -> Self {
        self.policy = policy;
        self
    }

    pub fn arrivals(mut self, arrivals: ArrivalSpec) -> Self {
        self.arrivals = arrivals;
        self
    }

    pub fn max_batch(mut self, max_batch: u32) -> Self {
        self.max_batch = max_batch;
        self
    }

    pub fn kv_capacity_tokens(mut self, kv: u64) -> Self {
        self.kv_capacity_tokens = kv;
        self
    }

    pub fn kv_quant(mut self, kv_quant: u32) -> Self {
        self.kv_quant = kv_quant;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn host_gap_sec(mut self, host_gap_sec: f64) -> Self {
        self.host_gap_sec = host_gap_sec;
        self
    }

    pub fn slo(mut self, ttft_sec: f64, tpot_sec: f64) -> Self {
        self.slo_ttft_sec = ttft_sec;
        self.slo_tpot_sec = tpot_sec;
        self
    }
}

/// A validated cluster scenario: resolved model + GPU and the materialized
/// arrival-sorted request list. Everything the event loop needs.
#[derive(Debug, Clone)]
pub struct CompiledCluster {
    pub llm: LlmConfig,
    pub gpu: GpuSpec,
    pub tp: u32,
    pub pp: u32,
    pub replicas: u32,
    pub policy: RoutePolicy,
    pub requests: Vec<ClusterRequest>,
    pub max_batch: u32,
    pub kv_capacity_tokens: u64,
    pub kv_quant: u32,
    pub seed: u64,
    pub host_gap_sec: f64,
    pub slo_ttft_sec: f64,
    pub slo_tpot_sec: f64,
}

fn materialize_arrivals(spec: &ClusterSpec) -> Result<Vec<ClusterRequest>, ScenarioError> {
    let bad = |why: String| Err(ScenarioError::InvalidCluster(why));
    let bad_wl = |why: String| Err(ScenarioError::InvalidWorkload(why));
    let check_n = |n: usize| -> Result<(), ScenarioError> {
        if n == 0 {
            return bad_wl("request mix must be non-empty".to_string());
        }
        if n > MAX_CLUSTER_REQUESTS {
            return bad_wl(format!("{n} requests exceed the cap of {MAX_CLUSTER_REQUESTS}"));
        }
        Ok(())
    };
    let mut reqs = match &spec.arrivals {
        ArrivalSpec::Trace(t) => {
            check_n(t.len())?;
            for (i, r) in t.iter().enumerate() {
                if !r.arrival_sec.is_finite() || r.arrival_sec < 0.0 {
                    return bad(format!(
                        "request {i} needs a finite arrival_sec >= 0, got {}",
                        r.arrival_sec
                    ));
                }
            }
            t.clone()
        }
        ArrivalSpec::Poisson { rate_rps, n, kind } => {
            check_n(*n)?;
            if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                return bad(format!("poisson arrivals need rate_rps > 0, got {rate_rps}"));
            }
            generated_arrivals(spec, *n, *kind, |rng| rng.exponential(*rate_rps))
        }
        ArrivalSpec::Uniform { gap_sec, n, kind } => {
            check_n(*n)?;
            if !gap_sec.is_finite() || *gap_sec < 0.0 {
                return bad(format!("uniform arrivals need gap_sec >= 0, got {gap_sec}"));
            }
            generated_arrivals(spec, *n, *kind, |_| *gap_sec)
        }
    };
    let mut total_tokens = 0u64;
    for (i, r) in reqs.iter().enumerate() {
        compiler::validate_request_lens(i, r.input_len, r.output_len)?;
        total_tokens += r.input_len as u64 + r.output_len as u64;
    }
    if total_tokens > MAX_CLUSTER_TOKENS {
        return bad(format!(
            "offered load of {total_tokens} tokens exceeds the cap of {MAX_CLUSTER_TOKENS}"
        ));
    }
    // stable sort: same-instant arrivals keep their trace order, so the
    // event timeline is fully determined by the spec
    reqs.sort_by(|a, b| a.arrival_sec.total_cmp(&b.arrival_sec));
    Ok(reqs)
}

/// Generate `n` seeded arrivals: lengths from the workload sampler, gaps
/// from `gap_of`, sessions from a pool of ~n/4 ids. Three forked streams
/// keep the three draws independent of each other's draw counts.
fn generated_arrivals(
    spec: &ClusterSpec,
    n: usize,
    kind: WorkloadKind,
    mut gap_of: impl FnMut(&mut Rng) -> f64,
) -> Vec<ClusterRequest> {
    let base = Rng::new(spec.seed);
    let mut len_rng = base.fork(1);
    let mut gap_rng = base.fork(2);
    let mut ses_rng = base.fork(3);
    let lens = sample_batch(kind, n, &mut len_rng);
    let pool = (n as u64 / 4).max(1);
    let mut t = 0.0;
    lens.into_iter()
        .map(|r| {
            t += gap_of(&mut gap_rng);
            ClusterRequest {
                arrival_sec: t,
                input_len: r.input_len,
                output_len: r.output_len,
                session: ses_rng.range_u64(0, pool - 1),
            }
        })
        .collect()
}

/// Validate a [`ClusterSpec`] and materialize its arrivals. Validation
/// order is part of the contract: model, GPU, parallelism, host gap,
/// cluster knobs, arrivals, per-request fit.
pub fn compile_cluster(spec: &ClusterSpec) -> Result<CompiledCluster, ScenarioError> {
    let (llm, gpu) = compiler::resolve_model_gpu(&spec.model, &spec.gpu)?;
    compiler::validate_parallelism(&llm, spec.tp, spec.pp)?;
    if !spec.host_gap_sec.is_finite() || spec.host_gap_sec < 0.0 {
        return Err(ScenarioError::MalformedSpec(format!(
            "host_gap_sec must be finite and >= 0, got {}",
            spec.host_gap_sec
        )));
    }
    let bad = |why: String| Err(ScenarioError::InvalidCluster(why));
    if spec.replicas == 0 || spec.replicas > MAX_REPLICAS {
        return bad(format!("replicas must be in 1..={MAX_REPLICAS}, got {}", spec.replicas));
    }
    if spec.max_batch == 0 || spec.max_batch as usize > MAX_BATCH {
        return bad(format!("max_batch must be in 1..={MAX_BATCH}, got {}", spec.max_batch));
    }
    if spec.kv_capacity_tokens == 0 {
        return bad("kv_capacity_tokens must be >= 1".to_string());
    }
    if spec.kv_quant == 0 {
        return bad("kv_quant must be >= 1".to_string());
    }
    for (label, v) in [("slo_ttft_sec", spec.slo_ttft_sec), ("slo_tpot_sec", spec.slo_tpot_sec)] {
        if !v.is_finite() || v <= 0.0 {
            return bad(format!("{label} must be finite and > 0, got {v}"));
        }
    }
    let requests = materialize_arrivals(spec)?;
    // every request must fit an empty replica, or it would wait forever
    // behind the strict-FIFO admission rule
    for (i, r) in requests.iter().enumerate() {
        let need = r.input_len as u64 + r.output_len as u64;
        if need > spec.kv_capacity_tokens {
            return bad(format!(
                "request {i} needs {need} KV tokens but kv_capacity_tokens is {}",
                spec.kv_capacity_tokens
            ));
        }
    }
    Ok(CompiledCluster {
        llm,
        gpu,
        tp: spec.tp,
        pp: spec.pp,
        replicas: spec.replicas,
        policy: spec.policy,
        requests,
        max_batch: spec.max_batch,
        kv_capacity_tokens: spec.kv_capacity_tokens,
        kv_quant: spec.kv_quant,
        seed: spec.seed,
        host_gap_sec: spec.host_gap_sec,
        slo_ttft_sec: spec.slo_ttft_sec,
        slo_tpot_sec: spec.slo_tpot_sec,
    })
}

/// Latency summary derived from a [`LogHistogram`]: exact count/mean/max,
/// bin-resolution p50/p95/p99. All-zero when no sample was recorded (e.g.
/// TPOT when every request generates a single token), so it serializes
/// without NaN.
#[derive(Debug, Clone, PartialEq)]
pub struct LatencySummary {
    pub count: u64,
    pub mean_sec: f64,
    pub p50_sec: f64,
    pub p95_sec: f64,
    pub p99_sec: f64,
    pub max_sec: f64,
}

impl LatencySummary {
    pub fn of(h: &LogHistogram) -> LatencySummary {
        if h.count() == 0 {
            return LatencySummary {
                count: 0,
                mean_sec: 0.0,
                p50_sec: 0.0,
                p95_sec: 0.0,
                p99_sec: 0.0,
                max_sec: 0.0,
            };
        }
        LatencySummary {
            count: h.count(),
            mean_sec: h.mean(),
            p50_sec: h.percentile(50.0),
            p95_sec: h.percentile(95.0),
            p99_sec: h.percentile(99.0),
            max_sec: h.max(),
        }
    }
}

/// Per-replica accounting.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaReport {
    pub completed: u64,
    /// Steps the replica executed (prefill + decode).
    pub steps: u64,
    pub prefill_steps: u64,
    /// Virtual seconds the replica spent inside steps.
    pub busy_sec: f64,
    /// `busy_sec / makespan` (0 for an empty simulation).
    pub utilization: f64,
    /// Peak KV reservation observed, tokens.
    pub peak_kv_tokens: u64,
    /// Largest step batch (running + entering) observed.
    pub max_batch_seen: u32,
}

/// The typed answer of a cluster simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterReport {
    pub model: String,
    pub gpu: String,
    pub tp: u32,
    pub pp: u32,
    pub policy: RoutePolicy,
    pub seed: u64,
    pub host_gap_sec: f64,
    /// Requests offered by the arrival process.
    pub offered: u64,
    /// Requests completed (equals `offered`: admission is starvation-free).
    pub completed: u64,
    /// Virtual time of the last event, seconds.
    pub makespan_sec: f64,
    /// Output tokens generated across all completed requests.
    pub generated_tokens: f64,
    pub tokens_per_sec: f64,
    pub requests_per_sec: f64,
    /// Time-to-first-token: arrival → prefill completion.
    pub ttft: LatencySummary,
    /// Time-per-output-token: (finish − first token) / (output − 1);
    /// recorded only for requests generating more than one token.
    pub tpot: LatencySummary,
    /// Queueing delay: arrival → prefill start.
    pub queue_delay: LatencySummary,
    pub ttft_hist: LogHistogram,
    pub tpot_hist: LogHistogram,
    pub queue_hist: LogHistogram,
    /// Fraction of completed requests meeting the TTFT SLO (exact,
    /// per-request — not derived from histogram bins).
    pub slo_ttft_attainment: f64,
    /// Fraction meeting the TPOT SLO (single-token requests count as
    /// meeting it).
    pub slo_tpot_attainment: f64,
    /// Fraction meeting both.
    pub slo_attainment: f64,
    pub replicas: Vec<ReplicaReport>,
    /// Kernel items answered with degraded (roofline) provenance across
    /// distinct evaluated step shapes.
    pub degraded_kernels: usize,
    /// Distinct step shapes evaluated through the predictor (memo size).
    pub distinct_steps: usize,
    /// Events processed by the virtual clock.
    pub events: u64,
}

enum Event {
    Arrival(usize),
    StepDone(usize),
}

/// What a replica is doing right now.
enum StepKind {
    Idle,
    /// Prefilling these newly admitted requests (by request index).
    Prefill(Vec<usize>),
    /// One decode step over the running set.
    Decode,
}

struct Replica {
    waiting: VecDeque<usize>,
    running: Vec<usize>,
    kv_reserved: u64,
    step: StepKind,
    completed: u64,
    steps: u64,
    prefill_steps: u64,
    busy_sec: f64,
    peak_kv_tokens: u64,
    max_batch_seen: u32,
}

impl Replica {
    fn new() -> Replica {
        Replica {
            waiting: VecDeque::new(),
            running: Vec::new(),
            kv_reserved: 0,
            step: StepKind::Idle,
            completed: 0,
            steps: 0,
            prefill_steps: 0,
            busy_sec: 0.0,
            peak_kv_tokens: 0,
            max_batch_seen: 0,
        }
    }

    /// Router-visible load: waiting + currently prefilling + running.
    fn load(&self) -> usize {
        let entering = match &self.step {
            StepKind::Prefill(v) => v.len(),
            _ => 0,
        };
        self.waiting.len() + entering + self.running.len()
    }
}

/// Per-request timeline.
#[derive(Clone)]
struct ReqState {
    replica: usize,
    prefill_start: f64,
    first_token: f64,
    finish: f64,
    decoded: u32,
}

#[derive(Hash, PartialEq, Eq)]
enum StepKey {
    /// Prompt lengths of the admitted batch, in admission order.
    Prefill(Vec<u32>),
    /// Quantized KV lengths of the running set, in running order.
    Decode(Vec<u32>),
}

/// Memoizing step-cost model over the predictor path. The memo is
/// lookup-only (never iterated), so `HashMap` order cannot leak into any
/// output.
struct CostModel<'a> {
    llm: &'a LlmConfig,
    gpu: &'a GpuSpec,
    tp: u32,
    pp: u32,
    models: &'a ModelSet,
    comm: &'a CommModel,
    host_gap_sec: f64,
    threads: usize,
    memo: HashMap<StepKey, f64>,
    degraded: usize,
}

impl CostModel<'_> {
    fn step_cost(&mut self, key: StepKey) -> f64 {
        if let Some(&secs) = self.memo.get(&key) {
            return secs;
        }
        let items = match &key {
            StepKey::Prefill(inputs) => {
                let reqs: Vec<Request> = inputs
                    .iter()
                    .map(|&input_len| Request { input_len, output_len: 1 })
                    .collect();
                trace::build_prefill_trace(self.llm, self.tp, self.pp, &reqs)
            }
            StepKey::Decode(kvs) => {
                trace::build_decode_step_trace(self.llm, self.tp, self.pp, kvs)
            }
        };
        let (secs, degraded) = eval::predict_stream_cost(
            &items,
            self.gpu,
            self.tp,
            self.models,
            self.comm,
            self.host_gap_sec,
            self.threads,
        );
        self.degraded += degraded;
        self.memo.insert(key, secs);
        secs
    }
}

fn quantize_kv(kv: u32, quant: u32) -> u32 {
    kv.div_ceil(quant).max(1) * quant
}

/// Metric accumulators filled at request completion.
struct Tally {
    ttft: LogHistogram,
    tpot: LogHistogram,
    queue: LogHistogram,
    completed: u64,
    generated_tokens: f64,
    slo_ttft_ok: u64,
    slo_tpot_ok: u64,
    slo_joint_ok: u64,
}

struct Sim<'a> {
    c: &'a CompiledCluster,
    reqs: Vec<ReqState>,
    reps: Vec<Replica>,
    q: EventQueue<Event>,
    rr_next: usize,
    tally: Tally,
}

impl Sim<'_> {
    fn route(&mut self, i: usize) -> usize {
        match self.c.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next % self.reps.len();
                self.rr_next += 1;
                r
            }
            RoutePolicy::LeastLoaded => {
                // min_by_key keeps the first minimum — lowest index wins
                self.reps
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, rep)| rep.load())
                    .map(|(idx, _)| idx)
                    .unwrap_or(0)
            }
            RoutePolicy::SessionAffinity => {
                let mut s = self.c.requests[i].session;
                (splitmix64(&mut s) % self.reps.len() as u64) as usize
            }
        }
    }

    /// Start the next step on replica `r` if it is idle and has work.
    /// Prefill (admission) takes priority over decode; admission is strict
    /// FIFO under the `max_batch` and KV-reservation gates.
    fn try_start_step(&mut self, cost: &mut CostModel, r: usize, now: f64) {
        if !matches!(self.reps[r].step, StepKind::Idle) {
            return;
        }
        let mut entering: Vec<usize> = Vec::new();
        loop {
            let Some(&i) = self.reps[r].waiting.front() else { break };
            let req = &self.c.requests[i];
            let need = req.input_len as u64 + req.output_len as u64;
            if self.reps[r].running.len() + entering.len() >= self.c.max_batch as usize {
                break;
            }
            if self.reps[r].kv_reserved + need > self.c.kv_capacity_tokens {
                break;
            }
            self.reps[r].kv_reserved += need;
            entering.push(i);
            self.reps[r].waiting.pop_front();
        }
        let (secs, kind) = if !entering.is_empty() {
            for &i in &entering {
                self.reqs[i].prefill_start = now;
            }
            let inputs: Vec<u32> =
                entering.iter().map(|&i| self.c.requests[i].input_len).collect();
            (cost.step_cost(StepKey::Prefill(inputs)), StepKind::Prefill(entering))
        } else if !self.reps[r].running.is_empty() {
            let kvs: Vec<u32> = self.reps[r]
                .running
                .iter()
                .map(|&i| {
                    let kv = self.c.requests[i].input_len.saturating_add(self.reqs[i].decoded);
                    quantize_kv(kv, self.c.kv_quant)
                })
                .collect();
            (cost.step_cost(StepKey::Decode(kvs)), StepKind::Decode)
        } else {
            return;
        };
        let batch = self.reps[r].running.len()
            + match &kind {
                StepKind::Prefill(v) => v.len(),
                _ => 0,
            };
        let rep = &mut self.reps[r];
        rep.steps += 1;
        if matches!(kind, StepKind::Prefill(_)) {
            rep.prefill_steps += 1;
        }
        rep.busy_sec += secs;
        rep.max_batch_seen = rep.max_batch_seen.max(batch as u32);
        rep.peak_kv_tokens = rep.peak_kv_tokens.max(rep.kv_reserved);
        rep.step = kind;
        self.q.push(now + secs, Event::StepDone(r));
    }

    fn finish_step(&mut self, r: usize, now: f64) {
        let step = std::mem::replace(&mut self.reps[r].step, StepKind::Idle);
        let mut done: Vec<usize> = Vec::new();
        match step {
            StepKind::Idle => unreachable!("StepDone for an idle replica"),
            StepKind::Prefill(entering) => {
                for i in entering {
                    let out_len = self.c.requests[i].output_len;
                    let st = &mut self.reqs[i];
                    st.first_token = now; // prefill emits the first token
                    st.decoded = 1;
                    if st.decoded >= out_len {
                        done.push(i);
                    } else {
                        self.reps[r].running.push(i);
                    }
                }
            }
            StepKind::Decode => {
                let running = std::mem::take(&mut self.reps[r].running);
                for i in running {
                    let out_len = self.c.requests[i].output_len;
                    let finished = {
                        let st = &mut self.reqs[i];
                        st.decoded += 1;
                        st.decoded >= out_len
                    };
                    if finished {
                        done.push(i);
                    } else {
                        self.reps[r].running.push(i);
                    }
                }
            }
        }
        for i in done {
            self.complete(r, i, now);
        }
    }

    fn complete(&mut self, r: usize, i: usize, now: f64) {
        let req = &self.c.requests[i];
        let st = &mut self.reqs[i];
        st.finish = now;
        let ttft = st.first_token - req.arrival_sec;
        let queue_delay = st.prefill_start - req.arrival_sec;
        self.tally.ttft.insert(ttft);
        self.tally.queue.insert(queue_delay);
        let ttft_ok = ttft <= self.c.slo_ttft_sec;
        let tpot_ok = if req.output_len > 1 {
            let tpot = (st.finish - st.first_token) / (req.output_len - 1) as f64;
            self.tally.tpot.insert(tpot);
            tpot <= self.c.slo_tpot_sec
        } else {
            true // a single-token request has no inter-token latency
        };
        self.tally.completed += 1;
        self.tally.generated_tokens += req.output_len as f64;
        self.tally.slo_ttft_ok += ttft_ok as u64;
        self.tally.slo_tpot_ok += tpot_ok as u64;
        self.tally.slo_joint_ok += (ttft_ok && tpot_ok) as u64;
        let rep = &mut self.reps[r];
        rep.completed += 1;
        rep.kv_reserved -= req.input_len as u64 + req.output_len as u64;
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// Run the event loop. Infallible by construction: [`compile_cluster`]
/// already validated the spec, and missing models answer in the documented
/// degraded roofline mode (visible in `degraded_kernels`).
pub fn simulate_cluster(
    c: &CompiledCluster,
    models: &ModelSet,
    comm: &CommModel,
    threads: usize,
) -> ClusterReport {
    let n = c.requests.len();
    let mut cost = CostModel {
        llm: &c.llm,
        gpu: &c.gpu,
        tp: c.tp,
        pp: c.pp,
        models,
        comm,
        host_gap_sec: c.host_gap_sec,
        threads: threads.max(1),
        memo: HashMap::new(),
        degraded: 0,
    };
    let mut sim = Sim {
        c,
        reqs: vec![
            ReqState {
                replica: usize::MAX,
                prefill_start: 0.0,
                first_token: 0.0,
                finish: 0.0,
                decoded: 0,
            };
            n
        ],
        reps: (0..c.replicas).map(|_| Replica::new()).collect(),
        q: EventQueue::new(),
        rr_next: 0,
        tally: Tally {
            ttft: LogHistogram::new(),
            tpot: LogHistogram::new(),
            queue: LogHistogram::new(),
            completed: 0,
            generated_tokens: 0.0,
            slo_ttft_ok: 0,
            slo_tpot_ok: 0,
            slo_joint_ok: 0,
        },
    };
    // requests are arrival-sorted; same-instant arrivals keep their order
    // through the queue's FIFO tie-break
    for (i, r) in c.requests.iter().enumerate() {
        sim.q.push(r.arrival_sec, Event::Arrival(i));
    }
    let mut events = 0u64;
    let mut makespan = 0.0f64;
    while let Some((now, ev)) = sim.q.pop() {
        events += 1;
        makespan = makespan.max(now);
        match ev {
            Event::Arrival(i) => {
                let r = sim.route(i);
                sim.reqs[i].replica = r;
                sim.reps[r].waiting.push_back(i);
                sim.try_start_step(&mut cost, r, now);
            }
            Event::StepDone(r) => {
                sim.finish_step(r, now);
                sim.try_start_step(&mut cost, r, now);
            }
        }
    }
    debug_assert_eq!(sim.tally.completed as usize, n, "admission must be starvation-free");

    let replicas: Vec<ReplicaReport> = sim
        .reps
        .iter()
        .map(|rep| ReplicaReport {
            completed: rep.completed,
            steps: rep.steps,
            prefill_steps: rep.prefill_steps,
            busy_sec: rep.busy_sec,
            utilization: ratio(rep.busy_sec, makespan),
            peak_kv_tokens: rep.peak_kv_tokens,
            max_batch_seen: rep.max_batch_seen,
        })
        .collect();
    let t = &sim.tally;
    ClusterReport {
        model: c.llm.name.to_string(),
        gpu: c.gpu.name.to_string(),
        tp: c.tp,
        pp: c.pp,
        policy: c.policy,
        seed: c.seed,
        host_gap_sec: c.host_gap_sec,
        offered: n as u64,
        completed: t.completed,
        makespan_sec: makespan,
        generated_tokens: t.generated_tokens,
        tokens_per_sec: ratio(t.generated_tokens, makespan),
        requests_per_sec: ratio(t.completed as f64, makespan),
        ttft: LatencySummary::of(&t.ttft),
        tpot: LatencySummary::of(&t.tpot),
        queue_delay: LatencySummary::of(&t.queue),
        ttft_hist: t.ttft.clone(),
        tpot_hist: t.tpot.clone(),
        queue_hist: t.queue.clone(),
        slo_ttft_attainment: ratio(t.slo_ttft_ok as f64, t.completed as f64),
        slo_tpot_attainment: ratio(t.slo_tpot_ok as f64, t.completed as f64),
        slo_attainment: ratio(t.slo_joint_ok as f64, t.completed as f64),
        replicas,
        degraded_kernels: cost.degraded,
        distinct_steps: cost.memo.len(),
        events,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Simulator;

    fn trace4() -> ArrivalSpec {
        ArrivalSpec::Trace(vec![
            ClusterRequest { arrival_sec: 0.0, input_len: 128, output_len: 8, session: 0 },
            ClusterRequest { arrival_sec: 0.001, input_len: 96, output_len: 4, session: 1 },
            ClusterRequest { arrival_sec: 0.002, input_len: 64, output_len: 6, session: 2 },
            ClusterRequest { arrival_sec: 0.003, input_len: 32, output_len: 2, session: 3 },
        ])
    }

    fn small_spec() -> ClusterSpec {
        ClusterSpec::new("Llama3.1-8B", "A100")
            .replicas(2)
            .arrivals(trace4())
            .max_batch(4)
            .kv_capacity_tokens(4096)
            .seed(7)
    }

    #[test]
    fn every_offered_request_completes() {
        let r = Simulator::degraded().simulate_cluster(&small_spec()).unwrap();
        assert_eq!(r.offered, 4);
        assert_eq!(r.completed, 4);
        assert_eq!(r.replicas.len(), 2);
        assert_eq!(r.replicas.iter().map(|x| x.completed).sum::<u64>(), 4);
        // round-robin over 2 replicas: 2 requests each
        assert_eq!(r.replicas[0].completed, 2);
        assert_eq!(r.replicas[1].completed, 2);
        assert_eq!(r.generated_tokens, 20.0);
        assert!(r.makespan_sec > 0.0 && r.makespan_sec.is_finite());
        assert!(r.tokens_per_sec > 0.0);
        assert_eq!(r.ttft.count, 4);
        assert_eq!(r.queue_delay.count, 4);
        // request 3 generates 2 tokens; 0, 1, 2 generate > 1 too
        assert_eq!(r.tpot.count, 4);
        assert!(r.ttft.p50_sec > 0.0);
        assert!(r.ttft.p99_sec >= r.ttft.p50_sec);
        assert!(r.events >= 4, "at least one event per arrival");
        assert!(r.distinct_steps > 0);
        assert!(r.degraded_kernels > 0, "degraded simulator must say so");
    }

    #[test]
    fn slo_attainment_hits_both_extremes() {
        let sim = Simulator::degraded();
        let lax = sim.simulate_cluster(&small_spec().slo(1e6, 1e6)).unwrap();
        assert_eq!(lax.slo_ttft_attainment, 1.0);
        assert_eq!(lax.slo_tpot_attainment, 1.0);
        assert_eq!(lax.slo_attainment, 1.0);
        let strict = sim.simulate_cluster(&small_spec().slo(1e-12, 1e-12)).unwrap();
        assert_eq!(strict.slo_ttft_attainment, 0.0);
        assert_eq!(strict.slo_attainment, 0.0);
    }

    #[test]
    fn kv_pressure_forces_queueing_but_not_starvation() {
        // capacity fits only one request at a time: strictly serial service
        let spec = small_spec().kv_capacity_tokens(150).max_batch(4);
        let r = Simulator::degraded().simulate_cluster(&spec).unwrap();
        assert_eq!(r.completed, 4);
        for rep in &r.replicas {
            assert!(rep.max_batch_seen <= 1, "KV budget admits one request at a time");
            assert!(rep.peak_kv_tokens <= 150);
        }
    }

    #[test]
    fn policies_route_deterministically() {
        let sim = Simulator::degraded();
        for policy in
            [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::SessionAffinity]
        {
            let spec = small_spec().policy(policy);
            let a = sim.simulate_cluster(&spec).unwrap();
            let b = sim.simulate_cluster(&spec).unwrap();
            assert_eq!(a, b, "{} must be run-to-run deterministic", policy.name());
            assert_eq!(a.completed, 4);
        }
        // session affinity: all four sessions distinct, but both requests
        // of one session land on one replica
        let spec = small_spec()
            .policy(RoutePolicy::SessionAffinity)
            .arrivals(ArrivalSpec::Trace(vec![
                ClusterRequest { arrival_sec: 0.0, input_len: 64, output_len: 4, session: 42 },
                ClusterRequest { arrival_sec: 0.1, input_len: 64, output_len: 4, session: 42 },
            ]));
        let r = sim.simulate_cluster(&spec).unwrap();
        assert!(
            r.replicas.iter().any(|rep| rep.completed == 2),
            "one session must stick to one replica"
        );
    }

    #[test]
    fn generated_arrivals_are_seeded_and_sorted() {
        let spec = ClusterSpec::new("Llama3.1-8B", "A100").arrivals(ArrivalSpec::Poisson {
            rate_rps: 10.0,
            n: 12,
            kind: WorkloadKind::Splitwise,
        });
        let a = compile_cluster(&spec).unwrap();
        let b = compile_cluster(&spec).unwrap();
        assert_eq!(a.requests, b.requests);
        assert!(a.requests.windows(2).all(|w| w[0].arrival_sec <= w[1].arrival_sec));
        assert!(a.requests[0].arrival_sec > 0.0, "poisson gaps are positive a.s.");
        let c = compile_cluster(&spec.clone().seed(1)).unwrap();
        assert_ne!(a.requests, c.requests, "different seed, different arrivals");
        // uniform: exact gaps
        let u = compile_cluster(&ClusterSpec::new("Llama3.1-8B", "A100").arrivals(
            ArrivalSpec::Uniform { gap_sec: 0.5, n: 3, kind: WorkloadKind::Arxiv },
        ))
        .unwrap();
        let times: Vec<f64> = u.requests.iter().map(|r| r.arrival_sec).collect();
        assert_eq!(times, vec![0.5, 1.0, 1.5]);
    }

    #[test]
    fn cluster_taxonomy_is_closed() {
        let sim = Simulator::degraded();
        let bad = |spec: ClusterSpec| sim.simulate_cluster(&spec).unwrap_err();
        assert!(matches!(
            bad(ClusterSpec::new("GPT-5", "A100")),
            ScenarioError::UnknownModel(_)
        ));
        assert!(matches!(
            bad(ClusterSpec::new("Llama3.1-8B", "B300")),
            ScenarioError::UnknownGpu(_)
        ));
        assert!(matches!(
            bad(small_spec().tp(3)),
            ScenarioError::InvalidParallelism(_)
        ));
        assert!(matches!(
            bad(small_spec().host_gap_sec(-1.0)),
            ScenarioError::MalformedSpec(_)
        ));
        assert!(matches!(bad(small_spec().replicas(0)), ScenarioError::InvalidCluster(_)));
        assert!(matches!(
            bad(small_spec().replicas(MAX_REPLICAS + 1)),
            ScenarioError::InvalidCluster(_)
        ));
        assert!(matches!(bad(small_spec().max_batch(0)), ScenarioError::InvalidCluster(_)));
        assert!(matches!(bad(small_spec().kv_quant(0)), ScenarioError::InvalidCluster(_)));
        assert!(matches!(
            bad(small_spec().slo(0.0, 1.0)),
            ScenarioError::InvalidCluster(_)
        ));
        // a request that cannot fit an empty replica is rejected up front
        assert!(matches!(
            bad(small_spec().kv_capacity_tokens(10)),
            ScenarioError::InvalidCluster(_)
        ));
        // arrival-process parameter errors
        assert!(matches!(
            bad(small_spec().arrivals(ArrivalSpec::Poisson {
                rate_rps: 0.0,
                n: 4,
                kind: WorkloadKind::Arxiv
            })),
            ScenarioError::InvalidCluster(_)
        ));
        assert!(matches!(
            bad(small_spec().arrivals(ArrivalSpec::Trace(vec![ClusterRequest {
                arrival_sec: f64::NAN,
                input_len: 8,
                output_len: 2,
                session: 0,
            }]))),
            ScenarioError::InvalidCluster(_)
        ));
        // workload-shaped problems keep the v1 taxonomy
        assert!(matches!(
            bad(small_spec().arrivals(ArrivalSpec::Trace(vec![]))),
            ScenarioError::InvalidWorkload(_)
        ));
        assert!(matches!(
            bad(small_spec().arrivals(ArrivalSpec::Trace(vec![ClusterRequest {
                arrival_sec: 0.0,
                input_len: 0,
                output_len: 2,
                session: 0,
            }]))),
            ScenarioError::InvalidWorkload(_)
        ));
    }

    #[test]
    fn kv_quant_trades_memo_size_for_fidelity() {
        let sim = Simulator::degraded();
        let exact = sim.simulate_cluster(&small_spec().kv_quant(1)).unwrap();
        let coarse = sim.simulate_cluster(&small_spec().kv_quant(64)).unwrap();
        assert!(coarse.distinct_steps <= exact.distinct_steps);
        assert_eq!(coarse.completed, exact.completed);
    }
}
