//! JSONL wire codec for the **`simulate` verb** of Scenario API v1: a
//! request line carries a [`ScenarioSpec`], the response line a
//! [`ScenarioReport`] (or a closed-taxonomy [`ScenarioError`]). The same
//! lines ride `synperf simulate` and `synperf serve --stdio` (which
//! dispatches per line between the `predict` and `simulate` verbs).
//!
//! Request line:
//!
//! ```json
//! {"v":1,"id":"s1","op":"simulate","scenario":{"model":"Qwen2.5-14B",
//!  "gpu":"A100","tp":2,"pp":1,"workload":{"kind":"arxiv","batch":8},
//!  "phases":"both","seed":7,"host_gap_sec":8e-7}}
//! ```
//!
//! `scenario.model` and `scenario.gpu` are required; everything else is
//! optional with the defaults shown. An explicit request mix replaces the
//! sampled workload: `"workload":{"requests":[[1000,200],[2000,100]]}`.
//! The response carries per-phase TTFT/TPOT/tokens-per-second, per-method
//! totals, the typed per-class breakdown, and provenance counts:
//!
//! ```json
//! {"v":1,"id":"s1","ok":true,"report":{"model":"Qwen2.5-14B","gpu":"A100",
//!  "tp":2,"pp":1,"seed":7,"host_gap_sec":8e-7,"launches":4.4e2,
//!  "cache_hits":40,"totals":{...,"degraded_kernels":44},"breakdown":{...},
//!  "phases":[{"phase":"prefill","ttft_sec":{...},...},...]}}
//! {"v":1,"id":"s2","ok":false,"error":{"code":"unknown_model",
//!  "message":"unknown model \"GPT-5\" (see llm::registry())","model":"GPT-5"}}
//! ```
//!
//! Malformed lines map to [`ScenarioError::MalformedSpec`] (mirroring the
//! predict verb's malformed-request bucket).
//!
//! **Scenario v2** rides the same verb with a `cluster` object in place of
//! `scenario`:
//!
//! ```json
//! {"v":1,"id":"c1","op":"simulate","cluster":{"model":"Llama3.1-8B",
//!  "gpu":"A100","replicas":2,"policy":"least_loaded",
//!  "arrivals":{"poisson":{"rate_rps":8,"n":32,"kind":"arxiv"}},
//!  "max_batch":16,"kv_capacity_tokens":262144,"kv_quant":16,"seed":7,
//!  "slo":{"ttft_sec":2,"tpot_sec":0.2}}}
//! ```
//!
//! Deterministic traces replace the sampled process:
//! `"arrivals":{"trace":[[0.0,1000,200,0],[0.5,600,100,1]]}` — entries are
//! `[arrival_sec, input, output]` or `[arrival_sec, input, output,
//! session]` (session defaults to the entry index). The response report
//! carries a `"cluster":true` discriminator, the per-request percentile
//! summaries (`ttft`, `tpot`, `queue_delay`), the mergeable fixed-bin
//! histograms behind them, SLO attainment, and per-replica accounting.
//! Cluster-knob errors speak the `invalid_cluster` taxonomy code.

use super::{
    ArrivalSpec, ClassBreakdown, ClusterReport, ClusterRequest, ClusterSpec, LatencySummary,
    Method, MethodTotals, OpClass, Phase, PhaseReport, PhaseSelection, ReplicaReport, RoutePolicy,
    ScenarioError, ScenarioReport, ScenarioSpec, WorkloadSpec,
};
use crate::api::wire::{esc, id_of};
use crate::api::PROTOCOL_VERSION;
use crate::e2e::workload::{Request, WorkloadKind};
use crate::util::json::{parse, Json};
use crate::util::stats::LogHistogram;
use anyhow::{anyhow, Result};

fn malformed(why: impl Into<String>) -> ScenarioError {
    ScenarioError::MalformedSpec(why.into())
}

fn num_u32(v: &Json, what: &str) -> Result<u32, ScenarioError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64)
        .map(|n| n as u32)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

fn num_u64(v: &Json, what: &str) -> Result<u64, ScenarioError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64)
        .map(|n| n as u64)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

/// Seeds are u64, but JSON numbers only survive the f64-based parser up to
/// 2^53 — larger seeds travel as strings so the codec round-trips its own
/// output for every value. [`seed_from`] accepts both shapes.
fn seed_to_json(seed: u64) -> String {
    if seed <= (1u64 << 53) {
        format!("{seed}")
    } else {
        format!("\"{seed}\"")
    }
}

fn seed_from(v: &Json, what: &str) -> Result<u64, ScenarioError> {
    match v {
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| malformed(format!("{what:?} must be a u64"))),
        _ => num_u64(v, what),
    }
}

// ---- spec ----------------------------------------------------------------

/// Canonical JSON for one scenario object — shared with the sweep codec,
/// which embeds specs as workload templates.
pub(crate) fn spec_to_json(spec: &ScenarioSpec) -> String {
    let workload = match &spec.workload {
        WorkloadSpec::Sampled { kind, batch } => {
            format!(r#"{{"kind":"{}","batch":{}}}"#, kind.name(), batch)
        }
        WorkloadSpec::Explicit(reqs) => {
            let pairs: Vec<String> =
                reqs.iter().map(|r| format!("[{},{}]", r.input_len, r.output_len)).collect();
            format!(r#"{{"requests":[{}]}}"#, pairs.join(","))
        }
    };
    format!(
        r#"{{"model":"{}","gpu":"{}","tp":{},"pp":{},"workload":{},"phases":"{}","seed":{},"host_gap_sec":{:e}}}"#,
        esc(&spec.model),
        esc(&spec.gpu),
        spec.tp,
        spec.pp,
        workload,
        spec.phases.name(),
        seed_to_json(spec.seed),
        spec.host_gap_sec
    )
}

/// Serialize a simulate request into its canonical wire line (no trailing
/// newline). The inverse of [`parse_simulate_request`].
pub fn encode_simulate_request(id: Option<&str>, spec: &ScenarioSpec) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(",\"op\":\"simulate\",\"scenario\":{}", spec_to_json(spec)));
    out.push('}');
    out
}

/// Parse one bare `scenario` object into a spec.
fn parse_spec_object(j: &Json) -> Result<ScenarioSpec, ScenarioError> {
    parse_spec_fields(j, None)
}

/// Sweep-template variant: `gpu` may be omitted (the grid overwrites it —
/// along with `tp`/`pp` — per point).
pub(crate) fn parse_spec_template(j: &Json) -> Result<ScenarioSpec, ScenarioError> {
    parse_spec_fields(j, Some(""))
}

fn parse_spec_fields(j: &Json, default_gpu: Option<&str>) -> Result<ScenarioSpec, ScenarioError> {
    let model = j
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| malformed("scenario needs \"model\": \"<name>\""))?;
    let gpu = match j.get("gpu").and_then(|v| v.as_str()) {
        Some(g) => g,
        None => default_gpu.ok_or_else(|| malformed("scenario needs \"gpu\": \"<name>\""))?,
    };
    let mut spec = ScenarioSpec::new(model, gpu);
    if let Some(v) = j.get("tp") {
        spec.tp = num_u32(v, "tp")?;
    }
    if let Some(v) = j.get("pp") {
        spec.pp = num_u32(v, "pp")?;
    }
    if let Some(w) = j.get("workload") {
        spec.workload = if let Some(rs) = w.get("requests") {
            let arr = rs
                .as_arr()
                .ok_or_else(|| malformed("\"requests\" must be an array of [input,output] pairs"))?;
            let mut reqs = Vec::with_capacity(arr.len());
            for pair in arr {
                let p = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| malformed("request entries are [input,output] pairs"))?;
                reqs.push(Request {
                    input_len: num_u32(&p[0], "input_len")?,
                    output_len: num_u32(&p[1], "output_len")?,
                });
            }
            WorkloadSpec::Explicit(reqs)
        } else {
            let kind = match w.get("kind") {
                None => WorkloadKind::Arxiv,
                Some(v) => super::workload_kind(
                    v.as_str().ok_or_else(|| malformed("\"kind\" must be a string"))?,
                )?,
            };
            let batch = match w.get("batch") {
                None => 8,
                // saturate rather than wrap on 32-bit targets: the
                // compiler's MAX_BATCH cap owns the rejection either way
                Some(v) => usize::try_from(num_u64(v, "batch")?).unwrap_or(usize::MAX),
            };
            WorkloadSpec::Sampled { kind, batch }
        };
    }
    if let Some(v) = j.get("phases") {
        let name = v.as_str().ok_or_else(|| malformed("\"phases\" must be a string"))?;
        spec.phases = PhaseSelection::parse(name)?;
    }
    if let Some(v) = j.get("seed") {
        spec.seed = seed_from(v, "seed")?;
    }
    if let Some(v) = j.get("host_gap_sec") {
        spec.host_gap_sec =
            v.as_f64().ok_or_else(|| malformed("\"host_gap_sec\" must be a number"))?;
    }
    Ok(spec)
}

fn check_version(j: &Json) -> Result<(), ScenarioError> {
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        if v as u32 != PROTOCOL_VERSION {
            return Err(malformed(format!(
                "protocol version {v} (this build speaks v{PROTOCOL_VERSION})"
            )));
        }
    }
    Ok(())
}

fn simulate_fields(j: &Json) -> Result<ScenarioSpec, ScenarioError> {
    check_version(j)?;
    let sc = j
        .get("scenario")
        .ok_or_else(|| malformed("simulate request needs a \"scenario\" object"))?;
    parse_spec_object(sc)
}

/// Parse one simulate request line (the `{"op":"simulate","scenario":{..}}`
/// envelope). The extracted `id` (if any) is returned even when parsing
/// fails, so the error response can still be correlated.
pub fn parse_simulate_request(line: &str) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    parse_simulate_json(&j)
}

/// Envelope parse over an already-decoded line (single-parse dispatch).
pub(crate) fn parse_simulate_json(
    j: &Json,
) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    (id_of(j), simulate_fields(j))
}

/// Parse a spec line in either shape: the wire envelope or a bare
/// `scenario` object (`{"model":..,"gpu":..}`) — what `synperf simulate
/// --spec` accepts.
pub fn parse_spec_line(line: &str) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    let res = if j.get("scenario").is_some() || j.get("op").is_some() {
        simulate_fields(&j)
    } else {
        parse_spec_object(&j)
    };
    (id_of(&j), res)
}

/// Whether a decoded wire object addresses the simulate verb (vs the
/// predict verb) — in either of its shapes (v1 `scenario`, v2 `cluster`).
pub(crate) fn is_simulate_json(j: &Json) -> bool {
    j.get("op").and_then(|v| v.as_str()) == Some("simulate")
        || j.get("scenario").is_some()
        || j.get("cluster").is_some()
}

// ---- cluster spec (Scenario v2) -------------------------------------------

/// One parsed `simulate` request: the v1 single-node scenario or the v2
/// cluster simulation. Both ride the same wire verb; the `scenario` /
/// `cluster` object key discriminates.
#[derive(Debug, Clone, PartialEq)]
pub enum SimulateRequest {
    Scenario(ScenarioSpec),
    Cluster(ClusterSpec),
}

fn arrivals_to_json(a: &ArrivalSpec) -> String {
    match a {
        ArrivalSpec::Trace(reqs) => {
            let rows: Vec<String> = reqs
                .iter()
                .map(|r| {
                    format!(
                        "[{:e},{},{},{}]",
                        r.arrival_sec,
                        r.input_len,
                        r.output_len,
                        seed_to_json(r.session)
                    )
                })
                .collect();
            format!(r#"{{"trace":[{}]}}"#, rows.join(","))
        }
        ArrivalSpec::Poisson { rate_rps, n, kind } => format!(
            r#"{{"poisson":{{"rate_rps":{:e},"n":{},"kind":"{}"}}}}"#,
            rate_rps,
            n,
            kind.name()
        ),
        ArrivalSpec::Uniform { gap_sec, n, kind } => format!(
            r#"{{"uniform":{{"gap_sec":{:e},"n":{},"kind":"{}"}}}}"#,
            gap_sec,
            n,
            kind.name()
        ),
    }
}

fn arrivals_from_json(j: &Json) -> Result<ArrivalSpec, ScenarioError> {
    if let Some(t) = j.get("trace") {
        let arr = t.as_arr().ok_or_else(|| malformed("\"trace\" must be an array"))?;
        let mut reqs = Vec::with_capacity(arr.len());
        for (i, row) in arr.iter().enumerate() {
            let p = row.as_arr().filter(|p| p.len() == 3 || p.len() == 4).ok_or_else(|| {
                malformed("trace entries are [arrival_sec,input,output] or [arrival_sec,input,output,session]")
            })?;
            let arrival_sec =
                p[0].as_f64().ok_or_else(|| malformed("\"arrival_sec\" must be a number"))?;
            let session = if p.len() == 4 { seed_from(&p[3], "session")? } else { i as u64 };
            reqs.push(ClusterRequest {
                arrival_sec,
                input_len: num_u32(&p[1], "input_len")?,
                output_len: num_u32(&p[2], "output_len")?,
                session,
            });
        }
        return Ok(ArrivalSpec::Trace(reqs));
    }
    let n_and_kind = |o: &Json| -> Result<(usize, WorkloadKind), ScenarioError> {
        let n = match o.get("n") {
            None => 16,
            // saturate rather than wrap on 32-bit targets: the request cap
            // owns the rejection either way
            Some(v) => usize::try_from(num_u64(v, "n")?).unwrap_or(usize::MAX),
        };
        let kind = match o.get("kind") {
            None => WorkloadKind::Arxiv,
            Some(v) => super::workload_kind(
                v.as_str().ok_or_else(|| malformed("\"kind\" must be a string"))?,
            )?,
        };
        Ok((n, kind))
    };
    if let Some(o) = j.get("poisson") {
        let rate_rps = o
            .get("rate_rps")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| malformed("poisson arrivals need \"rate_rps\""))?;
        let (n, kind) = n_and_kind(o)?;
        return Ok(ArrivalSpec::Poisson { rate_rps, n, kind });
    }
    if let Some(o) = j.get("uniform") {
        let gap_sec = o
            .get("gap_sec")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| malformed("uniform arrivals need \"gap_sec\""))?;
        let (n, kind) = n_and_kind(o)?;
        return Ok(ArrivalSpec::Uniform { gap_sec, n, kind });
    }
    Err(malformed("\"arrivals\" must contain \"trace\", \"poisson\" or \"uniform\""))
}

/// Canonical JSON for one cluster object — shared with the sweep codec,
/// which embeds cluster specs as workload templates.
pub(crate) fn cluster_to_json(spec: &ClusterSpec) -> String {
    format!(
        r#"{{"model":"{}","gpu":"{}","tp":{},"pp":{},"replicas":{},"policy":"{}","arrivals":{},"max_batch":{},"kv_capacity_tokens":{},"kv_quant":{},"seed":{},"host_gap_sec":{:e},"slo":{{"ttft_sec":{:e},"tpot_sec":{:e}}}}}"#,
        esc(&spec.model),
        esc(&spec.gpu),
        spec.tp,
        spec.pp,
        spec.replicas,
        spec.policy.name(),
        arrivals_to_json(&spec.arrivals),
        spec.max_batch,
        seed_to_json(spec.kv_capacity_tokens),
        spec.kv_quant,
        seed_to_json(spec.seed),
        spec.host_gap_sec,
        spec.slo_ttft_sec,
        spec.slo_tpot_sec
    )
}

fn parse_cluster_object(j: &Json) -> Result<ClusterSpec, ScenarioError> {
    parse_cluster_fields(j, None)
}

/// Sweep-template variant: `gpu` may be omitted (the grid overwrites it —
/// along with `tp`/`pp`/`replicas`/`policy` — per point).
pub(crate) fn parse_cluster_template(j: &Json) -> Result<ClusterSpec, ScenarioError> {
    parse_cluster_fields(j, Some(""))
}

fn parse_cluster_fields(j: &Json, default_gpu: Option<&str>) -> Result<ClusterSpec, ScenarioError> {
    let model = j
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| malformed("cluster needs \"model\": \"<name>\""))?;
    let gpu = match j.get("gpu").and_then(|v| v.as_str()) {
        Some(g) => g,
        None => default_gpu.ok_or_else(|| malformed("cluster needs \"gpu\": \"<name>\""))?,
    };
    let mut spec = ClusterSpec::new(model, gpu);
    if let Some(v) = j.get("tp") {
        spec.tp = num_u32(v, "tp")?;
    }
    if let Some(v) = j.get("pp") {
        spec.pp = num_u32(v, "pp")?;
    }
    if let Some(v) = j.get("replicas") {
        spec.replicas = num_u32(v, "replicas")?;
    }
    if let Some(v) = j.get("policy") {
        spec.policy = RoutePolicy::parse(
            v.as_str().ok_or_else(|| malformed("\"policy\" must be a string"))?,
        )?;
    }
    if let Some(v) = j.get("arrivals") {
        spec.arrivals = arrivals_from_json(v)?;
    }
    if let Some(v) = j.get("max_batch") {
        spec.max_batch = num_u32(v, "max_batch")?;
    }
    if let Some(v) = j.get("kv_capacity_tokens") {
        spec.kv_capacity_tokens = seed_from(v, "kv_capacity_tokens")?;
    }
    if let Some(v) = j.get("kv_quant") {
        spec.kv_quant = num_u32(v, "kv_quant")?;
    }
    if let Some(v) = j.get("seed") {
        spec.seed = seed_from(v, "seed")?;
    }
    if let Some(v) = j.get("host_gap_sec") {
        spec.host_gap_sec =
            v.as_f64().ok_or_else(|| malformed("\"host_gap_sec\" must be a number"))?;
    }
    if let Some(s) = j.get("slo") {
        if let Some(v) = s.get("ttft_sec") {
            spec.slo_ttft_sec =
                v.as_f64().ok_or_else(|| malformed("\"slo.ttft_sec\" must be a number"))?;
        }
        if let Some(v) = s.get("tpot_sec") {
            spec.slo_tpot_sec =
                v.as_f64().ok_or_else(|| malformed("\"slo.tpot_sec\" must be a number"))?;
        }
    }
    Ok(spec)
}

/// Serialize a cluster simulate request into its canonical wire line (no
/// trailing newline). The inverse of [`parse_request_line`].
pub fn encode_cluster_request(id: Option<&str>, spec: &ClusterSpec) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(",\"op\":\"simulate\",\"cluster\":{}", cluster_to_json(spec)));
    out.push('}');
    out
}

fn simulate_any_fields(j: &Json) -> Result<SimulateRequest, ScenarioError> {
    check_version(j)?;
    if let Some(c) = j.get("cluster") {
        return parse_cluster_object(c).map(SimulateRequest::Cluster);
    }
    let sc = j
        .get("scenario")
        .ok_or_else(|| malformed("simulate request needs a \"scenario\" or \"cluster\" object"))?;
    parse_spec_object(sc).map(SimulateRequest::Scenario)
}

/// Envelope parse over an already-decoded line, accepting both request
/// shapes (single-parse dispatch — what the stdio loop uses).
pub(crate) fn parse_request_json(
    j: &Json,
) -> (Option<String>, Result<SimulateRequest, ScenarioError>) {
    (id_of(j), simulate_any_fields(j))
}

/// Parse a request line in any accepted shape: the wire envelope (with a
/// `scenario` or `cluster` object), a bare scenario object, or a bare
/// `{"cluster":{..}}` wrapper — what `synperf simulate --spec` accepts.
pub fn parse_request_line(line: &str) -> (Option<String>, Result<SimulateRequest, ScenarioError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    let res = if j.get("cluster").is_some() || j.get("scenario").is_some() || j.get("op").is_some()
    {
        simulate_any_fields(&j)
    } else {
        parse_spec_object(&j).map(SimulateRequest::Scenario)
    };
    (id_of(&j), res)
}

/// Whether a wire line addresses the simulate verb (vs the predict verb).
/// Malformed JSON is not claimed — the predict codec owns that bucket, so
/// pre-scenario peers see unchanged error lines.
pub fn is_simulate_request(line: &str) -> bool {
    match parse(line) {
        Ok(j) => is_simulate_json(&j),
        Err(_) => false,
    }
}

// ---- report --------------------------------------------------------------

fn totals_to_json(t: &MethodTotals) -> String {
    format!(
        r#"{{"actual_sec":{:e},"synperf_sec":{:e},"roofline_sec":{:e},"linear_sec":{:e},"habitat_sec":{:e},"neusight_sec":{:e},"degraded_kernels":{}}}"#,
        t.actual, t.synperf, t.roofline, t.linear, t.habitat, t.neusight, t.degraded_kernels
    )
}

/// Breakdown keys are `<class>_sec` — except the host-gap aggregate,
/// which travels as `host_gap_total_sec` so a flat key-scan can never
/// confuse it with the per-launch `host_gap_sec` spec/report parameter.
fn class_key(c: OpClass) -> &'static str {
    match c {
        OpClass::Gemm => "gemm_sec",
        OpClass::Attention => "attention_sec",
        OpClass::RmsNorm => "rmsnorm_sec",
        OpClass::SiluMul => "silu_mul_sec",
        OpClass::FusedMoe => "fused_moe_sec",
        OpClass::AllReduce => "all_reduce_sec",
        OpClass::SendRecv => "send_recv_sec",
        OpClass::HostGap => "host_gap_total_sec",
    }
}

fn breakdown_to_json(b: &ClassBreakdown) -> String {
    let fields: Vec<String> = OpClass::ALL
        .iter()
        .map(|c| format!(r#""{}":{:e}"#, class_key(*c), b.get(*c)))
        .collect();
    format!("{{{}}}", fields.join(","))
}

fn phase_to_json(p: &PhaseReport) -> String {
    let mut out = format!(
        r#"{{"phase":"{}","tokens":{:e},"steps":{:e},"launches":{:e}"#,
        p.phase.name(),
        p.tokens,
        p.steps,
        p.launches
    );
    match p.phase {
        Phase::Prefill => out.push_str(&format!(
            r#","ttft_sec":{{"actual":{:e},"synperf":{:e}}}"#,
            p.ttft_sec(Method::Actual).unwrap_or(0.0),
            p.ttft_sec(Method::SynPerf).unwrap_or(0.0)
        )),
        Phase::Decode => out.push_str(&format!(
            r#","tpot_sec":{{"actual":{:e},"synperf":{:e}}}"#,
            p.tpot_sec(Method::Actual).unwrap_or(0.0),
            p.tpot_sec(Method::SynPerf).unwrap_or(0.0)
        )),
    }
    out.push_str(&format!(
        r#","tokens_per_sec":{{"actual":{:e},"synperf":{:e}}}"#,
        p.tokens_per_sec(Method::Actual),
        p.tokens_per_sec(Method::SynPerf)
    ));
    out.push_str(&format!(
        r#","totals":{},"breakdown":{}}}"#,
        totals_to_json(&p.totals),
        breakdown_to_json(&p.breakdown)
    ));
    out
}

fn report_to_json(r: &ScenarioReport) -> String {
    let phases: Vec<String> = r.phases.iter().map(phase_to_json).collect();
    format!(
        r#"{{"model":"{}","gpu":"{}","tp":{},"pp":{},"seed":{},"host_gap_sec":{:e},"launches":{:e},"cache_hits":{},"totals":{},"breakdown":{},"phases":[{}]}}"#,
        esc(&r.model),
        esc(&r.gpu),
        r.tp,
        r.pp,
        seed_to_json(r.seed),
        r.host_gap_sec,
        r.launches,
        r.cache_hits,
        totals_to_json(&r.totals),
        breakdown_to_json(&r.breakdown),
        phases.join(",")
    )
}

/// One owner of the error-object encoding, shared by the v1 and v2 report
/// encoders (and the sweep codec's per-row error objects) so the taxonomy
/// cannot drift between them.
pub(crate) fn error_to_json(e: &ScenarioError) -> String {
    let mut out =
        format!("{{\"code\":\"{}\",\"message\":\"{}\"", e.code(), esc(&e.to_string()));
    match e {
        ScenarioError::UnknownModel(name) => {
            out.push_str(&format!(",\"model\":\"{}\"", esc(name)));
        }
        ScenarioError::UnknownGpu(name) => {
            out.push_str(&format!(",\"gpu\":\"{}\"", esc(name)));
        }
        ScenarioError::InvalidParallelism(why)
        | ScenarioError::InvalidWorkload(why)
        | ScenarioError::MalformedSpec(why)
        | ScenarioError::InvalidCluster(why) => {
            out.push_str(&format!(",\"reason\":\"{}\"", esc(why)));
        }
    }
    out.push('}');
    out
}

/// Serialize one simulate result into its wire line (no trailing newline).
pub fn encode_report(id: Option<&str>, res: &Result<ScenarioReport, ScenarioError>) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(r) => out.push_str(&format!(",\"ok\":true,\"report\":{}", report_to_json(r))),
        Err(e) => out.push_str(&format!(",\"ok\":false,\"error\":{}", error_to_json(e))),
    }
    out.push('}');
    out
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("report field {key:?} missing"))
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("report field {key:?} missing"))
}

fn u64_field(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .filter(|n| *n >= 0.0 && n.fract() == 0.0)
        .map(|n| n as u64)
        .ok_or_else(|| anyhow!("report field {key:?} must be an unsigned integer"))
}

/// Client half of [`error_to_json`] — shared by the v1 and v2 report
/// parsers (and the sweep journal's row decoder).
pub(crate) fn error_from_json(err: &Json) -> Result<ScenarioError> {
    let code = err
        .get("code")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("error needs \"code\""))?;
    let message = err.get("message").and_then(|v| v.as_str()).unwrap_or_default().to_string();
    let reason =
        err.get("reason").and_then(|v| v.as_str()).map(str::to_string).unwrap_or(message);
    let detail =
        |key: &str| err.get(key).and_then(|v| v.as_str()).unwrap_or_default().to_string();
    Ok(match code {
        "unknown_model" => ScenarioError::UnknownModel(detail("model")),
        "unknown_gpu" => ScenarioError::UnknownGpu(detail("gpu")),
        "invalid_parallelism" => ScenarioError::InvalidParallelism(reason),
        "invalid_workload" => ScenarioError::InvalidWorkload(reason),
        "malformed_spec" => ScenarioError::MalformedSpec(reason),
        "invalid_cluster" => ScenarioError::InvalidCluster(reason),
        other => anyhow::bail!("unknown error code {other:?}"),
    })
}

fn totals_from_json(j: &Json) -> Result<MethodTotals> {
    let mut t = MethodTotals::default();
    for m in Method::ALL {
        let key = format!("{}_sec", m.name());
        let v = j
            .get(&key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("totals field {key:?} missing"))?;
        t.set(m, v);
    }
    t.degraded_kernels = j
        .get("degraded_kernels")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("totals need \"degraded_kernels\""))? as usize;
    Ok(t)
}

fn breakdown_from_json(j: &Json) -> Result<ClassBreakdown> {
    let mut b = ClassBreakdown::default();
    for c in OpClass::ALL {
        let key = class_key(c);
        let v = j
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("breakdown field {key:?} missing"))?;
        b.set(c, v);
    }
    Ok(b)
}

fn phase_from_json(j: &Json) -> Result<PhaseReport> {
    let phase = j
        .get("phase")
        .and_then(|v| v.as_str())
        .and_then(Phase::from_name)
        .ok_or_else(|| anyhow!("bad phase"))?;
    Ok(PhaseReport {
        phase,
        totals: totals_from_json(j.get("totals").ok_or_else(|| anyhow!("phase needs totals"))?)?,
        breakdown: breakdown_from_json(
            j.get("breakdown").ok_or_else(|| anyhow!("phase needs breakdown"))?,
        )?,
        launches: f64_field(j, "launches")?,
        tokens: f64_field(j, "tokens")?,
        steps: f64_field(j, "steps")?,
    })
}

/// Parse one report line back into the typed result — the client half of
/// the wire, used by round-trip tests and remote tooling.
pub fn parse_report(
    line: &str,
) -> Result<(Option<String>, Result<ScenarioReport, ScenarioError>)> {
    let j = parse(line)?;
    let id = id_of(&j);
    let ok =
        j.get("ok").and_then(|v| v.as_bool()).ok_or_else(|| anyhow!("response needs \"ok\""))?;
    if !ok {
        let err = j.get("error").ok_or_else(|| anyhow!("error response needs \"error\""))?;
        return Ok((id, Err(error_from_json(err)?)));
    }
    let rep = j.get("report").ok_or_else(|| anyhow!("ok response needs a \"report\""))?;
    let phases = rep
        .get("phases")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("report needs \"phases\""))?
        .iter()
        .map(phase_from_json)
        .collect::<Result<Vec<PhaseReport>>>()?;
    Ok((
        id,
        Ok(ScenarioReport {
            model: str_field(rep, "model")?,
            gpu: str_field(rep, "gpu")?,
            tp: f64_field(rep, "tp")? as u32,
            pp: f64_field(rep, "pp")? as u32,
            phases,
            totals: totals_from_json(
                rep.get("totals").ok_or_else(|| anyhow!("report needs \"totals\""))?,
            )?,
            breakdown: breakdown_from_json(
                rep.get("breakdown").ok_or_else(|| anyhow!("report needs \"breakdown\""))?,
            )?,
            launches: f64_field(rep, "launches")?,
            cache_hits: f64_field(rep, "cache_hits")? as usize,
            host_gap_sec: f64_field(rep, "host_gap_sec")?,
            seed: seed_from(
                rep.get("seed").ok_or_else(|| anyhow!("report needs \"seed\""))?,
                "seed",
            )?,
        }),
    ))
}

// ---- cluster report (Scenario v2) -----------------------------------------

/// Sparse histogram encoding: fixed geometry up front (`lo_sec`,
/// `bins_per_decade`), exact `count`/`sum_sec`/`min_sec`/`max_sec`, then
/// only the non-zero `[index, count]` bins. Mergeable on the client by
/// summing bins. An empty histogram encodes zeros (never NaN) for the
/// float fields.
fn hist_to_json(h: &LogHistogram) -> String {
    let bins: Vec<String> = h.nonzero_bins().map(|(i, c)| format!("[{i},{c}]")).collect();
    let (sum, min, max) =
        if h.count() == 0 { (0.0, 0.0, 0.0) } else { (h.sum(), h.min(), h.max()) };
    format!(
        r#"{{"lo_sec":{:e},"bins_per_decade":{},"count":{},"sum_sec":{:e},"min_sec":{:e},"max_sec":{:e},"bins":[{}]}}"#,
        LogHistogram::LO,
        LogHistogram::BINS_PER_DECADE,
        h.count(),
        sum,
        min,
        max,
        bins.join(",")
    )
}

fn hist_from_json(j: &Json) -> Result<LogHistogram> {
    let arr = j
        .get("bins")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("histogram needs \"bins\""))?;
    let mut bins = Vec::with_capacity(arr.len());
    for row in arr {
        let p = row
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| anyhow!("histogram bins are [index,count] pairs"))?;
        let i = p[0]
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .ok_or_else(|| anyhow!("bad histogram bin index"))? as usize;
        let c = p[1]
            .as_f64()
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .ok_or_else(|| anyhow!("bad histogram bin count"))? as u64;
        bins.push((i, c));
    }
    LogHistogram::from_parts(
        &bins,
        f64_field(j, "sum_sec")?,
        f64_field(j, "min_sec")?,
        f64_field(j, "max_sec")?,
    )
    .ok_or_else(|| anyhow!("histogram bin index out of range"))
}

fn summary_to_json(s: &LatencySummary) -> String {
    format!(
        r#"{{"count":{},"mean_sec":{:e},"p50_sec":{:e},"p95_sec":{:e},"p99_sec":{:e},"max_sec":{:e}}}"#,
        s.count, s.mean_sec, s.p50_sec, s.p95_sec, s.p99_sec, s.max_sec
    )
}

fn summary_from_json(j: &Json) -> Result<LatencySummary> {
    Ok(LatencySummary {
        count: u64_field(j, "count")?,
        mean_sec: f64_field(j, "mean_sec")?,
        p50_sec: f64_field(j, "p50_sec")?,
        p95_sec: f64_field(j, "p95_sec")?,
        p99_sec: f64_field(j, "p99_sec")?,
        max_sec: f64_field(j, "max_sec")?,
    })
}

fn replica_to_json(r: &ReplicaReport) -> String {
    format!(
        r#"{{"completed":{},"steps":{},"prefill_steps":{},"busy_sec":{:e},"utilization":{:e},"peak_kv_tokens":{},"max_batch_seen":{}}}"#,
        r.completed,
        r.steps,
        r.prefill_steps,
        r.busy_sec,
        r.utilization,
        r.peak_kv_tokens,
        r.max_batch_seen
    )
}

fn replica_from_json(j: &Json) -> Result<ReplicaReport> {
    Ok(ReplicaReport {
        completed: u64_field(j, "completed")?,
        steps: u64_field(j, "steps")?,
        prefill_steps: u64_field(j, "prefill_steps")?,
        busy_sec: f64_field(j, "busy_sec")?,
        utilization: f64_field(j, "utilization")?,
        peak_kv_tokens: u64_field(j, "peak_kv_tokens")?,
        max_batch_seen: u64_field(j, "max_batch_seen")? as u32,
    })
}

fn cluster_report_to_json(r: &ClusterReport) -> String {
    let reps: Vec<String> = r.replicas.iter().map(replica_to_json).collect();
    format!(
        r#"{{"cluster":true,"model":"{}","gpu":"{}","tp":{},"pp":{},"policy":"{}","seed":{},"host_gap_sec":{:e},"offered":{},"completed":{},"makespan_sec":{:e},"generated_tokens":{:e},"tokens_per_sec":{:e},"requests_per_sec":{:e},"ttft":{},"tpot":{},"queue_delay":{},"ttft_hist":{},"tpot_hist":{},"queue_hist":{},"slo":{{"ttft_attainment":{:e},"tpot_attainment":{:e},"attainment":{:e}}},"replicas":[{}],"degraded_kernels":{},"distinct_steps":{},"events":{}}}"#,
        esc(&r.model),
        esc(&r.gpu),
        r.tp,
        r.pp,
        r.policy.name(),
        seed_to_json(r.seed),
        r.host_gap_sec,
        r.offered,
        r.completed,
        r.makespan_sec,
        r.generated_tokens,
        r.tokens_per_sec,
        r.requests_per_sec,
        summary_to_json(&r.ttft),
        summary_to_json(&r.tpot),
        summary_to_json(&r.queue_delay),
        hist_to_json(&r.ttft_hist),
        hist_to_json(&r.tpot_hist),
        hist_to_json(&r.queue_hist),
        r.slo_ttft_attainment,
        r.slo_tpot_attainment,
        r.slo_attainment,
        reps.join(","),
        r.degraded_kernels,
        r.distinct_steps,
        r.events
    )
}

/// Serialize one cluster simulate result into its wire line (no trailing
/// newline). The report object leads with `"cluster":true` so clients can
/// discriminate v2 reports from v1 without schema knowledge.
pub fn encode_cluster_report(
    id: Option<&str>,
    res: &Result<ClusterReport, ScenarioError>,
) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(r) => out.push_str(&format!(",\"ok\":true,\"report\":{}", cluster_report_to_json(r))),
        Err(e) => out.push_str(&format!(",\"ok\":false,\"error\":{}", error_to_json(e))),
    }
    out.push('}');
    out
}

/// Parse one cluster report line back into the typed result — the client
/// half of the v2 wire, used by round-trip tests and remote tooling.
pub fn parse_cluster_report(
    line: &str,
) -> Result<(Option<String>, Result<ClusterReport, ScenarioError>)> {
    let j = parse(line)?;
    let id = id_of(&j);
    let ok =
        j.get("ok").and_then(|v| v.as_bool()).ok_or_else(|| anyhow!("response needs \"ok\""))?;
    if !ok {
        let err = j.get("error").ok_or_else(|| anyhow!("error response needs \"error\""))?;
        return Ok((id, Err(error_from_json(err)?)));
    }
    let rep = j.get("report").ok_or_else(|| anyhow!("ok response needs a \"report\""))?;
    if rep.get("cluster").and_then(|v| v.as_bool()) != Some(true) {
        anyhow::bail!("not a cluster report (missing \"cluster\":true)");
    }
    let policy_name = str_field(rep, "policy")?;
    let policy = RoutePolicy::from_name(&policy_name)
        .ok_or_else(|| anyhow!("unknown policy {policy_name:?}"))?;
    let slo = rep.get("slo").ok_or_else(|| anyhow!("cluster report needs \"slo\""))?;
    let replicas = rep
        .get("replicas")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("cluster report needs \"replicas\""))?
        .iter()
        .map(replica_from_json)
        .collect::<Result<Vec<ReplicaReport>>>()?;
    let sub = |key: &str| rep.get(key).ok_or_else(|| anyhow!("cluster report needs {key:?}"));
    Ok((
        id,
        Ok(ClusterReport {
            model: str_field(rep, "model")?,
            gpu: str_field(rep, "gpu")?,
            tp: f64_field(rep, "tp")? as u32,
            pp: f64_field(rep, "pp")? as u32,
            policy,
            seed: seed_from(
                rep.get("seed").ok_or_else(|| anyhow!("report needs \"seed\""))?,
                "seed",
            )?,
            host_gap_sec: f64_field(rep, "host_gap_sec")?,
            offered: u64_field(rep, "offered")?,
            completed: u64_field(rep, "completed")?,
            makespan_sec: f64_field(rep, "makespan_sec")?,
            generated_tokens: f64_field(rep, "generated_tokens")?,
            tokens_per_sec: f64_field(rep, "tokens_per_sec")?,
            requests_per_sec: f64_field(rep, "requests_per_sec")?,
            ttft: summary_from_json(sub("ttft")?)?,
            tpot: summary_from_json(sub("tpot")?)?,
            queue_delay: summary_from_json(sub("queue_delay")?)?,
            ttft_hist: hist_from_json(sub("ttft_hist")?)?,
            tpot_hist: hist_from_json(sub("tpot_hist")?)?,
            queue_hist: hist_from_json(sub("queue_hist")?)?,
            slo_ttft_attainment: f64_field(slo, "ttft_attainment")?,
            slo_tpot_attainment: f64_field(slo, "tpot_attainment")?,
            slo_attainment: f64_field(slo, "attainment")?,
            replicas,
            degraded_kernels: f64_field(rep, "degraded_kernels")? as usize,
            distinct_steps: f64_field(rep, "distinct_steps")? as usize,
            events: u64_field(rep, "events")?,
        }),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_round_trip_both_workload_shapes() {
        let sampled = ScenarioSpec::new("Qwen3-32B", "H800")
            .tp(8)
            .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Splitwise, batch: 48 })
            .phases(PhaseSelection::DecodeOnly)
            .seed(123)
            .host_gap_sec(1.25e-6);
        let explicit = ScenarioSpec::new("Llama3.1-8B", "A100")
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 1000, output_len: 200 },
                Request { input_len: 2000, output_len: 100 },
            ]));
        for spec in [sampled, explicit] {
            let line = encode_simulate_request(Some("x"), &spec);
            assert!(is_simulate_request(&line), "{line}");
            let (id, parsed) = parse_simulate_request(&line);
            assert_eq!(id.as_deref(), Some("x"));
            assert_eq!(parsed.unwrap(), spec, "round trip of {line}");
        }
    }

    #[test]
    fn bare_spec_objects_parse_too() {
        let (_, spec) = parse_spec_line(r#"{"model":"qwen2.5-14b","gpu":"A100","tp":2}"#);
        let spec = spec.unwrap();
        assert_eq!(spec.model, "qwen2.5-14b");
        assert_eq!(spec.tp, 2);
        assert_eq!(spec.host_gap_sec, crate::scenario::HOST_GAP_SEC);
    }

    #[test]
    fn malformed_lines_map_into_the_taxonomy() {
        let cases = [
            ("not json at all", "malformed_spec"),
            (r#"{"op":"simulate"}"#, "malformed_spec"),
            (r#"{"v":9,"op":"simulate","scenario":{"model":"a","gpu":"b"}}"#, "malformed_spec"),
            (r#"{"op":"simulate","scenario":{"gpu":"A100"}}"#, "malformed_spec"),
            (
                r#"{"op":"simulate","scenario":{"model":"a","gpu":"b","workload":{"kind":"mmlu"}}}"#,
                "invalid_workload",
            ),
            (
                r#"{"op":"simulate","scenario":{"model":"a","gpu":"b","tp":1.5}}"#,
                "malformed_spec",
            ),
        ];
        for (line, code) in cases {
            let (_, res) = parse_simulate_request(line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn large_seeds_round_trip_as_strings() {
        // above 2^53 a JSON number would lose bits in the f64 parser, so
        // the codec switches to a string — and accepts both shapes
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100").seed(u64::MAX);
        let line = encode_simulate_request(None, &spec);
        assert!(line.contains(r#""seed":"18446744073709551615""#), "{line}");
        let (_, back) = parse_simulate_request(&line);
        assert_eq!(back.unwrap().seed, u64::MAX);
        // small seeds stay plain numbers (golden-line compatible)
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100").seed(7);
        assert!(encode_simulate_request(None, &spec).contains(r#""seed":7,"#));
    }

    #[test]
    fn predict_lines_are_not_claimed() {
        assert!(!is_simulate_request(
            r#"{"gpu":"A100","kernel":{"type":"gemm","m":1,"n":1,"k":1}}"#
        ));
        assert!(!is_simulate_request("garbage"));
        assert!(is_simulate_request(r#"{"scenario":{"model":"m","gpu":"g"}}"#));
        assert!(is_simulate_request(r#"{"cluster":{"model":"m","gpu":"g"}}"#));
    }

    #[test]
    fn cluster_requests_round_trip_every_arrival_shape() {
        let trace = ClusterSpec::new("Llama3.1-8B", "A100")
            .replicas(2)
            .policy(RoutePolicy::SessionAffinity)
            .arrivals(ArrivalSpec::Trace(vec![
                ClusterRequest { arrival_sec: 0.0, input_len: 1000, output_len: 200, session: 0 },
                ClusterRequest {
                    arrival_sec: 0.5,
                    input_len: 600,
                    output_len: 100,
                    session: u64::MAX,
                },
            ]))
            .max_batch(8)
            .kv_capacity_tokens(65_536)
            .kv_quant(32)
            .seed(9)
            .slo(1.5, 0.1);
        let poisson = ClusterSpec::new("Qwen2.5-14B", "H800").arrivals(ArrivalSpec::Poisson {
            rate_rps: 8.0,
            n: 32,
            kind: WorkloadKind::Splitwise,
        });
        let uniform = ClusterSpec::new("Qwen3-32B", "A100")
            .policy(RoutePolicy::LeastLoaded)
            .arrivals(ArrivalSpec::Uniform { gap_sec: 0.25, n: 4, kind: WorkloadKind::Arxiv });
        for spec in [trace, poisson, uniform] {
            let line = encode_cluster_request(Some("c"), &spec);
            assert!(is_simulate_request(&line), "{line}");
            let (id, parsed) = parse_request_line(&line);
            assert_eq!(id.as_deref(), Some("c"));
            assert_eq!(parsed.unwrap(), SimulateRequest::Cluster(spec), "round trip of {line}");
        }
    }

    #[test]
    fn request_parser_still_speaks_v1_shapes() {
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100").tp(2);
        let line = encode_simulate_request(Some("s"), &spec);
        let (_, parsed) = parse_request_line(&line);
        assert_eq!(parsed.unwrap(), SimulateRequest::Scenario(spec));
        // bare objects stay scenario unless wrapped in "cluster"
        let (_, bare) = parse_request_line(r#"{"model":"Qwen2.5-14B","gpu":"A100"}"#);
        assert!(matches!(bare.unwrap(), SimulateRequest::Scenario(_)));
        let (_, wrapped) = parse_request_line(r#"{"cluster":{"model":"m","gpu":"g"}}"#);
        assert!(matches!(wrapped.unwrap(), SimulateRequest::Cluster(_)));
    }

    #[test]
    fn cluster_reports_round_trip_over_the_wire() {
        let sim = crate::scenario::Simulator::degraded();
        let spec = ClusterSpec::new("Llama3.1-8B", "A100")
            .replicas(2)
            .arrivals(ArrivalSpec::Trace(vec![
                ClusterRequest { arrival_sec: 0.0, input_len: 128, output_len: 8, session: 0 },
                ClusterRequest { arrival_sec: 0.001, input_len: 96, output_len: 1, session: 1 },
            ]))
            .kv_capacity_tokens(4096);
        let res = sim.simulate_cluster(&spec);
        assert!(res.is_ok());
        let line = encode_cluster_report(Some("c1"), &res);
        assert!(line.contains(r#""cluster":true"#), "{line}");
        let (id, back) = parse_cluster_report(&line).unwrap();
        assert_eq!(id.as_deref(), Some("c1"));
        let back = back.unwrap();
        assert_eq!(back, res.unwrap(), "typed round trip of {line}");
        // re-encoding the parsed report is byte-identical (canonical form)
        assert_eq!(encode_cluster_report(Some("c1"), &Ok(back)), line);
    }

    #[test]
    fn cluster_errors_ride_the_closed_taxonomy() {
        let sim = crate::scenario::Simulator::degraded();
        let res = sim.simulate_cluster(&ClusterSpec::new("Llama3.1-8B", "A100").replicas(0));
        let line = encode_cluster_report(None, &res);
        assert!(line.contains(r#""code":"invalid_cluster""#), "{line}");
        let (_, back) = parse_cluster_report(&line).unwrap();
        assert!(matches!(back.unwrap_err(), ScenarioError::InvalidCluster(_)));
        // malformed cluster objects keep the malformed_spec bucket
        let (_, parsed) = parse_request_line(r#"{"cluster":{"gpu":"A100"}}"#);
        assert_eq!(parsed.unwrap_err().code(), "malformed_spec");
        let (_, parsed) =
            parse_request_line(r#"{"cluster":{"model":"m","gpu":"g","policy":"random"}}"#);
        assert_eq!(parsed.unwrap_err().code(), "invalid_cluster");
        let (_, parsed) =
            parse_request_line(r#"{"cluster":{"model":"m","gpu":"g","arrivals":{"burst":{}}}}"#);
        assert_eq!(parsed.unwrap_err().code(), "malformed_spec");
    }

    #[test]
    fn empty_histograms_encode_zeros_not_nan() {
        let h = LogHistogram::new();
        let line = hist_to_json(&h);
        assert!(!line.contains("NaN") && !line.contains("null"), "{line}");
        let back = hist_from_json(&parse(&line).unwrap()).unwrap();
        assert_eq!(back, h);
        let mut h = LogHistogram::new();
        h.insert(0.002);
        h.insert(0.75);
        let back = hist_from_json(&parse(&hist_to_json(&h)).unwrap()).unwrap();
        assert_eq!(back, h);
        assert_eq!(back.percentile(50.0), h.percentile(50.0));
    }
}
