//! JSONL wire codec for the **`simulate` verb** of Scenario API v1: a
//! request line carries a [`ScenarioSpec`], the response line a
//! [`ScenarioReport`] (or a closed-taxonomy [`ScenarioError`]). The same
//! lines ride `synperf simulate` and `synperf serve --stdio` (which
//! dispatches per line between the `predict` and `simulate` verbs).
//!
//! Request line:
//!
//! ```json
//! {"v":1,"id":"s1","op":"simulate","scenario":{"model":"Qwen2.5-14B",
//!  "gpu":"A100","tp":2,"pp":1,"workload":{"kind":"arxiv","batch":8},
//!  "phases":"both","seed":7,"host_gap_sec":8e-7}}
//! ```
//!
//! `scenario.model` and `scenario.gpu` are required; everything else is
//! optional with the defaults shown. An explicit request mix replaces the
//! sampled workload: `"workload":{"requests":[[1000,200],[2000,100]]}`.
//! The response carries per-phase TTFT/TPOT/tokens-per-second, per-method
//! totals, the typed per-class breakdown, and provenance counts:
//!
//! ```json
//! {"v":1,"id":"s1","ok":true,"report":{"model":"Qwen2.5-14B","gpu":"A100",
//!  "tp":2,"pp":1,"seed":7,"host_gap_sec":8e-7,"launches":4.4e2,
//!  "cache_hits":40,"totals":{...,"degraded_kernels":44},"breakdown":{...},
//!  "phases":[{"phase":"prefill","ttft_sec":{...},...},...]}}
//! {"v":1,"id":"s2","ok":false,"error":{"code":"unknown_model",
//!  "message":"unknown model \"GPT-5\" (see llm::registry())","model":"GPT-5"}}
//! ```
//!
//! Malformed lines map to [`ScenarioError::MalformedSpec`] (mirroring the
//! predict verb's malformed-request bucket).

use super::{
    ClassBreakdown, Method, MethodTotals, OpClass, Phase, PhaseReport, PhaseSelection,
    ScenarioError, ScenarioReport, ScenarioSpec, WorkloadSpec,
};
use crate::api::wire::{esc, id_of};
use crate::api::PROTOCOL_VERSION;
use crate::e2e::workload::{Request, WorkloadKind};
use crate::util::json::{parse, Json};
use anyhow::{anyhow, Result};

fn malformed(why: impl Into<String>) -> ScenarioError {
    ScenarioError::MalformedSpec(why.into())
}

fn num_u32(v: &Json, what: &str) -> Result<u32, ScenarioError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64)
        .map(|n| n as u32)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

fn num_u64(v: &Json, what: &str) -> Result<u64, ScenarioError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64)
        .map(|n| n as u64)
        .ok_or_else(|| malformed(format!("{what:?} must be an unsigned integer")))
}

/// Seeds are u64, but JSON numbers only survive the f64-based parser up to
/// 2^53 — larger seeds travel as strings so the codec round-trips its own
/// output for every value. [`seed_from`] accepts both shapes.
fn seed_to_json(seed: u64) -> String {
    if seed <= (1u64 << 53) {
        format!("{seed}")
    } else {
        format!("\"{seed}\"")
    }
}

fn seed_from(v: &Json, what: &str) -> Result<u64, ScenarioError> {
    match v {
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| malformed(format!("{what:?} must be a u64"))),
        _ => num_u64(v, what),
    }
}

// ---- spec ----------------------------------------------------------------

fn spec_to_json(spec: &ScenarioSpec) -> String {
    let workload = match &spec.workload {
        WorkloadSpec::Sampled { kind, batch } => {
            format!(r#"{{"kind":"{}","batch":{}}}"#, kind.name(), batch)
        }
        WorkloadSpec::Explicit(reqs) => {
            let pairs: Vec<String> =
                reqs.iter().map(|r| format!("[{},{}]", r.input_len, r.output_len)).collect();
            format!(r#"{{"requests":[{}]}}"#, pairs.join(","))
        }
    };
    format!(
        r#"{{"model":"{}","gpu":"{}","tp":{},"pp":{},"workload":{},"phases":"{}","seed":{},"host_gap_sec":{:e}}}"#,
        esc(&spec.model),
        esc(&spec.gpu),
        spec.tp,
        spec.pp,
        workload,
        spec.phases.name(),
        seed_to_json(spec.seed),
        spec.host_gap_sec
    )
}

/// Serialize a simulate request into its canonical wire line (no trailing
/// newline). The inverse of [`parse_simulate_request`].
pub fn encode_simulate_request(id: Option<&str>, spec: &ScenarioSpec) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(",\"op\":\"simulate\",\"scenario\":{}", spec_to_json(spec)));
    out.push('}');
    out
}

/// Parse one bare `scenario` object into a spec.
fn parse_spec_object(j: &Json) -> Result<ScenarioSpec, ScenarioError> {
    let model = j
        .get("model")
        .and_then(|v| v.as_str())
        .ok_or_else(|| malformed("scenario needs \"model\": \"<name>\""))?;
    let gpu = j
        .get("gpu")
        .and_then(|v| v.as_str())
        .ok_or_else(|| malformed("scenario needs \"gpu\": \"<name>\""))?;
    let mut spec = ScenarioSpec::new(model, gpu);
    if let Some(v) = j.get("tp") {
        spec.tp = num_u32(v, "tp")?;
    }
    if let Some(v) = j.get("pp") {
        spec.pp = num_u32(v, "pp")?;
    }
    if let Some(w) = j.get("workload") {
        spec.workload = if let Some(rs) = w.get("requests") {
            let arr = rs
                .as_arr()
                .ok_or_else(|| malformed("\"requests\" must be an array of [input,output] pairs"))?;
            let mut reqs = Vec::with_capacity(arr.len());
            for pair in arr {
                let p = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| malformed("request entries are [input,output] pairs"))?;
                reqs.push(Request {
                    input_len: num_u32(&p[0], "input_len")?,
                    output_len: num_u32(&p[1], "output_len")?,
                });
            }
            WorkloadSpec::Explicit(reqs)
        } else {
            let kind = match w.get("kind") {
                None => WorkloadKind::Arxiv,
                Some(v) => super::workload_kind(
                    v.as_str().ok_or_else(|| malformed("\"kind\" must be a string"))?,
                )?,
            };
            let batch = match w.get("batch") {
                None => 8,
                // saturate rather than wrap on 32-bit targets: the
                // compiler's MAX_BATCH cap owns the rejection either way
                Some(v) => usize::try_from(num_u64(v, "batch")?).unwrap_or(usize::MAX),
            };
            WorkloadSpec::Sampled { kind, batch }
        };
    }
    if let Some(v) = j.get("phases") {
        let name = v.as_str().ok_or_else(|| malformed("\"phases\" must be a string"))?;
        spec.phases = PhaseSelection::parse(name)?;
    }
    if let Some(v) = j.get("seed") {
        spec.seed = seed_from(v, "seed")?;
    }
    if let Some(v) = j.get("host_gap_sec") {
        spec.host_gap_sec =
            v.as_f64().ok_or_else(|| malformed("\"host_gap_sec\" must be a number"))?;
    }
    Ok(spec)
}

fn simulate_fields(j: &Json) -> Result<ScenarioSpec, ScenarioError> {
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        if v as u32 != PROTOCOL_VERSION {
            return Err(malformed(format!(
                "protocol version {v} (this build speaks v{PROTOCOL_VERSION})"
            )));
        }
    }
    let sc = j
        .get("scenario")
        .ok_or_else(|| malformed("simulate request needs a \"scenario\" object"))?;
    parse_spec_object(sc)
}

/// Parse one simulate request line (the `{"op":"simulate","scenario":{..}}`
/// envelope). The extracted `id` (if any) is returned even when parsing
/// fails, so the error response can still be correlated.
pub fn parse_simulate_request(line: &str) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    parse_simulate_json(&j)
}

/// Envelope parse over an already-decoded line (single-parse dispatch).
pub(crate) fn parse_simulate_json(
    j: &Json,
) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    (id_of(j), simulate_fields(j))
}

/// Parse a spec line in either shape: the wire envelope or a bare
/// `scenario` object (`{"model":..,"gpu":..}`) — what `synperf simulate
/// --spec` accepts.
pub fn parse_spec_line(line: &str) -> (Option<String>, Result<ScenarioSpec, ScenarioError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    let res = if j.get("scenario").is_some() || j.get("op").is_some() {
        simulate_fields(&j)
    } else {
        parse_spec_object(&j)
    };
    (id_of(&j), res)
}

/// Whether a decoded wire object addresses the simulate verb (vs the
/// predict verb).
pub(crate) fn is_simulate_json(j: &Json) -> bool {
    j.get("op").and_then(|v| v.as_str()) == Some("simulate") || j.get("scenario").is_some()
}

/// Whether a wire line addresses the simulate verb (vs the predict verb).
/// Malformed JSON is not claimed — the predict codec owns that bucket, so
/// pre-scenario peers see unchanged error lines.
pub fn is_simulate_request(line: &str) -> bool {
    match parse(line) {
        Ok(j) => is_simulate_json(&j),
        Err(_) => false,
    }
}

// ---- report --------------------------------------------------------------

fn totals_to_json(t: &MethodTotals) -> String {
    format!(
        r#"{{"actual_sec":{:e},"synperf_sec":{:e},"roofline_sec":{:e},"linear_sec":{:e},"habitat_sec":{:e},"neusight_sec":{:e},"degraded_kernels":{}}}"#,
        t.actual, t.synperf, t.roofline, t.linear, t.habitat, t.neusight, t.degraded_kernels
    )
}

/// Breakdown keys are `<class>_sec` — except the host-gap aggregate,
/// which travels as `host_gap_total_sec` so a flat key-scan can never
/// confuse it with the per-launch `host_gap_sec` spec/report parameter.
fn class_key(c: OpClass) -> &'static str {
    match c {
        OpClass::Gemm => "gemm_sec",
        OpClass::Attention => "attention_sec",
        OpClass::RmsNorm => "rmsnorm_sec",
        OpClass::SiluMul => "silu_mul_sec",
        OpClass::FusedMoe => "fused_moe_sec",
        OpClass::AllReduce => "all_reduce_sec",
        OpClass::SendRecv => "send_recv_sec",
        OpClass::HostGap => "host_gap_total_sec",
    }
}

fn breakdown_to_json(b: &ClassBreakdown) -> String {
    let fields: Vec<String> = OpClass::ALL
        .iter()
        .map(|c| format!(r#""{}":{:e}"#, class_key(*c), b.get(*c)))
        .collect();
    format!("{{{}}}", fields.join(","))
}

fn phase_to_json(p: &PhaseReport) -> String {
    let mut out = format!(
        r#"{{"phase":"{}","tokens":{:e},"steps":{:e},"launches":{:e}"#,
        p.phase.name(),
        p.tokens,
        p.steps,
        p.launches
    );
    match p.phase {
        Phase::Prefill => out.push_str(&format!(
            r#","ttft_sec":{{"actual":{:e},"synperf":{:e}}}"#,
            p.ttft_sec(Method::Actual).unwrap_or(0.0),
            p.ttft_sec(Method::SynPerf).unwrap_or(0.0)
        )),
        Phase::Decode => out.push_str(&format!(
            r#","tpot_sec":{{"actual":{:e},"synperf":{:e}}}"#,
            p.tpot_sec(Method::Actual).unwrap_or(0.0),
            p.tpot_sec(Method::SynPerf).unwrap_or(0.0)
        )),
    }
    out.push_str(&format!(
        r#","tokens_per_sec":{{"actual":{:e},"synperf":{:e}}}"#,
        p.tokens_per_sec(Method::Actual),
        p.tokens_per_sec(Method::SynPerf)
    ));
    out.push_str(&format!(
        r#","totals":{},"breakdown":{}}}"#,
        totals_to_json(&p.totals),
        breakdown_to_json(&p.breakdown)
    ));
    out
}

fn report_to_json(r: &ScenarioReport) -> String {
    let phases: Vec<String> = r.phases.iter().map(phase_to_json).collect();
    format!(
        r#"{{"model":"{}","gpu":"{}","tp":{},"pp":{},"seed":{},"host_gap_sec":{:e},"launches":{:e},"cache_hits":{},"totals":{},"breakdown":{},"phases":[{}]}}"#,
        esc(&r.model),
        esc(&r.gpu),
        r.tp,
        r.pp,
        seed_to_json(r.seed),
        r.host_gap_sec,
        r.launches,
        r.cache_hits,
        totals_to_json(&r.totals),
        breakdown_to_json(&r.breakdown),
        phases.join(",")
    )
}

/// Serialize one simulate result into its wire line (no trailing newline).
pub fn encode_report(id: Option<&str>, res: &Result<ScenarioReport, ScenarioError>) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(r) => out.push_str(&format!(",\"ok\":true,\"report\":{}", report_to_json(r))),
        Err(e) => {
            out.push_str(&format!(
                ",\"ok\":false,\"error\":{{\"code\":\"{}\",\"message\":\"{}\"",
                e.code(),
                esc(&e.to_string())
            ));
            match e {
                ScenarioError::UnknownModel(name) => {
                    out.push_str(&format!(",\"model\":\"{}\"", esc(name)));
                }
                ScenarioError::UnknownGpu(name) => {
                    out.push_str(&format!(",\"gpu\":\"{}\"", esc(name)));
                }
                ScenarioError::InvalidParallelism(why)
                | ScenarioError::InvalidWorkload(why)
                | ScenarioError::MalformedSpec(why) => {
                    out.push_str(&format!(",\"reason\":\"{}\"", esc(why)));
                }
            }
            out.push('}');
        }
    }
    out.push('}');
    out
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    j.get(key).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("report field {key:?} missing"))
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("report field {key:?} missing"))
}

fn totals_from_json(j: &Json) -> Result<MethodTotals> {
    let mut t = MethodTotals::default();
    for m in Method::ALL {
        let key = format!("{}_sec", m.name());
        let v = j
            .get(&key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("totals field {key:?} missing"))?;
        t.set(m, v);
    }
    t.degraded_kernels = j
        .get("degraded_kernels")
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("totals need \"degraded_kernels\""))? as usize;
    Ok(t)
}

fn breakdown_from_json(j: &Json) -> Result<ClassBreakdown> {
    let mut b = ClassBreakdown::default();
    for c in OpClass::ALL {
        let key = class_key(c);
        let v = j
            .get(key)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("breakdown field {key:?} missing"))?;
        b.set(c, v);
    }
    Ok(b)
}

fn phase_from_json(j: &Json) -> Result<PhaseReport> {
    let phase = j
        .get("phase")
        .and_then(|v| v.as_str())
        .and_then(Phase::from_name)
        .ok_or_else(|| anyhow!("bad phase"))?;
    Ok(PhaseReport {
        phase,
        totals: totals_from_json(j.get("totals").ok_or_else(|| anyhow!("phase needs totals"))?)?,
        breakdown: breakdown_from_json(
            j.get("breakdown").ok_or_else(|| anyhow!("phase needs breakdown"))?,
        )?,
        launches: f64_field(j, "launches")?,
        tokens: f64_field(j, "tokens")?,
        steps: f64_field(j, "steps")?,
    })
}

/// Parse one report line back into the typed result — the client half of
/// the wire, used by round-trip tests and remote tooling.
pub fn parse_report(
    line: &str,
) -> Result<(Option<String>, Result<ScenarioReport, ScenarioError>)> {
    let j = parse(line)?;
    let id = id_of(&j);
    let ok =
        j.get("ok").and_then(|v| v.as_bool()).ok_or_else(|| anyhow!("response needs \"ok\""))?;
    if !ok {
        let err = j.get("error").ok_or_else(|| anyhow!("error response needs \"error\""))?;
        let code = err
            .get("code")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("error needs \"code\""))?;
        let message =
            err.get("message").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let reason =
            err.get("reason").and_then(|v| v.as_str()).map(str::to_string).unwrap_or(message);
        let detail = |key: &str| {
            err.get(key).and_then(|v| v.as_str()).unwrap_or_default().to_string()
        };
        let e = match code {
            "unknown_model" => ScenarioError::UnknownModel(detail("model")),
            "unknown_gpu" => ScenarioError::UnknownGpu(detail("gpu")),
            "invalid_parallelism" => ScenarioError::InvalidParallelism(reason),
            "invalid_workload" => ScenarioError::InvalidWorkload(reason),
            "malformed_spec" => ScenarioError::MalformedSpec(reason),
            other => anyhow::bail!("unknown error code {other:?}"),
        };
        return Ok((id, Err(e)));
    }
    let rep = j.get("report").ok_or_else(|| anyhow!("ok response needs a \"report\""))?;
    let phases = rep
        .get("phases")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow!("report needs \"phases\""))?
        .iter()
        .map(phase_from_json)
        .collect::<Result<Vec<PhaseReport>>>()?;
    Ok((
        id,
        Ok(ScenarioReport {
            model: str_field(rep, "model")?,
            gpu: str_field(rep, "gpu")?,
            tp: f64_field(rep, "tp")? as u32,
            pp: f64_field(rep, "pp")? as u32,
            phases,
            totals: totals_from_json(
                rep.get("totals").ok_or_else(|| anyhow!("report needs \"totals\""))?,
            )?,
            breakdown: breakdown_from_json(
                rep.get("breakdown").ok_or_else(|| anyhow!("report needs \"breakdown\""))?,
            )?,
            launches: f64_field(rep, "launches")?,
            cache_hits: f64_field(rep, "cache_hits")? as usize,
            host_gap_sec: f64_field(rep, "host_gap_sec")?,
            seed: seed_from(
                rep.get("seed").ok_or_else(|| anyhow!("report needs \"seed\""))?,
                "seed",
            )?,
        }),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_lines_round_trip_both_workload_shapes() {
        let sampled = ScenarioSpec::new("Qwen3-32B", "H800")
            .tp(8)
            .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Splitwise, batch: 48 })
            .phases(PhaseSelection::DecodeOnly)
            .seed(123)
            .host_gap_sec(1.25e-6);
        let explicit = ScenarioSpec::new("Llama3.1-8B", "A100")
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 1000, output_len: 200 },
                Request { input_len: 2000, output_len: 100 },
            ]));
        for spec in [sampled, explicit] {
            let line = encode_simulate_request(Some("x"), &spec);
            assert!(is_simulate_request(&line), "{line}");
            let (id, parsed) = parse_simulate_request(&line);
            assert_eq!(id.as_deref(), Some("x"));
            assert_eq!(parsed.unwrap(), spec, "round trip of {line}");
        }
    }

    #[test]
    fn bare_spec_objects_parse_too() {
        let (_, spec) = parse_spec_line(r#"{"model":"qwen2.5-14b","gpu":"A100","tp":2}"#);
        let spec = spec.unwrap();
        assert_eq!(spec.model, "qwen2.5-14b");
        assert_eq!(spec.tp, 2);
        assert_eq!(spec.host_gap_sec, crate::scenario::HOST_GAP_SEC);
    }

    #[test]
    fn malformed_lines_map_into_the_taxonomy() {
        let cases = [
            ("not json at all", "malformed_spec"),
            (r#"{"op":"simulate"}"#, "malformed_spec"),
            (r#"{"v":9,"op":"simulate","scenario":{"model":"a","gpu":"b"}}"#, "malformed_spec"),
            (r#"{"op":"simulate","scenario":{"gpu":"A100"}}"#, "malformed_spec"),
            (
                r#"{"op":"simulate","scenario":{"model":"a","gpu":"b","workload":{"kind":"mmlu"}}}"#,
                "invalid_workload",
            ),
            (
                r#"{"op":"simulate","scenario":{"model":"a","gpu":"b","tp":1.5}}"#,
                "malformed_spec",
            ),
        ];
        for (line, code) in cases {
            let (_, res) = parse_simulate_request(line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn large_seeds_round_trip_as_strings() {
        // above 2^53 a JSON number would lose bits in the f64 parser, so
        // the codec switches to a string — and accepts both shapes
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100").seed(u64::MAX);
        let line = encode_simulate_request(None, &spec);
        assert!(line.contains(r#""seed":"18446744073709551615""#), "{line}");
        let (_, back) = parse_simulate_request(&line);
        assert_eq!(back.unwrap().seed, u64::MAX);
        // small seeds stay plain numbers (golden-line compatible)
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100").seed(7);
        assert!(encode_simulate_request(None, &spec).contains(r#""seed":7,"#));
    }

    #[test]
    fn predict_lines_are_not_claimed() {
        assert!(!is_simulate_request(
            r#"{"gpu":"A100","kernel":{"type":"gemm","m":1,"n":1,"k":1}}"#
        ));
        assert!(!is_simulate_request("garbage"));
        assert!(is_simulate_request(r#"{"scenario":{"model":"m","gpu":"g"}}"#));
    }
}
