//! The scenario compiler: validates a [`ScenarioSpec`] against the closed
//! [`ScenarioError`] taxonomy and lowers it to phase-tagged kernel/comm op
//! streams. Compilation is deterministic in the spec (workload sampling is
//! seeded by `spec.seed`) and pure — no prediction or oracle work happens
//! here, so compiling is cheap enough to sweep (see `benches/hot_paths.rs`,
//! `scenario/compile`).

use super::{Phase, PhaseSelection, ScenarioError, ScenarioSpec, WorkloadSpec};
use crate::e2e::llm::{self, LlmConfig};
use crate::e2e::trace::{self, Op, TraceItem};
use crate::e2e::workload::{sample_batch, Request};
use crate::hw::{gpu_by_name, GpuSpec};
use crate::util::rng::Rng;

/// One phase-tagged op stream.
#[derive(Debug, Clone)]
pub struct PhaseStream {
    pub phase: Phase,
    /// Index of this stream's first op within the full two-phase op-seed
    /// stream. Phase-stable: a decode-only (disaggregated) run draws the
    /// same per-op oracle seeds as the decode phase of a colocated run of
    /// the same spec, so the two are directly comparable.
    pub seed_base: usize,
    pub items: Vec<TraceItem>,
}

/// A lowered scenario: resolved model + GPU, the materialized request mix,
/// and the op streams in execution order. Everything the evaluator needs.
#[derive(Debug, Clone)]
pub struct CompiledScenario {
    pub llm: LlmConfig,
    pub gpu: GpuSpec,
    pub tp: u32,
    pub pp: u32,
    pub requests: Vec<Request>,
    pub phases: Vec<PhaseStream>,
    pub host_gap_sec: f64,
    pub seed: u64,
}

impl CompiledScenario {
    /// Total kernel-launch count, accumulated in stream order (matches
    /// [`trace::launch_count`] over the concatenated trace bit for bit).
    pub fn launch_count(&self) -> f64 {
        let mut total = 0.0;
        for stream in &self.phases {
            for item in &stream.items {
                if matches!(item.op, Op::Kernel(_)) {
                    total += item.count;
                }
            }
        }
        total
    }

    /// Total op items across phases.
    pub fn num_items(&self) -> usize {
        self.phases.iter().map(|p| p.items.len()).sum()
    }
}

/// Resolve model + GPU names with the closed taxonomy — shared by the v1
/// compiler and the cluster (Scenario v2) compiler so the two surfaces
/// report identical errors.
pub(crate) fn resolve_model_gpu(
    model: &str,
    gpu: &str,
) -> Result<(LlmConfig, GpuSpec), ScenarioError> {
    let llm =
        llm::llm_by_name(model).ok_or_else(|| ScenarioError::UnknownModel(model.to_string()))?;
    let g = gpu_by_name(gpu).ok_or_else(|| ScenarioError::UnknownGpu(gpu.to_string()))?;
    Ok((llm, g))
}

pub(crate) fn validate_parallelism(llm: &LlmConfig, tp: u32, pp: u32) -> Result<(), ScenarioError> {
    let bad = |why: String| Err(ScenarioError::InvalidParallelism(why));
    if tp == 0 || pp == 0 {
        return bad(format!("tp and pp must be >= 1, got tp={tp} pp={pp}"));
    }
    if llm.heads % tp != 0 {
        return bad(format!(
            "tp={tp} does not divide {} attention heads of {}",
            llm.heads, llm.name
        ));
    }
    if pp > llm.layers {
        return bad(format!("pp={pp} exceeds the {} layers of {}", llm.layers, llm.name));
    }
    Ok(())
}

/// Largest accepted request batch. The simulate verb is a wire surface:
/// without a cap, one line could ask for a 2^53-request batch and take the
/// process down allocating it (the predict verb's inputs are implicitly
/// bounded by its u32 kernel dims).
pub const MAX_BATCH: usize = 4096;
/// Largest accepted prompt length per request (tokens).
pub const MAX_INPUT_LEN: u32 = 262_144;
/// Largest accepted generation length per request (tokens).
pub const MAX_OUTPUT_LEN: u32 = 65_536;

fn materialize_requests(spec: &ScenarioSpec) -> Result<Vec<Request>, ScenarioError> {
    let bad = |why: String| Err(ScenarioError::InvalidWorkload(why));
    let reqs = match &spec.workload {
        WorkloadSpec::Sampled { kind, batch } => {
            if *batch == 0 {
                return bad("batch must be >= 1".to_string());
            }
            if *batch > MAX_BATCH {
                return bad(format!("batch {batch} exceeds the cap of {MAX_BATCH}"));
            }
            let mut rng = Rng::new(spec.seed);
            sample_batch(*kind, *batch, &mut rng)
        }
        WorkloadSpec::Explicit(reqs) => {
            if reqs.len() > MAX_BATCH {
                return bad(format!(
                    "request mix of {} exceeds the cap of {MAX_BATCH}",
                    reqs.len()
                ));
            }
            reqs.clone()
        }
    };
    if reqs.is_empty() {
        return bad("request mix must be non-empty".to_string());
    }
    for (i, r) in reqs.iter().enumerate() {
        validate_request_lens(i, r.input_len, r.output_len)?;
    }
    Ok(reqs)
}

/// Validate one request's lengths against the wire-scale caps — shared by
/// the v1 workload materializer and the cluster arrival materializer.
pub(crate) fn validate_request_lens(
    i: usize,
    input_len: u32,
    output_len: u32,
) -> Result<(), ScenarioError> {
    let bad = |why: String| Err(ScenarioError::InvalidWorkload(why));
    if input_len == 0 || output_len == 0 {
        return bad(format!(
            "request {i} needs input_len >= 1 and output_len >= 1 (got {input_len}x{output_len})"
        ));
    }
    if input_len > MAX_INPUT_LEN || output_len > MAX_OUTPUT_LEN {
        return bad(format!(
            "request {i} exceeds the length caps ({input_len}x{output_len} vs {MAX_INPUT_LEN}x{MAX_OUTPUT_LEN})"
        ));
    }
    Ok(())
}

/// Lower a spec to its phase-tagged op streams. Validation order is part
/// of the contract: model, GPU, parallelism, host gap, workload.
pub fn compile(spec: &ScenarioSpec) -> Result<CompiledScenario, ScenarioError> {
    let (llm, gpu) = resolve_model_gpu(&spec.model, &spec.gpu)?;
    validate_parallelism(&llm, spec.tp, spec.pp)?;
    if !spec.host_gap_sec.is_finite() || spec.host_gap_sec < 0.0 {
        return Err(ScenarioError::MalformedSpec(format!(
            "host_gap_sec must be finite and >= 0, got {}",
            spec.host_gap_sec
        )));
    }
    let requests = materialize_requests(spec)?;

    // both streams are always built: items are run-length encoded (a
    // handful per phase, not per layer), so a decode-only spec paying for
    // the prefill stream it drops costs a few dozen structs — and buys the
    // phase-stable seed base below
    let (prefill, decode) = trace::build_phase_traces(&llm, spec.tp, spec.pp, &requests);
    let decode_base = prefill.len();
    let mut phases = Vec::new();
    if spec.phases != PhaseSelection::DecodeOnly {
        phases.push(PhaseStream { phase: Phase::Prefill, seed_base: 0, items: prefill });
    }
    if spec.phases != PhaseSelection::PrefillOnly {
        phases.push(PhaseStream { phase: Phase::Decode, seed_base: decode_base, items: decode });
    }

    Ok(CompiledScenario {
        llm,
        gpu,
        tp: spec.tp,
        pp: spec.pp,
        requests,
        phases,
        host_gap_sec: spec.host_gap_sec,
        seed: spec.seed,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::workload::WorkloadKind;

    fn spec() -> ScenarioSpec {
        ScenarioSpec::new("Qwen2.5-14B", "A100").workload(WorkloadSpec::Explicit(vec![
            Request { input_len: 128, output_len: 16 },
            Request { input_len: 64, output_len: 8 },
        ]))
    }

    #[test]
    fn compiles_to_phase_tagged_streams() {
        let c = compile(&spec()).unwrap();
        assert_eq!(c.phases.len(), 2);
        assert_eq!(c.phases[0].phase, Phase::Prefill);
        assert_eq!(c.phases[1].phase, Phase::Decode);
        assert!(!c.phases[0].items.is_empty() && !c.phases[1].items.is_empty());
        assert!(c.launch_count() > 0.0);
        assert_eq!(c.requests.len(), 2);
    }

    #[test]
    fn phase_selection_drops_the_other_phase() {
        let p = compile(&spec().phases(PhaseSelection::PrefillOnly)).unwrap();
        assert_eq!(p.phases.len(), 1);
        assert_eq!(p.phases[0].phase, Phase::Prefill);
        assert_eq!(p.phases[0].seed_base, 0);
        let d = compile(&spec().phases(PhaseSelection::DecodeOnly)).unwrap();
        assert_eq!(d.phases.len(), 1);
        assert_eq!(d.phases[0].phase, Phase::Decode);
        let both = compile(&spec()).unwrap();
        assert_eq!(
            (p.launch_count() + d.launch_count()).to_bits(),
            both.launch_count().to_bits(),
            "phases partition the launches"
        );
        // the op-seed stream is phase-stable: the decode-only stream keeps
        // the seed base it would have had in the colocated run
        assert_eq!(d.phases[0].seed_base, p.phases[0].items.len());
        assert_eq!(both.phases[1].seed_base, both.phases[0].items.len());
    }

    #[test]
    fn concatenated_streams_match_build_trace() {
        let c = compile(&spec()).unwrap();
        let reference = trace::build_trace(&c.llm, c.tp, c.pp, &c.requests);
        let flat: Vec<&TraceItem> =
            c.phases.iter().flat_map(|p| p.items.iter()).collect();
        assert_eq!(flat.len(), reference.len());
        for (a, b) in flat.iter().zip(&reference) {
            assert_eq!(a.count.to_bits(), b.count.to_bits());
        }
        assert_eq!(c.launch_count().to_bits(), trace::launch_count(&reference).to_bits());
    }

    #[test]
    fn sampled_workloads_are_seed_deterministic() {
        let s = ScenarioSpec::new("Llama3.1-8B", "H800")
            .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Splitwise, batch: 4 })
            .seed(42);
        let a = compile(&s).unwrap();
        let b = compile(&s).unwrap();
        assert_eq!(a.requests, b.requests);
        let c = compile(&s.clone().seed(43)).unwrap();
        assert_ne!(a.requests, c.requests, "different seed, different mix");
    }

    #[test]
    fn validation_order_and_taxonomy() {
        // model first, even when the GPU is also unknown
        let e = compile(&ScenarioSpec::new("GPT-5", "B300")).unwrap_err();
        assert!(matches!(e, ScenarioError::UnknownModel(_)));
        let e = compile(&ScenarioSpec::new("Qwen3-32B", "B300")).unwrap_err();
        assert!(matches!(e, ScenarioError::UnknownGpu(_)));
        let e = compile(&spec().tp(0)).unwrap_err();
        assert!(matches!(e, ScenarioError::InvalidParallelism(_)));
        let e = compile(&spec().pp(10_000)).unwrap_err();
        assert!(matches!(e, ScenarioError::InvalidParallelism(_)));
        let e = compile(&spec().host_gap_sec(f64::NAN)).unwrap_err();
        assert!(matches!(e, ScenarioError::MalformedSpec(_)));
        let e = compile(
            &spec().workload(WorkloadSpec::Explicit(vec![Request { input_len: 0, output_len: 1 }])),
        )
        .unwrap_err();
        assert!(matches!(e, ScenarioError::InvalidWorkload(_)));
        let e = compile(&spec().workload(WorkloadSpec::Explicit(vec![]))).unwrap_err();
        assert!(matches!(e, ScenarioError::InvalidWorkload(_)));
    }

    #[test]
    fn wire_scale_inputs_are_capped_not_allocated() {
        // a hostile simulate line must be refused before any allocation
        let huge_batch = spec().workload(WorkloadSpec::Sampled {
            kind: WorkloadKind::Arxiv,
            batch: MAX_BATCH + 1,
        });
        assert!(matches!(
            compile(&huge_batch).unwrap_err(),
            ScenarioError::InvalidWorkload(_)
        ));
        let huge_prompt = spec().workload(WorkloadSpec::Explicit(vec![Request {
            input_len: u32::MAX,
            output_len: 1,
        }]));
        assert!(matches!(
            compile(&huge_prompt).unwrap_err(),
            ScenarioError::InvalidWorkload(_)
        ));
        // the caps themselves are accepted
        let at_cap = spec().workload(WorkloadSpec::Explicit(vec![Request {
            input_len: MAX_INPUT_LEN,
            output_len: 1,
        }]));
        assert!(compile(&at_cap).is_ok());
    }
}
