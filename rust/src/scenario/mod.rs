//! **Scenario API v1** — declarative end-to-end serving simulation.
//!
//! The paper's headline claim is end-to-end inference prediction (§VI-D);
//! this module is the typed surface for it. A caller describes a serving
//! scenario declaratively — model by registry name, GPU by Table-VI name,
//! `{tp, pp}` parallelism, a workload (sampled mix or explicit requests),
//! the phase schedule (prefill/decode), a seed and the per-kernel host
//! launch gap — as a [`ScenarioSpec`]. The [`compiler`] lowers the spec to
//! phase-tagged kernel/comm op streams ([`CompiledScenario`]); [`eval`]
//! runs the streams — a parallel per-item pass then a serial stream-order
//! accumulation, bit-identical at every thread count — through the
//! protocol-v1 request path ([`crate::api::predict_batch_view_on`]) into
//! a typed [`ScenarioReport`]:
//! per-phase TTFT/TPOT/tokens-per-second, per-method [`MethodTotals`], a
//! typed [`OpClass`] breakdown (no stringly buckets), and the
//! degraded-kernel / cache-hit provenance carried up from the protocol.
//!
//! **Scenario v2** ([`cluster`]) layers a deterministic discrete-event
//! cluster simulation on the same predictor path: seeded arrival
//! processes, N replicas behind a router, continuous batching, and
//! per-request TTFT/TPOT/queueing percentiles (see the module docs).
//!
//! Failures speak the **closed** [`ScenarioError`] taxonomy (unknown
//! model, unknown GPU, invalid parallelism, invalid workload, malformed
//! spec, invalid cluster), mirroring [`crate::api::PredictError`]. The same schema rides
//! the JSONL wire as the `simulate` verb ([`wire`]): `synperf simulate`
//! and simulate lines on `synperf serve --stdio` both round-trip a
//! `ScenarioSpec` object to a `ScenarioReport` line.
//!
//! [`Simulator`] is the stateful entry point: it owns the per-category
//! model set and a per-GPU communication-model cache, so repeated
//! simulations (a sweep over batch sizes, a wire peer) train each RF comm
//! model once. [`evaluate`] is pinned bit-identical to the hand-built
//! `build_trace` + `eval_trace` reference path (`tests/proptests.rs`).

pub mod cluster;
pub mod compiler;
pub mod eval;
pub mod event;
pub mod wire;

pub use cluster::{
    compile_cluster, ArrivalSpec, ClusterReport, ClusterRequest, ClusterSpec, CompiledCluster,
    LatencySummary, ReplicaReport, RoutePolicy,
};
pub use compiler::{compile, CompiledScenario, PhaseStream};
pub use eval::evaluate;

pub use crate::e2e::predict::{Method, MethodTotals, HOST_GAP_SEC};

use crate::e2e::comm::CommModel;
use crate::e2e::predict::ModelSet;
use crate::e2e::workload::{Request, WorkloadKind};
use crate::hw::GpuSpec;
use crate::kernels::KernelKind;
use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt;
use std::rc::Rc;

/// A serving phase of the scenario.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
        }
    }

    pub fn from_name(s: &str) -> Option<Phase> {
        match s {
            "prefill" => Some(Phase::Prefill),
            "decode" => Some(Phase::Decode),
            _ => None,
        }
    }
}

/// Which phases the scenario schedules — `Both` is a colocated server;
/// `PrefillOnly`/`DecodeOnly` model a disaggregated (Splitwise-style)
/// prefill or decode node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseSelection {
    Both,
    PrefillOnly,
    DecodeOnly,
}

impl PhaseSelection {
    pub fn name(&self) -> &'static str {
        match self {
            PhaseSelection::Both => "both",
            PhaseSelection::PrefillOnly => "prefill",
            PhaseSelection::DecodeOnly => "decode",
        }
    }

    pub fn from_name(s: &str) -> Option<PhaseSelection> {
        match s {
            "both" => Some(PhaseSelection::Both),
            "prefill" => Some(PhaseSelection::PrefillOnly),
            "decode" => Some(PhaseSelection::DecodeOnly),
            _ => None,
        }
    }

    /// Parse with the closed-taxonomy error — the one owner of the message,
    /// shared by the wire codec and the CLI so the surfaces cannot drift.
    pub fn parse(s: &str) -> Result<PhaseSelection, ScenarioError> {
        PhaseSelection::from_name(s).ok_or_else(|| {
            ScenarioError::MalformedSpec(format!("unknown phases {s:?} (both|prefill|decode)"))
        })
    }
}

/// Resolve a workload kind by name with the closed-taxonomy error — the
/// one owner of the message, shared by the wire codec and the CLI.
pub fn workload_kind(name: &str) -> Result<WorkloadKind, ScenarioError> {
    WorkloadKind::from_name(name).ok_or_else(|| {
        ScenarioError::InvalidWorkload(format!("unknown workload kind {name:?} (arxiv|splitwise)"))
    })
}

/// The request mix: a sampled batch from one of the paper's workload
/// distributions, or an explicit list of (input, output) lengths.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WorkloadSpec {
    Sampled { kind: WorkloadKind, batch: usize },
    Explicit(Vec<Request>),
}

/// The declarative description of one serving scenario. Built fluently:
///
/// ```ignore
/// let spec = ScenarioSpec::new("Qwen2.5-14B", "A100")
///     .tp(2)
///     .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Arxiv, batch: 8 })
///     .seed(7);
/// let report = Simulator::degraded().simulate(&spec)?;
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Model name, resolved through [`crate::e2e::llm::llm_by_name`].
    pub model: String,
    /// GPU name, resolved through [`crate::hw::gpu_by_name`].
    pub gpu: String,
    /// Tensor-parallel degree (must divide the model's attention heads).
    pub tp: u32,
    /// Pipeline-parallel degree (must not exceed the model's layers).
    pub pp: u32,
    pub workload: WorkloadSpec,
    pub phases: PhaseSelection,
    /// Seeds both workload sampling and the oracle ground truth.
    pub seed: u64,
    /// Per-kernel host launch gap in the measured system (framework
    /// overhead). Defaults to [`HOST_GAP_SEC`].
    pub host_gap_sec: f64,
}

impl ScenarioSpec {
    pub fn new(model: impl Into<String>, gpu: impl Into<String>) -> ScenarioSpec {
        ScenarioSpec {
            model: model.into(),
            gpu: gpu.into(),
            tp: 1,
            pp: 1,
            workload: WorkloadSpec::Sampled { kind: WorkloadKind::Arxiv, batch: 8 },
            phases: PhaseSelection::Both,
            seed: 0,
            host_gap_sec: HOST_GAP_SEC,
        }
    }

    pub fn tp(mut self, tp: u32) -> Self {
        self.tp = tp;
        self
    }

    pub fn pp(mut self, pp: u32) -> Self {
        self.pp = pp;
        self
    }

    pub fn workload(mut self, workload: WorkloadSpec) -> Self {
        self.workload = workload;
        self
    }

    pub fn phases(mut self, phases: PhaseSelection) -> Self {
        self.phases = phases;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn host_gap_sec(mut self, host_gap_sec: f64) -> Self {
        self.host_gap_sec = host_gap_sec;
        self
    }
}

/// The closed error taxonomy of the Scenario API. Every public edge —
/// the compiler, the `Simulator`, the `simulate` wire verb — answers with
/// one of these, mirroring [`crate::api::PredictError`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScenarioError {
    /// The named model is not in the [`crate::e2e::llm::registry`].
    UnknownModel(String),
    /// The named GPU is not in the Table-VI spec database.
    UnknownGpu(String),
    /// `{tp, pp}` is inconsistent with the model architecture.
    InvalidParallelism(String),
    /// The request mix is empty or contains impossible lengths.
    InvalidWorkload(String),
    /// The spec itself is malformed (bad JSON, bad field types, bad gap).
    MalformedSpec(String),
    /// A cluster-level knob (replicas, policy, admission limits, arrival
    /// process, SLO thresholds) is out of range — Scenario v2 only.
    InvalidCluster(String),
}

impl ScenarioError {
    /// Stable machine-readable code (the `error.code` of the wire surface).
    pub fn code(&self) -> &'static str {
        match self {
            ScenarioError::UnknownModel(_) => "unknown_model",
            ScenarioError::UnknownGpu(_) => "unknown_gpu",
            ScenarioError::InvalidParallelism(_) => "invalid_parallelism",
            ScenarioError::InvalidWorkload(_) => "invalid_workload",
            ScenarioError::MalformedSpec(_) => "malformed_spec",
            ScenarioError::InvalidCluster(_) => "invalid_cluster",
        }
    }
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioError::UnknownModel(name) => {
                write!(f, "unknown model {name:?} (see llm::registry())")
            }
            ScenarioError::UnknownGpu(name) => {
                write!(
                    f,
                    "unknown GPU {name:?} (see Table VI; closest: {})",
                    crate::hw::nearest_names(name, 3).join(", ")
                )
            }
            ScenarioError::InvalidParallelism(why) => write!(f, "invalid parallelism: {why}"),
            ScenarioError::InvalidWorkload(why) => write!(f, "invalid workload: {why}"),
            ScenarioError::MalformedSpec(why) => write!(f, "malformed scenario spec: {why}"),
            ScenarioError::InvalidCluster(why) => write!(f, "invalid cluster: {why}"),
        }
    }
}

impl std::error::Error for ScenarioError {}

/// The typed op classes of the breakdown — replaces the former
/// `Vec<(String, f64)>` rows. `Gemm` covers both plain and scaled matmul
/// categories; `HostGap` is the per-launch framework overhead.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    Gemm,
    Attention,
    RmsNorm,
    SiluMul,
    FusedMoe,
    AllReduce,
    SendRecv,
    HostGap,
}

impl OpClass {
    pub const ALL: [OpClass; 8] = [
        OpClass::Gemm,
        OpClass::Attention,
        OpClass::RmsNorm,
        OpClass::SiluMul,
        OpClass::FusedMoe,
        OpClass::AllReduce,
        OpClass::SendRecv,
        OpClass::HostGap,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            OpClass::Gemm => "gemm",
            OpClass::Attention => "attention",
            OpClass::RmsNorm => "rmsnorm",
            OpClass::SiluMul => "silu_mul",
            OpClass::FusedMoe => "fused_moe",
            OpClass::AllReduce => "all_reduce",
            OpClass::SendRecv => "send_recv",
            OpClass::HostGap => "host_gap",
        }
    }

    pub fn from_name(s: &str) -> Option<OpClass> {
        Self::ALL.into_iter().find(|c| c.name() == s)
    }

    /// The class a kernel category reports under.
    pub fn of_kind(kind: KernelKind) -> OpClass {
        match kind {
            KernelKind::Gemm | KernelKind::ScaledMm => OpClass::Gemm,
            KernelKind::Attention => OpClass::Attention,
            KernelKind::RmsNorm => OpClass::RmsNorm,
            KernelKind::SiluMul => OpClass::SiluMul,
            KernelKind::FusedMoe => OpClass::FusedMoe,
        }
    }

    fn idx(self) -> usize {
        match self {
            OpClass::Gemm => 0,
            OpClass::Attention => 1,
            OpClass::RmsNorm => 2,
            OpClass::SiluMul => 3,
            OpClass::FusedMoe => 4,
            OpClass::AllReduce => 5,
            OpClass::SendRecv => 6,
            OpClass::HostGap => 7,
        }
    }
}

/// Ground-truth seconds per op class — the typed runtime breakdown
/// (Table I view).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ClassBreakdown {
    secs: [f64; 8],
}

impl ClassBreakdown {
    pub fn add(&mut self, class: OpClass, secs: f64) {
        self.secs[class.idx()] += secs;
    }

    pub fn set(&mut self, class: OpClass, secs: f64) {
        self.secs[class.idx()] = secs;
    }

    pub fn get(&self, class: OpClass) -> f64 {
        self.secs[class.idx()]
    }

    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }

    /// Share of the breakdown total, percent (0 when the total is zero).
    pub fn share_pct(&self, class: OpClass) -> f64 {
        let total = self.total();
        if total > 0.0 {
            100.0 * self.get(class) / total
        } else {
            0.0
        }
    }
}

fn ratio(num: f64, den: f64) -> f64 {
    if den > 0.0 {
        num / den
    } else {
        0.0
    }
}

/// One scheduled phase of the report: per-method totals, the typed
/// breakdown, launch and token accounting, and the derived serving
/// metrics (TTFT for prefill, TPOT for decode, tokens/s for both).
#[derive(Debug, Clone, PartialEq)]
pub struct PhaseReport {
    pub phase: Phase,
    pub totals: MethodTotals,
    /// Ground-truth seconds per op class within this phase.
    pub breakdown: ClassBreakdown,
    /// Kernel launches in this phase (fractional: decode checkpoints carry
    /// integration weights).
    pub launches: f64,
    /// Tokens this phase processes: prompt tokens for prefill, generated
    /// tokens for decode.
    pub tokens: f64,
    /// Sequential steps the phase spans: 1 for prefill, the longest
    /// request's generation length for decode (each decode step emits one
    /// token per active request, so wall time divides by steps — not by
    /// the batch-aggregate token count — for inter-token latency).
    pub steps: f64,
}

impl PhaseReport {
    /// Phase wall time under one method's model of the world.
    pub fn time_sec(&self, m: Method) -> f64 {
        self.totals.get(m)
    }

    /// Time-to-first-token: the prefill phase's wall time.
    pub fn ttft_sec(&self, m: Method) -> Option<f64> {
        (self.phase == Phase::Prefill).then(|| self.time_sec(m))
    }

    /// Time-per-output-token: decode wall time per decode *step* — the
    /// batch's inter-token latency, the metric serving systems report
    /// (dividing by the aggregate token count would understate it by
    /// roughly the batch size).
    pub fn tpot_sec(&self, m: Method) -> Option<f64> {
        (self.phase == Phase::Decode).then(|| ratio(self.time_sec(m), self.steps))
    }

    /// Aggregate token throughput of the phase (all requests together).
    pub fn tokens_per_sec(&self, m: Method) -> f64 {
        ratio(self.tokens, self.time_sec(m))
    }
}

/// The typed answer of a simulation — never a bare number. Whole-scenario
/// totals are accumulated in trace order, so they are bit-identical to the
/// hand-built `build_trace` + `eval_trace` reference for the same spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioReport {
    pub model: String,
    pub gpu: String,
    pub tp: u32,
    pub pp: u32,
    /// Scheduled phases in execution order (prefill before decode).
    pub phases: Vec<PhaseReport>,
    /// Whole-scenario per-method totals; `totals.degraded_kernels` is the
    /// provenance count carried up from the protocol-v1 responses.
    pub totals: MethodTotals,
    /// Whole-scenario ground-truth seconds per op class.
    pub breakdown: ClassBreakdown,
    /// Total kernel launches across phases.
    pub launches: f64,
    /// Kernel items whose analysis came from the engine's memoizing cache.
    pub cache_hits: usize,
    pub host_gap_sec: f64,
    pub seed: u64,
}

impl ScenarioReport {
    pub fn phase(&self, phase: Phase) -> Option<&PhaseReport> {
        self.phases.iter().find(|p| p.phase == phase)
    }

    /// TTFT under `m`, when the scenario schedules a prefill phase.
    pub fn ttft_sec(&self, m: Method) -> Option<f64> {
        self.phase(Phase::Prefill).and_then(|p| p.ttft_sec(m))
    }

    /// TPOT under `m`, when the scenario schedules a decode phase.
    pub fn tpot_sec(&self, m: Method) -> Option<f64> {
        self.phase(Phase::Decode).and_then(|p| p.tpot_sec(m))
    }
}

/// The stateful simulation entry point: owns the per-category model set
/// (empty = documented degraded roofline mode, visible in
/// `totals.degraded_kernels`) and a per-GPU cache of trained RF
/// communication models, so a sweep or a wire peer trains each comm model
/// once.
pub struct Simulator {
    models: ModelSet,
    comm_seed: u64,
    /// Worker threads for the two-pass parallel evaluator. Reports are
    /// bit-identical at every thread count, so this is purely a wall-clock
    /// knob (the CLI's `--threads`).
    threads: usize,
    comms: RefCell<HashMap<String, Rc<CommModel>>>,
}

impl Simulator {
    /// Comm-model training seed shared with the experiment `Lab` default;
    /// reference evaluations must train with the same seed to reproduce a
    /// `Simulator`'s numbers exactly.
    pub const DEFAULT_COMM_SEED: u64 = 0x5EED_CAFE;

    pub fn new(models: ModelSet) -> Simulator {
        Simulator::with_comm_seed(models, Self::DEFAULT_COMM_SEED)
    }

    pub fn with_comm_seed(models: ModelSet, comm_seed: u64) -> Simulator {
        Simulator {
            models,
            comm_seed,
            threads: crate::engine::par::default_threads(),
            comms: RefCell::new(HashMap::new()),
        }
    }

    /// A simulator with no trained models: every kernel item answers the
    /// analytical roof with `Roofline` provenance.
    pub fn degraded() -> Simulator {
        Simulator::new(ModelSet::default())
    }

    /// Set the evaluator's worker-thread count (default: available
    /// parallelism). Purely a speed knob — outputs do not change.
    pub fn threads(mut self, threads: usize) -> Simulator {
        self.threads = threads.max(1);
        self
    }

    fn comm_for(&self, gpu: &GpuSpec) -> Rc<CommModel> {
        if let Some(m) = self.comms.borrow().get(gpu.name) {
            return m.clone();
        }
        let m = Rc::new(CommModel::train(gpu, self.comm_seed));
        self.comms.borrow_mut().insert(gpu.name.to_string(), m.clone());
        m
    }

    /// Compile and evaluate one scenario with the configured thread count.
    pub fn simulate(&self, spec: &ScenarioSpec) -> Result<ScenarioReport, ScenarioError> {
        self.simulate_with_threads(spec, self.threads)
    }

    /// Compile and evaluate one scenario with an explicit thread count
    /// (shared-`Simulator` callers — e.g. the cached `Lab::simulator()` —
    /// use this instead of the consuming [`threads`](Self::threads)
    /// builder). Bit-identical to `threads = 1`.
    pub fn simulate_with_threads(
        &self,
        spec: &ScenarioSpec,
        threads: usize,
    ) -> Result<ScenarioReport, ScenarioError> {
        let compiled = compile(spec)?;
        let comm = self.comm_for(&compiled.gpu);
        Ok(evaluate(&compiled, &self.models, &comm, threads.max(1)))
    }

    /// Compile and run one cluster simulation (Scenario v2) with the
    /// configured thread count.
    pub fn simulate_cluster(&self, spec: &ClusterSpec) -> Result<ClusterReport, ScenarioError> {
        self.simulate_cluster_with_threads(spec, self.threads)
    }

    /// Compile and run one cluster simulation with an explicit thread
    /// count. The event loop is serial; `threads` only fans out the
    /// batched prediction calls inside each step, so reports are
    /// byte-identical to `threads = 1`.
    pub fn simulate_cluster_with_threads(
        &self,
        spec: &ClusterSpec,
        threads: usize,
    ) -> Result<ClusterReport, ScenarioError> {
        let compiled = compile_cluster(spec)?;
        let comm = self.comm_for(&compiled.gpu);
        Ok(cluster::simulate_cluster(&compiled, &self.models, &comm, threads.max(1)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_every_field() {
        let reqs = vec![Request { input_len: 64, output_len: 8 }];
        let spec = ScenarioSpec::new("Llama3.1-8B", "H800")
            .tp(2)
            .pp(2)
            .workload(WorkloadSpec::Explicit(reqs.clone()))
            .phases(PhaseSelection::PrefillOnly)
            .seed(99)
            .host_gap_sec(1.5e-6);
        assert_eq!(spec.model, "Llama3.1-8B");
        assert_eq!(spec.gpu, "H800");
        assert_eq!((spec.tp, spec.pp), (2, 2));
        assert_eq!(spec.workload, WorkloadSpec::Explicit(reqs));
        assert_eq!(spec.phases, PhaseSelection::PrefillOnly);
        assert_eq!(spec.seed, 99);
        assert_eq!(spec.host_gap_sec, 1.5e-6);
    }

    #[test]
    fn error_codes_are_stable() {
        let cases: [(ScenarioError, &str); 6] = [
            (ScenarioError::UnknownModel("x".into()), "unknown_model"),
            (ScenarioError::UnknownGpu("x".into()), "unknown_gpu"),
            (ScenarioError::InvalidParallelism("x".into()), "invalid_parallelism"),
            (ScenarioError::InvalidWorkload("x".into()), "invalid_workload"),
            (ScenarioError::MalformedSpec("x".into()), "malformed_spec"),
            (ScenarioError::InvalidCluster("x".into()), "invalid_cluster"),
        ];
        for (err, code) in cases {
            assert_eq!(err.code(), code);
            assert!(!err.to_string().is_empty());
        }
    }

    #[test]
    fn class_breakdown_accumulates_and_shares() {
        let mut b = ClassBreakdown::default();
        b.add(OpClass::Gemm, 0.3);
        b.add(OpClass::Gemm, 0.1);
        b.add(OpClass::HostGap, 0.1);
        assert_eq!(b.get(OpClass::Gemm), 0.4);
        assert_eq!(b.total(), 0.5);
        assert!((b.share_pct(OpClass::Gemm) - 80.0).abs() < 1e-9);
        assert_eq!(b.share_pct(OpClass::SendRecv), 0.0);
        assert_eq!(ClassBreakdown::default().share_pct(OpClass::Gemm), 0.0);
        for c in OpClass::ALL {
            assert_eq!(OpClass::from_name(c.name()), Some(c));
        }
    }

    #[test]
    fn degraded_simulator_reports_provenance_and_phases() {
        let sim = Simulator::degraded();
        let spec = ScenarioSpec::new("llama3.1-8b", "A100")
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 96, output_len: 8 },
                Request { input_len: 64, output_len: 4 },
            ]))
            .seed(5);
        let r = sim.simulate(&spec).unwrap();
        assert_eq!(r.phases.len(), 2);
        assert_eq!(r.phases[0].phase, Phase::Prefill);
        assert_eq!(r.phases[1].phase, Phase::Decode);
        assert!(r.totals.actual > 0.0 && r.totals.synperf > 0.0);
        assert!(r.totals.degraded_kernels > 0, "no models: provenance must say degraded");
        assert!(r.launches > 0.0);
        assert!(r.ttft_sec(Method::Actual).unwrap() > 0.0);
        assert!(r.tpot_sec(Method::SynPerf).unwrap() > 0.0);
        let prefill = r.phase(Phase::Prefill).unwrap();
        assert_eq!(prefill.tokens, 160.0);
        assert!(prefill.tokens_per_sec(Method::Actual) > 0.0);
        assert!(prefill.breakdown.get(OpClass::Gemm) > 0.0);
        assert!(prefill.breakdown.get(OpClass::HostGap) > 0.0);
        // tp=1: no collectives anywhere
        assert_eq!(r.breakdown.get(OpClass::AllReduce), 0.0);
        assert_eq!(r.breakdown.get(OpClass::SendRecv), 0.0);
    }

    #[test]
    fn simulate_surfaces_the_closed_taxonomy() {
        let sim = Simulator::degraded();
        let base = |model: &str, gpu: &str| ScenarioSpec::new(model, gpu);
        assert!(matches!(
            sim.simulate(&base("GPT-5", "A100")),
            Err(ScenarioError::UnknownModel(_))
        ));
        assert!(matches!(
            sim.simulate(&base("Qwen2.5-14B", "B300")),
            Err(ScenarioError::UnknownGpu(_))
        ));
        assert!(matches!(
            sim.simulate(&base("Qwen2.5-14B", "A100").tp(3)),
            Err(ScenarioError::InvalidParallelism(_))
        ));
        assert!(matches!(
            sim.simulate(
                &base("Qwen2.5-14B", "A100")
                    .workload(WorkloadSpec::Sampled { kind: WorkloadKind::Arxiv, batch: 0 })
            ),
            Err(ScenarioError::InvalidWorkload(_))
        ));
        assert!(matches!(
            sim.simulate(&base("Qwen2.5-14B", "A100").host_gap_sec(-1.0)),
            Err(ScenarioError::MalformedSpec(_))
        ));
    }
}
