//! Deterministic discrete-event core for Scenario v2: a virtual-clock
//! event queue ordered by `(time, sequence)`. The sequence number is
//! assigned at push, so events scheduled for the same instant pop in push
//! order — ordering can never depend on `BinaryHeap` internals, insertion
//! races, or float ties, which is what keeps whole cluster simulations
//! byte-identical run to run and thread count to thread count (the event
//! loop itself is serial; `--threads` only parallelizes the batched
//! prediction calls inside a step).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // reversed on both keys: BinaryHeap is a max-heap and we pop the
        // earliest (time, seq). total_cmp gives a total order on f64 so no
        // comparator panic is reachable.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Min-queue of timed events with FIFO tie-breaking.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> EventQueue<T> {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at virtual time `time` (seconds, finite).
    pub fn push(&mut self, time: f64, payload: T) {
        debug_assert!(time.is_finite(), "virtual time must be finite");
        self.heap.push(Entry { time, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Pop the earliest event; same-instant events pop in push order.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|e| (e.time, e.payload))
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_break_in_push_order() {
        let mut q = EventQueue::new();
        for i in 0..16u32 {
            q.push(1.5, i);
        }
        for i in 0..16u32 {
            assert_eq!(q.pop(), Some((1.5, i)), "FIFO within one instant");
        }
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(5.0, 50);
        q.push(1.0, 10);
        assert_eq!(q.pop(), Some((1.0, 10)));
        // a later push for an earlier time still pops first
        q.push(2.0, 20);
        q.push(5.0, 51);
        assert_eq!(q.pop(), Some((2.0, 20)));
        assert_eq!(q.pop(), Some((5.0, 50)), "equal times keep insertion order");
        assert_eq!(q.pop(), Some((5.0, 51)));
    }
}
