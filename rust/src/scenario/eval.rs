//! The scenario evaluator: the **two-pass deterministic-parallel** walk of
//! the compiled phase streams into the typed [`ScenarioReport`].
//!
//! Pass 1 fans the per-item seed-dependent measurements (oracle sampling,
//! comm oracles and RF predictions) out over [`par::par_map`] into an
//! index-ordered buffer; pass 2 accumulates totals serially in stream
//! order — the exact walk the serial evaluator always did — and then one
//! batched MLP routing pass per feature view runs through
//! [`crate::api::predict_batch_view_on`]. Every item's value depends only
//! on `(op, gpu, seed)`, and the accumulation order never changes, so the
//! report is **bit-identical at every thread count**. One precondition
//! applies to the `cache_hits` provenance counter alone: it re-probes the
//! engine cache after pass 1, so it equals the kernel-item count (and
//! stays thread-count independent) as long as the scenario's distinct
//! analyses fit the engine cache without eviction — comfortably true for
//! typical serving scenarios against the default 8192-entry cache. A
//! pathological schedule with tens of thousands of distinct per-step
//! shapes can evict between the passes, making `cache_hits` advisory
//! there; the method totals and breakdowns never depend on cache state
//! at all.
//!
//! The walk mirrors [`crate::e2e::predict::eval_trace`] **exactly** — the
//! same per-item op seeds (each stream's `seed_base` + offset, which for a
//! both-phase run is precisely the global trace index), the same oracle
//! calls, the same batched routing — while additionally tagging every
//! contribution with its phase and [`OpClass`]. The whole-scenario totals
//! are accumulated item by item in stream order (not by summing the
//! per-phase subtotals), so they are bit-identical to the hand-built
//! `build_trace` + `eval_trace` reference (pinned in `tests/proptests.rs`).
//! Because seed bases are phase-stable, a decode-only (disaggregated) run
//! reproduces the decode phase of the colocated run bit for bit.

use super::{ClassBreakdown, CompiledScenario, OpClass, Phase, PhaseReport, ScenarioReport};
use crate::api::{self, FeatureView, Source};
use crate::e2e::comm::CommModel;
use crate::e2e::predict::{eval_op, ItemEval, MethodTotals, ModelSet, EVAL_PAR_GRAIN};
use crate::e2e::trace::{Op, TraceItem};
use crate::hw::GpuSpec;
use crate::engine::{par, PredictionEngine};
use crate::kernels::KernelConfig;

fn phase_tokens(c: &CompiledScenario, phase: Phase) -> f64 {
    match phase {
        Phase::Prefill => c.requests.iter().map(|r| r.input_len as f64).sum(),
        Phase::Decode => c.requests.iter().map(|r| r.output_len as f64).sum(),
    }
}

/// Sequential steps a phase spans: prefill is one forward pass; decode
/// runs until the longest request finishes (one token per step).
fn phase_steps(c: &CompiledScenario, phase: Phase) -> f64 {
    match phase {
        Phase::Prefill => 1.0,
        Phase::Decode => {
            c.requests.iter().map(|r| r.output_len).max().unwrap_or(1).max(1) as f64
        }
    }
}

/// Shared accumulation for a comm op (All-Reduce / Send-Recv): ground
/// truth into `actual`, the RF prediction into every predictor, the class
/// seconds into both breakdowns. One body so the two arms cannot drift —
/// the accumulation order here is part of the `eval_trace` bit-identity
/// pin (grand fields first, then the phase's).
fn add_comm_op(
    grand: &mut MethodTotals,
    grand_breakdown: &mut ClassBreakdown,
    ph: &mut PhaseReport,
    class: OpClass,
    count: f64,
    actual: f64,
    pred: f64,
) {
    grand.actual += count * actual;
    ph.totals.actual += count * actual;
    for t in [&mut *grand, &mut ph.totals] {
        for p in [
            &mut t.synperf,
            &mut t.roofline,
            &mut t.linear,
            &mut t.habitat,
            &mut t.neusight,
        ] {
            *p += count * pred;
        }
    }
    ph.breakdown.add(class, count * actual);
    grand_breakdown.add(class, count * actual);
}

/// Evaluate a compiled scenario against ground truth and every predictor,
/// fanning the per-item pass out over `threads` workers (the report is
/// bit-identical at every thread count — see the module docs).
/// Infallible by construction: compilation already validated the spec, and
/// missing models answer in the documented degraded roofline mode (counted
/// in `totals.degraded_kernels`).
pub fn evaluate(
    c: &CompiledScenario,
    models: &ModelSet,
    comm: &CommModel,
    threads: usize,
) -> ScenarioReport {
    let engine = PredictionEngine::global();
    let gpu = &c.gpu;
    let host_gap = c.host_gap_sec;

    // pass 1 — parallel per-item measurements, index-ordered. Op seeds are
    // phase-stable: seed_base + offset equals the global trace index of a
    // both-phase run.
    let flat: Vec<(usize, usize)> = c
        .phases
        .iter()
        .enumerate()
        .flat_map(|(pi, stream)| (0..stream.items.len()).map(move |j| (pi, j)))
        .collect();
    // small scenarios stay serial: see EVAL_PAR_GRAIN
    let threads = threads.min(flat.len().div_ceil(EVAL_PAR_GRAIN)).max(1);
    let evals: Vec<ItemEval> = par::par_map(&flat, threads, |_, &(pi, j)| {
        let stream = &c.phases[pi];
        let op_seed = c.seed.wrapping_add((stream.seed_base + j) as u64 * 0x9E37);
        eval_op(engine, &stream.items[j].op, gpu, c.tp, comm, op_seed)
    });

    // pass 2 — serial stream-order accumulation, unchanged from the serial
    // reference (grand totals stay bit-identical to eval_trace)
    let mut grand = MethodTotals::default();
    let mut grand_breakdown = ClassBreakdown::default();
    let mut launches = 0.0f64;
    let mut reports: Vec<PhaseReport> = c
        .phases
        .iter()
        .map(|stream| PhaseReport {
            phase: stream.phase,
            totals: MethodTotals::default(),
            breakdown: ClassBreakdown::default(),
            launches: 0.0,
            tokens: phase_tokens(c, stream.phase),
            steps: phase_steps(c, stream.phase),
        })
        .collect();

    // kernel launches accumulated for one batched routing pass per view,
    // tagged with (phase index, repetition count)
    let mut kernel_cfgs: Vec<&KernelConfig> = Vec::new();
    let mut kernel_meta: Vec<(usize, f64)> = Vec::new();

    let mut fi = 0usize;
    for (pi, stream) in c.phases.iter().enumerate() {
        for item in &stream.items {
            let ev = &evals[fi];
            fi += 1;
            let ph = &mut reports[pi];
            match ev {
                ItemEval::Kernel(s) => {
                    let actual = item.count * (s.latency_sec + host_gap);
                    grand.actual += actual;
                    ph.totals.actual += actual;
                    grand.roofline += item.count * s.roofline_sec;
                    ph.totals.roofline += item.count * s.roofline_sec;
                    grand.habitat += item.count * s.habitat_sec;
                    ph.totals.habitat += item.count * s.habitat_sec;
                    let linear = match models.linear.get(&s.kind) {
                        Some(lm) => item.count * lm.predict(s),
                        None => item.count * s.roofline_sec, // no model: fall back
                    };
                    grand.linear += linear;
                    ph.totals.linear += linear;

                    let class = OpClass::of_kind(s.kind);
                    ph.breakdown.add(class, item.count * s.latency_sec);
                    ph.breakdown.add(OpClass::HostGap, item.count * host_gap);
                    grand_breakdown.add(class, item.count * s.latency_sec);
                    grand_breakdown.add(OpClass::HostGap, item.count * host_gap);
                    ph.launches += item.count;
                    launches += item.count;
                    let Op::Kernel(cfg) = &item.op else {
                        unreachable!("pass-1 evals align with stream items")
                    };
                    kernel_cfgs.push(cfg);
                    kernel_meta.push((pi, item.count));
                }
                ItemEval::Comm { actual, pred } => {
                    let class = match &item.op {
                        Op::AllReduce { .. } => OpClass::AllReduce,
                        Op::SendRecv { .. } => OpClass::SendRecv,
                        Op::Kernel(_) => {
                            unreachable!("pass-1 evals align with stream items")
                        }
                    };
                    add_comm_op(
                        &mut grand,
                        &mut grand_breakdown,
                        ph,
                        class,
                        item.count,
                        *actual,
                        *pred,
                    );
                }
            }
        }
    }

    // the one request path: per-category batched MLP routing with
    // provenance, once per feature view (SynPerf, Neusight baseline)
    let syn =
        api::predict_batch_view_on(&models.synperf, FeatureView::SynPerf, gpu, &kernel_cfgs, threads);
    let neu = api::predict_batch_view_on(
        &models.neusight,
        FeatureView::Neusight,
        gpu,
        &kernel_cfgs,
        threads,
    );
    let mut cache_hits = 0usize;
    for ((sp, np), (pi, count)) in syn.iter().zip(&neu).zip(&kernel_meta) {
        grand.synperf += count * sp.latency_sec;
        reports[*pi].totals.synperf += count * sp.latency_sec;
        grand.neusight += count * np.latency_sec;
        reports[*pi].totals.neusight += count * np.latency_sec;
        if sp.provenance.source == Source::Roofline {
            grand.degraded_kernels += 1;
            reports[*pi].totals.degraded_kernels += 1;
        }
        if sp.provenance.cache_hit {
            cache_hits += 1;
        }
    }

    ScenarioReport {
        model: c.llm.name.to_string(),
        gpu: c.gpu.name.to_string(),
        tp: c.tp,
        pp: c.pp,
        phases: reports,
        totals: grand,
        breakdown: grand_breakdown,
        launches,
        cache_hits,
        host_gap_sec: c.host_gap_sec,
        seed: c.seed,
    }
}

/// Predictor-side wall time of one op stream — the cluster simulator's
/// step clock (Scenario v2). Kernel latencies go through the same batched
/// [`api::predict_batch_view_on`] routing path as [`evaluate`], so the
/// sharded engine cache is exercised identically; comm ops use the shared
/// RF predictions; every kernel launch pays the host gap. Unlike
/// [`evaluate`] there is **no oracle sampling**: service times are what
/// the *predictor* says, which keeps the virtual clock a pure function of
/// `(items, gpu, models)` — no seed enters, so cluster timelines are
/// trivially deterministic. Returns the seconds plus the count of kernel
/// items answered with degraded (roofline-fallback) provenance.
pub(crate) fn predict_stream_cost(
    items: &[TraceItem],
    gpu: &GpuSpec,
    tp: u32,
    models: &ModelSet,
    comm: &CommModel,
    host_gap_sec: f64,
    threads: usize,
) -> (f64, usize) {
    let mut secs = 0.0;
    let mut kernel_cfgs: Vec<&KernelConfig> = Vec::new();
    let mut kernel_counts: Vec<f64> = Vec::new();
    for item in items {
        match &item.op {
            Op::Kernel(cfg) => {
                kernel_cfgs.push(cfg);
                kernel_counts.push(item.count);
                secs += item.count * host_gap_sec;
            }
            Op::AllReduce { bytes } => {
                secs += item.count * comm.predict_allreduce(*bytes, tp, gpu);
            }
            Op::SendRecv { bytes } => {
                secs += item.count * comm.predict_sendrecv(*bytes, gpu);
            }
        }
    }
    let syn =
        api::predict_batch_view_on(&models.synperf, FeatureView::SynPerf, gpu, &kernel_cfgs, threads);
    let mut degraded = 0usize;
    for (p, count) in syn.iter().zip(&kernel_counts) {
        secs += count * p.latency_sec;
        if p.provenance.source == Source::Roofline {
            degraded += 1;
        }
    }
    (secs, degraded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::workload::Request;
    use crate::scenario::{PhaseSelection, ScenarioSpec, Simulator, WorkloadSpec};

    #[test]
    fn phase_totals_partition_the_grand_totals() {
        let sim = Simulator::degraded();
        let spec = ScenarioSpec::new("Qwen2.5-14B", "A100")
            .tp(2)
            .pp(2)
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 192, output_len: 24 },
                Request { input_len: 80, output_len: 12 },
            ]))
            .seed(17);
        let r = sim.simulate(&spec).unwrap();
        assert_eq!(r.phases.len(), 2);
        let close = |a: f64, b: f64| (a - b).abs() <= 1e-12 * a.abs().max(b.abs()).max(1e-30);
        let mut actual = 0.0;
        let mut synperf = 0.0;
        let mut roofline = 0.0;
        let mut launches = 0.0;
        let mut bd_total = 0.0;
        let mut degraded = 0usize;
        for p in &r.phases {
            actual += p.totals.actual;
            synperf += p.totals.synperf;
            roofline += p.totals.roofline;
            launches += p.launches;
            bd_total += p.breakdown.total();
            degraded += p.totals.degraded_kernels;
        }
        assert!(close(actual, r.totals.actual));
        assert!(close(synperf, r.totals.synperf));
        assert!(close(roofline, r.totals.roofline));
        assert!(close(launches, r.launches));
        assert_eq!(degraded, r.totals.degraded_kernels);
        // tp=2, pp=2: collectives show up in the typed breakdown
        assert!(r.breakdown.get(OpClass::AllReduce) > 0.0);
        assert!(r.breakdown.get(OpClass::SendRecv) > 0.0);
        assert!(close(bd_total, r.breakdown.total()));
        // the breakdown's actual-side classes + comm == ground truth total
        assert!(close(r.breakdown.total(), r.totals.actual));
    }

    #[test]
    fn decode_phase_is_invariant_under_phase_selection() {
        // a disaggregated decode node must reproduce the decode phase of
        // the colocated run bit for bit (phase-stable op-seed bases)
        let sim = Simulator::degraded();
        let spec = ScenarioSpec::new("Llama3.1-8B", "A100")
            .workload(WorkloadSpec::Explicit(vec![
                Request { input_len: 96, output_len: 12 },
                Request { input_len: 48, output_len: 6 },
            ]))
            .seed(23);
        let both = sim.simulate(&spec).unwrap();
        let only = sim.simulate(&spec.clone().phases(PhaseSelection::DecodeOnly)).unwrap();
        let b = both.phase(Phase::Decode).unwrap();
        assert_eq!(only.phases.len(), 1);
        let o = &only.phases[0];
        assert_eq!(b.totals.actual.to_bits(), o.totals.actual.to_bits());
        assert_eq!(b.totals.synperf.to_bits(), o.totals.synperf.to_bits());
        assert_eq!(b.totals.roofline.to_bits(), o.totals.roofline.to_bits());
        assert_eq!(b.launches.to_bits(), o.launches.to_bits());
        assert_eq!(b.breakdown, o.breakdown);
    }
}
