//! Dataset construction (paper §V-B): sample kernel launches from the
//! paper's workload ranges, run the analytical pipeline (decompose ->
//! schedule -> features) and "profile" them on the oracle testbed, yielding
//! (feature-vector, theoretical-time, measured-latency) training rows.
//!
//! The per-kernel parameter ranges match §V-B verbatim; magnitudes are
//! log-uniformly sampled (the paper's ranges span 4-5 decades). The
//! analyze/measure pipeline is entered through the protocol-v1 surface
//! ([`crate::api::profile_sample`], which validates launch geometry) and
//! executes on [`crate::engine`]: building is fanned out over the engine's
//! scoped-thread workers and the analytical half of every sample goes
//! through its memoizing cache.

use crate::features::FEATURE_DIM;
use crate::hw::GpuSpec;
use crate::kernels::{fused_moe, DType, KernelConfig, KernelKind};
use crate::util::csv::{read_csv, CsvWriter};
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

/// One profiled sample: model input + targets.
#[derive(Debug, Clone)]
pub struct Sample {
    pub kind: KernelKind,
    pub gpu: String,
    pub seen: bool,
    pub x: [f32; FEATURE_DIM],
    pub theory_sec: f64,
    pub latency_sec: f64,
    /// The naive-roofline prediction (carried along for the baseline).
    pub roofline_sec: f64,
    /// Raw roof components for the Linear baseline [29]: aggregate compute
    /// cycles and (naive) memory cycles, in seconds.
    pub compute_sec: f64,
    pub mem_sec: f64,
    /// Habitat-style wave-scaled prediction (a *measurement* on the
    /// reference GPU scaled by roof ratios — computed at profiling time,
    /// like the original runtime-based predictor).
    pub habitat_sec: f64,
    /// Neusight-style tile-level features + static-wave theoretical time.
    pub x_alt: [f32; FEATURE_DIM],
    pub alt_theory_sec: f64,
}

impl Sample {
    /// Execution efficiency — the MLP's training target (§V-C).
    pub fn efficiency(&self) -> f64 {
        (self.theory_sec / self.latency_sec).clamp(0.002, 0.995)
    }
}

/// Draw one kernel configuration from the §V-B ranges. The returned config
/// is GPU-independent; [`finalize_for_gpu`] resolves GPU-specific choices
/// (FA2 vs FA3 kernel selection).
pub fn sample_config(kind: KernelKind, rng: &mut Rng) -> KernelConfig {
    match kind {
        KernelKind::Gemm => {
            if rng.bool(0.35) {
                // LLM projection shapes (the serving-framework kernels the
                // dataset targets): decode/prefill token counts against
                // typical hidden/intermediate/vocab widths
                let m = if rng.bool(0.5) {
                    rng.range_u32(1, 64) // decode batch
                } else {
                    rng.log_range_u32(256, 32_768) // prefill chunk
                };
                let dims: [u32; 12] = [
                    1_024, 2_048, 3_456, 4_096, 5_120, 6_912, 8_192, 11_008, 13_824,
                    27_648, 28_672, 152_064,
                ];
                KernelConfig::Gemm {
                    m,
                    n: *rng.choose(&dims),
                    k: *rng.choose(&dims[..10]),
                    dtype: DType::Bf16,
                }
            } else {
                KernelConfig::Gemm {
                    m: rng.log_range_u32(2, 131_072),
                    n: rng.log_range_u32(384, 152_064),
                    k: rng.log_range_u32(256, 53_248),
                    dtype: DType::Bf16,
                }
            }
        }
        KernelKind::ScaledMm => KernelConfig::ScaledMm {
            m: rng.log_range_u32(2, 131_072),
            n: rng.log_range_u32(384, 8_192),
            k: rng.log_range_u32(256, 8_192),
        },
        KernelKind::Attention => {
            let bs = rng.range_u32(1, 16);
            let nkv = *rng.choose(&[1u32, 2, 4, 8]);
            let nh = nkv * *rng.choose(&[1u32, 2, 4, 8, 16]);
            let hd = *rng.choose(&[64u32, 128]);
            let decode = rng.bool(0.4);
            // Query/KV lengths vary randomly within each batch (§V-B)
            let mean_q = if decode { 1 } else { rng.log_range_u32(2, 20_097) };
            let batch: Vec<(u32, u32)> = (0..bs)
                .map(|_| {
                    let q = if decode {
                        1
                    } else {
                        ((mean_q as f64 * rng.range_f64(0.5, 1.5)) as u32).clamp(1, 20_097)
                    };
                    let hist = rng.log_range_u32(1, 16_384) - 1;
                    (q, (q + hist).min(20_481).max(q))
                })
                .collect();
            KernelConfig::Attention { batch, nh, nkv, hd, causal: true, fa3: false }
        }
        KernelKind::RmsNorm => KernelConfig::RmsNorm {
            seq: rng.log_range_u32(2, 131_072),
            dim: rng.log_range_u32(128, 16_384),
        },
        KernelKind::SiluMul => KernelConfig::SiluMul {
            seq: rng.log_range_u32(2, 131_072),
            dim: rng.log_range_u32(768, 106_496),
        },
        KernelKind::FusedMoe => {
            let m = rng.log_range_u32(2, 8_192);
            let e = rng.range_u32(8, 128);
            let topk = rng.range_u32(2, 8);
            let h = rng.log_range_u32(1_024, 4_096);
            let n = rng.log_range_u32(512, 3_072);
            let expert_tokens = fused_moe::route_tokens(m, e, topk, rng);
            // production behaviour: the shipped default config, keyed on the
            // expected per-expert batch (as SGLang's config dictionaries are)
            let m_per_expert = (m * topk / e).max(1);
            KernelConfig::FusedMoe {
                m,
                e,
                topk,
                h,
                n,
                expert_tokens,
                cfg: fused_moe::default_config(m_per_expert, &crate::hw::all_gpus()[0]),
            }
        }
    }
}

/// The GPU-resolved half of [`finalize_for_gpu`]: FlashInfer dispatches FA3
/// on Hopper-class parts, FA2 elsewhere (§V-A). The engine's borrowed-key
/// cache probe consumes this directly so cache hits never clone the config.
pub fn fa3_for(gpu: &GpuSpec) -> bool {
    matches!(gpu.arch, crate::hw::Arch::Hopper | crate::hw::Arch::Blackwell)
}

/// Resolve GPU-specific kernel selection (FA2 vs FA3) into an owned config.
pub fn finalize_for_gpu(cfg: &KernelConfig, gpu: &GpuSpec) -> KernelConfig {
    let mut out = cfg.clone();
    if let KernelConfig::Attention { fa3, .. } = &mut out {
        *fa3 = fa3_for(gpu);
    }
    out
}

/// Analyze + measure one (config, gpu) pair into a Sample.
///
/// Routed through the protocol-v1 request path ([`crate::api`], which owns
/// validation and the shared engine): the analytical half (decompose →
/// schedule → featurize, plus the baseline feature views) is memoized
/// across calls; only the seeded oracle measurement always runs. The
/// sampler only produces valid launches, so validation failure is a bug.
pub fn make_sample(cfg: &KernelConfig, gpu: &GpuSpec, seed: u64) -> Sample {
    crate::api::profile_sample(cfg, gpu, seed).expect("sampled launch geometry is valid")
}

/// Build `n_configs` sampled configs profiled on every GPU in `gpus`,
/// parallelized across `threads` workers.
/// Deterministically re-derivable config list — experiments that need the
/// original launch parameters (e.g. the §VII autotuner) regenerate them
/// from the same seed.
pub fn sample_configs(kind: KernelKind, n_configs: usize, seed: u64) -> Vec<KernelConfig> {
    let mut base = Rng::new(seed ^ kind.name().len() as u64);
    (0..n_configs).map(|_| sample_config(kind, &mut base)).collect()
}

pub fn build(
    kind: KernelKind,
    gpus: &[GpuSpec],
    n_configs: usize,
    seed: u64,
    threads: usize,
) -> Vec<Sample> {
    crate::api::build_dataset(kind, gpus, n_configs, seed, threads)
}

/// Split by hardware: (seen-GPU rows, unseen-GPU rows) — Table VI split.
pub fn split_seen(samples: &[Sample]) -> (Vec<Sample>, Vec<Sample>) {
    let seen = samples.iter().filter(|s| s.seen).cloned().collect();
    let unseen = samples.iter().filter(|s| !s.seen).cloned().collect();
    (seen, unseen)
}

pub fn save<P: AsRef<Path>>(samples: &[Sample], path: P) -> Result<()> {
    let mut header = vec![
        "kind".to_string(),
        "gpu".to_string(),
        "seen".to_string(),
        "theory_sec".to_string(),
        "latency_sec".to_string(),
        "roofline_sec".to_string(),
        "compute_sec".to_string(),
        "mem_sec".to_string(),
        "habitat_sec".to_string(),
        "alt_theory_sec".to_string(),
    ];
    for i in 0..FEATURE_DIM {
        header.push(format!("x{i}"));
    }
    for i in 0..FEATURE_DIM {
        header.push(format!("a{i}"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut w = CsvWriter::create(path, &hdr)?;
    for s in samples {
        let mut row = vec![
            s.kind.name().to_string(),
            s.gpu.replace(',', ";"),
            (s.seen as u8).to_string(),
            format!("{:e}", s.theory_sec),
            format!("{:e}", s.latency_sec),
            format!("{:e}", s.roofline_sec),
            format!("{:e}", s.compute_sec),
            format!("{:e}", s.mem_sec),
            format!("{:e}", s.habitat_sec),
            format!("{:e}", s.alt_theory_sec),
        ];
        for v in s.x {
            row.push(format!("{v}"));
        }
        for v in s.x_alt {
            row.push(format!("{v}"));
        }
        w.row(&row)?;
    }
    w.finish()
}

pub fn load<P: AsRef<Path>>(path: P) -> Result<Vec<Sample>> {
    let data = read_csv(path)?;
    let kind_i = data.col_idx("kind")?;
    let gpu_i = data.col_idx("gpu")?;
    let seen_i = data.col_idx("seen")?;
    let th_i = data.col_idx("theory_sec")?;
    let lat_i = data.col_idx("latency_sec")?;
    let roof_i = data.col_idx("roofline_sec")?;
    let comp_i = data.col_idx("compute_sec")?;
    let mem_i = data.col_idx("mem_sec")?;
    let hab_i = data.col_idx("habitat_sec")?;
    let alt_i = data.col_idx("alt_theory_sec")?;
    let x0 = data.col_idx("x0")?;
    let a0 = data.col_idx("a0")?;
    let mut out = Vec::with_capacity(data.rows.len());
    for r in &data.rows {
        let mut x = [0f32; FEATURE_DIM];
        for (i, v) in x.iter_mut().enumerate() {
            *v = r[x0 + i].parse()?;
        }
        let mut x_alt = [0f32; FEATURE_DIM];
        for (i, v) in x_alt.iter_mut().enumerate() {
            *v = r[a0 + i].parse()?;
        }
        out.push(Sample {
            kind: KernelKind::from_name(&r[kind_i])
                .ok_or_else(|| anyhow::anyhow!("bad kind {:?}", r[kind_i]))?,
            gpu: r[gpu_i].clone(),
            seen: r[seen_i] == "1",
            theory_sec: r[th_i].parse()?,
            latency_sec: r[lat_i].parse()?,
            roofline_sec: r[roof_i].parse()?,
            compute_sec: r[comp_i].parse()?,
            mem_sec: r[mem_i].parse()?,
            habitat_sec: r[hab_i].parse()?,
            alt_theory_sec: r[alt_i].parse()?,
            x,
            x_alt,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{all_gpus, gpu_by_name};

    #[test]
    fn sampler_respects_ranges() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            match sample_config(KernelKind::Gemm, &mut rng) {
                KernelConfig::Gemm { m, n, k, .. } => {
                    assert!((2..=131_072).contains(&m));
                    assert!((384..=152_064).contains(&n));
                    assert!((256..=53_248).contains(&k));
                }
                _ => panic!(),
            }
            match sample_config(KernelKind::Attention, &mut rng) {
                KernelConfig::Attention { batch, nh, nkv, hd, .. } => {
                    assert!((1..=16).contains(&(batch.len() as u32)));
                    assert!(nh >= nkv && nh <= 128);
                    assert!(hd == 64 || hd == 128);
                    for (q, kv) in batch {
                        assert!(q >= 1 && kv >= q && kv <= 20_481);
                    }
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn build_produces_rows_per_gpu() {
        let gpus: Vec<GpuSpec> =
            vec![gpu_by_name("A100").unwrap(), gpu_by_name("H100").unwrap()];
        let ds = build(KernelKind::RmsNorm, &gpus, 8, 42, 2);
        assert_eq!(ds.len(), 16);
        assert!(ds.iter().all(|s| s.latency_sec > 0.0 && s.theory_sec > 0.0));
        assert!(ds.iter().all(|s| s.efficiency() > 0.0 && s.efficiency() < 1.0));
    }

    #[test]
    fn build_is_deterministic() {
        let gpus = vec![gpu_by_name("L20").unwrap()];
        let a = build(KernelKind::SiluMul, &gpus, 5, 7, 1);
        let b = build(KernelKind::SiluMul, &gpus, 5, 7, 3); // thread count irrelevant
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.latency_sec, y.latency_sec);
            assert_eq!(x.x, y.x);
        }
    }

    #[test]
    fn csv_roundtrip() {
        let gpus = vec![gpu_by_name("A40").unwrap()];
        let ds = build(KernelKind::Gemm, &gpus, 4, 3, 1);
        let path = std::env::temp_dir().join("synperf_ds_test.csv");
        save(&ds, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(ds.len(), back.len());
        for (a, b) in ds.iter().zip(&back) {
            assert_eq!(a.kind, b.kind);
            assert_eq!(a.gpu, b.gpu);
            assert!((a.latency_sec - b.latency_sec).abs() / a.latency_sec < 1e-9);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn seen_split_matches_table_vi() {
        let ds = build(KernelKind::RmsNorm, &all_gpus(), 3, 1, 4);
        let (seen, unseen) = split_seen(&ds);
        assert_eq!(seen.len(), 18);
        assert_eq!(unseen.len(), 15);
    }

    #[test]
    fn efficiency_varies_across_hardware() {
        // the learning signal: same config, different efficiency per GPU
        let mut rng = Rng::new(5);
        let cfg = sample_config(KernelKind::Gemm, &mut rng);
        let effs: Vec<f64> = all_gpus()
            .iter()
            .map(|g| make_sample(&cfg, g, 1).efficiency())
            .collect();
        let min = effs.iter().cloned().fold(f64::MAX, f64::min);
        let max = effs.iter().cloned().fold(0.0, f64::max);
        assert!(max > min * 1.15, "efficiency spread too small: {effs:?}");
    }
}
