//! Hand-rolled random-forest regressor — the data-driven estimator the paper
//! uses for communication kernels (§V-D: "we apply a data-driven regression
//! technique (e.g., Random Forest) to estimate communication kernel
//! latency"). Bootstrap-sampled CART trees with feature subsampling and a
//! depth/size cap; mean aggregation.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
enum Node {
    Leaf(f64),
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf(v) => return *v,
                Node::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct ForestConfig {
    pub n_trees: usize,
    pub max_depth: usize,
    pub min_leaf: usize,
    /// features tried per split (0 = all)
    pub max_features: usize,
    pub seed: u64,
}

impl Default for ForestConfig {
    fn default() -> Self {
        ForestConfig { n_trees: 40, max_depth: 12, min_leaf: 3, max_features: 0, seed: 99 }
    }
}

#[derive(Debug, Clone)]
pub struct RandomForest {
    trees: Vec<Tree>,
    pub dim: usize,
}

impl RandomForest {
    pub fn fit(xs: &[Vec<f64>], ys: &[f64], cfg: &ForestConfig) -> RandomForest {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let dim = xs[0].len();
        let mut rng = Rng::new(cfg.seed);
        let trees = (0..cfg.n_trees)
            .map(|_| {
                // bootstrap sample
                let idx: Vec<usize> =
                    (0..xs.len()).map(|_| rng.range_usize(0, xs.len() - 1)).collect();
                let mut t = Tree { nodes: Vec::new() };
                grow(&mut t, xs, ys, idx, 0, cfg, dim, &mut rng);
                t
            })
            .collect();
        RandomForest { trees, dim }
    }

    pub fn predict(&self, x: &[f64]) -> f64 {
        assert_eq!(x.len(), self.dim);
        self.trees.iter().map(|t| t.predict(x)).sum::<f64>() / self.trees.len() as f64
    }
}

fn mean_of(ys: &[f64], idx: &[usize]) -> f64 {
    idx.iter().map(|&i| ys[i]).sum::<f64>() / idx.len().max(1) as f64
}

fn sse_of(ys: &[f64], idx: &[usize], mean: f64) -> f64 {
    idx.iter().map(|&i| (ys[i] - mean).powi(2)).sum()
}

/// Recursively grow a tree; returns node index.
#[allow(clippy::too_many_arguments)]
fn grow(
    tree: &mut Tree,
    xs: &[Vec<f64>],
    ys: &[f64],
    idx: Vec<usize>,
    depth: usize,
    cfg: &ForestConfig,
    dim: usize,
    rng: &mut Rng,
) -> usize {
    let mean = mean_of(ys, &idx);
    if depth >= cfg.max_depth || idx.len() < 2 * cfg.min_leaf {
        tree.nodes.push(Node::Leaf(mean));
        return tree.nodes.len() - 1;
    }
    let parent_sse = sse_of(ys, &idx, mean);
    if parent_sse < 1e-18 {
        tree.nodes.push(Node::Leaf(mean));
        return tree.nodes.len() - 1;
    }

    // candidate features
    let k = if cfg.max_features == 0 { (dim as f64).sqrt().ceil() as usize } else { cfg.max_features };
    let mut feats: Vec<usize> = (0..dim).collect();
    rng.shuffle(&mut feats);
    feats.truncate(k.max(1));

    let mut best: Option<(f64, usize, f64)> = None; // (gain, feature, threshold)
    for &f in &feats {
        // candidate thresholds from value quantiles
        let mut vals: Vec<f64> = idx.iter().map(|&i| xs[i][f]).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        vals.dedup();
        if vals.len() < 2 {
            continue;
        }
        for q in 1..8 {
            let thr = vals[(vals.len() * q / 8).min(vals.len() - 1)];
            let (mut ls, mut ln, mut rs, mut rn) = (0.0, 0usize, 0.0, 0usize);
            for &i in &idx {
                if xs[i][f] <= thr {
                    ls += ys[i];
                    ln += 1;
                } else {
                    rs += ys[i];
                    rn += 1;
                }
            }
            if ln < cfg.min_leaf || rn < cfg.min_leaf {
                continue;
            }
            let (lm, rm) = (ls / ln as f64, rs / rn as f64);
            let child_sse: f64 = idx
                .iter()
                .map(|&i| {
                    let m = if xs[i][f] <= thr { lm } else { rm };
                    (ys[i] - m).powi(2)
                })
                .sum();
            let gain = parent_sse - child_sse;
            if best.map(|(g, _, _)| gain > g).unwrap_or(gain > 1e-15) {
                best = Some((gain, f, thr));
            }
        }
    }

    match best {
        None => {
            tree.nodes.push(Node::Leaf(mean));
            tree.nodes.len() - 1
        }
        Some((_, f, thr)) => {
            let (l_idx, r_idx): (Vec<usize>, Vec<usize>) =
                idx.into_iter().partition(|&i| xs[i][f] <= thr);
            let me = tree.nodes.len();
            tree.nodes.push(Node::Leaf(0.0)); // placeholder
            let left = grow(tree, xs, ys, l_idx, depth + 1, cfg, dim, rng);
            let right = grow(tree, xs, ys, r_idx, depth + 1, cfg, dim, rng);
            tree.nodes[me] = Node::Split { feature: f, threshold: thr, left, right };
            me
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let xs: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.range_f64(0.0, 10.0), rng.range_f64(0.0, 5.0)]).collect();
        // nonlinear target with interaction
        let ys: Vec<f64> =
            xs.iter().map(|x| (x[0] * x[1]).sqrt() + if x[0] > 5.0 { 3.0 } else { 0.0 }).collect();
        (xs, ys)
    }

    #[test]
    fn learns_nonlinear_function() {
        let (xs, ys) = toy(800, 1);
        let f = RandomForest::fit(&xs, &ys, &ForestConfig::default());
        let (txs, tys) = toy(200, 2);
        let mae: f64 = txs
            .iter()
            .zip(&tys)
            .map(|(x, y)| (f.predict(x) - y).abs())
            .sum::<f64>()
            / tys.len() as f64;
        let spread = tys.iter().cloned().fold(0.0, f64::max);
        assert!(mae < spread * 0.12, "mae {mae} vs spread {spread}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (xs, ys) = toy(100, 3);
        let a = RandomForest::fit(&xs, &ys, &ForestConfig::default());
        let b = RandomForest::fit(&xs, &ys, &ForestConfig::default());
        assert_eq!(a.predict(&xs[0]), b.predict(&xs[0]));
    }

    #[test]
    fn handles_constant_target() {
        let xs: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let ys = vec![2.5; 50];
        let f = RandomForest::fit(&xs, &ys, &ForestConfig::default());
        assert!((f.predict(&[25.0]) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn respects_min_leaf() {
        let (xs, ys) = toy(30, 4);
        let cfg = ForestConfig { min_leaf: 15, ..Default::default() };
        let f = RandomForest::fit(&xs, &ys, &cfg);
        // with min_leaf = n/2 trees are single leaves -> constant predictor
        let p1 = f.predict(&xs[0]);
        let p2 = f.predict(&xs[1]);
        assert!((p1 - p2).abs() < 1.0);
    }
}
