//! Pareto frontier over the sweep rows: maximize tokens/sec, maximize
//! SLO attainment, minimize GPU count (replicas × tp × pp). Error rows
//! never participate. The frontier is ranked by throughput-per-GPU (the
//! capacity-planning headline), and every dominated row is annotated with
//! the frontier rows that dominate it, in rank order.

use super::{SweepMetrics, SweepRow};
use std::collections::BTreeSet;

/// Cap on the per-row dominated-by annotation — enough to point at the
/// configs worth switching to without quadratic output on dense grids.
pub const DOMINATED_BY_CAP: usize = 3;

/// The ranked frontier plus dominated-by annotations, all in terms of row
/// indices (rows are emitted in index order, so `frontier[0]` names the
/// rank-1 row directly).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Pareto {
    /// Row indices on the frontier, best throughput-per-GPU first.
    pub frontier: Vec<usize>,
    /// `(row index, dominating frontier row indices)` for every ok row
    /// off the frontier, in row order; the inner list follows frontier
    /// rank and is capped at [`DOMINATED_BY_CAP`].
    pub dominated: Vec<(usize, Vec<usize>)>,
}

/// Strict Pareto dominance: at least as good on all three objectives and
/// strictly better on one.
fn dominates(a: (&SweepMetrics, u32), b: (&SweepMetrics, u32)) -> bool {
    let (am, ag) = a;
    let (bm, bg) = b;
    let better_eq = am.tokens_per_sec >= bm.tokens_per_sec
        && am.slo_attainment >= bm.slo_attainment
        && ag <= bg;
    let strict = am.tokens_per_sec > bm.tokens_per_sec
        || am.slo_attainment > bm.slo_attainment
        || ag < bg;
    better_eq && strict
}

/// Compute the frontier over `rows`. O(n²) dominance checks — bounded by
/// [`super::MAX_SWEEP_POINTS`], far below anything measurable.
pub fn pareto(rows: &[SweepRow]) -> Pareto {
    let ok: Vec<(usize, &SweepMetrics, u32)> = rows
        .iter()
        .filter_map(|r| r.outcome.as_ref().ok().map(|m| (r.index, m, r.gpu_count)))
        .collect();
    // positions (into `ok`) of rows no other ok row dominates
    let mut frontier: Vec<usize> = (0..ok.len())
        .filter(|&i| {
            !ok.iter()
                .enumerate()
                .any(|(j, b)| j != i && dominates((b.1, b.2), (ok[i].1, ok[i].2)))
        })
        .collect();
    frontier.sort_by(|&x, &y| {
        let (ix, mx, gx) = ok[x];
        let (iy, my, gy) = ok[y];
        let ex = mx.tokens_per_sec / f64::from(gx);
        let ey = my.tokens_per_sec / f64::from(gy);
        ey.total_cmp(&ex)
            .then(my.tokens_per_sec.total_cmp(&mx.tokens_per_sec))
            .then(ix.cmp(&iy))
    });
    let frontier_rows: Vec<usize> = frontier.iter().map(|&p| ok[p].0).collect();
    let on_frontier: BTreeSet<usize> = frontier_rows.iter().copied().collect();
    let dominated: Vec<(usize, Vec<usize>)> = ok
        .iter()
        .filter(|(ri, _, _)| !on_frontier.contains(ri))
        .map(|&(ri, m, g)| {
            let by: Vec<usize> = frontier
                .iter()
                .filter(|&&p| dominates((ok[p].1, ok[p].2), (m, g)))
                .map(|&p| ok[p].0)
                .take(DOMINATED_BY_CAP)
                .collect();
            (ri, by)
        })
        .collect();
    Pareto { frontier: frontier_rows, dominated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{RoutePolicy, ScenarioError};

    fn row(index: usize, tps: f64, slo: f64, gpus: u32) -> SweepRow {
        SweepRow {
            index,
            workload: "w".into(),
            gpu: "A100".into(),
            tp: gpus,
            pp: 1,
            replicas: 1,
            policy: RoutePolicy::RoundRobin,
            gpu_count: gpus,
            outcome: Ok(SweepMetrics {
                tokens_per_sec: tps,
                slo_attainment: slo,
                ttft_sec: 0.1,
                tpot_sec: 0.01,
                cluster: false,
                usd_per_hour: 1.9 * f64::from(gpus),
                usd_per_mtok: 0.5,
            }),
        }
    }

    fn err_row(index: usize) -> SweepRow {
        let mut r = row(index, 0.0, 0.0, 1);
        r.outcome = Err(ScenarioError::InvalidParallelism("tp".into()).into());
        r
    }

    #[test]
    fn frontier_keeps_tradeoffs_and_ranks_by_efficiency() {
        // r0: efficient; r1: 2x throughput at 2x cost (tie on tps/GPU,
        // higher raw tps ranks first); r2: dominated by both
        let rows = vec![row(0, 1024.0, 1.0, 1), row(1, 2048.0, 0.5, 2), row(2, 512.0, 0.5, 2)];
        let p = pareto(&rows);
        assert_eq!(p.frontier, vec![1, 0]);
        assert_eq!(p.dominated, vec![(2, vec![1, 0])]);
    }

    #[test]
    fn strictly_better_config_dominates_everything() {
        let rows =
            vec![row(0, 100.0, 0.5, 4), row(1, 200.0, 1.0, 1), row(2, 150.0, 0.75, 2)];
        let p = pareto(&rows);
        assert_eq!(p.frontier, vec![1]);
        assert_eq!(p.dominated.len(), 2);
        for (_, by) in &p.dominated {
            assert_eq!(by, &vec![1]);
        }
    }

    #[test]
    fn error_rows_never_participate() {
        let rows = vec![err_row(0), row(1, 10.0, 1.0, 1), err_row(2)];
        let p = pareto(&rows);
        assert_eq!(p.frontier, vec![1]);
        assert!(p.dominated.is_empty());
    }

    #[test]
    fn empty_and_all_error_sweeps_yield_empty_frontiers() {
        assert_eq!(pareto(&[]), Pareto::default());
        assert_eq!(pareto(&[err_row(0)]), Pareto::default());
    }

    #[test]
    fn dominated_by_honors_the_cap() {
        // four mutually non-dominating frontier points that all dominate r4
        let rows = vec![
            row(0, 400.0, 1.0, 4),
            row(1, 300.0, 1.0, 3),
            row(2, 200.0, 1.0, 2),
            row(3, 100.0, 1.0, 1),
            row(4, 50.0, 0.5, 5),
        ];
        let p = pareto(&rows);
        assert_eq!(p.frontier.len(), 4);
        let (ri, by) = &p.dominated[0];
        assert_eq!(*ri, 4);
        assert_eq!(by.len(), DOMINATED_BY_CAP);
    }
}
