//! The sweep executor: fan the expanded grid over work-stealing workers,
//! each owning one [`Simulator`] for its whole lifetime (per-GPU comm
//! models train once per worker, deterministically, and every evaluation
//! hammers the shared sharded engine cache), and re-emit finished rows in
//! strict index order regardless of scheduling. Rows are streamed through
//! the `on_row` callback as soon as their turn comes, so a caller can
//! print JSONL incrementally while the grid is still running.
//!
//! Crash safety rides on top of the same machinery: [`RunOptions`]
//! carries the process's [`Shard`] (only owned indices are evaluated),
//! the rows a journal already holds (re-emitted verbatim, never
//! recomputed), and an optional per-point deadline. A panicking point is
//! contained by `catch_unwind` into a typed `internal` error row — the
//! worker rebuilds its simulator and keeps going — and the deadline path
//! ([`run_sweep_deadline`]) runs detached workers under a watchdog that
//! converts a wedged evaluation into a typed `timeout` row while the
//! rest of the grid proceeds.

use super::grid::{expand_for, Shard, SweepPoint};
use super::journal::JournalSession;
use super::pareto::pareto;
use super::wire::{self, SweepRequest};
use super::{
    cluster_metrics, scenario_metrics, RowError, SweepError, SweepOutcome, SweepRow, SweepSpec,
};
use crate::scenario::wire::SimulateRequest;
use crate::scenario::Simulator;
use std::collections::{BTreeMap, BTreeSet, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Everything beyond the spec that shapes one run: worker budget, the
/// shard this process owns, an optional per-point deadline (honored by
/// [`run_sweep_deadline`] only — the scoped runner cannot abandon a
/// wedged scoped thread), and rows already durable in a journal.
#[derive(Debug, Default)]
pub struct RunOptions {
    /// Worker budget; a single worker evaluates serially and hands the
    /// full thread budget to the inner evaluators instead.
    pub threads: usize,
    pub shard: Shard,
    pub point_timeout_ms: Option<u64>,
    /// Rows replayed from a journal: re-emitted byte-identically (after
    /// re-encoding) and never recomputed.
    pub done: BTreeMap<usize, SweepRow>,
}

impl RunOptions {
    pub fn threads(threads: usize) -> Self {
        RunOptions { threads, ..Default::default() }
    }
}

/// Test-only failure injection, read once per run from the environment:
/// `SYNPERF_SWEEP_PANIC_INDEX=N` panics while evaluating global index N
/// (exercising `catch_unwind` containment); `SYNPERF_SWEEP_STALL_MS=N:MS`
/// wedges index N for MS milliseconds (exercising the watchdog). Only
/// spawned-process integration tests and example scripts set these — the
/// environment is process-global.
#[derive(Debug, Clone, Copy, Default)]
struct TestHooks {
    panic_index: Option<usize>,
    stall: Option<(usize, u64)>,
}

impl TestHooks {
    fn from_env() -> Self {
        let panic_index =
            std::env::var("SYNPERF_SWEEP_PANIC_INDEX").ok().and_then(|v| v.parse().ok());
        let stall = std::env::var("SYNPERF_SWEEP_STALL_MS").ok().and_then(|v| {
            let (idx, ms) = v.split_once(':')?;
            Some((idx.parse().ok()?, ms.parse().ok()?))
        });
        TestHooks { panic_index, stall }
    }
}

/// Materialize the simulate request for one grid point: the workload
/// template with the point's hardware coordinates written over it. For
/// cluster templates the sweep-level SLOs are pinned too, so attainment
/// is comparable across every row.
pub fn point_request(spec: &SweepSpec, point: &SweepPoint) -> SimulateRequest {
    match &spec.workloads[point.workload].template {
        SimulateRequest::Scenario(t) => {
            let mut s = t.clone();
            s.gpu = point.gpu.clone();
            s.tp = point.tp;
            s.pp = point.pp;
            SimulateRequest::Scenario(s)
        }
        SimulateRequest::Cluster(t) => {
            let mut c = t.clone();
            c.gpu = point.gpu.clone();
            c.tp = point.tp;
            c.pp = point.pp;
            c.replicas = point.replicas;
            c.policy = point.policy;
            c.slo_ttft_sec = spec.slo_ttft_sec;
            c.slo_tpot_sec = spec.slo_tpot_sec;
            SimulateRequest::Cluster(c)
        }
    }
}

/// A point's row skeleton — shared by real evaluation and the rows the
/// containment paths synthesize (panic, timeout, constraint).
fn point_row(spec: &SweepSpec, point: &SweepPoint, outcome: Result<super::SweepMetrics, RowError>) -> SweepRow {
    SweepRow {
        index: point.index,
        workload: spec.workloads[point.workload].name.clone(),
        gpu: point.gpu.clone(),
        tp: point.tp,
        pp: point.pp,
        replicas: point.replicas,
        policy: point.policy,
        gpu_count: point.replicas * point.tp * point.pp,
        outcome,
    }
}

/// Evaluate one point into its row. Never fails: infeasible configs
/// carry their typed error in the outcome. Hard constraints are checked
/// before the simulation where possible (GPU count, budget — the point
/// is not even evaluated) and after it otherwise (SLO attainment), both
/// yielding typed `constraint_violated` rows.
fn eval_point(sim: &Simulator, spec: &SweepSpec, point: &SweepPoint, threads: usize) -> SweepRow {
    let gpu_count = point.replicas * point.tp * point.pp;
    if let Some(max) = spec.max_gpus {
        if gpu_count > max {
            return point_row(
                spec,
                point,
                Err(RowError::ConstraintViolated(format!("gpu_count {gpu_count} > max_gpus {max}"))),
            );
        }
    }
    let gpu = crate::hw::gpu_by_name(&point.gpu);
    if let (Some(max), Some(g)) = (spec.max_usd_per_hour, gpu.as_ref()) {
        let rate = g.usd_per_hour * f64::from(gpu_count);
        if rate > max {
            return point_row(
                spec,
                point,
                Err(RowError::ConstraintViolated(format!(
                    "usd_per_hour {rate} > max_usd_per_hour {max}"
                ))),
            );
        }
    }
    let outcome = match point_request(spec, point) {
        SimulateRequest::Scenario(s) => sim
            .simulate_with_threads(&s, threads)
            .map(|r| scenario_metrics(spec.slo_ttft_sec, spec.slo_tpot_sec, point.replicas, &r)),
        SimulateRequest::Cluster(c) => {
            sim.simulate_cluster_with_threads(&c, threads).map(|r| cluster_metrics(&r))
        }
    };
    let outcome = outcome.map_err(RowError::from).and_then(|mut m| {
        if let Some(g) = gpu.as_ref() {
            m.apply_cost(g, gpu_count);
        }
        if let Some(min) = spec.min_slo_attainment {
            if m.slo_attainment < min {
                return Err(RowError::ConstraintViolated(format!(
                    "slo_attainment {} < min_slo_attainment {min}",
                    m.slo_attainment
                )));
            }
        }
        Ok(m)
    });
    point_row(spec, point, outcome)
}

/// [`eval_point`] under `catch_unwind`: a panicking point becomes a typed
/// `internal` error row and the worker's simulator is rebuilt (the panic
/// may have poisoned its internal state mid-update).
fn eval_contained(
    sim: &mut Simulator,
    factory: impl Fn() -> Simulator,
    spec: &SweepSpec,
    point: &SweepPoint,
    threads: usize,
    hooks: &TestHooks,
) -> SweepRow {
    let result = catch_unwind(AssertUnwindSafe(|| {
        if hooks.panic_index == Some(point.index) {
            panic!("test hook: injected panic at index {}", point.index);
        }
        if let Some((idx, ms)) = hooks.stall {
            if idx == point.index {
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        eval_point(sim, spec, point, threads)
    }));
    match result {
        Ok(row) => row,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<String>()
                .map(String::as_str)
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("panic");
            *sim = factory();
            point_row(
                spec,
                point,
                Err(RowError::Internal(format!("sweep point evaluation panicked: {msg}"))),
            )
        }
    }
}

/// Run the whole sweep with default options. `factory` builds one
/// [`Simulator`] per worker ([`Simulator`] is not `Send`, and per-worker
/// construction is exactly what keeps the comm-model cache hot);
/// `threads` bounds the worker count — rows are byte-identical at any
/// count, which is the repo-wide `--threads` invariant. `on_row` fires
/// once per row, in index order, as soon as the row's turn completes.
pub fn run_sweep<F, G>(
    spec: &SweepSpec,
    factory: F,
    threads: usize,
    on_row: G,
) -> Result<SweepOutcome, SweepError>
where
    F: Fn() -> Simulator + Sync,
    G: FnMut(&SweepRow),
{
    run_sweep_with(spec, &factory, &RunOptions::threads(threads), on_row)
}

/// The scoped runner: shard filtering, journal replay and panic
/// containment over borrowed state. Ignores `point_timeout_ms` — a
/// scoped thread cannot be abandoned, so the watchdog lives in
/// [`run_sweep_deadline`].
pub fn run_sweep_with<F, G>(
    spec: &SweepSpec,
    factory: &F,
    opts: &RunOptions,
    mut on_row: G,
) -> Result<SweepOutcome, SweepError>
where
    F: Fn() -> Simulator + Sync,
    G: FnMut(&SweepRow),
{
    opts.shard.check()?;
    let points = expand_for(spec, opts.shard.count)?;
    let hooks = TestHooks::from_env();
    // the emission sequence: every owned index, done rows included
    let seq: Vec<usize> =
        points.iter().map(|p| p.index).filter(|&i| opts.shard.owns(i)).collect();
    let todo: Vec<usize> = seq.iter().copied().filter(|i| !opts.done.contains_key(i)).collect();
    let threads = opts.threads.max(1);
    let workers = if todo.is_empty() { 1 } else { threads.min(todo.len()) };
    let mut rows: Vec<SweepRow> = Vec::with_capacity(seq.len());
    let mut emit = |pending: &mut BTreeMap<usize, SweepRow>,
                    next_emit: &mut usize,
                    rows: &mut Vec<SweepRow>| {
        while *next_emit < seq.len() {
            let Some(row) = pending.remove(&seq[*next_emit]) else { break };
            on_row(&row);
            rows.push(row);
            *next_emit += 1;
        }
    };
    let mut pending: BTreeMap<usize, SweepRow> = opts.done.clone();
    let mut next_emit = 0usize;
    if workers <= 1 {
        let mut sim = factory();
        emit(&mut pending, &mut next_emit, &mut rows);
        for &i in &todo {
            let row = eval_contained(&mut sim, factory, spec, &points[i], threads, &hooks);
            pending.insert(row.index, row);
            emit(&mut pending, &mut next_emit, &mut rows);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = sync_channel::<SweepRow>(workers * 4);
        let next_ref = &next;
        let todo_ref = &todo[..];
        let points_ref = &points[..];
        let hooks_ref = &hooks;
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                s.spawn(move || {
                    let mut sim = factory();
                    loop {
                        let t = next_ref.fetch_add(1, Ordering::Relaxed);
                        if t >= todo_ref.len() {
                            break;
                        }
                        // inner evaluation stays single-threaded — the
                        // outer fan-out owns the parallelism budget
                        let row = eval_contained(
                            &mut sim,
                            factory,
                            spec,
                            &points_ref[todo_ref[t]],
                            1,
                            hooks_ref,
                        );
                        if tx.send(row).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            // reorder out-of-order completions with O(workers + channel)
            // buffered rows: emit strictly by sequence position as gaps fill
            emit(&mut pending, &mut next_emit, &mut rows);
            while let Ok(row) = rx.recv() {
                pending.insert(row.index, row);
                emit(&mut pending, &mut next_emit, &mut rows);
            }
        });
    }
    debug_assert_eq!(rows.len(), seq.len());
    let frontier = pareto(&rows);
    Ok(SweepOutcome { rows, pareto: frontier })
}

/// Serve-surface entry: honor a full wire [`SweepRequest`] — shard
/// assignment plus an optional journal — with the scoped runner. The
/// journal is create-or-resume: an existing file is replayed (fingerprint
/// checked), a missing one starts fresh. Clobber policy belongs to
/// interactive callers (the CLI refuses without `--resume`); a serving
/// peer re-sending a request wants the resume. A journal write failure
/// fails the run loudly rather than pretending the rows are durable.
pub fn run_request<F>(
    req: &SweepRequest,
    factory: &F,
    threads: usize,
) -> Result<SweepOutcome, SweepError>
where
    F: Fn() -> Simulator + Sync,
{
    let mut session = match &req.journal {
        Some(p) => {
            let path = std::path::Path::new(p);
            Some(JournalSession::open(path, &req.spec, req.shard, path.exists())?)
        }
        None => None,
    };
    let done = session.as_mut().map(|s| std::mem::take(&mut s.done)).unwrap_or_default();
    let replayed: BTreeSet<usize> = done.keys().copied().collect();
    let opts = RunOptions { threads, shard: req.shard, point_timeout_ms: None, done };
    let mut io_err = None;
    let out = run_sweep_with(&req.spec, factory, &opts, |row| {
        if io_err.is_none() && !replayed.contains(&row.index) {
            if let Some(s) = session.as_mut() {
                if let Err(e) = s.record(&wire::encode_row(row)) {
                    io_err = Some(e);
                }
            }
        }
    })?;
    match io_err {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

/// The watchdog runner: same contract as [`run_sweep_with`], but workers
/// are detached `'static` threads under per-point claim tracking, so a
/// point that exceeds `point_timeout_ms` is abandoned — its claim turns
/// into a typed `timeout` row, a replacement worker takes over the rest
/// of the queue, and the wedged thread's eventual result (if any) is
/// dropped. The scoped runner cannot do this: joining a scope would
/// block on the wedged thread forever.
pub fn run_sweep_deadline<F, G>(
    spec: &SweepSpec,
    factory: Arc<F>,
    opts: &RunOptions,
    mut on_row: G,
) -> Result<SweepOutcome, SweepError>
where
    F: Fn() -> Simulator + Send + Sync + 'static,
    G: FnMut(&SweepRow),
{
    opts.shard.check()?;
    let points = Arc::new(expand_for(spec, opts.shard.count)?);
    let hooks = TestHooks::from_env();
    let spec = Arc::new(spec.clone());
    let seq: Vec<usize> =
        points.iter().map(|p| p.index).filter(|&i| opts.shard.owns(i)).collect();
    let todo: Arc<Vec<usize>> =
        Arc::new(seq.iter().copied().filter(|i| !opts.done.contains_key(i)).collect());
    let timeout = Duration::from_millis(opts.point_timeout_ms.unwrap_or(u64::MAX >> 20));
    let workers = if todo.is_empty() { 1 } else { opts.threads.max(1).min(todo.len()) };
    let next = Arc::new(AtomicUsize::new(0));
    let (tx, rx) = channel::<SweepRow>();

    type ClaimSlot = Arc<Mutex<Option<(usize, Instant)>>>;
    let spawn_worker = || -> ClaimSlot {
        let slot: ClaimSlot = Arc::new(Mutex::new(None));
        let (slot2, tx) = (slot.clone(), tx.clone());
        let (factory, spec) = (factory.clone(), spec.clone());
        let (points, todo, next) = (points.clone(), todo.clone(), next.clone());
        std::thread::spawn(move || {
            let mut sim = factory();
            loop {
                let t = next.fetch_add(1, Ordering::Relaxed);
                if t >= todo.len() {
                    break;
                }
                let gi = todo[t];
                *slot2.lock().unwrap() = Some((gi, Instant::now()));
                let row =
                    eval_contained(&mut sim, &*factory, &spec, &points[gi], 1, &hooks);
                *slot2.lock().unwrap() = None;
                if tx.send(row).is_err() {
                    break;
                }
            }
        });
        slot
    };
    let mut slots: Vec<ClaimSlot> = (0..workers).map(|_| spawn_worker()).collect();

    let mut rows: Vec<SweepRow> = Vec::with_capacity(seq.len());
    let mut pending: BTreeMap<usize, SweepRow> = opts.done.clone();
    let mut abandoned: HashSet<usize> = HashSet::new();
    let mut next_emit = 0usize;
    let tick = timeout.min(Duration::from_millis(20));
    while next_emit < seq.len() {
        while next_emit < seq.len() {
            let Some(row) = pending.remove(&seq[next_emit]) else { break };
            on_row(&row);
            rows.push(row);
            next_emit += 1;
        }
        if next_emit >= seq.len() {
            break;
        }
        match rx.recv_timeout(tick) {
            Ok(row) => {
                // a wedged point may complete after its timeout row was
                // already synthesized — the late result is dropped
                if !abandoned.contains(&row.index) {
                    pending.insert(row.index, row);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                let mut stale: Vec<usize> = Vec::new();
                for slot in &slots {
                    if let Some((gi, since)) = *slot.lock().unwrap() {
                        if since.elapsed() >= timeout && !abandoned.contains(&gi) {
                            stale.push(gi);
                        }
                    }
                }
                for gi in stale {
                    abandoned.insert(gi);
                    let why = format!(
                        "point evaluation exceeded {}ms",
                        opts.point_timeout_ms.unwrap_or_default()
                    );
                    pending.insert(gi, point_row(&spec, &points[gi], Err(RowError::Timeout(why))));
                    // the wedged worker is written off; keep the pool at
                    // strength if unclaimed work remains
                    if next.load(Ordering::Relaxed) < todo.len() {
                        slots.push(spawn_worker());
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => unreachable!("collector holds a sender"),
        }
    }
    drop(tx);
    let frontier = pareto(&rows);
    Ok(SweepOutcome { rows, pareto: frontier })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::workload::Request;
    use crate::scenario::{ScenarioSpec, WorkloadSpec};
    use crate::sweep::GpuFilter;

    fn small_sweep() -> SweepSpec {
        // llama3.1-8b has 32 attention heads: tp=3 cannot divide them, so
        // half the grid is infeasible by construction
        SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
            .tp(vec![1, 3])
            .scenario(
                "tiny",
                ScenarioSpec::new("llama3.1-8b", "")
                    .workload(WorkloadSpec::Explicit(vec![Request {
                        input_len: 64,
                        output_len: 4,
                    }]))
                    .seed(3),
            )
    }

    #[test]
    fn rows_stream_in_index_order_and_are_identical_across_thread_counts() {
        let spec = small_sweep();
        let run = |threads: usize| {
            let mut streamed: Vec<usize> = Vec::new();
            let out = run_sweep(&spec, Simulator::degraded, threads, |r| streamed.push(r.index))
                .unwrap();
            assert_eq!(streamed, vec![0, 1, 2, 3], "streaming order at {threads} threads");
            out
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.rows, four.rows, "rows must not depend on scheduling");
        assert_eq!(one.pareto, four.pareto);
        for (i, r) in one.rows.iter().enumerate() {
            assert_eq!(r.index, i);
        }
    }

    #[test]
    fn infeasible_points_become_typed_error_rows_without_aborting() {
        let out = run_sweep(&small_sweep(), Simulator::degraded, 2, |_| {}).unwrap();
        assert_eq!(out.rows.len(), 4);
        // grid order: (A100,1) (A100,3) (H800,1) (H800,3)
        for (i, r) in out.rows.iter().enumerate() {
            if r.tp == 3 {
                assert_eq!(
                    r.outcome.as_ref().unwrap_err().code(),
                    "invalid_parallelism",
                    "row {i}"
                );
            } else {
                let m = r.outcome.as_ref().expect("tp=1 rows must succeed");
                assert!(m.tokens_per_sec > 0.0, "row {i}");
            }
        }
        // error rows never reach the frontier
        for &fi in &out.pareto.frontier {
            assert!(out.rows[fi].outcome.is_ok());
        }
        assert!(!out.pareto.frontier.is_empty());
    }

    #[test]
    fn spec_level_failures_abort_before_any_row() {
        let spec = small_sweep().gpus(GpuFilter::Named(vec!["B300".into()]));
        let mut streamed = 0usize;
        let err = run_sweep(&spec, Simulator::degraded, 2, |_| streamed += 1).unwrap_err();
        assert_eq!(err.code(), "unknown_gpu");
        assert_eq!(streamed, 0);
    }

    #[test]
    fn v1_replicas_scale_throughput_but_not_latency() {
        let spec = small_sweep()
            .gpus(GpuFilter::Named(vec!["A100".into()]))
            .tp(vec![1])
            .replicas(vec![1, 2]);
        let out = run_sweep(&spec, Simulator::degraded, 1, |_| {}).unwrap();
        let one = out.rows[0].outcome.as_ref().unwrap();
        let two = out.rows[1].outcome.as_ref().unwrap();
        assert_eq!(out.rows[1].gpu_count, 2);
        assert!((two.tokens_per_sec - 2.0 * one.tokens_per_sec).abs() < 1e-9);
        assert_eq!(two.ttft_sec, one.ttft_sec);
        assert_eq!(two.tpot_sec, one.tpot_sec);
    }

    #[test]
    fn rows_carry_registry_cost_columns() {
        let spec = small_sweep().tp(vec![1]).replicas(vec![2]);
        let out = run_sweep(&spec, Simulator::degraded, 1, |_| {}).unwrap();
        for r in &out.rows {
            let g = crate::hw::gpu_by_name(&r.gpu).unwrap();
            let m = r.outcome.as_ref().unwrap();
            assert_eq!(m.usd_per_hour, g.usd_per_hour * f64::from(r.gpu_count), "{}", r.gpu);
            let expect = m.usd_per_hour / (m.tokens_per_sec * 3600.0 / 1.0e6);
            assert!((m.usd_per_mtok - expect).abs() < 1e-12, "{}", r.gpu);
            assert!(m.usd_per_mtok > 0.0);
        }
    }

    #[test]
    fn constraints_become_typed_rows_not_silent_drops() {
        // max_gpus: tp=3 rows (gpu_count 3) are filtered *before* the
        // infeasible-parallelism evaluation could even run
        let out =
            run_sweep(&small_sweep().max_gpus(2), Simulator::degraded, 2, |_| {}).unwrap();
        assert_eq!(out.rows.len(), 4);
        for r in &out.rows {
            if r.tp == 3 {
                let e = r.outcome.as_ref().unwrap_err();
                assert_eq!(e.code(), "constraint_violated");
                assert!(e.to_string().contains("gpu_count 3 > max_gpus 2"), "{e}");
            } else {
                assert!(r.outcome.is_ok());
            }
        }
        // budget: H800 rents at 2.8 $/hr, A100 at 1.9 — a 2.0 cap keeps
        // only the A100 rows
        let out = run_sweep(
            &small_sweep().tp(vec![1]).max_usd_per_hour(2.0),
            Simulator::degraded,
            1,
            |_| {},
        )
        .unwrap();
        assert!(out.rows[0].outcome.is_ok(), "A100 within budget");
        assert_eq!(out.rows[1].outcome.as_ref().unwrap_err().code(), "constraint_violated");
        // min_slo_attainment: an impossible bar turns every healthy row
        // into a typed violation
        let out = run_sweep(
            &small_sweep().tp(vec![1]).min_slo_attainment(1.0).slo(1e-9, 1e-9),
            Simulator::degraded,
            1,
            |_| {},
        )
        .unwrap();
        for r in &out.rows {
            assert_eq!(r.outcome.as_ref().unwrap_err().code(), "constraint_violated");
        }
        assert!(out.pareto.frontier.is_empty());
    }

    #[test]
    fn shards_cover_the_grid_and_union_to_the_unsharded_rows() {
        let spec = small_sweep();
        let full = run_sweep(&spec, Simulator::degraded, 2, |_| {}).unwrap();
        for count in [2u32, 3] {
            let mut union: Vec<SweepRow> = Vec::new();
            for index in 0..count {
                let opts = RunOptions {
                    threads: 2,
                    shard: Shard::new(index, count),
                    ..Default::default()
                };
                let part = run_sweep_with(&spec, &Simulator::degraded, &opts, |_| {}).unwrap();
                for r in &part.rows {
                    assert!(opts.shard.owns(r.index));
                }
                union.extend(part.rows);
            }
            union.sort_by_key(|r| r.index);
            assert_eq!(union, full.rows, "{count}-way shard union");
        }
    }

    #[test]
    fn journal_replay_rows_are_reemitted_not_recomputed() {
        let spec = small_sweep();
        let full = run_sweep(&spec, Simulator::degraded, 1, |_| {}).unwrap();
        // plant a sentinel as the "journaled" row 1: if the runner
        // recomputed it, the sentinel would be lost
        let mut sentinel = full.rows[1].clone();
        sentinel.workload = "journaled".into();
        let mut done = BTreeMap::new();
        done.insert(1usize, sentinel.clone());
        let opts = RunOptions { threads: 2, done, ..Default::default() };
        let mut streamed: Vec<usize> = Vec::new();
        let out = run_sweep_with(&spec, &Simulator::degraded, &opts, |r| streamed.push(r.index))
            .unwrap();
        assert_eq!(streamed, vec![0, 1, 2, 3], "replayed rows keep their stream slot");
        assert_eq!(out.rows[1], sentinel);
        assert_eq!(out.rows[0], full.rows[0]);
        assert_eq!(out.rows[2..], full.rows[2..]);
    }

    #[test]
    fn deadline_runner_matches_the_scoped_runner_when_nothing_wedges() {
        let spec = small_sweep();
        let scoped = run_sweep(&spec, Simulator::degraded, 2, |_| {}).unwrap();
        let opts = RunOptions { threads: 2, point_timeout_ms: Some(60_000), ..Default::default() };
        let mut streamed: Vec<usize> = Vec::new();
        let out = run_sweep_deadline(&spec, Arc::new(Simulator::degraded), &opts, |r| {
            streamed.push(r.index)
        })
        .unwrap();
        assert_eq!(streamed, vec![0, 1, 2, 3]);
        assert_eq!(out.rows, scoped.rows);
        assert_eq!(out.pareto, scoped.pareto);
    }
}
