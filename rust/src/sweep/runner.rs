//! The sweep executor: fan the expanded grid over work-stealing workers,
//! each owning one [`Simulator`] for its whole lifetime (per-GPU comm
//! models train once per worker, deterministically, and every evaluation
//! hammers the shared sharded engine cache), and re-emit finished rows in
//! strict index order regardless of scheduling. Rows are streamed through
//! the `on_row` callback as soon as their turn comes, so a caller can
//! print JSONL incrementally while the grid is still running.

use super::grid::{expand, SweepPoint};
use super::pareto::pareto;
use super::{cluster_metrics, scenario_metrics, SweepError, SweepOutcome, SweepRow, SweepSpec};
use crate::scenario::wire::SimulateRequest;
use crate::scenario::Simulator;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;

/// Materialize the simulate request for one grid point: the workload
/// template with the point's hardware coordinates written over it. For
/// cluster templates the sweep-level SLOs are pinned too, so attainment
/// is comparable across every row.
pub fn point_request(spec: &SweepSpec, point: &SweepPoint) -> SimulateRequest {
    match &spec.workloads[point.workload].template {
        SimulateRequest::Scenario(t) => {
            let mut s = t.clone();
            s.gpu = point.gpu.clone();
            s.tp = point.tp;
            s.pp = point.pp;
            SimulateRequest::Scenario(s)
        }
        SimulateRequest::Cluster(t) => {
            let mut c = t.clone();
            c.gpu = point.gpu.clone();
            c.tp = point.tp;
            c.pp = point.pp;
            c.replicas = point.replicas;
            c.policy = point.policy;
            c.slo_ttft_sec = spec.slo_ttft_sec;
            c.slo_tpot_sec = spec.slo_tpot_sec;
            SimulateRequest::Cluster(c)
        }
    }
}

/// Evaluate one point into its row. Never fails: infeasible configs
/// carry their typed [`crate::scenario::ScenarioError`] in the outcome.
fn eval_point(sim: &Simulator, spec: &SweepSpec, point: &SweepPoint, threads: usize) -> SweepRow {
    let outcome = match point_request(spec, point) {
        SimulateRequest::Scenario(s) => sim
            .simulate_with_threads(&s, threads)
            .map(|r| scenario_metrics(spec.slo_ttft_sec, spec.slo_tpot_sec, point.replicas, &r)),
        SimulateRequest::Cluster(c) => {
            sim.simulate_cluster_with_threads(&c, threads).map(|r| cluster_metrics(&r))
        }
    };
    SweepRow {
        index: point.index,
        workload: spec.workloads[point.workload].name.clone(),
        gpu: point.gpu.clone(),
        tp: point.tp,
        pp: point.pp,
        replicas: point.replicas,
        policy: point.policy,
        gpu_count: point.replicas * point.tp * point.pp,
        outcome,
    }
}

/// Run the whole sweep. `factory` builds one [`Simulator`] per worker
/// ([`Simulator`] is not `Send`, and per-worker construction is exactly
/// what keeps the comm-model cache hot); `threads` bounds the worker
/// count (a single worker evaluates serially and hands the full thread
/// budget to the inner evaluators instead — rows are byte-identical
/// either way, which is the repo-wide `--threads` invariant). `on_row`
/// fires once per row, in index order, as soon as the row's turn
/// completes.
pub fn run_sweep<F, G>(
    spec: &SweepSpec,
    factory: F,
    threads: usize,
    mut on_row: G,
) -> Result<SweepOutcome, SweepError>
where
    F: Fn() -> Simulator + Sync,
    G: FnMut(&SweepRow),
{
    let points = expand(spec)?;
    let threads = threads.max(1);
    let workers = threads.min(points.len()).max(1);
    let mut rows: Vec<SweepRow> = Vec::with_capacity(points.len());
    if workers <= 1 {
        let sim = factory();
        for point in &points {
            let row = eval_point(&sim, spec, point, threads);
            on_row(&row);
            rows.push(row);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = sync_channel::<SweepRow>(workers * 4);
        let next_ref = &next;
        let factory_ref = &factory;
        let points_ref = &points[..];
        std::thread::scope(|s| {
            for _ in 0..workers {
                let tx = tx.clone();
                s.spawn(move || {
                    let sim = factory_ref();
                    loop {
                        let i = next_ref.fetch_add(1, Ordering::Relaxed);
                        if i >= points_ref.len() {
                            break;
                        }
                        // inner evaluation stays single-threaded — the
                        // outer fan-out owns the parallelism budget
                        if tx.send(eval_point(&sim, spec, &points_ref[i], 1)).is_err() {
                            break;
                        }
                    }
                });
            }
            drop(tx);
            // reorder out-of-order completions with O(workers + channel)
            // buffered rows: emit strictly by index as gaps fill
            let mut pending: BTreeMap<usize, SweepRow> = BTreeMap::new();
            let mut next_emit = 0usize;
            while let Ok(row) = rx.recv() {
                pending.insert(row.index, row);
                while let Some(row) = pending.remove(&next_emit) {
                    on_row(&row);
                    rows.push(row);
                    next_emit += 1;
                }
            }
        });
    }
    let frontier = pareto(&rows);
    Ok(SweepOutcome { rows, pareto: frontier })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::workload::Request;
    use crate::scenario::{ScenarioSpec, WorkloadSpec};
    use crate::sweep::GpuFilter;

    fn small_sweep() -> SweepSpec {
        // llama3.1-8b has 32 attention heads: tp=3 cannot divide them, so
        // half the grid is infeasible by construction
        SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
            .tp(vec![1, 3])
            .scenario(
                "tiny",
                ScenarioSpec::new("llama3.1-8b", "")
                    .workload(WorkloadSpec::Explicit(vec![Request {
                        input_len: 64,
                        output_len: 4,
                    }]))
                    .seed(3),
            )
    }

    #[test]
    fn rows_stream_in_index_order_and_are_identical_across_thread_counts() {
        let spec = small_sweep();
        let run = |threads: usize| {
            let mut streamed: Vec<usize> = Vec::new();
            let out = run_sweep(&spec, Simulator::degraded, threads, |r| streamed.push(r.index))
                .unwrap();
            assert_eq!(streamed, vec![0, 1, 2, 3], "streaming order at {threads} threads");
            out
        };
        let one = run(1);
        let four = run(4);
        assert_eq!(one.rows, four.rows, "rows must not depend on scheduling");
        assert_eq!(one.pareto, four.pareto);
        for (i, r) in one.rows.iter().enumerate() {
            assert_eq!(r.index, i);
        }
    }

    #[test]
    fn infeasible_points_become_typed_error_rows_without_aborting() {
        let out = run_sweep(&small_sweep(), Simulator::degraded, 2, |_| {}).unwrap();
        assert_eq!(out.rows.len(), 4);
        // grid order: (A100,1) (A100,3) (H800,1) (H800,3)
        for (i, r) in out.rows.iter().enumerate() {
            if r.tp == 3 {
                assert_eq!(
                    r.outcome.as_ref().unwrap_err().code(),
                    "invalid_parallelism",
                    "row {i}"
                );
            } else {
                let m = r.outcome.as_ref().expect("tp=1 rows must succeed");
                assert!(m.tokens_per_sec > 0.0, "row {i}");
            }
        }
        // error rows never reach the frontier
        for &fi in &out.pareto.frontier {
            assert!(out.rows[fi].outcome.is_ok());
        }
        assert!(!out.pareto.frontier.is_empty());
    }

    #[test]
    fn spec_level_failures_abort_before_any_row() {
        let spec = small_sweep().gpus(GpuFilter::Named(vec!["B300".into()]));
        let mut streamed = 0usize;
        let err = run_sweep(&spec, Simulator::degraded, 2, |_| streamed += 1).unwrap_err();
        assert_eq!(err.code(), "unknown_gpu");
        assert_eq!(streamed, 0);
    }

    #[test]
    fn v1_replicas_scale_throughput_but_not_latency() {
        let spec = small_sweep()
            .gpus(GpuFilter::Named(vec!["A100".into()]))
            .tp(vec![1])
            .replicas(vec![1, 2]);
        let out = run_sweep(&spec, Simulator::degraded, 1, |_| {}).unwrap();
        let one = out.rows[0].outcome.as_ref().unwrap();
        let two = out.rows[1].outcome.as_ref().unwrap();
        assert_eq!(out.rows[1].gpu_count, 2);
        assert!((two.tokens_per_sec - 2.0 * one.tokens_per_sec).abs() < 1e-9);
        assert_eq!(two.ttft_sec, one.ttft_sec);
        assert_eq!(two.tpot_sec, one.tpot_sec);
    }
}
