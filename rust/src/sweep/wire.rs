//! JSONL wire codec for the **`sweep` verb**: a request line carries a
//! [`SweepSpec`]; the CLI streams one row line per grid point plus a
//! frontier block, while the stdio wire answers with a single line
//! embedding every row and the frontier (one-line-per-request holds).
//!
//! Request line:
//!
//! ```json
//! {"v":1,"id":"sw1","op":"sweep","sweep":{"gpus":"all","tp":[1,2],
//!  "pp":[1],"replicas":[1],"policies":["round_robin"],
//!  "slo":{"ttft_sec":2e0,"tpot_sec":2e-1},
//!  "workloads":[{"name":"chat","scenario":{"model":"Qwen2.5-14B",
//!  "workload":{"kind":"arxiv","batch":8},"seed":7}}]}}
//! ```
//!
//! `gpus` is `"all"` (default), `"seen"`, `"unseen"`, or an array of
//! names; every other axis defaults to `[1]` / `["round_robin"]`.
//! Workload templates are ordinary `scenario` / `cluster` objects whose
//! `gpu` (and `tp`/`pp`/`replicas`/`policy`) the grid overwrites per
//! point, so they may omit `gpu` entirely. Streamed row lines and the
//! frontier block:
//!
//! ```json
//! {"v":1,"row":{"index":0,"workload":"chat","gpu":"A40","tp":1,"pp":1,
//!  "replicas":1,"policy":"round_robin","gpu_count":1,"ok":true,
//!  "cluster":false,"tokens_per_sec":1.1e3,"slo_attainment":1e0,
//!  "ttft_sec":2.1e-1,"tpot_sec":1.9e-2}}
//! {"v":1,"row":{"index":3,...,"ok":false,"error":{"code":
//!  "invalid_parallelism","message":"...","reason":"..."}}}
//! {"v":1,"frontier":[{"rank":1,"index":5,...}],"dominated":[{"index":0,
//!  "by":[5]}]}
//! ```
//!
//! Spec-level failures speak the closed [`SweepError`] taxonomy; per-row
//! errors reuse the scenario error object byte-for-byte.

use super::{
    GpuFilter, Pareto, RowError, Shard, SweepError, SweepMetrics, SweepOutcome, SweepRow,
    SweepSpec, SweepWorkload,
};
use crate::api::wire::{esc, id_of};
use crate::api::PROTOCOL_VERSION;
use crate::scenario::wire::{self as scenario_wire, SimulateRequest};
use crate::scenario::{RoutePolicy, ScenarioError};
use crate::util::json::{parse, Json};

fn malformed(why: impl Into<String>) -> SweepError {
    SweepError::MalformedSpec(why.into())
}

/// Map a workload-template parse failure into the sweep taxonomy.
fn template_err(e: ScenarioError) -> SweepError {
    match e {
        ScenarioError::MalformedSpec(why) => SweepError::MalformedSpec(why),
        ScenarioError::UnknownGpu(gpu) => SweepError::UnknownGpu(gpu),
        other => SweepError::InvalidWorkload(other.to_string()),
    }
}

fn axis_u32(v: &Json, what: &str) -> Result<Vec<u32>, SweepError> {
    let arr = v
        .as_arr()
        .ok_or_else(|| malformed(format!("{what:?} must be an array of unsigned integers")))?;
    arr.iter()
        .map(|x| {
            x.as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
                .map(|n| n as u32)
                .ok_or_else(|| malformed(format!("{what:?} entries must be unsigned integers")))
        })
        .collect()
}

// ---- spec ----------------------------------------------------------------

fn filter_to_json(f: &GpuFilter) -> String {
    match f {
        GpuFilter::All => "\"all\"".to_string(),
        GpuFilter::Seen => "\"seen\"".to_string(),
        GpuFilter::Unseen => "\"unseen\"".to_string(),
        GpuFilter::Named(names) => {
            let items: Vec<String> = names.iter().map(|n| format!("\"{}\"", esc(n))).collect();
            format!("[{}]", items.join(","))
        }
    }
}

fn filter_from_json(v: &Json) -> Result<GpuFilter, SweepError> {
    match v {
        Json::Str(s) => match s.as_str() {
            "all" => Ok(GpuFilter::All),
            "seen" => Ok(GpuFilter::Seen),
            "unseen" => Ok(GpuFilter::Unseen),
            other => Err(malformed(format!(
                "\"gpus\" filter {other:?} is not all|seen|unseen"
            ))),
        },
        Json::Arr(items) => items
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| malformed("\"gpus\" entries must be strings"))
            })
            .collect::<Result<Vec<String>, SweepError>>()
            .map(GpuFilter::Named),
        _ => Err(malformed("\"gpus\" must be \"all\"|\"seen\"|\"unseen\" or an array of names")),
    }
}

/// Encode the optional hard constraints; empty string when none are set
/// (so legacy request lines stay byte-identical).
fn constraints_to_json(spec: &SweepSpec) -> String {
    let mut fields = Vec::new();
    if let Some(v) = spec.min_slo_attainment {
        fields.push(format!("\"min_slo_attainment\":{v:e}"));
    }
    if let Some(v) = spec.max_gpus {
        fields.push(format!("\"max_gpus\":{v}"));
    }
    if let Some(v) = spec.max_usd_per_hour {
        fields.push(format!("\"max_usd_per_hour\":{v:e}"));
    }
    if fields.is_empty() {
        String::new()
    } else {
        format!(",\"constraints\":{{{}}}", fields.join(","))
    }
}

/// Canonical spec encoding — the byte stream behind the journal
/// fingerprint ([`super::journal::fingerprint`]), so two processes agree
/// on spec identity exactly when their canonical encodings agree.
pub fn sweep_to_json(spec: &SweepSpec) -> String {
    let ints = |xs: &[u32]| xs.iter().map(u32::to_string).collect::<Vec<_>>().join(",");
    let policies: Vec<String> =
        spec.policies.iter().map(|p| format!("\"{}\"", p.name())).collect();
    let workloads: Vec<String> = spec
        .workloads
        .iter()
        .map(|w| {
            let body = match &w.template {
                SimulateRequest::Scenario(s) => {
                    format!("\"scenario\":{}", scenario_wire::spec_to_json(s))
                }
                SimulateRequest::Cluster(c) => {
                    format!("\"cluster\":{}", scenario_wire::cluster_to_json(c))
                }
            };
            format!("{{\"name\":\"{}\",{}}}", esc(&w.name), body)
        })
        .collect();
    format!(
        r#"{{"gpus":{},"tp":[{}],"pp":[{}],"replicas":[{}],"policies":[{}],"slo":{{"ttft_sec":{:e},"tpot_sec":{:e}}}{},"workloads":[{}]}}"#,
        filter_to_json(&spec.gpus),
        ints(&spec.tp),
        ints(&spec.pp),
        ints(&spec.replicas),
        policies.join(","),
        spec.slo_ttft_sec,
        spec.slo_tpot_sec,
        constraints_to_json(spec),
        workloads.join(",")
    )
}

/// A parsed sweep request: the spec plus the optional crash-safety
/// envelope fields — the shard this process owns and a journal path for
/// durable rows (stdio semantics: create-or-resume).
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRequest {
    pub spec: SweepSpec,
    pub shard: Shard,
    pub journal: Option<String>,
}

impl SweepRequest {
    pub fn new(spec: SweepSpec) -> Self {
        SweepRequest { spec, shard: Shard::default(), journal: None }
    }
}

/// Serialize a sweep request into its canonical wire line (no trailing
/// newline). The inverse of [`parse_sweep_line`].
pub fn encode_sweep_request(id: Option<&str>, spec: &SweepSpec) -> String {
    encode_sweep_request_with(id, &SweepRequest::new(spec.clone()))
}

/// [`encode_sweep_request`] carrying the crash-safety envelope fields:
/// `shard` is emitted only when non-default, `journal` only when set, so
/// plain requests stay byte-identical to the legacy shape.
pub fn encode_sweep_request_with(id: Option<&str>, req: &SweepRequest) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(",\"op\":\"sweep\",\"sweep\":{}", sweep_to_json(&req.spec)));
    if req.shard != Shard::default() {
        out.push_str(&format!(
            ",\"shard\":{{\"index\":{},\"count\":{}}}",
            req.shard.index, req.shard.count
        ));
    }
    if let Some(path) = &req.journal {
        out.push_str(&format!(",\"journal\":\"{}\"", esc(path)));
    }
    out.push('}');
    out
}

fn parse_sweep_object(j: &Json) -> Result<SweepSpec, SweepError> {
    let mut spec = SweepSpec::new();
    if let Some(v) = j.get("gpus") {
        spec.gpus = filter_from_json(v)?;
    }
    if let Some(v) = j.get("tp") {
        spec.tp = axis_u32(v, "tp")?;
    }
    if let Some(v) = j.get("pp") {
        spec.pp = axis_u32(v, "pp")?;
    }
    if let Some(v) = j.get("replicas") {
        spec.replicas = axis_u32(v, "replicas")?;
    }
    if let Some(v) = j.get("policies") {
        let arr =
            v.as_arr().ok_or_else(|| malformed("\"policies\" must be an array of names"))?;
        spec.policies = arr
            .iter()
            .map(|x| {
                let s = x
                    .as_str()
                    .ok_or_else(|| malformed("\"policies\" entries must be strings"))?;
                RoutePolicy::from_name(s).ok_or_else(|| {
                    SweepError::InvalidAxis(format!(
                        "unknown policy {s:?} (round_robin|least_loaded|session_affinity)"
                    ))
                })
            })
            .collect::<Result<Vec<RoutePolicy>, SweepError>>()?;
    }
    if let Some(s) = j.get("slo") {
        if let Some(v) = s.get("ttft_sec") {
            spec.slo_ttft_sec =
                v.as_f64().ok_or_else(|| malformed("\"slo.ttft_sec\" must be a number"))?;
        }
        if let Some(v) = s.get("tpot_sec") {
            spec.slo_tpot_sec =
                v.as_f64().ok_or_else(|| malformed("\"slo.tpot_sec\" must be a number"))?;
        }
    }
    if let Some(c) = j.get("constraints") {
        if let Some(v) = c.get("min_slo_attainment") {
            spec.min_slo_attainment = Some(v.as_f64().ok_or_else(|| {
                malformed("\"constraints.min_slo_attainment\" must be a number")
            })?);
        }
        if let Some(v) = c.get("max_gpus") {
            spec.max_gpus = Some(
                v.as_f64()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
                    .map(|n| n as u32)
                    .ok_or_else(|| {
                        malformed("\"constraints.max_gpus\" must be an unsigned integer")
                    })?,
            );
        }
        if let Some(v) = c.get("max_usd_per_hour") {
            spec.max_usd_per_hour = Some(v.as_f64().ok_or_else(|| {
                malformed("\"constraints.max_usd_per_hour\" must be a number")
            })?);
        }
    }
    let w = j.get("workloads").ok_or_else(|| malformed("sweep needs \"workloads\": [..]"))?;
    let arr = w.as_arr().ok_or_else(|| malformed("\"workloads\" must be an array"))?;
    let mut workloads = Vec::with_capacity(arr.len());
    for (i, item) in arr.iter().enumerate() {
        let name = match item.get("name") {
            None => format!("w{i}"),
            Some(v) => v
                .as_str()
                .ok_or_else(|| malformed("workload \"name\" must be a string"))?
                .to_string(),
        };
        let template = if let Some(c) = item.get("cluster") {
            scenario_wire::parse_cluster_template(c).map(SimulateRequest::Cluster)
        } else if let Some(s) = item.get("scenario") {
            scenario_wire::parse_spec_template(s).map(SimulateRequest::Scenario)
        } else {
            Err(ScenarioError::MalformedSpec(
                "workloads need a \"scenario\" or \"cluster\" template".into(),
            ))
        }
        .map_err(template_err)?;
        workloads.push(SweepWorkload { name, template });
    }
    spec.workloads = workloads;
    Ok(spec)
}

fn check_version(j: &Json) -> Result<(), SweepError> {
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        if v as u32 != PROTOCOL_VERSION {
            return Err(malformed(format!(
                "protocol version {v} (this build speaks v{PROTOCOL_VERSION})"
            )));
        }
    }
    Ok(())
}

/// Parse the optional `"shard":{"index":I,"count":N}` envelope field and
/// validate it against the shard bounds.
fn shard_of(j: &Json) -> Result<Shard, SweepError> {
    let Some(s) = j.get("shard") else { return Ok(Shard::default()) };
    let field = |name: &str| -> Result<u32, SweepError> {
        s.get(name)
            .and_then(Json::as_f64)
            .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
            .map(|n| n as u32)
            .ok_or_else(|| malformed(format!("\"shard.{name}\" must be an unsigned integer")))
    };
    let shard = Shard::new(field("index")?, field("count")?);
    shard.check()?;
    Ok(shard)
}

fn sweep_fields(j: &Json) -> Result<SweepRequest, SweepError> {
    check_version(j)?;
    let sw = j.get("sweep").ok_or_else(|| malformed("sweep request needs a \"sweep\" object"))?;
    let spec = parse_sweep_object(sw)?;
    let shard = shard_of(j)?;
    let journal = match j.get("journal") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| malformed("\"journal\" must be a path string"))?,
        ),
    };
    Ok(SweepRequest { spec, shard, journal })
}

/// Envelope parse over an already-decoded line (single-parse dispatch —
/// what the stdio loop uses).
pub(crate) fn parse_sweep_json(j: &Json) -> (Option<String>, Result<SweepRequest, SweepError>) {
    (id_of(j), sweep_fields(j))
}

/// Whether a decoded wire object addresses the sweep verb. Checked before
/// the simulate shapes in the stdio dispatcher.
pub(crate) fn is_sweep_json(j: &Json) -> bool {
    j.get("op").and_then(|v| v.as_str()) == Some("sweep") || j.get("sweep").is_some()
}

/// Parse a sweep line in either shape: the wire envelope or a bare sweep
/// object (`{"gpus":..,"workloads":[..]}`) — what `synperf sweep --spec`
/// accepts. Bare objects carry the default shard and no journal.
pub fn parse_sweep_line(line: &str) -> (Option<String>, Result<SweepRequest, SweepError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(malformed(format!("malformed JSON: {e}")))),
    };
    let res = if j.get("sweep").is_some() || j.get("op").is_some() {
        sweep_fields(&j)
    } else {
        parse_sweep_object(&j).map(SweepRequest::new)
    };
    (id_of(&j), res)
}

/// Whether a wire line addresses the sweep verb (malformed JSON is not
/// claimed — the predict codec owns that bucket).
pub fn is_sweep_request(line: &str) -> bool {
    match parse(line) {
        Ok(j) => is_sweep_json(&j),
        Err(_) => false,
    }
}

// ---- rows & frontier ------------------------------------------------------

fn row_error_to_json(e: &RowError) -> String {
    match e {
        // scenario errors keep the shared error-object bytes exactly
        RowError::Scenario(se) => scenario_wire::error_to_json(se),
        RowError::Internal(why) | RowError::Timeout(why) | RowError::ConstraintViolated(why) => {
            format!(
                "{{\"code\":\"{}\",\"message\":\"{}\",\"reason\":\"{}\"}}",
                e.code(),
                esc(&e.to_string()),
                esc(why)
            )
        }
    }
}

fn row_to_json(r: &SweepRow) -> String {
    let mut out = format!(
        r#"{{"index":{},"workload":"{}","gpu":"{}","tp":{},"pp":{},"replicas":{},"policy":"{}","gpu_count":{}"#,
        r.index,
        esc(&r.workload),
        esc(&r.gpu),
        r.tp,
        r.pp,
        r.replicas,
        r.policy.name(),
        r.gpu_count
    );
    match &r.outcome {
        Ok(m) => out.push_str(&format!(
            r#","ok":true,"cluster":{},"tokens_per_sec":{:e},"slo_attainment":{:e},"ttft_sec":{:e},"tpot_sec":{:e},"usd_per_hour":{:e},"usd_per_mtok":{:e}"#,
            m.cluster,
            m.tokens_per_sec,
            m.slo_attainment,
            m.ttft_sec,
            m.tpot_sec,
            m.usd_per_hour,
            m.usd_per_mtok
        )),
        Err(e) => out.push_str(&format!(",\"ok\":false,\"error\":{}", row_error_to_json(e))),
    }
    out.push('}');
    out
}

/// One streamed JSONL result row (no trailing newline).
pub fn encode_row(r: &SweepRow) -> String {
    format!("{{\"v\":{PROTOCOL_VERSION},\"row\":{}}}", row_to_json(r))
}

fn row_u32(j: &Json, key: &str) -> Result<u32, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
        .map(|n| n as u32)
        .ok_or_else(|| format!("row field {key:?} must be an unsigned integer"))
}

fn row_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key).and_then(Json::as_f64).ok_or_else(|| format!("row field {key:?} missing"))
}

fn row_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("row field {key:?} missing"))
}

fn row_error_from_json(err: &Json) -> Result<RowError, String> {
    let code = err
        .get("code")
        .and_then(Json::as_str)
        .ok_or_else(|| "row error needs \"code\"".to_string())?;
    let reason = || {
        err.get("reason")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| format!("row error {code:?} needs \"reason\""))
    };
    match code {
        "internal" => Ok(RowError::Internal(reason()?)),
        "timeout" => Ok(RowError::Timeout(reason()?)),
        "constraint_violated" => Ok(RowError::ConstraintViolated(reason()?)),
        _ => scenario_wire::error_from_json(err)
            .map(RowError::Scenario)
            .map_err(|e| e.to_string()),
    }
}

/// Decode one streamed row line back into a [`SweepRow`] — the journal's
/// replay half. Exact inverse of [`encode_row`]: re-encoding the parsed
/// row reproduces the input bytes, which is what makes resumed runs
/// byte-identical to uninterrupted ones.
pub fn parse_row(line: &str) -> Result<SweepRow, String> {
    let j = parse(line).map_err(|e| format!("malformed row JSON: {e}"))?;
    let r = j.get("row").ok_or_else(|| "not a row line (no \"row\" object)".to_string())?;
    let policy_name = row_str(r, "policy")?;
    let policy = RoutePolicy::from_name(&policy_name)
        .ok_or_else(|| format!("unknown policy {policy_name:?}"))?;
    let outcome = match r.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(SweepMetrics {
            tokens_per_sec: row_f64(r, "tokens_per_sec")?,
            slo_attainment: row_f64(r, "slo_attainment")?,
            ttft_sec: row_f64(r, "ttft_sec")?,
            tpot_sec: row_f64(r, "tpot_sec")?,
            cluster: r
                .get("cluster")
                .and_then(Json::as_bool)
                .ok_or_else(|| "row field \"cluster\" missing".to_string())?,
            usd_per_hour: row_f64(r, "usd_per_hour")?,
            usd_per_mtok: row_f64(r, "usd_per_mtok")?,
        }),
        Some(false) => Err(row_error_from_json(
            r.get("error").ok_or_else(|| "error row needs \"error\"".to_string())?,
        )?),
        None => return Err("row needs a boolean \"ok\"".to_string()),
    };
    Ok(SweepRow {
        index: row_u32(r, "index")? as usize,
        workload: row_str(r, "workload")?,
        gpu: row_str(r, "gpu")?,
        tp: row_u32(r, "tp")?,
        pp: row_u32(r, "pp")?,
        replicas: row_u32(r, "replicas")?,
        policy,
        gpu_count: row_u32(r, "gpu_count")?,
        outcome,
    })
}

fn frontier_entry_to_json(rank: usize, r: &SweepRow) -> String {
    // frontier members are ok rows by construction
    let m = r.outcome.as_ref().expect("frontier rows carry metrics");
    format!(
        r#"{{"rank":{},"index":{},"workload":"{}","gpu":"{}","tp":{},"pp":{},"replicas":{},"policy":"{}","gpu_count":{},"tokens_per_sec":{:e},"slo_attainment":{:e},"usd_per_mtok":{:e}}}"#,
        rank,
        r.index,
        esc(&r.workload),
        esc(&r.gpu),
        r.tp,
        r.pp,
        r.replicas,
        r.policy.name(),
        r.gpu_count,
        m.tokens_per_sec,
        m.slo_attainment,
        m.usd_per_mtok
    )
}

/// `rows` must be in index order (what [`super::run_sweep`] yields), so
/// the frontier's row indices can be used as positions directly.
fn frontier_body(rows: &[SweepRow], p: &Pareto) -> String {
    let entries: Vec<String> = p
        .frontier
        .iter()
        .enumerate()
        .map(|(i, &ri)| frontier_entry_to_json(i + 1, &rows[ri]))
        .collect();
    let dom: Vec<String> = p
        .dominated
        .iter()
        .map(|(ri, by)| {
            let by: Vec<String> = by.iter().map(usize::to_string).collect();
            format!(r#"{{"index":{},"by":[{}]}}"#, ri, by.join(","))
        })
        .collect();
    format!(r#""frontier":[{}],"dominated":[{}]"#, entries.join(","), dom.join(","))
}

/// The frontier block the CLI emits after the last row (no trailing
/// newline).
pub fn encode_frontier(rows: &[SweepRow], p: &Pareto) -> String {
    format!("{{\"v\":{PROTOCOL_VERSION},{}}}", frontier_body(rows, p))
}

fn sweep_error_to_json(e: &SweepError) -> String {
    let mut out =
        format!("{{\"code\":\"{}\",\"message\":\"{}\"", e.code(), esc(&e.to_string()));
    match e {
        SweepError::UnknownGpu(name) => out.push_str(&format!(",\"gpu\":\"{}\"", esc(name))),
        SweepError::InvalidAxis(why)
        | SweepError::GridTooLarge(why)
        | SweepError::MalformedSpec(why)
        | SweepError::InvalidWorkload(why)
        | SweepError::JournalCorrupt(why)
        | SweepError::FingerprintMismatch(why)
        | SweepError::MergeConflict(why)
        | SweepError::MergeIncomplete(why) => {
            out.push_str(&format!(",\"reason\":\"{}\"", esc(why)));
        }
    }
    out.push('}');
    out
}

/// One-line sweep response for the stdio wire: every row plus the ranked
/// frontier in a single envelope, or the spec-level error. The grid cap
/// ([`super::MAX_SWEEP_POINTS`]) bounds the line length.
pub fn encode_sweep_response(id: Option<&str>, res: &Result<SweepOutcome, SweepError>) -> String {
    let mut out = format!("{{\"v\":{PROTOCOL_VERSION}");
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(o) => {
            let rows: Vec<String> = o.rows.iter().map(row_to_json).collect();
            out.push_str(&format!(
                ",\"ok\":true,\"sweep\":{{\"rows\":[{}],{}}}",
                rows.join(","),
                frontier_body(&o.rows, &o.pareto)
            ));
        }
        Err(e) => out.push_str(&format!(",\"ok\":false,\"error\":{}", sweep_error_to_json(e))),
    }
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::e2e::workload::WorkloadKind;
    use crate::scenario::{ArrivalSpec, ClusterSpec, ScenarioSpec};
    use crate::sweep::run_sweep;
    use crate::scenario::Simulator;

    fn round_trip_spec() -> SweepSpec {
        SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
            .tp(vec![1, 2])
            .replicas(vec![1, 4])
            .policies(vec![RoutePolicy::LeastLoaded])
            .slo(1.5, 0.25)
            .scenario("chat", ScenarioSpec::new("Qwen2.5-14B", "").seed(7))
            .workload(
                "serve",
                SimulateRequest::Cluster(ClusterSpec::new("Llama3.1-8B", "").arrivals(
                    ArrivalSpec::Uniform { gap_sec: 0.5, n: 4, kind: WorkloadKind::Arxiv },
                )),
            )
    }

    #[test]
    fn sweep_requests_round_trip() {
        let spec = round_trip_spec();
        let line = encode_sweep_request(Some("sw"), &spec);
        assert!(is_sweep_request(&line), "{line}");
        // no constraints set → no "constraints" object on the wire
        assert!(!line.contains("constraints"), "{line}");
        let (id, parsed) = parse_sweep_line(&line);
        assert_eq!(id.as_deref(), Some("sw"));
        let req = parsed.unwrap();
        assert_eq!(req.spec, spec, "round trip of {line}");
        assert_eq!(req.shard, Shard::default());
        assert_eq!(req.journal, None);
    }

    #[test]
    fn constraints_and_shard_round_trip_when_set() {
        let spec = round_trip_spec().min_slo_attainment(0.75).max_gpus(8).max_usd_per_hour(42.5);
        let req = SweepRequest {
            spec,
            shard: Shard::new(1, 3),
            journal: Some("/tmp/sweep.jsonl".into()),
        };
        let line = encode_sweep_request_with(Some("sw"), &req);
        assert!(
            line.contains(
                r#""constraints":{"min_slo_attainment":7.5e-1,"max_gpus":8,"max_usd_per_hour":4.25e1}"#
            ),
            "{line}"
        );
        assert!(line.contains(r#""shard":{"index":1,"count":3}"#), "{line}");
        let (_, parsed) = parse_sweep_line(&line);
        assert_eq!(parsed.unwrap(), req, "round trip of {line}");
    }

    #[test]
    fn bad_shard_envelopes_speak_the_taxonomy() {
        let base = r#"{"op":"sweep","sweep":{"workloads":[{"scenario":{"model":"llama3.1-8b"}}]}"#;
        let cases = [
            (r#","shard":{"index":0}}"#, "malformed_spec"),
            (r#","shard":{"index":1.5,"count":2}}"#, "malformed_spec"),
            (r#","shard":{"index":3,"count":3}}"#, "invalid_axis"),
            (r#","shard":{"index":0,"count":0}}"#, "invalid_axis"),
            (r#","journal":7}"#, "malformed_spec"),
        ];
        for (suffix, code) in cases {
            let line = format!("{base}{suffix}");
            let (_, res) = parse_sweep_line(&line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn bare_sweep_objects_parse_with_defaults() {
        let (_, res) = parse_sweep_line(
            r#"{"workloads":[{"scenario":{"model":"Qwen2.5-14B"}},{"cluster":{"model":"Llama3.1-8B"}}]}"#,
        );
        let spec = res.unwrap();
        assert_eq!(spec.gpus, GpuFilter::All);
        assert_eq!(spec.tp, vec![1]);
        assert_eq!(spec.policies, vec![RoutePolicy::RoundRobin]);
        assert_eq!(spec.slo_ttft_sec, 2.0);
        assert_eq!(spec.workloads.len(), 2);
        // auto-named by position
        assert_eq!(spec.workloads[0].name, "w0");
        assert_eq!(spec.workloads[1].name, "w1");
        assert!(matches!(spec.workloads[1].template, SimulateRequest::Cluster(_)));
    }

    #[test]
    fn malformed_sweeps_map_into_the_taxonomy() {
        let cases = [
            ("not json", "malformed_spec"),
            (r#"{"op":"sweep"}"#, "malformed_spec"),
            (r#"{"v":9,"op":"sweep","sweep":{"workloads":[]}}"#, "malformed_spec"),
            (r#"{"sweep":{}}"#, "malformed_spec"),
            (r#"{"sweep":{"gpus":"fastest","workloads":[]}}"#, "malformed_spec"),
            (r#"{"sweep":{"tp":[1.5],"workloads":[]}}"#, "malformed_spec"),
            (
                r#"{"sweep":{"policies":["random"],"workloads":[{"scenario":{"model":"m"}}]}}"#,
                "invalid_axis",
            ),
            (r#"{"sweep":{"workloads":[{"scenario":{"gpu":"A100"}}]}}"#, "malformed_spec"),
            (r#"{"sweep":{"workloads":[{"name":"x"}]}}"#, "malformed_spec"),
            (
                r#"{"sweep":{"workloads":[{"scenario":{"model":"m","workload":{"kind":"mmlu"}}}]}}"#,
                "invalid_workload",
            ),
        ];
        for (line, code) in cases {
            let (_, res) = parse_sweep_line(line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn verb_dispatch_does_not_overlap_simulate() {
        assert!(is_sweep_request(r#"{"op":"sweep","sweep":{"workloads":[]}}"#));
        assert!(is_sweep_request(r#"{"sweep":{"workloads":[]}}"#));
        assert!(!is_sweep_request(r#"{"scenario":{"model":"m","gpu":"g"}}"#));
        assert!(!is_sweep_request(r#"{"cluster":{"model":"m","gpu":"g"}}"#));
        assert!(!is_sweep_request(r#"{"gpu":"A100","kernel":{"type":"rmsnorm","seq":1,"dim":8}}"#));
        assert!(!crate::scenario::wire::is_simulate_request(
            r#"{"op":"sweep","sweep":{"workloads":[]}}"#
        ));
    }

    #[test]
    fn responses_embed_rows_and_frontier_in_one_line() {
        use crate::e2e::workload::Request;
        use crate::scenario::WorkloadSpec;
        let spec = SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into(), "H20".into()]))
            .scenario(
                "tiny",
                ScenarioSpec::new("llama3.1-8b", "").workload(WorkloadSpec::Explicit(vec![
                    Request { input_len: 48, output_len: 2 },
                ])),
            );
        let out = run_sweep(&spec, Simulator::degraded, 2, |_| {}).unwrap();
        let line = encode_sweep_response(Some("sw1"), &Ok(out.clone()));
        assert!(line.starts_with(r#"{"v":1,"id":"sw1","ok":true,"sweep":{"rows":["#), "{line}");
        assert!(line.contains(r#""frontier":["#), "{line}");
        assert!(!line.contains('\n'));
        // each row's embedded object matches its streamed encoding
        for row in &out.rows {
            let streamed = encode_row(row);
            let inner = streamed
                .strip_prefix(r#"{"v":1,"row":"#)
                .and_then(|s| s.strip_suffix('}'))
                .unwrap();
            assert!(line.contains(inner), "row {} drifted between shapes", row.index);
        }
        // spec-level errors ride the same envelope
        let err = encode_sweep_response(None, &Err(SweepError::GridTooLarge("big".into())));
        assert_eq!(
            err,
            r#"{"v":1,"ok":false,"error":{"code":"grid_too_large","message":"sweep grid too large: big","reason":"big"}}"#
        );
    }

    fn tricky_row(outcome: Result<SweepMetrics, RowError>) -> SweepRow {
        SweepRow {
            index: 4097,
            workload: "w \"quoted\"".into(),
            gpu: "RTX 6000 Ada".into(),
            tp: 2,
            pp: 3,
            replicas: 4,
            policy: RoutePolicy::SessionAffinity,
            gpu_count: 24,
            outcome,
        }
    }

    #[test]
    fn rows_round_trip_byte_identically() {
        // floats chosen to stress the shortest-round-trip encoder
        let ok = tricky_row(Ok(SweepMetrics {
            tokens_per_sec: 1234.5678901234567,
            slo_attainment: 0.1 + 0.2, // 0.30000000000000004
            ttft_sec: 1.0e-308,
            tpot_sec: f64::MIN_POSITIVE,
            cluster: true,
            usd_per_hour: 59.99999999999999,
            usd_per_mtok: 3.0303030303030303e-5,
        }));
        let errs = [
            RowError::Scenario(ScenarioError::InvalidParallelism("tp=2 vs 7 heads".into())),
            RowError::Scenario(ScenarioError::UnknownModel("gpt-9".into())),
            RowError::Internal("sweep point evaluation panicked: boom".into()),
            RowError::Timeout("point evaluation exceeded 50ms".into()),
            RowError::ConstraintViolated("gpu_count 24 > max_gpus 8".into()),
        ];
        let mut rows = vec![ok];
        rows.extend(errs.into_iter().map(|e| tricky_row(Err(e))));
        for row in rows {
            let line = encode_row(&row);
            let parsed = parse_row(&line).unwrap();
            assert_eq!(parsed, row, "value round trip of {line}");
            assert_eq!(encode_row(&parsed), line, "byte round trip of {line}");
        }
    }

    #[test]
    fn corrupt_rows_are_rejected_with_reasons() {
        assert!(parse_row("not json").is_err());
        assert!(parse_row(r#"{"v":1}"#).is_err());
        assert!(parse_row(r#"{"v":1,"row":{"index":0}}"#).is_err());
        // truncated tail of a real line
        let line = encode_row(&tricky_row(Err(RowError::Internal("x".into()))));
        assert!(parse_row(&line[..line.len() - 2]).is_err());
    }
}
