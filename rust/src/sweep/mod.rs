//! **Sweep subsystem** — fleet-scale hardware search over the full
//! prediction stack (the paper's headline use case: next-generation
//! hardware selection across the 11 GPUs of Table VI).
//!
//! A declarative [`SweepSpec`] names the axes of a config grid — GPUs
//! (the whole registry by default, or the seen/unseen split, or explicit
//! names), tensor/pipeline parallel degrees, replica counts, routing
//! policies — and one or more workloads: a Scenario-v1 [`ScenarioSpec`]
//! or a Scenario-v2 [`crate::scenario::ClusterSpec`] used as a *template*
//! whose hardware axes the grid overwrites per point. [`grid::expand`]
//! validates the axes against the closed [`SweepError`] taxonomy
//! (mirroring [`ScenarioError`]) and materializes the cross-product;
//! [`runner::run_sweep`] fans the points over work-stealing workers —
//! each owning one [`crate::scenario::Simulator`] so per-GPU comm models
//! train once per worker and the sharded engine cache is hammered as
//! designed — and streams one [`SweepRow`] per config in deterministic
//! index order regardless of scheduling. Infeasible configs (tp that does
//! not divide the heads, overlong requests, …) become typed per-row
//! error rows instead of aborting the sweep.
//!
//! On top of the rows, [`pareto::pareto`] computes the Pareto frontier
//! over (tokens/sec ↑, SLO attainment ↑, GPU count = replicas × tp × pp
//! ↓) with ranked dominated-by annotations. The whole surface rides the
//! `synperf sweep` CLI verb and a `sweep` request shape on the stdio
//! wire ([`wire`]).

pub mod grid;
pub mod journal;
pub mod pareto;
pub mod runner;
pub mod wire;

pub use grid::{expand, expand_for, Shard, SweepPoint, MAX_SHARD_COUNT, MAX_SWEEP_POINTS};
pub use journal::{fingerprint, merge, JournalHeader, JournalSession};
pub use pareto::{pareto, Pareto, DOMINATED_BY_CAP};
pub use runner::{
    point_request, run_request, run_sweep, run_sweep_deadline, run_sweep_with, RunOptions,
};
pub use wire::SweepRequest;

use crate::scenario::wire::SimulateRequest;
use crate::scenario::{
    ClusterReport, Method, Phase, RoutePolicy, ScenarioError, ScenarioReport, ScenarioSpec,
};
use std::fmt;

/// Which registry slice a sweep covers when GPUs are not named explicitly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GpuFilter {
    /// Every GPU of Table VI (the default).
    All,
    /// The training ("seen") split only.
    Seen,
    /// The held-out ("unseen") split only — the what-if regime.
    Unseen,
    /// Explicit names, resolved through the fuzzy [`crate::hw::gpu_by_name`].
    Named(Vec<String>),
}

/// One workload of the sweep: a display name plus a v1 scenario or v2
/// cluster template. The template's `gpu`/`tp`/`pp` (and, for clusters,
/// `replicas`/`policy`/SLOs) are overwritten by the grid per point, so a
/// template may omit its `gpu` entirely on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepWorkload {
    pub name: String,
    pub template: SimulateRequest,
}

/// The declarative sweep: axes × workloads. Empty axes are invalid; the
/// builder defaults mirror a single-node, single-replica serving setup.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub gpus: GpuFilter,
    pub tp: Vec<u32>,
    pub pp: Vec<u32>,
    pub replicas: Vec<u32>,
    /// Routing policies — a cluster knob; v1 scenario workloads take only
    /// the first entry so the grid carries no duplicate rows.
    pub policies: Vec<RoutePolicy>,
    /// Sweep-level SLO thresholds, pinned over every workload template so
    /// attainment is comparable across the whole grid.
    pub slo_ttft_sec: f64,
    pub slo_tpot_sec: f64,
    /// Hard procurement constraint: rows below this SLO attainment become
    /// typed `constraint_violated` rows (never silent drops).
    pub min_slo_attainment: Option<f64>,
    /// Hard constraint on the row's GPU count (replicas × tp × pp);
    /// violating points are not even simulated.
    pub max_gpus: Option<u32>,
    /// Hard budget constraint on the row's fleet rental rate (GPU count ×
    /// per-GPU `usd_per_hour`); violating points are not even simulated.
    pub max_usd_per_hour: Option<f64>,
    pub workloads: Vec<SweepWorkload>,
}

impl SweepSpec {
    pub fn new() -> Self {
        SweepSpec {
            gpus: GpuFilter::All,
            tp: vec![1],
            pp: vec![1],
            replicas: vec![1],
            policies: vec![RoutePolicy::RoundRobin],
            slo_ttft_sec: 2.0,
            slo_tpot_sec: 0.2,
            min_slo_attainment: None,
            max_gpus: None,
            max_usd_per_hour: None,
            workloads: Vec::new(),
        }
    }

    pub fn gpus(mut self, gpus: GpuFilter) -> Self {
        self.gpus = gpus;
        self
    }

    pub fn tp(mut self, tp: Vec<u32>) -> Self {
        self.tp = tp;
        self
    }

    pub fn pp(mut self, pp: Vec<u32>) -> Self {
        self.pp = pp;
        self
    }

    pub fn replicas(mut self, replicas: Vec<u32>) -> Self {
        self.replicas = replicas;
        self
    }

    pub fn policies(mut self, policies: Vec<RoutePolicy>) -> Self {
        self.policies = policies;
        self
    }

    pub fn slo(mut self, ttft_sec: f64, tpot_sec: f64) -> Self {
        self.slo_ttft_sec = ttft_sec;
        self.slo_tpot_sec = tpot_sec;
        self
    }

    /// Require at least this SLO attainment (0..=1) per row.
    pub fn min_slo_attainment(mut self, min: f64) -> Self {
        self.min_slo_attainment = Some(min);
        self
    }

    /// Cap the per-row GPU count (replicas × tp × pp).
    pub fn max_gpus(mut self, max: u32) -> Self {
        self.max_gpus = Some(max);
        self
    }

    /// Cap the per-row fleet rental rate in USD per hour.
    pub fn max_usd_per_hour(mut self, max: f64) -> Self {
        self.max_usd_per_hour = Some(max);
        self
    }

    /// Append a workload (any [`SimulateRequest`] shape) under a name.
    pub fn workload(mut self, name: &str, template: SimulateRequest) -> Self {
        self.workloads.push(SweepWorkload { name: name.to_string(), template });
        self
    }

    /// Convenience: append a v1 scenario workload.
    pub fn scenario(self, name: &str, template: ScenarioSpec) -> Self {
        self.workload(name, SimulateRequest::Scenario(template))
    }
}

impl Default for SweepSpec {
    fn default() -> Self {
        Self::new()
    }
}

/// The closed error taxonomy of the sweep surface, mirroring
/// [`ScenarioError`]. These are *spec-level* failures that abort before
/// any row is evaluated; per-point runtime failures stay `ScenarioError`
/// values inside typed error rows and never abort the sweep.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SweepError {
    /// A named GPU is not in the Table-VI registry.
    UnknownGpu(String),
    /// An axis is empty, zero-valued or out of range.
    InvalidAxis(String),
    /// The cross-product exceeds [`MAX_SWEEP_POINTS`].
    GridTooLarge(String),
    /// The spec itself is malformed (bad JSON, bad field types).
    MalformedSpec(String),
    /// A workload template is invalid before any point is evaluated.
    InvalidWorkload(String),
    /// A journal file is unreadable, has a bad header, or contains a
    /// non-final malformed line (only the *final* line may be truncated
    /// by a crash — that one is silently discarded on resume).
    JournalCorrupt(String),
    /// A journal was written by a different spec / grid shape / shard
    /// count — resuming or merging it would corrupt the row stream.
    FingerprintMismatch(String),
    /// Two merge inputs claim the same shard.
    MergeConflict(String),
    /// The merge inputs do not cover the full grid (missing shards or
    /// rows a shard never finished).
    MergeIncomplete(String),
}

impl SweepError {
    /// Stable machine-readable code (the `error.code` of the wire surface).
    pub fn code(&self) -> &'static str {
        match self {
            SweepError::UnknownGpu(_) => "unknown_gpu",
            SweepError::InvalidAxis(_) => "invalid_axis",
            SweepError::GridTooLarge(_) => "grid_too_large",
            SweepError::MalformedSpec(_) => "malformed_spec",
            SweepError::InvalidWorkload(_) => "invalid_workload",
            SweepError::JournalCorrupt(_) => "journal_corrupt",
            SweepError::FingerprintMismatch(_) => "fingerprint_mismatch",
            SweepError::MergeConflict(_) => "merge_conflict",
            SweepError::MergeIncomplete(_) => "merge_incomplete",
        }
    }
}

impl fmt::Display for SweepError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SweepError::UnknownGpu(name) => {
                write!(
                    f,
                    "unknown GPU {name:?} (see Table VI; closest: {})",
                    crate::hw::nearest_names(name, 3).join(", ")
                )
            }
            SweepError::InvalidAxis(why) => write!(f, "invalid sweep axis: {why}"),
            SweepError::GridTooLarge(why) => write!(f, "sweep grid too large: {why}"),
            SweepError::MalformedSpec(why) => write!(f, "malformed sweep spec: {why}"),
            SweepError::InvalidWorkload(why) => write!(f, "invalid sweep workload: {why}"),
            SweepError::JournalCorrupt(why) => write!(f, "sweep journal corrupt: {why}"),
            SweepError::FingerprintMismatch(why) => {
                write!(f, "sweep journal fingerprint mismatch: {why}")
            }
            SweepError::MergeConflict(why) => write!(f, "sweep merge conflict: {why}"),
            SweepError::MergeIncomplete(why) => write!(f, "sweep merge incomplete: {why}"),
        }
    }
}

impl std::error::Error for SweepError {}

/// Per-row failure taxonomy: the scenario errors a point can hit plus
/// the containment outcomes the runner synthesizes. Rows carry these —
/// they never abort the sweep.
#[derive(Debug, Clone, PartialEq)]
pub enum RowError {
    /// The workload evaluation failed with a typed scenario error.
    Scenario(ScenarioError),
    /// The point's evaluation panicked; `catch_unwind` contained it.
    Internal(String),
    /// The point exceeded `--point-timeout-ms` and was abandoned.
    Timeout(String),
    /// A `SweepSpec` hard constraint filtered this point.
    ConstraintViolated(String),
}

impl RowError {
    /// Stable machine-readable code (the row `error.code` on the wire).
    pub fn code(&self) -> &'static str {
        match self {
            RowError::Scenario(e) => e.code(),
            RowError::Internal(_) => "internal",
            RowError::Timeout(_) => "timeout",
            RowError::ConstraintViolated(_) => "constraint_violated",
        }
    }
}

impl fmt::Display for RowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RowError::Scenario(e) => e.fmt(f),
            RowError::Internal(why) => write!(f, "internal sweep error: {why}"),
            RowError::Timeout(why) => write!(f, "sweep point timed out: {why}"),
            RowError::ConstraintViolated(why) => write!(f, "constraint violated: {why}"),
        }
    }
}

impl std::error::Error for RowError {}

impl From<ScenarioError> for RowError {
    fn from(e: ScenarioError) -> Self {
        RowError::Scenario(e)
    }
}

/// The comparable metrics every grid point collapses to — the three
/// Pareto objectives plus the latency headline behind the attainment.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepMetrics {
    pub tokens_per_sec: f64,
    pub slo_attainment: f64,
    /// v1: SynPerf-method TTFT; v2: cluster p95 TTFT.
    pub ttft_sec: f64,
    /// v1: SynPerf-method TPOT; v2: cluster p95 TPOT.
    pub tpot_sec: f64,
    /// Whether the row came from a v2 cluster simulation.
    pub cluster: bool,
    /// Fleet rental rate: GPU count × the registry's per-GPU rate.
    pub usd_per_hour: f64,
    /// Cost objective: `$ / 1M output tokens` at this row's throughput
    /// (0.0 when the row produced no tokens — never `inf` on the wire).
    pub usd_per_mtok: f64,
}

impl SweepMetrics {
    /// Stamp the cost columns from the registry: `usd_per_hour` from the
    /// GPU's rental rate × count, `usd_per_mtok` from that rate over the
    /// row's token throughput.
    pub fn apply_cost(&mut self, gpu: &crate::hw::GpuSpec, gpu_count: u32) {
        self.usd_per_hour = gpu.usd_per_hour * f64::from(gpu_count);
        self.usd_per_mtok = if self.tokens_per_sec > 0.0 {
            self.usd_per_hour / (self.tokens_per_sec * 3600.0 / 1.0e6)
        } else {
            0.0
        };
    }
}

/// One streamed result row: the point's coordinates plus either its
/// metrics or the typed per-point failure.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub index: usize,
    pub workload: String,
    pub gpu: String,
    pub tp: u32,
    pub pp: u32,
    pub replicas: u32,
    pub policy: RoutePolicy,
    /// replicas × tp × pp — the Pareto cost objective.
    pub gpu_count: u32,
    pub outcome: Result<SweepMetrics, RowError>,
}

/// Everything a finished sweep yields: the rows (in index order) and the
/// ranked Pareto frontier over them.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepOutcome {
    pub rows: Vec<SweepRow>,
    pub pareto: Pareto,
}

/// Collapse a v1 scenario report into sweep metrics: SynPerf-method
/// throughput scaled by independent replicas (TTFT/TPOT are per-replica
/// and stay unchanged), SLO attainment = fraction of scheduled phase
/// checks met (1.0 when no phase is scheduled).
pub fn scenario_metrics(
    slo_ttft_sec: f64,
    slo_tpot_sec: f64,
    replicas: u32,
    r: &ScenarioReport,
) -> SweepMetrics {
    let m = Method::SynPerf;
    let tokens: f64 = r.phases.iter().map(|p| p.tokens).sum();
    let time = r.totals.get(m);
    let per_replica = if time > 0.0 { tokens / time } else { 0.0 };
    let ttft_sec = r.ttft_sec(m).unwrap_or(0.0);
    let tpot_sec = r.tpot_sec(m).unwrap_or(0.0);
    let mut checks = 0u32;
    let mut met = 0u32;
    if r.phase(Phase::Prefill).is_some() {
        checks += 1;
        met += u32::from(ttft_sec <= slo_ttft_sec);
    }
    if r.phase(Phase::Decode).is_some() {
        checks += 1;
        met += u32::from(tpot_sec <= slo_tpot_sec);
    }
    SweepMetrics {
        tokens_per_sec: per_replica * f64::from(replicas),
        slo_attainment: if checks > 0 { f64::from(met) / f64::from(checks) } else { 1.0 },
        ttft_sec,
        tpot_sec,
        cluster: false,
        usd_per_hour: 0.0,
        usd_per_mtok: 0.0,
    }
}

/// Collapse a v2 cluster report into sweep metrics — the report already
/// aggregates across replicas, so no scaling is applied.
pub fn cluster_metrics(r: &ClusterReport) -> SweepMetrics {
    SweepMetrics {
        tokens_per_sec: r.tokens_per_sec,
        slo_attainment: r.slo_attainment,
        ttft_sec: r.ttft.p95_sec,
        tpot_sec: r.tpot.p95_sec,
        cluster: true,
        usd_per_hour: 0.0,
        usd_per_mtok: 0.0,
    }
}
