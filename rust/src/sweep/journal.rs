//! **Durable sweep journal** — the crash-safety half of the sweep
//! subsystem. A journal is append-only JSONL: one header line stamping
//! the spec fingerprint, grid size and shard, then one completed row
//! line per evaluated point, each fsync'd at line granularity so a
//! crash loses at most the row being written (a half-written final
//! line is discarded on replay; every earlier line is durable).
//!
//! ```json
//! {"v":1,"sweep_journal":{"fingerprint":"9a3c…","points":44,"shard_index":0,"shard_count":1}}
//! {"v":1,"row":{"index":0,…}}
//! ```
//!
//! The fingerprint is a stable FNV-1a 64-bit hash over the canonical
//! spec encoding ([`wire::sweep_to_json`]) plus the grid shape (total
//! points, shard count — the shard *index* is excluded so sibling
//! shards of one campaign share a fingerprint and `sweep-merge` can
//! verify they belong together). Resuming or merging a journal whose
//! fingerprint disagrees is a typed [`SweepError::FingerprintMismatch`],
//! never a silent row-stream corruption.

use super::{expand_for, wire, Shard, SweepError, SweepRow, SweepSpec};
use crate::api::PROTOCOL_VERSION;
use crate::util::json::{parse, Json};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{Read, Write};
use std::path::Path;

/// The first line of every journal: enough to verify that a journal,
/// a spec and a shard assignment all describe the same campaign.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalHeader {
    pub fingerprint: String,
    /// Total points of the *whole* grid (all shards).
    pub points: usize,
    pub shard_index: u32,
    pub shard_count: u32,
}

fn io_err(e: std::io::Error) -> SweepError {
    SweepError::JournalCorrupt(format!("journal i/o error: {e}"))
}

/// Stable spec identity: FNV-1a 64 over the canonical spec encoding,
/// the total point count and the shard count, rendered as 16 hex
/// digits. Deliberately *not* a cryptographic hash — it guards against
/// operator mix-ups, not adversaries.
pub fn fingerprint(spec: &SweepSpec, points: usize, shard_count: u32) -> String {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    let mut eat = |bytes: &[u8]| {
        for &b in bytes {
            h ^= u64::from(b);
            h = h.wrapping_mul(PRIME);
        }
    };
    eat(wire::sweep_to_json(spec).as_bytes());
    eat(format!(":{points}:{shard_count}").as_bytes());
    format!("{h:016x}")
}

/// The header's wire line (no trailing newline).
pub fn encode_header(h: &JournalHeader) -> String {
    format!(
        r#"{{"v":{PROTOCOL_VERSION},"sweep_journal":{{"fingerprint":"{}","points":{},"shard_index":{},"shard_count":{}}}}}"#,
        h.fingerprint, h.points, h.shard_index, h.shard_count
    )
}

fn header_u32(j: &Json, key: &str) -> Result<u32, SweepError> {
    j.get(key)
        .and_then(Json::as_f64)
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= f64::from(u32::MAX))
        .map(|n| n as u32)
        .ok_or_else(|| {
            SweepError::JournalCorrupt(format!("header field {key:?} must be an unsigned integer"))
        })
}

/// Decode a header line. Any malformation is [`SweepError::JournalCorrupt`].
pub fn parse_header_line(line: &str) -> Result<JournalHeader, SweepError> {
    let j = parse(line)
        .map_err(|e| SweepError::JournalCorrupt(format!("malformed header JSON: {e}")))?;
    let h = j.get("sweep_journal").ok_or_else(|| {
        SweepError::JournalCorrupt("first line is not a \"sweep_journal\" header".into())
    })?;
    let fingerprint = h
        .get("fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| SweepError::JournalCorrupt("header needs a \"fingerprint\"".into()))?
        .to_string();
    let header = JournalHeader {
        fingerprint,
        points: header_u32(h, "points")? as usize,
        shard_index: header_u32(h, "shard_index")?,
        shard_count: header_u32(h, "shard_count")?,
    };
    let shard = Shard::new(header.shard_index, header.shard_count);
    shard.check().map_err(|e| SweepError::JournalCorrupt(format!("header shard: {e}")))?;
    Ok(header)
}

/// Read a whole journal: header plus every durable row, keyed by global
/// index (a re-run of the same point keeps the last write). A corrupt
/// *final* line is a crash artifact and is silently discarded; a corrupt
/// line anywhere else — and any row outside the header's shard or grid —
/// is typed [`SweepError::JournalCorrupt`].
pub fn read_journal(path: &Path) -> Result<(JournalHeader, BTreeMap<usize, SweepRow>), SweepError> {
    let mut text = String::new();
    File::open(path).and_then(|mut f| f.read_to_string(&mut text)).map_err(io_err)?;
    let lines: Vec<&str> = text.lines().collect();
    let Some((first, rest)) = lines.split_first() else {
        return Err(SweepError::JournalCorrupt(format!(
            "journal {} is empty (no header)",
            path.display()
        )));
    };
    let header = parse_header_line(first)?;
    let shard = Shard::new(header.shard_index, header.shard_count);
    let mut done = BTreeMap::new();
    for (i, line) in rest.iter().enumerate() {
        match wire::parse_row(line) {
            Ok(row) => {
                if row.index >= header.points || !shard.owns(row.index) {
                    return Err(SweepError::JournalCorrupt(format!(
                        "row index {} does not belong to shard {}/{} of a {}-point grid",
                        row.index, header.shard_index, header.shard_count, header.points
                    )));
                }
                done.insert(row.index, row);
            }
            Err(why) => {
                // only a final line the crash cut short — no trailing
                // newline — is a discardable artifact; a fully written
                // garbage line anywhere is corruption
                if i + 1 == rest.len() && !text.ends_with('\n') {
                    break;
                }
                return Err(SweepError::JournalCorrupt(format!("line {}: {why}", i + 2)));
            }
        }
    }
    Ok((header, done))
}

/// Append half of an open journal: line-granular durable writes.
#[derive(Debug)]
pub struct JournalWriter {
    file: File,
}

impl JournalWriter {
    fn append(&mut self, line: &str) -> Result<(), SweepError> {
        self.file.write_all(line.as_bytes()).map_err(io_err)?;
        self.file.write_all(b"\n").map_err(io_err)?;
        self.file.sync_data().map_err(io_err)
    }
}

/// An open journal bound to one sweep run: the rows already durable
/// (replayed on resume) and the writer new rows go through.
#[derive(Debug)]
pub struct JournalSession {
    writer: JournalWriter,
    /// Rows already completed by a previous run of this shard.
    pub done: BTreeMap<usize, SweepRow>,
}

impl JournalSession {
    /// Open a journal for a run of `spec` on `shard`. With `resume`, an
    /// existing file is replayed (fingerprint and shard must match —
    /// [`SweepError::FingerprintMismatch`] otherwise) and appended to; a
    /// missing file starts fresh either way. Without `resume`, the file
    /// must not already exist — the caller decides clobber policy.
    pub fn open(
        path: &Path,
        spec: &SweepSpec,
        shard: Shard,
        resume: bool,
    ) -> Result<JournalSession, SweepError> {
        shard.check()?;
        let points = expand_for(spec, shard.count)?.len();
        let fp = fingerprint(spec, points, shard.count);
        if resume && path.exists() {
            let (header, done) = read_journal(path)?;
            if header.fingerprint != fp
                || header.points != points
                || header.shard_index != shard.index
                || header.shard_count != shard.count
            {
                return Err(SweepError::FingerprintMismatch(format!(
                    "journal {} was written for fingerprint {} shard {}/{} ({} points); \
                     this run is fingerprint {fp} shard {}/{} ({points} points)",
                    path.display(),
                    header.fingerprint,
                    header.shard_index,
                    header.shard_count,
                    header.points,
                    shard.index,
                    shard.count,
                )));
            }
            let file = OpenOptions::new().append(true).open(path).map_err(io_err)?;
            // a crash can leave a half-written final line (discarded by
            // the replay above, but still on disk); chop it off so the
            // next appended row starts on a fresh line instead of
            // concatenating onto the partial tail
            let bytes = std::fs::read(path).map_err(io_err)?;
            if !bytes.ends_with(b"\n") {
                let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |p| p + 1);
                file.set_len(keep as u64).map_err(io_err)?;
            }
            return Ok(JournalSession { writer: JournalWriter { file }, done });
        }
        let file = OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)
            .map_err(io_err)?;
        let mut writer = JournalWriter { file };
        let header = JournalHeader {
            fingerprint: fp,
            points,
            shard_index: shard.index,
            shard_count: shard.count,
        };
        writer.append(&encode_header(&header))?;
        Ok(JournalSession { writer, done: BTreeMap::new() })
    }

    /// Durably record one completed row line (the exact bytes
    /// [`wire::encode_row`] streamed).
    pub fn record(&mut self, line: &str) -> Result<(), SweepError> {
        self.writer.append(line)
    }
}

/// Deterministically merge the journals of one sharded campaign into the
/// full row stream, sorted by global index — exactly what a one-process
/// run would have emitted. Typed failures, never silent: disagreeing
/// headers are [`SweepError::FingerprintMismatch`], a shard claimed
/// twice is [`SweepError::MergeConflict`], and missing shards or rows a
/// shard never finished are [`SweepError::MergeIncomplete`].
pub fn merge(paths: &[std::path::PathBuf]) -> Result<Vec<SweepRow>, SweepError> {
    let mut first: Option<JournalHeader> = None;
    let mut seen: BTreeMap<u32, String> = BTreeMap::new();
    let mut rows: BTreeMap<usize, SweepRow> = BTreeMap::new();
    for path in paths {
        let (h, done) = read_journal(path)?;
        match &first {
            None => first = Some(h.clone()),
            Some(f) => {
                if h.fingerprint != f.fingerprint
                    || h.points != f.points
                    || h.shard_count != f.shard_count
                {
                    return Err(SweepError::FingerprintMismatch(format!(
                        "journal {} is fingerprint {} ({} points, {} shards); \
                         expected fingerprint {} ({} points, {} shards)",
                        path.display(),
                        h.fingerprint,
                        h.points,
                        h.shard_count,
                        f.fingerprint,
                        f.points,
                        f.shard_count
                    )));
                }
            }
        }
        if let Some(other) = seen.insert(h.shard_index, path.display().to_string()) {
            return Err(SweepError::MergeConflict(format!(
                "shard {}/{} appears in both {} and {}",
                h.shard_index,
                h.shard_count,
                other,
                path.display()
            )));
        }
        let shard = Shard::new(h.shard_index, h.shard_count);
        let expected = (0..h.points).filter(|&i| shard.owns(i)).count();
        if done.len() != expected {
            return Err(SweepError::MergeIncomplete(format!(
                "journal {} holds {} of the {} rows of shard {}/{}",
                path.display(),
                done.len(),
                expected,
                h.shard_index,
                h.shard_count
            )));
        }
        rows.extend(done);
    }
    let Some(f) = first else {
        return Err(SweepError::MergeIncomplete("no journals to merge".into()));
    };
    if seen.len() != f.shard_count as usize {
        let missing: Vec<String> = (0..f.shard_count)
            .filter(|i| !seen.contains_key(i))
            .map(|i| i.to_string())
            .collect();
        return Err(SweepError::MergeIncomplete(format!(
            "missing shard(s) {} of {}",
            missing.join(", "),
            f.shard_count
        )));
    }
    Ok(rows.into_values().collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::ScenarioSpec;
    use crate::sweep::{GpuFilter, SweepMetrics};
    use std::fs;
    use std::path::PathBuf;

    fn spec() -> SweepSpec {
        SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
            .tp(vec![1, 2])
            .scenario("w", ScenarioSpec::new("llama3.1-8b", ""))
    }

    fn row(index: usize) -> SweepRow {
        SweepRow {
            index,
            workload: "w".into(),
            gpu: "A100".into(),
            tp: 1,
            pp: 1,
            replicas: 1,
            policy: crate::scenario::RoutePolicy::RoundRobin,
            gpu_count: 1,
            outcome: Ok(SweepMetrics {
                tokens_per_sec: 1024.0,
                slo_attainment: 1.0,
                ttft_sec: 0.25,
                tpot_sec: 0.125,
                cluster: false,
                usd_per_hour: 1.9,
                usd_per_mtok: 0.515,
            }),
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("synperf_journal_{name}.jsonl"));
        let _ = fs::remove_file(&p);
        p
    }

    #[test]
    fn fingerprints_are_stable_and_shard_index_free() {
        let fp = fingerprint(&spec(), 4, 3);
        assert_eq!(fp.len(), 16, "{fp}");
        assert_eq!(fp, fingerprint(&spec(), 4, 3), "deterministic");
        // a different spec, point count or shard count changes it
        assert_ne!(fp, fingerprint(&spec().tp(vec![1]), 4, 3));
        assert_ne!(fp, fingerprint(&spec(), 5, 3));
        assert_ne!(fp, fingerprint(&spec(), 4, 2));
    }

    #[test]
    fn headers_round_trip_and_reject_garbage() {
        let h = JournalHeader {
            fingerprint: fingerprint(&spec(), 4, 2),
            points: 4,
            shard_index: 1,
            shard_count: 2,
        };
        assert_eq!(parse_header_line(&encode_header(&h)).unwrap(), h);
        for bad in [
            "not json",
            r#"{"v":1,"row":{}}"#,
            r#"{"v":1,"sweep_journal":{"points":4,"shard_index":0,"shard_count":1}}"#,
            r#"{"v":1,"sweep_journal":{"fingerprint":"x","points":4,"shard_index":2,"shard_count":1}}"#,
        ] {
            assert_eq!(parse_header_line(bad).unwrap_err().code(), "journal_corrupt", "{bad}");
        }
    }

    #[test]
    fn sessions_persist_rows_and_resume_them() {
        let path = tmp("resume");
        let mut s = JournalSession::open(&path, &spec(), Shard::default(), false).unwrap();
        assert!(s.done.is_empty());
        s.record(&wire::encode_row(&row(0))).unwrap();
        s.record(&wire::encode_row(&row(2))).unwrap();
        drop(s);
        let s = JournalSession::open(&path, &spec(), Shard::default(), true).unwrap();
        assert_eq!(s.done.keys().copied().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(s.done[&0], row(0));
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn truncated_final_lines_are_discarded_but_interior_corruption_is_typed() {
        let path = tmp("trunc");
        let mut s = JournalSession::open(&path, &spec(), Shard::default(), false).unwrap();
        s.record(&wire::encode_row(&row(0))).unwrap();
        s.record(&wire::encode_row(&row(1))).unwrap();
        drop(s);
        // chop the last line mid-way: the row survives only up to index 0
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, &text[..text.len() - 20]).unwrap();
        let (_, done) = read_journal(&path).unwrap();
        assert_eq!(done.keys().copied().collect::<Vec<_>>(), vec![0]);
        // resuming truncates the partial tail before appending, so a
        // fresh row lands on its own line rather than concatenating
        let mut s = JournalSession::open(&path, &spec(), Shard::default(), true).unwrap();
        s.record(&wire::encode_row(&row(1))).unwrap();
        drop(s);
        let (_, done) = read_journal(&path).unwrap();
        assert_eq!(done.keys().copied().collect::<Vec<_>>(), vec![0, 1]);
        let text = fs::read_to_string(&path).unwrap();
        // corrupt an interior line → typed error
        let mut lines: Vec<String> = text.lines().map(str::to_string).collect();
        lines[1] = "{\"v\":1,\"row\":garbage".into();
        fs::write(&path, format!("{}\n", lines.join("\n"))).unwrap();
        assert_eq!(read_journal(&path).unwrap_err().code(), "journal_corrupt");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn mismatched_specs_cannot_resume_each_others_journals() {
        let path = tmp("mismatch");
        let mut s = JournalSession::open(&path, &spec(), Shard::default(), false).unwrap();
        s.record(&wire::encode_row(&row(0))).unwrap();
        drop(s);
        let other = spec().tp(vec![1]);
        let err = JournalSession::open(&path, &other, Shard::default(), true).unwrap_err();
        assert_eq!(err.code(), "fingerprint_mismatch");
        // same spec, different shard → also refused
        let err = JournalSession::open(&path, &spec(), Shard::new(0, 2), true).unwrap_err();
        assert_eq!(err.code(), "fingerprint_mismatch");
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn merge_unions_complete_shards_and_types_every_failure() {
        let s = spec();
        let p0 = tmp("merge0");
        let p1 = tmp("merge1");
        let mut j0 = JournalSession::open(&p0, &s, Shard::new(0, 2), false).unwrap();
        j0.record(&wire::encode_row(&row(0))).unwrap();
        let mut j1 = JournalSession::open(&p1, &s, Shard::new(1, 2), false).unwrap();
        j1.record(&wire::encode_row(&row(1))).unwrap();
        j1.record(&wire::encode_row(&row(3))).unwrap();
        drop(j1);
        // shard 0 hasn't finished row 2 yet → incomplete
        let err = merge(&[p0.clone(), p1.clone()]).unwrap_err();
        assert_eq!(err.code(), "merge_incomplete", "{err}");
        j0.record(&wire::encode_row(&row(2))).unwrap();
        drop(j0);
        // order of arguments never matters: rows come back by global index
        let rows = merge(&[p1.clone(), p0.clone()]).unwrap();
        assert_eq!(rows.iter().map(|r| r.index).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // a shard absent entirely → incomplete; the same shard twice → conflict
        assert_eq!(merge(&[p0.clone()]).unwrap_err().code(), "merge_incomplete");
        assert_eq!(merge(&[p0.clone(), p0.clone()]).unwrap_err().code(), "merge_conflict");
        // a journal from a different campaign can never sneak in
        let px = tmp("merge_other");
        drop(JournalSession::open(&px, &spec().tp(vec![1]), Shard::default(), false).unwrap());
        assert_eq!(
            merge(&[p0.clone(), px.clone()]).unwrap_err().code(),
            "fingerprint_mismatch"
        );
        for p in [p0, p1, px] {
            let _ = fs::remove_file(&p);
        }
    }

    #[test]
    fn rows_outside_the_shard_or_grid_are_corruption() {
        let path = tmp("foreign");
        let mut s = JournalSession::open(&path, &spec(), Shard::new(0, 2), false).unwrap();
        // index 1 belongs to shard 1/2, not 0/2
        s.record(&wire::encode_row(&row(1))).unwrap();
        // a later valid line keeps it from being "the truncated tail"
        s.record(&wire::encode_row(&row(2))).unwrap();
        drop(s);
        assert_eq!(read_journal(&path).unwrap_err().code(), "journal_corrupt");
        let _ = fs::remove_file(&path);
    }
}
