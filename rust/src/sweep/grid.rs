//! Grid expansion: validate the sweep axes and materialize the
//! cross-product as indexed [`SweepPoint`]s. Expansion order is
//! workloads → GPUs → tp → pp → replicas → policies, so row indices are
//! stable and human-predictable; the routing axis only multiplies cluster
//! workloads (it is a v2 knob).

use super::{GpuFilter, SweepError, SweepSpec};
use crate::hw;
use crate::scenario::cluster::MAX_REPLICAS;
use crate::scenario::wire::SimulateRequest;
use crate::scenario::RoutePolicy;

/// Hard cap on the grid size — the same order as the wire batch cap, far
/// above any interactive search but low enough that the one-line stdio
/// response and the row buffer stay bounded.
pub const MAX_SWEEP_POINTS: usize = 4096;

/// tp/pp axis values beyond this are rejected at the grid level; the
/// per-model feasibility check (divisibility, layer count) still runs per
/// point and yields typed error rows.
const MAX_AXIS_DEGREE: u32 = 64;

/// Upper bound on `--shard I/N` shard counts — enough to spread the
/// lifted `N × MAX_SWEEP_POINTS` cap across a rack of processes without
/// letting a typo'd count explode the grid budget.
pub const MAX_SHARD_COUNT: u32 = 64;

/// One process's slice of a sharded sweep: round-robin over the global
/// row index, so `index % count == index_of_this_shard`. The default
/// `0/1` is the whole grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shard {
    pub index: u32,
    pub count: u32,
}

impl Default for Shard {
    fn default() -> Self {
        Shard { index: 0, count: 1 }
    }
}

impl Shard {
    pub fn new(index: u32, count: u32) -> Self {
        Shard { index, count }
    }

    /// Validate `index < count`, `1 <= count <= MAX_SHARD_COUNT`.
    pub fn check(&self) -> Result<(), SweepError> {
        if self.count == 0 {
            return Err(SweepError::InvalidAxis("shard count must be >= 1".into()));
        }
        if self.count > MAX_SHARD_COUNT {
            return Err(SweepError::InvalidAxis(format!(
                "shard count must be <= {MAX_SHARD_COUNT}, got {}",
                self.count
            )));
        }
        if self.index >= self.count {
            return Err(SweepError::InvalidAxis(format!(
                "shard index {} out of range for {} shards",
                self.index, self.count
            )));
        }
        Ok(())
    }

    /// Whether a global row index belongs to this shard.
    pub fn owns(&self, index: usize) -> bool {
        index % self.count as usize == self.index as usize
    }
}

/// One cell of the expanded grid: the workload it evaluates (by spec
/// index) and the hardware coordinates written over that template.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepPoint {
    pub index: usize,
    pub workload: usize,
    /// Canonical registry name (post [`hw::gpu_by_name`] resolution).
    pub gpu: String,
    pub tp: u32,
    pub pp: u32,
    pub replicas: u32,
    pub policy: RoutePolicy,
}

/// Resolve a GPU filter to canonical registry names, in registry order
/// (or, for [`GpuFilter::Named`], in the order given).
pub fn gpu_names(filter: &GpuFilter) -> Result<Vec<String>, SweepError> {
    let names = |gpus: Vec<hw::GpuSpec>| gpus.iter().map(|g| g.name.to_string()).collect();
    match filter {
        GpuFilter::All => Ok(names(hw::all_gpus())),
        GpuFilter::Seen => Ok(names(hw::seen_gpus())),
        GpuFilter::Unseen => Ok(names(hw::unseen_gpus())),
        GpuFilter::Named(list) => {
            if list.is_empty() {
                return Err(SweepError::InvalidAxis(
                    "\"gpus\" must name at least one GPU".into(),
                ));
            }
            list.iter()
                .map(|n| {
                    hw::gpu_by_name(n)
                        .map(|g| g.name.to_string())
                        .ok_or_else(|| SweepError::UnknownGpu(n.clone()))
                })
                .collect()
        }
    }
}

fn check_axis(name: &str, values: &[u32], max: u32) -> Result<(), SweepError> {
    if values.is_empty() {
        return Err(SweepError::InvalidAxis(format!(
            "\"{name}\" must list at least one value"
        )));
    }
    for &v in values {
        if v == 0 {
            return Err(SweepError::InvalidAxis(format!("\"{name}\" values must be >= 1")));
        }
        if v > max {
            return Err(SweepError::InvalidAxis(format!(
                "\"{name}\" values must be <= {max}, got {v}"
            )));
        }
    }
    Ok(())
}

fn check_slo(name: &str, v: f64) -> Result<(), SweepError> {
    if v > 0.0 && v.is_finite() {
        Ok(())
    } else {
        Err(SweepError::InvalidAxis(format!("\"slo.{name}\" must be positive and finite")))
    }
}

/// The policies a workload actually multiplies over: the full axis for
/// cluster templates, a single fixed policy for v1 scenarios (routing is
/// meaningless without a router — duplicating rows would skew the grid).
fn policies_for<'a>(spec: &'a SweepSpec, template: &SimulateRequest) -> &'a [RoutePolicy] {
    match template {
        SimulateRequest::Cluster(_) => &spec.policies,
        SimulateRequest::Scenario(_) => &spec.policies[..1],
    }
}

fn check_constraints(spec: &SweepSpec) -> Result<(), SweepError> {
    if let Some(min) = spec.min_slo_attainment {
        if !(0.0..=1.0).contains(&min) || !min.is_finite() {
            return Err(SweepError::InvalidAxis(
                "\"constraints.min_slo_attainment\" must be in [0, 1]".into(),
            ));
        }
    }
    if let Some(max) = spec.max_gpus {
        if max == 0 {
            return Err(SweepError::InvalidAxis("\"constraints.max_gpus\" must be >= 1".into()));
        }
    }
    if let Some(max) = spec.max_usd_per_hour {
        if max <= 0.0 || !max.is_finite() {
            return Err(SweepError::InvalidAxis(
                "\"constraints.max_usd_per_hour\" must be positive and finite".into(),
            ));
        }
    }
    Ok(())
}

/// Validate every axis and expand the cross-product. Fails closed before
/// any evaluation: unknown named GPUs, empty/zero axes, non-finite SLOs
/// and oversized grids are spec-level [`SweepError`]s.
pub fn expand(spec: &SweepSpec) -> Result<Vec<SweepPoint>, SweepError> {
    expand_for(spec, 1)
}

/// [`expand`] with a sharding-aware cap: an `N`-shard campaign may carry
/// up to `N × MAX_SWEEP_POINTS` total points, since each process only
/// evaluates its `1/N` round-robin slice.
pub fn expand_for(spec: &SweepSpec, shard_count: u32) -> Result<Vec<SweepPoint>, SweepError> {
    let gpus = gpu_names(&spec.gpus)?;
    check_axis("tp", &spec.tp, MAX_AXIS_DEGREE)?;
    check_axis("pp", &spec.pp, MAX_AXIS_DEGREE)?;
    check_axis("replicas", &spec.replicas, MAX_REPLICAS)?;
    if spec.policies.is_empty() {
        return Err(SweepError::InvalidAxis("\"policies\" must list at least one policy".into()));
    }
    if spec.workloads.is_empty() {
        return Err(SweepError::InvalidAxis(
            "\"workloads\" must list at least one workload".into(),
        ));
    }
    check_slo("ttft_sec", spec.slo_ttft_sec)?;
    check_slo("tpot_sec", spec.slo_tpot_sec)?;
    check_constraints(spec)?;
    let cap = (shard_count.max(1) as usize).saturating_mul(MAX_SWEEP_POINTS);
    let per_point = gpus.len() * spec.tp.len() * spec.pp.len() * spec.replicas.len();
    let total: usize = spec
        .workloads
        .iter()
        .map(|w| per_point.saturating_mul(policies_for(spec, &w.template).len()))
        .fold(0usize, usize::saturating_add);
    if total > cap {
        return Err(SweepError::GridTooLarge(format!(
            "{total} points exceed the cap of {cap}"
        )));
    }
    let mut points = Vec::with_capacity(total);
    for (wi, w) in spec.workloads.iter().enumerate() {
        let policies = policies_for(spec, &w.template);
        for gpu in &gpus {
            for &tp in &spec.tp {
                for &pp in &spec.pp {
                    for &replicas in &spec.replicas {
                        for &policy in policies {
                            points.push(SweepPoint {
                                index: points.len(),
                                workload: wi,
                                gpu: gpu.clone(),
                                tp,
                                pp,
                                replicas,
                                policy,
                            });
                        }
                    }
                }
            }
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{ArrivalSpec, ClusterSpec, ScenarioSpec};

    fn v1(name: &str) -> SweepSpec {
        SweepSpec::new().scenario(name, ScenarioSpec::new("llama3.1-8b", ""))
    }

    #[test]
    fn default_filter_covers_the_whole_registry_in_order() {
        let points = expand(&v1("w")).unwrap();
        assert_eq!(points.len(), 11);
        assert_eq!(points[0].gpu, "A40");
        assert_eq!(points[1].gpu, "A100");
        assert_eq!(points[10].gpu, "RTX PRO 6000 S");
        for (i, p) in points.iter().enumerate() {
            assert_eq!(p.index, i);
        }
    }

    #[test]
    fn seen_unseen_filters_slice_the_registry() {
        assert_eq!(expand(&v1("w").gpus(GpuFilter::Seen)).unwrap().len(), 6);
        assert_eq!(expand(&v1("w").gpus(GpuFilter::Unseen)).unwrap().len(), 5);
    }

    #[test]
    fn named_gpus_resolve_fuzzily_to_canonical_names() {
        let spec = v1("w").gpus(GpuFilter::Named(vec!["h800".into(), "rtx_6000_ada".into()]));
        let points = expand(&spec).unwrap();
        assert_eq!(points[0].gpu, "H800");
        assert_eq!(points[1].gpu, "RTX 6000 Ada");
    }

    #[test]
    fn unknown_named_gpu_fails_the_whole_sweep() {
        let spec = v1("w").gpus(GpuFilter::Named(vec!["B300".into()]));
        let err = expand(&spec).unwrap_err();
        assert_eq!(err.code(), "unknown_gpu");
        assert!(err.to_string().contains("closest: A100, H800, H100"), "{err}");
    }

    #[test]
    fn expansion_order_is_workload_gpu_tp_pp_replicas_policy() {
        let spec = v1("w")
            .gpus(GpuFilter::Named(vec!["A100".into(), "H800".into()]))
            .tp(vec![1, 2])
            .replicas(vec![1, 2]);
        let points = expand(&spec).unwrap();
        assert_eq!(points.len(), 8);
        // replicas vary fastest, then pp/tp, then GPU
        let coords: Vec<(&str, u32, u32)> =
            points.iter().map(|p| (p.gpu.as_str(), p.tp, p.replicas)).collect();
        assert_eq!(
            coords,
            vec![
                ("A100", 1, 1),
                ("A100", 1, 2),
                ("A100", 2, 1),
                ("A100", 2, 2),
                ("H800", 1, 1),
                ("H800", 1, 2),
                ("H800", 2, 1),
                ("H800", 2, 2),
            ]
        );
    }

    #[test]
    fn policy_axis_multiplies_cluster_workloads_only() {
        use crate::e2e::workload::WorkloadKind;
        let cluster = ClusterSpec::new("llama3.1-8b", "").arrivals(ArrivalSpec::Uniform {
            gap_sec: 0.5,
            n: 2,
            kind: WorkloadKind::Arxiv,
        });
        let spec = SweepSpec::new()
            .gpus(GpuFilter::Named(vec!["A100".into()]))
            .policies(vec![RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded])
            .scenario("v1", ScenarioSpec::new("llama3.1-8b", ""))
            .workload("v2", SimulateRequest::Cluster(cluster));
        let points = expand(&spec).unwrap();
        // 1 (v1 pinned to the first policy) + 2 (v2 crosses the axis)
        assert_eq!(points.len(), 3);
        assert_eq!(points[0].policy, RoutePolicy::RoundRobin);
        assert_eq!(points[1].policy, RoutePolicy::RoundRobin);
        assert_eq!(points[2].policy, RoutePolicy::LeastLoaded);
    }

    #[test]
    fn invalid_axes_speak_the_taxonomy() {
        assert_eq!(expand(&v1("w").tp(vec![])).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&v1("w").tp(vec![0])).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&v1("w").pp(vec![65])).unwrap_err().code(), "invalid_axis");
        assert_eq!(
            expand(&v1("w").replicas(vec![MAX_REPLICAS + 1])).unwrap_err().code(),
            "invalid_axis"
        );
        assert_eq!(expand(&v1("w").policies(vec![])).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&SweepSpec::new()).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&v1("w").slo(0.0, 0.2)).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&v1("w").slo(2.0, f64::NAN)).unwrap_err().code(), "invalid_axis");
        assert_eq!(
            expand(&v1("w").gpus(GpuFilter::Named(vec![]))).unwrap_err().code(),
            "invalid_axis"
        );
    }

    #[test]
    fn shards_partition_the_grid_round_robin() {
        let points = expand(&v1("w")).unwrap();
        for count in [2u32, 3] {
            let mut seen = Vec::new();
            for index in 0..count {
                let shard = Shard::new(index, count);
                shard.check().unwrap();
                seen.extend(points.iter().map(|p| p.index).filter(|&i| shard.owns(i)));
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..points.len()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn bad_shards_speak_the_taxonomy() {
        assert_eq!(Shard::new(0, 0).check().unwrap_err().code(), "invalid_axis");
        assert_eq!(Shard::new(2, 2).check().unwrap_err().code(), "invalid_axis");
        assert_eq!(
            Shard::new(0, MAX_SHARD_COUNT + 1).check().unwrap_err().code(),
            "invalid_axis"
        );
        assert_eq!(Shard::default(), Shard::new(0, 1));
    }

    #[test]
    fn shard_count_lifts_the_grid_cap() {
        // 11 GPUs × 8 tp × 8 pp × 8 replicas = 5632: over one shard's
        // 4096 cap, within a 2-shard campaign's 8192.
        let spec = v1("w")
            .tp(vec![1, 2, 3, 4, 5, 6, 7, 8])
            .pp(vec![1, 2, 3, 4, 5, 6, 7, 8])
            .replicas(vec![1, 2, 3, 4, 5, 6, 7, 8]);
        assert_eq!(expand(&spec).unwrap_err().code(), "grid_too_large");
        assert_eq!(expand_for(&spec, 2).unwrap().len(), 5632);
    }

    #[test]
    fn invalid_constraints_speak_the_taxonomy() {
        assert_eq!(
            expand(&v1("w").min_slo_attainment(1.5)).unwrap_err().code(),
            "invalid_axis"
        );
        assert_eq!(
            expand(&v1("w").min_slo_attainment(f64::NAN)).unwrap_err().code(),
            "invalid_axis"
        );
        assert_eq!(expand(&v1("w").max_gpus(0)).unwrap_err().code(), "invalid_axis");
        assert_eq!(expand(&v1("w").max_usd_per_hour(0.0)).unwrap_err().code(), "invalid_axis");
        assert_eq!(
            expand(&v1("w").max_usd_per_hour(f64::INFINITY)).unwrap_err().code(),
            "invalid_axis"
        );
        // well-formed constraints expand fine
        let spec = v1("w").min_slo_attainment(0.9).max_gpus(8).max_usd_per_hour(50.0);
        assert_eq!(expand(&spec).unwrap().len(), 11);
    }

    #[test]
    fn oversized_grids_are_rejected_up_front() {
        // 11 GPUs × 8 tp × 8 pp × 8 replicas = 5632 > 4096
        let spec = v1("w")
            .tp(vec![1, 2, 3, 4, 5, 6, 7, 8])
            .pp(vec![1, 2, 3, 4, 5, 6, 7, 8])
            .replicas(vec![1, 2, 3, 4, 5, 6, 7, 8]);
        let err = expand(&spec).unwrap_err();
        assert_eq!(err.code(), "grid_too_large");
        assert!(err.to_string().contains("5632"), "{err}");
    }
}
