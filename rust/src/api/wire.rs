//! JSONL wire codec for protocol v1 (`synperf serve --stdio`): one request
//! object per line in, one response object per line out, over the in-tree
//! [`crate::util::json`] parser (the offline vendor set has no serde).
//!
//! Request line:
//!
//! ```json
//! {"v":1,"id":"r1","gpu":"A100",
//!  "kernel":{"type":"gemm","m":4096,"n":4096,"k":4096,"dtype":"bf16"},
//!  "flavor":"mean","allow_degraded":true,"breakdown":false,"tag":"warmup"}
//! ```
//!
//! `gpu` and `kernel` are required; everything else is optional with the
//! defaults shown. Success and error response lines:
//!
//! ```json
//! {"v":1,"id":"r1","ok":true,"latency_sec":1.234e-4,"latency_us":123.400,
//!  "source":"mlp","cache_hit":false,"flavor":"mean","kernel":"gemm","gpu":"A100"}
//! {"v":1,"id":"r2","ok":false,"error":{"code":"unknown_gpu",
//!  "message":"unknown GPU \"B300\" (see Table VI; closest: A100, H800, H100)","gpu":"B300"}}
//! ```
//!
//! Malformed lines map into the closed taxonomy as
//! [`PredictError::UnsupportedKernel`] (the malformed-request bucket); GPU
//! name lookups that fail map to [`PredictError::UnknownGpu`].
//!
//! The same JSONL surface also speaks the **`simulate` verb**: a line with
//! `"op":"simulate"` (or a `"scenario"` object) carries a
//! [`crate::scenario::ScenarioSpec`] and answers with a
//! [`crate::scenario::ScenarioReport`] line — the codec lives in
//! [`crate::scenario::wire`], and [`super::stdio`] dispatches between the
//! two verbs per line.

use super::{
    Breakdown, Flavor, PipeStat, PredictError, PredictRequest, PredictResponse, Provenance,
    Source,
};
use crate::kernels::{DType, KernelConfig, KernelKind, MoeConfig};
use crate::util::json::{parse, Json};
use anyhow::{anyhow, Result};

fn unsupported(why: impl Into<String>) -> PredictError {
    PredictError::UnsupportedKernel(why.into())
}

/// JSON string escape (the inverse of the parser's unescape). Shared with
/// the scenario wire codec ([`crate::scenario::wire`]).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn dtype_name(d: DType) -> &'static str {
    match d {
        DType::Fp32 => "fp32",
        DType::Bf16 => "bf16",
        DType::Fp8 => "fp8",
    }
}

fn dtype_from(s: &str) -> Result<DType, PredictError> {
    match s {
        "fp32" => Ok(DType::Fp32),
        "bf16" => Ok(DType::Bf16),
        "fp8" => Ok(DType::Fp8),
        other => Err(unsupported(format!("unknown dtype {other:?}"))),
    }
}

fn u32_field(obj: &Json, key: &str) -> Result<u32, PredictError> {
    num_u32(
        obj.get(key)
            .ok_or_else(|| unsupported(format!("kernel field {key:?} missing")))?,
        key,
    )
}

fn num_u32(v: &Json, what: &str) -> Result<u32, PredictError> {
    v.as_f64()
        .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= u32::MAX as f64)
        .map(|n| n as u32)
        .ok_or_else(|| unsupported(format!("{what:?} must be an unsigned integer")))
}

/// Serialize a kernel config into its canonical wire object.
pub fn kernel_to_json(cfg: &KernelConfig) -> String {
    match cfg {
        KernelConfig::Gemm { m, n, k, dtype } => format!(
            r#"{{"type":"gemm","m":{m},"n":{n},"k":{k},"dtype":"{}"}}"#,
            dtype_name(*dtype)
        ),
        KernelConfig::ScaledMm { m, n, k } => {
            format!(r#"{{"type":"scaled_mm","m":{m},"n":{n},"k":{k}}}"#)
        }
        KernelConfig::Attention { batch, nh, nkv, hd, causal, fa3 } => {
            let pairs: Vec<String> =
                batch.iter().map(|(q, kv)| format!("[{q},{kv}]")).collect();
            format!(
                r#"{{"type":"attention","batch":[{}],"nh":{nh},"nkv":{nkv},"hd":{hd},"causal":{causal},"fa3":{fa3}}}"#,
                pairs.join(",")
            )
        }
        KernelConfig::RmsNorm { seq, dim } => {
            format!(r#"{{"type":"rmsnorm","seq":{seq},"dim":{dim}}}"#)
        }
        KernelConfig::SiluMul { seq, dim } => {
            format!(r#"{{"type":"silu_mul","seq":{seq},"dim":{dim}}}"#)
        }
        KernelConfig::FusedMoe { m, e, topk, h, n, expert_tokens, cfg } => {
            let toks: Vec<String> = expert_tokens.iter().map(|t| t.to_string()).collect();
            format!(
                r#"{{"type":"fused_moe","m":{m},"e":{e},"topk":{topk},"h":{h},"n":{n},"expert_tokens":[{}],"cfg":{{"block_m":{},"block_n":{},"block_k":{},"num_stages":{},"num_warps":{}}}}}"#,
                toks.join(","),
                cfg.block_m,
                cfg.block_n,
                cfg.block_k,
                cfg.num_stages,
                cfg.num_warps
            )
        }
    }
}

fn kernel_from_json(j: &Json, gpu: &crate::hw::GpuSpec) -> Result<KernelConfig, PredictError> {
    let ty = j
        .get("type")
        .and_then(|v| v.as_str())
        .ok_or_else(|| unsupported("kernel object needs a \"type\""))?;
    match ty {
        "gemm" => Ok(KernelConfig::Gemm {
            m: u32_field(j, "m")?,
            n: u32_field(j, "n")?,
            k: u32_field(j, "k")?,
            dtype: match j.get("dtype") {
                None => DType::Bf16,
                Some(v) => dtype_from(
                    v.as_str().ok_or_else(|| unsupported("\"dtype\" must be a string"))?,
                )?,
            },
        }),
        "scaled_mm" => Ok(KernelConfig::ScaledMm {
            m: u32_field(j, "m")?,
            n: u32_field(j, "n")?,
            k: u32_field(j, "k")?,
        }),
        "attention" => {
            let arr = j
                .get("batch")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| unsupported("attention needs \"batch\": [[q,kv],...]"))?;
            let mut batch = Vec::with_capacity(arr.len());
            for pair in arr {
                let p = pair
                    .as_arr()
                    .filter(|p| p.len() == 2)
                    .ok_or_else(|| unsupported("attention batch entries are [q,kv] pairs"))?;
                batch.push((num_u32(&p[0], "q")?, num_u32(&p[1], "kv")?));
            }
            let nh = u32_field(j, "nh")?;
            Ok(KernelConfig::Attention {
                batch,
                nh,
                nkv: match j.get("nkv") {
                    None => nh,
                    Some(v) => num_u32(v, "nkv")?,
                },
                hd: u32_field(j, "hd")?,
                causal: j.get("causal").and_then(|v| v.as_bool()).unwrap_or(true),
                // FA2-vs-FA3 selection is resolved per GPU by the engine
                // (finalize_for_gpu); the wire value is only a hint
                fa3: j.get("fa3").and_then(|v| v.as_bool()).unwrap_or(false),
            })
        }
        "rmsnorm" => Ok(KernelConfig::RmsNorm {
            seq: u32_field(j, "seq")?,
            dim: u32_field(j, "dim")?,
        }),
        "silu_mul" => Ok(KernelConfig::SiluMul {
            seq: u32_field(j, "seq")?,
            dim: u32_field(j, "dim")?,
        }),
        "fused_moe" => {
            let m = u32_field(j, "m")?;
            let e = u32_field(j, "e")?;
            let topk = u32_field(j, "topk")?;
            if e == 0 {
                return Err(unsupported("fused_moe needs e >= 1"));
            }
            let expert_tokens = match j.get("expert_tokens") {
                Some(v) => {
                    let arr = v
                        .as_arr()
                        .ok_or_else(|| unsupported("\"expert_tokens\" must be an array"))?;
                    arr.iter()
                        .map(|t| num_u32(t, "expert_tokens[i]"))
                        .collect::<Result<Vec<u32>, PredictError>>()?
                }
                // deterministic uniform routing when the caller doesn't
                // supply the routing result
                None => {
                    let total = m.saturating_mul(topk);
                    let (base, rem) = (total / e, total % e);
                    (0..e).map(|i| base + u32::from(i < rem)).collect()
                }
            };
            let cfg = match j.get("cfg") {
                Some(c) => MoeConfig {
                    block_m: u32_field(c, "block_m")?,
                    block_n: u32_field(c, "block_n")?,
                    block_k: u32_field(c, "block_k")?,
                    num_stages: u32_field(c, "num_stages")?,
                    num_warps: u32_field(c, "num_warps")?,
                },
                None => crate::kernels::fused_moe::default_config(
                    (m.saturating_mul(topk) / e).max(1),
                    gpu,
                ),
            };
            Ok(KernelConfig::FusedMoe {
                m,
                e,
                topk,
                h: u32_field(j, "h")?,
                n: u32_field(j, "n")?,
                expert_tokens,
                cfg,
            })
        }
        other => Err(unsupported(format!("unknown kernel type {other:?}"))),
    }
}

/// Serialize a typed request into its canonical wire line (no trailing
/// newline). The inverse of [`parse_request`].
pub fn encode_request(id: Option<&str>, req: &PredictRequest) -> String {
    let mut out = format!("{{\"v\":{}", super::PROTOCOL_VERSION);
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(
        ",\"gpu\":\"{}\",\"kernel\":{},\"flavor\":\"{}\",\"allow_degraded\":{},\"breakdown\":{}",
        esc(req.gpu.name),
        kernel_to_json(&req.cfg),
        req.opts.flavor.name(),
        req.opts.allow_degraded,
        req.opts.with_breakdown
    ));
    if let Some(tag) = &req.opts.tag {
        out.push_str(&format!(",\"tag\":\"{}\"", esc(tag)));
    }
    if let Some(ms) = req.opts.deadline_ms {
        out.push_str(&format!(",\"deadline_ms\":{ms}"));
    }
    out.push('}');
    out
}

/// Parse one request line. The extracted `id` (if any) is returned even
/// when parsing fails, so the error response can still be correlated.
pub fn parse_request(line: &str) -> (Option<String>, Result<PredictRequest, PredictError>) {
    let j = match parse(line) {
        Ok(j) => j,
        Err(e) => return (None, Err(unsupported(format!("malformed JSON: {e}")))),
    };
    parse_request_json(&j)
}

/// Extract the correlation id (string or number) from a decoded line —
/// shared by both wire verbs so id handling cannot diverge.
pub(crate) fn id_of(j: &Json) -> Option<String> {
    match j.get("id") {
        Some(Json::Str(s)) => Some(s.clone()),
        Some(Json::Num(n)) => Some(format!("{n}")),
        _ => None,
    }
}

/// Parse an already-decoded request object — the single-parse dispatch
/// path of the stdio serve loop (which decodes each line once to pick a
/// verb, then hands the `Json` to the winning codec).
pub(crate) fn parse_request_json(j: &Json) -> (Option<String>, Result<PredictRequest, PredictError>) {
    (id_of(j), parse_request_fields(j))
}

fn parse_request_fields(j: &Json) -> Result<PredictRequest, PredictError> {
    if let Some(v) = j.get("v").and_then(|v| v.as_f64()) {
        if v as u32 != super::PROTOCOL_VERSION {
            return Err(unsupported(format!(
                "protocol version {v} (this build speaks v{})",
                super::PROTOCOL_VERSION
            )));
        }
    }
    let gpu_name = j
        .get("gpu")
        .and_then(|v| v.as_str())
        .ok_or_else(|| unsupported("request needs \"gpu\": \"<name>\""))?;
    let gpu = super::resolve_gpu(gpu_name)?;
    let kernel = j
        .get("kernel")
        .ok_or_else(|| unsupported("request needs a \"kernel\" object"))?;
    let cfg = kernel_from_json(kernel, &gpu)?;
    let mut req = PredictRequest::new(cfg, gpu);
    if let Some(v) = j.get("flavor") {
        let name = v.as_str().ok_or_else(|| unsupported("\"flavor\" must be a string"))?;
        req.opts.flavor = Flavor::from_name(name)
            .ok_or_else(|| unsupported(format!("unknown flavor {name:?} (mean|p80)")))?;
    }
    if let Some(v) = j.get("allow_degraded") {
        req.opts.allow_degraded =
            v.as_bool().ok_or_else(|| unsupported("\"allow_degraded\" must be a bool"))?;
    }
    if let Some(v) = j.get("breakdown") {
        req.opts.with_breakdown =
            v.as_bool().ok_or_else(|| unsupported("\"breakdown\" must be a bool"))?;
    }
    if let Some(v) = j.get("tag") {
        req.opts.tag =
            Some(v.as_str().ok_or_else(|| unsupported("\"tag\" must be a string"))?.to_string());
    }
    if let Some(v) = j.get("deadline_ms") {
        // u32 range is ~49 days of milliseconds — ample for an admission
        // deadline, and it reuses the strict integer check
        req.opts.deadline_ms = Some(u64::from(num_u32(v, "deadline_ms")?));
    }
    Ok(req)
}

fn pipe_to_json(p: &PipeStat) -> String {
    format!(
        r#"{{"total_ops":{:e},"max_sm_ops":{:e},"total_cycles":{:e}}}"#,
        p.total_ops, p.max_sm_ops, p.total_cycles
    )
}

fn breakdown_to_json(b: &Breakdown) -> String {
    format!(
        r#"{{"tensor":{},"fma":{},"xu":{},"mio_bytes":{:e},"dram_cycles":{:e},"theory_sec":{:e},"naive_roofline_sec":{:e}}}"#,
        pipe_to_json(&b.tensor),
        pipe_to_json(&b.fma),
        pipe_to_json(&b.xu),
        b.mio_bytes,
        b.dram_cycles,
        b.theory_sec,
        b.naive_roofline_sec
    )
}

/// Serialize one typed result into its wire line (no trailing newline).
pub fn encode_response(id: Option<&str>, res: &Result<PredictResponse, PredictError>) -> String {
    let mut out = format!("{{\"v\":{}", super::PROTOCOL_VERSION);
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    match res {
        Ok(r) => {
            out.push_str(&format!(
                ",\"ok\":true,\"latency_sec\":{:e},\"latency_us\":{:.3},\"source\":\"{}\",\"cache_hit\":{},\"flavor\":\"{}\",\"kernel\":\"{}\",\"gpu\":\"{}\"",
                r.latency_sec,
                r.latency_sec * 1e6,
                r.provenance.source.name(),
                r.provenance.cache_hit,
                r.flavor.name(),
                r.kind.name(),
                esc(&r.gpu)
            ));
            if let Some(tag) = &r.tag {
                out.push_str(&format!(",\"tag\":\"{}\"", esc(tag)));
            }
            if let Some(b) = &r.breakdown {
                out.push_str(&format!(",\"breakdown\":{}", breakdown_to_json(b)));
            }
        }
        Err(e) => {
            out.push_str(&format!(
                ",\"ok\":false,\"error\":{{\"code\":\"{}\",\"message\":\"{}\"",
                e.code(),
                esc(&e.to_string())
            ));
            match e {
                PredictError::UnknownGpu(name) => {
                    out.push_str(&format!(",\"gpu\":\"{}\"", esc(name)));
                }
                PredictError::UnsupportedKernel(why) => {
                    out.push_str(&format!(",\"reason\":\"{}\"", esc(why)));
                }
                PredictError::PredictorUnavailable(kind) => {
                    out.push_str(&format!(",\"kind\":\"{}\"", kind.name()));
                }
                _ => {}
            }
            out.push('}');
        }
    }
    out.push('}');
    out
}

fn pipe_from_json(j: &Json) -> Result<PipeStat> {
    let f = |key: &str| {
        j.get(key).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("pipe stat {key:?} missing"))
    };
    Ok(PipeStat { total_ops: f("total_ops")?, max_sm_ops: f("max_sm_ops")?, total_cycles: f("total_cycles")? })
}

/// Parse one response line back into the typed result — the client half of
/// the wire, used by round-trip tests and remote tooling.
pub fn parse_response(
    line: &str,
) -> Result<(Option<String>, Result<PredictResponse, PredictError>)> {
    let j = parse(line)?;
    let id = id_of(&j);
    let ok = j.get("ok").and_then(|v| v.as_bool()).ok_or_else(|| anyhow!("response needs \"ok\""))?;
    if !ok {
        let err = j.get("error").ok_or_else(|| anyhow!("error response needs \"error\""))?;
        let code = err
            .get("code")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("error needs \"code\""))?;
        let message =
            err.get("message").and_then(|v| v.as_str()).unwrap_or_default().to_string();
        let e = match code {
            "unknown_gpu" => PredictError::UnknownGpu(
                err.get("gpu").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
            ),
            "unsupported_kernel" => PredictError::UnsupportedKernel(
                err.get("reason").and_then(|v| v.as_str()).map(str::to_string).unwrap_or(message),
            ),
            "predictor_unavailable" => PredictError::PredictorUnavailable(
                err.get("kind")
                    .and_then(|v| v.as_str())
                    .and_then(KernelKind::from_name)
                    .ok_or_else(|| anyhow!("predictor_unavailable needs a \"kind\""))?,
            ),
            "queue_full" => PredictError::QueueFull,
            "deadline_exceeded" => PredictError::DeadlineExceeded,
            "shutdown" => PredictError::Shutdown,
            other => anyhow::bail!("unknown error code {other:?}"),
        };
        return Ok((id, Err(e)));
    }
    let f64_field = |key: &str| {
        j.get(key).and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("response field {key:?} missing"))
    };
    let str_field = |key: &str| {
        j.get(key)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("response field {key:?} missing"))
    };
    let breakdown = match j.get("breakdown") {
        None => None,
        Some(b) => {
            let f = |key: &str| {
                b.get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("breakdown field {key:?} missing"))
            };
            Some(Breakdown {
                tensor: pipe_from_json(b.get("tensor").ok_or_else(|| anyhow!("no tensor"))?)?,
                fma: pipe_from_json(b.get("fma").ok_or_else(|| anyhow!("no fma"))?)?,
                xu: pipe_from_json(b.get("xu").ok_or_else(|| anyhow!("no xu"))?)?,
                mio_bytes: f("mio_bytes")?,
                dram_cycles: f("dram_cycles")?,
                theory_sec: f("theory_sec")?,
                naive_roofline_sec: f("naive_roofline_sec")?,
            })
        }
    };
    let source = match j.get("source").and_then(|v| v.as_str()) {
        Some("mlp") => Source::Mlp,
        Some("roofline") => Source::Roofline,
        other => anyhow::bail!("bad source {other:?}"),
    };
    let flavor = j
        .get("flavor")
        .and_then(|v| v.as_str())
        .and_then(Flavor::from_name)
        .ok_or_else(|| anyhow!("bad flavor"))?;
    let kind = j
        .get("kernel")
        .and_then(|v| v.as_str())
        .and_then(KernelKind::from_name)
        .ok_or_else(|| anyhow!("bad kernel kind"))?;
    Ok((
        id,
        Ok(PredictResponse {
            latency_sec: f64_field("latency_sec")?,
            provenance: Provenance {
                source,
                cache_hit: j
                    .get("cache_hit")
                    .and_then(|v| v.as_bool())
                    .ok_or_else(|| anyhow!("response needs \"cache_hit\""))?,
            },
            flavor,
            kind,
            gpu: str_field("gpu")?,
            breakdown,
            tag: j.get("tag").and_then(|v| v.as_str()).map(str::to_string),
        }),
    ))
}

/// Per-surface connection counters of the `stats` verb. The stdio surface
/// reports its single implicit peer (`connected: 1, total: 1`); the TCP
/// surface reports its live connection table plus the fault counters of
/// the serving front end.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ClientStats {
    /// Connections currently open.
    pub connected: u64,
    /// Connections accepted over the server's lifetime.
    pub total: u64,
    /// Connections dropped for repeated malformed/oversized lines.
    pub quarantined: u64,
    /// Connections reaped after `idle_timeout` without a byte of progress.
    pub idle_reaped: u64,
    /// Lines refused for exceeding the line-size cap (typed error answers,
    /// connection stays up until quarantine).
    pub oversized_lines: u64,
    /// Connections that vanished mid-stream (read/write I/O errors).
    pub disconnects: u64,
}

/// The one JSON shape both wire surfaces answer the `stats` verb with:
/// coordinator metrics (the lock-free `Metrics::snapshot` path) plus the
/// serving surface's own line/connection counters.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct StatsReport {
    /// Requests the coordinator has answered.
    pub requests: u64,
    /// Dynamic batches processed.
    pub batches: u64,
    /// Mean requests per batch.
    pub mean_batch: f64,
    /// Requests refused with `queue_full`.
    pub rejected_requests: u64,
    /// Requests answered `deadline_exceeded`.
    pub deadline_exceeded: u64,
    /// Live bounded-queue backlog and its high-water mark.
    pub queue_depth: u64,
    pub max_queue_depth: u64,
    /// Engine analysis-cache outcome counters.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Response lines this surface has written (including this one).
    pub served: u64,
    /// How many of `served` were error lines.
    pub errors: u64,
    /// Simulate-, sweep- and tune-verb lines among `served`.
    pub simulated: u64,
    pub swept: u64,
    pub tuned: u64,
    pub clients: ClientStats,
}

/// Is this decoded line the `stats` verb? (`{"op":"stats"}`.)
pub(crate) fn is_stats_json(j: &Json) -> bool {
    j.get("op").and_then(|v| v.as_str()) == Some("stats")
}

/// Serialize a stats report into its wire line (no trailing newline).
/// Field order is fixed — `tests/protocol.rs` pins the exact bytes.
pub fn encode_stats(id: Option<&str>, s: &StatsReport) -> String {
    let mut out = format!("{{\"v\":{}", super::PROTOCOL_VERSION);
    if let Some(id) = id {
        out.push_str(&format!(",\"id\":\"{}\"", esc(id)));
    }
    out.push_str(&format!(
        ",\"ok\":true,\"stats\":{{\"requests\":{},\"batches\":{},\"mean_batch\":{:e},\"rejected_requests\":{},\"deadline_exceeded\":{},\"queue_depth\":{},\"max_queue_depth\":{},\"cache_hits\":{},\"cache_misses\":{},\"served\":{},\"errors\":{},\"simulated\":{},\"swept\":{},\"tuned\":{},\"clients\":{{\"connected\":{},\"total\":{},\"quarantined\":{},\"idle_reaped\":{},\"oversized_lines\":{},\"disconnects\":{}}}}}}}",
        s.requests,
        s.batches,
        s.mean_batch,
        s.rejected_requests,
        s.deadline_exceeded,
        s.queue_depth,
        s.max_queue_depth,
        s.cache_hits,
        s.cache_misses,
        s.served,
        s.errors,
        s.simulated,
        s.swept,
        s.tuned,
        s.clients.connected,
        s.clients.total,
        s.clients.quarantined,
        s.clients.idle_reaped,
        s.clients.oversized_lines,
        s.clients.disconnects,
    ));
    out
}

/// Parse a stats response line back into the typed report — the client
/// half, used by goldens, the chaos harness and remote tooling.
pub fn parse_stats(line: &str) -> Result<(Option<String>, StatsReport)> {
    let j = parse(line)?;
    let id = id_of(&j);
    let s = j.get("stats").ok_or_else(|| anyhow!("stats response needs \"stats\""))?;
    let u = |obj: &Json, key: &str| -> Result<u64> {
        obj.get(key)
            .and_then(|v| v.as_f64())
            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
            .map(|n| n as u64)
            .ok_or_else(|| anyhow!("stats field {key:?} missing or not a count"))
    };
    let c = s.get("clients").ok_or_else(|| anyhow!("stats needs \"clients\""))?;
    Ok((
        id,
        StatsReport {
            requests: u(s, "requests")?,
            batches: u(s, "batches")?,
            mean_batch: s
                .get("mean_batch")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("stats needs \"mean_batch\""))?,
            rejected_requests: u(s, "rejected_requests")?,
            deadline_exceeded: u(s, "deadline_exceeded")?,
            queue_depth: u(s, "queue_depth")?,
            max_queue_depth: u(s, "max_queue_depth")?,
            cache_hits: u(s, "cache_hits")?,
            cache_misses: u(s, "cache_misses")?,
            served: u(s, "served")?,
            errors: u(s, "errors")?,
            simulated: u(s, "simulated")?,
            swept: u(s, "swept")?,
            tuned: u(s, "tuned")?,
            clients: ClientStats {
                connected: u(c, "connected")?,
                total: u(c, "total")?,
                quarantined: u(c, "quarantined")?,
                idle_reaped: u(c, "idle_reaped")?,
                oversized_lines: u(c, "oversized_lines")?,
                disconnects: u(c, "disconnects")?,
            },
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::resolve_gpu;

    #[test]
    fn request_lines_round_trip_every_kind() {
        let gpu = resolve_gpu("A100").unwrap();
        let cfgs = vec![
            KernelConfig::Gemm { m: 7, n: 9, k: 11, dtype: DType::Fp8 },
            KernelConfig::ScaledMm { m: 4, n: 8, k: 16 },
            KernelConfig::Attention {
                batch: vec![(3, 5), (1, 9)],
                nh: 8,
                nkv: 2,
                hd: 64,
                causal: false,
                fa3: false,
            },
            KernelConfig::RmsNorm { seq: 13, dim: 17 },
            KernelConfig::SiluMul { seq: 19, dim: 23 },
            KernelConfig::FusedMoe {
                m: 6,
                e: 3,
                topk: 2,
                h: 32,
                n: 16,
                expert_tokens: vec![4, 4, 4],
                cfg: MoeConfig { block_m: 16, block_n: 32, block_k: 64, num_stages: 3, num_warps: 4 },
            },
        ];
        for cfg in cfgs {
            let req = PredictRequest::new(cfg.clone(), gpu.clone()).tagged("rt");
            let line = encode_request(Some("x1"), &req);
            let (id, parsed) = parse_request(&line);
            assert_eq!(id.as_deref(), Some("x1"));
            let back = parsed.unwrap();
            assert_eq!(back.cfg, cfg, "round trip of {line}");
            assert_eq!(back.gpu.name, "A100");
            assert_eq!(back.opts, req.opts);
        }
    }

    #[test]
    fn fused_moe_defaults_derive_routing_and_cfg() {
        let line = r#"{"gpu":"H100","kernel":{"type":"fused_moe","m":10,"e":4,"topk":2,"h":64,"n":32}}"#;
        let (_, req) = parse_request(line);
        match req.unwrap().cfg {
            KernelConfig::FusedMoe { expert_tokens, .. } => {
                assert_eq!(expert_tokens, vec![5, 5, 5, 5]);
            }
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn errors_map_into_the_closed_taxonomy() {
        let cases = [
            ("not json at all", "unsupported_kernel"),
            (r#"{"kernel":{"type":"gemm","m":1,"n":1,"k":1}}"#, "unsupported_kernel"),
            (r#"{"gpu":"B300","kernel":{"type":"gemm","m":1,"n":1,"k":1}}"#, "unknown_gpu"),
            (r#"{"gpu":"A100","kernel":{"type":"conv2d"}}"#, "unsupported_kernel"),
            (r#"{"v":9,"gpu":"A100","kernel":{"type":"rmsnorm","seq":1,"dim":1}}"#, "unsupported_kernel"),
        ];
        for (line, code) in cases {
            let (_, res) = parse_request(line);
            assert_eq!(res.unwrap_err().code(), code, "for line {line}");
        }
    }

    #[test]
    fn deadline_ms_rides_the_request_wire() {
        let gpu = resolve_gpu("A100").unwrap();
        let req =
            PredictRequest::new(KernelConfig::RmsNorm { seq: 2, dim: 2 }, gpu).deadline_ms(250);
        let line = encode_request(None, &req);
        assert!(line.contains(r#""deadline_ms":250"#), "{line}");
        let (_, back) = parse_request(&line);
        assert_eq!(back.unwrap().opts.deadline_ms, Some(250));
        // a non-integer deadline is refused, not truncated
        let (_, bad) = parse_request(
            r#"{"gpu":"A100","kernel":{"type":"rmsnorm","seq":2,"dim":2},"deadline_ms":1.5}"#,
        );
        assert_eq!(bad.unwrap_err().code(), "unsupported_kernel");
    }

    #[test]
    fn stats_report_round_trips() {
        let report = StatsReport {
            requests: 9,
            batches: 3,
            mean_batch: 3.0,
            rejected_requests: 2,
            deadline_exceeded: 1,
            queue_depth: 0,
            max_queue_depth: 5,
            cache_hits: 7,
            cache_misses: 2,
            served: 12,
            errors: 3,
            simulated: 1,
            swept: 0,
            tuned: 1,
            clients: ClientStats {
                connected: 2,
                total: 4,
                quarantined: 1,
                idle_reaped: 1,
                oversized_lines: 2,
                disconnects: 1,
            },
        };
        let line = encode_stats(Some("st"), &report);
        let (id, back) = parse_stats(&line).unwrap();
        assert_eq!(id.as_deref(), Some("st"));
        assert_eq!(back, report);
    }

    #[test]
    fn string_escaping_survives_the_wire() {
        let gpu = resolve_gpu("L20").unwrap();
        let req = PredictRequest::new(
            KernelConfig::RmsNorm { seq: 2, dim: 2 },
            gpu,
        )
        .tagged("a\"b\\c\nd");
        let line = encode_request(None, &req);
        let (_, back) = parse_request(&line);
        assert_eq!(back.unwrap().opts.tag.as_deref(), Some("a\"b\\c\nd"));
    }
}
